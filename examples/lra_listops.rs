//! LRA-analog example: train the small classifier on ListOps-lite with
//! MRA-2 attention and report held-out accuracy (one row of the Table 5
//! substitute; `mra lra --task all` runs every task x attention variant).
//!
//! ```bash
//! make artifacts
//! cargo run --release --example lra_listops -- --steps 120
//! ```

use anyhow::Result;

use mra::cli::Args;
use mra::data::lra::LraTask;
use mra::runtime::{self, HostTensor};
use mra::tensor::Rng;

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let steps = args.usize_or("steps", 120)?;
    let attn = args.str_or("attention", "mra2");
    let artifacts = args.str_or("artifacts", "artifacts");

    let (rt, manifest) = runtime::spawn(&artifacts)?;
    let tag = format!("cls_{attn}_n128_d64_l2_h2_v64");
    let batch = 32usize;
    let seq = 128usize;
    let train_name = format!("train_{tag}_b{batch}");
    let eval_name = format!("eval_{tag}_b{batch}");
    let mut params = manifest.load_f32(&format!("{tag}.params.f32"))?;
    let n = params.len();
    let (mut m, mut v) = (vec![0.0f32; n], vec![0.0f32; n]);
    let task = LraTask::ListOps;
    let mut rng = Rng::new(0);

    println!("training {tag} on ListOps-lite for {steps} steps");
    let mut first_loss = None;
    let mut last_loss = 0.0f32;
    for step in 0..steps {
        let b = task.batch(batch, seq, &mut rng);
        let inputs = vec![
            HostTensor::F32(params, vec![n]),
            HostTensor::F32(m, vec![n]),
            HostTensor::F32(v, vec![n]),
            HostTensor::scalar_f32(step as f32),
            HostTensor::I32(b.input_ids, vec![batch, seq]),
            HostTensor::I32(b.labels, vec![batch]),
        ];
        let mut out = rt.execute(&train_name, inputs)?;
        let acc = out.pop().unwrap().as_f32()?[0];
        let loss = out.pop().unwrap().as_f32()?[0];
        v = out.pop().unwrap().as_f32()?.to_vec();
        m = out.pop().unwrap().as_f32()?.to_vec();
        params = out.pop().unwrap().as_f32()?.to_vec();
        first_loss.get_or_insert(loss);
        last_loss = loss;
        if step % 20 == 0 {
            println!("step {step:>4}  loss {loss:.3}  train-acc {acc:.3}");
        }
    }
    assert!(last_loss < first_loss.unwrap(), "loss did not decrease");

    let mut eval_rng = Rng::new(0xE7A1);
    let mut acc_sum = 0.0;
    for _ in 0..4 {
        let b = task.batch(batch, seq, &mut eval_rng);
        let inputs = vec![
            HostTensor::F32(params.clone(), vec![n]),
            HostTensor::I32(b.input_ids, vec![batch, seq]),
            HostTensor::I32(b.labels, vec![batch]),
        ];
        let out = rt.execute(&eval_name, inputs)?;
        acc_sum += out[1].as_f32()?[0];
    }
    println!("held-out accuracy: {:.3}", acc_sum / 4.0);
    println!("lra_listops OK");
    Ok(())
}
