//! Serving demo: start the coordinator (batcher -> workers) over the MRA-2
//! MLM model and fire concurrent requests, printing latency/throughput —
//! the serving-paper shape of the evaluation.
//!
//! With `artifacts/` built the workers execute the AOT model through PJRT;
//! without it (or without the `pjrt` feature) batches route through the
//! native parallel batched engine instead, so the demo always runs.
//!
//! ```bash
//! cargo run --release --example serve_batch -- --requests 64 --clients 4
//! # optional: make artifacts   (switches to the AOT path)
//! ```

use std::sync::Arc;

use anyhow::Result;

use mra::cli::Args;
use mra::config::ServeConfig;
use mra::coordinator::{NativeMlmConfig, Server};
use mra::data::{Corpus, CorpusConfig};
use mra::engine::pool;

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let requests = args.usize_or("requests", 64)?;
    let clients = args.usize_or("clients", 4)?.max(1);
    let artifacts = args.str_or("artifacts", "artifacts");
    let model = args.str_or("model", "mlm_mra2_n128_d128_l2_h2_v512");
    let threads = args.usize_or("threads", pool::default_threads())?;

    let cfg = ServeConfig {
        model: model.clone(),
        artifacts_dir: artifacts.clone(),
        max_batch: args.usize_or("max-batch", 8)?,
        flush_us: args.usize_or("flush-us", 2000)? as u64,
        workers: args.usize_or("workers", 2)?,
        queue_depth: 256,
    };
    // the AOT path needs both artifacts/ *and* a PJRT-capable build; the
    // stub runtime (no `pjrt-xla` backend) can parse manifests but not
    // execute HLO, so route straight to the native engine in that case
    let spawned = if cfg!(feature = "pjrt-xla") {
        mra::runtime::spawn(&artifacts).map_err(|e| format!("{e:#}"))
    } else {
        Err("built without the `pjrt-xla` backend".to_string())
    };
    let (server, seq_len, vocab) = match spawned {
        Ok((rt, manifest)) => {
            let model_cfg = manifest.load_cfg(&model)?;
            let seq_len: usize = model_cfg["seq_len"].parse()?;
            let vocab: usize = model_cfg["vocab"].parse()?;
            println!(
                "serving {model} from AOT artifacts (seq_len {seq_len}, max_batch {})",
                cfg.max_batch
            );
            (Server::start(rt, manifest, cfg.clone())?, seq_len, vocab)
        }
        Err(why) => {
            let mcfg = NativeMlmConfig::from_tag(&model);
            let (seq_len, vocab) = (mcfg.seq_len, mcfg.vocab);
            println!(
                "AOT path unavailable ({why});\nserving {model} through the native \
                 batched engine ({threads} attention threads, max_batch {})",
                cfg.max_batch
            );
            (Server::start_native(cfg.clone(), mcfg, threads)?, seq_len, vocab)
        }
    };
    let server = Arc::new(server);

    let t0 = std::time::Instant::now();
    let per_client = requests / clients;
    std::thread::scope(|s| {
        for c in 0..clients as u64 {
            let server = server.clone();
            s.spawn(move || {
                let mut corpus = Corpus::new(
                    CorpusConfig { vocab, seq_len, ..Default::default() },
                    100 + c,
                );
                for r in 0..per_client {
                    let toks = corpus.sequence();
                    match server.infer(toks.clone()) {
                        Ok(resp) => {
                            assert_eq!(resp.predictions.len(), toks.len());
                        }
                        Err(e) => eprintln!("client {c} req {r}: {e:#}"),
                    }
                }
            });
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    println!("{}", server.metrics.summary());
    println!(
        "throughput {:.1} req/s ({} requests / {:.2}s wall)",
        (per_client * clients) as f64 / wall,
        per_client * clients,
        wall
    );
    if let Ok(s) = Arc::try_unwrap(server) {
        s.shutdown();
    }
    println!("serve_batch OK");
    Ok(())
}
