//! Serving demo: start the coordinator (batcher -> bucket router -> PJRT
//! worker) over the MRA-2 MLM model and fire concurrent requests, printing
//! latency/throughput — the serving-paper shape of the evaluation.
//!
//! ```bash
//! make artifacts
//! cargo run --release --example serve_batch -- --requests 64 --clients 4
//! ```

use std::sync::Arc;

use anyhow::Result;

use mra::cli::Args;
use mra::config::ServeConfig;
use mra::coordinator::Server;
use mra::data::{Corpus, CorpusConfig};

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let requests = args.usize_or("requests", 64)?;
    let clients = args.usize_or("clients", 4)?.max(1);
    let artifacts = args.str_or("artifacts", "artifacts");
    let model = args.str_or("model", "mlm_mra2_n128_d128_l2_h2_v512");

    let (rt, manifest) = mra::runtime::spawn(&artifacts)?;
    let cfg = ServeConfig {
        model: model.clone(),
        artifacts_dir: artifacts,
        max_batch: args.usize_or("max-batch", 8)?,
        flush_us: args.usize_or("flush-us", 2000)? as u64,
        workers: 2,
        queue_depth: 256,
    };
    let model_cfg = manifest.load_cfg(&model)?;
    let seq_len: usize = model_cfg["seq_len"].parse()?;
    let vocab: usize = model_cfg["vocab"].parse()?;
    println!("serving {model} (seq_len {seq_len}) with max_batch {}", cfg.max_batch);
    let server = Arc::new(Server::start(rt, manifest, cfg)?);

    let t0 = std::time::Instant::now();
    let per_client = requests / clients;
    std::thread::scope(|s| {
        for c in 0..clients as u64 {
            let server = server.clone();
            s.spawn(move || {
                let mut corpus = Corpus::new(
                    CorpusConfig { vocab, seq_len, ..Default::default() },
                    100 + c,
                );
                for r in 0..per_client {
                    let toks = corpus.sequence();
                    match server.infer(toks.clone()) {
                        Ok(resp) => {
                            assert_eq!(resp.predictions.len(), toks.len());
                        }
                        Err(e) => eprintln!("client {c} req {r}: {e:#}"),
                    }
                }
            });
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    println!("{}", server.metrics.summary());
    println!(
        "throughput {:.1} req/s ({} requests / {:.2}s wall)",
        (per_client * clients) as f64 / wall,
        per_client * clients,
        wall
    );
    if let Ok(s) = Arc::try_unwrap(server) {
        s.shutdown();
    }
    println!("serve_batch OK");
    Ok(())
}
