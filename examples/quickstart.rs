//! Quickstart: the three-layer stack end to end on one attention call.
//!
//! 1. loads the AOT Pallas MRA-2 attention artifact (L1/L2, built by
//!    `make artifacts`) through the PJRT runtime,
//! 2. runs it on random Q/K/V from Rust (L3),
//! 3. cross-checks the numbers against (a) the exact-attention artifact and
//!    (b) the native Rust MRA-2 implementation.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use anyhow::Result;

use mra::mra::{mra2_attention, Variant};
use mra::runtime::{HostTensor, Runtime};
use mra::tensor::{ops, Mat, Rng};

fn main() -> Result<()> {
    let rt = Runtime::new("artifacts")?;
    println!("PJRT platform: {}", rt.platform());

    // shapes must match the compiled artifact: (1, 2, 256, 64)
    let (h, n, d) = (2usize, 256usize, 64usize);
    let mut rng = Rng::new(0);
    let mk = |rng: &mut Rng| -> Vec<f32> { (0..h * n * d).map(|_| rng.normal() * 0.5).collect() };
    let (q, k, v) = (mk(&mut rng), mk(&mut rng), mk(&mut rng));
    let dims = vec![1, h, n, d];
    let inputs = vec![
        HostTensor::F32(q.clone(), dims.clone()),
        HostTensor::F32(k.clone(), dims.clone()),
        HostTensor::F32(v.clone(), dims.clone()),
    ];

    // --- L1 Pallas MRA-2 kernel through PJRT --------------------------------
    let z_mra = rt.execute("attn_mra2_n256_h2_d64", &inputs)?;
    let z_mra = z_mra[0].as_f32()?.to_vec();
    // --- exact attention artifact -------------------------------------------
    let z_exact = rt.execute("attn_exact_n256_h2_d64", &inputs)?;
    let z_exact = z_exact[0].as_f32()?.to_vec();

    let rel = rel_err(&z_mra, &z_exact);
    println!("MRA-2 artifact vs exact artifact: rel error {rel:.4}");
    assert!(rel < 0.6, "approximation unexpectedly poor");

    // --- cross-check against the native Rust MRA core (per head) -----------
    let nb = n / 32;
    let mut worst = 0.0f64;
    for head in 0..h {
        let base = head * n * d;
        let qm = Mat::from_vec(n, d, q[base..base + n * d].to_vec());
        let km = Mat::from_vec(n, d, k[base..base + n * d].to_vec());
        let vm = Mat::from_vec(n, d, v[base..base + n * d].to_vec());
        let z_native = mra2_attention(&qm, &km, &vm, 32, 4 * nb, Variant::Full);
        let z_art = Mat::from_vec(n, d, z_mra[base..base + n * d].to_vec());
        worst = worst.max(ops::rel_fro_error(&z_art, &z_native));
    }
    println!("Pallas artifact vs native Rust MRA-2: rel diff {worst:.5}");
    assert!(worst < 5e-2, "kernel and native implementation disagree");
    println!("quickstart OK");
    Ok(())
}

fn rel_err(a: &[f32], b: &[f32]) -> f64 {
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for (x, y) in a.iter().zip(b) {
        num += ((x - y) as f64).powi(2);
        den += (*y as f64).powi(2);
    }
    (num / den).sqrt()
}
