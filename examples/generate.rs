//! Autoregressive generation demo on the session API: greedy decode over
//! the causal MRA-2 incremental engine (paged per-(layer, head) KV caches,
//! DESIGN.md §7/§9), streaming tokens as they are produced.  The same
//! prompt is then generated a *second* time against the same radix prefix
//! cache — the run must report a cache hit (the block-aligned prompt
//! prefix served from physically shared pages) and produce the identical
//! token stream.  Finally the prompt rides the serving path
//! (`Server::start_native_lm_sessions` + `Server::generate_stream`):
//! tokens arrive on a `TokenStream` as the continuous-batching scheduler
//! decodes them, and the streamed sequence is asserted bitwise identical
//! to the one-shot `Server::generate` result (greedy decoding is
//! deterministic, so streaming changes delivery, never content).
//!
//! Runs entirely on the native CPU path — no artifacts required.
//!
//! ```bash
//! cargo run --release --example generate -- --prompt-len 48 --new 32
//! cargo run --release --example generate -- --model lm_mra2_n256_d128_l2_h4_v512
//! ```

use std::io::Write;

use anyhow::Result;
use mra::cli::Args;
use mra::config::{ServeConfig, SessionConfig, TraceConfig};
use mra::coordinator::{GenOptions, NativeLm, NativeMlmConfig, Server};
use mra::data::{Corpus, CorpusConfig};
use mra::engine::pool;

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let model = args.str_or("model", "lm_mra2_n128_d128_l2_h2_v512");
    let prompt_len = args.usize_or("prompt-len", 48)?.max(1);
    let max_new = args.usize_or("new", 32)?.max(1);
    let threads = args.usize_or("threads", pool::default_threads())?;

    let mcfg = NativeMlmConfig::from_tag(&model);
    let lm = NativeLm::new(mcfg.clone(), threads);
    let cfg = lm.config();
    if prompt_len + max_new > cfg.seq_len {
        anyhow::bail!(
            "--prompt-len {prompt_len} + --new {max_new} exceeds seq_len {}",
            cfg.seq_len
        );
    }
    let mut corpus = Corpus::new(
        CorpusConfig { vocab: cfg.vocab, seq_len: cfg.seq_len, ..Default::default() },
        7,
    );
    let mut prompt = corpus.sequence();
    prompt.truncate(prompt_len);

    println!(
        "model {model} ({}), decode budget {} refined past blocks/step",
        lm.kernel_name(),
        lm.decode_budget()
    );
    print!("prompt :");
    for t in &prompt {
        print!(" {t}");
    }
    println!();

    // one shared page pool + radix prefix cache for both runs
    let kv_pool = lm.new_page_pool(4096);
    let mut cache = lm.new_radix_cache();

    print!("stream :");
    let t0 = std::time::Instant::now();
    let mut session = lm.new_session(&prompt, &kv_pool, Some(&mut cache))?;
    let t_prefill = std::time::Instant::now();
    let mut toks = Vec::with_capacity(max_new);
    for _ in 0..max_new {
        let tok = lm.session_step(&mut session)?;
        toks.push(tok);
        print!(" {tok}");
        let _ = std::io::stdout().flush();
    }
    let t_end = std::time::Instant::now();
    let prefill_ms = t_prefill.duration_since(t0).as_secs_f64() * 1e3;
    let decode_s = t_end.duration_since(t_prefill).as_secs_f64();
    println!(
        "\n{} tokens (prefill {prompt_len} tokens in {prefill_ms:.1} ms; decode {:.1} \
         tokens/s; context {prompt_len} -> {})",
        toks.len(),
        toks.len() as f64 / decode_s.max(1e-9),
        prompt_len + max_new
    );
    // the session path is bitwise identical to the plain generate() path
    assert_eq!(toks, lm.generate(&prompt, max_new)?, "session decode != generate()");

    // the same prompt again: the block-aligned prefix must be served from
    // the radix cache (physically shared pages), with identical output
    let expected_cached = (prompt.len() - 1) / cfg.block * cfg.block;
    let mut warm = lm.new_session(&prompt, &kv_pool, Some(&mut cache))?;
    assert_eq!(
        warm.cached_tokens(),
        expected_cached,
        "second run must hit the prefix cache for every complete prompt block"
    );
    let warm_toks: Vec<i32> =
        (0..max_new).map(|_| lm.session_step(&mut warm)).collect::<Result<_>>()?;
    assert_eq!(warm_toks, toks, "cache-hit decode must be bitwise identical");
    println!(
        "replay : cache hit on {}/{} prompt tokens (shared pages, {} in pool), identical \
         {}-token stream",
        warm.cached_tokens(),
        prompt_len,
        kv_pool.pages_in_use(),
        warm_toks.len()
    );

    // the same prompt through the serving path: generation requests ride
    // the continuous-batching session scheduler
    let serve = ServeConfig {
        max_batch: 4,
        flush_us: 500,
        workers: 1,
        queue_depth: 64,
        model: model.clone(),
        artifacts_dir: "artifacts".to_string(),
    };
    let scfg = SessionConfig {
        total_pages: 4096,
        // record this request's timeline in the flight recorder
        trace: TraceConfig { enabled: true, capacity: 1024 },
        ..Default::default()
    };
    let server = Server::start_native_lm_sessions(serve, mcfg, threads, scfg)?;
    print!("server :");
    let mut stream = server.generate_stream(prompt.clone(), GenOptions::new(max_new))?;
    let mut streamed = Vec::with_capacity(max_new);
    for tok in stream.by_ref() {
        streamed.push(tok);
        print!(" {tok}");
        let _ = std::io::stdout().flush();
    }
    let resp = stream.wait()?;
    assert_eq!(
        streamed, resp.predictions,
        "every streamed token must appear exactly once, in response order"
    );
    assert_eq!(resp.predictions, toks, "server decode must match the direct path");
    // one-shot delivery of the same request: greedy decoding is
    // deterministic, so streaming only changes *when* tokens arrive
    let oneshot = server.generate(prompt.clone(), max_new)?;
    assert_eq!(
        oneshot.predictions, streamed,
        "stream and one-shot must be bitwise identical under greedy decoding"
    );
    println!(
        "\nserver : {} tokens streamed via the session scheduler in {:.1} ms (bitwise \
         identical to one-shot)",
        resp.predictions.len(),
        resp.latency.as_secs_f64() * 1e3
    );
    // observability: the flight recorder saw both requests end to end, and
    // the per-phase step timing accounts for where the step time went
    let dump = server.dump_trace().expect("tracing was enabled");
    let decodes = dump.lines().filter(|l| l.contains("\"ev\":\"Decode\"")).count();
    let snap = server.metrics_snapshot();
    let decode_attend =
        snap.phases[mra::coordinator::StepPhase::DecodeAttend.index()].sum_us();
    println!(
        "trace  : {} events ({decodes} decodes); decode-attend phase spent {decode_attend} us",
        dump.lines().count()
    );
    assert!(decodes > 0, "the trace must contain the decoded tokens");
    server.shutdown();
    println!("generate OK");
    Ok(())
}
