//! Autoregressive generation demo: greedy decode over the causal MRA-2
//! incremental engine (per-(layer, head) KV caches, DESIGN.md §7),
//! streaming tokens as they are produced, then the same prompt through the
//! serving path (`Server::start_native_lm` + `Server::generate`) to show
//! generation requests riding the dynamic batcher.
//!
//! Runs entirely on the native CPU path — no artifacts required.
//!
//! ```bash
//! cargo run --release --example generate -- --prompt-len 16 --new 32
//! cargo run --release --example generate -- --model lm_mra2_n256_d128_l2_h4_v512
//! ```

use std::io::Write;

use anyhow::Result;
use mra::cli::Args;
use mra::config::ServeConfig;
use mra::coordinator::{NativeLm, NativeMlmConfig, Server};
use mra::data::{Corpus, CorpusConfig};
use mra::engine::pool;

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let model = args.str_or("model", "lm_mra2_n128_d128_l2_h2_v512");
    let prompt_len = args.usize_or("prompt-len", 16)?.max(1);
    let max_new = args.usize_or("new", 32)?.max(1);
    let threads = args.usize_or("threads", pool::default_threads())?;

    let mcfg = NativeMlmConfig::from_tag(&model);
    let lm = NativeLm::new(mcfg.clone(), threads);
    let cfg = lm.config();
    if prompt_len + max_new > cfg.seq_len {
        anyhow::bail!(
            "--prompt-len {prompt_len} + --new {max_new} exceeds seq_len {}",
            cfg.seq_len
        );
    }
    let mut corpus = Corpus::new(
        CorpusConfig { vocab: cfg.vocab, seq_len: cfg.seq_len, ..Default::default() },
        7,
    );
    let mut prompt = corpus.sequence();
    prompt.truncate(prompt_len);

    println!(
        "model {model} ({}), decode budget {} refined past blocks/step",
        lm.kernel_name(),
        lm.decode_budget()
    );
    print!("prompt :");
    for t in &prompt {
        print!(" {t}");
    }
    println!();

    print!("stream :");
    let t0 = std::time::Instant::now();
    // the first callback fires right after prefill, before any decode
    // step for generated tokens — split the timing there so tokens/s
    // measures decode only (consistent with bench_decode)
    let mut t_first = None;
    let toks = lm.generate_with(&prompt, max_new, |_, tok| {
        if t_first.is_none() {
            t_first = Some(std::time::Instant::now());
        }
        print!(" {tok}");
        let _ = std::io::stdout().flush();
    })?;
    let t_end = std::time::Instant::now();
    let t_first = t_first.unwrap_or(t_end);
    let prefill_ms = t_first.duration_since(t0).as_secs_f64() * 1e3;
    let decode_s = t_end.duration_since(t_first).as_secs_f64();
    let decode_steps = toks.len().saturating_sub(1);
    print!(
        "\n{} tokens (prefill {} tokens in {prefill_ms:.1} ms",
        toks.len(),
        prompt_len
    );
    if decode_steps > 0 {
        print!("; decode {:.1} tokens/s", decode_steps as f64 / decode_s.max(1e-9));
    }
    println!("; context {} -> {})", prompt_len, prompt_len + max_new);

    // the same prompt through the serving path: generation requests ride
    // the dynamic batcher exactly like MLM inference
    let serve = ServeConfig {
        max_batch: 4,
        flush_us: 500,
        workers: 1,
        queue_depth: 64,
        model: model.clone(),
        artifacts_dir: "artifacts".to_string(),
    };
    let server = Server::start_native_lm(serve, mcfg, threads)?;
    let resp = server.generate(prompt.clone(), max_new)?;
    assert_eq!(resp.predictions, toks, "server decode must match the direct path");
    println!(
        "server : {} tokens via the batcher in {:.1} ms (bitwise identical)",
        resp.predictions.len(),
        resp.latency.as_secs_f64() * 1e3
    );
    server.shutdown();
    println!("generate OK");
    Ok(())
}
