//! Session-serving demo: N clients share a system prompt through the
//! continuous-batching scheduler (`Server::start_native_lm_sessions`), and
//! a direct-API segment forks one session several ways and decodes the
//! forks interleaved — showing that the shared prefix is *physically* the
//! same memory (page pointers and pool occupancy), not a numeric copy.
//!
//! Clients consume their responses **token by token** over
//! `Server::generate_stream` with mixed QoS priorities, and each asserts
//! its streamed sequence is bitwise identical to the one-shot
//! `Server::generate` result (greedy decoding).  A final request with an
//! already-expired admission deadline shows deadline-expired waiters being
//! answered with a descriptive error instead of hanging.
//!
//! Runs entirely on the native CPU path — no artifacts required.
//!
//! ```bash
//! cargo run --release --example serve_sessions -- --clients 6 --new 24
//! cargo run --release --example serve_sessions -- --model lm_mra2_n1024_d64_l2_h2_v256
//! ```

use std::sync::Arc;

use anyhow::Result;
use mra::cli::Args;
use mra::config::{ServeConfig, SessionConfig, TraceConfig};
use mra::coordinator::{
    GenOptions, LmSession, NativeLm, NativeMlmConfig, Server, PRIORITY_NORMAL,
};
use mra::engine::pool;

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let model = args.str_or("model", "lm_mra2_n256_d64_l2_h2_v512");
    let clients = args.usize_or("clients", 6)?.max(1);
    let max_new = args.usize_or("new", 24)?.max(1);
    let threads = args.usize_or("threads", pool::default_threads())?;

    let mcfg = NativeMlmConfig::from_tag(&model);
    let lm = NativeLm::new(mcfg.clone(), threads);
    let cfg = lm.config().clone();
    let block = cfg.block;
    // shared system prompt: two cacheable blocks, then per-client suffixes
    let sys_len = 2 * block;
    if sys_len + block + max_new > cfg.seq_len {
        anyhow::bail!("--new {max_new} too large for seq_len {}", cfg.seq_len);
    }
    let system: Vec<i32> = (0..sys_len).map(|i| 2 + (i as i32 * 5) % 60).collect();

    // ---- part 1: fork + interleaved decode on the direct session API ---
    println!("== fork demo: {model} ({}) ==", lm.kernel_name());
    let kv_pool = lm.new_page_pool(1024);
    let mut cache = lm.new_radix_cache();
    let base = lm.new_session(&system, &kv_pool, Some(&mut cache))?;
    let pages_base = kv_pool.pages_in_use();
    let fanout = 3usize;
    let mut forks: Vec<LmSession> = (0..fanout).map(|_| base.fork()).collect();
    assert_eq!(
        kv_pool.pages_in_use(),
        pages_base,
        "forking must clone page handles, not pages"
    );
    // every fork's first page IS the base session's first page
    for f in &forks {
        assert!(Arc::ptr_eq(&base.states()[0].pages()[0], &f.states()[0].pages()[0]));
    }
    println!(
        "forked {fanout} sessions off a {sys_len}-token prompt: {} physical pages before \
         and after (handles shared)",
        pages_base
    );
    // diverge each fork with its own continuation, then decode interleaved
    for (fi, fork) in forks.iter_mut().enumerate() {
        let suffix: Vec<i32> = (0..4).map(|j| 3 + (fi * 7 + j) as i32 % 50).collect();
        lm.extend_session(fork, &suffix)?;
    }
    let mut streams: Vec<Vec<i32>> = vec![Vec::new(); fanout];
    for _ in 0..8 {
        // round-robin, one token per fork per round (the scheduler's
        // continuous batching does exactly this across sessions)
        for (fi, fork) in forks.iter_mut().enumerate() {
            streams[fi].push(lm.session_step(fork)?);
        }
    }
    for (fi, toks) in streams.iter().enumerate() {
        println!("  fork {fi}: {toks:?}");
    }
    println!(
        "pool after divergence: {} pages in use (shared prefix still single-copy)\n",
        kv_pool.pages_in_use()
    );

    // ---- part 2: N clients through the continuous-batching server ------
    println!("== serving demo: {clients} clients, shared {sys_len}-token system prompt ==");
    let serve = ServeConfig {
        max_batch: 8,
        flush_us: 1_000,
        workers: 1,
        queue_depth: 256,
        model: model.clone(),
        artifacts_dir: "artifacts".to_string(),
    };
    let scfg = SessionConfig {
        total_pages: 2048,
        free_watermark: 16,
        max_running: 32,
        prefix_cache: true,
        // one block per step keeps the demo's interleaving visible in the
        // prefill_chunks / prefill_backlog metrics below
        prefill_chunk_tokens: block,
        // flight recorder on: every Admit/PrefillChunk/Decode/Finish below
        // lands in a 4096-event ring we dump as JSON lines at the end
        trace: TraceConfig { enabled: true, capacity: 4096 },
        ..SessionConfig::default()
    };
    let server = Arc::new(Server::start_native_lm_sessions(serve, mcfg, threads, scfg)?);
    let t0 = std::time::Instant::now();
    std::thread::scope(|s| {
        for c in 0..clients {
            let server = server.clone();
            let mut prompt = system.clone();
            s.spawn(move || {
                prompt.extend((0..6).map(|j| 4 + (c * 11 + j) as i32 % 40));
                // alternate QoS priorities: even clients boosted, odd ones
                // deprioritized (aging still guarantees the odd ones run)
                let prio =
                    if c % 2 == 0 { PRIORITY_NORMAL + 10 } else { PRIORITY_NORMAL - 10 };
                let opts = GenOptions::new(max_new).priority(prio);
                let mut stream =
                    server.generate_stream(prompt.clone(), opts).expect("stream");
                let streamed: Vec<i32> = stream.by_ref().collect();
                let resp = stream.wait().expect("generate");
                assert_eq!(resp.predictions.len(), max_new);
                assert_eq!(
                    streamed, resp.predictions,
                    "streamed tokens must equal the final response exactly"
                );
                // greedy decoding: one-shot delivery of the same prompt is
                // bitwise identical to the streamed sequence
                let oneshot = server.generate(prompt, max_new).expect("one-shot");
                assert_eq!(
                    oneshot.predictions, streamed,
                    "stream and one-shot must be bitwise identical under greedy"
                );
            });
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    println!("{}", server.metrics.summary());
    let hit_tokens = server
        .metrics
        .prefix_hit_tokens
        .load(std::sync::atomic::Ordering::Relaxed);
    println!(
        "served {clients} clients in {:.1} ms — {hit_tokens} prompt tokens reused from \
         shared prefix pages",
        wall * 1e3
    );
    if clients > 1 {
        assert!(
            hit_tokens >= sys_len as u64,
            "clients sharing a system prompt must hit the radix cache"
        );
    }
    // a request whose admission deadline has already passed is answered
    // with a descriptive error instead of hanging its client (deadline
    // expiry runs before admission each step, so a zero TTL always fires)
    let expired = server.generate_opts(
        system.clone(),
        GenOptions::new(max_new).deadline(std::time::Duration::ZERO),
    );
    let err = expired.expect_err("a zero admission deadline must expire");
    assert!(
        err.to_string().contains("admission deadline"),
        "expiry error must be descriptive, got: {err}"
    );
    println!("deadline: zero-TTL request answered with a descriptive error");

    // ---- part 3: observability surfaces -------------------------------
    // per-phase step timing, scraped through the typed snapshot
    let snap = server.metrics_snapshot();
    println!(
        "step phases (mean us): prefill_attend={:.0} decode_attend={:.0} logits={:.0}",
        snap.phases[mra::coordinator::StepPhase::PrefillAttend.index()].mean_us(),
        snap.phases[mra::coordinator::StepPhase::DecodeAttend.index()].mean_us(),
        snap.phases[mra::coordinator::StepPhase::Logits.index()].mean_us(),
    );
    assert!(
        snap.phases[mra::coordinator::StepPhase::DecodeAttend.index()].count() > 0,
        "serving must have recorded decode-attend phase samples"
    );
    // Prometheus text exposition — the body a /metrics endpoint would serve
    let prom = server.render_metrics();
    assert!(prom.contains("mra_generated_tokens_total"), "exposition missing counters");
    println!("prometheus exposition: {} bytes, {} series lines", prom.len(), prom.lines().count());
    // flight-recorder dump: one JSON line per event, chronological
    let dump = server.dump_trace().expect("tracing was enabled");
    let admits = dump.lines().filter(|l| l.contains("\"ev\":\"Admit\"")).count();
    let finishes = dump.lines().filter(|l| l.contains("\"ev\":\"Finish\"")).count();
    println!(
        "flight recorder: {} events ({admits} admits, {finishes} finishes)",
        dump.lines().count()
    );
    assert!(admits > 0 && finishes > 0, "trace must show the served requests");

    if let Ok(s) = Arc::try_unwrap(server) {
        s.shutdown();
    }
    println!("serve_sessions OK");
    Ok(())
}
