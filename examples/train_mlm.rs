//! **End-to-end driver** (DESIGN.md §6): train an MLM transformer with
//! MRA-2 attention for a few hundred steps on the synthetic corpus —
//! entirely from Rust over the AOT `train_step` artifact — and log the
//! loss curve.  Optionally trains the exact-attention model for the same
//! budget and compares the curves (the Tab. 2 "from scratch" check).
//!
//! ```bash
//! make artifacts
//! cargo run --release --example train_mlm -- --steps 300 --compare-exact
//! ```

use anyhow::Result;

use mra::cli::Args;
use mra::config::TrainConfig;
use mra::coordinator::Trainer;

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let steps = args.usize_or("steps", 300)?;
    let batch = args.usize_or("batch", 32)?;
    let artifacts = args.str_or("artifacts", "artifacts");
    let compare = args.bool("compare-exact");

    let (rt, manifest) = mra::runtime::spawn(&artifacts)?;
    let mut results = Vec::new();
    let mut variants = vec!["mra2"];
    if compare {
        variants.push("exact");
    }
    for attn in variants {
        let cfg = TrainConfig {
            steps,
            batch,
            eval_every: (steps / 4).max(1),
            seed: 0,
            model: format!("mlm_{attn}_n128_d128_l2_h2_v512"),
            artifacts_dir: artifacts.clone(),
            log_every: (steps / 20).max(1),
        };
        println!("=== training {} for {steps} steps (batch {batch}) ===", cfg.model);
        let mut trainer = Trainer::new(rt.clone(), manifest.clone(), cfg)?;
        let t0 = std::time::Instant::now();
        let log = trainer.run()?;
        let wall = t0.elapsed().as_secs_f64();
        let (head, tail) = log.head_tail_means(3);
        let (eval_loss, eval_acc) = trainer.eval()?;
        println!(
            "{attn}: loss {head:.3} -> {tail:.3}, eval loss {eval_loss:.3}, \
             eval masked-acc {eval_acc:.3}, {:.0} ms/step",
            wall * 1e3 / steps as f64
        );
        assert!(tail < head, "loss did not decrease: {head} -> {tail}");
        results.push((attn, tail, eval_acc));
    }
    println!("\nloss curve summary:");
    for (attn, tail, acc) in &results {
        println!("  {attn:<6} final-loss {tail:.3} masked-acc {acc:.3}");
    }
    println!("train_mlm OK");
    Ok(())
}
