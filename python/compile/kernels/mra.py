"""Layer-1 Pallas kernels for MRA-2 approximate self-attention.

This module implements the paper's practical scheme (Sec. 4) for the
two-scale pyramid ``R = {b, 1}`` used by **MRA-2** and **MRA-2-s**:

1. ``pool``          — Eq. (7): average-pool Q/K/V rows into the pyramid.
2. ``lowres_scores`` — block-mean score matrix ``S = Q~ K~^T / sqrt(d)``
                       whose exponential is the Jensen bound mu (Eq. 6).
3. ``block_scores``  — exact ``b x b`` score tiles for the selected blocks
                       (the scale-1 refinement of Alg. 1).
4. ``block_attn``    — stabilized ``exp`` + value aggregation per selected
                       block (the high-resolution half of Alg. 2).

The data-dependent parts (``top_k`` selection, gathers, segment reductions)
live between kernels as plain jnp/lax ops: on a real TPU they would be
expressed through the BlockSpec index map (scalar prefetch), but they are
memory movement, not FLOPs, and XLA lowers them natively.

TPU adaptation (DESIGN.md §4): each kernel instance works on ``b x d`` tiles
staged HBM->VMEM by its BlockSpec; the ``b x d @ d x b`` products are MXU
shaped.  ``interpret=True`` everywhere — the CPU PJRT plugin cannot execute
Mosaic custom calls, so interpret mode is the correctness (and AOT) path.

All kernels are single-head; use :func:`mra2_attention` for batched
multi-head inputs (vmapped).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

INTERPRET = True  # CPU PJRT: Mosaic custom-calls are not executable.

# A large additive boost that forces diagonal blocks to the front of the
# top-k selection (Alg. 1's "initial J prespecified via priors").
_DIAG_BOOST = 1e9


# ---------------------------------------------------------------------------
# kernel 1: pyramid pooling (Eq. 7)
# ---------------------------------------------------------------------------

def _pool_kernel(x_ref, o_ref, *, inv_b):
    # x_ref: (b, d) tile; o_ref: (1, d).  Mean over the block's rows.
    o_ref[...] = jnp.sum(x_ref[...], axis=0, keepdims=True) * inv_b


def pool(x: jax.Array, b: int) -> jax.Array:
    """Average-pool rows: ``(n, d) -> (n/b, d)`` (Pallas kernel)."""
    n, d = x.shape
    assert n % b == 0, f"block size {b} must divide n={n}"
    nb = n // b
    return pl.pallas_call(
        functools.partial(_pool_kernel, inv_b=1.0 / b),
        grid=(nb,),
        in_specs=[pl.BlockSpec((b, d), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, d), x.dtype),
        interpret=INTERPRET,
    )(x)


# ---------------------------------------------------------------------------
# kernel 2: low-resolution scores  S[x, y] = q~_x . k~_y / sqrt(d)
# ---------------------------------------------------------------------------

def _scores_kernel(qt_ref, kt_ref, o_ref, *, scale):
    # qt_ref: (tb, d); kt_ref: (nb, d); o_ref: (tb, nb).
    o_ref[...] = jnp.dot(
        qt_ref[...], kt_ref[...].T, preferred_element_type=jnp.float32
    ) * scale


def lowres_scores(qt: jax.Array, kt: jax.Array, tile: int = 0) -> jax.Array:
    """``(nb, d) x (nb, d) -> (nb, nb)`` block-mean score matrix (Pallas)."""
    nb, d = qt.shape
    tile = tile or nb  # one MXU tile is plenty at bench sizes
    assert nb % tile == 0
    scale = 1.0 / math.sqrt(d)
    return pl.pallas_call(
        functools.partial(_scores_kernel, scale=scale),
        grid=(nb // tile,),
        in_specs=[
            pl.BlockSpec((tile, d), lambda i: (i, 0)),
            pl.BlockSpec((nb, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile, nb), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, nb), jnp.float32),
        interpret=INTERPRET,
    )(qt, kt)


# ---------------------------------------------------------------------------
# kernel 3: exact scores for the selected blocks
# ---------------------------------------------------------------------------

def _block_scores_kernel(qb_ref, kb_ref, o_ref, *, scale):
    # qb_ref/kb_ref: (1, b, d); o_ref: (1, b, b).
    o_ref[0] = jnp.dot(
        qb_ref[0], kb_ref[0].T, preferred_element_type=jnp.float32
    ) * scale


def block_scores(qb: jax.Array, kb: jax.Array) -> jax.Array:
    """Exact ``P`` tiles for gathered blocks: ``(m,b,d),(m,b,d)->(m,b,b)``."""
    m, b, d = qb.shape
    scale = 1.0 / math.sqrt(d)
    return pl.pallas_call(
        functools.partial(_block_scores_kernel, scale=scale),
        grid=(m,),
        in_specs=[
            pl.BlockSpec((1, b, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, b, d), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, b, b), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((m, b, b), jnp.float32),
        interpret=INTERPRET,
    )(qb, kb)


# ---------------------------------------------------------------------------
# kernel 4: stabilized exp + per-block value aggregation
# ---------------------------------------------------------------------------

def _block_attn_kernel(p_ref, vb_ref, mx_ref, num_ref, den_ref):
    # p_ref: (1, b, b); vb_ref: (1, b, d); mx_ref: (1, 1) per-block max shift.
    a = jnp.exp(p_ref[0] - mx_ref[0, 0])                     # (b, b)
    num_ref[0] = jnp.dot(a, vb_ref[0], preferred_element_type=jnp.float32)
    den_ref[0] = jnp.sum(a, axis=-1)


def block_attn(p_hi: jax.Array, vb: jax.Array, mx: jax.Array):
    """Per-block ``exp(P - mx)`` numerator/denominator.

    ``p_hi (m,b,b)``, ``vb (m,b,d)``, ``mx (m,)`` -> ``num (m,b,d)``,
    ``den (m,b)``.
    """
    m, b, _ = p_hi.shape
    d = vb.shape[-1]
    return pl.pallas_call(
        _block_attn_kernel,
        grid=(m,),
        in_specs=[
            pl.BlockSpec((1, b, b), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, b, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, b, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, b), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, b, d), jnp.float32),
            jax.ShapeDtypeStruct((m, b), jnp.float32),
        ],
        interpret=INTERPRET,
    )(p_hi, vb, mx.reshape(m, 1))


# ---------------------------------------------------------------------------
# full MRA-2 head: Alg. 1 (two scales) + Alg. 2
# ---------------------------------------------------------------------------

def mra2_attention_head(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    block: int = 32,
    num_blocks: int = 0,
    variant: str = "full",
    use_pallas: bool = True,
) -> jax.Array:
    """MRA-2 (``variant='full'``) / MRA-2-s (``'sparse'``) for one head.

    ``num_blocks`` is the Alg.-1 budget ``m_1`` (count of ``block x block``
    regions refined to exact resolution); 0 means ``4 * n/block`` (the
    paper's linear-budget regime ``O(m_1 n)``).  Differentiable when
    ``use_pallas=False`` — training artifacts use the jnp path, inference
    artifacts the Pallas path; both are validated equal in pytest.
    """
    n, d = q.shape
    b = block
    assert n % b == 0, f"block {b} must divide n={n}"
    nb = n // b
    m = num_blocks or 4 * nb
    m = min(m, nb * nb)
    q32 = q.astype(jnp.float32)
    k32 = k.astype(jnp.float32)
    v32 = v.astype(jnp.float32)

    if use_pallas:
        qt, kt, vt = pool(q32, b), pool(k32, b), pool(v32, b)
        s_low = lowres_scores(qt, kt)
    else:
        qt = q32.reshape(nb, b, d).mean(axis=1)
        kt = k32.reshape(nb, b, d).mean(axis=1)
        vt = v32.reshape(nb, b, d).mean(axis=1)
        s_low = qt @ kt.T / math.sqrt(d)

    # --- Alg. 1: pick the m blocks with the largest mu (diagonal seeded) ---
    # NOTE: argsort (HLO `sort`) instead of lax.top_k — jax lowers top_k to
    # the `topk` HLO custom op whose text form xla_extension 0.5.1 cannot
    # parse (the AOT interchange constraint, see DESIGN.md §3).
    # Selection is non-differentiable (gradients flow through the gathered
    # values, not the choice) — stop_gradient *before* the sort so the
    # train-step lowering never needs sort's JVP.
    prio = s_low + _DIAG_BOOST * jnp.eye(nb, dtype=s_low.dtype)
    prio = lax.stop_gradient(prio)
    idx = jnp.argsort(-prio.reshape(-1))[:m]
    bx, by = idx // nb, idx % nb
    sel = jnp.zeros((nb * nb,), jnp.bool_).at[idx].set(True).reshape(nb, nb)

    # --- gather the selected Q/K/V row-blocks -----------------------------
    qb = q32.reshape(nb, b, d)[bx]            # (m, b, d)
    kb = k32.reshape(nb, b, d)[by]
    vb = v32.reshape(nb, b, d)[by]

    if use_pallas:
        p_hi = block_scores(qb, kb)           # (m, b, b)
    else:
        p_hi = jnp.einsum("mbd,mcd->mbc", qb, kb) / math.sqrt(d)

    # --- shared per-query-block max for a stable exp ----------------------
    hi_max = jax.ops.segment_max(
        p_hi.max(axis=(1, 2)), bx, num_segments=nb
    )                                                        # (nb,)
    if variant == "full":
        low_max = jnp.where(sel, -jnp.inf, s_low).max(axis=1)
        mb = jnp.maximum(hi_max, low_max)
    else:
        mb = hi_max                           # diagonal seeding => finite

    # --- high-resolution half of Alg. 2 ------------------------------------
    if use_pallas:
        num_hi, den_hi = block_attn(p_hi, vb, mb[bx])
    else:
        a_hi = jnp.exp(p_hi - mb[bx][:, None, None])
        num_hi = jnp.einsum("mbc,mcd->mbd", a_hi, vb)
        den_hi = a_hi.sum(axis=-1)
    y_hi = jax.ops.segment_sum(num_hi, bx, num_segments=nb)  # (nb, b, d)
    d_hi = jax.ops.segment_sum(den_hi, bx, num_segments=nb)  # (nb, b)

    # --- low-resolution half (MRA-2 only) ----------------------------------
    if variant == "full":
        a_low = jnp.where(sel, 0.0, jnp.exp(s_low - mb[:, None]))  # (nb, nb)
        y_low = (a_low @ vt) * b                                   # (nb, d)
        d_low = a_low.sum(axis=1) * b                              # (nb,)
        num = y_hi + y_low[:, None, :]
        den = d_hi + d_low[:, None]
    else:
        num, den = y_hi, d_hi

    out = num / jnp.maximum(den, 1e-30)[..., None]
    return out.reshape(n, d).astype(q.dtype)


def mra2_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    block: int = 32,
    num_blocks: int = 0,
    variant: str = "full",
    use_pallas: bool = True,
) -> jax.Array:
    """Batched multi-head MRA-2: ``(..., n, d)`` inputs, vmapped per head."""
    fn = functools.partial(
        mra2_attention_head,
        block=block,
        num_blocks=num_blocks,
        variant=variant,
        use_pallas=use_pallas,
    )
    if q.ndim == 2:
        return fn(q, k, v)
    flat_fn = fn
    for _ in range(q.ndim - 2):
        flat_fn = jax.vmap(flat_fn)
    return flat_fn(q, k, v)


def exact_attention(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Standard softmax attention over the same batched layout (baseline)."""
    d = q.shape[-1]
    p = jnp.einsum("...nd,...md->...nm", q, k) / math.sqrt(d)
    a = jax.nn.softmax(p, axis=-1)
    return jnp.einsum("...nm,...md->...nd", a, v)
