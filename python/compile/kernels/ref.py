"""Pure-jnp / numpy oracles for the MRA attention kernels.

Everything in this module is *reference* code: it materializes the dense
``n x n`` attention matrix and the dense MRA approximation ``A_hat`` exactly
as defined in the paper (Eqs. 1-6, Alg. 1, Alg. 2), with no regard for
efficiency.  The Pallas kernels in :mod:`compile.kernels.mra` and the Rust
implementation in ``rust/src/mra/`` are both validated against these
semantics.

Conventions (used across the whole repository):

* ``P = Q @ K.T / sqrt(d)``  (we keep the standard ``1/sqrt(d)`` scaling the
  paper omits "for notational simplicity").
* ``A = exp(P)`` unnormalized, ``Z = D^-1 A V`` with row-sum normalization.
* block size ``b`` divides ``n``; block ``(x, y)`` covers rows
  ``[x*b, (x+1)*b)`` and columns ``[y*b, (y+1)*b)``  (0-based, unlike the
  paper's 1-based ``(sx-s, sx]``).
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# exact attention
# ---------------------------------------------------------------------------

def exact_attention(q, k, v):
    """Standard softmax attention ``softmax(QK^T/sqrt(d)) V`` (single head)."""
    d = q.shape[-1]
    p = q @ k.T / np.sqrt(d)
    a = jnp.exp(p - p.max(axis=-1, keepdims=True))
    return a @ v / a.sum(axis=-1, keepdims=True)


def exact_unnormalized(q, k, v):
    """Return ``(A, AV)`` without softmax normalization (A = exp(P))."""
    d = q.shape[-1]
    p = q @ k.T / np.sqrt(d)
    a = jnp.exp(p)
    return a, a @ v


# ---------------------------------------------------------------------------
# pyramid pooling (Eq. 7)
# ---------------------------------------------------------------------------

def pool_rows(x, b):
    """Average ``b`` consecutive rows: (n, d) -> (n/b, d)."""
    n, d = x.shape
    assert n % b == 0, f"block {b} must divide n={n}"
    return x.reshape(n // b, b, d).mean(axis=1)


def pyramid(x, scales):
    """Return ``{s: pooled x at scale s}`` for every s in `scales` (1 = x)."""
    return {s: pool_rows(x, s) for s in scales}


# ---------------------------------------------------------------------------
# block scores mu (Eq. 6): exp of block-mean of P
# ---------------------------------------------------------------------------

def block_mean_scores(q, k, b):
    """(n/b, n/b) matrix of block means of P (the log of Eq. 6's mu)."""
    d = q.shape[-1]
    qt = pool_rows(q, b)
    kt = pool_rows(k, b)
    return qt @ kt.T / np.sqrt(d)


def mu_lower_bound(q, k, b):
    """Eq. 6: mu_{b,x,y} = exp(<B, P>/b^2) (Jensen lower bound of Eq. 4)."""
    return jnp.exp(block_mean_scores(q, k, b))


def mu_exact(q, k, b):
    """Eq. 4: mu*_{b,x,y} = block mean of exp(P)."""
    d = q.shape[-1]
    n = q.shape[0]
    p = q @ k.T / np.sqrt(d)
    a = jnp.exp(p)
    nb = n // b
    return a.reshape(nb, b, nb, b).mean(axis=(1, 3))


# ---------------------------------------------------------------------------
# block selection (Alg. 1 for R = {b, 1}) — MRA-2 / MRA-2-s
# ---------------------------------------------------------------------------

def select_blocks(q, k, b, m, include_diagonal=True):
    """Greedy Alg. 1 selection at two scales R = {b, 1}.

    Returns a boolean (n/b, n/b) mask of the blocks refined to scale 1
    (i.e. computed *exactly*), chosen as the ``m`` largest low-resolution
    scores.  ``include_diagonal`` force-includes the diagonal blocks (the
    "initial J prespecified via priors" input of Alg. 1 — the official
    implementation seeds the diagonal so every query block has at least one
    exact key block, which also guarantees a nonzero softmax denominator for
    the sparse MRA-2-s variant).
    """
    s = np.asarray(block_mean_scores(q, k, b))
    nb = s.shape[0]
    m = int(min(m, nb * nb))
    prio = s.copy()
    if include_diagonal:
        prio[np.arange(nb), np.arange(nb)] = np.inf
    flat = prio.reshape(-1)
    top = np.argsort(-flat, kind="stable")[:m]
    mask = np.zeros(nb * nb, dtype=bool)
    mask[top] = True
    return mask.reshape(nb, nb)


# ---------------------------------------------------------------------------
# dense MRA-2 approximation (Eqs. 5/6 + Alg. 2 semantics, materialized)
# ---------------------------------------------------------------------------

def dense_mra2(q, k, v, b, m, variant="full", include_diagonal=True):
    """Materialize ``A_hat`` for R = {b, 1} and return ``(A_hat, Z_hat)``.

    ``variant='full'`` is MRA-2: exact entries inside selected blocks and the
    low-resolution constant ``mu_{b,x,y}`` elsewhere.  ``variant='sparse'``
    is MRA-2-s: only the selected blocks (block-sparse exact attention).
    ``Z_hat`` is row-normalized: ``D_hat^-1 A_hat V``.
    """
    n, d = q.shape
    nb = n // b
    p = np.asarray(q @ k.T) / np.sqrt(d)
    sel = select_blocks(q, k, b, m, include_diagonal)
    mu = np.exp(np.asarray(block_mean_scores(q, k, b)))

    a_hat = np.zeros((n, n), dtype=np.float64)
    for x in range(nb):
        for y in range(nb):
            rs, cs = slice(x * b, (x + 1) * b), slice(y * b, (y + 1) * b)
            if sel[x, y]:
                a_hat[rs, cs] = np.exp(p[rs, cs])
            elif variant == "full":
                a_hat[rs, cs] = mu[x, y]
    den = a_hat.sum(axis=-1, keepdims=True)
    den = np.where(den == 0.0, 1.0, den)
    z_hat = a_hat @ np.asarray(v) / den
    return a_hat, z_hat


# ---------------------------------------------------------------------------
# general multi-scale reference (Alg. 1 + Alg. 2 for arbitrary R)
# ---------------------------------------------------------------------------

def dense_mra_general(q, k, v, scales, budgets, include_diagonal=True):
    """Dense reference for the general pyramid R = ``scales`` (descending).

    ``budgets[i]`` is ``m_{i+1}`` — how many scale-``scales[i]`` regions are
    refined into scale ``scales[i+1]`` blocks (Alg. 1).  Returns
    ``(A_hat, Z_hat)``.  Selection uses exp-of-mean scores (Eq. 6) at every
    scale, exactly like Alg. 1.
    """
    n, d = q.shape
    assert list(scales) == sorted(scales, reverse=True)
    assert len(budgets) == len(scales) - 1
    p = np.asarray(q @ k.T) / np.sqrt(d)

    def mean_scores(s):
        nb = n // s
        return p.reshape(nb, s, nb, s).mean(axis=(1, 3))

    s0 = scales[0]
    a_hat = np.zeros((n, n), dtype=np.float64)
    raw0 = mean_scores(s0)
    prio0 = raw0.copy()
    if include_diagonal and len(scales) > 1:
        for i in range(n // s0):
            prio0[i, i] = np.inf

    # `cur` maps surviving block (x, y) at the current scale to its selection
    # priority; `raw` holds its true mean score (for the final exp()).
    cur = {(x, y): prio0[x, y] for x in range(n // s0) for y in range(n // s0)}
    scale_of = scales[0]
    for level in range(1, len(scales)):
        s_prev, s_new = scales[level - 1], scales[level]
        raw_prev = mean_scores(s_prev)
        m = min(budgets[level - 1], len(cur))
        ranked = sorted(cur.items(), key=lambda kv: -kv[1])
        popped = [xy for xy, _ in ranked[:m]]
        # blocks NOT refined stay in J at scale s_prev
        for (x, y) in cur:
            if (x, y) not in set(popped):
                rs = slice(x * s_prev, (x + 1) * s_prev)
                cs = slice(y * s_prev, (y + 1) * s_prev)
                a_hat[rs, cs] = np.exp(raw_prev[x, y])
        ratio = s_prev // s_new
        raw_new = mean_scores(s_new)
        cur = {}
        for (x, y) in popped:
            for dx in range(ratio):
                for dy in range(ratio):
                    nx, ny = x * ratio + dx, y * ratio + dy
                    cur[(nx, ny)] = raw_new[nx, ny]
        scale_of = s_new
    # finest-level members of J
    raw_fin = mean_scores(scale_of)
    for (x, y) in cur:
        rs = slice(x * scale_of, (x + 1) * scale_of)
        cs = slice(y * scale_of, (y + 1) * scale_of)
        a_hat[rs, cs] = np.exp(raw_fin[x, y])
    den = a_hat.sum(axis=-1, keepdims=True)
    den = np.where(den == 0.0, 1.0, den)
    return a_hat, a_hat @ np.asarray(v) / den


# ---------------------------------------------------------------------------
# error metrics
# ---------------------------------------------------------------------------

def rel_fro_error(approx, exact):
    """||approx - exact||_F / ||exact||_F (the paper's relative error)."""
    approx = np.asarray(approx, dtype=np.float64)
    exact = np.asarray(exact, dtype=np.float64)
    return float(np.linalg.norm(approx - exact) / np.linalg.norm(exact))


def attention_entropy(q, k):
    """Mean softmax row entropy — the x-axis of Fig. 5 / Fig. 7 (right)."""
    d = q.shape[-1]
    p = np.asarray(q @ k.T) / np.sqrt(d)
    p = p - p.max(axis=-1, keepdims=True)
    a = np.exp(p)
    a /= a.sum(axis=-1, keepdims=True)
    ent = -(a * np.log(np.clip(a, 1e-30, None))).sum(axis=-1)
    return float(ent.mean())
