"""Layer-2 JAX model: RoBERTa-style encoder with pluggable attention.

This is the compute graph the paper plugs MRA attention into (Sec. 5):
a pre-LN transformer encoder with a masked-language-modeling head and a
sequence-classification head, plus an inlined Adam train step.  Everything
here is **build-time only** — :mod:`compile.aot` lowers jitted entry points
to HLO text and the Rust coordinator executes them; Python never appears on
the request path.

Parameter interchange: all parameters (and Adam moments) travel as a single
flat ``f32`` vector with a deterministic layout given by
:func:`param_specs`.  The Rust side treats the vector as opaque, which keeps
the PJRT call arity constant regardless of model size.

Attention variants (``ModelConfig.attention``):

* ``"exact"``  — standard softmax attention (the Transformer baseline row).
* ``"mra2"``   — MRA-2, two-scale pyramid ``R = {block, 1}`` (paper Sec. 5).
* ``"mra2s"``  — MRA-2-s, the block-sparse variant.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from compile.kernels import mra


# ---------------------------------------------------------------------------
# configuration
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture + attention hyperparameters (mirrors paper Tab. 8)."""

    vocab: int = 512
    seq_len: int = 128
    d_model: int = 128
    n_heads: int = 2
    n_layers: int = 2
    d_ff: int = 512
    num_classes: int = 10
    attention: str = "mra2"       # exact | mra2 | mra2s
    block: int = 32               # MRA-2 uses R = {32, 1} (paper Sec. 5)
    num_blocks: int = 0           # m_1 budget; 0 => 4 * n/block
    use_pallas: bool = False      # Pallas fwd for inference artifacts
    lr: float = 1e-3
    adam_b1: float = 0.9
    adam_b2: float = 0.999
    adam_eps: float = 1e-8

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def tag(self) -> str:
        return (
            f"{self.attention}_n{self.seq_len}_d{self.d_model}"
            f"_l{self.n_layers}_h{self.n_heads}_v{self.vocab}"
        )


# ---------------------------------------------------------------------------
# parameter layout
# ---------------------------------------------------------------------------

def param_specs(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Deterministic (name, shape) list defining the flat-vector layout."""
    d, f, v, n = cfg.d_model, cfg.d_ff, cfg.vocab, cfg.seq_len
    specs: List[Tuple[str, Tuple[int, ...]]] = [
        ("tok_emb", (v, d)),
        ("pos_emb", (n, d)),
    ]
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        specs += [
            (p + "ln1.g", (d,)), (p + "ln1.b", (d,)),
            (p + "wq", (d, d)), (p + "bq", (d,)),
            (p + "wk", (d, d)), (p + "bk", (d,)),
            (p + "wv", (d, d)), (p + "bv", (d,)),
            (p + "wo", (d, d)), (p + "bo", (d,)),
            (p + "ln2.g", (d,)), (p + "ln2.b", (d,)),
            (p + "w1", (d, f)), (p + "b1", (f,)),
            (p + "w2", (f, d)), (p + "b2", (d,)),
        ]
    specs += [
        ("ln_f.g", (d,)), ("ln_f.b", (d,)),
        ("mlm.w", (d, v)), ("mlm.b", (v,)),
        ("cls.w1", (d, d)), ("cls.b1", (d,)),
        ("cls.w2", (d, cfg.num_classes)), ("cls.b2", (cfg.num_classes,)),
    ]
    return specs


def param_count(cfg: ModelConfig) -> int:
    return sum(int(np.prod(s)) for _, s in param_specs(cfg))


def init_params(cfg: ModelConfig, seed: int = 0) -> np.ndarray:
    """Initialize the flat parameter vector (truncated-normal-ish / zeros)."""
    rng = np.random.default_rng(seed)
    chunks = []
    for name, shape in param_specs(cfg):
        if name.endswith((".b", ".b1", ".b2", "bq", "bk", "bv", "bo")) or \
                name.endswith(("ln1.b", "ln2.b", "ln_f.b", "mlm.b")):
            x = np.zeros(shape, np.float32)
        elif ".g" in name:
            x = np.ones(shape, np.float32)
        else:
            fan_in = shape[0] if len(shape) > 1 else int(np.prod(shape))
            x = rng.normal(0.0, 1.0 / math.sqrt(fan_in), shape)
        chunks.append(np.asarray(x, np.float32).reshape(-1))
    return np.concatenate(chunks)


def unpack(vec: jax.Array, cfg: ModelConfig) -> Dict[str, jax.Array]:
    """Flat f32 vector -> named parameter dict (static slicing)."""
    out, off = {}, 0
    for name, shape in param_specs(cfg):
        size = int(np.prod(shape))
        out[name] = vec[off:off + size].reshape(shape)
        off += size
    return out


def pack(params: Dict[str, np.ndarray], cfg: ModelConfig) -> np.ndarray:
    return np.concatenate(
        [np.asarray(params[n], np.float32).reshape(-1)
         for n, _ in param_specs(cfg)]
    )


# ---------------------------------------------------------------------------
# model blocks
# ---------------------------------------------------------------------------

def layer_norm(x, g, b, eps=1e-5):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def gelu(x):
    # tanh approximation — identical across jax/rust substrates
    return 0.5 * x * (1.0 + jnp.tanh(
        math.sqrt(2.0 / math.pi) * (x + 0.044715 * x ** 3)))


def _attention(cfg: ModelConfig, q, k, v):
    """Dispatch on cfg.attention; q/k/v are (B, H, n, d_head)."""
    if cfg.attention == "exact":
        return mra.exact_attention(q, k, v)
    variant = "full" if cfg.attention == "mra2" else "sparse"
    return mra.mra2_attention(
        q, k, v,
        block=cfg.block,
        num_blocks=cfg.num_blocks,
        variant=variant,
        use_pallas=cfg.use_pallas,
    )


def _mha(cfg: ModelConfig, p: Dict[str, jax.Array], prefix: str, x):
    bsz, n, d = x.shape
    h, dh = cfg.n_heads, cfg.d_head

    def proj(w, b):
        y = x @ p[prefix + w] + p[prefix + b]
        return y.reshape(bsz, n, h, dh).transpose(0, 2, 1, 3)

    q, k, v = proj("wq", "bq"), proj("wk", "bk"), proj("wv", "bv")
    o = _attention(cfg, q, k, v)                      # (B, H, n, dh)
    o = o.transpose(0, 2, 1, 3).reshape(bsz, n, d)
    return o @ p[prefix + "wo"] + p[prefix + "bo"]


def encode(cfg: ModelConfig, p: Dict[str, jax.Array], ids: jax.Array):
    """Token ids (B, n) -> hidden states (B, n, d_model)."""
    x = p["tok_emb"][ids] + p["pos_emb"][None, :, :]
    for i in range(cfg.n_layers):
        pre = f"layer{i}."
        h = layer_norm(x, p[pre + "ln1.g"], p[pre + "ln1.b"])
        x = x + _mha(cfg, p, pre, h)
        h = layer_norm(x, p[pre + "ln2.g"], p[pre + "ln2.b"])
        h = gelu(h @ p[pre + "w1"] + p[pre + "b1"])
        x = x + h @ p[pre + "w2"] + p[pre + "b2"]
    return layer_norm(x, p["ln_f.g"], p["ln_f.b"])


def mlm_logits(cfg: ModelConfig, vec: jax.Array, ids: jax.Array):
    p = unpack(vec, cfg)
    h = encode(cfg, p, ids)
    return h @ p["mlm.w"] + p["mlm.b"]                # (B, n, vocab)


def cls_logits(cfg: ModelConfig, vec: jax.Array, ids: jax.Array):
    p = unpack(vec, cfg)
    h = encode(cfg, p, ids).mean(axis=1)              # mean pool
    h = jnp.tanh(h @ p["cls.w1"] + p["cls.b1"])
    return h @ p["cls.w2"] + p["cls.b2"]              # (B, C)


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------

def _weighted_ce(logits, labels, weights):
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    wsum = jnp.maximum(weights.sum(), 1e-6)
    loss = -(ll * weights).sum() / wsum
    acc = ((logits.argmax(-1) == labels) * weights).sum() / wsum
    return loss, acc


def mlm_loss(cfg: ModelConfig, vec, ids, labels, weights):
    """Masked-LM loss; `weights` is 1.0 at masked positions, else 0."""
    return _weighted_ce(mlm_logits(cfg, vec, ids), labels, weights)


def cls_loss(cfg: ModelConfig, vec, ids, labels):
    logits = cls_logits(cfg, vec, ids)
    w = jnp.ones(labels.shape, jnp.float32)
    return _weighted_ce(logits, labels, w)


# ---------------------------------------------------------------------------
# Adam train steps (state = flat vectors, elementwise update)
# ---------------------------------------------------------------------------

def _adam(cfg: ModelConfig, vec, g, m, v, step):
    b1, b2, eps, lr = cfg.adam_b1, cfg.adam_b2, cfg.adam_eps, cfg.lr
    m = b1 * m + (1 - b1) * g
    v = b2 * v + (1 - b2) * g * g
    mh = m / (1 - b1 ** (step + 1))
    vh = v / (1 - b2 ** (step + 1))
    return vec - lr * mh / (jnp.sqrt(vh) + eps), m, v


def make_train_step_mlm(cfg: ModelConfig):
    """(vec, m, v, step, ids, labels, weights) -> (vec', m', v', loss, acc)."""

    def step_fn(vec, m, v, step, ids, labels, weights):
        (loss, acc), g = jax.value_and_grad(
            lambda w: mlm_loss(cfg, w, ids, labels, weights), has_aux=True
        )(vec)
        vec2, m2, v2 = _adam(cfg, vec, g, m, v, step)
        return vec2, m2, v2, loss, acc

    return step_fn


def make_train_step_cls(cfg: ModelConfig):
    """(vec, m, v, step, ids, labels) -> (vec', m', v', loss, acc)."""

    def step_fn(vec, m, v, step, ids, labels):
        (loss, acc), g = jax.value_and_grad(
            lambda w: cls_loss(cfg, w, ids, labels), has_aux=True
        )(vec)
        vec2, m2, v2 = _adam(cfg, vec, g, m, v, step)
        return vec2, m2, v2, loss, acc

    return step_fn


def make_eval_mlm(cfg: ModelConfig):
    """(vec, ids, labels, weights) -> (loss, acc)."""

    def eval_fn(vec, ids, labels, weights):
        return mlm_loss(cfg, vec, ids, labels, weights)

    return eval_fn


def make_eval_cls(cfg: ModelConfig):
    def eval_fn(vec, ids, labels):
        return cls_loss(cfg, vec, ids, labels)

    return eval_fn


def make_attention_only(cfg: ModelConfig):
    """(q, k, v) -> z for a (B, H, n, d_head) microbench artifact."""

    def attn_fn(q, k, v):
        return _attention(cfg, q, k, v)

    return attn_fn
