"""AOT pipeline: lower jitted JAX entry points to HLO text artifacts.

``python -m compile.aot --out ../artifacts`` writes, for every registered
entry point:

* ``<name>.hlo.txt``      — HLO **text** (the interchange format: jax >= 0.5
  serialized protos carry 64-bit instruction ids that xla_extension 0.5.1
  rejects; the text parser reassigns ids and round-trips cleanly),
* ``<tag>.params.f32``    — raw little-endian f32 initial parameter vector,
* ``<tag>.cfg``           — ``key=value`` model config sidecar,
* ``manifest.tsv``        — one row per artifact: name, file, input
  signature, output arity (parsed by ``rust/src/runtime/artifacts.rs``).

Python runs exactly once (``make artifacts``); the Rust binary is
self-contained afterwards.
"""

from __future__ import annotations

import argparse
import dataclasses
import os
from typing import Callable, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import model as M


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


@dataclasses.dataclass
class Entry:
    """One AOT entry point: a jittable fn + example argument shapes."""

    name: str
    fn: Callable
    args: Sequence[jax.ShapeDtypeStruct]
    n_outputs: int
    tag: str = ""          # model tag (links to .params.f32 / .cfg)

    def signature(self) -> str:
        return ",".join(
            f"{a.dtype}:{'x'.join(str(s) for s in a.shape) or 'scalar'}"
            for a in self.args
        )


def _sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


# ---------------------------------------------------------------------------
# registry of model variants and entry points
# ---------------------------------------------------------------------------

ATTENTIONS = ("exact", "mra2", "mra2s")


def small_cfg(attn: str, use_pallas: bool = False) -> M.ModelConfig:
    """RoBERTa-small-analog used by train_mlm / serve examples."""
    return M.ModelConfig(
        vocab=512, seq_len=128, d_model=128, n_heads=2, n_layers=2,
        d_ff=512, attention=attn, block=32, num_blocks=8,
        use_pallas=use_pallas,
    )


def long_cfg(attn: str, use_pallas: bool = False) -> M.ModelConfig:
    """Longer-sequence variant for the serving latency demo (Tab. 3/4)."""
    return M.ModelConfig(
        vocab=512, seq_len=512, d_model=128, n_heads=2, n_layers=2,
        d_ff=512, attention=attn, block=32, num_blocks=48,
        use_pallas=use_pallas,
    )


def cls_cfg(attn: str) -> M.ModelConfig:
    """LRA-analog classifier config (ListOps-lite / retrieval / image)."""
    return M.ModelConfig(
        vocab=64, seq_len=128, d_model=64, n_heads=2, n_layers=2,
        d_ff=256, num_classes=10, attention=attn, block=32, num_blocks=8,
    )


def build_entries(quick: bool = False) -> Tuple[List[Entry], dict]:
    entries: List[Entry] = []
    configs: dict = {}
    i32 = jnp.int32

    def add_model(cfg: M.ModelConfig, kind: str, batches_fwd, batch_train):
        tag = f"{kind}_{cfg.tag()}"
        configs[tag] = cfg
        plen = M.param_count(cfg)
        n = cfg.seq_len
        if kind == "mlm":
            if batch_train:
                b = batch_train
                entries.append(Entry(
                    f"train_{tag}_b{b}", M.make_train_step_mlm(cfg),
                    [_sds((plen,)), _sds((plen,)), _sds((plen,)), _sds(()),
                     _sds((b, n), i32), _sds((b, n), i32), _sds((b, n))],
                    5, tag))
                entries.append(Entry(
                    f"eval_{tag}_b{b}", M.make_eval_mlm(cfg),
                    [_sds((plen,)), _sds((b, n), i32), _sds((b, n), i32),
                     _sds((b, n))],
                    2, tag))
            # inference path: Pallas kernels on for the MRA variants
            icfg = dataclasses.replace(cfg, use_pallas=cfg.attention != "exact")
            for b in batches_fwd:
                entries.append(Entry(
                    f"fwd_{tag}_b{b}",
                    lambda vec, ids, c=icfg: M.mlm_logits(c, vec, ids),
                    [_sds((plen,)), _sds((b, n), i32)], 1, tag))
        else:  # classifier
            if batch_train:
                b = batch_train
                entries.append(Entry(
                    f"train_{tag}_b{b}", M.make_train_step_cls(cfg),
                    [_sds((plen,)), _sds((plen,)), _sds((plen,)), _sds(()),
                     _sds((b, n), i32), _sds((b,), i32)],
                    5, tag))
                entries.append(Entry(
                    f"eval_{tag}_b{b}", M.make_eval_cls(cfg),
                    [_sds((plen,)), _sds((b, n), i32), _sds((b,), i32)],
                    2, tag))
            for b in batches_fwd:
                entries.append(Entry(
                    f"fwd_{tag}_b{b}",
                    lambda vec, ids, c=cfg: M.cls_logits(c, vec, ids),
                    [_sds((plen,)), _sds((b, n), i32)], 1, tag))

    # --- MLM models (Tables 1/2 analog; train_mlm example) ----------------
    attns = ("exact", "mra2") if quick else ATTENTIONS
    for attn in attns:
        add_model(small_cfg(attn), "mlm", batches_fwd=(1, 8), batch_train=32)

    # --- longer-sequence serving models (Tables 3/4 analog) ---------------
    if not quick:
        for attn in ("exact", "mra2", "mra2s"):
            add_model(long_cfg(attn), "mlm", batches_fwd=(1, 4),
                      batch_train=8)

    # --- LRA-analog classifiers (Table 5) ----------------------------------
    if not quick:
        for attn in ATTENTIONS:
            add_model(cls_cfg(attn), "cls", batches_fwd=(8,), batch_train=32)

    # --- attention-only microbench artifacts (Fig. 4 / Tab. 7 e2e check) ---
    h, dh = 2, 64
    for attn in attns:
        for n in (256,) if quick else (256, 512):
            nb = n // 32
            acfg = M.ModelConfig(
                seq_len=n, attention=attn, block=32, num_blocks=4 * nb,
                use_pallas=attn != "exact",
            )
            entries.append(Entry(
                f"attn_{attn}_n{n}_h{h}_d{dh}",
                M.make_attention_only(acfg),
                [_sds((1, h, n, dh))] * 3, 1, ""))

    return entries, configs


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def write_artifacts(out_dir: str, quick: bool = False,
                    only: str = "") -> None:
    os.makedirs(out_dir, exist_ok=True)
    entries, configs = build_entries(quick)
    manifest_rows = []

    for tag, cfg in sorted(configs.items()):
        vec = M.init_params(cfg, seed=0)
        pfile = f"{tag}.params.f32"
        vec.astype("<f4").tofile(os.path.join(out_dir, pfile))
        with open(os.path.join(out_dir, f"{tag}.cfg"), "w") as f:
            for k, v in dataclasses.asdict(cfg).items():
                f.write(f"{k}={v}\n")
            f.write(f"param_count={len(vec)}\n")
        print(f"[aot] params {tag}: {len(vec)} f32 -> {pfile}")

    for e in entries:
        if only and only not in e.name:
            continue
        lowered = jax.jit(e.fn).lower(*e.args)
        text = to_hlo_text(lowered)
        fname = f"{e.name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        manifest_rows.append(
            f"{e.name}\t{fname}\t{e.signature()}\t{e.n_outputs}\t{e.tag}")
        print(f"[aot] hlo {e.name}: {len(text) / 1024:.0f} KiB")

    with open(os.path.join(out_dir, "manifest.tsv"), "w") as f:
        f.write("# name\tfile\tinputs(dtype:shape,...)\tn_outputs\ttag\n")
        f.write("\n".join(manifest_rows) + "\n")
    print(f"[aot] wrote {len(manifest_rows)} artifacts to {out_dir}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--quick", action="store_true",
                    help="small subset for fast iteration")
    ap.add_argument("--only", default="",
                    help="substring filter on entry names")
    args = ap.parse_args()
    write_artifacts(args.out, args.quick, args.only)


if __name__ == "__main__":
    main()
