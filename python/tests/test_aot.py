"""AOT pipeline tests: HLO text emission, manifest format, params dump."""

import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import aot, model as M


def test_to_hlo_text_simple_fn():
    fn = lambda x, y: (x @ y + 1.0,)
    spec = jax.ShapeDtypeStruct((4, 4), jnp.float32)
    text = aot.to_hlo_text(jax.jit(fn).lower(spec, spec))
    assert "HloModule" in text
    assert "f32[4,4]" in text


def test_entry_signature_format():
    e = aot.Entry(
        "x", lambda a: a,
        [jax.ShapeDtypeStruct((2, 3), jnp.int32),
         jax.ShapeDtypeStruct((), jnp.float32)], 1)
    assert e.signature() == "int32:2x3,float32:scalar"


def test_build_entries_quick_contains_core_set():
    entries, configs = aot.build_entries(quick=True)
    names = {e.name for e in entries}
    assert any(n.startswith("train_mlm_exact") for n in names)
    assert any(n.startswith("train_mlm_mra2") for n in names)
    assert any(n.startswith("fwd_mlm_mra2") for n in names)
    assert any(n.startswith("attn_mra2") for n in names)
    assert all(isinstance(c, M.ModelConfig) for c in configs.values())


def test_build_entries_full_has_all_variants():
    entries, configs = aot.build_entries(quick=False)
    names = {e.name for e in entries}
    for attn in ("exact", "mra2", "mra2s"):
        assert any(f"mlm_{attn}_n128" in n and n.startswith("train_")
                   for n in names), attn
        assert any(f"cls_{attn}" in n and n.startswith("train_")
                   for n in names), attn
        assert any(n == f"attn_{attn}_n512_h2_d64" for n in names), attn
    # long-sequence serving variants present
    assert any("mlm_exact_n512" in n for n in names)
    assert any("mlm_mra2_n512" in n for n in names)


def test_write_artifacts_quick(tmp_path):
    out = str(tmp_path / "artifacts")
    aot.write_artifacts(out, quick=True, only="attn_exact_n256")
    files = os.listdir(out)
    assert "manifest.tsv" in files
    assert "attn_exact_n256_h2_d64.hlo.txt" in files
    # params + cfg sidecars are written for every registered model
    assert any(f.endswith(".params.f32") for f in files)
    assert any(f.endswith(".cfg") for f in files)
    rows = [l for l in open(os.path.join(out, "manifest.tsv"))
            if l.strip() and not l.startswith("#")]
    assert len(rows) == 1
    name, fname, sig, nout, tag = rows[0].rstrip("\n").split("\t")
    assert name == "attn_exact_n256_h2_d64"
    assert sig == ",".join(["float32:1x2x256x64"] * 3)
    assert nout == "1"


def test_params_dump_roundtrip(tmp_path):
    out = str(tmp_path / "a")
    aot.write_artifacts(out, quick=True, only="__none__")
    cfg = aot.small_cfg("exact")
    tag = f"mlm_{cfg.tag()}"
    vec = np.fromfile(os.path.join(out, f"{tag}.params.f32"), "<f4")
    assert vec.shape == (M.param_count(cfg),)
    np.testing.assert_array_equal(vec, M.init_params(cfg, seed=0))
    cfg_lines = dict(
        l.strip().split("=", 1)
        for l in open(os.path.join(out, f"{tag}.cfg")))
    assert cfg_lines["attention"] == "exact"
    assert int(cfg_lines["param_count"]) == len(vec)


def test_cfg_tags_unique():
    _, configs = aot.build_entries(quick=False)
    assert len(configs) == len(set(configs))
