"""L2 model tests: shapes, parameter packing, losses, Adam, training."""

import math

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from compile import model as M


def tiny_cfg(attention="mra2", **kw):
    base = dict(vocab=64, seq_len=64, d_model=32, n_heads=2, n_layers=2,
                d_ff=64, num_classes=4, attention=attention, block=16,
                num_blocks=6)
    base.update(kw)
    return M.ModelConfig(**base)


# ---------------------------------------------------------------------------
# parameter layout
# ---------------------------------------------------------------------------

def test_param_pack_unpack_roundtrip():
    cfg = tiny_cfg()
    vec = M.init_params(cfg, seed=1)
    assert vec.shape == (M.param_count(cfg),)
    params = M.unpack(jnp.array(vec), cfg)
    assert set(params) == {n for n, _ in M.param_specs(cfg)}
    back = M.pack({k: np.asarray(v) for k, v in params.items()}, cfg)
    np.testing.assert_array_equal(back, vec)


def test_param_specs_deterministic():
    cfg = tiny_cfg()
    assert M.param_specs(cfg) == M.param_specs(cfg)


def test_layernorm_gain_init():
    cfg = tiny_cfg()
    p = M.unpack(jnp.array(M.init_params(cfg)), cfg)
    np.testing.assert_array_equal(np.asarray(p["ln_f.g"]), 1.0)
    np.testing.assert_array_equal(np.asarray(p["ln_f.b"]), 0.0)


# ---------------------------------------------------------------------------
# forward shapes, all attention variants
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("attn", ["exact", "mra2", "mra2s"])
def test_mlm_logits_shape(attn):
    cfg = tiny_cfg(attn)
    vec = jnp.array(M.init_params(cfg))
    ids = jnp.zeros((3, cfg.seq_len), jnp.int32)
    logits = M.mlm_logits(cfg, vec, ids)
    assert logits.shape == (3, cfg.seq_len, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("attn", ["exact", "mra2", "mra2s"])
def test_cls_logits_shape(attn):
    cfg = tiny_cfg(attn)
    vec = jnp.array(M.init_params(cfg))
    ids = jnp.zeros((5, cfg.seq_len), jnp.int32)
    logits = M.cls_logits(cfg, vec, ids)
    assert logits.shape == (5, cfg.num_classes)
    assert np.isfinite(np.asarray(logits)).all()


def test_mra2_close_to_exact_at_init():
    """At init the attention matrices are diffuse; MRA-2 with a generous
    budget should produce nearly the same encoder output as exact."""
    cfg_e = tiny_cfg("exact")
    nb = cfg_e.seq_len // cfg_e.block
    cfg_m = tiny_cfg("mra2", num_blocks=nb * nb)
    vec = jnp.array(M.init_params(cfg_e))
    ids = jnp.arange(cfg_e.seq_len, dtype=jnp.int32)[None, :] % cfg_e.vocab
    le = np.asarray(M.mlm_logits(cfg_e, vec, ids))
    lm = np.asarray(M.mlm_logits(cfg_m, vec, ids))
    np.testing.assert_allclose(le, lm, rtol=1e-3, atol=1e-3)


def test_pallas_fwd_matches_jnp_fwd():
    cfg_j = tiny_cfg("mra2", use_pallas=False)
    cfg_p = tiny_cfg("mra2", use_pallas=True)
    vec = jnp.array(M.init_params(cfg_j))
    ids = (jnp.arange(2 * cfg_j.seq_len, dtype=jnp.int32)
           .reshape(2, cfg_j.seq_len) % cfg_j.vocab)
    lj = np.asarray(M.mlm_logits(cfg_j, vec, ids))
    lp = np.asarray(M.mlm_logits(cfg_p, vec, ids))
    np.testing.assert_allclose(lj, lp, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------

def test_mlm_loss_uniform_at_init_is_log_vocab():
    cfg = tiny_cfg("exact")
    # zero params except embeddings -> logits ~ const -> loss ~ log(vocab)
    vec = jnp.array(M.init_params(cfg))
    rng = np.random.default_rng(0)
    ids = jnp.array(rng.integers(0, cfg.vocab, (2, cfg.seq_len)), jnp.int32)
    labels = jnp.array(rng.integers(0, cfg.vocab, (2, cfg.seq_len)),
                       jnp.int32)
    w = jnp.ones((2, cfg.seq_len), jnp.float32)
    loss, acc = M.mlm_loss(cfg, vec, ids, labels, w)
    assert abs(float(loss) - math.log(cfg.vocab)) < 1.5
    assert 0.0 <= float(acc) <= 1.0


def test_mlm_loss_respects_weights():
    cfg = tiny_cfg("exact")
    vec = jnp.array(M.init_params(cfg, seed=2))
    rng = np.random.default_rng(1)
    ids = jnp.array(rng.integers(0, cfg.vocab, (1, cfg.seq_len)), jnp.int32)
    labels = ids
    w0 = jnp.zeros((1, cfg.seq_len), jnp.float32).at[0, 0].set(1.0)
    w1 = jnp.zeros((1, cfg.seq_len), jnp.float32).at[0, 1].set(1.0)
    l0, _ = M.mlm_loss(cfg, vec, ids, labels, w0)
    l1, _ = M.mlm_loss(cfg, vec, ids, labels, w1)
    # different masked positions -> generally different losses
    assert not np.isclose(float(l0), float(l1))


# ---------------------------------------------------------------------------
# Adam + training
# ---------------------------------------------------------------------------

def test_adam_matches_numpy_reference():
    cfg = tiny_cfg()
    rng = np.random.default_rng(0)
    n = 64
    vec = rng.normal(size=n).astype(np.float32)
    g = rng.normal(size=n).astype(np.float32)
    m = rng.normal(size=n).astype(np.float32) * 0.1
    v = np.abs(rng.normal(size=n)).astype(np.float32) * 0.1
    step = 3.0
    got_vec, got_m, got_v = M._adam(
        cfg, jnp.array(vec), jnp.array(g), jnp.array(m), jnp.array(v),
        jnp.float32(step))
    b1, b2 = cfg.adam_b1, cfg.adam_b2
    m2 = b1 * m + (1 - b1) * g
    v2 = b2 * v + (1 - b2) * g * g
    mh = m2 / (1 - b1 ** (step + 1))
    vh = v2 / (1 - b2 ** (step + 1))
    want = vec - cfg.lr * mh / (np.sqrt(vh) + cfg.adam_eps)
    np.testing.assert_allclose(np.asarray(got_vec), want, rtol=1e-5,
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(got_m), m2, rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(np.asarray(got_v), v2, rtol=1e-6, atol=1e-7)


@pytest.mark.parametrize("attn", ["exact", "mra2", "mra2s"])
def test_train_step_decreases_loss(attn):
    """A few MLM steps on a fixed batch must reduce the loss."""
    cfg = tiny_cfg(attn, lr=5e-3)
    step_fn = jax.jit(M.make_train_step_mlm(cfg))
    vec = jnp.array(M.init_params(cfg, seed=0))
    m = jnp.zeros_like(vec)
    v = jnp.zeros_like(vec)
    rng = np.random.default_rng(0)
    ids = jnp.array(rng.integers(0, cfg.vocab, (4, cfg.seq_len)), jnp.int32)
    labels = ids
    w = jnp.array(rng.random((4, cfg.seq_len)) < 0.15, jnp.float32)
    losses = []
    for step in range(8):
        vec, m, v, loss, acc = step_fn(vec, m, v, jnp.float32(step), ids,
                                       labels, w)
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses
    assert np.isfinite(losses).all()


def test_train_step_cls_decreases_loss():
    cfg = tiny_cfg("mra2", lr=5e-3)
    step_fn = jax.jit(M.make_train_step_cls(cfg))
    vec = jnp.array(M.init_params(cfg, seed=0))
    m = jnp.zeros_like(vec)
    v = jnp.zeros_like(vec)
    rng = np.random.default_rng(0)
    ids = jnp.array(rng.integers(0, cfg.vocab, (8, cfg.seq_len)), jnp.int32)
    labels = jnp.array(rng.integers(0, cfg.num_classes, (8,)), jnp.int32)
    losses = []
    for step in range(8):
        vec, m, v, loss, acc = step_fn(vec, m, v, jnp.float32(step), ids,
                                       labels)
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses


def test_eval_fn_matches_loss():
    cfg = tiny_cfg("mra2")
    vec = jnp.array(M.init_params(cfg, seed=0))
    rng = np.random.default_rng(0)
    ids = jnp.array(rng.integers(0, cfg.vocab, (2, cfg.seq_len)), jnp.int32)
    labels = ids
    w = jnp.ones((2, cfg.seq_len), jnp.float32)
    l1, a1 = M.make_eval_mlm(cfg)(vec, ids, labels, w)
    l2, a2 = M.mlm_loss(cfg, vec, ids, labels, w)
    assert float(l1) == pytest.approx(float(l2))
    assert float(a1) == pytest.approx(float(a2))
