"""Pallas MRA kernels vs the dense oracle (`compile.kernels.ref`).

This is the core L1 correctness signal: every kernel and the assembled
MRA-2 / MRA-2-s attention are checked against the paper-literal dense
construction, over hypothesis-swept shapes and budgets.
"""

import math

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import mra, ref

SET = dict(deadline=None, max_examples=15, print_blob=True)


def rand_qkv(seed, n, d, scale=1.0):
    rng = np.random.default_rng(seed)
    q = (rng.normal(size=(n, d)) * scale).astype(np.float32)
    k = (rng.normal(size=(n, d)) * scale).astype(np.float32)
    v = rng.normal(size=(n, d)).astype(np.float32)
    return q, k, v


# ---------------------------------------------------------------------------
# individual kernels
# ---------------------------------------------------------------------------

@settings(**SET)
@given(
    n=st.sampled_from([32, 64, 128]),
    d=st.sampled_from([8, 16, 64]),
    b=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_pool_kernel_matches_ref(n, d, b, seed):
    if n % b:
        return
    x = np.random.default_rng(seed).normal(size=(n, d)).astype(np.float32)
    got = np.asarray(mra.pool(jnp.array(x), b))
    want = np.asarray(ref.pool_rows(x, b))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


@settings(**SET)
@given(
    nb=st.sampled_from([4, 8, 16]),
    d=st.sampled_from([8, 32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_lowres_scores_kernel(nb, d, seed):
    rng = np.random.default_rng(seed)
    qt = rng.normal(size=(nb, d)).astype(np.float32)
    kt = rng.normal(size=(nb, d)).astype(np.float32)
    got = np.asarray(mra.lowres_scores(jnp.array(qt), jnp.array(kt)))
    want = qt @ kt.T / math.sqrt(d)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(**SET)
@given(
    m=st.sampled_from([1, 3, 8]),
    b=st.sampled_from([8, 32]),
    d=st.sampled_from([16, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_block_scores_kernel(m, b, d, seed):
    rng = np.random.default_rng(seed)
    qb = rng.normal(size=(m, b, d)).astype(np.float32)
    kb = rng.normal(size=(m, b, d)).astype(np.float32)
    got = np.asarray(mra.block_scores(jnp.array(qb), jnp.array(kb)))
    want = np.einsum("mbd,mcd->mbc", qb, kb) / math.sqrt(d)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(**SET)
@given(
    m=st.sampled_from([1, 4]),
    b=st.sampled_from([8, 16]),
    d=st.sampled_from([8, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_block_attn_kernel(m, b, d, seed):
    rng = np.random.default_rng(seed)
    p = rng.normal(size=(m, b, b)).astype(np.float32)
    vb = rng.normal(size=(m, b, d)).astype(np.float32)
    mx = p.max(axis=(1, 2))
    num, den = mra.block_attn(jnp.array(p), jnp.array(vb), jnp.array(mx))
    a = np.exp(p - mx[:, None, None])
    np.testing.assert_allclose(np.asarray(num),
                               np.einsum("mbc,mcd->mbd", a, vb),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(den), a.sum(-1),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# assembled MRA-2 vs dense oracle
# ---------------------------------------------------------------------------

@settings(**SET)
@given(
    n=st.sampled_from([64, 128, 256]),
    d=st.sampled_from([16, 32]),
    b=st.sampled_from([16, 32]),
    frac=st.sampled_from([0.2, 0.5, 1.0]),
    variant=st.sampled_from(["full", "sparse"]),
    use_pallas=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_mra2_matches_dense_oracle(n, d, b, frac, variant, use_pallas, seed):
    nb = n // b
    m = max(1, int(frac * nb * nb))
    q, k, v = rand_qkv(seed, n, d)
    _, z_ref = ref.dense_mra2(q, k, v, b, m, variant)
    z = mra.mra2_attention(
        jnp.array(q), jnp.array(k), jnp.array(v),
        block=b, num_blocks=m, variant=variant, use_pallas=use_pallas)
    np.testing.assert_allclose(np.asarray(z), z_ref, rtol=2e-4, atol=2e-4)


def test_mra2_full_budget_is_exact():
    """When every block is selected, MRA-2 == exact softmax attention."""
    q, k, v = rand_qkv(7, 128, 32)
    nb = 128 // 32
    z = mra.mra2_attention(jnp.array(q), jnp.array(k), jnp.array(v),
                           block=32, num_blocks=nb * nb)
    ze = np.asarray(ref.exact_attention(q, k, v))
    np.testing.assert_allclose(np.asarray(z), ze, rtol=1e-4, atol=1e-5)


def test_mra2s_full_budget_is_exact():
    q, k, v = rand_qkv(8, 64, 16)
    z = mra.mra2_attention(jnp.array(q), jnp.array(k), jnp.array(v),
                           block=16, num_blocks=16, variant="sparse")
    ze = np.asarray(ref.exact_attention(q, k, v))
    np.testing.assert_allclose(np.asarray(z), ze, rtol=1e-4, atol=1e-5)


def test_batched_multihead_layout():
    """(B, H, n, d) batching is a per-head map of the single-head kernel."""
    rng = np.random.default_rng(3)
    q = rng.normal(size=(2, 3, 64, 16)).astype(np.float32)
    k = rng.normal(size=(2, 3, 64, 16)).astype(np.float32)
    v = rng.normal(size=(2, 3, 64, 16)).astype(np.float32)
    z = np.asarray(mra.mra2_attention(
        jnp.array(q), jnp.array(k), jnp.array(v), block=16, num_blocks=6))
    for i in range(2):
        for h in range(3):
            zi = np.asarray(mra.mra2_attention(
                jnp.array(q[i, h]), jnp.array(k[i, h]), jnp.array(v[i, h]),
                block=16, num_blocks=6))
            np.testing.assert_allclose(z[i, h], zi, rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# paper semantics on the oracle itself
# ---------------------------------------------------------------------------

@settings(**SET)
@given(
    n=st.sampled_from([32, 64]),
    d=st.sampled_from([8, 16]),
    b=st.sampled_from([8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_jensen_bound_direction(n, d, b, seed):
    """Lemma 4.1: mu (exp of mean) <= mu* (mean of exp), elementwise."""
    q, k, _ = rand_qkv(seed, n, d)
    mu = np.asarray(ref.mu_lower_bound(q, k, b))
    mu_star = np.asarray(ref.mu_exact(q, k, b))
    assert (mu <= mu_star * (1 + 1e-5)).all()


@settings(**SET)
@given(
    n=st.sampled_from([32, 64]),
    d=st.sampled_from([8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_lemma41_error_bound(n, d, seed):
    """0 <= mu* - mu <= C_r mu with r the measured in-block range of P."""
    b = 16
    q, k, _ = rand_qkv(seed, n, d)
    p = q @ k.T / math.sqrt(d)
    nb = n // b
    mu = np.asarray(ref.mu_lower_bound(q, k, b))
    mu_star = np.asarray(ref.mu_exact(q, k, b))
    pb = p.reshape(nb, b, nb, b)
    r = pb.max(axis=(1, 3)) - pb.min(axis=(1, 3))
    c_r = 1 + np.exp(r) - 2 * np.exp(r / 2)
    gap = mu_star - mu
    assert (gap >= -1e-5 * mu).all()
    assert (gap <= c_r * mu * (1 + 1e-4) + 1e-6).all()


def test_general_reference_matches_two_scale():
    """dense_mra_general with R={b,1} reproduces dense_mra2 selection."""
    q, k, v = rand_qkv(11, 64, 16)
    b, m = 16, 6
    a2, z2 = ref.dense_mra2(q, k, v, b, m, "full")
    ag, zg = ref.dense_mra_general(q, k, v, [b, 1], [m])
    np.testing.assert_allclose(ag, a2, rtol=1e-6, atol=1e-9)
    np.testing.assert_allclose(zg, z2, rtol=1e-6, atol=1e-9)


def test_general_reference_three_scales_runs():
    """R={16,4,1} pyramid: A_hat rows partition into disjoint supports."""
    q, k, v = rand_qkv(13, 64, 16)
    a_hat, z = ref.dense_mra_general(q, k, v, [16, 4, 1], [4, 8])
    assert a_hat.shape == (64, 64)
    assert np.isfinite(z).all()
    assert (a_hat >= 0).all()


@settings(**SET)
@given(seed=st.integers(0, 2**31 - 1))
def test_error_monotone_in_budget(seed):
    """Approximation error decreases (weakly) as the budget m grows."""
    q, k, v = rand_qkv(seed, 128, 16)
    _, av = ref.exact_unnormalized(q, k, v)
    z_exact = np.asarray(ref.exact_attention(q, k, v))
    errs = []
    for m in (4, 8, 16, 32, 64):
        _, z = ref.dense_mra2(q, k, v, 16, m, "full")
        errs.append(ref.rel_fro_error(z, z_exact))
    assert errs[-1] <= errs[0] + 1e-9
    assert errs[-1] < 1e-5  # m = nb^2 = 64 is the full budget -> exact


def test_prop45_bound_holds():
    """Prop. 4.5 relative error bound on the unnormalized A_hat."""
    q, k, v = rand_qkv(5, 64, 8, scale=0.5)
    b, m = 16, 6
    n = 64
    d = 8
    p = q @ k.T / math.sqrt(d)
    a = np.exp(p)
    a_hat, _ = ref.dense_mra2(q, k, v, b, m, "full", include_diagonal=False)
    nb = n // b
    mu = np.asarray(ref.mu_lower_bound(q, k, b))
    sel = ref.select_blocks(q, k, b, m, include_diagonal=False)
    delta = np.sort(mu.reshape(-1))[-m]
    pb = p.reshape(nb, b, nb, b)
    r = float((pb.max(axis=(1, 3)) - pb.min(axis=(1, 3))).max())
    c2r = 1 + np.exp(2 * r) - 2 * np.exp(r)
    bound = math.sqrt(
        (n * n - m * b * b) * c2r * delta**2 / np.exp(2 * p).sum())
    err = np.linalg.norm(a_hat - a) / np.linalg.norm(a)
    assert err <= bound * (1 + 1e-6), (err, bound)
