#!/usr/bin/env python3
"""Summarize a flight-recorder JSON-lines dump (DESIGN.md §14).

Usage:
    trace_summarize.py TRACE.jsonl [--request ID] [--max-requests N]

The input is what `FlightRecorder::dump_jsonl` / `Server::dump_trace`
emit (and what `cargo bench --bench bench_serve` writes to
`target/bench_serve_trace.jsonl`): one event per line, chronological,
each carrying `step`, `us` (injected-clock microseconds) and `ev`.

Output, stdlib-only:

* header — event counts per kind, step span, and the autotune budget
  trajectory when the trace saw resizes;
* per-phase step timing — each `StepEnd` carries the seven phase spans
  (ingress, admission, reserve, prefill-attend, decode-attend, logits,
  stream-egress); the table totals them, shows each phase's share of the
  attributed time, and reports what fraction of the measured step time
  the phases account for (the rest is scheduler glue);
* per-request timelines — admission, radix hits, prefill chunks,
  preemptions + readmissions, page demotions, decode/stall counts,
  finish latency; one
  line per request, or the full event-by-event timeline with
  `--request ID`.

Every line must parse and carry the schema fields — a malformed dump
exits nonzero, which is exactly what CI's bench-smoke run of this script
is for (the Rust side only asserts the lines it greps for).

Exit codes: 0 ok, nonzero unreadable/malformed trace.
"""

import argparse
import json
import sys
from collections import defaultdict

# StepPhase::ALL order (rust/src/coordinator/metrics.rs)
PHASES = (
    "ingress",
    "admission",
    "reserve",
    "prefill_attend",
    "decode_attend",
    "logits",
    "stream_egress",
)


def load(path):
    events = []
    try:
        with open(path, encoding="utf-8") as f:
            for ln, line in enumerate(f, 1):
                line = line.strip()
                if not line:
                    continue
                try:
                    ev = json.loads(line)
                except json.JSONDecodeError as e:
                    sys.exit(f"trace_summarize: {path}:{ln}: invalid JSON: {e}")
                for req in ("step", "us", "ev"):
                    if req not in ev:
                        sys.exit(f"trace_summarize: {path}:{ln}: missing {req!r} field")
                events.append(ev)
    except OSError as e:
        sys.exit(f"trace_summarize: cannot read {path}: {e}")
    if not events:
        sys.exit(f"trace_summarize: {path} holds no events")
    return events


def phase_table(events):
    ends = [e for e in events if e["ev"] == "StepEnd"]
    if not ends:
        print("no StepEnd events (per-phase timing unavailable)")
        return
    sums = [0] * len(PHASES)
    total = 0
    for e in ends:
        ph = e.get("phases")
        if not isinstance(ph, list) or len(ph) != len(PHASES):
            sys.exit("trace_summarize: StepEnd with malformed phases array")
        for i, v in enumerate(ph):
            sums[i] += v
        total += e.get("total_us", 0)
    attributed = sum(sums)
    print(
        f"per-phase step timing over {len(ends)} steps ({total} us measured, "
        f"{attributed} us attributed = {100.0 * attributed / max(total, 1):.1f}%):"
    )
    width = max(len(p) for p in PHASES)
    for name, s in zip(PHASES, sums):
        share = 100.0 * s / max(attributed, 1)
        mean = s / len(ends)
        print(f"  {name:<{width}}  {s:>10} us  {share:5.1f}%  mean {mean:8.1f} us/step")


def request_events(events):
    """Events grouped per request id (StepEnd and AutotuneResize carry
    no id and stay global)."""
    by_id = defaultdict(list)
    for e in events:
        if "id" in e:
            by_id[e["id"]].append(e)
    return by_id


def one_line(rid, evs):
    kinds = [e["ev"] for e in evs]
    admit = next((e for e in evs if e["ev"] == "Admit"), None)
    finish = next((e for e in evs if e["ev"] == "Finish"), None)
    chunks = [e for e in evs if e["ev"] == "PrefillChunk"]
    preempts = [e for e in evs if e["ev"] == "Preempt"]
    decodes = kinds.count("Decode")
    readmits = kinds.count("Readmit")
    stalls = kinds.count("StreamStall")
    hits = sum(e.get("cached_tokens", 0) for e in evs if e["ev"] == "RadixHit")
    parts = []
    if admit:
        parts.append(f"admit@{admit['step']} ({admit.get('prompt_tokens', '?')} prompt tokens)")
    else:
        parts.append("admit outside window")  # ring overwrote the oldest past
    if hits:
        parts.append(f"radix hit {hits} tokens")
    if chunks:
        fed = sum(c.get("tokens", 0) for c in chunks)
        parts.append(f"{len(chunks)} prefill chunks ({fed} tokens)")
    if preempts:
        reasons = ",".join(sorted({p.get("reason", "?") for p in preempts}))
        parts.append(f"{len(preempts)} preempt ({reasons}), {readmits} readmit")
    demotes = [e for e in evs if e["ev"] == "PageDemote"]
    if demotes:
        pages = sum(d.get("pages", 0) for d in demotes)
        parts.append(f"{len(demotes)} demote passes ({pages} pages compressed)")
    if decodes:
        parts.append(f"{decodes} decodes")
    if stalls:
        parts.append(f"{stalls} stream stalls")
    if "Expire" in kinds:
        parts.append("EXPIRED")
    if finish:
        tail = f"finish@{finish['step']} ({finish.get('generated', '?')} tokens"
        if admit:
            tail += f", {finish['us'] - admit['us']} us after admit"
        parts.append(tail + ")")
    elif "Expire" not in kinds:
        parts.append("no finish in window")
    print(f"  request {rid}: " + "; ".join(parts))


def full_timeline(rid, evs):
    print(f"timeline for request {rid} ({len(evs)} events):")
    for e in evs:
        extras = {k: v for k, v in e.items() if k not in ("step", "us", "ev", "id")}
        tail = ("  " + " ".join(f"{k}={v}" for k, v in extras.items())) if extras else ""
        print(f"  step {e['step']:>6}  {e['us']:>10} us  {e['ev']}{tail}")


def main():
    ap = argparse.ArgumentParser(
        description=__doc__, formatter_class=argparse.RawDescriptionHelpFormatter
    )
    ap.add_argument("trace", help="JSON-lines dump (FlightRecorder::dump_jsonl)")
    ap.add_argument(
        "--request",
        type=int,
        default=None,
        help="print the full event-by-event timeline of one request id",
    )
    ap.add_argument(
        "--max-requests",
        type=int,
        default=32,
        help="request summary lines to print (default %(default)s)",
    )
    args = ap.parse_args()

    events = load(args.trace)
    steps = [e["step"] for e in events]
    kinds = defaultdict(int)
    for e in events:
        kinds[e["ev"]] += 1
    print(f"{args.trace}: {len(events)} events over steps {min(steps)}..{max(steps)}")
    print("  " + "  ".join(f"{k}={v}" for k, v in sorted(kinds.items())))
    resizes = [e for e in events if e["ev"] == "AutotuneResize"]
    if resizes:
        traj = [str(resizes[0].get("old", "?"))] + [str(r.get("new", "?")) for r in resizes]
        print(f"  autotune budget: {' -> '.join(traj)} tokens/step")
    print()
    phase_table(events)

    by_id = request_events(events)
    if args.request is not None:
        evs = by_id.get(args.request)
        if evs is None:
            known = ", ".join(str(r) for r in sorted(by_id)[:16])
            sys.exit(f"trace_summarize: request {args.request} not in trace (ids: {known})")
        print()
        full_timeline(args.request, evs)
        return
    ordered = sorted(by_id.items(), key=lambda kv: (kv[1][0]["step"], kv[0]))
    shown = ordered[: args.max_requests]
    print(f"\nrequests ({len(ordered)} in trace, showing {len(shown)}):")
    for rid, evs in shown:
        one_line(rid, evs)
    if len(ordered) > len(shown):
        print(f"  ... {len(ordered) - len(shown)} more (raise --max-requests)")


if __name__ == "__main__":
    main()
