#!/usr/bin/env python3
"""Compare a freshly produced BENCH_*.json perf artifact against a
committed baseline and fail on throughput regressions.

Usage:
    bench_diff.py BASELINE.json CURRENT.json [--max-regression 0.20]
                  [--fields f1,f2,...]
    bench_diff.py --list-metrics [BASELINE.json]

Both files use the repo's BenchJson schema:
    {"bench": "<name>", "rows": [{<identity and metric fields>}, ...]}

Rows are keyed by their identity fields (everything that is not a known
metric — e.g. impl/kernel, n, b, threads).  For every key present in both
files, each tracked metric present in *both* rows is compared; the gate
fails (exit 1) when
    current < baseline * (1 - max_regression)   # higher-is-better metrics
    current > baseline * (1 + max_regression)   # lower-is-better metrics
                                                # (overheads, TRACKED_LOWER)

The committed baseline may carry only machine-portable metrics (e.g.
`speedup_vs_scalar`) — absolute tokens/sec are only compared when the
baseline records them (i.e. it was refreshed from a CI artifact of the
same runner class; see EXPERIMENTS.md §Attention kernel bench).

Exit codes: 0 ok, 1 regression, 2 usage/schema error (including zero
comparable rows — a silent no-op gate would be worse than a loud one).
"""

import argparse
import json
import sys

# higher-is-better metrics the gate tracks; everything else (mean_ms,
# percentiles, ...) is ignored for regression purposes
TRACKED = (
    "tokens_per_sec",
    "heads_per_sec",
    "gflops",
    "speedup_vs_scalar",
    "speedup_vs_exact",
    "speedup_vs_fixed",
    "prefill_speedup_vs_per_token",
    "ttft_speedup_vs_finish",
    "fused_serve_speedup_vs_phased",
    "fused_decode_p95_gain_vs_phased",
    "autotune_converged",
    "resident_sessions_gain_vs_f32",
)
# lower-is-better metrics (overheads): the gate fails when current
# exceeds baseline * (1 + max_regression)
TRACKED_LOWER = ("trace_overhead_pct",)
# fields that are metrics (never part of a row's identity key)
METRIC_FIELDS = set(TRACKED) | set(TRACKED_LOWER) | {
    "mean_ms",
    "p50_ms",
    "p95_ms",
    "min_ms",
    "us_per_token",
    "ttft_ms",
    "ttft_finish_ms",
    "itl_p50_ms",
    "itl_p95_ms",
    "settled_budget_tokens",
    "resident_sessions",
    "worst_rel_logit_err",
}


def load_rows(path):
    try:
        with open(path, "r", encoding="utf-8") as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        sys.exit(f"bench_diff: cannot read {path}: {e}")
    if "rows" not in doc or not isinstance(doc["rows"], list):
        sys.exit(f"bench_diff: {path} has no 'rows' list")
    keyed = {}
    for row in doc["rows"]:
        key = tuple(sorted((k, str(v)) for k, v in row.items() if k not in METRIC_FIELDS))
        keyed[key] = row
    return doc.get("bench", "?"), keyed


def fmt_key(key):
    return " ".join(f"{k}={v}" for k, v in key)


def list_metrics(baseline):
    """Print the gate's metric vocabulary (and, given a baseline, which of
    it that file actually carries) — the discoverable answer to "what can
    I pass to --fields?"."""
    print("tracked (regression-gated, higher is better):")
    for f in TRACKED:
        print(f"  {f}")
    print("tracked (regression-gated, lower is better):")
    for f in TRACKED_LOWER:
        print(f"  {f}")
    print("informational (recognized as metrics, never gated):")
    for f in sorted(METRIC_FIELDS - set(TRACKED) - set(TRACKED_LOWER)):
        print(f"  {f}")
    if baseline is not None:
        _, rows = load_rows(baseline)
        present = sorted({f for row in rows.values() for f in row if f in METRIC_FIELDS})
        print(f"metrics present in {baseline}:")
        for f in present:
            if f in TRACKED:
                gated = "tracked, higher is better"
            elif f in TRACKED_LOWER:
                gated = "tracked, lower is better"
            else:
                gated = "informational"
            print(f"  {f} ({gated})")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    # positionals are optional only so --list-metrics can run without
    # them; a compare invocation missing either is still a usage error
    ap.add_argument("baseline", nargs="?")
    ap.add_argument("current", nargs="?")
    ap.add_argument(
        "--list-metrics",
        action="store_true",
        help="print the tracked and informational metric fields (plus, if a "
        "baseline is given, which ones it carries) and exit",
    )
    ap.add_argument(
        "--max-regression",
        type=float,
        default=0.20,
        help="allowed fractional drop per metric (default 0.20 = 20%%)",
    )
    ap.add_argument(
        "--fields",
        default=",".join(TRACKED + TRACKED_LOWER),
        help="comma-separated metric fields to compare (default: %(default)s)",
    )
    ap.add_argument(
        "--allow-missing",
        action="store_true",
        help="tolerate baseline rows absent from the current artifact "
        "(default: missing rows fail the gate — silent sweep drift must not "
        "shrink coverage)",
    )
    args = ap.parse_args()
    if args.list_metrics:
        list_metrics(args.baseline)
        return
    if args.baseline is None or args.current is None:
        ap.error("baseline and current are required unless --list-metrics is given")
    fields = [f.strip() for f in args.fields.split(",") if f.strip()]
    # a typo'd --fields entry must fail loudly up front, not silently
    # compare nothing (or, worse, be treated as a row-identity field)
    unknown = [f for f in fields if f not in METRIC_FIELDS]
    if unknown:
        sys.exit(
            "bench_diff: unknown metric field(s) "
            + ", ".join(repr(f) for f in unknown)
            + "; known metrics: "
            + ", ".join(sorted(METRIC_FIELDS))
        )

    bench_b, base = load_rows(args.baseline)
    bench_c, cur = load_rows(args.current)
    if bench_b != bench_c:
        print(f"bench_diff: warning: bench names differ ({bench_b!r} vs {bench_c!r})")

    compared = 0
    regressions = []
    missing = []
    seen_fields = set()
    for key, brow in sorted(base.items()):
        # track which requested metrics the baseline carries at all, even
        # for rows absent from the current artifact — a pure row-key
        # mismatch must not be misdiagnosed as a metric-less baseline
        for f in fields:
            if f in brow:
                seen_fields.add(f)
        crow = cur.get(key)
        if crow is None:
            missing.append(key)
            print(f"bench_diff: baseline row missing from current: {fmt_key(key)}")
            continue
        for f in fields:
            if f not in brow or f not in crow:
                continue
            try:
                b, c = float(brow[f]), float(crow[f])
            except (TypeError, ValueError):
                sys.exit(f"bench_diff: non-numeric {f} in row {fmt_key(key)}")
            compared += 1
            if f in TRACKED_LOWER:
                ceiling = b * (1.0 + args.max_regression)
                status = "ok"
                if b > 0 and c > ceiling:
                    status = "REGRESSION"
                    regressions.append((key, f, b, c))
                print(
                    f"  {fmt_key(key)}  {f}: baseline {b:.3f} -> current {c:.3f} "
                    f"(ceiling {ceiling:.3f}) {status}"
                )
            else:
                floor = b * (1.0 - args.max_regression)
                status = "ok"
                if b > 0 and c < floor:
                    status = "REGRESSION"
                    regressions.append((key, f, b, c))
                print(
                    f"  {fmt_key(key)}  {f}: baseline {b:.3f} -> current {c:.3f} "
                    f"(floor {floor:.3f}) {status}"
                )

    if compared == 0:
        # distinguish "the requested metric is not in the baseline at all"
        # (the old failure surfaced as an opaque KeyError-ish no-op) from a
        # row-identity mismatch
        requested = [f for f in fields if f != ""]
        absent = [f for f in requested if f not in seen_fields]
        if absent and len(absent) == len(requested):
            sys.exit(
                "bench_diff: none of the requested metric(s) "
                + ", ".join(repr(f) for f in absent)
                + f" appear in any baseline row of {args.baseline} — refresh the "
                "committed baseline to carry the new metric (see "
                "rust/benches/baseline/README.md)"
            )
        sys.exit(
            "bench_diff: no comparable (row, metric) pairs between "
            f"{args.baseline} and {args.current} — key or schema mismatch"
        )
    if missing and not args.allow_missing:
        print(
            f"\nbench_diff: {len(missing)} baseline row(s) missing from the "
            "current artifact — the bench sweep shrank (update the committed "
            "baseline deliberately, or pass --allow-missing):"
        )
        for key in missing:
            print(f"  {fmt_key(key)}")
        sys.exit(1)
    if regressions:
        print(
            f"\nbench_diff: {len(regressions)} metric(s) regressed more than "
            f"{args.max_regression:.0%}:"
        )
        for key, f, b, c in regressions:
            print(f"  {fmt_key(key)}  {f}: {b:.3f} -> {c:.3f} ({c / b - 1.0:+.1%})")
        sys.exit(1)
    print(f"\nbench_diff: OK ({compared} metric comparisons within {args.max_regression:.0%})")


if __name__ == "__main__":
    main()
