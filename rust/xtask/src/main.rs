//! `cargo xtask lint` — custom repo lint (DESIGN.md §11).
//!
//! Three rules the stock toolchain cannot express, enforced token-wise
//! over `rust/src` (a hand-rolled lexer strips comments, strings and
//! char literals, then tracks `fn` bodies by brace depth — no `syn`,
//! because the offline build cannot fetch dependencies):
//!
//! * **hot-path-alloc** — no allocating calls (`Vec::new`, `vec!`,
//!   `.to_vec`, `.collect`, `.clone`, `Box::new`, `String::new`,
//!   `.to_string`, `format!`, `.with_capacity`) inside the fn bodies
//!   registered in [`HOT_PATH_MANIFEST`].  These are the serving/decode
//!   hot loops whose zero-steady-state-allocation claims the
//!   `alloc_gate` test asserts dynamically; the lint keeps casual
//!   allocations from creeping in between benchmark runs.  A registered
//!   fn that no longer exists in its file is itself a violation, so the
//!   manifest cannot silently rot.
//! * **no-unwrap** — no `.unwrap()` / `.expect(` in the coordinator
//!   request/responder paths ([`NO_UNWRAP_FILES`]): a panic on the
//!   scheduler or worker thread drops every responder it holds and
//!   hangs the waiting clients.  (`unwrap_or`/`unwrap_or_else` are
//!   fine — the token must be followed by an open paren directly.)
//! * **no-wallclock** — no `Instant::now` / `SystemTime` in the
//!   bitwise-gated modules (`mra/`, `tensor/`, `engine/decode.rs`):
//!   their outputs are replay-deterministic and property-tested
//!   bitwise; time must never feed a computation there.
//!
//! Escape hatch: a line ending in `// lint: allow(<rule>)` suppresses
//! `<rule>` on that line.  Every use must carry a justification comment
//! nearby — the escape hatch is grep-able (`git grep 'lint: allow'`)
//! and reviewed like an `unsafe` block.
//!
//! `#[cfg(test)] mod` bodies are exempt from every rule (tests allocate
//! and unwrap freely); the module-level clippy `deny(unwrap_used)`
//! attributes mirror the same split.

use std::collections::HashMap;
use std::fs;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

/// Hot-path fn registry: `(file, fn names)` relative to `rust/`.
/// Adding a fn here bans allocation in its body; removing a fn from the
/// source without updating this table fails the lint.
const HOT_PATH_MANIFEST: &[(&str, &[&str])] = &[
    ("src/mra/attention.rs", &["mra2_apply_blocks"]),
    (
        "src/engine/decode.rs",
        &[
            "attend_last_into",
            "attend_pos_into",
            "step_into",
            "attend_row_core",
            "attend_row_paged",
        ],
    ),
    (
        "src/tensor/kernel.rs",
        &[
            "softmax_accum_panel",
            "score_panel",
            "dot",
            "axpy",
            "scale",
            "pack_transpose",
            "dequant_bf16",
            "dequant_i8",
        ],
    ),
    // the shared format-agnostic page read: every compressed-page attend
    // dequantizes through this body (the `*_deq` accessors are thin
    // offset wrappers around it)
    ("src/engine/cache/page.rs", &["section_deq"]),
    ("src/engine/pool.rs", &["run_with"]),
    (
        "src/coordinator/native.rs",
        &["fused_decode_task", "fused_prefill_project_append", "fused_prefill_attend"],
    ),
    // every `fn record` body (inherent + TraceSink impls): the flight
    // recorder's per-event cost claim is "one lock, one slot overwrite"
    ("src/coordinator/trace.rs", &["record"]),
];

/// Coordinator request paths: a panic here drops client responders.
const NO_UNWRAP_FILES: &[&str] = &[
    "src/coordinator/scheduler.rs",
    "src/coordinator/server.rs",
    "src/coordinator/batcher.rs",
];

/// Bitwise-gated modules: no wall-clock reads.
const NO_WALLCLOCK_PREFIXES: &[&str] = &["src/mra/", "src/tensor/"];
const NO_WALLCLOCK_FILES: &[&str] = &["src/engine/decode.rs"];

/// Banned tokens for `hot-path-alloc`: `(pattern, ident boundary
/// required before, ident boundary required after)`.
const HOT_BANNED: &[(&str, bool, bool)] = &[
    ("Vec::new", true, true),
    ("vec!", true, false),
    ("Box::new", true, true),
    ("String::new", true, true),
    ("format!", true, false),
    (".to_vec", false, true),
    (".to_string", false, true),
    (".collect", false, true),
    (".clone", false, true),
    (".with_capacity", false, true),
];

const UNWRAP_BANNED: &[(&str, bool, bool)] =
    &[(".unwrap(", false, false), (".expect(", false, false)];

const WALLCLOCK_BANNED: &[(&str, bool, bool)] =
    &[("Instant::now", true, true), ("SystemTime", true, true)];

#[derive(Debug)]
struct Violation {
    file: String,
    line: usize,
    rule: &'static str,
    msg: String,
}

impl std::fmt::Display for Violation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.msg)
    }
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("lint") => {}
        _ => {
            eprintln!("usage: cargo xtask lint");
            eprintln!("  custom repo lint over rust/src — see DESIGN.md §11");
            return ExitCode::from(2);
        }
    }
    let root = src_root();
    let (files, violations) = lint_tree(&root);
    for v in &violations {
        println!("{v}");
    }
    if violations.is_empty() {
        println!("xtask lint: OK ({files} files checked)");
        ExitCode::SUCCESS
    } else {
        eprintln!("xtask lint: {} violation(s) in {files} files", violations.len());
        ExitCode::FAILURE
    }
}

/// `rust/src`, anchored on this crate's manifest dir so the lint works
/// from any CWD (CI, `cargo test`, editor integrations).
fn src_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("src")
}

/// Lint every `.rs` file under `root`; returns `(files checked,
/// violations)`.
fn lint_tree(root: &Path) -> (usize, Vec<Violation>) {
    let mut files = Vec::new();
    collect_rs_files(root, &mut files);
    files.sort();
    let mut violations = Vec::new();
    for path in &files {
        let rel = path.strip_prefix(root).unwrap_or(path);
        let label = format!(
            "src/{}",
            rel.components()
                .map(|c| c.as_os_str().to_string_lossy())
                .collect::<Vec<_>>()
                .join("/")
        );
        match fs::read_to_string(path) {
            Ok(raw) => violations.extend(check_source(&label, &raw)),
            Err(e) => violations.push(Violation {
                file: label,
                line: 0,
                rule: "io",
                msg: format!("unreadable: {e}"),
            }),
        }
    }
    (files.len(), violations)
}

fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(entries) = fs::read_dir(dir) else { return };
    for entry in entries.flatten() {
        let path = entry.path();
        if path.is_dir() {
            collect_rs_files(&path, out);
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
}

/// All rules over one source file.  `label` is the `rust/`-relative
/// path (`src/...`) and selects which rules apply; self-tests feed
/// fixture strings under real labels.
fn check_source(label: &str, raw: &str) -> Vec<Violation> {
    let stripped = strip(raw);
    let allows = allowed_rules(raw);
    let in_test = test_mask(&stripped);
    let starts = line_starts(&stripped);
    let mut out = Vec::new();

    let mut flag = |pos: usize, rule: &'static str, msg: String, out: &mut Vec<Violation>| {
        let line = line_of(&starts, pos);
        if in_test[pos] {
            return;
        }
        if allows.get(&line).is_some_and(|rs| rs.iter().any(|r| r == rule)) {
            return;
        }
        out.push(Violation { file: label.to_string(), line, rule, msg });
    };

    if let Some((_, fns)) = HOT_PATH_MANIFEST.iter().find(|(f, _)| *f == label) {
        let bodies = fn_body_ranges(&stripped, fns, &in_test);
        for name in *fns {
            if !bodies.iter().any(|(_, _, n)| n == name) {
                out.push(Violation {
                    file: label.to_string(),
                    line: 1,
                    rule: "hot-path-alloc",
                    msg: format!(
                        "manifest-registered hot-path fn `{name}` not found — \
                         update HOT_PATH_MANIFEST in xtask/src/main.rs"
                    ),
                });
            }
        }
        for &(pat, pre, post) in HOT_BANNED {
            for pos in find_tokens(&stripped, pat, pre, post) {
                if let Some((_, _, name)) = bodies.iter().find(|&&(a, b, _)| pos >= a && pos < b) {
                    flag(
                        pos,
                        "hot-path-alloc",
                        format!("`{pat}` allocates inside hot-path fn `{name}`"),
                        &mut out,
                    );
                }
            }
        }
    }

    if NO_UNWRAP_FILES.contains(&label) {
        for &(pat, pre, post) in UNWRAP_BANNED {
            for pos in find_tokens(&stripped, pat, pre, post) {
                flag(
                    pos,
                    "no-unwrap",
                    format!(
                        "`{pat})` on a coordinator request path — handle the error; \
                         a panic here drops client responders"
                    ),
                    &mut out,
                );
            }
        }
    }

    let wallclock = NO_WALLCLOCK_FILES.contains(&label)
        || NO_WALLCLOCK_PREFIXES.iter().any(|p| label.starts_with(p));
    if wallclock {
        for &(pat, pre, post) in WALLCLOCK_BANNED {
            for pos in find_tokens(&stripped, pat, pre, post) {
                flag(
                    pos,
                    "no-wallclock",
                    format!("`{pat}` in a bitwise-gated module — results must not depend on time"),
                    &mut out,
                );
            }
        }
    }

    out.sort_by_key(|v| v.line);
    out
}

/// Per-line escape hatches: `// lint: allow(rule)` (scanned on the raw
/// line, so the annotation itself lives in a comment).
fn allowed_rules(raw: &str) -> HashMap<usize, Vec<String>> {
    let mut map: HashMap<usize, Vec<String>> = HashMap::new();
    for (ln, line) in raw.lines().enumerate() {
        let mut rest = line;
        while let Some(i) = rest.find("lint: allow(") {
            let after = &rest[i + "lint: allow(".len()..];
            if let Some(end) = after.find(')') {
                map.entry(ln + 1).or_default().push(after[..end].trim().to_string());
                rest = &after[end..];
            } else {
                break;
            }
        }
    }
    map
}

/// Replace comments, string literals and char literals with spaces,
/// preserving newlines — line numbers and code tokens survive, prose
/// does not.  Handles nested block comments, raw strings (`r"…"`,
/// `r#"…"#`), escapes, and the char-literal/lifetime ambiguity.
fn strip(src: &str) -> String {
    let b: Vec<char> = src.chars().collect();
    let mut out: Vec<char> = Vec::with_capacity(b.len());
    let blank = |c: char| if c == '\n' { '\n' } else { ' ' };
    let mut i = 0;
    while i < b.len() {
        let c = b[i];
        if c == '/' && b.get(i + 1) == Some(&'/') {
            while i < b.len() && b[i] != '\n' {
                out.push(' ');
                i += 1;
            }
        } else if c == '/' && b.get(i + 1) == Some(&'*') {
            let mut depth = 1usize;
            out.push(' ');
            out.push(' ');
            i += 2;
            while i < b.len() && depth > 0 {
                if b[i] == '/' && b.get(i + 1) == Some(&'*') {
                    depth += 1;
                    out.push(' ');
                    out.push(' ');
                    i += 2;
                } else if b[i] == '*' && b.get(i + 1) == Some(&'/') {
                    depth -= 1;
                    out.push(' ');
                    out.push(' ');
                    i += 2;
                } else {
                    out.push(blank(b[i]));
                    i += 1;
                }
            }
        } else if c == 'r'
            && !ident_char_at(&b, i.wrapping_sub(1))
            && raw_string_at(&b, i).is_some()
        {
            let hashes = raw_string_at(&b, i).unwrap_or(0);
            // r, hashes, opening quote
            for _ in 0..(hashes + 2) {
                out.push(' ');
            }
            i += hashes + 2;
            while i < b.len() {
                if b[i] == '"' && (0..hashes).all(|h| b.get(i + 1 + h) == Some(&'#')) {
                    for _ in 0..(hashes + 1) {
                        out.push(' ');
                    }
                    i += hashes + 1;
                    break;
                }
                out.push(blank(b[i]));
                i += 1;
            }
        } else if c == '"' {
            out.push(' ');
            i += 1;
            while i < b.len() {
                if b[i] == '\\' {
                    // the escaped char may be a newline (string line
                    // continuation) — newlines must survive stripping
                    out.push(' ');
                    out.push(b.get(i + 1).map_or(' ', |&e| blank(e)));
                    i += 2;
                } else if b[i] == '"' {
                    out.push(' ');
                    i += 1;
                    break;
                } else {
                    out.push(blank(b[i]));
                    i += 1;
                }
            }
        } else if c == '\'' {
            if b.get(i + 1) == Some(&'\\') {
                // escaped char literal: '\n', '\\', '\u{..}' — to closing quote
                out.push(' ');
                out.push(' ');
                i += 2;
                while i < b.len() && b[i] != '\'' {
                    out.push(blank(b[i]));
                    i += 1;
                }
                if i < b.len() {
                    out.push(' ');
                    i += 1;
                }
            } else if b.get(i + 2) == Some(&'\'') && b.get(i + 1).is_some_and(|&x| x != '\'') {
                out.push(' ');
                out.push(' ');
                out.push(' ');
                i += 3;
            } else {
                // lifetime ('a, '_) — keep the tick, tokens stay intact
                out.push('\'');
                i += 1;
            }
        } else {
            out.push(c);
            i += 1;
        }
    }
    out.into_iter().collect()
}

/// `Some(hash count)` when `b[i..]` opens a raw string (`r"`, `r#"`,
/// `br"` is caught via its `r`).
fn raw_string_at(b: &[char], i: usize) -> Option<usize> {
    let mut j = i + 1;
    let mut hashes = 0;
    while b.get(j) == Some(&'#') {
        hashes += 1;
        j += 1;
    }
    (b.get(j) == Some(&'"')).then_some(hashes)
}

fn ident_char_at(b: &[char], i: usize) -> bool {
    b.get(i).is_some_and(|c| c.is_alphanumeric() || *c == '_')
}

fn is_ident(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Byte mask: `true` where the byte lies inside a `#[cfg(test)] mod {…}`
/// body.  (`#[cfg(test)] mod x;` declarations guard files compiled out
/// entirely — nothing to mask.)
fn test_mask(stripped: &str) -> Vec<bool> {
    let bytes = stripped.as_bytes();
    let mut mask = vec![false; bytes.len() + 1]; // +1: patterns ending at EOF
    let mut from = 0;
    while let Some(off) = stripped[from..].find("#[cfg(test)]") {
        let attr_end = from + off + "#[cfg(test)]".len();
        // skip whitespace and further attributes (#[allow(...)], …)
        let mut j = attr_end;
        loop {
            while j < bytes.len() && bytes[j].is_ascii_whitespace() {
                j += 1;
            }
            if j + 1 < bytes.len() && bytes[j] == b'#' && bytes[j + 1] == b'[' {
                let mut depth = 0usize;
                while j < bytes.len() {
                    match bytes[j] {
                        b'[' => depth += 1,
                        b']' => {
                            depth -= 1;
                            if depth == 0 {
                                j += 1;
                                break;
                            }
                        }
                        _ => {}
                    }
                    j += 1;
                }
            } else {
                break;
            }
        }
        // a `;` before any `{` is a module declaration — no body to mask
        while j < bytes.len() && bytes[j] != b'{' && bytes[j] != b';' {
            j += 1;
        }
        if j < bytes.len() && bytes[j] == b'{' {
            let open = j;
            let mut depth = 0usize;
            while j < bytes.len() {
                match bytes[j] {
                    b'{' => depth += 1,
                    b'}' => {
                        depth -= 1;
                        if depth == 0 {
                            break;
                        }
                    }
                    _ => {}
                }
                j += 1;
            }
            for m in mask.iter_mut().take(j.min(bytes.len()) + 1).skip(open) {
                *m = true;
            }
        }
        from = attr_end;
    }
    mask
}

/// Byte offsets where each line starts (line 1 at offset 0).
fn line_starts(stripped: &str) -> Vec<usize> {
    let mut starts = vec![0usize];
    for (i, b) in stripped.bytes().enumerate() {
        if b == b'\n' {
            starts.push(i + 1);
        }
    }
    starts
}

fn line_of(starts: &[usize], pos: usize) -> usize {
    starts.partition_point(|&s| s <= pos)
}

/// Body byte ranges `(open brace, close brace, name)` of every fn in
/// `names` defined outside test mods.  Trait method *declarations*
/// (`fn f(…);`) have no body and are skipped.
fn fn_body_ranges(stripped: &str, names: &[&str], in_test: &[bool]) -> Vec<(usize, usize, String)> {
    let bytes = stripped.as_bytes();
    let mut out = Vec::new();
    let mut i = 0;
    while i < bytes.len() {
        // next ident token
        if !is_ident(bytes[i] as char) {
            i += 1;
            continue;
        }
        let start = i;
        while i < bytes.len() && is_ident(bytes[i] as char) {
            i += 1;
        }
        if &stripped[start..i] != "fn" || (start > 0 && is_ident(bytes[start - 1] as char)) {
            continue;
        }
        // the fn name
        let mut j = i;
        while j < bytes.len() && bytes[j].is_ascii_whitespace() {
            j += 1;
        }
        let name_start = j;
        while j < bytes.len() && is_ident(bytes[j] as char) {
            j += 1;
        }
        let name = &stripped[name_start..j];
        if !names.contains(&name) || in_test.get(name_start).copied().unwrap_or(false) {
            continue;
        }
        // signature runs to `{` (body) or `;` (trait declaration)
        while j < bytes.len() && bytes[j] != b'{' && bytes[j] != b';' {
            j += 1;
        }
        if j >= bytes.len() || bytes[j] == b';' {
            continue;
        }
        let open = j;
        let mut depth = 0usize;
        while j < bytes.len() {
            match bytes[j] {
                b'{' => depth += 1,
                b'}' => {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                }
                _ => {}
            }
            j += 1;
        }
        out.push((open, j.min(bytes.len()), name.to_string()));
        i = j;
    }
    out
}

/// Byte offsets of `pat` in `stripped`, honoring ident boundaries:
/// `pre` requires a non-ident char before the match, `post` one after.
fn find_tokens(stripped: &str, pat: &str, pre: bool, post: bool) -> Vec<usize> {
    let bytes = stripped.as_bytes();
    let mut out = Vec::new();
    for (pos, _) in stripped.match_indices(pat) {
        if pre && pos > 0 && is_ident(bytes[pos - 1] as char) {
            continue;
        }
        if post {
            let end = pos + pat.len();
            if end < bytes.len() && is_ident(bytes[end] as char) {
                continue;
            }
        }
        out.push(pos);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules_of(violations: &[Violation]) -> Vec<(&'static str, usize)> {
        violations.iter().map(|v| (v.rule, v.line)).collect()
    }

    #[test]
    fn seeded_hot_path_allocation_is_flagged_with_file_and_line() {
        let fixture = "\
pub fn attend_last_into(&mut self, q: &[f32], out: &mut [f32]) {
    let tmp: Vec<f32> = q.iter().copied().collect();
    out.copy_from_slice(&tmp);
}
pub fn attend_pos_into(&mut self) {}
pub fn step_into(&mut self) {}
fn attend_row_core(&self) {}
fn attend_row_paged(&self) {}
";
        let v = check_source("src/engine/decode.rs", fixture);
        assert_eq!(rules_of(&v), vec![("hot-path-alloc", 2)], "{v:?}");
        assert!(v[0].msg.contains("attend_last_into"), "{}", v[0].msg);
        assert!(v[0].to_string().starts_with("src/engine/decode.rs:2:"), "{}", v[0]);
    }

    #[test]
    fn escape_hatch_suppresses_exactly_the_named_rule() {
        let fixture = "\
pub fn attend_last_into(&mut self) {
    let tmp = q.to_vec(); // setup only, hoisted by caller — lint: allow(hot-path-alloc)
    let bad = r.to_vec(); // lint: allow(no-unwrap) — wrong rule, still flagged
}
pub fn attend_pos_into(&mut self) {}
pub fn step_into(&mut self) {}
fn attend_row_core(&self) {}
fn attend_row_paged(&self) {}
";
        let v = check_source("src/engine/decode.rs", fixture);
        assert_eq!(rules_of(&v), vec![("hot-path-alloc", 3)], "{v:?}");
    }

    #[test]
    fn fused_step_bodies_are_manifest_covered() {
        // the shared fused-step bodies are registered hot paths: a seeded
        // allocation in one is flagged, and dropping one from the file
        // (here: fused_prefill_attend) fails the manifest
        let fixture = "\
fn fused_decode_task(st: &mut DecodeState, slot: &mut [f32]) -> bool {
    let tmp = slot.to_vec();
    true
}
fn fused_prefill_project_append() -> bool { true }
";
        let v = check_source("src/coordinator/native.rs", fixture);
        assert_eq!(rules_of(&v), vec![("hot-path-alloc", 1), ("hot-path-alloc", 2)], "{v:?}");
        assert!(v[0].msg.contains("fused_prefill_attend"), "{}", v[0].msg);
        assert!(v[1].msg.contains("fused_decode_task"), "{}", v[1].msg);
    }

    #[test]
    fn dequant_and_page_read_bodies_are_manifest_covered() {
        // the compressed-KV read path is a registered hot path at both
        // layers: the kernel dequant loops and the page-level
        // `section_deq` dispatch.  A seeded allocation in either is
        // flagged, and a kernel.rs without the dequant fns fails the
        // manifest (so the compressed-page attend cannot silently lose
        // its allocation-free claim)
        let fixture = "\
fn section_deq(&self, off: usize, len: usize, buf: &mut Vec<f32>) -> &[f32] {
    let tmp: Vec<f32> = self.bits.to_vec();
    &buf[..len]
}
";
        let v = check_source("src/engine/cache/page.rs", fixture);
        assert_eq!(rules_of(&v), vec![("hot-path-alloc", 2)], "{v:?}");
        assert!(v[0].msg.contains("section_deq"), "{}", v[0].msg);

        let fixture = "\
pub fn softmax_accum_panel() {}
pub fn score_panel() {}
pub fn dot() {}
pub fn axpy() {}
pub fn scale() {}
pub fn pack_transpose() {}
pub fn dequant_bf16(src: &[u16], out: &mut [f32]) {
    let copy = src.to_vec();
}
";
        let v = check_source("src/tensor/kernel.rs", fixture);
        assert_eq!(rules_of(&v), vec![("hot-path-alloc", 1), ("hot-path-alloc", 8)], "{v:?}");
        assert!(v[0].msg.contains("dequant_i8"), "{}", v[0].msg);
        assert!(v[1].msg.contains("dequant_bf16"), "{}", v[1].msg);
    }

    #[test]
    fn trace_record_bodies_are_manifest_covered() {
        // every `fn record` body in trace.rs is a registered hot path —
        // recording must stay allocation-free (the ring is preallocated
        // at construction); a seeded allocation is flagged on its line
        let fixture = "\
impl FlightRecorder {
    pub fn record(&self, step: u64, at_us: u64, event: TraceEvent) {
        let label = format!(\"{step}\");
    }
}
";
        let v = check_source("src/coordinator/trace.rs", fixture);
        assert_eq!(rules_of(&v), vec![("hot-path-alloc", 3)], "{v:?}");
        assert!(v[0].msg.contains("record"), "{}", v[0].msg);
        // a trace.rs without any `record` fn fails the manifest
        let v = check_source("src/coordinator/trace.rs", "fn dump_jsonl() {}\n");
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(v[0].msg.contains("`record` not found"), "{}", v[0].msg);
    }

    #[test]
    fn a_renamed_hot_path_fn_fails_the_manifest() {
        let fixture = "pub fn run_with_renamed() {}\n";
        let v = check_source("src/engine/pool.rs", fixture);
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(v[0].msg.contains("`run_with` not found"), "{}", v[0].msg);
    }

    #[test]
    fn unwrap_on_a_request_path_is_flagged_but_unwrap_or_else_is_not() {
        let fixture = "\
fn admit(&mut self) {
    let p = self.waiting.pop_front().unwrap();
    let q = self.waiting.pop_front().expect(\"front\");
    let g = lock.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
    let d = self.cache.as_ref().map(|c| c.pages_held()).unwrap_or(0);
}
";
        let v = check_source("src/coordinator/scheduler.rs", fixture);
        assert_eq!(rules_of(&v), vec![("no-unwrap", 2), ("no-unwrap", 3)], "{v:?}");
    }

    #[test]
    fn test_modules_are_exempt_from_every_rule() {
        let fixture = "\
fn admit(&mut self) {
    let ok = self.waiting.pop_front();
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    fn helper() {
        let p = queue.pop_front().unwrap();
        let t = Instant::now();
    }
}
";
        let v = check_source("src/coordinator/scheduler.rs", fixture);
        assert!(v.is_empty(), "{v:?}");
    }

    #[test]
    fn wallclock_reads_in_bitwise_gated_modules_are_flagged() {
        let fixture = "\
fn mra2_apply_blocks() {
    let t0 = Instant::now();
}
";
        let v = check_source("src/mra/attention.rs", fixture);
        assert_eq!(rules_of(&v), vec![("no-wallclock", 2)], "{v:?}");
        // SystemTime too, and prefix matching covers any file in tensor/
        let v = check_source("src/tensor/new_kernel.rs", "fn f() { SystemTime::now(); }\n");
        assert_eq!(rules_of(&v), vec![("no-wallclock", 1)], "{v:?}");
    }

    #[test]
    fn strings_and_comments_never_trigger_rules() {
        let fixture = "\
fn admit(&mut self) {
    // prose about .unwrap() and Instant::now and vec![] patterns
    let msg = \".unwrap( in a string is fine\";
    let raw = r#\"so is .expect( here\"#;
    let ch = '\\n';
}
";
        let v = check_source("src/coordinator/scheduler.rs", fixture);
        assert!(v.is_empty(), "{v:?}");
    }

    #[test]
    fn lifetimes_do_not_derail_the_lexer() {
        let fixture = "\
fn admit<'a>(&'a mut self, x: &'a str) {
    let p = self.waiting.pop_front().unwrap();
}
";
        let v = check_source("src/coordinator/scheduler.rs", fixture);
        assert_eq!(rules_of(&v), vec![("no-unwrap", 2)], "{v:?}");
    }

    #[test]
    fn unregistered_files_and_fns_are_untouched() {
        // allocations outside registered fns of a registered file: fine
        let fixture = "\
pub fn helper() {
    let v: Vec<f32> = xs.to_vec();
}
pub fn mra2_apply_blocks() {
    let x = 1;
}
";
        let v = check_source("src/mra/attention.rs", fixture);
        assert!(v.is_empty(), "{v:?}");
        // a file under no rule at all
        let v = check_source(
            "src/runtime/pjrt.rs",
            "fn f() { x.unwrap(); let t = Instant::now(); }\n",
        );
        assert!(v.is_empty(), "{v:?}");
    }

    /// The shipped tree must be lint-clean: this is the same check CI
    /// runs as `cargo xtask lint`, wired into `cargo test` so a
    /// violation cannot land even when CI's lint job is skipped.
    #[test]
    fn the_real_tree_is_clean() {
        let root = src_root();
        assert!(root.is_dir(), "source root missing: {}", root.display());
        let (files, violations) = lint_tree(&root);
        assert!(files > 20, "walked only {files} files — wrong root?");
        assert!(
            violations.is_empty(),
            "tree has lint violations:\n{}",
            violations.iter().map(|v| v.to_string()).collect::<Vec<_>>().join("\n")
        );
    }
}
