//! Tiny property-testing helper (no `proptest` crate offline): run a
//! predicate over `cases` seeded inputs, reporting the first failing seed
//! so it can be replayed deterministically.

use crate::tensor::Rng;

/// Run `prop(seed, rng)` for `cases` seeds; panic with the failing seed.
pub fn for_all_seeds(cases: u64, mut prop: impl FnMut(u64, &mut Rng) -> Result<(), String>) {
    for seed in 0..cases {
        let mut rng = Rng::new(0xC0FFEE ^ seed.wrapping_mul(0x9E3779B97F4A7C15));
        if let Err(msg) = prop(seed, &mut rng) {
            panic!("property failed at seed {seed}: {msg}");
        }
    }
}

/// Assert two f32 slices are elementwise close.
pub fn assert_close(a: &[f32], b: &[f32], atol: f32, rtol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let tol = atol + rtol * y.abs();
        if (x - y).abs() > tol {
            return Err(format!("elem {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        for_all_seeds(20, |_, rng| {
            let u = rng.uniform();
            if (0.0..1.0).contains(&u) { Ok(()) } else { Err(format!("{u}")) }
        });
    }

    #[test]
    #[should_panic(expected = "property failed at seed 3")]
    fn reports_failing_seed() {
        for_all_seeds(10, |seed, _| if seed == 3 { Err("boom".into()) } else { Ok(()) });
    }

    #[test]
    fn assert_close_tolerances() {
        assert!(assert_close(&[1.0], &[1.0005], 0.0, 1e-3).is_ok());
        assert!(assert_close(&[1.0], &[1.1], 0.0, 1e-3).is_err());
        assert!(assert_close(&[0.0], &[1e-6], 1e-5, 0.0).is_ok());
        assert!(assert_close(&[1.0], &[1.0, 2.0], 1.0, 1.0).is_err());
    }

    /// The fused online-softmax fast path matches the dense oracle to
    /// <= 1e-5 max abs for random `(n, d, b, m, causality, variant)`
    /// combinations — `d` sweeps the kernel layer's specialized widths
    /// (32, 64) and the generic path, `b` goes down to 1 (where the causal
    /// diagonal tile is a single element and the per-row triangular mask
    /// must degenerate to a no-op).
    #[test]
    fn fused_fast_path_matches_dense_oracle_for_random_shapes() {
        use crate::mra::{
            dense_mra2, dense_mra2_causal, mra2_attention, mra2_attention_causal, Variant,
        };
        use crate::tensor::Mat;
        const BLOCKS: [usize; 5] = [1, 2, 4, 8, 16];
        const DIMS: [usize; 5] = [4, 8, 16, 32, 64];
        for_all_seeds(16, |seed, rng| {
            // seed 0 pins the trickiest corner: causal at b = 1
            let (b, d, causal) = if seed == 0 {
                (1usize, 8usize, true)
            } else {
                (
                    BLOCKS[rng.below(BLOCKS.len())],
                    DIMS[rng.below(DIMS.len())],
                    rng.below(2) == 0,
                )
            };
            let nb = 2 + rng.below(6);
            let n = b * nb;
            let m = 1 + rng.below(nb * nb);
            let variant = if rng.below(2) == 0 {
                Variant::Full
            } else {
                Variant::Sparse
            };
            let q = Mat::randn(n, d, 1.0, rng);
            let k = Mat::randn(n, d, 1.0, rng);
            let v = Mat::randn(n, d, 1.0, rng);
            let (z, z_dense) = if causal {
                (
                    mra2_attention_causal(&q, &k, &v, b, m, variant),
                    dense_mra2_causal(&q, &k, &v, b, m, variant).1,
                )
            } else {
                (
                    mra2_attention(&q, &k, &v, b, m, variant),
                    dense_mra2(&q, &k, &v, b, m, variant).1,
                )
            };
            let max_abs = z
                .data
                .iter()
                .zip(&z_dense.data)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            if max_abs > 1e-5 {
                return Err(format!(
                    "n={n} d={d} b={b} m={m} causal={causal} {variant:?}: max abs {max_abs}"
                ));
            }
            Ok(())
        });
    }

    /// Causal MRA-2 never attends to future positions: rewriting every
    /// q/k/v row from a block-aligned cut onward — values, keys *and*
    /// queries — must leave all output rows before the cut bitwise
    /// unchanged.  This holds because causal selection keeps its budget
    /// local to each query block (DESIGN.md §7): neither the refined set
    /// nor the low-res correction of block `x` reads pooled statistics of
    /// blocks `> x`.
    #[test]
    fn causal_mra2_output_never_attends_to_future_positions() {
        use crate::mra::{mra2_attention_causal, Variant};
        use crate::tensor::Mat;
        let (n, b, d) = (64usize, 8usize, 8usize);
        for_all_seeds(12, |seed, rng| {
            let m = 1 + rng.below(24);
            let variant = if seed % 2 == 0 {
                Variant::Full
            } else {
                Variant::Sparse
            };
            let mut q = Mat::randn(n, d, 1.0, rng);
            let mut k = Mat::randn(n, d, 1.0, rng);
            let mut v = Mat::randn(n, d, 1.0, rng);
            let z = mra2_attention_causal(&q, &k, &v, b, m, variant);
            let cut = (1 + rng.below(n / b - 1)) * b;
            for i in cut..n {
                for j in 0..d {
                    q.set(i, j, rng.normal());
                    k.set(i, j, rng.normal());
                    v.set(i, j, rng.normal());
                }
            }
            let z2 = mra2_attention_causal(&q, &k, &v, b, m, variant);
            if z.data[..cut * d] != z2.data[..cut * d] {
                return Err(format!(
                    "rows before {cut} changed with the future (m={m}, {variant:?})"
                ));
            }
            Ok(())
        });
    }
}
