//! # mra-attention
//!
//! Production-grade reproduction of *"Multi Resolution Analysis (MRA) for
//! Approximate Self-Attention"* (Zeng et al., ICML 2022) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L1** (`python/compile/kernels/`) — Pallas kernels for the MRA block
//!   operators, lowered at build time.
//! * **L2** (`python/compile/model.py`) — JAX transformer fwd/bwd calling
//!   the kernels, AOT-lowered to HLO text artifacts.
//! * **L3** (this crate) — the coordinator: PJRT runtime (feature `pjrt`),
//!   serving batcher / router, training driver, the parallel batched
//!   multi-head attention engine ([`engine`]), plus a complete native
//!   implementation of the paper's algorithm and every baseline for CPU
//!   benchmarking.
//!
//! See `DESIGN.md` (repo root) for the full system inventory and the
//! engine schedule, and `EXPERIMENTS.md` for reproduced tables/figures and
//! the perf methodology.

pub mod baselines;
pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod engine;
pub mod mra;
pub mod proptest;
pub mod runtime;
pub mod tensor;
pub mod wavelet;
