//! Parallel batched multi-head attention engine.
//!
//! The native serving/bench core of the repo: a [`BatchedTensor`] holds the
//! contiguous `(batch, heads, n, d)` Q/K/V buffers, an [`AttnKernel`]
//! implements one attention algorithm (MRA-2 / MRA-2-s, exact, or any
//! [`crate::baselines::AttentionApprox`] via [`kernels::ApproxShim`]), and
//! [`Engine::forward`] schedules the work over a scoped-thread pool
//! ([`pool`], std only):
//!
//! 1. **plan phase** — one task per `(batch, head)` pair builds the
//!    kernel's read-only per-head plan (for MRA-2: pyramid pooling + Alg. 1
//!    selection);
//! 2. **compute phase** — each head's output is split into disjoint
//!    query-row shards (for MRA-2: query-block ranges of the fast path,
//!    which are fully independent — see `mra::attention::mra2_apply_blocks`)
//!    and the flattened `(batch, head, query-block)` task list drains
//!    through the pool's work-stealing atomic cursor ([`pool::run_with`]);
//!    every worker owns one kernel scratch arena
//!    ([`kernels::AttnKernel::make_scratch`]) reused across all the shards
//!    it claims, so the steady-state compute phase performs zero heap
//!    allocations.
//!
//! Shards own disjoint `&mut` slices of the output buffer, so the whole
//! scheduler is safe Rust, and every shard computes exactly the same float
//! sequence as the sequential path — the parallel output is **bitwise
//! identical** at any thread count (asserted in tests and
//! `benches/bench_engine.rs`).
//!
//! See DESIGN.md §Engine for the schedule and EXPERIMENTS.md §Engine for
//! measured thread scaling.
//!
//! Serving-side state lives next door: [`cache`] is the paged KV arena +
//! radix prefix tree, and [`decode`] the per-stream incremental decode
//! state built on its pages (DESIGN.md §9).

pub mod cache;
pub mod decode;
pub mod kernels;
pub mod pool;
#[cfg(all(loom, test))]
mod pool_loom;
#[cfg(test)]
mod pool_model;
pub mod tensor4;

pub use cache::{CacheStats, Page, PageFormat, PagePool, PageRef, PoolExhausted, RadixCache};
pub use decode::{causal_row_attention, causal_row_oracle, DecodeScratch, DecodeState, DrawState};
pub use kernels::{
    kernel_by_name, ApproxShim, AttnKernel, CausalExactKernel, ExactKernel, HeadPlan,
    KernelScratch, Mra2Kernel, KERNEL_NAMES,
};
pub use tensor4::{rel_fro_error_flat, BatchedTensor, MatView};

/// Batched multi-head attention executor over one kernel.
pub struct Engine {
    kernel: Box<dyn AttnKernel>,
    threads: usize,
}

/// One unit of compute-phase work: a disjoint output shard of one head.
struct ShardTask<'a> {
    pair: usize,
    r0: usize,
    out: &'a mut [f32],
}

impl Engine {
    /// Engine over `kernel` with an explicit worker count (1 = sequential).
    pub fn new(kernel: Box<dyn AttnKernel>, threads: usize) -> Self {
        Engine { kernel, threads: threads.max(1) }
    }

    /// Engine sized to the machine's available parallelism.
    pub fn with_default_threads(kernel: Box<dyn AttnKernel>) -> Self {
        Self::new(kernel, pool::default_threads())
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    pub fn kernel_name(&self) -> String {
        self.kernel.name()
    }

    /// Batched attention forward: `(batch, heads, n, d)` Q/K/V in, the
    /// row-normalized `Z_hat` of the same shape out.
    pub fn forward(
        &self,
        q: &BatchedTensor,
        k: &BatchedTensor,
        v: &BatchedTensor,
    ) -> BatchedTensor {
        assert_eq!(q.shape(), k.shape(), "q/k shape mismatch");
        assert_eq!(q.shape(), v.shape(), "q/v shape mismatch");
        let (batch, heads, n, d) = q.shape();
        let pairs = batch * heads;
        let head_len = n * d;

        // phase 1: per-(batch, head) plans, parallel across pairs
        let mut plans: Vec<Option<HeadPlan>> = Vec::with_capacity(pairs);
        plans.resize_with(pairs, || None);
        {
            let slots = plans.iter_mut().enumerate().collect::<Vec<_>>();
            pool::run(self.threads, slots, |(p, slot): (usize, &mut Option<HeadPlan>)| {
                let (b, h) = (p / heads, p % heads);
                *slot = Some(self.kernel.plan_head(q.view(b, h), k.view(b, h), v.view(b, h)));
            });
        }

        // phase 2: the flattened (batch, head, query-block) task list
        // drains through the pool's work-stealing cursor; each worker keeps
        // one kernel scratch arena for every shard it claims
        let mut out = BatchedTensor::zeros(batch, heads, n, d);
        let shard_rows = self.kernel.shard_rows(n);
        let mut tasks: Vec<ShardTask<'_>> = Vec::new();
        for (p, head_out) in out.data.chunks_mut(head_len).enumerate() {
            match shard_rows {
                Some(rows) if rows < n => {
                    for (si, sub) in head_out.chunks_mut(rows * d).enumerate() {
                        tasks.push(ShardTask { pair: p, r0: si * rows, out: sub });
                    }
                }
                _ => tasks.push(ShardTask { pair: p, r0: 0, out: head_out }),
            }
        }
        let plans = &plans;
        let kernel = self.kernel.as_ref();
        pool::run_with(
            self.threads,
            tasks,
            || kernel.make_scratch(),
            |scratch, t| {
                let (b, h) = (t.pair / heads, t.pair % heads);
                let rows = t.out.len() / d;
                let plan = plans[t.pair].as_ref().expect("plan built in phase 1");
                kernel.compute_range(
                    plan,
                    q.view(b, h),
                    k.view(b, h),
                    v.view(b, h),
                    t.r0,
                    t.r0 + rows,
                    t.out,
                    scratch,
                );
            },
        );
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::longformer::Longformer;
    use crate::baselines::nystromformer::Nystromformer;
    use crate::baselines::AttentionApprox;
    use crate::mra::{mra2_attention, mra2_attention_causal, Variant};
    use crate::tensor::{ops, Mat, Rng};

    fn qkv(batch: usize, heads: usize, n: usize, d: usize, seed: u64) -> [BatchedTensor; 3] {
        let mut rng = Rng::new(seed);
        [
            BatchedTensor::randn(batch, heads, n, d, 1.0, &mut rng),
            BatchedTensor::randn(batch, heads, n, d, 1.0, &mut rng),
            BatchedTensor::randn(batch, heads, n, d, 1.0, &mut rng),
        ]
    }

    #[test]
    fn mra2_parallel_is_bitwise_sequential_at_every_thread_count() {
        let [q, k, v] = qkv(2, 3, 128, 16, 0);
        for variant in [Variant::Full, Variant::Sparse] {
            // per-head sequential reference through the public fast path
            let mut reference = BatchedTensor::zeros(2, 3, 128, 16);
            for b in 0..2 {
                for h in 0..3 {
                    let z = mra2_attention(
                        &q.head_mat(b, h),
                        &k.head_mat(b, h),
                        &v.head_mat(b, h),
                        16,
                        6,
                        variant,
                    );
                    reference.head_mut(b, h).copy_from_slice(&z.data);
                }
            }
            for threads in [1, 2, 4, 8] {
                let engine =
                    Engine::new(Box::new(Mra2Kernel::new(16, 6, variant)), threads);
                let out = engine.forward(&q, &k, &v);
                assert_eq!(
                    out.data, reference.data,
                    "{variant:?} diverged at {threads} threads"
                );
                // the acceptance-criterion form of the same statement
                assert!(rel_fro_error_flat(&out.data, &reference.data) <= 1e-6);
            }
        }
    }

    #[test]
    fn causal_mra2_parallel_is_bitwise_sequential() {
        let [q, k, v] = qkv(2, 2, 128, 16, 7);
        for variant in [Variant::Full, Variant::Sparse] {
            let mut reference = BatchedTensor::zeros(2, 2, 128, 16);
            for b in 0..2 {
                for h in 0..2 {
                    let z = mra2_attention_causal(
                        &q.head_mat(b, h),
                        &k.head_mat(b, h),
                        &v.head_mat(b, h),
                        16,
                        8,
                        variant,
                    );
                    reference.head_mut(b, h).copy_from_slice(&z.data);
                }
            }
            for threads in [1, 4] {
                let engine =
                    Engine::new(Box::new(Mra2Kernel::new_causal(16, 8, variant)), threads);
                let out = engine.forward(&q, &k, &v);
                assert_eq!(
                    out.data, reference.data,
                    "causal {variant:?} diverged at {threads} threads"
                );
            }
        }
    }

    #[test]
    fn causal_exact_kernel_masks_the_future() {
        let [q, k, _] = qkv(1, 1, 96, 8, 8);
        // ones-values: every causal row is a convex combination -> exactly 1
        let mut v = BatchedTensor::zeros(1, 1, 96, 8);
        v.data.fill(1.0);
        let engine = Engine::new(Box::new(CausalExactKernel), 3);
        let out = engine.forward(&q, &k, &v);
        for &x in out.data.iter() {
            assert!((x - 1.0).abs() < 1e-5);
        }
        // row 0 attends only itself: output row 0 == v row 0 for random v
        let mut rng = Rng::new(9);
        let v = BatchedTensor::randn(1, 1, 96, 8, 1.0, &mut rng);
        let out = engine.forward(&q, &k, &v);
        for c in 0..8 {
            assert!((out.view(0, 0).get(0, c) - v.view(0, 0).get(0, c)).abs() < 1e-5);
        }
    }

    #[test]
    fn exact_kernel_matches_dense_reference() {
        let [q, k, v] = qkv(2, 2, 96, 8, 1);
        let engine = Engine::new(Box::new(ExactKernel), 3);
        let out = engine.forward(&q, &k, &v);
        for b in 0..2 {
            for h in 0..2 {
                let want =
                    ops::exact_attention(&q.head_mat(b, h), &k.head_mat(b, h), &v.head_mat(b, h));
                let got = out.head_mat(b, h);
                assert!(ops::rel_fro_error(&got, &want) < 1e-5, "head ({b},{h})");
            }
        }
    }

    #[test]
    fn approx_shims_match_their_single_head_baselines() {
        let [q, k, v] = qkv(1, 2, 128, 16, 2);
        let shims: Vec<Box<dyn AttnKernel>> = vec![
            Box::new(ApproxShim::new(Longformer::new(8, 1))),
            Box::new(ApproxShim::new(Nystromformer::new(16, 6))),
        ];
        let directs: Vec<Box<dyn AttentionApprox>> = vec![
            Box::new(Longformer::new(8, 1)),
            Box::new(Nystromformer::new(16, 6)),
        ];
        for (shim, direct) in shims.into_iter().zip(directs) {
            let engine = Engine::new(shim, 4);
            let out = engine.forward(&q, &k, &v);
            for h in 0..2 {
                let want =
                    direct.compute(&q.head_mat(0, h), &k.head_mat(0, h), &v.head_mat(0, h));
                assert_eq!(out.head_mat(0, h), want, "{} head {h}", direct.name());
            }
        }
    }

    #[test]
    fn engine_output_rows_stay_convex_under_tiny_budgets() {
        // batched form of the zero-row regression: m = 2 with nb = 8
        let mut rng = Rng::new(3);
        let q = BatchedTensor::randn(2, 2, 128, 16, 1.0, &mut rng);
        let k = BatchedTensor::randn(2, 2, 128, 16, 1.0, &mut rng);
        let mut v = BatchedTensor::zeros(2, 2, 128, 16);
        v.data.fill(1.0);
        for variant in [Variant::Full, Variant::Sparse] {
            let engine = Engine::new(Box::new(Mra2Kernel::new(16, 2, variant)), 4);
            let out = engine.forward(&q, &k, &v);
            for &x in out.data.iter() {
                assert!((x - 1.0).abs() < 1e-4, "{variant:?}: {x}");
            }
        }
    }

    #[test]
    fn kernel_accessors() {
        let engine = Engine::with_default_threads(Box::new(ExactKernel));
        assert!(engine.threads() >= 1);
        assert!(engine.kernel_name().contains("exact"));
        let m = Engine::new(Box::new(Mra2Kernel::new(32, 8, Variant::Full)), 2);
        assert!(m.kernel_name().contains("mra-2"));
        let mat = Mat::eye(4);
        assert_eq!(MatView::from_mat(&mat).get(2, 2), 1.0);
    }
}
