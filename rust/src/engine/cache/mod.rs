//! Session-serving KV cache: a paged arena + radix prefix tree.
//!
//! The MRA-2 decode state of one `(layer, head)` stream decomposes into
//! block-aligned units (DESIGN.md §7): raw K/V rows, the packed K^T panel
//! and the pooled pyramid rows of a block are all finalized exactly when
//! the block completes, and attention only ever reads them at block
//! granularity.  That makes the KV state *naturally pageable*: one
//! [`Page`] holds everything the row-attention core needs about one
//! `block`-token span of one stream — a page boundary on a multiple of
//! `block` never splits a tile or a pyramid node.
//!
//! * [`page`] — the bounded [`PagePool`] arena (fixed-size pages, recycled
//!   buffers, refcounted handles, copy-on-write for shared partial tails).
//! * [`radix`] — the [`RadixCache`] token-prefix tree mapping cached
//!   prompt prefixes to their physical pages, at block granularity, with
//!   LRU eviction under memory pressure.
//!
//! Sharing model: a [`PageRef`] is an `Arc` — a forked session or a
//! prefix-cache hit clones handles, not floats, so the shared-prefix
//! portion of a forked session is *physically the same memory* as its
//! parent (asserted via `Arc::ptr_eq` / pool occupancy in tests).  Pages
//! of complete blocks are immutable for life; only the partial tail page
//! of a stream is ever written, and writers copy-on-write when the tail
//! is shared.  See DESIGN.md §9 for the page layout and lifetime rules.

// public cache APIs that can panic must say so — the serving scheduler
// treats any undocumented panic source in this module as a bug (the
// invariant checkers below it rely on panic-free steady-state paths)
#![warn(clippy::missing_panics_doc)]

pub mod page;
pub mod radix;

pub use page::{Page, PageFormat, PagePool, PageRef, PoolExhausted};
pub use radix::{CacheStats, RadixCache};
