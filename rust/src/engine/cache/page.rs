//! Bounded paged arena for decode KV state, with precision-typed pages.
//!
//! One [`Page`] stores everything the per-row attention core
//! ([`crate::engine::decode`]) reads about one `block`-token span of one
//! `(layer, head)` stream, in one fixed-size buffer:
//!
//! ```text
//! [ k rows      | v rows      | K^T panel   | pooled k | pooled v ]
//!   block * d     block * d     block * d     d          d
//! ```
//!
//! K/V rows are written token by token as the stream appends; the panel
//! and the pooled rows are written once, when the block completes
//! ([`Page::finalize`]) — after that the page is immutable for life, so it
//! can be shared freely across sessions (fork, radix prefix cache).
//!
//! **Page formats** (DESIGN.md §15): every page is *born* [`PageFormat::F32`]
//! — the bitwise reference layout, byte-identical to the historical
//! f32-everywhere arena.  Under memory pressure the scheduler *demotes*
//! cold pages ([`PagePool::demote`]) to [`PageFormat::Bf16`] (round-to-
//! nearest-even truncation, 2 bytes/elem) or [`PageFormat::Int8`]
//! (symmetric per-page scale = maxabs/127, 1 byte/elem; the 4-byte scale
//! lives in the [`Page`] handle, not the buffer, and is excluded from
//! byte accounting).  A compressed page keeps the same element layout and
//! dequantizes section-by-section into a caller scratch on read
//! ([`Page::kt_deq`] and friends) — the f32 fast path of those reads is a
//! zero-copy slice, so `F32` stays bitwise *and* cost-identical.
//! Demotion requires exclusivity (`Arc` refcount 1): a page's format is
//! part of its sharing identity, so radix-cached and forked pages are
//! never rewritten under a peer's feet.
//!
//! [`PagePool`] is the global bounded arena: it hands out refcounted
//! [`PageRef`]s up to a fixed **byte** budget (`capacity` f32-sized
//! pages) and recycles the underlying buffers per format when the last
//! reference drops, so the steady-state serving loop performs no heap
//! allocations for cache growth — a page "allocation" is a freelist pop
//! ([`PagePool::buffers_created`] is the f32 high-water mark the
//! allocation-free tests gate on).  Compressed pages shrink the resident
//! footprint, so a mixed-format pool admits more pages than `capacity`
//! f32 ones — [`PagePool::free_pages`] reports the remaining budget in
//! conservative f32-page units (appends always create f32 pages).  When
//! the budget is exhausted, [`PagePool::try_alloc`] fails with
//! [`PoolExhausted`] and the scheduler reacts (radix-cache eviction, then
//! demotion, then session preemption) instead of growing memory without
//! bound.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::tensor::kernel;

/// Error returned when the bounded page pool has no free pages left.
/// Callers either evict/preempt and retry, or surface the error.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PoolExhausted;

impl std::fmt::Display for PoolExhausted {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "KV page pool exhausted (all pages in use)")
    }
}

impl std::error::Error for PoolExhausted {}

/// Storage precision of one page (DESIGN.md §15).  Pages are always
/// *created* `F32`; the compressed formats exist only as demotion
/// targets.  `F32` reads are bitwise identical (zero-copy) to the
/// historical layout; the compressed formats trade a documented
/// attend-output error budget for 2x / 4x resident-byte savings.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum PageFormat {
    /// 4 bytes/elem — the bitwise reference (and the only writable format).
    #[default]
    F32,
    /// 2 bytes/elem — f32 truncated to its top half, round-to-nearest-even.
    Bf16,
    /// 1 byte/elem — symmetric per-page scale (`maxabs / 127`), stored in
    /// the page handle outside the byte-accounted buffer.
    Int8,
}

impl PageFormat {
    /// Bytes each stored element occupies.
    pub const fn bytes_per_elem(self) -> usize {
        match self {
            PageFormat::F32 => 4,
            PageFormat::Bf16 => 2,
            PageFormat::Int8 => 1,
        }
    }

    /// Buffer bytes of one page of `page_elems` elements in this format
    /// (the unit of pool byte accounting; the int8 per-page scale is a
    /// handle field and deliberately not counted).
    pub const fn page_bytes(self, page_elems: usize) -> usize {
        page_elems * self.bytes_per_elem()
    }

    /// Config-file name (`[sessions] page_format`).
    pub const fn name(self) -> &'static str {
        match self {
            PageFormat::F32 => "f32",
            PageFormat::Bf16 => "bf16",
            PageFormat::Int8 => "int8",
        }
    }

    /// Parse a config-file name; `None` for unknown spellings.
    pub fn parse(s: &str) -> Option<PageFormat> {
        match s {
            "f32" => Some(PageFormat::F32),
            "bf16" => Some(PageFormat::Bf16),
            "int8" => Some(PageFormat::Int8),
            _ => None,
        }
    }

    /// Documented max-abs error budget of one attend output row computed
    /// from pages demoted to this format, versus the all-f32 oracle, for
    /// unit-scale (standard normal) inputs.  These are deliberately loose
    /// upper bounds — validated empirically by the
    /// `compressed_pages_attend_within_error_budget` proptest and the
    /// bench_serve error-budget leg, not tight analytical bounds.
    pub const fn error_budget(self) -> f32 {
        match self {
            PageFormat::F32 => 0.0,
            PageFormat::Bf16 => 1e-1,
            PageFormat::Int8 => 4e-1,
        }
    }
}

impl std::fmt::Display for PageFormat {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

struct PoolShared {
    block: usize,
    d: usize,
    page_elems: usize,
    /// Max live f32-equivalent pages; `usize::MAX` = unbounded.
    capacity: usize,
    /// Byte budget: `capacity * 4 * page_elems` (`usize::MAX` = unbounded).
    capacity_bytes: usize,
    /// Physical pages currently alive, any format (each counted once
    /// however many sessions/cache entries share it).
    live: AtomicUsize,
    /// Resident buffer bytes across live pages of every format.
    live_bytes: AtomicUsize,
    /// Live pages per format (byte conservation: `live_bytes` must equal
    /// the format-weighted sum of these).
    live_f32: AtomicUsize,
    live_b16: AtomicUsize,
    live_i8: AtomicUsize,
    /// f32 buffers ever created — the allocation high-water mark the
    /// steady-state gates track; stops growing once the freelist covers
    /// the working set.
    created: AtomicUsize,
    /// Compressed (bf16 + int8) buffers ever created.
    created_compressed: AtomicUsize,
    /// Retired page buffers awaiting reuse, one freelist per format.
    recycled: Mutex<Vec<Box<[f32]>>>,
    recycled_b16: Mutex<Vec<Box<[u16]>>>,
    recycled_i8: Mutex<Vec<Box<[i8]>>>,
}

/// Shared handle to the bounded page arena (cheap to clone).
pub struct PagePool {
    shared: Arc<PoolShared>,
}

impl Clone for PagePool {
    fn clone(&self) -> Self {
        PagePool { shared: self.shared.clone() }
    }
}

impl std::fmt::Debug for PagePool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PagePool")
            .field("block", &self.shared.block)
            .field("d", &self.shared.d)
            .field("capacity", &self.shared.capacity)
            .field("in_use", &self.pages_in_use())
            .field("bytes_in_use", &self.bytes_in_use())
            .finish()
    }
}

/// Refcounted handle to one page; cloning shares the physical page.
pub type PageRef = Arc<Page>;

/// Recover a freelist guard even when a peer thread panicked while
/// holding it.  Each freelist is a `Vec<Box<[T]>>` push/pop — every
/// intermediate state is valid — so poisoning carries no information
/// here, and propagating it from [`Page::drop`] would abort the process
/// (panic-in-drop during unwind).
fn freelist_lock<T>(m: &Mutex<Vec<Box<[T]>>>) -> std::sync::MutexGuard<'_, Vec<Box<[T]>>> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Reserve `bytes` of the pool's byte budget, failing (and rolling the
/// reservation back) when a bounded pool would overshoot.  Reserving
/// before touching a freelist is what keeps concurrent allocators from
/// collectively exceeding the budget.
fn reserve_page_bytes(shared: &PoolShared, bytes: usize) -> Result<(), PoolExhausted> {
    let prev = shared.live_bytes.fetch_add(bytes, Ordering::Relaxed);
    if shared.capacity_bytes != usize::MAX && prev + bytes > shared.capacity_bytes {
        shared.live_bytes.fetch_sub(bytes, Ordering::Relaxed);
        return Err(PoolExhausted);
    }
    Ok(())
}

impl PagePool {
    /// Pool of at most `capacity` live f32-sized pages (a byte budget of
    /// `capacity * 4 * (3*block*d + 2*d)`) for `(block, d)` streams.
    /// Buffers are created lazily and recycled on free; demoted pages
    /// occupy proportionally fewer bytes of the same budget.
    ///
    /// # Panics
    ///
    /// Panics when `capacity == 0` or the `(block, d)` geometry is not
    /// positive — a zero-page pool or zero-sized page is always a
    /// configuration bug, never a runtime condition.
    pub fn new(capacity: usize, block: usize, d: usize) -> Self {
        assert!(capacity > 0, "page pool capacity must be positive");
        assert!(block > 0 && d > 0, "page geometry must be positive");
        let page_elems = 3 * block * d + 2 * d;
        let capacity_bytes = if capacity == usize::MAX {
            usize::MAX
        } else {
            capacity.saturating_mul(PageFormat::F32.page_bytes(page_elems))
        };
        PagePool {
            shared: Arc::new(PoolShared {
                block,
                d,
                page_elems,
                capacity,
                capacity_bytes,
                live: AtomicUsize::new(0),
                live_bytes: AtomicUsize::new(0),
                live_f32: AtomicUsize::new(0),
                live_b16: AtomicUsize::new(0),
                live_i8: AtomicUsize::new(0),
                created: AtomicUsize::new(0),
                created_compressed: AtomicUsize::new(0),
                recycled: Mutex::new(Vec::new()),
                recycled_b16: Mutex::new(Vec::new()),
                recycled_i8: Mutex::new(Vec::new()),
            }),
        }
    }

    /// Unbounded pool — the default for standalone [`DecodeState`]s and
    /// tests; serving schedulers always bound theirs.
    ///
    /// [`DecodeState`]: crate::engine::DecodeState
    pub fn unbounded(block: usize, d: usize) -> Self {
        Self::new(usize::MAX, block, d)
    }

    pub fn block(&self) -> usize {
        self.shared.block
    }

    pub fn d(&self) -> usize {
        self.shared.d
    }

    /// Elements per page (`3 * block * d + 2 * d`), format-independent.
    pub fn page_elems(&self) -> usize {
        self.shared.page_elems
    }

    /// Capacity in f32-equivalent pages (the historical unit; the byte
    /// budget is `capacity_bytes`).
    pub fn capacity(&self) -> usize {
        self.shared.capacity
    }

    /// The pool's byte budget (`usize::MAX` = unbounded).
    pub fn capacity_bytes(&self) -> usize {
        self.shared.capacity_bytes
    }

    /// Physical pages currently alive, in any format.
    pub fn pages_in_use(&self) -> usize {
        self.shared.live.load(Ordering::Relaxed)
    }

    /// Resident buffer bytes across live pages of every format.
    pub fn bytes_in_use(&self) -> usize {
        self.shared.live_bytes.load(Ordering::Relaxed)
    }

    /// Live pages currently in a compressed (bf16/int8) format.
    pub fn compressed_pages_in_use(&self) -> usize {
        self.shared.live_b16.load(Ordering::Relaxed)
            + self.shared.live_i8.load(Ordering::Relaxed)
    }

    /// Full (f32) pages that can still be allocated before
    /// [`PoolExhausted`] — the remaining byte budget in conservative
    /// f32-page units (appends always create f32 pages, so this is the
    /// unit the scheduler's reservation arithmetic needs).
    pub fn free_pages(&self) -> usize {
        self.shared.capacity_bytes.saturating_sub(self.bytes_in_use())
            / PageFormat::F32.page_bytes(self.shared.page_elems)
    }

    /// f32 buffers ever created (the heap-allocation high-water mark;
    /// steady state recycles instead of creating).
    pub fn buffers_created(&self) -> usize {
        self.shared.created.load(Ordering::Relaxed)
    }

    /// Compressed (bf16 + int8) buffers ever created by demotion.
    pub fn compressed_buffers_created(&self) -> usize {
        self.shared.created_compressed.load(Ordering::Relaxed)
    }

    fn grab_buffer(&self) -> Result<Box<[f32]>, PoolExhausted> {
        // reserve the byte budget first so concurrent allocators cannot
        // collectively overshoot the capacity
        reserve_page_bytes(
            &self.shared,
            PageFormat::F32.page_bytes(self.shared.page_elems),
        )?;
        self.shared.live.fetch_add(1, Ordering::Relaxed);
        self.shared.live_f32.fetch_add(1, Ordering::Relaxed);
        let reused = freelist_lock(&self.shared.recycled).pop();
        Ok(reused.unwrap_or_else(|| {
            self.shared.created.fetch_add(1, Ordering::Relaxed);
            vec![0.0f32; self.shared.page_elems].into_boxed_slice()
        }))
    }

    /// Allocate a zeroed f32 page, failing when the pool is out of bytes.
    pub fn try_alloc(&self) -> Result<PageRef, PoolExhausted> {
        let mut data = self.grab_buffer()?;
        data.fill(0.0);
        Ok(Arc::new(Page {
            bits: PageBits::F32(data),
            block: self.shared.block,
            d: self.shared.d,
            pool: self.shared.clone(),
        }))
    }

    /// Allocate a page holding a copy of `src`'s contents — the
    /// copy-on-write step for a shared partial tail page.
    ///
    /// # Panics
    ///
    /// Panics when `src` is not an f32 page: only partial tails are ever
    /// copied-on-write, and partial tails are always f32 (demotion skips
    /// the tail block by construction).
    pub fn alloc_copy(&self, src: &Page) -> Result<PageRef, PoolExhausted> {
        let mut data = self.grab_buffer()?;
        data.copy_from_slice(src.f32_data());
        Ok(Arc::new(Page {
            bits: PageBits::F32(data),
            block: self.shared.block,
            d: self.shared.d,
            pool: self.shared.clone(),
        }))
    }

    /// Take a compressed buffer for a demotion, bypassing the byte-budget
    /// gate: demotion is net-freeing (the compressed page replaces a
    /// strictly larger f32 one that drops the moment the swap completes),
    /// so the transient overshoot is at most one compressed page per
    /// in-flight demotion and can never be what pushes the pool over.
    fn grab_b16_buffer(&self) -> Box<[u16]> {
        self.shared
            .live_bytes
            .fetch_add(PageFormat::Bf16.page_bytes(self.shared.page_elems), Ordering::Relaxed);
        self.shared.live.fetch_add(1, Ordering::Relaxed);
        self.shared.live_b16.fetch_add(1, Ordering::Relaxed);
        freelist_lock(&self.shared.recycled_b16).pop().unwrap_or_else(|| {
            self.shared.created_compressed.fetch_add(1, Ordering::Relaxed);
            vec![0u16; self.shared.page_elems].into_boxed_slice()
        })
    }

    fn grab_i8_buffer(&self) -> Box<[i8]> {
        self.shared
            .live_bytes
            .fetch_add(PageFormat::Int8.page_bytes(self.shared.page_elems), Ordering::Relaxed);
        self.shared.live.fetch_add(1, Ordering::Relaxed);
        self.shared.live_i8.fetch_add(1, Ordering::Relaxed);
        freelist_lock(&self.shared.recycled_i8).pop().unwrap_or_else(|| {
            self.shared.created_compressed.fetch_add(1, Ordering::Relaxed);
            vec![0i8; self.shared.page_elems].into_boxed_slice()
        })
    }

    /// Demote the f32 page behind `page` to `fmt`, swapping the handle
    /// for a freshly quantized compressed twin and returning its f32
    /// bytes to the budget.  Returns `false` (and does nothing) when the
    /// demotion is not applicable:
    ///
    /// * `fmt` is `F32` (nothing to do — the configured no-compression
    ///   mode), or
    /// * the page is already compressed, or
    /// * the handle is shared (`Arc` refcount > 1): a page's format is
    ///   part of its sharing identity — radix-cached and forked pages
    ///   must never change representation under a peer's feet.
    ///
    /// The swap preserves the element layout; only precision changes.
    /// Byte accounting transiently holds both pages (see
    /// [`PagePool::grab_b16_buffer`]) and nets out `3/4` (bf16) or `1/4`
    /// (int8) of an f32 page the moment the old handle drops here.
    pub fn demote(&self, page: &mut PageRef, fmt: PageFormat) -> bool {
        if fmt == PageFormat::F32
            || page.format() != PageFormat::F32
            || Arc::strong_count(page) != 1
        {
            return false;
        }
        let (block, d) = (page.block, page.d);
        let bits = {
            let src = page.f32_data();
            match fmt {
                PageFormat::Bf16 => {
                    let mut data = self.grab_b16_buffer();
                    kernel::quant_bf16(src, &mut data);
                    PageBits::Bf16(data)
                }
                PageFormat::Int8 => {
                    let mut data = self.grab_i8_buffer();
                    let scale = kernel::int8_scale(src);
                    kernel::quant_i8(src, scale, &mut data);
                    PageBits::Int8 { data, scale }
                }
                PageFormat::F32 => return false,
            }
        };
        *page = Arc::new(Page { bits, block, d, pool: self.shared.clone() });
        true
    }

    /// Structural self-check of the arena's accounting, for the
    /// verification layer (DESIGN.md §11).  Returns `Err` with a
    /// description of the first violated invariant:
    ///
    /// * **buffer conservation** — every buffer ever created is either
    ///   inside a live page or parked on its format's freelist:
    ///   `created == live_f32 + recycled_f32` and `created_compressed ==
    ///   live_bf16 + live_int8 + recycled_bf16 + recycled_int8`;
    /// * **page-count conservation** — the per-format live counts sum to
    ///   the total: `live == live_f32 + live_bf16 + live_int8`;
    /// * **byte conservation** — resident bytes equal the format-weighted
    ///   page counts: `live_bytes == 4*pe*live_f32 + 2*pe*live_bf16 +
    ///   pe*live_int8` (a mixed-format pool must not leak fractional
    ///   capacity);
    /// * **bound** — a bounded pool never holds more resident bytes than
    ///   its budget, and `bytes_in_use + free_pages * 4*pe <=
    ///   capacity_bytes` stays consistent;
    /// * **freelist hygiene** — recycled buffers all have the pool's
    ///   exact page geometry (a foreign or truncated buffer would
    ///   corrupt the next page allocated from it).
    ///
    /// Only meaningful at a quiescent point (no concurrent alloc/drop or
    /// demotion in flight): `grab_buffer` reserves bytes before touching
    /// the freelist and a demotion transiently holds both the old and new
    /// page, so mid-operation snapshots can observe transient skew.
    pub fn verify(&self) -> Result<(), String> {
        let pe = self.shared.page_elems;
        let live = self.shared.live.load(Ordering::SeqCst);
        let live_bytes = self.shared.live_bytes.load(Ordering::SeqCst);
        let live_f32 = self.shared.live_f32.load(Ordering::SeqCst);
        let live_b16 = self.shared.live_b16.load(Ordering::SeqCst);
        let live_i8 = self.shared.live_i8.load(Ordering::SeqCst);
        let created = self.shared.created.load(Ordering::SeqCst);
        let created_compressed = self.shared.created_compressed.load(Ordering::SeqCst);
        let count_freelist = |len: usize, bad: usize, what: &str| -> Result<usize, String> {
            if bad != 0 {
                Err(format!(
                    "{what} freelist holds {bad} buffer(s) with the wrong geometry \
                     (expected {pe} elements each)"
                ))
            } else {
                Ok(len)
            }
        };
        let rec_f32 = {
            let g = freelist_lock(&self.shared.recycled);
            count_freelist(g.len(), g.iter().filter(|b| b.len() != pe).count(), "f32")?
        };
        let rec_b16 = {
            let g = freelist_lock(&self.shared.recycled_b16);
            count_freelist(g.len(), g.iter().filter(|b| b.len() != pe).count(), "bf16")?
        };
        let rec_i8 = {
            let g = freelist_lock(&self.shared.recycled_i8);
            count_freelist(g.len(), g.iter().filter(|b| b.len() != pe).count(), "int8")?
        };
        if created != live_f32 + rec_f32 {
            return Err(format!(
                "f32 buffer conservation violated: created {created} != live {live_f32} + \
                 recycled {rec_f32}"
            ));
        }
        if created_compressed != live_b16 + live_i8 + rec_b16 + rec_i8 {
            return Err(format!(
                "compressed buffer conservation violated: created {created_compressed} != \
                 live {} + recycled {}",
                live_b16 + live_i8,
                rec_b16 + rec_i8
            ));
        }
        if live != live_f32 + live_b16 + live_i8 {
            return Err(format!(
                "page-count conservation violated: live {live} != f32 {live_f32} + \
                 bf16 {live_b16} + int8 {live_i8}"
            ));
        }
        let want_bytes = PageFormat::F32.page_bytes(pe) * live_f32
            + PageFormat::Bf16.page_bytes(pe) * live_b16
            + PageFormat::Int8.page_bytes(pe) * live_i8;
        if live_bytes != want_bytes {
            return Err(format!(
                "byte conservation violated: live_bytes {live_bytes} != format-weighted \
                 {want_bytes} (f32 {live_f32}, bf16 {live_b16}, int8 {live_i8} pages \
                 of {pe} elements)"
            ));
        }
        if self.shared.capacity_bytes != usize::MAX {
            if live_bytes > self.shared.capacity_bytes {
                return Err(format!(
                    "resident bytes {live_bytes} exceed the budget {}",
                    self.shared.capacity_bytes
                ));
            }
            let free = self.free_pages();
            if live_bytes + free * PageFormat::F32.page_bytes(pe) > self.shared.capacity_bytes {
                return Err(format!(
                    "byte accounting violated: in_use {live_bytes} + free {free} f32 pages \
                     overshoot the budget {}",
                    self.shared.capacity_bytes
                ));
            }
        }
        Ok(())
    }

    /// Test hook: register the accounting of a phantom f32 page no
    /// handle reaches, keeping the pool's *own* checkers self-consistent
    /// (live, per-format, byte and buffer counts all move together).
    /// Lets checkers layered above the pool (`Scheduler::verify`) prove
    /// they catch reachable-set vs pool-accounting drift the pool itself
    /// cannot see.
    #[cfg(test)]
    pub(crate) fn register_phantom_page_for_test(&self) {
        let pe = self.shared.page_elems;
        self.shared.live.fetch_add(1, Ordering::Relaxed);
        self.shared.live_f32.fetch_add(1, Ordering::Relaxed);
        self.shared.live_bytes.fetch_add(PageFormat::F32.page_bytes(pe), Ordering::Relaxed);
        self.shared.created.fetch_add(1, Ordering::Relaxed);
    }

    /// Undo [`PagePool::register_phantom_page_for_test`].
    #[cfg(test)]
    pub(crate) fn unregister_phantom_page_for_test(&self) {
        let pe = self.shared.page_elems;
        self.shared.live.fetch_sub(1, Ordering::Relaxed);
        self.shared.live_f32.fetch_sub(1, Ordering::Relaxed);
        self.shared.live_bytes.fetch_sub(PageFormat::F32.page_bytes(pe), Ordering::Relaxed);
        self.shared.created.fetch_sub(1, Ordering::Relaxed);
    }

    /// Assert [`PagePool::verify`] under `debug_assertions` or the
    /// `paranoid` feature; compiled to a no-op in plain release builds.
    ///
    /// # Panics
    ///
    /// Panics with the violated invariant's description when the arena
    /// accounting is inconsistent.
    #[track_caller]
    pub fn check_invariants(&self) {
        if cfg!(any(debug_assertions, feature = "paranoid")) {
            if let Err(msg) = self.verify() {
                panic!("PagePool invariant violated: {msg}");
            }
        }
    }
}

/// Precision-typed page storage.  The element *layout* is identical
/// across variants (see the module docs); only the per-element encoding
/// differs.  The int8 scale lives here — one scale for the whole page —
/// so the buffer stays a dense byte array the freelists can recycle.
enum PageBits {
    F32(Box<[f32]>),
    Bf16(Box<[u16]>),
    Int8 { data: Box<[i8]>, scale: f32 },
}

/// One block-aligned span of one `(layer, head)` KV stream.  See the
/// module docs for the layout.  The raw accessors ([`Page::k_row`] and
/// friends) are zero-copy slices valid only on f32 pages; the `_deq`
/// twins are format-agnostic and fall back to dequantizing into a caller
/// scratch.
pub struct Page {
    bits: PageBits,
    block: usize,
    d: usize,
    pool: Arc<PoolShared>,
}

impl Page {
    #[inline]
    fn bd(&self) -> usize {
        self.block * self.d
    }

    /// Storage precision of this page.
    #[inline]
    pub fn format(&self) -> PageFormat {
        match self.bits {
            PageBits::F32(_) => PageFormat::F32,
            PageBits::Bf16(_) => PageFormat::Bf16,
            PageBits::Int8 { .. } => PageFormat::Int8,
        }
    }

    /// Resident buffer bytes of this page (its contribution to
    /// [`PagePool::bytes_in_use`]).
    #[inline]
    pub fn bytes(&self) -> usize {
        self.format().page_bytes(self.pool.page_elems)
    }

    /// The int8 per-page scale (`None` unless the page is `Int8`).
    #[inline]
    pub fn int8_scale(&self) -> Option<f32> {
        match self.bits {
            PageBits::Int8 { scale, .. } => Some(scale),
            _ => None,
        }
    }

    /// The raw f32 buffer; raw accessors and the write path go through
    /// here so a compressed page can never be silently misread as f32.
    #[inline]
    fn f32_data(&self) -> &[f32] {
        match &self.bits {
            PageBits::F32(data) => data,
            _ => panic!(
                "raw f32 accessor on a {} page — use the *_deq reads",
                self.format()
            ),
        }
    }

    #[inline]
    fn f32_data_mut(&mut self) -> &mut [f32] {
        match &mut self.bits {
            PageBits::F32(data) => data,
            PageBits::Bf16(_) | PageBits::Int8 { .. } => panic!(
                "write to a compressed page — only f32 pages are writable"
            ),
        }
    }

    /// Read `len` elements at `off`, format-agnostically: f32 pages
    /// return the zero-copy slice (bitwise identical to the historical
    /// path), compressed pages dequantize into `buf` (grown on first
    /// use, then reused — allocation-free once warm).
    #[inline]
    fn section_deq<'a>(&'a self, off: usize, len: usize, buf: &'a mut Vec<f32>) -> &'a [f32] {
        match &self.bits {
            PageBits::F32(data) => &data[off..off + len],
            PageBits::Bf16(data) => {
                if buf.len() < len {
                    buf.resize(len, 0.0);
                }
                kernel::dequant_bf16(&data[off..off + len], &mut buf[..len]);
                &buf[..len]
            }
            PageBits::Int8 { data, scale } => {
                if buf.len() < len {
                    buf.resize(len, 0.0);
                }
                kernel::dequant_i8(&data[off..off + len], *scale, &mut buf[..len]);
                &buf[..len]
            }
        }
    }

    /// Raw key row `i` of this block (`i < block`).
    ///
    /// # Panics
    ///
    /// Panics on a compressed page (as do all raw accessors below) —
    /// use the `_deq` reads on format-agnostic paths.
    #[inline]
    pub fn k_row(&self, i: usize) -> &[f32] {
        debug_assert!(i < self.block);
        &self.f32_data()[i * self.d..(i + 1) * self.d]
    }

    /// First `rows` key rows, row-major (the partial-tail view).
    ///
    /// # Panics
    ///
    /// Panics on a compressed page.
    #[inline]
    pub fn k_rows(&self, rows: usize) -> &[f32] {
        debug_assert!(rows <= self.block);
        &self.f32_data()[..rows * self.d]
    }

    /// First `rows` value rows, row-major (the partial-tail view).
    ///
    /// # Panics
    ///
    /// Panics on a compressed page.
    #[inline]
    pub fn v_rows(&self, rows: usize) -> &[f32] {
        debug_assert!(rows <= self.block);
        let bd = self.bd();
        &self.f32_data()[bd..bd + rows * self.d]
    }

    /// All `block` value rows (complete-block view).
    ///
    /// # Panics
    ///
    /// Panics on a compressed page.
    #[inline]
    pub fn v_block(&self) -> &[f32] {
        let bd = self.bd();
        &self.f32_data()[bd..2 * bd]
    }

    /// Packed `(d, block)` K^T panel (valid once the block completed).
    ///
    /// # Panics
    ///
    /// Panics on a compressed page.
    #[inline]
    pub fn panel(&self) -> &[f32] {
        let bd = self.bd();
        &self.f32_data()[2 * bd..3 * bd]
    }

    /// Pooled (mean) key row (valid once the block completed).
    ///
    /// # Panics
    ///
    /// Panics on a compressed page.
    #[inline]
    pub fn kt(&self) -> &[f32] {
        let bd = self.bd();
        &self.f32_data()[3 * bd..3 * bd + self.d]
    }

    /// Pooled (mean) value row (valid once the block completed).
    ///
    /// # Panics
    ///
    /// Panics on a compressed page.
    #[inline]
    pub fn vt(&self) -> &[f32] {
        let bd = self.bd();
        &self.f32_data()[3 * bd + self.d..3 * bd + 2 * self.d]
    }

    /// Format-agnostic [`Page::k_rows`]: zero-copy on f32 pages,
    /// dequantized into `buf` otherwise.
    #[inline]
    pub fn k_rows_deq<'a>(&'a self, rows: usize, buf: &'a mut Vec<f32>) -> &'a [f32] {
        debug_assert!(rows <= self.block);
        self.section_deq(0, rows * self.d, buf)
    }

    /// Format-agnostic [`Page::v_rows`].
    #[inline]
    pub fn v_rows_deq<'a>(&'a self, rows: usize, buf: &'a mut Vec<f32>) -> &'a [f32] {
        debug_assert!(rows <= self.block);
        self.section_deq(self.bd(), rows * self.d, buf)
    }

    /// Format-agnostic [`Page::v_block`].
    #[inline]
    pub fn v_block_deq<'a>(&'a self, buf: &'a mut Vec<f32>) -> &'a [f32] {
        self.section_deq(self.bd(), self.bd(), buf)
    }

    /// Format-agnostic [`Page::panel`].
    #[inline]
    pub fn panel_deq<'a>(&'a self, buf: &'a mut Vec<f32>) -> &'a [f32] {
        self.section_deq(2 * self.bd(), self.bd(), buf)
    }

    /// Format-agnostic [`Page::kt`].
    #[inline]
    pub fn kt_deq<'a>(&'a self, buf: &'a mut Vec<f32>) -> &'a [f32] {
        self.section_deq(3 * self.bd(), self.d, buf)
    }

    /// Format-agnostic [`Page::vt`].
    #[inline]
    pub fn vt_deq<'a>(&'a self, buf: &'a mut Vec<f32>) -> &'a [f32] {
        self.section_deq(3 * self.bd() + self.d, self.d, buf)
    }

    /// Write the key/value rows of position `i` within the block.  Only
    /// ever called through a unique (copy-on-write) handle.
    ///
    /// # Panics
    ///
    /// Panics on a compressed page — only f32 pages are writable
    /// (demotion never touches a page that can still be appended to).
    pub fn write_kv_row(&mut self, i: usize, k_row: &[f32], v_row: &[f32]) {
        debug_assert!(i < self.block);
        debug_assert_eq!(k_row.len(), self.d);
        debug_assert_eq!(v_row.len(), self.d);
        let (d, bd) = (self.d, self.bd());
        let data = self.f32_data_mut();
        data[i * d..(i + 1) * d].copy_from_slice(k_row);
        data[bd + i * d..bd + (i + 1) * d].copy_from_slice(v_row);
    }

    /// Seal a completed block: write the pooled rows (`sum * inv`, the
    /// same float sequence as the historical `DecodeState` finalization)
    /// and pack the K^T panel from the page's own key rows (a pure
    /// permutation).  After this the page is immutable (until a possible
    /// demotion, which requires exclusivity).
    ///
    /// # Panics
    ///
    /// Panics on a compressed page.
    pub fn finalize(&mut self, ksum: &[f32], vsum: &[f32], inv: f32) {
        debug_assert_eq!(ksum.len(), self.d);
        debug_assert_eq!(vsum.len(), self.d);
        let (d, block) = (self.d, self.block);
        let bd = block * d;
        let (rows, derived) = self.f32_data_mut().split_at_mut(2 * bd);
        for (o, &s) in derived[bd..bd + d].iter_mut().zip(ksum) {
            *o = s * inv;
        }
        for (o, &s) in derived[bd + d..bd + 2 * d].iter_mut().zip(vsum) {
            *o = s * inv;
        }
        kernel::pack_transpose(&rows[..bd], block, d, &mut derived[..bd]);
    }
}

impl Drop for Page {
    fn drop(&mut self) {
        let pe = self.pool.page_elems;
        // freelist_lock (not .unwrap()): panicking here while another
        // thread unwinds with the freelist held would turn that panic
        // into a process abort
        match std::mem::replace(&mut self.bits, PageBits::F32(Box::default())) {
            PageBits::F32(buf) => {
                freelist_lock(&self.pool.recycled).push(buf);
                self.pool.live_f32.fetch_sub(1, Ordering::Relaxed);
                self.pool.live_bytes.fetch_sub(PageFormat::F32.page_bytes(pe), Ordering::Relaxed);
            }
            PageBits::Bf16(buf) => {
                freelist_lock(&self.pool.recycled_b16).push(buf);
                self.pool.live_b16.fetch_sub(1, Ordering::Relaxed);
                self.pool.live_bytes.fetch_sub(PageFormat::Bf16.page_bytes(pe), Ordering::Relaxed);
            }
            PageBits::Int8 { data, .. } => {
                freelist_lock(&self.pool.recycled_i8).push(data);
                self.pool.live_i8.fetch_sub(1, Ordering::Relaxed);
                self.pool.live_bytes.fetch_sub(PageFormat::Int8.page_bytes(pe), Ordering::Relaxed);
            }
        }
        self.pool.live.fetch_sub(1, Ordering::Relaxed);
    }
}

impl std::fmt::Debug for Page {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Page")
            .field("block", &self.block)
            .field("d", &self.d)
            .field("format", &self.format())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bounded_pool_refuses_past_capacity_and_recycles() {
        let pool = PagePool::new(2, 4, 8);
        assert_eq!(pool.page_elems(), 3 * 4 * 8 + 2 * 8);
        let a = pool.try_alloc().unwrap();
        let b = pool.try_alloc().unwrap();
        assert_eq!(pool.pages_in_use(), 2);
        assert_eq!(pool.free_pages(), 0);
        assert_eq!(pool.try_alloc().unwrap_err(), PoolExhausted);
        drop(a);
        assert_eq!(pool.free_pages(), 1);
        // freed buffer is recycled, not re-created
        let created = pool.buffers_created();
        let c = pool.try_alloc().unwrap();
        assert_eq!(pool.buffers_created(), created, "steady state re-created a buffer");
        drop((b, c));
        assert_eq!(pool.pages_in_use(), 0);
        assert_eq!(pool.bytes_in_use(), 0);
    }

    #[test]
    fn sharing_a_page_does_not_consume_pool_pages() {
        let pool = PagePool::new(4, 2, 4);
        let a = pool.try_alloc().unwrap();
        let shared = a.clone();
        assert_eq!(Arc::strong_count(&a), 2);
        assert_eq!(pool.pages_in_use(), 1, "a shared page is one physical page");
        drop(a);
        assert_eq!(pool.pages_in_use(), 1);
        drop(shared);
        assert_eq!(pool.pages_in_use(), 0);
    }

    #[test]
    fn write_finalize_roundtrip_matches_layout() {
        let (b, d) = (2usize, 3usize);
        let pool = PagePool::unbounded(b, d);
        let mut page = pool.try_alloc().unwrap();
        let p = Arc::get_mut(&mut page).unwrap();
        p.write_kv_row(0, &[1.0, 2.0, 3.0], &[10.0, 20.0, 30.0]);
        p.write_kv_row(1, &[4.0, 5.0, 6.0], &[40.0, 50.0, 60.0]);
        let ksum = [5.0, 7.0, 9.0];
        let vsum = [50.0, 70.0, 90.0];
        p.finalize(&ksum, &vsum, 0.5);
        assert_eq!(page.k_row(0), &[1.0, 2.0, 3.0]);
        assert_eq!(page.k_rows(2), &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(page.v_rows(1), &[10.0, 20.0, 30.0]);
        assert_eq!(page.v_block(), &[10.0, 20.0, 30.0, 40.0, 50.0, 60.0]);
        assert_eq!(page.kt(), &[2.5, 3.5, 4.5]);
        assert_eq!(page.vt(), &[25.0, 35.0, 45.0]);
        // panel is the (d, block) transpose of the key rows
        let mut panel = vec![0.0f32; b * d];
        kernel::pack_transpose(page.k_rows(b), b, d, &mut panel);
        assert_eq!(page.panel(), &panel[..]);
    }

    #[test]
    fn alloc_copy_duplicates_contents_into_a_fresh_page() {
        let pool = PagePool::new(3, 2, 2);
        let mut page = pool.try_alloc().unwrap();
        Arc::get_mut(&mut page).unwrap().write_kv_row(0, &[1.0, 2.0], &[3.0, 4.0]);
        let copy = pool.alloc_copy(&page).unwrap();
        assert!(!Arc::ptr_eq(&page, &copy));
        assert_eq!(copy.k_row(0), page.k_row(0));
        assert_eq!(copy.v_rows(1), page.v_rows(1));
        assert_eq!(pool.pages_in_use(), 2);
    }

    #[test]
    fn recycled_pages_come_back_zeroed() {
        let pool = PagePool::new(1, 2, 2);
        let mut page = pool.try_alloc().unwrap();
        Arc::get_mut(&mut page).unwrap().write_kv_row(1, &[9.0, 9.0], &[9.0, 9.0]);
        drop(page);
        let fresh = pool.try_alloc().unwrap();
        assert!(fresh.k_rows(2).iter().all(|&x| x == 0.0));
        assert!(fresh.v_block().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn pool_exhausted_error_is_descriptive() {
        let msg = PoolExhausted.to_string();
        assert!(msg.contains("page pool exhausted"), "{msg}");
    }

    #[test]
    fn page_format_parse_name_roundtrip_and_sizes() {
        for fmt in [PageFormat::F32, PageFormat::Bf16, PageFormat::Int8] {
            assert_eq!(PageFormat::parse(fmt.name()), Some(fmt));
        }
        assert_eq!(PageFormat::parse("fp8"), None);
        assert_eq!(PageFormat::F32.page_bytes(10), 40);
        assert_eq!(PageFormat::Bf16.page_bytes(10), 20);
        assert_eq!(PageFormat::Int8.page_bytes(10), 10);
        assert_eq!(PageFormat::default(), PageFormat::F32);
        assert_eq!(PageFormat::F32.error_budget(), 0.0);
        assert!(PageFormat::Bf16.error_budget() < PageFormat::Int8.error_budget());
    }

    /// Build one finalized page of pseudo-random contents.
    fn filled_page(pool: &PagePool) -> PageRef {
        let (b, d) = (pool.block(), pool.d());
        let mut rng = crate::tensor::Rng::new(77);
        let mut page = pool.try_alloc().unwrap();
        let p = Arc::get_mut(&mut page).unwrap();
        let mut ksum = vec![0.0f32; d];
        let mut vsum = vec![0.0f32; d];
        for i in 0..b {
            let k: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
            let v: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
            p.write_kv_row(i, &k, &v);
            for (s, &x) in ksum.iter_mut().zip(&k) {
                *s += x;
            }
            for (s, &x) in vsum.iter_mut().zip(&v) {
                *s += x;
            }
        }
        p.finalize(&ksum, &vsum, 1.0 / b as f32);
        page
    }

    #[test]
    fn deq_reads_on_f32_pages_are_zero_copy_bitwise() {
        let pool = PagePool::unbounded(4, 8);
        let page = filled_page(&pool);
        let mut buf = Vec::new();
        assert_eq!(page.kt_deq(&mut buf), page.kt());
        assert_eq!(page.vt_deq(&mut buf), page.vt());
        assert_eq!(page.panel_deq(&mut buf), page.panel());
        assert_eq!(page.v_block_deq(&mut buf), page.v_block());
        assert_eq!(page.k_rows_deq(3, &mut buf), page.k_rows(3));
        assert_eq!(page.v_rows_deq(2, &mut buf), page.v_rows(2));
        assert!(buf.is_empty(), "f32 reads must not touch the dequant scratch");
    }

    #[test]
    fn demote_quantizes_within_format_budget_and_frees_bytes() {
        let (b, d) = (4usize, 8usize);
        let pe = 3 * b * d + 2 * d;
        let pool = PagePool::new(8, b, d);
        for fmt in [PageFormat::Bf16, PageFormat::Int8] {
            let mut page = filled_page(&pool);
            let want: Vec<f32> = page.panel().to_vec();
            let want_kt: Vec<f32> = page.kt().to_vec();
            let bytes_before = pool.bytes_in_use();
            assert!(pool.demote(&mut page, fmt), "{fmt}");
            assert_eq!(page.format(), fmt);
            assert_eq!(page.bytes(), fmt.page_bytes(pe));
            assert_eq!(
                pool.bytes_in_use(),
                bytes_before - PageFormat::F32.page_bytes(pe) + fmt.page_bytes(pe),
                "{fmt} demotion must net-free bytes"
            );
            // element-wise quantization error stays within the step size
            let mut buf = Vec::new();
            let tol = match fmt {
                PageFormat::Bf16 => 1.0 / 128.0, // relative 2^-8 on |x| <~ 4
                _ => page.int8_scale().unwrap() * 0.5 + 1e-6,
            };
            for (&q, &w) in page.panel_deq(&mut buf).iter().zip(&want) {
                assert!((q - w).abs() <= tol.max(w.abs() / 128.0), "{fmt}: {q} vs {w}");
            }
            for (&q, &w) in page.kt_deq(&mut buf).iter().zip(&want_kt) {
                assert!((q - w).abs() <= tol.max(w.abs() / 128.0), "{fmt}: {q} vs {w}");
            }
            pool.check_invariants();
            drop(page);
            pool.check_invariants();
        }
        // compressed buffers recycle per format
        let created = pool.compressed_buffers_created();
        let mut again = filled_page(&pool);
        assert!(pool.demote(&mut again, PageFormat::Bf16));
        assert_eq!(pool.compressed_buffers_created(), created, "bf16 freelist must recycle");
    }

    #[test]
    fn demote_refuses_shared_compressed_and_f32_targets() {
        let pool = PagePool::new(4, 2, 4);
        let mut page = filled_page(&pool);
        // F32 target is the no-compression mode: a no-op
        assert!(!pool.demote(&mut page, PageFormat::F32));
        assert_eq!(page.format(), PageFormat::F32);
        // shared handles keep their format (sharing identity)
        let peer = page.clone();
        assert!(!pool.demote(&mut page, PageFormat::Bf16));
        assert_eq!(page.format(), PageFormat::F32);
        drop(peer);
        assert!(pool.demote(&mut page, PageFormat::Bf16));
        // already-compressed pages are not re-quantized
        assert!(!pool.demote(&mut page, PageFormat::Int8));
        assert_eq!(page.format(), PageFormat::Bf16);
        pool.check_invariants();
    }

    #[test]
    fn compressed_bytes_admit_more_pages_than_f32_capacity() {
        // a 2-f32-page budget holds 1 f32 + 2 bf16 + 1 int8 pages
        // (4 + 2 + 2 + 1 = 9 quarter-pages of 8 x 4 = 2 full pages)
        let (b, d) = (2usize, 4usize);
        let pool = PagePool::new(2, b, d);
        let keep = pool.try_alloc().unwrap();
        let mut b16a = filled_page(&pool);
        assert!(pool.demote(&mut b16a, PageFormat::Bf16));
        let mut b16b = filled_page(&pool);
        assert!(pool.demote(&mut b16b, PageFormat::Bf16));
        // 1 f32 + 2 bf16 = 2 full pages' bytes: no f32 page fits...
        assert_eq!(pool.free_pages(), 0);
        assert_eq!(pool.try_alloc().unwrap_err(), PoolExhausted);
        assert_eq!(pool.pages_in_use(), 3, "3 pages resident in a 2-page budget");
        pool.check_invariants();
        drop((keep, b16a, b16b));
        assert_eq!(pool.bytes_in_use(), 0);
    }

    #[test]
    fn invariants_hold_across_alloc_share_drop_lifecycle() {
        let pool = PagePool::new(3, 4, 8);
        pool.check_invariants();
        let a = pool.try_alloc().unwrap();
        let b = pool.try_alloc().unwrap();
        pool.check_invariants();
        let shared = a.clone();
        pool.check_invariants();
        let c = pool.try_alloc().unwrap();
        assert_eq!(pool.try_alloc().map(|_| ()), Err(PoolExhausted));
        pool.check_invariants();
        drop((a, shared));
        pool.check_invariants();
        drop((b, c));
        pool.check_invariants();
        assert_eq!(pool.buffers_created(), 3, "capacity-filling lifecycle created 3 buffers");
        // unbounded pools skip the capacity arithmetic but keep conservation
        let ub = PagePool::unbounded(2, 2);
        let p = ub.try_alloc().unwrap();
        ub.check_invariants();
        drop(p);
        ub.check_invariants();
    }

    #[test]
    fn verify_reports_seeded_accounting_corruption() {
        let pool = PagePool::new(2, 2, 2);
        let _page = pool.try_alloc().unwrap();
        assert!(pool.verify().is_ok());
        // a leaked live count (page dropped without returning its buffer)
        pool.shared.live.fetch_add(1, Ordering::SeqCst);
        let msg = pool.verify().unwrap_err();
        assert!(msg.contains("conservation"), "{msg}");
        pool.shared.live.fetch_sub(1, Ordering::SeqCst);
        assert!(pool.verify().is_ok());
        // leaked bytes: the format mix no longer explains the residency
        pool.shared.live_bytes.fetch_add(3, Ordering::SeqCst);
        let msg = pool.verify().unwrap_err();
        assert!(msg.contains("byte conservation"), "{msg}");
        pool.shared.live_bytes.fetch_sub(3, Ordering::SeqCst);
        assert!(pool.verify().is_ok());
        // a format-count drift (a demotion that lost its bookkeeping):
        // bytes AND counts both move, so byte conservation catches it
        pool.shared.live_f32.fetch_sub(1, Ordering::SeqCst);
        pool.shared.live_b16.fetch_add(1, Ordering::SeqCst);
        let msg = pool.verify().unwrap_err();
        assert!(msg.contains("conservation"), "{msg}");
        pool.shared.live_f32.fetch_add(1, Ordering::SeqCst);
        pool.shared.live_b16.fetch_sub(1, Ordering::SeqCst);
        assert!(pool.verify().is_ok());
        // a foreign buffer smuggled onto the freelist
        freelist_lock(&pool.shared.recycled).push(vec![0.0f32; 1].into_boxed_slice());
        let msg = pool.verify().unwrap_err();
        assert!(msg.contains("geometry"), "{msg}");
    }
}
