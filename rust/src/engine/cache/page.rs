//! Bounded paged arena for decode KV state.
//!
//! One [`Page`] stores everything the per-row attention core
//! ([`crate::engine::decode`]) reads about one `block`-token span of one
//! `(layer, head)` stream, in one fixed-size buffer:
//!
//! ```text
//! [ k rows      | v rows      | K^T panel   | pooled k | pooled v ]
//!   block * d     block * d     block * d     d          d
//! ```
//!
//! K/V rows are written token by token as the stream appends; the panel
//! and the pooled rows are written once, when the block completes
//! ([`Page::finalize`]) — after that the page is immutable for life, so it
//! can be shared freely across sessions (fork, radix prefix cache).
//!
//! [`PagePool`] is the global bounded arena: it hands out refcounted
//! [`PageRef`]s up to a fixed capacity and recycles the underlying buffers
//! when the last reference drops, so the steady-state serving loop
//! performs no heap allocations for cache growth — a page "allocation" is
//! a freelist pop ([`PagePool::buffers_created`] is the high-water mark
//! the allocation-free tests gate on).  When the pool is exhausted,
//! [`PagePool::try_alloc`] fails with [`PoolExhausted`] and the scheduler
//! reacts (radix-cache eviction, then session preemption) instead of
//! growing memory without bound.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::tensor::kernel;

/// Error returned when the bounded page pool has no free pages left.
/// Callers either evict/preempt and retry, or surface the error.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PoolExhausted;

impl std::fmt::Display for PoolExhausted {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "KV page pool exhausted (all pages in use)")
    }
}

impl std::error::Error for PoolExhausted {}

struct PoolShared {
    block: usize,
    d: usize,
    page_elems: usize,
    /// Max live (physical) pages; `usize::MAX` = unbounded.
    capacity: usize,
    /// Physical pages currently alive (each counted once however many
    /// sessions/cache entries share it).
    live: AtomicUsize,
    /// Buffers ever created — the allocation high-water mark; stops
    /// growing once the freelist covers the working set.
    created: AtomicUsize,
    /// Retired page buffers awaiting reuse.
    recycled: Mutex<Vec<Box<[f32]>>>,
}

/// Shared handle to the bounded page arena (cheap to clone).
pub struct PagePool {
    shared: Arc<PoolShared>,
}

impl Clone for PagePool {
    fn clone(&self) -> Self {
        PagePool { shared: self.shared.clone() }
    }
}

impl std::fmt::Debug for PagePool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PagePool")
            .field("block", &self.shared.block)
            .field("d", &self.shared.d)
            .field("capacity", &self.shared.capacity)
            .field("in_use", &self.pages_in_use())
            .finish()
    }
}

/// Refcounted handle to one page; cloning shares the physical page.
pub type PageRef = Arc<Page>;

/// Recover a freelist guard even when a peer thread panicked while
/// holding it.  The freelist is a `Vec<Box<[f32]>>` push/pop — every
/// intermediate state is valid — so poisoning carries no information
/// here, and propagating it from [`Page::drop`] would abort the process
/// (panic-in-drop during unwind).
fn recycled_lock(shared: &PoolShared) -> std::sync::MutexGuard<'_, Vec<Box<[f32]>>> {
    shared.recycled.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

impl PagePool {
    /// Pool of at most `capacity` live pages sized for `(block, d)`
    /// streams.  Buffers are created lazily and recycled on free.
    ///
    /// # Panics
    ///
    /// Panics when `capacity == 0` or the `(block, d)` geometry is not
    /// positive — a zero-page pool or zero-sized page is always a
    /// configuration bug, never a runtime condition.
    pub fn new(capacity: usize, block: usize, d: usize) -> Self {
        assert!(capacity > 0, "page pool capacity must be positive");
        assert!(block > 0 && d > 0, "page geometry must be positive");
        PagePool {
            shared: Arc::new(PoolShared {
                block,
                d,
                page_elems: 3 * block * d + 2 * d,
                capacity,
                live: AtomicUsize::new(0),
                created: AtomicUsize::new(0),
                recycled: Mutex::new(Vec::new()),
            }),
        }
    }

    /// Unbounded pool — the default for standalone [`DecodeState`]s and
    /// tests; serving schedulers always bound theirs.
    ///
    /// [`DecodeState`]: crate::engine::DecodeState
    pub fn unbounded(block: usize, d: usize) -> Self {
        Self::new(usize::MAX, block, d)
    }

    pub fn block(&self) -> usize {
        self.shared.block
    }

    pub fn d(&self) -> usize {
        self.shared.d
    }

    /// Floats per page (`3 * block * d + 2 * d`).
    pub fn page_elems(&self) -> usize {
        self.shared.page_elems
    }

    pub fn capacity(&self) -> usize {
        self.shared.capacity
    }

    /// Physical pages currently alive.
    pub fn pages_in_use(&self) -> usize {
        self.shared.live.load(Ordering::Relaxed)
    }

    /// Pages that can still be allocated before [`PoolExhausted`].
    pub fn free_pages(&self) -> usize {
        self.shared.capacity.saturating_sub(self.pages_in_use())
    }

    /// Buffers ever created (the heap-allocation high-water mark; steady
    /// state recycles instead of creating).
    pub fn buffers_created(&self) -> usize {
        self.shared.created.load(Ordering::Relaxed)
    }

    fn grab_buffer(&self) -> Result<Box<[f32]>, PoolExhausted> {
        // reserve the live slot first so concurrent allocators cannot
        // overshoot the capacity
        let prev = self.shared.live.fetch_add(1, Ordering::Relaxed);
        if prev >= self.shared.capacity {
            self.shared.live.fetch_sub(1, Ordering::Relaxed);
            return Err(PoolExhausted);
        }
        let reused = recycled_lock(&self.shared).pop();
        Ok(reused.unwrap_or_else(|| {
            self.shared.created.fetch_add(1, Ordering::Relaxed);
            vec![0.0f32; self.shared.page_elems].into_boxed_slice()
        }))
    }

    /// Allocate a zeroed page, failing when the pool is at capacity.
    pub fn try_alloc(&self) -> Result<PageRef, PoolExhausted> {
        let mut data = self.grab_buffer()?;
        data.fill(0.0);
        Ok(Arc::new(Page {
            data,
            block: self.shared.block,
            d: self.shared.d,
            pool: self.shared.clone(),
        }))
    }

    /// Allocate a page holding a copy of `src`'s contents — the
    /// copy-on-write step for a shared partial tail page.
    pub fn alloc_copy(&self, src: &Page) -> Result<PageRef, PoolExhausted> {
        let mut data = self.grab_buffer()?;
        data.copy_from_slice(&src.data);
        Ok(Arc::new(Page {
            data,
            block: self.shared.block,
            d: self.shared.d,
            pool: self.shared.clone(),
        }))
    }

    /// Structural self-check of the arena's accounting, for the
    /// verification layer (DESIGN.md §11).  Returns `Err` with a
    /// description of the first violated invariant:
    ///
    /// * **buffer conservation** — every buffer ever created is either
    ///   inside a live page or parked on the freelist:
    ///   `created == live + recycled`;
    /// * **bound** — a bounded pool never has more live pages than its
    ///   capacity, and `in_use + free == capacity`;
    /// * **freelist hygiene** — recycled buffers all have the pool's
    ///   exact page geometry (a foreign or truncated buffer would
    ///   corrupt the next page allocated from it).
    ///
    /// Only meaningful at a quiescent point (no concurrent
    /// alloc/drop in flight): `grab_buffer` reserves the live slot
    /// before touching the freelist, so mid-allocation snapshots can
    /// transiently observe `created < live + recycled`.
    pub fn verify(&self) -> Result<(), String> {
        let live = self.shared.live.load(Ordering::SeqCst);
        let created = self.shared.created.load(Ordering::SeqCst);
        let (recycled, bad_geometry) = {
            let guard = recycled_lock(&self.shared);
            let bad = guard.iter().filter(|b| b.len() != self.shared.page_elems).count();
            (guard.len(), bad)
        };
        if bad_geometry != 0 {
            return Err(format!(
                "freelist holds {bad_geometry} buffer(s) with the wrong geometry \
                 (expected {} floats each)",
                self.shared.page_elems
            ));
        }
        if created != live + recycled {
            return Err(format!(
                "buffer conservation violated: created {created} != live {live} + \
                 recycled {recycled}"
            ));
        }
        if self.shared.capacity != usize::MAX {
            if live > self.shared.capacity {
                return Err(format!(
                    "live pages {live} exceed capacity {}",
                    self.shared.capacity
                ));
            }
            let free = self.free_pages();
            if live + free != self.shared.capacity {
                return Err(format!(
                    "page accounting violated: in_use {live} + free {free} != capacity {}",
                    self.shared.capacity
                ));
            }
        }
        Ok(())
    }

    /// Assert [`PagePool::verify`] under `debug_assertions` or the
    /// `paranoid` feature; compiled to a no-op in plain release builds.
    ///
    /// # Panics
    ///
    /// Panics with the violated invariant's description when the arena
    /// accounting is inconsistent.
    #[track_caller]
    pub fn check_invariants(&self) {
        if cfg!(any(debug_assertions, feature = "paranoid")) {
            if let Err(msg) = self.verify() {
                panic!("PagePool invariant violated: {msg}");
            }
        }
    }
}

/// One block-aligned span of one `(layer, head)` KV stream.  See the
/// module docs for the layout; all accessors are zero-copy slices into
/// the page buffer.
pub struct Page {
    data: Box<[f32]>,
    block: usize,
    d: usize,
    pool: Arc<PoolShared>,
}

impl Page {
    #[inline]
    fn bd(&self) -> usize {
        self.block * self.d
    }

    /// Raw key row `i` of this block (`i < block`).
    #[inline]
    pub fn k_row(&self, i: usize) -> &[f32] {
        debug_assert!(i < self.block);
        &self.data[i * self.d..(i + 1) * self.d]
    }

    /// First `rows` key rows, row-major (the partial-tail view).
    #[inline]
    pub fn k_rows(&self, rows: usize) -> &[f32] {
        debug_assert!(rows <= self.block);
        &self.data[..rows * self.d]
    }

    /// First `rows` value rows, row-major (the partial-tail view).
    #[inline]
    pub fn v_rows(&self, rows: usize) -> &[f32] {
        debug_assert!(rows <= self.block);
        let bd = self.bd();
        &self.data[bd..bd + rows * self.d]
    }

    /// All `block` value rows (complete-block view).
    #[inline]
    pub fn v_block(&self) -> &[f32] {
        let bd = self.bd();
        &self.data[bd..2 * bd]
    }

    /// Packed `(d, block)` K^T panel (valid once the block completed).
    #[inline]
    pub fn panel(&self) -> &[f32] {
        let bd = self.bd();
        &self.data[2 * bd..3 * bd]
    }

    /// Pooled (mean) key row (valid once the block completed).
    #[inline]
    pub fn kt(&self) -> &[f32] {
        let bd = self.bd();
        &self.data[3 * bd..3 * bd + self.d]
    }

    /// Pooled (mean) value row (valid once the block completed).
    #[inline]
    pub fn vt(&self) -> &[f32] {
        let bd = self.bd();
        &self.data[3 * bd + self.d..3 * bd + 2 * self.d]
    }

    /// Write the key/value rows of position `i` within the block.  Only
    /// ever called through a unique (copy-on-write) handle.
    pub fn write_kv_row(&mut self, i: usize, k_row: &[f32], v_row: &[f32]) {
        debug_assert!(i < self.block);
        debug_assert_eq!(k_row.len(), self.d);
        debug_assert_eq!(v_row.len(), self.d);
        let (d, bd) = (self.d, self.bd());
        self.data[i * d..(i + 1) * d].copy_from_slice(k_row);
        self.data[bd + i * d..bd + (i + 1) * d].copy_from_slice(v_row);
    }

    /// Seal a completed block: write the pooled rows (`sum * inv`, the
    /// same float sequence as the historical `DecodeState` finalization)
    /// and pack the K^T panel from the page's own key rows (a pure
    /// permutation).  After this the page is immutable.
    pub fn finalize(&mut self, ksum: &[f32], vsum: &[f32], inv: f32) {
        debug_assert_eq!(ksum.len(), self.d);
        debug_assert_eq!(vsum.len(), self.d);
        let (d, block) = (self.d, self.block);
        let bd = block * d;
        let (rows, derived) = self.data.split_at_mut(2 * bd);
        for (o, &s) in derived[bd..bd + d].iter_mut().zip(ksum) {
            *o = s * inv;
        }
        for (o, &s) in derived[bd + d..bd + 2 * d].iter_mut().zip(vsum) {
            *o = s * inv;
        }
        kernel::pack_transpose(&rows[..bd], block, d, &mut derived[..bd]);
    }
}

impl Drop for Page {
    fn drop(&mut self) {
        let buf = std::mem::take(&mut self.data);
        // recycled_lock (not .unwrap()): panicking here while another
        // thread unwinds with the freelist held would turn that panic
        // into a process abort
        recycled_lock(&self.pool).push(buf);
        self.pool.live.fetch_sub(1, Ordering::Relaxed);
    }
}

impl std::fmt::Debug for Page {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Page").field("block", &self.block).field("d", &self.d).finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bounded_pool_refuses_past_capacity_and_recycles() {
        let pool = PagePool::new(2, 4, 8);
        assert_eq!(pool.page_elems(), 3 * 4 * 8 + 2 * 8);
        let a = pool.try_alloc().unwrap();
        let b = pool.try_alloc().unwrap();
        assert_eq!(pool.pages_in_use(), 2);
        assert_eq!(pool.free_pages(), 0);
        assert_eq!(pool.try_alloc().unwrap_err(), PoolExhausted);
        drop(a);
        assert_eq!(pool.free_pages(), 1);
        // freed buffer is recycled, not re-created
        let created = pool.buffers_created();
        let c = pool.try_alloc().unwrap();
        assert_eq!(pool.buffers_created(), created, "steady state re-created a buffer");
        drop((b, c));
        assert_eq!(pool.pages_in_use(), 0);
    }

    #[test]
    fn sharing_a_page_does_not_consume_pool_pages() {
        let pool = PagePool::new(4, 2, 4);
        let a = pool.try_alloc().unwrap();
        let shared = a.clone();
        assert_eq!(Arc::strong_count(&a), 2);
        assert_eq!(pool.pages_in_use(), 1, "a shared page is one physical page");
        drop(a);
        assert_eq!(pool.pages_in_use(), 1);
        drop(shared);
        assert_eq!(pool.pages_in_use(), 0);
    }

    #[test]
    fn write_finalize_roundtrip_matches_layout() {
        let (b, d) = (2usize, 3usize);
        let pool = PagePool::unbounded(b, d);
        let mut page = pool.try_alloc().unwrap();
        let p = Arc::get_mut(&mut page).unwrap();
        p.write_kv_row(0, &[1.0, 2.0, 3.0], &[10.0, 20.0, 30.0]);
        p.write_kv_row(1, &[4.0, 5.0, 6.0], &[40.0, 50.0, 60.0]);
        let ksum = [5.0, 7.0, 9.0];
        let vsum = [50.0, 70.0, 90.0];
        p.finalize(&ksum, &vsum, 0.5);
        assert_eq!(page.k_row(0), &[1.0, 2.0, 3.0]);
        assert_eq!(page.k_rows(2), &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(page.v_rows(1), &[10.0, 20.0, 30.0]);
        assert_eq!(page.v_block(), &[10.0, 20.0, 30.0, 40.0, 50.0, 60.0]);
        assert_eq!(page.kt(), &[2.5, 3.5, 4.5]);
        assert_eq!(page.vt(), &[25.0, 35.0, 45.0]);
        // panel is the (d, block) transpose of the key rows
        let mut panel = vec![0.0f32; b * d];
        kernel::pack_transpose(page.k_rows(b), b, d, &mut panel);
        assert_eq!(page.panel(), &panel[..]);
    }

    #[test]
    fn alloc_copy_duplicates_contents_into_a_fresh_page() {
        let pool = PagePool::new(3, 2, 2);
        let mut page = pool.try_alloc().unwrap();
        Arc::get_mut(&mut page).unwrap().write_kv_row(0, &[1.0, 2.0], &[3.0, 4.0]);
        let copy = pool.alloc_copy(&page).unwrap();
        assert!(!Arc::ptr_eq(&page, &copy));
        assert_eq!(copy.k_row(0), page.k_row(0));
        assert_eq!(copy.v_rows(1), page.v_rows(1));
        assert_eq!(pool.pages_in_use(), 2);
    }

    #[test]
    fn recycled_pages_come_back_zeroed() {
        let pool = PagePool::new(1, 2, 2);
        let mut page = pool.try_alloc().unwrap();
        Arc::get_mut(&mut page).unwrap().write_kv_row(1, &[9.0, 9.0], &[9.0, 9.0]);
        drop(page);
        let fresh = pool.try_alloc().unwrap();
        assert!(fresh.k_rows(2).iter().all(|&x| x == 0.0));
        assert!(fresh.v_block().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn pool_exhausted_error_is_descriptive() {
        let msg = PoolExhausted.to_string();
        assert!(msg.contains("page pool exhausted"), "{msg}");
    }

    #[test]
    fn invariants_hold_across_alloc_share_drop_lifecycle() {
        let pool = PagePool::new(3, 4, 8);
        pool.check_invariants();
        let a = pool.try_alloc().unwrap();
        let b = pool.try_alloc().unwrap();
        pool.check_invariants();
        let shared = a.clone();
        pool.check_invariants();
        let c = pool.try_alloc().unwrap();
        assert_eq!(pool.try_alloc().map(|_| ()), Err(PoolExhausted));
        pool.check_invariants();
        drop((a, shared));
        pool.check_invariants();
        drop((b, c));
        pool.check_invariants();
        assert_eq!(pool.buffers_created(), 3, "capacity-filling lifecycle created 3 buffers");
        // unbounded pools skip the capacity arithmetic but keep conservation
        let ub = PagePool::unbounded(2, 2);
        let p = ub.try_alloc().unwrap();
        ub.check_invariants();
        drop(p);
        ub.check_invariants();
    }

    #[test]
    fn verify_reports_seeded_accounting_corruption() {
        let pool = PagePool::new(2, 2, 2);
        let _page = pool.try_alloc().unwrap();
        assert!(pool.verify().is_ok());
        // a leaked live count (page dropped without returning its buffer)
        pool.shared.live.fetch_add(1, Ordering::SeqCst);
        let msg = pool.verify().unwrap_err();
        assert!(msg.contains("conservation"), "{msg}");
        pool.shared.live.fetch_sub(1, Ordering::SeqCst);
        assert!(pool.verify().is_ok());
        // a foreign buffer smuggled onto the freelist
        recycled_lock(&pool.shared).push(vec![0.0f32; 1].into_boxed_slice());
        let msg = pool.verify().unwrap_err();
        assert!(msg.contains("geometry"), "{msg}");
    }
}
