//! Radix (token-prefix) tree over cached KV pages.
//!
//! Maps token prefixes to the physical [`PageRef`]s that hold their
//! decode KV state, so sessions that share a prompt prefix (same system
//! prompt, forked conversations, retries) reuse pages instead of
//! recomputing — and *physically* share memory, since a hit clones `Arc`
//! handles, not floats.
//!
//! Granularity is one `block` of tokens: only complete blocks are cached
//! (their pages are immutable — see [`super::page`]), and every edge label
//! is a whole number of blocks, so matching, splitting and insertion all
//! operate block-by-block.  One cached block carries `streams =
//! layers * heads` pages (one per `(layer, head)` KV stream), stored
//! block-major: `pages[bi * streams + s]`.
//!
//! Eviction is LRU over leaves: every lookup/insert stamps the touched
//! path with a monotone tick, and [`RadixCache::evict_lru`] repeatedly
//! removes the least-recently-used leaf until enough *exclusive* pages
//! (refcount 1 — actually returnable to the pool) have been freed.  Pages
//! still referenced by live sessions survive in those sessions regardless;
//! dropping the tree's handle merely stops advertising them.

use std::sync::Arc;

use super::page::PageRef;

/// Monotone counters of cache behavior (mirrored into the serving
/// [`Metrics`] by the scheduler).
///
/// [`Metrics`]: crate::coordinator::Metrics
#[derive(Clone, Copy, Debug, Default)]
pub struct CacheStats {
    pub lookups: u64,
    /// Lookups that matched at least one block.
    pub hits: u64,
    /// Tokens served from cache across all lookups.
    pub hit_tokens: u64,
    /// Page handles inserted (block pages newly advertised).
    pub inserted_pages: u64,
    /// Page handles dropped by eviction (>= physically freed pages).
    pub evicted_pages: u64,
}

struct Node {
    /// Edge label from the parent (a whole number of blocks; empty only
    /// at the root).
    tokens: Vec<i32>,
    /// `(tokens.len() / block) * streams` page handles, block-major.
    pages: Vec<PageRef>,
    children: Vec<Node>,
    last_used: u64,
}

impl Node {
    fn leaf(tokens: Vec<i32>, pages: Vec<PageRef>, last_used: u64) -> Self {
        Node { tokens, pages, children: Vec::new(), last_used }
    }
}

/// Block-granular token-prefix tree over cached KV pages.
pub struct RadixCache {
    block: usize,
    streams: usize,
    root: Node,
    tick: u64,
    stats: CacheStats,
}

impl RadixCache {
    /// Cache for streams of `block`-token pages, `streams = layers * heads`
    /// pages per cached block.
    ///
    /// # Panics
    ///
    /// Panics when `block == 0` or `streams == 0` — degenerate geometry
    /// is a wiring bug, never a runtime condition.
    pub fn new(block: usize, streams: usize) -> Self {
        assert!(block > 0 && streams > 0, "cache geometry must be positive");
        RadixCache {
            block,
            streams,
            root: Node::leaf(Vec::new(), Vec::new(), 0),
            tick: 0,
            stats: CacheStats::default(),
        }
    }

    pub fn block(&self) -> usize {
        self.block
    }

    pub fn streams(&self) -> usize {
        self.streams
    }

    pub fn stats(&self) -> CacheStats {
        self.stats
    }

    /// Longest cached block-aligned prefix of `tokens`: returns the
    /// matched token count (a multiple of `block`) and, per stream, the
    /// shared page handles of the matched blocks in order.  Touches the
    /// matched path for LRU.
    pub fn lookup(&mut self, tokens: &[i32]) -> (usize, Vec<Vec<PageRef>>) {
        self.tick += 1;
        self.stats.lookups += 1;
        let mut per_stream: Vec<Vec<PageRef>> = vec![Vec::new(); self.streams];
        let matched = lookup_rec(
            &mut self.root,
            tokens,
            self.block,
            self.streams,
            self.tick,
            &mut per_stream,
        );
        if matched > 0 {
            self.stats.hits += 1;
            self.stats.hit_tokens += matched as u64;
        }
        (matched, per_stream)
    }

    /// Advertise the pages of a computed prefix.  `tokens` must be a whole
    /// number of blocks and `pages` its block-major page handles
    /// (`(tokens.len() / block) * streams`).  Blocks already cached keep
    /// their existing (physically shared) pages; only the unmatched
    /// suffix inserts new handles.
    ///
    /// # Panics
    ///
    /// Panics when `tokens` is not a whole number of blocks or `pages`
    /// does not carry exactly one handle per `(block, stream)` — a
    /// misaligned insert would silently advertise torn KV state.
    pub fn insert(&mut self, tokens: &[i32], pages: &[PageRef]) {
        assert_eq!(tokens.len() % self.block, 0, "insert must be block-aligned");
        assert_eq!(
            pages.len(),
            tokens.len() / self.block * self.streams,
            "one page per (block, stream)"
        );
        if tokens.is_empty() {
            return;
        }
        self.tick += 1;
        insert_rec(
            &mut self.root,
            tokens,
            pages,
            self.block,
            self.streams,
            self.tick,
            &mut self.stats.inserted_pages,
        );
    }

    /// Page handles currently held by the tree (some may be shared with
    /// live sessions — see [`RadixCache::evict_lru`]).  O(1): inserts and
    /// evictions are the only flows in/out of the tree, so this is their
    /// running difference (cross-checked against a full walk in tests).
    pub fn pages_held(&self) -> usize {
        (self.stats.inserted_pages - self.stats.evicted_pages) as usize
    }

    /// Read-only probe: how many leading tokens [`RadixCache::lookup`]
    /// would match — no handle clones, no LRU touch.  The scheduler uses
    /// this to discount a request's admission page estimate by the pages
    /// it will share instead of allocate.
    pub fn probe(&self, tokens: &[i32]) -> usize {
        fn rec(node: &Node, tokens: &[i32], block: usize) -> usize {
            if tokens.len() < block {
                return 0;
            }
            let Some(child) =
                node.children.iter().find(|c| c.tokens[..block] == tokens[..block])
            else {
                return 0;
            };
            let nb_child = child.tokens.len() / block;
            let max_m = nb_child.min(tokens.len() / block);
            let mut m = 1;
            while m < max_m
                && child.tokens[m * block..(m + 1) * block] == tokens[m * block..(m + 1) * block]
            {
                m += 1;
            }
            let mut matched = m * block;
            if m == nb_child {
                matched += rec(child, &tokens[matched..], block);
            }
            matched
        }
        rec(&self.root, tokens, self.block)
    }

    /// Evict least-recently-used *reclaimable* leaves until at least
    /// `target` pages held exclusively by the cache (refcount 1, i.e.
    /// actually returned to the pool) have been freed, or nothing
    /// reclaimable remains.  Leaves whose pages are all still shared
    /// with live sessions are left in place — evicting them frees no
    /// memory and would only destroy hot prefixes (e.g. the shared
    /// system prompt of every running session).  Returns the
    /// exclusively-freed page count.
    ///
    /// Cost: O(freed-leaves · nodes) — each pop re-scores subtrees to
    /// find the LRU reclaimable leaf.  The tree is bounded by the page
    /// pool (≤ `total_pages / streams` block nodes), so this stays in
    /// the tens of microseconds at the scales served here; revisit with
    /// a score cache if pools grow orders of magnitude.
    pub fn evict_lru(&mut self, target: usize) -> usize {
        let mut freed = 0;
        while freed < target {
            let Some(leaf) = pop_lru_reclaimable_leaf(&mut self.root) else { break };
            self.stats.evicted_pages += leaf.pages.len() as u64;
            for p in &leaf.pages {
                if Arc::strong_count(p) == 1 {
                    freed += 1;
                }
            }
            // leaf (and its page handles) dropped here
        }
        freed
    }

    /// Drop every cached entry (counts toward `evicted_pages`).
    pub fn clear(&mut self) {
        self.stats.evicted_pages += self.pages_held() as u64;
        self.root.children.clear();
    }

    /// Visit every page handle held by the tree (block-major within each
    /// edge).  Used by the scheduler's conservation check, which needs
    /// the set of physical pages reachable from the cache.
    pub(crate) fn for_each_page(&self, f: &mut impl FnMut(&PageRef)) {
        fn rec(node: &Node, f: &mut impl FnMut(&PageRef)) {
            for p in &node.pages {
                f(p);
            }
            for c in &node.children {
                rec(c, f);
            }
        }
        rec(&self.root, f);
    }

    /// Structural self-check of the tree, for the verification layer
    /// (DESIGN.md §11).  Returns `Err` describing the first violated
    /// invariant:
    ///
    /// * **root shape** — the root's edge label and page list are empty;
    /// * **edge alignment** — every non-root edge is a non-empty whole
    ///   number of blocks carrying exactly one page per
    ///   `(block, stream)`;
    /// * **radix property** — the children of a node have pairwise
    ///   distinct first blocks (otherwise lookups would be ambiguous);
    /// * **LRU consistency** — every node's `last_used` is within the
    ///   monotone tick, and a parent is never staler than its children
    ///   (lookup/insert stamp the whole path, splits keep the tail's
    ///   old stamp), so subtree LRU scores are well-founded;
    /// * **handle accounting** — the O(1) [`RadixCache::pages_held`]
    ///   counter equals the full-tree handle count.
    pub fn verify(&self) -> Result<(), String> {
        fn rec(
            node: &Node,
            is_root: bool,
            block: usize,
            streams: usize,
            tick: u64,
            held: &mut usize,
        ) -> Result<(), String> {
            if is_root {
                if !node.tokens.is_empty() || !node.pages.is_empty() {
                    return Err("root node must have an empty edge and no pages".into());
                }
            } else {
                if node.tokens.is_empty() || node.tokens.len() % block != 0 {
                    return Err(format!(
                        "edge label of {} token(s) is not a positive multiple of block {block}",
                        node.tokens.len()
                    ));
                }
                let want = node.tokens.len() / block * streams;
                if node.pages.len() != want {
                    return Err(format!(
                        "edge of {} block(s) holds {} page handle(s), expected {want}",
                        node.tokens.len() / block,
                        node.pages.len()
                    ));
                }
            }
            if node.last_used > tick {
                return Err(format!(
                    "node stamped at {} but the cache tick is only {tick}",
                    node.last_used
                ));
            }
            *held += node.pages.len();
            for (i, a) in node.children.iter().enumerate() {
                if a.last_used > node.last_used {
                    return Err(format!(
                        "parent stamped {} is staler than child stamped {}",
                        node.last_used, a.last_used
                    ));
                }
                for b in &node.children[..i] {
                    if a.tokens[..block] == b.tokens[..block] {
                        return Err(format!(
                            "two children share the first block {:?}",
                            &a.tokens[..block]
                        ));
                    }
                }
                rec(a, false, block, streams, tick, held)?;
            }
            Ok(())
        }
        let mut held = 0usize;
        rec(&self.root, true, self.block, self.streams, self.tick, &mut held)?;
        if held != self.pages_held() {
            return Err(format!(
                "pages_held() reports {} but the tree holds {held} handle(s)",
                self.pages_held()
            ));
        }
        Ok(())
    }

    /// Assert [`RadixCache::verify`] under `debug_assertions` or the
    /// `paranoid` feature; compiled to a no-op in plain release builds.
    ///
    /// # Panics
    ///
    /// Panics with the violated invariant's description when the tree
    /// is inconsistent.
    #[track_caller]
    pub fn check_invariants(&self) {
        if cfg!(any(debug_assertions, feature = "paranoid")) {
            if let Err(msg) = self.verify() {
                panic!("RadixCache invariant violated: {msg}");
            }
        }
    }
}

fn lookup_rec(
    node: &mut Node,
    tokens: &[i32],
    block: usize,
    streams: usize,
    tick: u64,
    out: &mut [Vec<PageRef>],
) -> usize {
    node.last_used = tick;
    if tokens.len() < block {
        return 0;
    }
    let Some(ci) =
        node.children.iter().position(|c| c.tokens[..block] == tokens[..block])
    else {
        return 0;
    };
    let child = &mut node.children[ci];
    let nb_child = child.tokens.len() / block;
    let max_m = nb_child.min(tokens.len() / block);
    let mut m = 1; // the child-selection test matched the first block
    while m < max_m
        && child.tokens[m * block..(m + 1) * block] == tokens[m * block..(m + 1) * block]
    {
        m += 1;
    }
    for bi in 0..m {
        for (s, stream_out) in out.iter_mut().enumerate() {
            stream_out.push(child.pages[bi * streams + s].clone());
        }
    }
    let mut matched = m * block;
    if m == nb_child {
        matched += lookup_rec(child, &tokens[matched..], block, streams, tick, out);
    } else {
        child.last_used = tick;
    }
    matched
}

fn insert_rec(
    node: &mut Node,
    tokens: &[i32],
    pages: &[PageRef],
    block: usize,
    streams: usize,
    tick: u64,
    inserted: &mut u64,
) {
    node.last_used = tick;
    if tokens.is_empty() {
        return;
    }
    let Some(ci) =
        node.children.iter().position(|c| c.tokens[..block] == tokens[..block])
    else {
        node.children.push(Node::leaf(tokens.to_vec(), pages.to_vec(), tick));
        *inserted += pages.len() as u64;
        return;
    };
    let child = &mut node.children[ci];
    let nb_child = child.tokens.len() / block;
    let nb_new = tokens.len() / block;
    let mut m = 1;
    while m < nb_child.min(nb_new)
        && child.tokens[m * block..(m + 1) * block] == tokens[m * block..(m + 1) * block]
    {
        m += 1;
    }
    if m < nb_child {
        // split the edge at the matched boundary; the tail (with its
        // pages and subtree) becomes the single child of the head
        let tail_tokens = child.tokens.split_off(m * block);
        let tail_pages = child.pages.split_off(m * streams);
        let tail_children = std::mem::take(&mut child.children);
        let tail = Node {
            tokens: tail_tokens,
            pages: tail_pages,
            children: tail_children,
            last_used: child.last_used,
        };
        child.children.push(tail);
    }
    insert_rec(
        child,
        &tokens[m * block..],
        &pages[m * streams..],
        block,
        streams,
        tick,
        inserted,
    );
}

/// A leaf is reclaimable when evicting it would return at least one
/// physical page to the pool (some page held only by the tree).
fn leaf_is_reclaimable(node: &Node) -> bool {
    node.pages.iter().any(|p| Arc::strong_count(p) == 1)
}

/// Minimum `last_used` over the subtree's *reclaimable* leaves
/// (`u64::MAX` when it has none).
fn lru_reclaimable_score(node: &Node) -> u64 {
    if node.children.is_empty() {
        if leaf_is_reclaimable(node) {
            node.last_used
        } else {
            u64::MAX
        }
    } else {
        node.children.iter().map(lru_reclaimable_score).min().unwrap_or(u64::MAX)
    }
}

/// Remove and return the least-recently-used reclaimable leaf below
/// `node` (`None` when no leaf below would free a page).
fn pop_lru_reclaimable_leaf(node: &mut Node) -> Option<Node> {
    if node.children.is_empty() {
        return None;
    }
    let (ci, score) = (0..node.children.len())
        .map(|i| (i, lru_reclaimable_score(&node.children[i])))
        .min_by_key(|&(_, s)| s)
        .expect("non-empty children");
    if score == u64::MAX {
        return None;
    }
    if node.children[ci].children.is_empty() {
        Some(node.children.swap_remove(ci))
    } else {
        pop_lru_reclaimable_leaf(&mut node.children[ci])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::cache::page::PagePool;

    fn pages(pool: &PagePool, n: usize) -> Vec<PageRef> {
        (0..n).map(|_| pool.try_alloc().unwrap()).collect()
    }

    fn toks(blocks: &[i32], block: usize) -> Vec<i32> {
        blocks.iter().flat_map(|&b| (0..block as i32).map(move |j| b * 100 + j)).collect()
    }

    #[test]
    fn lookup_returns_the_physically_same_pages() {
        let (b, streams) = (4usize, 2usize);
        let pool = PagePool::unbounded(b, 4);
        let mut cache = RadixCache::new(b, streams);
        let t = toks(&[1, 2, 3], b);
        let pg = pages(&pool, 3 * streams);
        cache.insert(&t, &pg);
        // full match, plus a non-aligned tail that must be ignored
        let mut query = t.clone();
        query.extend_from_slice(&[9, 9]);
        let (matched, per_stream) = cache.lookup(&query);
        assert_eq!(matched, 3 * b);
        for (s, stream_pages) in per_stream.iter().enumerate() {
            assert_eq!(stream_pages.len(), 3);
            for (bi, p) in stream_pages.iter().enumerate() {
                assert!(
                    Arc::ptr_eq(p, &pg[bi * streams + s]),
                    "block {bi} stream {s} is not the same physical page"
                );
            }
        }
        let st = cache.stats();
        assert_eq!((st.lookups, st.hits, st.hit_tokens), (1, 1, 3 * b as u64));
    }

    #[test]
    fn partial_and_diverging_prefixes_match_block_by_block() {
        let (b, streams) = (2usize, 1usize);
        let pool = PagePool::unbounded(b, 2);
        let mut cache = RadixCache::new(b, streams);
        cache.insert(&toks(&[1, 2, 3], b), &pages(&pool, 3));
        // diverges inside the edge after one block
        let (m, ps) = cache.lookup(&toks(&[1, 7], b));
        assert_eq!(m, b);
        assert_eq!(ps[0].len(), 1);
        // shorter query than the edge
        let (m, _) = cache.lookup(&toks(&[1, 2], b));
        assert_eq!(m, 2 * b);
        // unknown root block
        let (m, ps) = cache.lookup(&toks(&[5], b));
        assert_eq!(m, 0);
        assert!(ps[0].is_empty());
    }

    #[test]
    fn insert_splits_edges_and_shares_the_common_prefix() {
        let (b, streams) = (2usize, 1usize);
        let pool = PagePool::unbounded(b, 2);
        let mut cache = RadixCache::new(b, streams);
        let first = pages(&pool, 3);
        cache.insert(&toks(&[1, 2, 3], b), &first);
        // second path shares block 1 then diverges
        let second = pages(&pool, 3);
        cache.insert(&toks(&[1, 8, 9], b), &second);
        // the shared block keeps the *first* insertion's page
        let (m, ps) = cache.lookup(&toks(&[1, 8, 9], b));
        assert_eq!(m, 3 * b);
        assert!(Arc::ptr_eq(&ps[0][0], &first[0]), "shared block must keep its first page");
        assert!(Arc::ptr_eq(&ps[0][1], &second[1]));
        let (m, ps) = cache.lookup(&toks(&[1, 2, 3], b));
        assert_eq!(m, 3 * b);
        assert!(Arc::ptr_eq(&ps[0][2], &first[2]));
        // 3 + 2 handles live in the tree (the duplicate shared block's
        // second handle was dropped on insert)
        assert_eq!(cache.pages_held(), 5);
    }

    #[test]
    fn evict_lru_frees_exclusive_pages_oldest_first() {
        let (b, streams) = (2usize, 1usize);
        let pool = PagePool::new(8, b, 2);
        let mut cache = RadixCache::new(b, streams);
        cache.insert(&toks(&[1], b), &pages(&pool, 1));
        cache.insert(&toks(&[2], b), &pages(&pool, 1));
        // touch [1] so [2] becomes LRU
        let _ = cache.lookup(&toks(&[1], b));
        assert_eq!(pool.pages_in_use(), 2);
        let freed = cache.evict_lru(1);
        assert_eq!(freed, 1);
        assert_eq!(pool.pages_in_use(), 1, "evicted page returned to the pool");
        let (m, _) = cache.lookup(&toks(&[2], b));
        assert_eq!(m, 0, "LRU entry [2] must be the evicted one");
        let (m, _) = cache.lookup(&toks(&[1], b));
        assert_eq!(m, b, "recently used entry survives");
    }

    #[test]
    fn eviction_spares_leaves_shared_with_live_sessions() {
        let (b, streams) = (2usize, 1usize);
        let pool = PagePool::new(4, b, 2);
        let mut cache = RadixCache::new(b, streams);
        let shared = pages(&pool, 1);
        cache.insert(&toks(&[1], b), &shared); // `shared` = a live session
        cache.insert(&toks(&[2], b), &pages(&pool, 1)); // exclusive
        // an unmeetable shortfall must not wipe the shared (hot) entry:
        // only the exclusive leaf is reclaimable
        let freed = cache.evict_lru(10);
        assert_eq!(freed, 1, "only the exclusive page can be freed");
        let (m, _) = cache.lookup(&toks(&[1], b));
        assert_eq!(m, b, "shared prefix must survive eviction pressure");
        assert_eq!(pool.pages_in_use(), 1);
        // once the session ends, the entry becomes reclaimable
        drop(shared);
        assert_eq!(cache.evict_lru(1), 1);
        assert_eq!(pool.pages_in_use(), 0);
    }

    #[test]
    fn clear_drops_everything() {
        let b = 2;
        let pool = PagePool::unbounded(b, 2);
        let mut cache = RadixCache::new(b, 1);
        cache.insert(&toks(&[1, 2], b), &pages(&pool, 2));
        cache.clear();
        assert_eq!(cache.pages_held(), 0);
        assert_eq!(pool.pages_in_use(), 0);
        assert_eq!(cache.stats().evicted_pages, 2);
    }

    /// `probe` and `lookup` implement the same block-matching walk in a
    /// read-only vs stateful form; they must never disagree (the
    /// scheduler's admission estimate rides on `probe`).  Randomized
    /// tries with shared prefixes, splits and divergences cross-check
    /// them token-for-token.
    #[test]
    fn probe_always_agrees_with_lookup_on_random_tries() {
        use crate::proptest::for_all_seeds;
        for_all_seeds(10, |_, rng| {
            let b = 1 + rng.below(4);
            let pool = PagePool::unbounded(b, 2);
            let mut cache = RadixCache::new(b, 1);
            // grow a randomized trie from a tiny alphabet so prefixes
            // collide often (splits + shared edges)
            for _ in 0..12 {
                let nb = 1 + rng.below(5);
                let t: Vec<i32> =
                    (0..nb * b).map(|_| rng.below(3) as i32).collect();
                cache.insert(&t, &pages(&pool, nb));
            }
            for _ in 0..20 {
                let qlen = rng.below(6 * b + 2);
                let q: Vec<i32> = (0..qlen).map(|_| rng.below(3) as i32).collect();
                let probed = cache.probe(&q);
                let (matched, per_stream) = cache.lookup(&q);
                if probed != matched {
                    return Err(format!(
                        "probe {probed} != lookup {matched} for {q:?} (b={b})"
                    ));
                }
                if per_stream[0].len() * b != matched {
                    return Err(format!("lookup pages/token mismatch for {q:?}"));
                }
            }
            Ok(())
        });
    }

    /// The O(1) `pages_held` counter must track the actual tree contents
    /// through inserts, splits, evictions and clears; `probe` must agree
    /// with `lookup` without touching LRU state or cloning handles.
    #[test]
    fn pages_held_counter_and_probe_agree_with_the_tree() {
        fn walk(cache: &RadixCache) -> usize {
            // recompute by materializing every cached prefix via lookups?
            // simpler: pages_in_use of a dedicated pool equals tree handles
            // when nothing else holds refs — asserted by the caller
            cache.pages_held()
        }
        let (b, streams) = (2usize, 1usize);
        let pool = PagePool::new(16, b, 2);
        let mut cache = RadixCache::new(b, streams);
        cache.insert(&toks(&[1, 2, 3], b), &pages(&pool, 3));
        cache.insert(&toks(&[1, 8], b), &pages(&pool, 2)); // splits, adds 1
        assert_eq!(walk(&cache), 4);
        assert_eq!(pool.pages_in_use(), 4, "tree is the only owner");
        // probe matches lookup's result, without cloning or LRU updates
        assert_eq!(cache.probe(&toks(&[1, 8, 9], b)), 2 * b);
        assert_eq!(cache.probe(&toks(&[1, 2], b)), 2 * b);
        assert_eq!(cache.probe(&toks(&[7], b)), 0);
        let (m, _) = cache.lookup(&toks(&[1, 8], b));
        assert_eq!(m, 2 * b);
        let freed = cache.evict_lru(1);
        assert!(freed >= 1);
        assert_eq!(walk(&cache), pool.pages_in_use());
        cache.clear();
        assert_eq!(walk(&cache), 0);
        assert_eq!(pool.pages_in_use(), 0);
    }

    /// Randomized tries stay invariant-clean through every mutation the
    /// cache supports (insert, split, lookup, eviction, clear) — the
    /// checker itself is exercised against the full mutation surface, not
    /// just hand-built shapes.
    #[test]
    fn invariants_hold_through_randomized_mutation_sequences() {
        use crate::proptest::for_all_seeds;
        for_all_seeds(8, |_, rng| {
            let b = 1 + rng.below(3);
            let streams = 1 + rng.below(2);
            let pool = PagePool::unbounded(b, 2);
            let mut cache = RadixCache::new(b, streams);
            for _ in 0..24 {
                match rng.below(4) {
                    0 | 1 => {
                        let nb = 1 + rng.below(4);
                        let t: Vec<i32> = (0..nb * b).map(|_| rng.below(3) as i32).collect();
                        cache.insert(&t, &pages(&pool, nb * streams));
                    }
                    2 => {
                        let qlen = rng.below(5 * b + 1);
                        let q: Vec<i32> = (0..qlen).map(|_| rng.below(3) as i32).collect();
                        let _ = cache.lookup(&q);
                    }
                    _ => {
                        let _ = cache.evict_lru(1 + rng.below(3));
                    }
                }
                cache.verify().map_err(|e| format!("after mutation: {e}"))?;
                let mut walked = 0usize;
                cache.for_each_page(&mut |_| walked += 1);
                if walked != cache.pages_held() {
                    return Err(format!(
                        "for_each_page visited {walked}, pages_held says {}",
                        cache.pages_held()
                    ));
                }
            }
            cache.clear();
            cache.verify().map_err(|e| format!("after clear: {e}"))?;
            Ok(())
        });
    }

    #[test]
    fn verify_reports_seeded_tree_corruption() {
        let (b, streams) = (2usize, 1usize);
        let pool = PagePool::unbounded(b, 2);
        let mut cache = RadixCache::new(b, streams);
        cache.insert(&toks(&[1, 2], b), &pages(&pool, 2));
        assert!(cache.verify().is_ok());
        // (a) torn edge: drop one page handle from a two-block edge
        let stolen = cache.root.children[0].pages.pop().unwrap();
        let msg = cache.verify().unwrap_err();
        assert!(msg.contains("page handle"), "{msg}");
        cache.root.children[0].pages.push(stolen);
        assert!(cache.verify().is_ok());
        // (b) LRU inversion: a child stamped fresher than its parent
        cache.root.children[0].children.push(Node::leaf(
            toks(&[9], b),
            pages(&pool, 1),
            u64::MAX - 1,
        ));
        let msg = cache.verify().unwrap_err();
        assert!(msg.contains("tick") || msg.contains("staler"), "{msg}");
        cache.root.children[0].children.clear();
        // (c) counter drift: handle count no longer matches stats
        cache.stats.inserted_pages += 1;
        let msg = cache.verify().unwrap_err();
        assert!(msg.contains("pages_held"), "{msg}");
    }
}
