//! Batched attention tensors: one contiguous `(batch, heads, n, d)` buffer
//! with cheap per-`(b, h)` matrix views.
//!
//! The engine never copies per-head data on the hot path — [`MatView`] is a
//! borrowed `(rows, cols, &[f32])` triple straight into the batched buffer,
//! and output heads are handed to workers as disjoint `&mut [f32]` chunks
//! of the same layout.

use crate::tensor::{Mat, Rng};

/// Contiguous `(batch, heads, n, d)` f32 tensor, row-major in every axis
/// (the layout the AOT artifacts and the Pallas kernels use).
#[derive(Clone, Debug, PartialEq)]
pub struct BatchedTensor {
    pub batch: usize,
    pub heads: usize,
    pub n: usize,
    pub d: usize,
    pub data: Vec<f32>,
}

impl BatchedTensor {
    /// All-zeros tensor.
    pub fn zeros(batch: usize, heads: usize, n: usize, d: usize) -> Self {
        BatchedTensor { batch, heads, n, d, data: vec![0.0; batch * heads * n * d] }
    }

    /// i.i.d. standard-normal entries scaled by `scale`.
    pub fn randn(
        batch: usize,
        heads: usize,
        n: usize,
        d: usize,
        scale: f32,
        rng: &mut Rng,
    ) -> Self {
        let mut t = Self::zeros(batch, heads, n, d);
        for v in t.data.iter_mut() {
            *v = rng.normal() * scale;
        }
        t
    }

    /// Assemble from per-head matrices in `(batch, head)` row-major order
    /// (`mats.len() == batch * heads`, each `(n, d)`).
    pub fn from_heads(batch: usize, heads: usize, mats: &[Mat]) -> Self {
        assert_eq!(mats.len(), batch * heads, "head count mismatch");
        let (n, d) = (mats[0].rows, mats[0].cols);
        let mut t = Self::zeros(batch, heads, n, d);
        for (p, m) in mats.iter().enumerate() {
            assert_eq!((m.rows, m.cols), (n, d), "ragged head shapes");
            t.data[p * n * d..(p + 1) * n * d].copy_from_slice(&m.data);
        }
        t
    }

    /// Elements in one `(b, h)` head.
    #[inline(always)]
    pub fn head_len(&self) -> usize {
        self.n * self.d
    }

    /// Total `(batch, head)` pairs.
    #[inline(always)]
    pub fn pairs(&self) -> usize {
        self.batch * self.heads
    }

    /// Flat offset of head `(b, h)`.
    #[inline(always)]
    pub fn offset(&self, b: usize, h: usize) -> usize {
        debug_assert!(b < self.batch && h < self.heads);
        (b * self.heads + h) * self.head_len()
    }

    /// Borrowed `(n, d)` view of head `(b, h)` — no copy.
    #[inline(always)]
    pub fn view(&self, b: usize, h: usize) -> MatView<'_> {
        let o = self.offset(b, h);
        MatView { rows: self.n, cols: self.d, data: &self.data[o..o + self.head_len()] }
    }

    /// Mutable flat slice of head `(b, h)`.
    pub fn head_mut(&mut self, b: usize, h: usize) -> &mut [f32] {
        let o = self.offset(b, h);
        let l = self.head_len();
        &mut self.data[o..o + l]
    }

    /// Owned copy of head `(b, h)` as a [`Mat`].
    pub fn head_mat(&self, b: usize, h: usize) -> Mat {
        self.view(b, h).to_mat()
    }

    pub fn shape(&self) -> (usize, usize, usize, usize) {
        (self.batch, self.heads, self.n, self.d)
    }
}

/// Borrowed row-major `(rows, cols)` matrix view (e.g. one head of a
/// [`BatchedTensor`], or a whole [`Mat`]).
#[derive(Clone, Copy, Debug)]
pub struct MatView<'a> {
    pub rows: usize,
    pub cols: usize,
    pub data: &'a [f32],
}

impl<'a> MatView<'a> {
    pub fn from_mat(m: &'a Mat) -> Self {
        MatView { rows: m.rows, cols: m.cols, data: &m.data }
    }

    #[inline(always)]
    pub fn row(&self, i: usize) -> &'a [f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline(always)]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    /// Owned copy (for shims whose inner implementation needs a `Mat`).
    pub fn to_mat(&self) -> Mat {
        Mat::from_vec(self.rows, self.cols, self.data.to_vec())
    }
}

impl<'a> From<&'a Mat> for MatView<'a> {
    fn from(m: &'a Mat) -> Self {
        MatView::from_mat(m)
    }
}

/// Relative Frobenius error between two equal-length flat buffers
/// (`||a - b||_F / ||b||_F`, the paper's metric lifted to batched tensors).
pub fn rel_fro_error_flat(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len(), "buffer length mismatch");
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for (x, y) in a.iter().zip(b.iter()) {
        let d = (*x as f64) - (*y as f64);
        num += d * d;
        den += (*y as f64) * (*y as f64);
    }
    (num / den.max(1e-300)).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn views_index_the_right_head() {
        let mut t = BatchedTensor::zeros(2, 3, 4, 2);
        for (i, v) in t.data.iter_mut().enumerate() {
            *v = i as f32;
        }
        let v = t.view(1, 2);
        assert_eq!((v.rows, v.cols), (4, 2));
        // head (1, 2) is pair index 5, so its first element is 5 * 8
        assert_eq!(v.get(0, 0), 40.0);
        assert_eq!(v.row(3), &[46.0, 47.0]);
        assert_eq!(t.head_mut(0, 1)[0], 8.0);
    }

    #[test]
    fn from_heads_round_trips() {
        let mut rng = Rng::new(0);
        let mats: Vec<Mat> = (0..6).map(|_| Mat::randn(4, 3, 1.0, &mut rng)).collect();
        let t = BatchedTensor::from_heads(2, 3, &mats);
        for b in 0..2 {
            for h in 0..3 {
                assert_eq!(t.head_mat(b, h), mats[b * 3 + h]);
            }
        }
    }

    #[test]
    fn matview_from_mat_borrows() {
        let m = Mat::from_fn(3, 2, |i, j| (i * 2 + j) as f32);
        let v = MatView::from_mat(&m);
        assert_eq!(v.get(2, 1), 5.0);
        assert_eq!(v.to_mat(), m);
    }

    #[test]
    fn rel_fro_flat_basics() {
        let a = [3.0f32, 4.0];
        let b = [0.0f32, 0.0];
        assert!(rel_fro_error_flat(&a, &a) < 1e-12);
        assert!(rel_fro_error_flat(&b, &a) > 0.99);
    }
}
