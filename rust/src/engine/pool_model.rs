//! Exhaustive interleaving checker for the [`super::pool`] claim
//! protocol (in-tree "mini-loom"; the real `loom` model of the same
//! protocol lives in `pool_loom.rs`, compiled under `--cfg loom` by the
//! nightly `verify-deep` CI job — no offline dependency needed here).
//!
//! The protocol under test is `run_with`'s worker loop:
//!
//! ```text
//! loop {
//!     i = cursor.fetch_add(1)          // atomic claim
//!     if i >= slots.len() { break }    // shutdown: drain complete
//!     if let Some(item) = slots[i].lock().take() { f(item) }
//! }
//! ```
//!
//! Every shared access is modeled as one transition of a per-worker state
//! machine, and a DFS enumerates **all** interleavings of those
//! transitions (memoized on the global state, so the search is the state
//! graph, not the exponential trace tree).  Checked properties:
//!
//! * **exactly-once** — at every terminal state each task executed once
//!   (no lost tasks, no double execution);
//! * **termination / no deadlock** — every non-terminal state has an
//!   enabled transition, and every execution reaches a terminal state
//!   where all workers exited the loop (the shutdown path);
//! * **self-validation** — deliberately broken variants of the protocol
//!   (a torn non-atomic cursor, a take without the slot mutex) must be
//!   *caught* by the same checker, so a green run means the checker can
//!   actually see the races it claims to rule out.
//!
//! The model is small (2–3 workers, up to 4 slots) but exhaustive within
//! that size: the claim protocol has no behavior that only appears at
//! larger counts, because workers are symmetric and slots independent.

use std::collections::HashSet;

/// Per-worker program counter.  `Fetch`/`WriteCur` model the cursor
/// claim (one step when atomic, torn read/write when not);
/// `Take`/`Check`/`Exec` model the slot handoff (one step under the
/// mutex, torn check/execute without it).
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
enum Pc {
    Fetch,
    /// Non-atomic cursor only: holds the stale read, about to write.
    WriteCur(usize),
    /// Mutex-protected take of slot `i` (single transition).
    Take(usize),
    /// Unlocked variant: observed slot `i`, not yet marked.
    Check(usize),
    /// Unlocked variant: executing slot `i` before marking it taken.
    Exec(usize),
    Done,
}

#[derive(Clone, PartialEq, Eq, Hash)]
struct State {
    cursor: usize,
    /// `true` while the slot still holds its item.
    full: Vec<bool>,
    pcs: Vec<Pc>,
    /// Times each task's `f` ran.
    executed: Vec<u8>,
}

/// Protocol variant knobs.  The shipped pool is `atomic_cursor &&
/// locked_take`; the other combinations exist to validate the checker.
#[derive(Clone, Copy)]
struct Model {
    slots: usize,
    workers: usize,
    atomic_cursor: bool,
    locked_take: bool,
}

#[derive(Default)]
struct Outcome {
    states: usize,
    terminals: usize,
    violations: Vec<String>,
}

impl Model {
    fn initial(&self) -> State {
        State {
            cursor: 0,
            full: vec![true; self.slots],
            pcs: vec![Pc::Fetch; self.workers],
            executed: vec![0; self.slots],
        }
    }

    /// The state after worker `w` takes its next step, or `None` when it
    /// has exited the loop.
    fn step(&self, st: &State, w: usize) -> Option<State> {
        let mut next = st.clone();
        match st.pcs[w] {
            Pc::Done => return None,
            Pc::Fetch => {
                let i = st.cursor;
                if self.atomic_cursor {
                    // read-modify-write as one indivisible transition
                    next.cursor = i + 1;
                    next.pcs[w] = self.after_claim(i);
                } else {
                    // torn: the write lands in a later transition, so
                    // another worker can claim the same index in between
                    next.pcs[w] = Pc::WriteCur(i);
                }
            }
            Pc::WriteCur(i) => {
                next.cursor = i + 1; // may regress the cursor (lost update)
                next.pcs[w] = self.after_claim(i);
            }
            Pc::Take(i) => {
                // mutex-guarded lock().take(): observing and emptying the
                // slot is a single transition, execution follows outside
                // the lock (f's effect is attributed to the taker)
                if st.full[i] {
                    next.full[i] = false;
                    next.executed[i] += 1;
                }
                next.pcs[w] = Pc::Fetch;
            }
            Pc::Check(i) => {
                next.pcs[w] = if st.full[i] { Pc::Exec(i) } else { Pc::Fetch };
            }
            Pc::Exec(i) => {
                next.executed[i] += 1;
                next.full[i] = false;
                next.pcs[w] = Pc::Fetch;
            }
        }
        Some(next)
    }

    fn after_claim(&self, i: usize) -> Pc {
        if i >= self.slots {
            Pc::Done // shutdown: claimed past the end, exit the loop
        } else if self.locked_take {
            Pc::Take(i)
        } else {
            Pc::Check(i)
        }
    }

    /// DFS over the reachable state graph, checking properties at every
    /// state.  Iterative with an explicit stack — interleaving graphs are
    /// deeper than they are wide.
    fn explore(&self) -> Outcome {
        let mut out = Outcome::default();
        let mut seen: HashSet<State> = HashSet::new();
        let mut stack = vec![self.initial()];
        seen.insert(self.initial());
        while let Some(st) = stack.pop() {
            out.states += 1;
            let mut enabled = 0;
            for w in 0..self.workers {
                if let Some(next) = self.step(&st, w) {
                    enabled += 1;
                    if seen.insert(next.clone()) {
                        stack.push(next);
                    }
                }
            }
            if enabled == 0 {
                // terminal: every worker exited; the drain must be complete
                out.terminals += 1;
                debug_assert!(st.pcs.iter().all(|p| *p == Pc::Done));
                for (i, &n) in st.executed.iter().enumerate() {
                    if n != 1 {
                        out.violations.push(format!(
                            "task {i} executed {n} times (cursor ended at {})",
                            st.cursor
                        ));
                    }
                }
            } else if st.pcs.iter().all(|p| *p == Pc::Done) {
                out.violations.push("worker transition enabled after Done".into());
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shipped_protocol_runs_every_task_exactly_once_under_all_interleavings() {
        for workers in [2usize, 3] {
            for slots in [0usize, 1, 2, 3, 4] {
                let m = Model { slots, workers, atomic_cursor: true, locked_take: true };
                let out = m.explore();
                assert!(
                    out.violations.is_empty(),
                    "workers={workers} slots={slots}: {:?}",
                    out.violations
                );
                assert!(out.terminals >= 1, "workers={workers} slots={slots}: no terminal");
                if slots >= 2 {
                    // the search must actually branch over interleavings,
                    // not collapse to one schedule
                    assert!(
                        out.states > 20,
                        "workers={workers} slots={slots}: only {} states explored",
                        out.states
                    );
                }
            }
        }
    }

    #[test]
    fn shutdown_is_deadlock_free_even_with_more_workers_than_tasks() {
        // every worker must observe cursor >= slots and exit — the drain
        // protocol has no waiting state to get stuck in
        let m = Model { slots: 1, workers: 3, atomic_cursor: true, locked_take: true };
        let out = m.explore();
        assert!(out.violations.is_empty(), "{:?}", out.violations);
        assert!(out.terminals >= 1);
    }

    /// A torn (non-atomic) cursor alone is masked by the slot mutex: two
    /// workers may claim the same index, but `lock().take()` still hands
    /// the item to exactly one of them.  This documents *which* layer of
    /// the protocol carries the exactly-once guarantee.
    #[test]
    fn slot_mutex_masks_a_torn_cursor() {
        let m = Model { slots: 2, workers: 2, atomic_cursor: false, locked_take: true };
        let out = m.explore();
        assert!(out.violations.is_empty(), "{:?}", out.violations);
    }

    /// Checker self-validation: with the slot mutex *and* cursor
    /// atomicity both removed, some interleaving double-executes a task —
    /// and the checker must find it.  If this test ever passes with zero
    /// violations, the checker went blind, not the protocol safe.
    #[test]
    fn checker_catches_the_double_execution_race_in_a_broken_protocol() {
        let m = Model { slots: 2, workers: 2, atomic_cursor: false, locked_take: false };
        let out = m.explore();
        assert!(
            out.violations.iter().any(|v| v.contains("2 times")),
            "broken protocol not caught; violations: {:?}",
            out.violations
        );
    }
}
