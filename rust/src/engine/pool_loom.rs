//! `loom` model of the [`super::pool`] claim + shutdown protocol.
//!
//! Compiled only under `--cfg loom` (the nightly `verify-deep` CI job
//! runs `cargo add loom --dev && RUSTFLAGS="--cfg loom" cargo test
//! --release engine::pool_loom`); the offline tree carries no loom
//! dependency, and the same protocol is exhaustively checked without it
//! in `pool_model.rs`.
//!
//! Unlike the in-tree model, loom explores the protocol under the real
//! C11 memory model — including the `Ordering::Relaxed` cursor claim,
//! which the hand-rolled checker assumes is sequentially consistent.
//! The property is the same: every task is executed exactly once, every
//! worker terminates (the shutdown drain), and the scoped join observes
//! all effects.

use loom::sync::atomic::{AtomicUsize, Ordering};
use loom::sync::Mutex;
use loom::thread;
use std::sync::Arc;

/// The worker loop of `pool::run_with`, verbatim modulo loom types:
/// claim an index with one `fetch_add(Relaxed)`, exit past the end,
/// hand the item over through the slot's mutex.
fn worker(slots: &[Mutex<Option<usize>>], cursor: &AtomicUsize, hits: &[AtomicUsize]) {
    loop {
        let i = cursor.fetch_add(1, Ordering::Relaxed);
        if i >= slots.len() {
            break;
        }
        let item = slots[i].lock().unwrap_or_else(std::sync::PoisonError::into_inner).take();
        if let Some(item) = item {
            hits[item].fetch_add(1, Ordering::Relaxed);
        }
    }
}

/// 2 workers x 3 tasks (loom's practical exhaustiveness budget for a
/// protocol with a mutex per slot): no interleaving loses a task,
/// double-executes one, or deadlocks the drain.
#[test]
fn claim_protocol_is_exactly_once_and_deadlock_free() {
    loom::model(|| {
        const TASKS: usize = 3;
        let slots: Arc<Vec<Mutex<Option<usize>>>> =
            Arc::new((0..TASKS).map(|i| Mutex::new(Some(i))).collect());
        let cursor = Arc::new(AtomicUsize::new(0));
        let hits: Arc<Vec<AtomicUsize>> =
            Arc::new((0..TASKS).map(|_| AtomicUsize::new(0)).collect());
        let handles: Vec<_> = (0..2)
            .map(|_| {
                let (slots, cursor, hits) = (slots.clone(), cursor.clone(), hits.clone());
                thread::spawn(move || worker(&slots, &cursor, &hits))
            })
            .collect();
        for h in handles {
            h.join().expect("worker panicked");
        }
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "task {i} not executed exactly once");
        }
        // shutdown drain: the cursor moved past every slot
        assert!(cursor.load(Ordering::Relaxed) >= TASKS);
    });
}

/// More workers than tasks: surplus workers must observe an
/// exhausted cursor and exit — the shutdown path cannot hang.
#[test]
fn surplus_workers_drain_and_exit() {
    loom::model(|| {
        let slots: Arc<Vec<Mutex<Option<usize>>>> = Arc::new(vec![Mutex::new(Some(0))]);
        let cursor = Arc::new(AtomicUsize::new(0));
        let hits: Arc<Vec<AtomicUsize>> = Arc::new(vec![AtomicUsize::new(0)]);
        let handles: Vec<_> = (0..3)
            .map(|_| {
                let (slots, cursor, hits) = (slots.clone(), cursor.clone(), hits.clone());
                thread::spawn(move || worker(&slots, &cursor, &hits))
            })
            .collect();
        for h in handles {
            h.join().expect("worker panicked");
        }
        assert_eq!(hits[0].load(Ordering::Relaxed), 1);
    });
}
