//! Incremental causal decode: a per-(batch, head) KV cache that reuses the
//! MRA-2 pyramid across steps — backed by the paged arena
//! ([`crate::engine::cache`]) so sessions can fork and share prefixes
//! physically.
//!
//! [`DecodeState::append`] maintains the pooled key/value pyramid
//! incrementally — partial-block sums accumulate in arrival order and are
//! finalized (scaled by `1/block`) exactly when a block completes, the
//! same float sequence as pooling the full prefix from scratch — and packs
//! each completed key block into a K^T panel for the micro-kernel layer
//! (a pure permutation).  The incremental path is therefore **bitwise
//! identical** to recomputing the causal prefix ([`causal_row_attention`];
//! asserted in tests and `benches/bench_decode.rs`).
//!
//! State lives in fixed-size block-aligned [`Page`]s from a (possibly
//! bounded) [`PagePool`]: one page holds one block's raw K/V rows, its
//! packed K^T panel and its pooled pyramid rows, so a page boundary never
//! splits a tile or a pyramid node.  [`DecodeState::fork`] clones page
//! *handles* — the shared prefix of a forked session is physically the
//! same memory as its parent's (`Arc::ptr_eq`, asserted in tests), and
//! only the partial tail page is copied on the next write (copy-on-write).
//! [`DecodeState::from_cached`] rebuilds a state directly from
//! radix-cached pages of a shared prompt.
//!
//! [`DecodeState::attend_last`] runs a strictly per-row causal MRA-2 for
//! the newest position: exact attention over the current (possibly
//! partial) block and the `budget` best complete past blocks by pooled
//! score, low-resolution `mu` correction over the remaining past blocks
//! (Full variant).  Refined blocks are scored through
//! [`kernel::score_panel`] against the packed K^T panels and aggregated by
//! the fused online-softmax kernel ([`kernel::softmax_accum_panel`]); all
//! transients live in a per-state scratch, so the steady decode path
//! ([`DecodeState::attend_last_into`]) performs **zero heap allocations**
//! per token (page "allocations" at block boundaries are freelist pops
//! once the pool is warm).  Cost per generated token is
//! `O(block + budget * block + n / block)` against `O(n)` for exact causal
//! decode — the tokens/sec gap `benches/bench_decode.rs` measures.
//!
//! This per-row selection is the decode-time analog of the causal batch
//! plan's per-query-block budget (`mra::attention::mra2_plan` with
//! [`Causality::Causal`][crate::mra::Causality]); see DESIGN.md §7 for how
//! the two schedules relate and §9 for the page lifetime rules.

use std::sync::Arc;

use crate::engine::cache::{Page, PageFormat, PagePool, PageRef, PoolExhausted};
use crate::mra::Variant;
use crate::tensor::{kernel, ops, topk};

/// Per-step scratch of one decode stream: low-res scores, the refined-set
/// bookkeeping and one score row.  Sized on the first step and reused
/// verbatim afterwards (allocation-free steady path).
///
/// Public so chunked-prefill callers ([`DecodeState::attend_pos_into`])
/// can keep one scratch per pool worker instead of one per stream; the
/// scratch never influences results — every field is fully overwritten
/// before use, which is what lets a fresh scratch reproduce the per-token
/// float sequence bitwise.
#[derive(Clone, Debug, Default)]
pub struct DecodeScratch {
    /// Pooled scores of every complete past block (`<= n / block`).
    s_low: Vec<f32>,
    /// Refined block indices (ascending; `<= budget`).
    refined: Vec<usize>,
    /// Membership flags over the complete past blocks.
    is_refined: Vec<bool>,
    /// One block-wide score row (`<= block`).
    scores: Vec<f32>,
    /// Dequantization landing zone for compressed pages (`<= block * d`,
    /// one section at a time).  Stays empty — zero capacity, zero cost —
    /// while every page is f32, which keeps the default path's scratch
    /// footprint and float sequence bitwise identical to the historical
    /// f32-only layout.
    deq: Vec<f32>,
}

/// Per-block view the row-attention core reads: pooled rows, packed K^T
/// panel and raw value rows of every complete past block, plus the raw
/// K/V rows of the current (possibly partial) block.  Implemented by the
/// paged state and by the flat-slice recompute path — both feed the same
/// float sequence through [`attend_row_core`], which is what keeps the
/// paged layout bitwise identical to the historical contiguous one.
/// Methods take `&mut self` because the paged source may have to
/// dequantize a compressed page's section into its scratch buffer: each
/// returned slice is only valid until the next call, and the core
/// consumes every section before requesting the next one.  On all-f32
/// sources the slices are zero-copy and the `&mut` is vacuous — the f32
/// float sequence is untouched by this seam.
trait BlockSource {
    /// Pooled (mean) key row of complete block `y`.
    fn kt(&mut self, y: usize) -> &[f32];
    /// Pooled (mean) value row of complete block `y`.
    fn vt(&mut self, y: usize) -> &[f32];
    /// Packed `(d, block)` K^T panel of complete block `y`.
    fn panel(&mut self, y: usize) -> &[f32];
    /// Raw value rows of complete block `y` (`block * d`).
    fn v_block(&mut self, y: usize) -> &[f32];
    /// Raw key rows of the current block (`w * d`).
    fn tail_k(&mut self) -> &[f32];
    /// Raw value rows of the current block (`w * d`).
    fn tail_v(&mut self) -> &[f32];
}

/// [`BlockSource`] over the paged state: block `y` is page `y`.  The
/// "tail" block is page `x` — the block holding the attending position —
/// which is the *last* page for `attend_last`, but an interior (possibly
/// already finalized) page for the positional attends of chunked prefill.
/// Finalization only writes the panel/pooled rows, never the raw K/V
/// rows, so reading a finalized page's first `w` raw rows is bitwise
/// identical to reading them while the block was still partial.
/// Pages may be in any [`PageFormat`]: every read goes through the
/// format-agnostic `_deq` accessors, which are zero-copy (bitwise
/// identical to the historical raw reads) on f32 pages and dequantize
/// into `deq` — the caller's [`DecodeScratch::deq`] — on compressed ones.
struct PagedBlocks<'a> {
    pages: &'a [PageRef],
    /// Block index of the attending position (`pos / block`).
    x: usize,
    /// Rows of block `x` visible to the attending position.
    w: usize,
    /// Dequantization landing zone (reused section by section).
    deq: &'a mut Vec<f32>,
}

impl BlockSource for PagedBlocks<'_> {
    fn kt(&mut self, y: usize) -> &[f32] {
        self.pages[y].kt_deq(self.deq)
    }

    fn vt(&mut self, y: usize) -> &[f32] {
        self.pages[y].vt_deq(self.deq)
    }

    fn panel(&mut self, y: usize) -> &[f32] {
        self.pages[y].panel_deq(self.deq)
    }

    fn v_block(&mut self, y: usize) -> &[f32] {
        self.pages[y].v_block_deq(self.deq)
    }

    fn tail_k(&mut self) -> &[f32] {
        self.pages[self.x].k_rows_deq(self.w, self.deq)
    }

    fn tail_v(&mut self) -> &[f32] {
        self.pages[self.x].v_rows_deq(self.w, self.deq)
    }
}

/// [`BlockSource`] over flat prefix slices (the from-scratch recompute
/// path of [`causal_row_attention`]).
struct SliceBlocks<'a> {
    d: usize,
    b: usize,
    kt: &'a [f32],
    vt: &'a [f32],
    panels: &'a [f32],
    v_prefix: &'a [f32],
    tail_k: &'a [f32],
    tail_v: &'a [f32],
}

impl BlockSource for SliceBlocks<'_> {
    fn kt(&mut self, y: usize) -> &[f32] {
        &self.kt[y * self.d..(y + 1) * self.d]
    }

    fn vt(&mut self, y: usize) -> &[f32] {
        &self.vt[y * self.d..(y + 1) * self.d]
    }

    fn panel(&mut self, y: usize) -> &[f32] {
        &self.panels[y * self.b * self.d..(y + 1) * self.b * self.d]
    }

    fn v_block(&mut self, y: usize) -> &[f32] {
        &self.v_prefix[y * self.b * self.d..(y + 1) * self.b * self.d]
    }

    fn tail_k(&mut self) -> &[f32] {
        self.tail_k
    }

    fn tail_v(&mut self) -> &[f32] {
        self.tail_v
    }
}

/// Incremental KV cache + pooled pyramid for one `(batch, head)` pair of
/// an autoregressive decode stream, stored in block-aligned pages.
///
/// Cloning (= [`DecodeState::fork`]) shares the pages physically and the
/// pool handle; the clone costs zero pool pages until it diverges.
#[derive(Clone, Debug)]
pub struct DecodeState {
    block: usize,
    /// Refined complete past blocks per step (per-row Alg. 1 budget).
    budget: usize,
    variant: Variant,
    d: usize,
    len: usize,
    /// Page allocator (shared across forks; bounded under the serving
    /// scheduler, unbounded for standalone states).
    pool: PagePool,
    /// One page per started block; all complete except possibly the last.
    pages: Vec<PageRef>,
    /// Running sums of the current partial block.
    ksum: Vec<f32>,
    vsum: Vec<f32>,
    /// Reusable per-step transients.
    scratch: DecodeScratch,
}

impl DecodeState {
    /// Standalone state with a private unbounded page pool.
    pub fn new(block: usize, budget: usize, variant: Variant, d: usize) -> Self {
        assert!(block > 0, "block must be positive");
        assert!(d > 0, "head dim must be positive");
        Self::with_pool(&PagePool::unbounded(block, d), budget, variant)
    }

    /// State allocating from a shared (possibly bounded) pool; `block`
    /// and `d` come from the pool's page geometry.
    pub fn with_pool(pool: &PagePool, budget: usize, variant: Variant) -> Self {
        let d = pool.d();
        DecodeState {
            block: pool.block(),
            budget,
            variant,
            d,
            len: 0,
            pool: pool.clone(),
            pages: Vec::new(),
            ksum: vec![0.0; d],
            vsum: vec![0.0; d],
            scratch: DecodeScratch::default(),
        }
    }

    /// Rebuild a state from radix-cached pages of a shared prefix:
    /// `pages` must be complete-block pages in order (`len = pages.len() *
    /// block` tokens).  The pages are shared, not copied — this is the
    /// prefix-cache hit path.
    pub fn from_cached(
        pool: &PagePool,
        budget: usize,
        variant: Variant,
        pages: Vec<PageRef>,
        len: usize,
    ) -> Self {
        assert_eq!(
            len,
            pages.len() * pool.block(),
            "cached prefix must be whole blocks"
        );
        let mut st = Self::with_pool(pool, budget, variant);
        st.pages = pages;
        st.len = len;
        st
    }

    /// Number of cached positions.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn block(&self) -> usize {
        self.block
    }

    /// The pages backing this stream (one per started block; all complete
    /// except possibly the last).  Complete pages are immutable and safe
    /// to share (radix cache, forks).
    pub fn pages(&self) -> &[PageRef] {
        &self.pages
    }

    /// The pool this stream allocates from.
    pub fn pool(&self) -> &PagePool {
        &self.pool
    }

    /// Fork the stream: the clone shares every page physically (the
    /// partial tail copies on its next write) and allocates from the same
    /// pool.  Bitwise: both sides continue exactly as a cold state fed
    /// the same prefix would.
    pub fn fork(&self) -> Self {
        self.clone()
    }

    /// Whether the next [`DecodeState::append`] allocates a page — either
    /// it starts a new block, or the partial tail is shared with a fork
    /// and will copy-on-write.  The scheduler's per-step page reservation
    /// hook.
    pub fn next_append_needs_page(&self) -> bool {
        self.pages_needed_for_append(1) > 0
    }

    /// Physical pages appending `rows` more positions would take from the
    /// pool: one per block boundary crossed, plus one when the partial
    /// tail is shared with a fork and will copy-on-write — the chunked
    /// form of [`DecodeState::next_append_needs_page`], used by the
    /// scheduler to reserve a prefill chunk before running it.
    pub fn pages_needed_for_append(&self, rows: usize) -> usize {
        if rows == 0 {
            return 0;
        }
        let before = self.len.div_ceil(self.block);
        let after = (self.len + rows).div_ceil(self.block);
        let mut need = after - before;
        if self.len % self.block != 0 {
            if let Some(tail) = self.pages.last() {
                if Arc::strong_count(tail) > 1 {
                    need += 1; // shared partial tail copies on the next write
                }
            }
        }
        need
    }

    /// Demote up to `limit` cold pages of this stream to `fmt`, oldest
    /// first, returning how many pages actually changed format — the
    /// scheduler's pressure-relief step before preempting a session
    /// (DESIGN.md §15).
    ///
    /// "Cold" excludes the *hot tail*: the last started block, whose page
    /// is still being written (partial) or is about to be re-read at full
    /// precision by the very next `attend_last`.  Shared pages (radix
    /// cache, forks) are skipped inside [`PagePool::demote`] — a page's
    /// format is part of its sharing identity.  `fmt == F32` (the
    /// no-compression config) and `limit == 0` are no-ops.
    ///
    /// Demotion changes attend outputs within the format's documented
    /// [`PageFormat::error_budget`]; it never changes stream *consistency*
    /// — appends only touch the (never-demoted) tail, and replayed
    /// sampling is teacher-forced ([`DrawState`]), so a demoted session
    /// continues structurally exactly as before.
    pub fn demote_cold(&mut self, fmt: PageFormat, limit: usize) -> usize {
        if fmt == PageFormat::F32 || limit == 0 {
            return 0;
        }
        let hot = self.len.div_ceil(self.block).saturating_sub(1);
        let mut demoted = 0usize;
        for page in self.pages[..hot].iter_mut() {
            if demoted == limit {
                break;
            }
            if self.pool.demote(page, fmt) {
                demoted += 1;
            }
        }
        demoted
    }

    /// Resident bytes of this stream's pages (format-weighted; shared
    /// pages are counted here in full, as in every stream that holds a
    /// handle — the pool's own [`PagePool::bytes_in_use`] counts each
    /// physical page once).
    pub fn bytes_resident(&self) -> usize {
        self.pages.iter().map(|p| p.bytes()).sum()
    }

    /// Pages of this stream currently in a compressed format.
    pub fn compressed_pages(&self) -> usize {
        self.pages.iter().filter(|p| p.format() != PageFormat::F32).count()
    }

    /// Append one key/value row to the cache, maintaining the pooled
    /// pyramid incrementally.  Rows accumulate into the partial-block sums
    /// in arrival order and are finalized exactly when the block completes
    /// — the same float sequence as `ops::pool_rows_slice` over the full
    /// prefix, which is what makes incremental decode bitwise identical to
    /// a from-scratch recompute.  Completed blocks are also packed into
    /// K^T panels (a permutation — no float arithmetic).
    ///
    /// Panics when the pool is exhausted; serving paths use
    /// [`DecodeState::try_append`] and let the scheduler evict/preempt.
    pub fn append(&mut self, k_row: &[f32], v_row: &[f32]) {
        self.try_append(k_row, v_row).expect("KV page pool exhausted");
    }

    /// [`DecodeState::append`] returning [`PoolExhausted`] when no page is
    /// free.  On error the state is unchanged (the failed step can be
    /// retried after eviction, or the whole stream preempted and
    /// recomputed later — decode is deterministic).
    pub fn try_append(&mut self, k_row: &[f32], v_row: &[f32]) -> Result<(), PoolExhausted> {
        assert_eq!(k_row.len(), self.d, "k row width");
        assert_eq!(v_row.len(), self.d, "v row width");
        if self.len % self.block == 0 {
            self.pages.push(self.pool.try_alloc()?);
        } else if Arc::get_mut(self.pages.last_mut().expect("tail page")).is_none() {
            // shared partial tail (fork before a block boundary):
            // copy-on-write before the first divergent row lands
            let copy = self.pool.alloc_copy(self.pages.last().expect("tail page"))?;
            *self.pages.last_mut().expect("tail page") = copy;
        }
        let off = self.len % self.block;
        let page: &mut Page = Arc::get_mut(self.pages.last_mut().expect("tail page"))
            .expect("tail page unique after CoW");
        page.write_kv_row(off, k_row, v_row);
        for (s, &x) in self.ksum.iter_mut().zip(k_row) {
            *s += x;
        }
        for (s, &x) in self.vsum.iter_mut().zip(v_row) {
            *s += x;
        }
        self.len += 1;
        if self.len % self.block == 0 {
            let inv = 1.0 / self.block as f32;
            let page = Arc::get_mut(self.pages.last_mut().expect("tail page"))
                .expect("tail page unique while completing");
            page.finalize(&self.ksum, &self.vsum, inv);
            self.ksum.fill(0.0);
            self.vsum.fill(0.0);
        }
        Ok(())
    }

    /// Append a whole chunk of key/value rows (`rows * d` each, row-major)
    /// — the prefill-chunk bulk form of [`DecodeState::try_append`].  The
    /// per-row float sequence (partial sums, finalization, panel packing)
    /// is exactly the per-token one, so a chunked prefill stays bitwise
    /// identical to feeding the rows one at a time.
    ///
    /// **Not atomic**: on [`PoolExhausted`] the rows before the failing
    /// one remain appended.  A multi-stream caller (one chunk across every
    /// `(layer, head)` stream) must treat the whole session as torn and
    /// discard it, exactly like a failed batched decode step.
    pub fn try_append_rows(&mut self, k_rows: &[f32], v_rows: &[f32]) -> Result<(), PoolExhausted> {
        assert_eq!(k_rows.len(), v_rows.len(), "k/v chunk length mismatch");
        assert_eq!(k_rows.len() % self.d, 0, "chunk must be whole rows");
        for (k, v) in k_rows.chunks_exact(self.d).zip(v_rows.chunks_exact(self.d)) {
            self.try_append(k, v)?;
        }
        Ok(())
    }

    /// Causal MRA-2 attention of `q_row` (the newest position, `len - 1`)
    /// over the cached prefix; returns the row-normalized output row.
    /// Allocates the output — serving hot paths should pass a reusable
    /// buffer to [`DecodeState::attend_last_into`] instead.
    pub fn attend_last(&mut self, q_row: &[f32]) -> Vec<f32> {
        let mut out = vec![0.0f32; self.d];
        self.attend_last_into(q_row, &mut out);
        out
    }

    /// [`DecodeState::attend_last`] into a caller-owned output row — the
    /// allocation-free steady path (all transients live in the state's
    /// scratch; asserted by the scratch-reuse test).
    pub fn attend_last_into(&mut self, q_row: &[f32], out: &mut [f32]) {
        assert!(self.len > 0, "attend_last on an empty cache");
        assert_eq!(q_row.len(), self.d, "q row width");
        let (len, block, budget, variant) = (self.len, self.block, self.budget, self.variant);
        attend_row_paged(
            &self.pages,
            len - 1,
            block,
            budget,
            variant,
            q_row,
            &mut self.scratch,
            out,
        );
    }

    /// Causal attention of `q_row` *as position `pos`* over the prefix
    /// `0..=pos` of the cache — the chunked-prefill form of
    /// [`DecodeState::attend_last_into`]: after a whole chunk of K/V rows
    /// has been appended, every row of the chunk attends its own causal
    /// prefix, in parallel, through a caller-owned (per pool worker)
    /// scratch.  Takes `&self` so one stream's rows can fan out across
    /// workers; the float sequence for each row is exactly what
    /// `attend_last_into` produced when `pos` was the newest position
    /// (asserted by the chunked-prefill bitwise tests).
    pub fn attend_pos_into(
        &self,
        q_row: &[f32],
        pos: usize,
        scratch: &mut DecodeScratch,
        out: &mut [f32],
    ) {
        assert!(pos < self.len, "position {pos} not cached (len {})", self.len);
        assert_eq!(q_row.len(), self.d, "q row width");
        attend_row_paged(
            &self.pages,
            pos,
            self.block,
            self.budget,
            self.variant,
            q_row,
            scratch,
            out,
        );
    }

    /// One decode step: `append` + `attend_last`.
    pub fn step(&mut self, q_row: &[f32], k_row: &[f32], v_row: &[f32]) -> Vec<f32> {
        self.append(k_row, v_row);
        self.attend_last(q_row)
    }

    /// [`DecodeState::step`] into a caller-owned output row — the
    /// allocation-free serving loop (`append` +
    /// [`DecodeState::attend_last_into`]).
    pub fn step_into(&mut self, q_row: &[f32], k_row: &[f32], v_row: &[f32], out: &mut [f32]) {
        self.append(k_row, v_row);
        self.attend_last_into(q_row, out);
    }

    /// Total reserved f32/usize elements of the per-step scratch — the
    /// steady-state allocation gate asserts this stops growing.
    #[cfg(test)]
    fn scratch_elems(&self) -> usize {
        self.scratch.s_low.capacity()
            + self.scratch.refined.capacity()
            + self.scratch.is_refined.capacity()
            + self.scratch.scores.capacity()
            + self.scratch.deq.capacity()
    }
}

/// Attend position `pos` of a paged stream over its causal prefix — the
/// shared body of [`DecodeState::attend_last_into`] (newest position,
/// state-owned scratch) and [`DecodeState::attend_pos_into`] (any cached
/// position, caller-owned scratch).
#[allow(clippy::too_many_arguments)]
fn attend_row_paged(
    pages: &[PageRef],
    pos: usize,
    block: usize,
    budget: usize,
    variant: Variant,
    q_row: &[f32],
    scratch: &mut DecodeScratch,
    out: &mut [f32],
) {
    let d = q_row.len();
    assert_eq!(out.len(), d, "out row width");
    let len = pos + 1;
    let x = pos / block;
    let w = len - x * block;
    // lend the scratch's dequant buffer to the block source while the
    // rest of the scratch feeds the core (allocation-free: take/put-back
    // moves the Vec, preserving its capacity)
    let mut deq = std::mem::take(&mut scratch.deq);
    let mut src = PagedBlocks { pages, x, w, deq: &mut deq };
    attend_row_core(q_row, &mut src, len, block, budget, variant, scratch, out);
    scratch.deq = deq;
}

/// Shared row-attention core: the position `len - 1` attends the cached
/// prefix exposed by `src` (complete past blocks `0..x` plus the current
/// block's `w` rows).
///
/// Refined past blocks stream through the fused online-softmax kernel
/// (running max seeded at the Full variant's stabilization floor), then
/// the current partial block, then the low-res `mu` correction — the same
/// schedule as the batch path's [`crate::mra::mra2_apply_blocks`] with a
/// single query row.  Every [`BlockSource`] feeds the identical float
/// sequence, so paged and contiguous states agree bitwise.
#[allow(clippy::too_many_arguments)]
fn attend_row_core<S: BlockSource>(
    q_row: &[f32],
    src: &mut S,
    len: usize,
    block: usize,
    budget: usize,
    variant: Variant,
    scratch: &mut DecodeScratch,
    out: &mut [f32],
) {
    let d = q_row.len();
    let b = block;
    let i = len - 1;
    let x = i / b; // current (query) block
    let inv_sqrt_d = 1.0 / (d as f32).sqrt();

    // per-row Alg. 1: score every complete past block at low resolution
    let s_low = &mut scratch.s_low;
    s_low.clear();
    s_low.extend((0..x).map(|y| kernel::dot(q_row, src.kt(y)) * inv_sqrt_d));
    topk::top_k_into(s_low, budget.min(x), &mut scratch.refined);
    scratch.refined.sort_unstable();
    let is_refined = &mut scratch.is_refined;
    is_refined.clear();
    is_refined.resize(x, false);
    for &y in &scratch.refined {
        is_refined[y] = true;
    }

    // stabilization floor: best non-refined low-res score (Full only)
    let mut floor = f32::NEG_INFINITY;
    if variant == Variant::Full {
        for (y, &s) in s_low.iter().enumerate() {
            if !is_refined[y] && s > floor {
                floor = s;
            }
        }
    }

    // fused pass: refined past blocks, then the current (partial) block,
    // under the single-row online-softmax recurrence
    out.fill(0.0);
    let mut rowmax = [floor];
    let mut den = [0.0f32];
    let scores = &mut scratch.scores;
    for &y in &scratch.refined {
        scores.clear();
        scores.resize(b, 0.0);
        kernel::score_panel(q_row, d, src.panel(y), b, inv_sqrt_d, scores);
        kernel::softmax_accum_panel(scores, src.v_block(y), b, d, &mut rowmax, &mut den, out);
    }
    let w = len - x * b;
    let tail_k = src.tail_k();
    scores.clear();
    scores.extend((0..w).map(|r| kernel::dot(q_row, &tail_k[r * d..(r + 1) * d]) * inv_sqrt_d));
    kernel::softmax_accum_panel(scores, src.tail_v(), w, d, &mut rowmax, &mut den, out);

    // low-resolution contribution of the non-refined past blocks; the
    // running max is >= the floor >= every non-refined pooled score, so
    // each `mu` stays in range
    if variant == Variant::Full {
        let mf = rowmax[0];
        for (y, &s) in s_low.iter().enumerate() {
            if is_refined[y] {
                continue;
            }
            let mu = (s - mf).exp() * b as f32;
            den[0] += mu;
            kernel::axpy(out, src.vt(y), mu);
        }
    }

    let inv = if den[0] > 0.0 { 1.0 / den[0] } else { 0.0 };
    kernel::scale(out, inv);
}

/// Attention output of the *last* position of a causal prefix, computed
/// from scratch (no incremental state): pools the complete blocks of the
/// prefix, packs their K^T panels, and runs the same row core as
/// [`DecodeState::attend_last`].  Bitwise identical to an incrementally
/// maintained [`DecodeState`] — the regression surface for KV-cache and
/// page bookkeeping bugs.
pub fn causal_row_attention(
    q_row: &[f32],
    k_prefix: &[f32],
    v_prefix: &[f32],
    block: usize,
    budget: usize,
    variant: Variant,
) -> Vec<f32> {
    let d = q_row.len();
    assert!(!k_prefix.is_empty() && k_prefix.len() % d == 0, "k prefix shape");
    assert_eq!(k_prefix.len(), v_prefix.len(), "k/v prefix mismatch");
    let len = k_prefix.len() / d;
    let x = (len - 1) / block;
    let kt = ops::pool_rows_slice(&k_prefix[..x * block * d], x * block, d, block);
    let vt = ops::pool_rows_slice(&v_prefix[..x * block * d], x * block, d, block);
    let mut kt_panels = vec![0.0f32; x * block * d];
    for (y, panel) in kt_panels.chunks_exact_mut(block * d).enumerate() {
        kernel::pack_transpose(&k_prefix[y * block * d..(y + 1) * block * d], block, d, panel);
    }
    let mut src = SliceBlocks {
        d,
        b: block,
        kt: &kt.data,
        vt: &vt.data,
        panels: &kt_panels,
        v_prefix,
        tail_k: &k_prefix[x * block * d..len * d],
        tail_v: &v_prefix[x * block * d..len * d],
    };
    let mut out = vec![0.0f32; d];
    attend_row_core(
        q_row,
        &mut src,
        len,
        block,
        budget,
        variant,
        &mut DecodeScratch::default(),
        &mut out,
    );
    out
}

/// Dense oracle for one decode row: materialize the full score vector over
/// the prefix under the same per-row selection rule (exact for the current
/// block and refined past blocks, pooled `mu` scores elsewhere, `-inf`
/// for dropped blocks in the sparse variant), softmax-normalize, and
/// aggregate values position by position.  Deliberately kept on the scalar
/// `dot` path — the reference the fused kernels are gated against (<= 1e-5
/// max abs error in tests and `benches/bench_decode.rs`).
pub fn causal_row_oracle(
    q_row: &[f32],
    k_prefix: &[f32],
    v_prefix: &[f32],
    block: usize,
    budget: usize,
    variant: Variant,
) -> Vec<f32> {
    let d = q_row.len();
    assert!(!k_prefix.is_empty() && k_prefix.len() % d == 0, "k prefix shape");
    assert_eq!(k_prefix.len(), v_prefix.len(), "k/v prefix mismatch");
    let len = k_prefix.len() / d;
    let b = block;
    let x = (len - 1) / b;
    let inv_sqrt_d = 1.0 / (d as f32).sqrt();
    let kt = ops::pool_rows_slice(&k_prefix[..x * b * d], x * b, d, b);

    let s_low: Vec<f32> =
        (0..x).map(|y| kernel::dot(q_row, kt.row(y)) * inv_sqrt_d).collect();
    let refined = topk::top_k_indices(&s_low, budget.min(x));
    let mut is_refined = vec![false; x];
    for &y in &refined {
        is_refined[y] = true;
    }

    let mut s = vec![f32::NEG_INFINITY; len];
    for y in 0..x {
        for j in y * b..(y + 1) * b {
            s[j] = if is_refined[y] {
                kernel::dot(q_row, &k_prefix[j * d..(j + 1) * d]) * inv_sqrt_d
            } else if variant == Variant::Full {
                s_low[y]
            } else {
                f32::NEG_INFINITY
            };
        }
    }
    for (j, sj) in s.iter_mut().enumerate().skip(x * b) {
        *sj = kernel::dot(q_row, &k_prefix[j * d..(j + 1) * d]) * inv_sqrt_d;
    }

    let mx = s.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut out = vec![0.0f32; d];
    let mut den = 0.0f32;
    for (j, &sj) in s.iter().enumerate() {
        let a = (sj - mx).exp();
        if a == 0.0 {
            continue;
        }
        den += a;
        for (o, &vv) in out.iter_mut().zip(&v_prefix[j * d..(j + 1) * d]) {
            *o += a * vv;
        }
    }
    let inv = 1.0 / den.max(1e-30);
    for o in out.iter_mut() {
        *o *= inv;
    }
    out
}

/// Counter-based deterministic RNG state for stochastic token selection —
/// the piece of session state that makes sampled decode **replayable**.
///
/// Draw `i` is a pure function of `(seed, i)`: the SplitMix64 output
/// function applied to `seed + (i + 1) * GAMMA`.  The sequence is
/// identical to [`crate::tensor::Rng::new(seed)`](crate::tensor::Rng)
/// calling `uniform()` repeatedly, but the state is just two integers —
/// so a preempted session persists `(seed, draws)`, and
/// [`DrawState::replay`] fast-forwards in O(1) to reproduce the *exact*
/// remaining draw sequence after recompute-on-readmit (DESIGN.md §12).
///
/// Lives in `engine/decode.rs` because it is decode-time session state
/// with the same lifecycle as [`DecodeState`]; like the KV pyramid it must
/// survive page eviction by being cheap to serialize (two `u64`s).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DrawState {
    seed: u64,
    draws: u64,
}

const SPLITMIX_GAMMA: u64 = 0x9E3779B97F4A7C15;

#[inline]
fn splitmix_finalize(x: u64) -> u64 {
    let mut z = x;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl DrawState {
    /// Fresh draw stream for `seed` (no draws consumed yet).
    pub fn new(seed: u64) -> Self {
        DrawState { seed, draws: 0 }
    }

    /// Reconstruct a stream that has already consumed `draws` draws —
    /// O(1), the replay primitive used at session readmission.
    pub fn replay(seed: u64, draws: u64) -> Self {
        DrawState { seed, draws }
    }

    /// Seed this stream was created with.
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Number of draws consumed so far (the replay cursor).
    pub fn draws(&self) -> u64 {
        self.draws
    }

    /// Next raw 64-bit draw.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.draws = self.draws.wrapping_add(1);
        // `Rng::new` pre-advances one GAMMA, so its draw i sits at counter
        // i + 1; mirroring that keeps the two sequences bitwise equal.
        let ctr = self.draws.wrapping_add(1).wrapping_mul(SPLITMIX_GAMMA);
        splitmix_finalize(self.seed.wrapping_add(ctr))
    }

    /// Next uniform draw in `[0, 1)` (top 24 bits, matching
    /// [`crate::tensor::Rng::uniform`] bitwise).
    #[inline]
    pub fn next_uniform(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest::{assert_close, for_all_seeds};
    use crate::tensor::Rng;

    fn rows(n: usize, d: usize, rng: &mut Rng) -> Vec<f32> {
        (0..n * d).map(|_| rng.normal()).collect()
    }

    #[test]
    fn incremental_decode_is_bitwise_identical_to_prefix_recompute() {
        let (d, b) = (16usize, 8usize);
        for variant in [Variant::Full, Variant::Sparse] {
            let mut rng = Rng::new(11);
            let n = 70; // crosses several block boundaries + a partial tail
            let q = rows(n, d, &mut rng);
            let k = rows(n, d, &mut rng);
            let v = rows(n, d, &mut rng);
            let mut st = DecodeState::new(b, 2, variant, d);
            for t in 0..n {
                st.append(&k[t * d..(t + 1) * d], &v[t * d..(t + 1) * d]);
                let inc = st.attend_last(&q[t * d..(t + 1) * d]);
                let scratch = causal_row_attention(
                    &q[t * d..(t + 1) * d],
                    &k[..(t + 1) * d],
                    &v[..(t + 1) * d],
                    b,
                    2,
                    variant,
                );
                assert_eq!(inc, scratch, "{variant:?} step {t}");
            }
        }
    }

    #[test]
    fn decode_matches_dense_oracle() {
        for_all_seeds(8, |seed, rng| {
            let (d, b) = (8usize, 8usize);
            let n = 1 + rng.below(64);
            let budget = rng.below(4);
            let variant = if seed % 2 == 0 {
                Variant::Full
            } else {
                Variant::Sparse
            };
            let q = rows(n, d, rng);
            let k = rows(n, d, rng);
            let v = rows(n, d, rng);
            let mut st = DecodeState::new(b, budget, variant, d);
            for t in 0..n {
                st.append(&k[t * d..(t + 1) * d], &v[t * d..(t + 1) * d]);
                let fast = st.attend_last(&q[t * d..(t + 1) * d]);
                let oracle = causal_row_oracle(
                    &q[t * d..(t + 1) * d],
                    &k[..(t + 1) * d],
                    &v[..(t + 1) * d],
                    b,
                    budget,
                    variant,
                );
                assert_close(&fast, &oracle, 1e-5, 1e-4)
                    .map_err(|e| format!("{variant:?} budget={budget} step {t}: {e}"))?;
            }
            Ok(())
        });
    }

    #[test]
    fn attend_last_into_is_allocation_free_once_warm() {
        // steady-state gate: after a warmup step at full pyramid depth, the
        // per-step scratch must stop growing and attend_last_into must
        // match attend_last exactly
        let (d, b) = (16usize, 8usize);
        let mut rng = Rng::new(21);
        let n = 64;
        let q = rows(n, d, &mut rng);
        let k = rows(n, d, &mut rng);
        let v = rows(n, d, &mut rng);
        let mut st = DecodeState::new(b, 2, Variant::Full, d);
        let mut out = vec![0.0f32; d];
        for t in 0..n {
            st.append(&k[t * d..(t + 1) * d], &v[t * d..(t + 1) * d]);
            st.attend_last_into(&q[t * d..(t + 1) * d], &mut out);
            let alloc = st.attend_last(&q[t * d..(t + 1) * d]);
            assert_eq!(out, alloc, "step {t}: into/alloc paths diverged");
        }
        // same-length steady state: repeat the last step's attention many
        // times; the scratch footprint must be exactly stable
        let stable = st.scratch_elems();
        for _ in 0..16 {
            st.attend_last_into(&q[(n - 1) * d..n * d], &mut out);
            assert_eq!(st.scratch_elems(), stable, "steady-state scratch grew");
        }
    }

    #[test]
    fn fork_shares_pages_physically_then_copy_on_writes() {
        let (d, b) = (8usize, 4usize);
        let pool = PagePool::new(64, b, d);
        let mut rng = Rng::new(31);
        let n = 10; // 2 complete pages + a 2-row partial tail
        let k = rows(n + 4, d, &mut rng);
        let v = rows(n + 4, d, &mut rng);
        let mut base = DecodeState::with_pool(&pool, 2, Variant::Full);
        for t in 0..n {
            base.append(&k[t * d..(t + 1) * d], &v[t * d..(t + 1) * d]);
        }
        let used_before = pool.pages_in_use();
        let mut forked = base.fork();
        // the fork is physically the same memory, not a numeric copy
        assert_eq!(pool.pages_in_use(), used_before, "fork must not consume pages");
        for (a, bb) in base.pages().iter().zip(forked.pages()) {
            assert!(Arc::ptr_eq(a, bb), "forked page is not shared");
        }
        assert!(Arc::strong_count(&base.pages()[0]) >= 2);
        // divergent appends: the shared partial tail copies on write,
        // complete pages stay shared
        let t = n;
        forked.append(&k[t * d..(t + 1) * d], &v[t * d..(t + 1) * d]);
        assert_eq!(pool.pages_in_use(), used_before + 1, "CoW must copy one page");
        assert!(!Arc::ptr_eq(&base.pages()[2], &forked.pages()[2]), "tail must diverge");
        assert!(Arc::ptr_eq(&base.pages()[0], &forked.pages()[0]));
        assert!(Arc::ptr_eq(&base.pages()[1], &forked.pages()[1]));
        // and the parent is untouched: bitwise identical to a cold state
        // over the same rows
        let t2 = n + 1;
        base.append(&k[t2 * d..(t2 + 1) * d], &v[t2 * d..(t2 + 1) * d]);
        let q = rows(1, d, &mut rng);
        let out_base = base.attend_last(&q);
        let mut cold = DecodeState::new(b, 2, Variant::Full, d);
        for tt in 0..n {
            cold.append(&k[tt * d..(tt + 1) * d], &v[tt * d..(tt + 1) * d]);
        }
        cold.append(&k[t2 * d..(t2 + 1) * d], &v[t2 * d..(t2 + 1) * d]);
        assert_eq!(out_base, cold.attend_last(&q), "parent diverged after fork CoW");
    }

    #[test]
    fn from_cached_pages_continue_bitwise_identically() {
        let (d, b) = (8usize, 4usize);
        let pool = PagePool::new(64, b, d);
        let mut rng = Rng::new(33);
        let n = 14; // 3 complete blocks + 2 tail rows
        let k = rows(n, d, &mut rng);
        let v = rows(n, d, &mut rng);
        let q = rows(n, d, &mut rng);
        let mut full = DecodeState::with_pool(&pool, 2, Variant::Full);
        for t in 0..n {
            full.append(&k[t * d..(t + 1) * d], &v[t * d..(t + 1) * d]);
        }
        // seed a new state from the first 2 complete blocks' pages (the
        // radix-cache hit path), then replay the rest
        let cached: Vec<PageRef> = full.pages()[..2].to_vec();
        let mut warm = DecodeState::from_cached(&pool, 2, Variant::Full, cached, 2 * b);
        for t in 2 * b..n {
            warm.append(&k[t * d..(t + 1) * d], &v[t * d..(t + 1) * d]);
        }
        assert!(Arc::ptr_eq(&full.pages()[0], &warm.pages()[0]));
        assert!(Arc::ptr_eq(&full.pages()[1], &warm.pages()[1]));
        let qrow = &q[(n - 1) * d..n * d];
        assert_eq!(full.attend_last(qrow), warm.attend_last(qrow));
    }

    #[test]
    fn chunked_append_and_positional_attend_match_per_token_bitwise() {
        // the decode-layer half of the chunked-prefill identity: appending
        // a whole chunk and attending each row at its own position must
        // reproduce the per-token append/attend_last float sequence exactly
        let (d, b) = (16usize, 8usize);
        for variant in [Variant::Full, Variant::Sparse] {
            let mut rng = Rng::new(41);
            let n = 61; // non-block-aligned, several boundaries
            let q = rows(n, d, &mut rng);
            let k = rows(n, d, &mut rng);
            let v = rows(n, d, &mut rng);
            // per-token reference
            let mut per_tok = DecodeState::new(b, 2, variant, d);
            let mut want = Vec::new();
            for t in 0..n {
                per_tok.append(&k[t * d..(t + 1) * d], &v[t * d..(t + 1) * d]);
                want.push(per_tok.attend_last(&q[t * d..(t + 1) * d]));
            }
            // chunked: bulk-append in uneven chunks, then attend each row
            // positionally with a fresh caller scratch
            let mut chunked = DecodeState::new(b, 2, variant, d);
            let mut start = 0usize;
            for take in [5usize, 8, 16, 3, 29] {
                let end = (start + take).min(n);
                chunked
                    .try_append_rows(&k[start * d..end * d], &v[start * d..end * d])
                    .unwrap();
                let mut scratch = DecodeScratch::default();
                let mut out = vec![0.0f32; d];
                for pos in start..end {
                    let qrow = &q[pos * d..(pos + 1) * d];
                    chunked.attend_pos_into(qrow, pos, &mut scratch, &mut out);
                    assert_eq!(out, want[pos], "{variant:?} pos {pos}");
                }
                start = end;
            }
            assert_eq!(chunked.len(), n);
            // positional attends re-run after later blocks completed still
            // read the same rows (finalization never rewrites raw K/V)
            let mut scratch = DecodeScratch::default();
            let mut out = vec![0.0f32; d];
            for pos in [0usize, 7, 8, 20, n - 1] {
                chunked.attend_pos_into(&q[pos * d..(pos + 1) * d], pos, &mut scratch, &mut out);
                assert_eq!(out, want[pos], "{variant:?} replayed pos {pos}");
            }
        }
    }

    #[test]
    fn compressed_pages_attend_within_error_budget() {
        // three twin streams fed identical rows: `oracle` stays all-f32,
        // `plain` is "demoted" to F32 (the configured no-compression mode
        // — must be a bitwise no-op), `demoted` compresses cold pages
        // mid-stream and must stay within the format's documented budget
        for_all_seeds(8, |seed, rng| {
            let (d, b) = (8usize, 8usize);
            let budget = 2usize;
            let fmt = if seed % 2 == 0 { PageFormat::Bf16 } else { PageFormat::Int8 };
            let n = 2 * b + 1 + rng.below(4 * b);
            let q = rows(n, d, rng);
            let k = rows(n, d, rng);
            let v = rows(n, d, rng);
            let oracle_pool = PagePool::unbounded(b, d);
            let plain_pool = PagePool::unbounded(b, d);
            let demoted_pool = PagePool::unbounded(b, d);
            let mut oracle = DecodeState::with_pool(&oracle_pool, budget, Variant::Full);
            let mut plain = DecodeState::with_pool(&plain_pool, budget, Variant::Full);
            let mut demoted = DecodeState::with_pool(&demoted_pool, budget, Variant::Full);
            let mut out_o = vec![0.0f32; d];
            let mut out_p = vec![0.0f32; d];
            let mut out_c = vec![0.0f32; d];
            for t in 0..n {
                let (kr, vr) = (&k[t * d..(t + 1) * d], &v[t * d..(t + 1) * d]);
                oracle.append(kr, vr);
                plain.append(kr, vr);
                demoted.append(kr, vr);
                // mid-stream pressure every few steps
                if t % 5 == 4 {
                    demoted.demote_cold(fmt, 1);
                }
                if plain.demote_cold(PageFormat::F32, usize::MAX) != 0 {
                    return Err("F32 demotion must be a no-op".to_string());
                }
                let qrow = &q[t * d..(t + 1) * d];
                oracle.attend_last_into(qrow, &mut out_o);
                plain.attend_last_into(qrow, &mut out_p);
                demoted.attend_last_into(qrow, &mut out_c);
                // (a) F32 mode bitwise identical
                if out_p != out_o {
                    return Err(format!("step {t}: F32 page mode diverged bitwise"));
                }
                // quantized pooled scores can flip the refined-set choice
                // when two blocks are nearly tied; that flip is an
                // approximation-level change, not a quantization error, so
                // the budget is only asserted away from ties (the pooled-
                // score perturbation is < 0.02 for both formats here)
                let x = t / b;
                let tied = x > budget && {
                    let inv_sqrt_d = 1.0 / (d as f32).sqrt();
                    let mut s: Vec<f32> = (0..x)
                        .map(|y| kernel::dot(qrow, oracle.pages()[y].kt()) * inv_sqrt_d)
                        .collect();
                    s.sort_by(|a2, b2| b2.partial_cmp(a2).unwrap());
                    (s[budget - 1] - s[budget]).abs() < 0.05
                };
                if !tied {
                    // (b) compressed outputs within the documented budget
                    for (j, (&a2, &b2)) in out_o.iter().zip(&out_c).enumerate() {
                        if (a2 - b2).abs() > fmt.error_budget() {
                            return Err(format!(
                                "step {t} dim {j}: |{a2} - {b2}| > {} ({fmt})",
                                fmt.error_budget()
                            ));
                        }
                    }
                }
            }
            // (c) pool occupancy in bytes matches the stream's format mix
            if demoted.compressed_pages() == 0 {
                return Err("no page was ever demoted".to_string());
            }
            if demoted_pool.bytes_in_use() != demoted.bytes_resident() {
                return Err(format!(
                    "pool bytes {} != format-weighted resident bytes {}",
                    demoted_pool.bytes_in_use(),
                    demoted.bytes_resident()
                ));
            }
            if demoted_pool.bytes_in_use() >= oracle_pool.bytes_in_use() {
                return Err("compressed stream must be smaller than its f32 twin".to_string());
            }
            demoted_pool.verify().map_err(|e| format!("pool verify: {e}"))?;
            Ok(())
        });
    }

    #[test]
    fn demote_cold_skips_hot_tail_and_shared_pages() {
        let (d, b) = (8usize, 4usize);
        let pool = PagePool::new(64, b, d);
        let mut rng = Rng::new(51);
        let n = 3 * b + 2; // 3 complete blocks + partial tail
        let k = rows(n, d, &mut rng);
        let v = rows(n, d, &mut rng);
        let mut st = DecodeState::with_pool(&pool, 2, Variant::Full);
        for t in 0..n {
            st.append(&k[t * d..(t + 1) * d], &v[t * d..(t + 1) * d]);
        }
        // share the first page (a radix-cache hit would do this)
        let cached = st.pages()[0].clone();
        // limit binds: only one page demoted per call
        assert_eq!(st.demote_cold(PageFormat::Bf16, 1), 1);
        // the shared page 0 was skipped — page 1 got demoted instead
        assert_eq!(st.pages()[0].format(), PageFormat::F32);
        assert_eq!(st.pages()[1].format(), PageFormat::Bf16);
        // drain: page 2 is cold, page 3 is the hot (partial) tail
        assert_eq!(st.demote_cold(PageFormat::Bf16, usize::MAX), 1);
        assert_eq!(st.pages()[2].format(), PageFormat::Bf16);
        assert_eq!(st.pages()[3].format(), PageFormat::F32, "hot tail never demotes");
        assert_eq!(st.compressed_pages(), 2);
        // appending across the demoted prefix still works (tail is f32)
        for t in 0..b {
            st.append(&k[t * d..(t + 1) * d], &v[t * d..(t + 1) * d]);
        }
        let out = st.attend_last(&k[..d]);
        assert_eq!(out.len(), d);
        drop(cached);
        pool.check_invariants();
    }

    #[test]
    fn pages_needed_for_append_counts_boundaries_and_cow() {
        let (d, b) = (4usize, 4usize);
        let pool = PagePool::new(64, b, d);
        let mut st = DecodeState::with_pool(&pool, 1, Variant::Full);
        let row = vec![1.0f32; d];
        assert_eq!(st.pages_needed_for_append(0), 0);
        assert_eq!(st.pages_needed_for_append(1), 1); // starts block 0
        assert_eq!(st.pages_needed_for_append(b), 1);
        assert_eq!(st.pages_needed_for_append(b + 1), 2);
        assert_eq!(st.pages_needed_for_append(3 * b), 3);
        st.try_append(&row, &row).unwrap(); // len 1: inside block 0
        assert_eq!(st.pages_needed_for_append(b - 1), 0);
        assert_eq!(st.pages_needed_for_append(b), 1);
        assert!(!st.next_append_needs_page());
        // a fork shares the partial tail: the next append copies-on-write
        let fork = st.fork();
        assert_eq!(st.pages_needed_for_append(b - 1), 1, "CoW counted");
        assert_eq!(st.pages_needed_for_append(b), 2, "CoW + new block");
        assert!(st.next_append_needs_page());
        drop(fork);
        assert_eq!(st.pages_needed_for_append(b - 1), 0);
        // the estimate matches what a real chunk consumes
        let used = pool.pages_in_use();
        let need = st.pages_needed_for_append(2 * b + 1);
        let many = vec![1.0f32; (2 * b + 1) * d];
        st.try_append_rows(&many, &many).unwrap();
        assert_eq!(pool.pages_in_use(), used + need);
    }

    #[test]
    fn bounded_pool_exhaustion_is_clean_and_retryable() {
        let (d, b) = (4usize, 4usize);
        let pool = PagePool::new(2, b, d);
        let mut st = DecodeState::with_pool(&pool, 1, Variant::Full);
        let row = vec![1.0f32; d];
        for _ in 0..b {
            st.try_append(&row, &row).unwrap();
        }
        // a second stream grabs the last free page
        let hog = pool.try_alloc().unwrap();
        // next append needs a second page: fails, state unchanged
        assert!(st.next_append_needs_page());
        assert_eq!(st.try_append(&row, &row).unwrap_err(), PoolExhausted);
        assert_eq!(st.len(), b);
        let out = st.attend_last(&row); // still fully usable
        assert_eq!(out.len(), d);
        // freeing pages elsewhere makes the *same* append succeed (the
        // scheduler's evict-then-retry path)
        drop(hog);
        st.try_append(&row, &row).unwrap();
        assert_eq!(st.len(), b + 1);
    }

    #[test]
    fn first_token_attends_only_itself() {
        let mut rng = Rng::new(3);
        let d = 8;
        let q = rows(1, d, &mut rng);
        let k = rows(1, d, &mut rng);
        let v = rows(1, d, &mut rng);
        let mut st = DecodeState::new(4, 2, Variant::Full, d);
        st.append(&k, &v);
        let out = st.attend_last(&q);
        assert_close(&out, &v, 1e-6, 1e-6).unwrap();
    }

    #[test]
    fn decode_rows_are_convex_with_ones_values() {
        let (d, b) = (8usize, 8usize);
        for variant in [Variant::Full, Variant::Sparse] {
            let mut rng = Rng::new(5);
            let n = 40;
            let q = rows(n, d, &mut rng);
            let k = rows(n, d, &mut rng);
            let v = vec![1.0f32; n * d];
            let mut st = DecodeState::new(b, 1, variant, d);
            for t in 0..n {
                let out = st.step(
                    &q[t * d..(t + 1) * d],
                    &k[t * d..(t + 1) * d],
                    &v[t * d..(t + 1) * d],
                );
                for &x in &out {
                    assert!((x - 1.0).abs() < 1e-4, "{variant:?} step {t}: {x}");
                }
            }
        }
    }

    #[test]
    fn step_is_append_plus_attend() {
        let d = 4;
        let mut rng = Rng::new(7);
        let q = rows(3, d, &mut rng);
        let k = rows(3, d, &mut rng);
        let v = rows(3, d, &mut rng);
        let mut a = DecodeState::new(2, 1, Variant::Full, d);
        let mut b2 = DecodeState::new(2, 1, Variant::Full, d);
        for t in 0..3 {
            let stepped = a.step(
                &q[t * d..(t + 1) * d],
                &k[t * d..(t + 1) * d],
                &v[t * d..(t + 1) * d],
            );
            b2.append(&k[t * d..(t + 1) * d], &v[t * d..(t + 1) * d]);
            let split = b2.attend_last(&q[t * d..(t + 1) * d]);
            assert_eq!(stepped, split, "step {t}");
        }
        assert_eq!(a.len(), 3);
        assert!(!a.is_empty());
        assert_eq!(a.block(), 2);
    }

    #[test]
    #[should_panic(expected = "empty cache")]
    fn attend_on_empty_cache_panics() {
        let mut st = DecodeState::new(4, 1, Variant::Full, 4);
        let _ = st.attend_last(&[0.0; 4]);
    }

    #[test]
    fn draw_state_matches_rng_uniform_bitwise() {
        for seed in [0u64, 1, 42, 0xDEAD_BEEF] {
            let mut rng = Rng::new(seed);
            let mut ds = DrawState::new(seed);
            for i in 0..64 {
                assert_eq!(
                    rng.uniform().to_bits(),
                    ds.next_uniform().to_bits(),
                    "seed {seed} draw {i}"
                );
            }
            assert_eq!(ds.draws(), 64);
            assert_eq!(ds.seed(), seed);
        }
    }

    #[test]
    fn draw_state_replay_is_exact_fast_forward() {
        for_all_seeds(16, |seed, rng| {
            let cut = (rng.below(30) + 1) as u64;
            let mut full = DrawState::new(seed);
            let mut head = Vec::new();
            for _ in 0..cut {
                head.push(full.next_u64());
            }
            // replay from (seed, cut) must continue the identical sequence
            let mut replayed = DrawState::replay(seed, cut);
            assert_eq!(replayed, full, "replay state mismatch");
            for i in 0..40 {
                let (a, b) = (full.next_u64(), replayed.next_u64());
                if a != b {
                    return Err(format!("post-replay draw {i}: {a} vs {b}"));
                }
            }
            // and the head is reproducible from scratch
            let mut again = DrawState::new(seed);
            for (i, h) in head.iter().enumerate() {
                if again.next_u64() != *h {
                    return Err(format!("head draw {i} not reproducible"));
                }
            }
            Ok(())
        });
    }
}
