//! Incremental causal decode: a per-(batch, head) KV cache that reuses the
//! MRA-2 pyramid across steps.
//!
//! [`DecodeState::append`] maintains the pooled key/value pyramid
//! incrementally — partial-block sums accumulate in arrival order and are
//! finalized (scaled by `1/block`) exactly when a block completes, the
//! same float sequence as pooling the full prefix from scratch, so the
//! incremental path is **bitwise identical** to recomputing the causal
//! prefix ([`causal_row_attention`]; asserted in tests and
//! `benches/bench_decode.rs`).
//!
//! [`DecodeState::attend_last`] runs a strictly per-row causal MRA-2 for
//! the newest position: exact attention over the current (possibly
//! partial) block and the `budget` best complete past blocks by pooled
//! score, low-resolution `mu` correction over the remaining past blocks
//! (Full variant).  Cost per generated token is
//! `O(block + budget * block + n / block)` against `O(n)` for exact causal
//! decode — the tokens/sec gap `benches/bench_decode.rs` measures.
//!
//! This per-row selection is the decode-time analog of the causal batch
//! plan's per-query-block budget (`mra::attention::mra2_plan` with
//! [`Causality::Causal`][crate::mra::Causality]); see DESIGN.md §7 for how
//! the two schedules relate.

use crate::mra::Variant;
use crate::tensor::mat::dot;
use crate::tensor::{ops, topk};

/// Incremental KV cache + pooled pyramid for one `(batch, head)` pair of
/// an autoregressive decode stream.
#[derive(Clone, Debug)]
pub struct DecodeState {
    block: usize,
    /// Refined complete past blocks per step (per-row Alg. 1 budget).
    budget: usize,
    variant: Variant,
    d: usize,
    len: usize,
    /// Raw appended key/value rows, `(len, d)` row-major.
    k_rows: Vec<f32>,
    v_rows: Vec<f32>,
    /// Pooled (mean) rows of every *completed* block, `(len / block, d)`.
    kt: Vec<f32>,
    vt: Vec<f32>,
    /// Running sums of the current partial block.
    ksum: Vec<f32>,
    vsum: Vec<f32>,
}

impl DecodeState {
    pub fn new(block: usize, budget: usize, variant: Variant, d: usize) -> Self {
        assert!(block > 0, "block must be positive");
        assert!(d > 0, "head dim must be positive");
        DecodeState {
            block,
            budget,
            variant,
            d,
            len: 0,
            k_rows: Vec::new(),
            v_rows: Vec::new(),
            kt: Vec::new(),
            vt: Vec::new(),
            ksum: vec![0.0; d],
            vsum: vec![0.0; d],
        }
    }

    /// Number of cached positions.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn block(&self) -> usize {
        self.block
    }

    /// Append one key/value row to the cache, maintaining the pooled
    /// pyramid incrementally.  Rows accumulate into the partial-block sums
    /// in arrival order and are finalized exactly when the block completes
    /// — the same float sequence as `ops::pool_rows_slice` over the full
    /// prefix, which is what makes incremental decode bitwise identical to
    /// a from-scratch recompute.
    pub fn append(&mut self, k_row: &[f32], v_row: &[f32]) {
        assert_eq!(k_row.len(), self.d, "k row width");
        assert_eq!(v_row.len(), self.d, "v row width");
        self.k_rows.extend_from_slice(k_row);
        self.v_rows.extend_from_slice(v_row);
        for (s, &x) in self.ksum.iter_mut().zip(k_row) {
            *s += x;
        }
        for (s, &x) in self.vsum.iter_mut().zip(v_row) {
            *s += x;
        }
        self.len += 1;
        if self.len % self.block == 0 {
            let inv = 1.0 / self.block as f32;
            self.kt.extend(self.ksum.iter().map(|&s| s * inv));
            self.vt.extend(self.vsum.iter().map(|&s| s * inv));
            self.ksum.fill(0.0);
            self.vsum.fill(0.0);
        }
    }

    /// Causal MRA-2 attention of `q_row` (the newest position, `len - 1`)
    /// over the cached prefix; returns the row-normalized output row.
    pub fn attend_last(&self, q_row: &[f32]) -> Vec<f32> {
        assert!(self.len > 0, "attend_last on an empty cache");
        assert_eq!(q_row.len(), self.d, "q row width");
        attend_row_core(
            q_row,
            &self.k_rows,
            &self.v_rows,
            self.len,
            &self.kt,
            &self.vt,
            self.block,
            self.budget,
            self.variant,
        )
    }

    /// One decode step: `append` + `attend_last`.
    pub fn step(&mut self, q_row: &[f32], k_row: &[f32], v_row: &[f32]) -> Vec<f32> {
        self.append(k_row, v_row);
        self.attend_last(q_row)
    }
}

/// Shared row-attention core: the position `len - 1` attends the `len`
/// cached k/v rows, with pooled complete-block mats `kt` / `vt` holding at
/// least `(len - 1) / block` rows each.
#[allow(clippy::too_many_arguments)]
fn attend_row_core(
    q_row: &[f32],
    k_rows: &[f32],
    v_rows: &[f32],
    len: usize,
    kt: &[f32],
    vt: &[f32],
    block: usize,
    budget: usize,
    variant: Variant,
) -> Vec<f32> {
    let d = q_row.len();
    let b = block;
    let i = len - 1;
    let x = i / b; // current (query) block
    debug_assert!(kt.len() >= x * d && vt.len() >= x * d, "pooled pyramid too short");
    let inv_sqrt_d = 1.0 / (d as f32).sqrt();

    // per-row Alg. 1: score every complete past block at low resolution
    let s_low: Vec<f32> =
        (0..x).map(|y| dot(q_row, &kt[y * d..(y + 1) * d]) * inv_sqrt_d).collect();
    let mut refined = topk::top_k_indices(&s_low, budget.min(x));
    refined.sort_unstable();
    let mut is_refined = vec![false; x];
    for &y in &refined {
        is_refined[y] = true;
    }

    // stabilization floor: best non-refined low-res score (Full only)
    let mut mx = f32::NEG_INFINITY;
    if variant == Variant::Full {
        for (y, &s) in s_low.iter().enumerate() {
            if !is_refined[y] && s > mx {
                mx = s;
            }
        }
    }

    // pass 1: exact scores for the refined past blocks + the current block
    let cur_start = x * b;
    let exact_count = refined.len() * b + (len - cur_start);
    let mut scores: Vec<f32> = Vec::with_capacity(exact_count);
    let mut positions: Vec<usize> = Vec::with_capacity(exact_count);
    for &y in &refined {
        for j in y * b..(y + 1) * b {
            let s = dot(q_row, &k_rows[j * d..(j + 1) * d]) * inv_sqrt_d;
            if s > mx {
                mx = s;
            }
            scores.push(s);
            positions.push(j);
        }
    }
    for j in cur_start..len {
        let s = dot(q_row, &k_rows[j * d..(j + 1) * d]) * inv_sqrt_d;
        if s > mx {
            mx = s;
        }
        scores.push(s);
        positions.push(j);
    }

    // pass 2: stabilized exp + value aggregation
    let mut out = vec![0.0f32; d];
    let mut den = 0.0f32;
    for (&s, &j) in scores.iter().zip(&positions) {
        let a = (s - mx).exp();
        den += a;
        let vrow = &v_rows[j * d..(j + 1) * d];
        for (o, &vv) in out.iter_mut().zip(vrow) {
            *o += a * vv;
        }
    }

    // low-resolution contribution of the non-refined past blocks
    if variant == Variant::Full {
        for (y, &s) in s_low.iter().enumerate() {
            if is_refined[y] {
                continue;
            }
            let mu = (s - mx).exp() * b as f32;
            den += mu;
            let vrow = &vt[y * d..(y + 1) * d];
            for (o, &vv) in out.iter_mut().zip(vrow) {
                *o += mu * vv;
            }
        }
    }

    let inv = if den > 0.0 { 1.0 / den } else { 0.0 };
    for o in out.iter_mut() {
        *o *= inv;
    }
    out
}

/// Attention output of the *last* position of a causal prefix, computed
/// from scratch (no incremental state): pools the complete blocks of the
/// prefix and runs the same row core as [`DecodeState::attend_last`].
/// Bitwise identical to an incrementally maintained [`DecodeState`] — the
/// regression surface for KV-cache bookkeeping bugs.
pub fn causal_row_attention(
    q_row: &[f32],
    k_prefix: &[f32],
    v_prefix: &[f32],
    block: usize,
    budget: usize,
    variant: Variant,
) -> Vec<f32> {
    let d = q_row.len();
    assert!(!k_prefix.is_empty() && k_prefix.len() % d == 0, "k prefix shape");
    assert_eq!(k_prefix.len(), v_prefix.len(), "k/v prefix mismatch");
    let len = k_prefix.len() / d;
    let x = (len - 1) / block;
    let kt = ops::pool_rows_slice(&k_prefix[..x * block * d], x * block, d, block);
    let vt = ops::pool_rows_slice(&v_prefix[..x * block * d], x * block, d, block);
    attend_row_core(q_row, k_prefix, v_prefix, len, &kt.data, &vt.data, block, budget, variant)
}

/// Dense oracle for one decode row: materialize the full score vector over
/// the prefix under the same per-row selection rule (exact for the current
/// block and refined past blocks, pooled `mu` scores elsewhere, `-inf`
/// for dropped blocks in the sparse variant), softmax-normalize, and
/// aggregate values position by position.  Tests and
/// `benches/bench_decode.rs` gate the fast path against this (<= 1e-5 max
/// abs error).
pub fn causal_row_oracle(
    q_row: &[f32],
    k_prefix: &[f32],
    v_prefix: &[f32],
    block: usize,
    budget: usize,
    variant: Variant,
) -> Vec<f32> {
    let d = q_row.len();
    assert!(!k_prefix.is_empty() && k_prefix.len() % d == 0, "k prefix shape");
    assert_eq!(k_prefix.len(), v_prefix.len(), "k/v prefix mismatch");
    let len = k_prefix.len() / d;
    let b = block;
    let x = (len - 1) / b;
    let inv_sqrt_d = 1.0 / (d as f32).sqrt();
    let kt = ops::pool_rows_slice(&k_prefix[..x * b * d], x * b, d, b);

    let s_low: Vec<f32> = (0..x).map(|y| dot(q_row, kt.row(y)) * inv_sqrt_d).collect();
    let refined = topk::top_k_indices(&s_low, budget.min(x));
    let mut is_refined = vec![false; x];
    for &y in &refined {
        is_refined[y] = true;
    }

    let mut s = vec![f32::NEG_INFINITY; len];
    for y in 0..x {
        for j in y * b..(y + 1) * b {
            s[j] = if is_refined[y] {
                dot(q_row, &k_prefix[j * d..(j + 1) * d]) * inv_sqrt_d
            } else if variant == Variant::Full {
                s_low[y]
            } else {
                f32::NEG_INFINITY
            };
        }
    }
    for (j, sj) in s.iter_mut().enumerate().skip(x * b) {
        *sj = dot(q_row, &k_prefix[j * d..(j + 1) * d]) * inv_sqrt_d;
    }

    let mx = s.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut out = vec![0.0f32; d];
    let mut den = 0.0f32;
    for (j, &sj) in s.iter().enumerate() {
        let a = (sj - mx).exp();
        if a == 0.0 {
            continue;
        }
        den += a;
        for (o, &vv) in out.iter_mut().zip(&v_prefix[j * d..(j + 1) * d]) {
            *o += a * vv;
        }
    }
    let inv = 1.0 / den.max(1e-30);
    for o in out.iter_mut() {
        *o *= inv;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest::{assert_close, for_all_seeds};
    use crate::tensor::Rng;

    fn rows(n: usize, d: usize, rng: &mut Rng) -> Vec<f32> {
        (0..n * d).map(|_| rng.normal()).collect()
    }

    #[test]
    fn incremental_decode_is_bitwise_identical_to_prefix_recompute() {
        let (d, b) = (16usize, 8usize);
        for variant in [Variant::Full, Variant::Sparse] {
            let mut rng = Rng::new(11);
            let n = 70; // crosses several block boundaries + a partial tail
            let q = rows(n, d, &mut rng);
            let k = rows(n, d, &mut rng);
            let v = rows(n, d, &mut rng);
            let mut st = DecodeState::new(b, 2, variant, d);
            for t in 0..n {
                st.append(&k[t * d..(t + 1) * d], &v[t * d..(t + 1) * d]);
                let inc = st.attend_last(&q[t * d..(t + 1) * d]);
                let scratch = causal_row_attention(
                    &q[t * d..(t + 1) * d],
                    &k[..(t + 1) * d],
                    &v[..(t + 1) * d],
                    b,
                    2,
                    variant,
                );
                assert_eq!(inc, scratch, "{variant:?} step {t}");
            }
        }
    }

    #[test]
    fn decode_matches_dense_oracle() {
        for_all_seeds(8, |seed, rng| {
            let (d, b) = (8usize, 8usize);
            let n = 1 + rng.below(64);
            let budget = rng.below(4);
            let variant = if seed % 2 == 0 {
                Variant::Full
            } else {
                Variant::Sparse
            };
            let q = rows(n, d, rng);
            let k = rows(n, d, rng);
            let v = rows(n, d, rng);
            let mut st = DecodeState::new(b, budget, variant, d);
            for t in 0..n {
                st.append(&k[t * d..(t + 1) * d], &v[t * d..(t + 1) * d]);
                let fast = st.attend_last(&q[t * d..(t + 1) * d]);
                let oracle = causal_row_oracle(
                    &q[t * d..(t + 1) * d],
                    &k[..(t + 1) * d],
                    &v[..(t + 1) * d],
                    b,
                    budget,
                    variant,
                );
                assert_close(&fast, &oracle, 1e-5, 1e-4)
                    .map_err(|e| format!("{variant:?} budget={budget} step {t}: {e}"))?;
            }
            Ok(())
        });
    }

    #[test]
    fn first_token_attends_only_itself() {
        let mut rng = Rng::new(3);
        let d = 8;
        let q = rows(1, d, &mut rng);
        let k = rows(1, d, &mut rng);
        let v = rows(1, d, &mut rng);
        let mut st = DecodeState::new(4, 2, Variant::Full, d);
        st.append(&k, &v);
        let out = st.attend_last(&q);
        assert_close(&out, &v, 1e-6, 1e-6).unwrap();
    }

    #[test]
    fn decode_rows_are_convex_with_ones_values() {
        let (d, b) = (8usize, 8usize);
        for variant in [Variant::Full, Variant::Sparse] {
            let mut rng = Rng::new(5);
            let n = 40;
            let q = rows(n, d, &mut rng);
            let k = rows(n, d, &mut rng);
            let v = vec![1.0f32; n * d];
            let mut st = DecodeState::new(b, 1, variant, d);
            for t in 0..n {
                let out = st.step(
                    &q[t * d..(t + 1) * d],
                    &k[t * d..(t + 1) * d],
                    &v[t * d..(t + 1) * d],
                );
                for &x in &out {
                    assert!((x - 1.0).abs() < 1e-4, "{variant:?} step {t}: {x}");
                }
            }
        }
    }

    #[test]
    fn step_is_append_plus_attend() {
        let d = 4;
        let mut rng = Rng::new(7);
        let q = rows(3, d, &mut rng);
        let k = rows(3, d, &mut rng);
        let v = rows(3, d, &mut rng);
        let mut a = DecodeState::new(2, 1, Variant::Full, d);
        let mut b2 = DecodeState::new(2, 1, Variant::Full, d);
        for t in 0..3 {
            let stepped = a.step(
                &q[t * d..(t + 1) * d],
                &k[t * d..(t + 1) * d],
                &v[t * d..(t + 1) * d],
            );
            b2.append(&k[t * d..(t + 1) * d], &v[t * d..(t + 1) * d]);
            let split = b2.attend_last(&q[t * d..(t + 1) * d]);
            assert_eq!(stepped, split, "step {t}");
        }
        assert_eq!(a.len(), 3);
        assert!(!a.is_empty());
        assert_eq!(a.block(), 2);
    }

    #[test]
    #[should_panic(expected = "empty cache")]
    fn attend_on_empty_cache_panics() {
        let st = DecodeState::new(4, 1, Variant::Full, 4);
        let _ = st.attend_last(&[0.0; 4]);
    }
}
