//! Scoped-thread worker pool (std only — no rayon offline).
//!
//! Work stealing over a **flattened, precomputed task list**: every task is
//! pushed into a `Vec` up front and workers claim tasks by bumping one
//! shared atomic cursor ([`run`] / [`run_with`]).  Compared with the old
//! mutex-guarded iterator, a claim is a single `fetch_add` — no lock
//! convoy on the queue head — and skewed task costs (e.g. MRA-2 query
//! blocks with different refined-tile counts) still self-balance because
//! idle workers immediately steal the next unclaimed index.
//!
//! [`run_with`] additionally gives every worker a private state value
//! (built once per worker, reused across all the tasks it claims) — the
//! hook the engine uses to keep one kernel scratch arena per worker so the
//! compute phase performs zero steady-state heap allocations.
//!
//! Tasks carry their own disjoint `&mut` output shards, which keeps the
//! whole scheme safe-Rust: no worker ever aliases another worker's output.
//! Each task slot is handed over through a dedicated `Mutex<Option<T>>`
//! that is locked exactly once, by the worker that claimed its index —
//! uncontended by construction.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

/// Default worker count: the machine's available parallelism.
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Worker indices with dedicated busy/steal counters; higher indices fold
/// into the last slot (machines that wide are out of scope here).
pub const TRACKED_WORKERS: usize = 64;

// process-global per-worker drain counters: pools are ephemeral
// (one scoped drain per call), so cumulative statics are the only
// aggregation point that survives across drains.  Relaxed counters —
// observability, not synchronization.
#[allow(clippy::declare_interior_mutable_const)]
const ZERO_COUNTER: AtomicU64 = AtomicU64::new(0);
static WORKER_BUSY: [AtomicU64; TRACKED_WORKERS] = [ZERO_COUNTER; TRACKED_WORKERS];
static WORKER_STEALS: [AtomicU64; TRACKED_WORKERS] = [ZERO_COUNTER; TRACKED_WORKERS];

/// Cumulative `(tasks_run, tasks_stolen)` per worker index, across every
/// drain since process start.  A task counts as **stolen** when the
/// claiming worker is not the task's home worker under an even block
/// split (`home = index * workers / items`) — i.e. the cursor let an idle
/// worker pull load a uniform split would have given to someone else.
/// Entries beyond the widest drain so far stay `(0, 0)`.  Monotone:
/// consumers (metrics exposition) diff snapshots, they never reset.
pub fn worker_stats() -> Vec<(u64, u64)> {
    WORKER_BUSY
        .iter()
        .zip(WORKER_STEALS.iter())
        .map(|(b, s)| (b.load(Ordering::Relaxed), s.load(Ordering::Relaxed)))
        .collect()
}

/// Run `f` over every item using up to `threads` scoped workers.
///
/// With `threads <= 1` everything runs inline on the caller's thread, so
/// the sequential path has zero synchronization overhead.
pub fn run<T: Send>(threads: usize, items: Vec<T>, f: impl Fn(T) + Sync) {
    run_with(threads, items, || (), |_state, item| f(item));
}

/// [`run`] with per-worker state: each worker calls `init` once and gets
/// `&mut` access to its state for every task it claims.  Use it to hoist
/// per-task allocations (scratch buffers, score arenas) into a per-worker
/// arena that lives for the whole drain.
pub fn run_with<T: Send, S>(
    threads: usize,
    items: Vec<T>,
    init: impl Fn() -> S + Sync,
    f: impl Fn(&mut S, T) + Sync,
) {
    let workers = threads.max(1).min(items.len().max(1));
    if workers <= 1 {
        let n = items.len() as u64;
        let mut state = init();
        for item in items {
            f(&mut state, item);
        }
        // the inline path is all "worker 0", nothing can be stolen
        WORKER_BUSY[0].fetch_add(n, Ordering::Relaxed);
        return;
    }
    // one setup allocation per drain, before any worker claims a task —
    // the per-task worker loop below is allocation-free
    let slots: Vec<Mutex<Option<T>>> =
        items.into_iter().map(|t| Mutex::new(Some(t))).collect(); // lint: allow(hot-path-alloc)
    let cursor = AtomicUsize::new(0);
    let (slots, cursor, init, f) = (&slots, &cursor, &init, &f);
    std::thread::scope(|s| {
        for w in 0..workers {
            s.spawn(move || {
                let slot = w.min(TRACKED_WORKERS - 1);
                let mut busy = 0u64;
                let mut steals = 0u64;
                let mut state = init();
                loop {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= slots.len() {
                        break;
                    }
                    // the lock is held only for the `take` (which cannot
                    // panic), so poisoning carries no information here —
                    // recover the guard instead of stacking a second
                    // panic onto an already-unwinding scope
                    let item = slots[i]
                        .lock()
                        .unwrap_or_else(std::sync::PoisonError::into_inner)
                        .take();
                    if let Some(item) = item {
                        busy += 1;
                        // "stolen" relative to an even block split of the
                        // task list — the load-balance signal metrics
                        // exposition surfaces per worker
                        if i * workers / slots.len() != w {
                            steals += 1;
                        }
                        f(&mut state, item);
                    }
                }
                // fold into the process-wide counters once per drain, not
                // per task — two relaxed adds per worker per drain
                WORKER_BUSY[slot].fetch_add(busy, Ordering::Relaxed);
                WORKER_STEALS[slot].fetch_add(steals, Ordering::Relaxed);
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_every_item_exactly_once_at_any_thread_count() {
        for threads in [1, 2, 4, 8, 32] {
            let sum = AtomicUsize::new(0);
            let count = AtomicUsize::new(0);
            let items: Vec<usize> = (1..=100).collect();
            run(threads, items, |i| {
                sum.fetch_add(i, Ordering::Relaxed);
                count.fetch_add(1, Ordering::Relaxed);
            });
            assert_eq!(sum.load(Ordering::Relaxed), 5050, "threads={threads}");
            assert_eq!(count.load(Ordering::Relaxed), 100, "threads={threads}");
        }
    }

    #[test]
    fn disjoint_mut_shards_are_safe() {
        let mut out = vec![0.0f32; 64];
        let items: Vec<(usize, &mut [f32])> = out.chunks_mut(8).enumerate().collect();
        run(4, items, |(i, chunk)| {
            for (j, v) in chunk.iter_mut().enumerate() {
                *v = (i * 8 + j) as f32;
            }
        });
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i as f32);
        }
    }

    #[test]
    fn empty_work_list_is_a_no_op() {
        run(4, Vec::<usize>::new(), |_| panic!("no items expected"));
    }

    #[test]
    fn run_with_builds_one_state_per_worker_and_reuses_it() {
        for threads in [1usize, 3, 8] {
            let inits = AtomicUsize::new(0);
            let touched = AtomicUsize::new(0);
            let items: Vec<usize> = (0..64).collect();
            run_with(
                threads,
                items,
                || {
                    inits.fetch_add(1, Ordering::Relaxed);
                    Vec::<usize>::new() // per-worker arena
                },
                |arena, item| {
                    arena.push(item); // grows only within one worker
                    touched.fetch_add(1, Ordering::Relaxed);
                },
            );
            let n_inits = inits.load(Ordering::Relaxed);
            assert!(
                n_inits >= 1 && n_inits <= threads.max(1),
                "threads={threads}: {n_inits} states built"
            );
            assert_eq!(touched.load(Ordering::Relaxed), 64, "threads={threads}");
        }
    }

    #[test]
    fn run_with_sequential_path_reuses_a_single_state() {
        // threads = 1 must run inline: exactly one init, items in order
        let mut seen = Vec::new();
        {
            let seen_cell = std::sync::Mutex::new(&mut seen);
            run_with(
                1,
                (0..10).collect::<Vec<usize>>(),
                || 0usize,
                |state, item| {
                    *state += 1;
                    seen_cell.lock().unwrap().push((item, *state));
                },
            );
        }
        let want: Vec<(usize, usize)> = (0..10).map(|i| (i, i + 1)).collect();
        assert_eq!(seen, want);
    }

    #[test]
    fn default_threads_positive() {
        assert!(default_threads() >= 1);
    }

    #[test]
    fn worker_stats_accumulate_busy_counts_across_drains() {
        // counters are process-global and other tests drain pools in
        // parallel, so assert on deltas and with >= not ==
        let before: u64 = worker_stats().iter().map(|(b, _)| b).sum();
        run(1, (0..17usize).collect(), |_| {});
        run(4, (0..23usize).collect(), |_| {});
        let after: u64 = worker_stats().iter().map(|(b, _)| b).sum();
        assert!(
            after - before >= 40,
            "expected at least 40 new busy counts, got {}",
            after - before
        );
        let stats = worker_stats();
        assert_eq!(stats.len(), TRACKED_WORKERS);
        for (busy, steals) in &stats {
            assert!(steals <= busy, "a worker cannot steal more tasks than it ran");
        }
    }
}
