//! Scoped-thread worker pool (std only — no rayon offline).
//!
//! [`run`] drains an explicit work list through `threads` scoped workers
//! pulling from a shared queue, so uneven task costs (e.g. MRA-2 query
//! blocks with different refined-tile counts) self-balance.  Tasks carry
//! their own disjoint `&mut` output shards, which keeps the whole scheme
//! safe-Rust: no worker ever aliases another worker's output.

use std::sync::Mutex;

/// Default worker count: the machine's available parallelism.
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Run `f` over every item using up to `threads` scoped workers.
///
/// Items are pulled from a shared queue (work stealing by contention);
/// with `threads <= 1` everything runs inline on the caller's thread, so
/// the sequential path has zero synchronization overhead.
pub fn run<T: Send>(threads: usize, items: Vec<T>, f: impl Fn(T) + Sync) {
    let workers = threads.max(1).min(items.len().max(1));
    if workers <= 1 {
        for item in items {
            f(item);
        }
        return;
    }
    let queue = Mutex::new(items.into_iter());
    let queue = &queue;
    let f = &f;
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(move || loop {
                let item = queue.lock().unwrap().next();
                match item {
                    Some(item) => f(item),
                    None => break,
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_every_item_exactly_once_at_any_thread_count() {
        for threads in [1, 2, 4, 8, 32] {
            let sum = AtomicUsize::new(0);
            let count = AtomicUsize::new(0);
            let items: Vec<usize> = (1..=100).collect();
            run(threads, items, |i| {
                sum.fetch_add(i, Ordering::Relaxed);
                count.fetch_add(1, Ordering::Relaxed);
            });
            assert_eq!(sum.load(Ordering::Relaxed), 5050, "threads={threads}");
            assert_eq!(count.load(Ordering::Relaxed), 100, "threads={threads}");
        }
    }

    #[test]
    fn disjoint_mut_shards_are_safe() {
        let mut out = vec![0.0f32; 64];
        let items: Vec<(usize, &mut [f32])> = out.chunks_mut(8).enumerate().collect();
        run(4, items, |(i, chunk)| {
            for (j, v) in chunk.iter_mut().enumerate() {
                *v = (i * 8 + j) as f32;
            }
        });
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i as f32);
        }
    }

    #[test]
    fn empty_work_list_is_a_no_op() {
        run(4, Vec::<usize>::new(), |_| panic!("no items expected"));
    }

    #[test]
    fn default_threads_positive() {
        assert!(default_threads() >= 1);
    }
}
