//! Attention kernels the engine schedules: the paper's MRA-2 / MRA-2-s fast
//! path (query-block sharded), exact attention (row sharded), and
//! `mra_adapter`-style shims that lift any [`AttentionApprox`] baseline into
//! the batched engine.

// compute_range carries the full (plan, q, k, v, range, out) context
#![allow(clippy::too_many_arguments)]

use std::any::Any;

use anyhow::{bail, Result};

use crate::baselines::longformer::Longformer;
use crate::baselines::nystromformer::Nystromformer;
use crate::baselines::AttentionApprox;
use crate::engine::tensor4::MatView;
use crate::mra::{mra2_apply_blocks, mra2_plan, Causality, Mra2Plan, Mra2Scratch, Variant};
use crate::tensor::mat::dot;

/// Opaque per-head state produced by [`AttnKernel::plan_head`] and shared
/// read-only by every row shard of that head.
pub type HeadPlan = Box<dyn Any + Send + Sync>;

/// Opaque per-worker scratch produced by [`AttnKernel::make_scratch`]:
/// each pool worker owns one for the whole compute-phase drain
/// (`pool::run_with`), so per-shard transients (tile buffers, score rows)
/// are allocated once per worker instead of once per call.
pub type KernelScratch = Box<dyn Any + Send>;

/// A batched attention kernel: computes `Z_hat ~ softmax(QK^T/sqrt(d)) V`
/// for one `(batch, head)` pair, optionally split into independent
/// query-row ranges so the engine can parallelize *within* a head.
pub trait AttnKernel: Send + Sync {
    /// Display name including budget knobs (for bench tables).
    fn name(&self) -> String;

    /// Row granularity when one head is split across workers; `None` means
    /// the head must be computed whole (single shard).
    fn shard_rows(&self, _n: usize) -> Option<usize> {
        None
    }

    /// Precompute per-head state (selection, pooling, ...) shared by every
    /// shard.  Kernels without shared state return the default `()` plan.
    fn plan_head(&self, _q: MatView, _k: MatView, _v: MatView) -> HeadPlan {
        Box::new(())
    }

    /// Build one per-worker scratch arena (reused across every shard the
    /// worker claims).  Kernels without transients return the default `()`.
    fn make_scratch(&self) -> KernelScratch {
        Box::new(())
    }

    /// Compute the row-normalized output rows `[r0, r1)` of one head into
    /// `out` (length `(r1 - r0) * d`, zero-initialized by the engine),
    /// using the worker's `scratch` (from [`AttnKernel::make_scratch`])
    /// for all transient state.
    #[allow(clippy::too_many_arguments)]
    fn compute_range(
        &self,
        plan: &HeadPlan,
        q: MatView,
        k: MatView,
        v: MatView,
        r0: usize,
        r1: usize,
        out: &mut [f32],
        scratch: &mut KernelScratch,
    );
}

/// The paper's MRA-2 / MRA-2-s fast path.  Plans once per head (pyramid +
/// Alg. 1 selection), then computes query blocks independently — the
/// per-block loop in `mra::attention` is embarrassingly parallel once the
/// output is sharded by query block, and the parallel result is bitwise
/// identical to the sequential one.
pub struct Mra2Kernel {
    pub block: usize,
    /// Refinement budget `m` (coverage rule may refine more; see
    /// [`mra2_plan`]).
    pub m: usize,
    pub variant: Variant,
    /// Bidirectional (MLM) or causal (autoregressive) plan path.
    pub causality: Causality,
}

impl Mra2Kernel {
    pub fn new(block: usize, m: usize, variant: Variant) -> Self {
        Mra2Kernel { block, m, variant, causality: Causality::Bidirectional }
    }

    /// Causal MRA-2: lower-triangular selection + masked diagonal tiles
    /// (DESIGN.md §7).
    pub fn new_causal(block: usize, m: usize, variant: Variant) -> Self {
        Mra2Kernel { block, m, variant, causality: Causality::Causal }
    }

    fn clamped_block(&self, n: usize) -> usize {
        self.block.min(n).max(1)
    }
}

impl AttnKernel for Mra2Kernel {
    fn name(&self) -> String {
        let mut tag = String::from("mra-2");
        if self.variant == Variant::Sparse {
            tag.push_str("-s");
        }
        if self.causality == Causality::Causal {
            tag.push_str("-causal");
        }
        format!("{tag}(b={},m={})", self.block, self.m)
    }

    fn shard_rows(&self, n: usize) -> Option<usize> {
        Some(self.clamped_block(n))
    }

    fn plan_head(&self, q: MatView, k: MatView, v: MatView) -> HeadPlan {
        let block = self.clamped_block(q.rows);
        Box::new(mra2_plan(
            q.data,
            k.data,
            v.data,
            q.rows,
            q.cols,
            block,
            self.m,
            self.variant,
            self.causality,
        ))
    }

    fn make_scratch(&self) -> KernelScratch {
        Box::new(Mra2Scratch::new())
    }

    fn compute_range(
        &self,
        plan: &HeadPlan,
        q: MatView,
        _k: MatView,
        _v: MatView,
        r0: usize,
        r1: usize,
        out: &mut [f32],
        scratch: &mut KernelScratch,
    ) {
        let plan = plan.downcast_ref::<Mra2Plan>().expect("Mra2Kernel plan");
        let scratch = scratch.downcast_mut::<Mra2Scratch>().expect("Mra2Kernel scratch");
        let b = plan.block;
        debug_assert!(r0 % b == 0 && r1 % b == 0, "shard not block-aligned");
        // K/V are read from the plan's packed panels, not the raw views
        mra2_apply_blocks(plan, q.data, r0 / b, r1 / b, out, scratch);
    }
}

/// Exact softmax attention, sharded by query rows (each row's softmax and
/// value aggregation is independent).
pub struct ExactKernel;

impl AttnKernel for ExactKernel {
    fn name(&self) -> String {
        "transformer(exact)".to_string()
    }

    fn shard_rows(&self, n: usize) -> Option<usize> {
        Some(64.min(n).max(1))
    }

    fn make_scratch(&self) -> KernelScratch {
        Box::new(Vec::<f32>::new())
    }

    fn compute_range(
        &self,
        _plan: &HeadPlan,
        q: MatView,
        k: MatView,
        v: MatView,
        r0: usize,
        r1: usize,
        out: &mut [f32],
        scratch: &mut KernelScratch,
    ) {
        let n = k.rows;
        let d = v.cols;
        let inv_sqrt_d = 1.0 / (q.cols as f32).sqrt();
        let scores = scratch.downcast_mut::<Vec<f32>>().expect("ExactKernel scratch");
        scores.resize(n, 0.0); // every entry is overwritten below
        for i in r0..r1 {
            let qrow = q.row(i);
            let mut mx = f32::NEG_INFINITY;
            for (j, s) in scores.iter_mut().enumerate() {
                *s = dot(qrow, k.row(j)) * inv_sqrt_d;
                if *s > mx {
                    mx = *s;
                }
            }
            let orow = &mut out[(i - r0) * d..(i - r0 + 1) * d];
            orow.fill(0.0);
            let mut den = 0.0f32;
            for (j, &s) in scores.iter().enumerate() {
                let a = (s - mx).exp();
                den += a;
                for (o, &vv) in orow.iter_mut().zip(v.row(j)) {
                    *o += a * vv;
                }
            }
            let inv = 1.0 / den.max(1e-30);
            for o in orow.iter_mut() {
                *o *= inv;
            }
        }
    }
}

/// Exact causal softmax attention (query row `i` attends keys `j <= i`),
/// sharded by query rows — the decode-path baseline and the reference for
/// the causal MRA-2 kernels.
pub struct CausalExactKernel;

impl AttnKernel for CausalExactKernel {
    fn name(&self) -> String {
        "transformer(exact-causal)".to_string()
    }

    fn shard_rows(&self, n: usize) -> Option<usize> {
        Some(64.min(n).max(1))
    }

    fn make_scratch(&self) -> KernelScratch {
        Box::new(Vec::<f32>::new())
    }

    fn compute_range(
        &self,
        _plan: &HeadPlan,
        q: MatView,
        k: MatView,
        v: MatView,
        r0: usize,
        r1: usize,
        out: &mut [f32],
        scratch: &mut KernelScratch,
    ) {
        let d = v.cols;
        let inv_sqrt_d = 1.0 / (q.cols as f32).sqrt();
        let scores = scratch.downcast_mut::<Vec<f32>>().expect("CausalExactKernel scratch");
        scores.resize(k.rows, 0.0); // entries [0, i] overwritten before use
        for i in r0..r1 {
            let qrow = q.row(i);
            let mut mx = f32::NEG_INFINITY;
            for (j, s) in scores.iter_mut().enumerate().take(i + 1) {
                *s = dot(qrow, k.row(j)) * inv_sqrt_d;
                if *s > mx {
                    mx = *s;
                }
            }
            let orow = &mut out[(i - r0) * d..(i - r0 + 1) * d];
            orow.fill(0.0);
            let mut den = 0.0f32;
            for (j, &s) in scores.iter().enumerate().take(i + 1) {
                let a = (s - mx).exp();
                den += a;
                for (o, &vv) in orow.iter_mut().zip(v.row(j)) {
                    *o += a * vv;
                }
            }
            let inv = 1.0 / den.max(1e-30);
            for o in orow.iter_mut() {
                *o *= inv;
            }
        }
    }
}

/// Lift any [`AttentionApprox`] baseline into the engine (whole-head
/// granularity: baselines parallelize across `(batch, head)` pairs only).
pub struct ApproxShim<A: AttentionApprox + Send + Sync> {
    pub inner: A,
}

impl<A: AttentionApprox + Send + Sync> ApproxShim<A> {
    pub fn new(inner: A) -> Self {
        ApproxShim { inner }
    }
}

impl<A: AttentionApprox + Send + Sync> AttnKernel for ApproxShim<A> {
    fn name(&self) -> String {
        self.inner.name()
    }

    fn compute_range(
        &self,
        _plan: &HeadPlan,
        q: MatView,
        k: MatView,
        v: MatView,
        r0: usize,
        r1: usize,
        out: &mut [f32],
        _scratch: &mut KernelScratch,
    ) {
        assert!(r0 == 0 && r1 == q.rows, "approx shims compute whole heads");
        let z = self.inner.compute(&q.to_mat(), &k.to_mat(), &v.to_mat());
        out.copy_from_slice(&z.data);
    }
}

/// Every short name [`kernel_by_name`] accepts (bench/CLI discovery).
pub const KERNEL_NAMES: [&str; 8] = [
    "exact",
    "exact-causal",
    "mra2",
    "mra2s",
    "mra2-causal",
    "mra2s-causal",
    "longformer",
    "nystromformer",
];

/// Construct a kernel by short name (see [`KERNEL_NAMES`]) with MRA-style
/// `block` / `m` knobs.  Unknown names return a descriptive error listing
/// the known suite — config typos surface at construction time instead of
/// an uninformative `unwrap` panic downstream.
pub fn kernel_by_name(name: &str, block: usize, m: usize) -> Result<Box<dyn AttnKernel>> {
    Ok(match name {
        "exact" => Box::new(ExactKernel),
        "exact-causal" => Box::new(CausalExactKernel),
        "mra2" => Box::new(Mra2Kernel::new(block, m, Variant::Full)),
        "mra2s" => Box::new(Mra2Kernel::new(block, m, Variant::Sparse)),
        "mra2-causal" => Box::new(Mra2Kernel::new_causal(block, m, Variant::Full)),
        "mra2s-causal" => Box::new(Mra2Kernel::new_causal(block, m, Variant::Sparse)),
        // §bugfix: the `m` budget knob used to be silently dropped for the
        // baseline shims (budgets were hard-coded from `block` alone) — a
        // sweep over m produced identical longformer/nystromformer rows.
        // `m` now maps onto each baseline's own budget axis: longformer's
        // global-token count and nystromformer's landmark count (its rank
        // budget, floored for pseudo-inverse stability); `block` keeps
        // setting the longformer window, its geometric analog.
        "longformer" => Box::new(ApproxShim::new(Longformer::new(block.max(4), m.max(1)))),
        "nystromformer" => Box::new(ApproxShim::new(Nystromformer::new(m.max(8), 6))),
        other => bail!(
            "unknown attention kernel {other:?}; known kernels: {}",
            KERNEL_NAMES.join(", ")
        ),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kernel_by_name_covers_the_suite() {
        for name in KERNEL_NAMES {
            let k = kernel_by_name(name, 16, 8).unwrap_or_else(|e| panic!("{e}"));
            assert!(!k.name().is_empty());
        }
    }

    #[test]
    fn kernel_by_name_rejects_unknown_names_with_a_useful_error() {
        // regression: kernel_by_name used to return Option, so unknown
        // names surfaced as an uninformative unwrap panic at the caller
        let err = kernel_by_name("no-such-kernel", 16, 8).err().expect("must fail");
        let msg = format!("{err:#}");
        assert!(msg.contains("no-such-kernel"), "{msg}");
        assert!(msg.contains("mra2-causal"), "should list the known suite: {msg}");
    }

    #[test]
    fn causal_kernel_names_are_tagged() {
        assert!(Mra2Kernel::new_causal(16, 8, Variant::Full).name().contains("-causal"));
        assert!(CausalExactKernel.name().contains("exact-causal"));
        assert!(!Mra2Kernel::new(16, 8, Variant::Full).name().contains("causal"));
    }

    #[test]
    fn shim_kernels_thread_the_m_budget_knob() {
        // §bugfix regression: `m` used to be silently ignored for the
        // baseline shims, so a budget sweep produced identical rows.  The
        // knob must now be observable through the constructed kernel.
        let lo = kernel_by_name("longformer", 16, 1).unwrap();
        let hi = kernel_by_name("longformer", 16, 6).unwrap();
        assert_ne!(lo.name(), hi.name(), "longformer must report the threaded budget");
        assert!(hi.name().contains("g=6"), "{}", hi.name());
        let lo = kernel_by_name("nystromformer", 16, 16).unwrap();
        let hi = kernel_by_name("nystromformer", 16, 48).unwrap();
        assert_ne!(lo.name(), hi.name(), "nystromformer must report the threaded budget");
        assert!(lo.name().contains("l=16"), "{}", lo.name());
        assert!(hi.name().contains("l=48"), "{}", hi.name());
        // the workload model scales with the knob too (the budget axis)
        assert!(
            Longformer::new(16, 6).workload(256, 32) > Longformer::new(16, 1).workload(256, 32)
        );
        assert!(
            Nystromformer::new(48, 6).workload(256, 32)
                > Nystromformer::new(16, 6).workload(256, 32)
        );
    }

    #[test]
    fn shim_kernels_compute_whole_heads_under_engine_sharding() {
        use crate::engine::{BatchedTensor, Engine};
        use crate::tensor::Rng;
        // §bugfix regression: ApproxShim::compute_range hard-asserts
        // whole-head ranges while the engine shards by shard_rows(n) —
        // every shim must keep the default shard_rows == None (one shard
        // per head), including at n not divisible by the block knob, or
        // the multi-threaded engine trips the assert
        let mut rng = Rng::new(17);
        let n = 50; // not divisible by block 16 or the derived budgets
        let q = BatchedTensor::randn(2, 2, n, 8, 1.0, &mut rng);
        let k = BatchedTensor::randn(2, 2, n, 8, 1.0, &mut rng);
        let v = BatchedTensor::randn(2, 2, n, 8, 1.0, &mut rng);
        for name in ["longformer", "nystromformer"] {
            let kernel = kernel_by_name(name, 16, 8).unwrap();
            assert!(kernel.shard_rows(n).is_none(), "{name} must compute whole heads");
            let engine = Engine::new(kernel, 4);
            let out = engine.forward(&q, &k, &v);
            assert_eq!(out.shape(), (2, 2, n, 8));
            assert!(
                out.data.iter().all(|x| x.is_finite()),
                "{name} produced non-finite output"
            );
        }
    }

    #[test]
    fn mra2_kernel_shards_align_to_blocks() {
        let k = Mra2Kernel::new(32, 8, Variant::Full);
        assert_eq!(k.shard_rows(256), Some(32));
        // block clamps to n for short sequences
        assert_eq!(k.shard_rows(16), Some(16));
    }
}
