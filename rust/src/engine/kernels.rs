//! Attention kernels the engine schedules: the paper's MRA-2 / MRA-2-s fast
//! path (query-block sharded), exact attention (row sharded), and
//! `mra_adapter`-style shims that lift any [`AttentionApprox`] baseline into
//! the batched engine.

// compute_range carries the full (plan, q, k, v, range, out) context
#![allow(clippy::too_many_arguments)]

use std::any::Any;

use crate::baselines::longformer::Longformer;
use crate::baselines::nystromformer::Nystromformer;
use crate::baselines::AttentionApprox;
use crate::engine::tensor4::MatView;
use crate::mra::{mra2_apply_blocks, mra2_plan, Mra2Plan, Variant};
use crate::tensor::mat::dot;

/// Opaque per-head state produced by [`AttnKernel::plan_head`] and shared
/// read-only by every row shard of that head.
pub type HeadPlan = Box<dyn Any + Send + Sync>;

/// A batched attention kernel: computes `Z_hat ~ softmax(QK^T/sqrt(d)) V`
/// for one `(batch, head)` pair, optionally split into independent
/// query-row ranges so the engine can parallelize *within* a head.
pub trait AttnKernel: Send + Sync {
    /// Display name including budget knobs (for bench tables).
    fn name(&self) -> String;

    /// Row granularity when one head is split across workers; `None` means
    /// the head must be computed whole (single shard).
    fn shard_rows(&self, _n: usize) -> Option<usize> {
        None
    }

    /// Precompute per-head state (selection, pooling, ...) shared by every
    /// shard.  Kernels without shared state return the default `()` plan.
    fn plan_head(&self, _q: MatView, _k: MatView, _v: MatView) -> HeadPlan {
        Box::new(())
    }

    /// Compute the row-normalized output rows `[r0, r1)` of one head into
    /// `out` (length `(r1 - r0) * d`, zero-initialized by the engine).
    fn compute_range(
        &self,
        plan: &HeadPlan,
        q: MatView,
        k: MatView,
        v: MatView,
        r0: usize,
        r1: usize,
        out: &mut [f32],
    );
}

/// The paper's MRA-2 / MRA-2-s fast path.  Plans once per head (pyramid +
/// Alg. 1 selection), then computes query blocks independently — the
/// per-block loop in `mra::attention` is embarrassingly parallel once the
/// output is sharded by query block, and the parallel result is bitwise
/// identical to the sequential one.
pub struct Mra2Kernel {
    pub block: usize,
    /// Refinement budget `m` (coverage rule may refine more; see
    /// [`mra2_plan`]).
    pub m: usize,
    pub variant: Variant,
}

impl Mra2Kernel {
    pub fn new(block: usize, m: usize, variant: Variant) -> Self {
        Mra2Kernel { block, m, variant }
    }

    fn clamped_block(&self, n: usize) -> usize {
        self.block.min(n).max(1)
    }
}

impl AttnKernel for Mra2Kernel {
    fn name(&self) -> String {
        format!(
            "mra-2{}(b={},m={})",
            if self.variant == Variant::Sparse { "-s" } else { "" },
            self.block,
            self.m
        )
    }

    fn shard_rows(&self, n: usize) -> Option<usize> {
        Some(self.clamped_block(n))
    }

    fn plan_head(&self, q: MatView, k: MatView, v: MatView) -> HeadPlan {
        let block = self.clamped_block(q.rows);
        Box::new(mra2_plan(q.data, k.data, v.data, q.rows, q.cols, block, self.m, self.variant))
    }

    fn compute_range(
        &self,
        plan: &HeadPlan,
        q: MatView,
        k: MatView,
        v: MatView,
        r0: usize,
        r1: usize,
        out: &mut [f32],
    ) {
        let plan = plan.downcast_ref::<Mra2Plan>().expect("Mra2Kernel plan");
        let b = plan.block;
        debug_assert!(r0 % b == 0 && r1 % b == 0, "shard not block-aligned");
        mra2_apply_blocks(plan, q.data, k.data, v.data, r0 / b, r1 / b, out);
    }
}

/// Exact softmax attention, sharded by query rows (each row's softmax and
/// value aggregation is independent).
pub struct ExactKernel;

impl AttnKernel for ExactKernel {
    fn name(&self) -> String {
        "transformer(exact)".to_string()
    }

    fn shard_rows(&self, n: usize) -> Option<usize> {
        Some(64.min(n).max(1))
    }

    fn compute_range(
        &self,
        _plan: &HeadPlan,
        q: MatView,
        k: MatView,
        v: MatView,
        r0: usize,
        r1: usize,
        out: &mut [f32],
    ) {
        let n = k.rows;
        let d = v.cols;
        let inv_sqrt_d = 1.0 / (q.cols as f32).sqrt();
        let mut scores = vec![0.0f32; n];
        for i in r0..r1 {
            let qrow = q.row(i);
            let mut mx = f32::NEG_INFINITY;
            for (j, s) in scores.iter_mut().enumerate() {
                *s = dot(qrow, k.row(j)) * inv_sqrt_d;
                if *s > mx {
                    mx = *s;
                }
            }
            let orow = &mut out[(i - r0) * d..(i - r0 + 1) * d];
            orow.fill(0.0);
            let mut den = 0.0f32;
            for (j, &s) in scores.iter().enumerate() {
                let a = (s - mx).exp();
                den += a;
                for (o, &vv) in orow.iter_mut().zip(v.row(j)) {
                    *o += a * vv;
                }
            }
            let inv = 1.0 / den.max(1e-30);
            for o in orow.iter_mut() {
                *o *= inv;
            }
        }
    }
}

/// Lift any [`AttentionApprox`] baseline into the engine (whole-head
/// granularity: baselines parallelize across `(batch, head)` pairs only).
pub struct ApproxShim<A: AttentionApprox + Send + Sync> {
    pub inner: A,
}

impl<A: AttentionApprox + Send + Sync> ApproxShim<A> {
    pub fn new(inner: A) -> Self {
        ApproxShim { inner }
    }
}

impl<A: AttentionApprox + Send + Sync> AttnKernel for ApproxShim<A> {
    fn name(&self) -> String {
        self.inner.name()
    }

    fn compute_range(
        &self,
        _plan: &HeadPlan,
        q: MatView,
        k: MatView,
        v: MatView,
        r0: usize,
        r1: usize,
        out: &mut [f32],
    ) {
        assert!(r0 == 0 && r1 == q.rows, "approx shims compute whole heads");
        let z = self.inner.compute(&q.to_mat(), &k.to_mat(), &v.to_mat());
        out.copy_from_slice(&z.data);
    }
}

/// Construct a kernel by short name (`exact`, `mra2`, `mra2s`,
/// `longformer`, `nystromformer`) with MRA-style `block` / `m` knobs.
pub fn kernel_by_name(name: &str, block: usize, m: usize) -> Option<Box<dyn AttnKernel>> {
    match name {
        "exact" => Some(Box::new(ExactKernel)),
        "mra2" => Some(Box::new(Mra2Kernel::new(block, m, Variant::Full))),
        "mra2s" => Some(Box::new(Mra2Kernel::new(block, m, Variant::Sparse))),
        "longformer" => Some(Box::new(ApproxShim::new(Longformer::new(block.max(4), 1)))),
        "nystromformer" => {
            Some(Box::new(ApproxShim::new(Nystromformer::new((2 * block).max(8), 6))))
        }
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kernel_by_name_covers_the_suite() {
        for name in ["exact", "mra2", "mra2s", "longformer", "nystromformer"] {
            let k = kernel_by_name(name, 16, 8).unwrap_or_else(|| panic!("missing {name}"));
            assert!(!k.name().is_empty());
        }
        assert!(kernel_by_name("no-such-kernel", 16, 8).is_none());
    }

    #[test]
    fn mra2_kernel_shards_align_to_blocks() {
        let k = Mra2Kernel::new(32, 8, Variant::Full);
        assert_eq!(k.shard_rows(256), Some(32));
        // block clamps to n for short sequences
        assert_eq!(k.shard_rows(16), Some(16));
    }
}
