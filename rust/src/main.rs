//! `mra` — coordinator CLI for the MRA-attention reproduction.
//!
//! Subcommands:
//!
//! * `serve`   — start the serving coordinator and run a self-test load.
//! * `train`   — run the MLM training driver over an AOT train_step.
//! * `lra`     — train + evaluate the LRA-analog classifier tasks (Tab. 5).
//! * `table`   — scaled reproductions of Tables 1/2/4/6 rows.
//! * `fig3`    — ASCII visualization of progressive refinement (Fig. 3/6).
//! * `info`    — list artifacts and model configs.
//!
//! Bench-table reproductions of Fig. 4/5/7/8 + Tab. 7 live in
//! `cargo bench` targets (see EXPERIMENTS.md).

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use mra::cli::Args;
use mra::config::{Config, ServeConfig, TrainConfig};
use mra::coordinator::{Server, Trainer};
use mra::data::lra::{LraTask, CLASSES};
use mra::data::Corpus;
use mra::runtime::{self, HostTensor};
use mra::tensor::{ops, Mat, Rng};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args = Args::from_env()?;
    match args.subcommand() {
        Some("serve") => cmd_serve(&args),
        Some("train") => cmd_train(&args),
        Some("lra") => cmd_lra(&args),
        Some("table") => cmd_table(&args),
        Some("fig3") => cmd_fig3(&args),
        Some("info") => cmd_info(&args),
        other => {
            if let Some(o) = other {
                eprintln!("unknown subcommand {o:?}\n");
            }
            println!(
                "usage: mra <serve|train|lra|table|fig3|info> [--flags]\n\
                 see README.md for details"
            );
            Ok(())
        }
    }
}

fn load_config(args: &Args) -> Result<Config> {
    match args.str_opt("config") {
        Some(path) => Config::load(path),
        None => Ok(Config::default()),
    }
}

fn cmd_serve(args: &Args) -> Result<()> {
    let mut cfg = ServeConfig::from_config(&load_config(args)?)?;
    if let Some(m) = args.str_opt("model") {
        cfg.model = m.to_string();
    }
    cfg.artifacts_dir = args.str_or("artifacts", &cfg.artifacts_dir);
    let requests = args.usize_or("requests", 64)?;
    let (rt, manifest) = runtime::spawn(&cfg.artifacts_dir)?;
    println!("starting server over model {} ({} artifacts)", cfg.model, manifest.artifacts.len());
    let server = Server::start(rt, manifest.clone(), cfg.clone())?;

    // self-test load: concurrent clients with synthetic sequences
    let model_cfg = manifest.load_cfg(&cfg.model)?;
    let seq_len: usize = model_cfg["seq_len"].parse()?;
    let vocab: usize = model_cfg["vocab"].parse()?;
    let server = Arc::new(server);
    let t0 = std::time::Instant::now();
    std::thread::scope(|s| {
        for c in 0..4u64 {
            let server = server.clone();
            s.spawn(move || {
                let mut corpus = Corpus::new(
                    mra::data::CorpusConfig {
                        vocab,
                        seq_len,
                        ..Default::default()
                    },
                    c,
                );
                for _ in 0..requests / 4 {
                    let toks = corpus.sequence();
                    if let Err(e) = server.infer(toks) {
                        eprintln!("client {c}: {e:#}");
                    }
                }
            });
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    println!("{}", server.metrics.summary());
    println!(
        "throughput: {:.1} req/s over {:.2}s",
        requests as f64 / wall,
        wall
    );
    match Arc::try_unwrap(server) {
        Ok(s) => s.shutdown(),
        Err(_) => {}
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let mut cfg = TrainConfig::from_config(&load_config(args)?)?;
    if let Some(m) = args.str_opt("model") {
        cfg.model = m.to_string();
    }
    cfg.steps = args.usize_or("steps", cfg.steps)?;
    cfg.batch = args.usize_or("batch", cfg.batch)?;
    cfg.artifacts_dir = args.str_or("artifacts", &cfg.artifacts_dir);
    let (rt, manifest) = runtime::spawn(&cfg.artifacts_dir)?;
    let mut trainer = Trainer::new(rt, manifest, cfg)?;
    let log = trainer.run()?;
    let (head, tail) = log.head_tail_means(3);
    println!("loss {head:.3} -> {tail:.3} over {} logged points", log.losses.len());
    Ok(())
}

/// Train an LRA-analog classifier from the `cls_*` artifacts and report
/// test accuracy (Table 5 substitute).
fn cmd_lra(args: &Args) -> Result<()> {
    let artifacts_dir = args.str_or("artifacts", "artifacts");
    let steps = args.usize_or("steps", 120)?;
    let attn = args.str_or("attention", "mra2");
    let task_name = args.str_or("task", "listops");
    let tasks: Vec<LraTask> = if task_name == "all" {
        LraTask::all().to_vec()
    } else {
        vec![LraTask::parse(&task_name).context("unknown task")?]
    };
    let (rt, manifest) = runtime::spawn(&artifacts_dir)?;
    for task in tasks {
        let acc = run_lra_task(&rt, &manifest, task, &attn, steps, 0)?;
        println!("lra/{:<10} attention={attn:<6} test-acc {:.3}", task.name(), acc);
    }
    Ok(())
}

/// Shared LRA train/eval loop (also used by `table --id 5`-style runs).
pub fn run_lra_task(
    rt: &runtime::RuntimeHandle,
    manifest: &runtime::Manifest,
    task: LraTask,
    attn: &str,
    steps: usize,
    seed: u64,
) -> Result<f32> {
    let tag = format!("cls_{attn}_n128_d64_l2_h2_v64");
    let batch = 32usize;
    let train_name = format!("train_{tag}_b{batch}");
    let eval_name = format!("eval_{tag}_b{batch}");
    manifest.get(&train_name)?;
    let mut params = manifest.load_f32(&format!("{tag}.params.f32"))?;
    let n = params.len();
    let (mut m, mut v) = (vec![0.0f32; n], vec![0.0f32; n]);
    let mut rng = Rng::new(seed ^ 0x14A);
    let seq = 128usize;
    for step in 0..steps {
        let b = task.batch(batch, seq, &mut rng);
        let inputs = vec![
            HostTensor::F32(params, vec![n]),
            HostTensor::F32(m, vec![n]),
            HostTensor::F32(v, vec![n]),
            HostTensor::scalar_f32(step as f32),
            HostTensor::I32(b.input_ids, vec![batch, seq]),
            HostTensor::I32(b.labels, vec![batch]),
        ];
        let mut out = rt.execute(&train_name, inputs)?;
        let _acc = out.pop().unwrap();
        let loss = out.pop().unwrap();
        v = out.pop().unwrap().as_f32()?.to_vec();
        m = out.pop().unwrap().as_f32()?.to_vec();
        params = out.pop().unwrap().as_f32()?.to_vec();
        if step % 20 == 0 {
            println!("  {} step {step:>4} loss {:.3}", task.name(), loss.as_f32()?[0]);
        }
    }
    // held-out accuracy over a few batches
    let mut eval_rng = Rng::new(seed ^ 0xE7A1);
    let mut acc_sum = 0.0f32;
    let evals = 4;
    for _ in 0..evals {
        let b = task.batch(batch, seq, &mut eval_rng);
        let inputs = vec![
            HostTensor::F32(params.clone(), vec![n]),
            HostTensor::I32(b.input_ids, vec![batch, seq]),
            HostTensor::I32(b.labels, vec![batch]),
        ];
        let out = rt.execute(&eval_name, inputs)?;
        acc_sum += out[1].as_f32()?[0];
    }
    let _ = CLASSES;
    Ok(acc_sum / evals as f32)
}

/// Scaled Table 1/2/4/6 rows: train the small MLM models from scratch for
/// each attention variant and report loss/accuracy + step timing.
fn cmd_table(args: &Args) -> Result<()> {
    let id = args.usize_or("id", 2)?;
    let steps = args.usize_or("steps", 120)?;
    let artifacts_dir = args.str_or("artifacts", "artifacts");
    let (rt, manifest) = runtime::spawn(&artifacts_dir)?;
    match id {
        1 | 2 => {
            println!("== Table {id} (scaled): 128-token MLM from scratch, {steps} steps ==");
            let mut table = mra::bench::Table::new(&[
                "method", "ms/step", "final-loss", "masked-acc",
            ]);
            for attn in ["exact", "mra2", "mra2s"] {
                let cfg = TrainConfig {
                    steps,
                    batch: 32,
                    eval_every: 0,
                    seed: 0,
                    model: format!("mlm_{attn}_n128_d128_l2_h2_v512"),
                    artifacts_dir: artifacts_dir.clone(),
                    log_every: steps.max(1) / 4,
                };
                let mut trainer = Trainer::new(rt.clone(), manifest.clone(), cfg)?;
                let t0 = std::time::Instant::now();
                let log = trainer.run()?;
                let ms = t0.elapsed().as_secs_f64() * 1e3 / steps as f64;
                let (el, ea) = trainer.eval()?;
                let _ = el;
                table.row(&[
                    display_name(attn).into(),
                    format!("{ms:.1}"),
                    format!("{:.3}", log.final_loss()),
                    format!("{ea:.3}"),
                ]);
            }
            table.print();
            // MNLI-analog downstream column (entailment task on the cls
            // artifacts — see data::lra::entailment)
            println!("\n-- MNLI-analog (3-class entailment), {steps} steps --");
            for attn in ["exact", "mra2", "mra2s"] {
                let acc = run_lra_task(
                    &rt, &manifest, LraTask::Entailment, attn, steps, 0)?;
                println!("{:<12} entail-acc {:.3}", display_name(attn), acc);
            }
        }
        3 | 4 => {
            println!("== Table {id} (scaled): 512-token models, fwd latency ==");
            let mut table = mra::bench::Table::new(&["method", "fwd ms (b=1)", "fwd ms (b=4)"]);
            for attn in ["exact", "mra2", "mra2s"] {
                let tag = format!("mlm_{attn}_n512_d128_l2_h2_v512");
                let params = manifest.load_f32(&format!("{tag}.params.f32"))?;
                let mut cells = vec![display_name(attn).to_string()];
                for b in [1usize, 4] {
                    let name = format!("fwd_{tag}_b{b}");
                    rt.warm(&name)?;
                    let ids = vec![2i32; b * 512];
                    let stats = mra::bench::time_it(1, 5, || {
                        let inputs = vec![
                            HostTensor::F32(params.clone(), vec![params.len()]),
                            HostTensor::I32(ids.clone(), vec![b, 512]),
                        ];
                        rt.execute(&name, inputs).expect("exec");
                    });
                    cells.push(format!("{:.1}", stats.mean_ms));
                }
                table.row(&cells);
            }
            table.print();
        }
        5 => {
            println!("== Table 5 (scaled LRA): see `mra lra --task all` ==");
            for attn in ["exact", "mra2", "mra2s"] {
                for task in LraTask::all() {
                    let acc = run_lra_task(&rt, &manifest, task, attn, steps, 0)?;
                    println!("{:<12} {:<10} acc {:.3}", display_name(attn), task.name(), acc);
                }
            }
        }
        6 => {
            println!("== Table 6 (scaled ImageNet-analog): image-grid task ==");
            for attn in ["exact", "mra2", "mra2s"] {
                let acc =
                    run_lra_task(&rt, &manifest, LraTask::ImageGrid, attn, steps, 1)?;
                println!("{:<12} top-1 {:.3}", display_name(attn), acc);
            }
        }
        other => bail!("no table {other}; available: 1,2,3,4,5,6"),
    }
    Ok(())
}

fn display_name(attn: &str) -> &'static str {
    match attn {
        "exact" => "transformer",
        "mra2" => "mra-2",
        "mra2s" => "mra-2-s",
        _ => "?",
    }
}

/// ASCII rendering of the progressive multiresolution refinement (Fig. 3/6).
fn cmd_fig3(args: &Args) -> Result<()> {
    let n = args.usize_or("n", 64)?;
    let mut rng = Rng::new(args.usize_or("seed", 0)? as u64);
    // locality-structured inputs (random walk, keys tracking queries)
    let d = 16;
    let mut q = Mat::zeros(n, d);
    let mut k = Mat::zeros(n, d);
    for i in 0..n {
        for j in 0..d {
            let pq = if i > 0 { q.get(i - 1, j) } else { 0.0 };
            q.set(i, j, 0.9 * pq + 0.5 * rng.normal());
            k.set(i, j, q.get(i, j) + 0.3 * rng.normal());
        }
    }
    let p = ops::scores(&q, &k);
    let a = ops::softmax_rows(&p);
    println!("exact attention (log scale):");
    ascii_heat(&a, 32);
    for (scales, budgets) in [
        (vec![16usize, 4], vec![6usize]),
        (vec![16, 4, 1], vec![6, 24]),
    ] {
        let cfg = mra::mra::MraConfig {
            scales: scales.clone(),
            budgets: budgets.clone(),
            include_diagonal: true,
            variant: mra::mra::Variant::Full,
        };
        let v = Mat::eye(n);
        let z = mra::mra::mra_attention(&q, &k, &v, &cfg);
        println!("\nMRA approximation R={scales:?} budgets={budgets:?}:");
        ascii_heat(&z, 32);
        let exact = ops::exact_attention(&q, &k, &v);
        println!("rel error vs exact: {:.4}", ops::rel_fro_error(&z, &exact));
    }
    Ok(())
}

/// Coarse ASCII heatmap (log scale) of a matrix, downsampled to `px`.
fn ascii_heat(m: &Mat, px: usize) {
    let ramp: &[u8] = b" .:-=+*#%@";
    let step = (m.rows / px).max(1);
    let mut lines = Vec::new();
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    let mut cells = Vec::new();
    for i in (0..m.rows).step_by(step) {
        let mut row = Vec::new();
        for j in (0..m.cols).step_by(step) {
            let mut mx = 0.0f32;
            for a in i..(i + step).min(m.rows) {
                for b in j..(j + step).min(m.cols) {
                    mx = mx.max(m.get(a, b));
                }
            }
            let lg = (mx.max(1e-9)).ln();
            lo = lo.min(lg);
            hi = hi.max(lg);
            row.push(lg);
        }
        cells.push(row);
    }
    for row in cells {
        let mut line = String::new();
        for lg in row {
            let t = ((lg - lo) / (hi - lo).max(1e-6) * (ramp.len() - 1) as f32) as usize;
            line.push(ramp[t.min(ramp.len() - 1)] as char);
        }
        lines.push(line);
    }
    for l in lines {
        println!("  {l}");
    }
}

fn cmd_info(args: &Args) -> Result<()> {
    let dir = args.str_or("artifacts", "artifacts");
    let manifest = runtime::Manifest::load(&dir)?;
    let mut names: Vec<&String> = manifest.artifacts.keys().collect();
    names.sort();
    println!("{} artifacts in {dir}:", names.len());
    for n in names {
        let a = &manifest.artifacts[n.as_str()];
        println!("  {n}  inputs={} outputs={} tag={}", a.inputs.len(), a.n_outputs, a.tag);
    }
    Ok(())
}
