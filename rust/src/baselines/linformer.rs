//! Linformer (Wang et al., 2020): project the *sequence length* dimension
//! of K and V to `p` rows with a (here: fixed random, as at init) linear
//! projection, then run exact attention against the projected keys.

use crate::baselines::AttentionApprox;
use crate::tensor::ops;
use crate::tensor::{Mat, Rng};

pub struct Linformer {
    /// Projection size `p` (the paper's knob; `O(p n)` complexity).
    pub proj: usize,
    pub seed: u64,
}

impl Linformer {
    pub fn new(proj: usize, seed: u64) -> Self {
        Linformer { proj, seed }
    }

    fn projection(&self, n: usize) -> Mat {
        let mut rng = Rng::new(self.seed ^ 0x11f0);
        // E in R^{p x n}, row-stochastic (softmax of Gaussian logits): each
        // projected key/value is a convex combination of tokens.  The
        // Linformer paper *learns* a dense E; an averaging initialization
        // is the standard stand-in and keeps the projected attention on the
        // simplex.  (That Linformer still diverges from exact attention is
        // faithful — Tab. 1 shows it is incompatible with trained weights.)
        let logits = Mat::randn(self.proj, n, 2.0, &mut rng);
        ops::softmax_rows(&logits)
    }
}

impl AttentionApprox for Linformer {
    fn name(&self) -> String {
        format!("linformer(p={})", self.proj)
    }

    fn compute(&self, q: &Mat, k: &Mat, v: &Mat) -> Mat {
        let e = self.projection(k.rows); // (p, n)
        let kp = e.matmul(k); // (p, d)
        let vp = e.matmul(v); // (p, d)
        ops::softmax_rows(&ops::scores(q, &kp)).matmul(&vp)
    }

    fn workload(&self, n: usize, d: usize) -> usize {
        2 * self.proj * n * d + 2 * n * self.proj * d
    }

    fn memory_elems(&self, n: usize, d: usize) -> usize {
        self.proj * n + n * self.proj + 2 * self.proj * d
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn output_shape_and_finite() {
        let mut rng = Rng::new(0);
        let q = Mat::randn(64, 8, 1.0, &mut rng);
        let k = Mat::randn(64, 8, 1.0, &mut rng);
        let v = Mat::randn(64, 8, 1.0, &mut rng);
        let z = Linformer::new(16, 1).compute(&q, &k, &v);
        assert_eq!((z.rows, z.cols), (64, 8));
        assert!(z.data.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn deterministic_given_seed() {
        let mut rng = Rng::new(1);
        let q = Mat::randn(32, 4, 1.0, &mut rng);
        let k = Mat::randn(32, 4, 1.0, &mut rng);
        let v = Mat::randn(32, 4, 1.0, &mut rng);
        let z1 = Linformer::new(8, 7).compute(&q, &k, &v);
        let z2 = Linformer::new(8, 7).compute(&q, &k, &v);
        assert_eq!(z1, z2);
    }

    #[test]
    fn bigger_projection_reduces_error_on_average() {
        let mut rng = Rng::new(2);
        let (mut e_small, mut e_big) = (0.0, 0.0);
        for seed in 0..5 {
            let q = Mat::randn(64, 8, 0.4, &mut rng);
            let k = Mat::randn(64, 8, 0.4, &mut rng);
            let v = Mat::randn(64, 8, 1.0, &mut rng);
            let exact = ops::exact_attention(&q, &k, &v);
            e_small += ops::rel_fro_error(
                &Linformer::new(4, seed).compute(&q, &k, &v), &exact);
            e_big += ops::rel_fro_error(
                &Linformer::new(48, seed).compute(&q, &k, &v), &exact);
        }
        assert!(e_big < e_small, "{e_big} vs {e_small}");
    }
}
