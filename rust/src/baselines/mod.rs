//! Every efficient-attention comparator in the paper's evaluation,
//! implemented from scratch on the shared [`crate::tensor`] substrate so
//! that the Fig. 4 / Fig. 5 / Fig. 7 / Tab. 7 comparisons run on identical
//! footing.
//!
//! | paper baseline | module |
//! |---|---|
//! | Transformer (exact) | [`exact`] |
//! | optimal sparsity / optimal low rank (Fig. 1/7) | [`optimal`] |
//! | Linformer | [`linformer`] |
//! | Performer (FAVOR+) | [`performer`] |
//! | Nyströmformer | [`nystromformer`] |
//! | Longformer (sliding window + global) | [`longformer`] |
//! | Big Bird (window + global + random) | [`bigbird`] |
//! | Reformer (LSH buckets) | [`reformer`] |
//! | H-Transformer-1D (hierarchical) | [`h1d`] |
//! | Scatterbrain (sparse + low rank) | [`scatterbrain`] |
//! | MRA-2 / MRA-2-s (ours) | [`mra_adapter`] |

pub mod bigbird;
pub mod exact;
pub mod h1d;
pub mod linformer;
pub mod longformer;
pub mod mra_adapter;
pub mod nystromformer;
pub mod optimal;
pub mod performer;
pub mod reformer;
pub mod scatterbrain;

use crate::tensor::Mat;

/// A self-attention approximator: maps `(Q, K, V)` (single head, `n x d`)
/// to the row-normalized output `Z_hat ~ softmax(QK^T/sqrt(d)) V`.
pub trait AttentionApprox {
    /// Display name including the budget knob (for bench tables).
    fn name(&self) -> String;

    /// Compute the approximate attention output.
    fn compute(&self, q: &Mat, k: &Mat, v: &Mat) -> Mat;

    /// Theoretical multiply–accumulate workload (Fig. 7 left).
    fn workload(&self, n: usize, d: usize) -> usize;

    /// Transient memory footprint estimate in f32 elements (Tab. 7 Mem).
    fn memory_elems(&self, n: usize, d: usize) -> usize;
}

/// All baselines at one representative budget (entropy/fig-5 style runs).
pub fn default_suite(n: usize, seed: u64) -> Vec<Box<dyn AttentionApprox>> {
    let w = (n / 16).max(8);
    vec![
        Box::new(exact::Exact),
        Box::new(linformer::Linformer::new(w * 2, seed)),
        Box::new(performer::Performer::new(w * 2, seed)),
        Box::new(nystromformer::Nystromformer::new(w.min(64), 6)),
        Box::new(longformer::Longformer::new(w, 1)),
        Box::new(bigbird::BigBird::new(w / 2, 1, 2, seed)),
        Box::new(reformer::Reformer::new((n / w).max(2), 2, seed)),
        Box::new(h1d::HTransformer1d::new(16)),
        Box::new(scatterbrain::Scatterbrain::new(w, w * 2, seed)),
        Box::new(mra_adapter::Mra2::new(32, n / 8, false)),
        Box::new(mra_adapter::Mra2::new(32, n / 8, true)),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{ops, Rng};

    /// Every baseline must (a) produce finite outputs, (b) map all-ones V
    /// to (approximately) all-ones — i.e. its rows are (near-)convex
    /// combinations of the values.
    #[test]
    fn suite_smoke_all_methods() {
        let n = 128;
        let mut rng = Rng::new(0);
        let q = Mat::randn(n, 16, 1.0, &mut rng);
        let k = Mat::randn(n, 16, 1.0, &mut rng);
        let ones = Mat::full(n, 16, 1.0);
        for method in default_suite(n, 7) {
            let z = method.compute(&q, &k, &ones);
            assert_eq!((z.rows, z.cols), (n, 16), "{}", method.name());
            let bad = z.data.iter().filter(|v| !v.is_finite()).count();
            assert_eq!(bad, 0, "{} produced non-finite", method.name());
            // convexity is exact for kernel/sparse methods, approximate for
            // low-rank projections — allow a loose band
            let mean: f32 = z.data.iter().sum::<f32>() / z.data.len() as f32;
            assert!((mean - 1.0).abs() < 0.35, "{}: mean {}", method.name(), mean);
        }
    }

    /// Sanity ordering: on locality-structured inputs every method should
    /// stay within a loose error band of exact attention.
    #[test]
    fn suite_errors_bounded() {
        let n = 128;
        let mut rng = Rng::new(1);
        // locality-structured Q, K: random-walk rows with keys tracking
        // queries (diagonally dominant attention, the common trained-model
        // pattern every baseline is designed around)
        let mut q = Mat::zeros(n, 16);
        let mut k = Mat::zeros(n, 16);
        for i in 0..n {
            for j in 0..16 {
                let prev_q = if i > 0 { q.get(i - 1, j) } else { 0.0 };
                q.set(i, j, 0.9 * prev_q + 0.6 * rng.normal());
                k.set(i, j, q.get(i, j) + 0.3 * rng.normal());
            }
        }
        let v = Mat::randn(n, 16, 1.0, &mut rng);
        let z_exact = ops::exact_attention(&q, &k, &v);
        for method in default_suite(n, 7) {
            let z = method.compute(&q, &k, &v);
            let err = ops::rel_fro_error(&z, &z_exact);
            assert!(err < 1.5, "{}: err {}", method.name(), err);
        }
    }

    #[test]
    fn workload_and_memory_positive() {
        for method in default_suite(256, 3) {
            assert!(method.workload(256, 64) > 0, "{}", method.name());
            assert!(method.memory_elems(256, 64) > 0, "{}", method.name());
        }
    }
}
