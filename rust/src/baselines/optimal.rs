//! "Best possible" sparse and low-rank approximators (Fig. 1, Fig. 7, §A.2).
//!
//! These set efficiency aside and use the *optimal* approximation of each
//! family: top-|entries| support for sparsity, truncated SVD for low rank.
//! They bound what any practical method of that family can achieve.

use crate::baselines::AttentionApprox;
use crate::tensor::{ops, svd, topk, Mat, Rng};

/// `exp(P - max(P))` — globally rescaled unnormalized attention (the shift
/// cancels under row normalization but keeps f32 finite on peaked scores).
fn stab_exp(p: &Mat) -> Mat {
    let mx = p.data.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    p.map(|v| (v - mx).exp())
}

/// Optimal sparsity: keep the `keep` largest entries of `A = exp(P)`.
pub struct OptimalSparse {
    pub keep: usize,
}

impl OptimalSparse {
    /// Return the unnormalized sparse `A_hat` (Fig. 1 comparator).
    /// `exp` is taken after subtracting the global max score — a pure
    /// rescaling of `A` that avoids f32 overflow on peaked attention.
    pub fn a_hat(&self, q: &Mat, k: &Mat) -> Mat {
        let a = stab_exp(&ops::scores(q, k));
        let idx = topk::top_k_indices(&a.data, self.keep.min(a.data.len()));
        let mut out = Mat::zeros(a.rows, a.cols);
        for i in idx {
            out.data[i] = a.data[i];
        }
        out
    }
}

impl AttentionApprox for OptimalSparse {
    fn name(&self) -> String {
        format!("sparse-opt(k={})", self.keep)
    }

    fn compute(&self, q: &Mat, k: &Mat, v: &Mat) -> Mat {
        let a = self.a_hat(q, k);
        let den = ops::row_sums(&a);
        // top-k A_hat is almost entirely structural zeros
        ops::div_rows(&a.matmul_sparse(v), &den)
    }

    fn workload(&self, n: usize, d: usize) -> usize {
        n * n * d + self.keep * d // must scan A, then sparse AV
    }

    fn memory_elems(&self, n: usize, _d: usize) -> usize {
        n * n
    }
}

/// Optimal low rank: truncated SVD of `A = exp(P)` at rank `rank`.
pub struct OptimalLowRank {
    pub rank: usize,
    pub seed: u64,
}

impl OptimalLowRank {
    /// Return the unnormalized rank-`rank` `A_hat` (Fig. 1 comparator),
    /// computed on the max-stabilized `A` (see [`OptimalSparse::a_hat`]).
    pub fn a_hat(&self, q: &Mat, k: &Mat) -> Mat {
        let a = stab_exp(&ops::scores(q, k));
        let mut rng = Rng::new(self.seed);
        let dec = svd::randomized_svd(&a, self.rank, 4, &mut rng);
        dec.reconstruct(self.rank)
    }
}

impl AttentionApprox for OptimalLowRank {
    fn name(&self) -> String {
        format!("lowrank-opt(r={})", self.rank)
    }

    fn compute(&self, q: &Mat, k: &Mat, v: &Mat) -> Mat {
        let a = self.a_hat(q, k);
        let den = ops::row_sums(&a);
        ops::div_rows(&a.matmul(v), &den)
    }

    fn workload(&self, n: usize, d: usize) -> usize {
        n * n * (self.rank + d) // sketch + reconstruct
    }

    fn memory_elems(&self, n: usize, _d: usize) -> usize {
        n * n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup(n: usize, d: usize, seed: u64) -> (Mat, Mat, Mat) {
        let mut rng = Rng::new(seed);
        (
            Mat::randn(n, d, 1.0, &mut rng),
            Mat::randn(n, d, 1.0, &mut rng),
            Mat::randn(n, d, 1.0, &mut rng),
        )
    }

    #[test]
    fn sparse_full_keep_is_exact() {
        let (q, k, v) = setup(32, 8, 0);
        let z = OptimalSparse { keep: 32 * 32 }.compute(&q, &k, &v);
        let exact = ops::exact_attention(&q, &k, &v);
        assert!(ops::rel_fro_error(&z, &exact) < 1e-5);
    }

    #[test]
    fn sparse_error_monotone_in_keep() {
        let (q, k, _) = setup(64, 8, 1);
        let a = stab_exp(&ops::scores(&q, &k));
        let e_small = ops::rel_fro_error(&OptimalSparse { keep: 64 }.a_hat(&q, &k), &a);
        let e_big = ops::rel_fro_error(&OptimalSparse { keep: 2048 }.a_hat(&q, &k), &a);
        assert!(e_big < e_small);
    }

    #[test]
    fn lowrank_full_rank_is_exact() {
        let (q, k, _) = setup(32, 8, 2);
        let a = stab_exp(&ops::scores(&q, &k));
        let rec = OptimalLowRank { rank: 32, seed: 0 }.a_hat(&q, &k);
        assert!(ops::rel_fro_error(&rec, &a) < 1e-2);
    }

    #[test]
    fn fig1_style_mra_beats_lowrank_at_matched_budget() {
        // the Fig. 1 claim: at ~10% budget on a *peaked*, locality-
        // structured attention matrix (like trained-model attention),
        // MRA error < low-rank error.  Low rank fails on peaked attention
        // (§A.2); sharpness is what trained attention maps look like.
        let n = 128;
        let mut rng = Rng::new(3);
        let mut q = Mat::zeros(n, 16);
        let mut k = Mat::zeros(n, 16);
        for i in 0..n {
            for j in 0..16 {
                let pq = if i > 0 { q.get(i - 1, j) } else { 0.0 };
                q.set(i, j, 0.95 * pq + 0.4 * rng.normal());
                // keys track queries: trained-model attention is diagonally
                // dominant, which is precisely where SVD truncation fails
                k.set(i, j, q.get(i, j) + 0.2 * rng.normal());
            }
        }
        // normalize rows to a fixed norm: keeps P bounded (no f32 overflow
        // in exp) while making attention *peaked* enough that the Taylor
        // linearization of exp is invalid -> low rank genuinely struggles
        for m in [&mut q, &mut k] {
            for i in 0..n {
                let norm: f32 =
                    m.row(i).iter().map(|v| v * v).sum::<f32>().sqrt().max(1e-6);
                let s = 5.0 / norm;
                for v in m.row_mut(i) {
                    *v *= s;
                }
            }
        }
        let a = stab_exp(&ops::scores(&q, &k));
        // matched 10%-of-coefficients budget: low-res grid + m exact blocks
        let b = 8;
        let nb = n / b;
        let m = ((n * n) / 10 - nb * nb) / (b * b);
        let (a_mra, _) = crate::mra::dense_mra2(
            &q, &k, &Mat::zeros(n, 16), b, m, crate::mra::Variant::Full);
        let shift = ops::scores(&q, &k)
            .data
            .iter()
            .cloned()
            .fold(f32::NEG_INFINITY, f32::max);
        let a_mra = a_mra.scale((-shift).exp());
        let e_mra = ops::rel_fro_error(&a_mra, &a);
        let rank = (n as f64 * 0.1) as usize; // 10% of ranks (paper Fig. 1)
        let e_lr = ops::rel_fro_error(
            &OptimalLowRank { rank, seed: 1 }.a_hat(&q, &k), &a);
        assert!(e_mra < e_lr, "mra {e_mra} vs lowrank {e_lr}");
    }
}
