//! MRA-2 / MRA-2-s wrapped in the [`AttentionApprox`] trait so the paper's
//! method rides through the same bench harness as every baseline.

use crate::baselines::AttentionApprox;
use crate::mra::{self, MraConfig, Variant};
use crate::tensor::Mat;

/// Two-scale MRA (the paper's MRA-2 / MRA-2-s).
pub struct Mra2 {
    pub block: usize,
    /// Refinement budget `m_1`.
    pub budget: usize,
    /// `true` -> MRA-2-s (block-sparse only).
    pub sparse: bool,
}

impl Mra2 {
    pub fn new(block: usize, budget: usize, sparse: bool) -> Self {
        Mra2 { block, budget, sparse }
    }

    fn variant(&self) -> Variant {
        if self.sparse { Variant::Sparse } else { Variant::Full }
    }
}

impl AttentionApprox for Mra2 {
    fn name(&self) -> String {
        format!(
            "mra-2{}(b={},m={})",
            if self.sparse { "-s" } else { "" },
            self.block,
            self.budget
        )
    }

    fn compute(&self, q: &Mat, k: &Mat, v: &Mat) -> Mat {
        let block = self.block.min(q.rows);
        mra::mra2_attention(q, k, v, block, self.budget, self.variant())
    }

    fn workload(&self, n: usize, d: usize) -> usize {
        let cfg = if self.sparse {
            MraConfig::mra2_sparse(self.block, self.budget)
        } else {
            MraConfig::mra2(self.block, self.budget)
        };
        cfg.workload(n) * d
    }

    fn memory_elems(&self, n: usize, d: usize) -> usize {
        let nb = n / self.block.max(1);
        let lowres = if self.sparse { 0 } else { nb * nb };
        self.budget * self.block * self.block + lowres + 3 * nb * d
    }
}

/// General multi-scale MRA (for the R = {16,4,1} style ablations).
pub struct MraGeneral {
    pub cfg: MraConfig,
}

impl AttentionApprox for MraGeneral {
    fn name(&self) -> String {
        format!("mra-general(R={:?},m={:?})", self.cfg.scales, self.cfg.budgets)
    }

    fn compute(&self, q: &Mat, k: &Mat, v: &Mat) -> Mat {
        mra::mra_attention(q, k, v, &self.cfg)
    }

    fn workload(&self, n: usize, d: usize) -> usize {
        self.cfg.workload(n) * d
    }

    fn memory_elems(&self, n: usize, d: usize) -> usize {
        let s0 = self.cfg.scales[0];
        (n / s0) * (n / s0) + 3 * n / s0 * d + self.cfg.workload(n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{ops, Rng};

    #[test]
    fn adapter_matches_core_function() {
        let mut rng = Rng::new(0);
        let q = Mat::randn(64, 8, 1.0, &mut rng);
        let k = Mat::randn(64, 8, 1.0, &mut rng);
        let v = Mat::randn(64, 8, 1.0, &mut rng);
        let z1 = Mra2::new(16, 6, false).compute(&q, &k, &v);
        let z2 = mra::mra2_attention(&q, &k, &v, 16, 6, Variant::Full);
        assert_eq!(z1, z2);
    }

    #[test]
    fn sparse_memory_smaller_than_full() {
        let full = Mra2::new(32, 16, false);
        let sparse = Mra2::new(32, 16, true);
        assert!(sparse.memory_elems(1024, 64) < full.memory_elems(1024, 64));
    }

    #[test]
    fn general_three_scale_runs() {
        let mut rng = Rng::new(1);
        let q = Mat::randn(64, 8, 1.0, &mut rng);
        let k = Mat::randn(64, 8, 1.0, &mut rng);
        let v = Mat::randn(64, 8, 1.0, &mut rng);
        let g = MraGeneral {
            cfg: MraConfig {
                scales: vec![16, 4, 1],
                budgets: vec![4, 16],
                include_diagonal: true,
                variant: Variant::Full,
            },
        };
        let z = g.compute(&q, &k, &v);
        let exact = ops::exact_attention(&q, &k, &v);
        assert!(ops::rel_fro_error(&z, &exact) < 1.0);
    }
}
