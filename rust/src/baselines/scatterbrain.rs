//! Scatterbrain (Chen et al., 2021): unified sparse + low-rank attention.
//!
//! Low-rank part: Performer-style positive random features `phi`.
//! Sparse part: on a locality support `S` (sliding window here), store the
//! *residual* `exp(P_ij) - phi(q_i).phi(k_j)` so the combined estimate is
//! exact on the support and low-rank elsewhere — the paper's unbiased
//! combination.

use crate::baselines::AttentionApprox;
use crate::tensor::{mat::dot, Mat, Rng};

pub struct Scatterbrain {
    /// One-sided sliding-window width of the sparse support.
    pub window: usize,
    /// Random features of the low-rank half.
    pub features: usize,
    pub seed: u64,
}

impl Scatterbrain {
    pub fn new(window: usize, features: usize, seed: u64) -> Self {
        Scatterbrain { window, features, seed }
    }
}

impl AttentionApprox for Scatterbrain {
    fn name(&self) -> String {
        format!("scatterbrain(w={},m={})", self.window, self.features)
    }

    fn compute(&self, q: &Mat, k: &Mat, v: &Mat) -> Mat {
        let (n, d) = (q.rows, q.cols);
        let scale = 1.0 / (d as f32).powf(0.25);
        let qs = q.scale(scale);
        let ks = k.scale(scale);
        let mut rng = Rng::new(self.seed ^ 0x5CA7);
        let w = Mat::randn(self.features, d, 1.0, &mut rng);
        let m = self.features;
        // positive random features WITHOUT per-row max shifts: the sparse
        // residual correction needs phi values on an absolute scale
        let phi = |x: &Mat| -> Mat {
            let logits = x.matmul_transb(&w);
            let mut out = Mat::zeros(x.rows, m);
            let inv_sqrt_m = 1.0 / (m as f32).sqrt();
            for i in 0..x.rows {
                let sq: f32 = x.row(i).iter().map(|&t| t * t).sum::<f32>() * 0.5;
                for j in 0..m {
                    out.set(i, j, (logits.get(i, j) - sq).exp() * inv_sqrt_m);
                }
            }
            out
        };
        let pq = phi(&qs);
        let pk = phi(&ks);
        // low-rank numerator / denominator
        let kv = pk.transpose().matmul(v); // (m, d)
        let mut num = pq.matmul(&kv); // (n, d)
        let ksum: Vec<f32> = (0..m).map(|j| (0..n).map(|i| pk.get(i, j)).sum()).collect();
        let mut den: Vec<f32> = (0..n)
            .map(|i| dot(pq.row(i), &ksum))
            .collect();
        // sparse residual on the window support
        let inv_sqrt_d = 1.0 / (d as f32).sqrt();
        for i in 0..n {
            let lo = i.saturating_sub(self.window);
            let hi = (i + self.window + 1).min(n);
            for j in lo..hi {
                let exact = (dot(q.row(i), k.row(j)) * inv_sqrt_d).exp();
                let lowrank = dot(pq.row(i), pk.row(j));
                let resid = exact - lowrank;
                den[i] += resid;
                let nrow = num.row_mut(i);
                for (o, &vv) in nrow.iter_mut().zip(v.row(j)) {
                    *o += resid * vv;
                }
            }
        }
        for i in 0..n {
            let inv = 1.0 / den[i].max(1e-20);
            for x in num.row_mut(i) {
                *x *= inv;
            }
        }
        num
    }

    fn workload(&self, n: usize, d: usize) -> usize {
        2 * n * self.features * d + n * (2 * self.window + 1) * (2 * d + self.features)
    }

    fn memory_elems(&self, n: usize, d: usize) -> usize {
        2 * n * self.features + self.features * d + n * (2 * self.window + 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops;

    #[test]
    fn exact_on_support_plus_lowrank_beats_lowrank_alone() {
        // diagonally-dominant attention: the sparse residual sits exactly
        // on the mass the low-rank half misses (the Scatterbrain setting)
        let mut rng = Rng::new(0);
        let n = 64;
        let mut q = Mat::zeros(n, 8);
        let mut k = Mat::zeros(n, 8);
        for i in 0..n {
            for j in 0..8 {
                let pq = if i > 0 { q.get(i - 1, j) } else { 0.0 };
                q.set(i, j, 0.9 * pq + 0.5 * rng.normal());
                k.set(i, j, q.get(i, j) + 0.2 * rng.normal());
            }
        }
        let v = Mat::randn(64, 8, 1.0, &mut rng);
        let exact = ops::exact_attention(&q, &k, &v);
        let mut e_sb = 0.0;
        let mut e_perf = 0.0;
        for seed in 0..10 {
            e_sb += ops::rel_fro_error(
                &Scatterbrain::new(12, 64, seed).compute(&q, &k, &v), &exact);
            e_perf += ops::rel_fro_error(
                &crate::baselines::performer::Performer::new(64, seed).compute(&q, &k, &v),
                &exact,
            );
        }
        assert!(e_sb < e_perf, "{e_sb} vs {e_perf}");
    }

    #[test]
    fn full_window_is_exact() {
        let mut rng = Rng::new(1);
        let q = Mat::randn(32, 8, 0.5, &mut rng);
        let k = Mat::randn(32, 8, 0.5, &mut rng);
        let v = Mat::randn(32, 8, 1.0, &mut rng);
        let exact = ops::exact_attention(&q, &k, &v);
        // window covers everything -> residual correction recovers exact
        let z = Scatterbrain::new(32, 16, 0).compute(&q, &k, &v);
        assert!(ops::rel_fro_error(&z, &exact) < 1e-3);
    }

    #[test]
    fn finite_outputs() {
        let mut rng = Rng::new(2);
        let q = Mat::randn(48, 8, 1.0, &mut rng);
        let k = Mat::randn(48, 8, 1.0, &mut rng);
        let v = Mat::randn(48, 8, 1.0, &mut rng);
        let z = Scatterbrain::new(4, 32, 5).compute(&q, &k, &v);
        assert!(z.data.iter().all(|x| x.is_finite()));
    }
}
