//! Standard softmax attention — the "Transformer" row of every table.

use crate::baselines::AttentionApprox;
use crate::tensor::{ops, Mat};

/// Exact `softmax(QK^T/sqrt(d)) V`.
pub struct Exact;

impl AttentionApprox for Exact {
    fn name(&self) -> String {
        "transformer".into()
    }

    fn compute(&self, q: &Mat, k: &Mat, v: &Mat) -> Mat {
        ops::exact_attention(q, k, v)
    }

    fn workload(&self, n: usize, d: usize) -> usize {
        2 * n * n * d // scores + AV
    }

    fn memory_elems(&self, n: usize, _d: usize) -> usize {
        n * n // the dense attention matrix
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    #[test]
    fn identity_values_recover_softmax_rows() {
        let mut rng = Rng::new(0);
        let q = Mat::randn(8, 4, 1.0, &mut rng);
        let k = Mat::randn(8, 4, 1.0, &mut rng);
        let v = Mat::eye(8).row_block(0, 8); // identity as values
        let z = Exact.compute(&q, &k, &v);
        // rows of Z are then exactly the softmax rows: they sum to 1
        for i in 0..8 {
            let s: f32 = z.row(i).iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn quadratic_workload() {
        assert_eq!(Exact.workload(100, 8), 2 * 100 * 100 * 8);
    }
}
