//! Big Bird (Zaheer et al., 2020): sliding window + global tokens +
//! uniformly random extra keys per query row.

use crate::baselines::longformer::{normalize_support, sparse_attention};
use crate::baselines::AttentionApprox;
use crate::tensor::{Mat, Rng};

pub struct BigBird {
    pub window: usize,
    pub globals: usize,
    /// Random extra keys per row.
    pub random: usize,
    pub seed: u64,
}

impl BigBird {
    pub fn new(window: usize, globals: usize, random: usize, seed: u64) -> Self {
        BigBird { window, globals, random, seed }
    }

    pub fn support(&self, n: usize) -> Vec<Vec<usize>> {
        let mut rng = Rng::new(self.seed ^ 0xB16B);
        let mut rows: Vec<Vec<usize>> = Vec::with_capacity(n);
        for i in 0..n {
            let lo = i.saturating_sub(self.window);
            let hi = (i + self.window + 1).min(n);
            let mut cols: Vec<usize> = (lo..hi).collect();
            cols.extend(0..self.globals.min(n));
            for _ in 0..self.random {
                cols.push(rng.below(n));
            }
            if i < self.globals {
                cols = (0..n).collect();
            }
            rows.push(cols);
        }
        normalize_support(&mut rows);
        rows
    }
}

impl AttentionApprox for BigBird {
    fn name(&self) -> String {
        format!("bigbird(w={},r={})", self.window, self.random)
    }

    fn compute(&self, q: &Mat, k: &Mat, v: &Mat) -> Mat {
        sparse_attention(q, k, v, &self.support(q.rows))
    }

    fn workload(&self, n: usize, d: usize) -> usize {
        n * (2 * self.window + 1 + self.globals + self.random) * 2 * d
    }

    fn memory_elems(&self, n: usize, _d: usize) -> usize {
        n * (2 * self.window + 1 + self.globals + self.random)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops;

    #[test]
    fn support_contains_window_globals_and_randoms() {
        let bb = BigBird::new(1, 1, 3, 0);
        let s = bb.support(32);
        // row 16: window {15,16,17}, global {0}, up to 3 randoms
        assert!(s[16].contains(&15) && s[16].contains(&16) && s[16].contains(&17));
        assert!(s[16].contains(&0));
        assert!(s[16].len() >= 4 && s[16].len() <= 7);
    }

    #[test]
    fn random_keys_extend_reach_beyond_window() {
        let bb = BigBird::new(1, 0, 4, 1);
        let s = bb.support(64);
        let far = s
            .iter()
            .enumerate()
            .any(|(i, cols)| cols.iter().any(|&j| (j as i64 - i as i64).abs() > 2));
        assert!(far);
    }

    #[test]
    fn beats_pure_window_on_distant_dependency() {
        // planted structure: every row attends strongly to key 0
        let n = 64;
        let mut rng = Rng::new(3);
        let mut q = Mat::randn(n, 8, 0.1, &mut rng);
        let mut k = Mat::randn(n, 8, 0.1, &mut rng);
        for j in 0..8 {
            k.set(0, j, 2.0); // hot key
            for i in 0..n {
                q.set(i, j, q.get(i, j) + 1.0);
            }
        }
        let v = Mat::randn(n, 8, 1.0, &mut rng);
        let exact = ops::exact_attention(&q, &k, &v);
        let e_bb = ops::rel_fro_error(
            &BigBird::new(2, 1, 2, 0).compute(&q, &k, &v), &exact);
        let e_win = ops::rel_fro_error(
            &crate::baselines::longformer::Longformer::new(2, 0).compute(&q, &k, &v),
            &exact,
        );
        assert!(e_bb < e_win, "{e_bb} vs {e_win}");
    }
}
