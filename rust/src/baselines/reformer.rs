//! Reformer (Kitaev et al., 2020): LSH-bucketed attention.
//!
//! Random-rotation LSH over the shared query/key space; tokens attend only
//! within their bucket (union over `rounds` independent hash rounds).
//! Like the paper's implementation we hash `K` (queries use the same
//! projection), so similar vectors land in the same bucket w.h.p.

use crate::baselines::longformer::{normalize_support, sparse_attention};
use crate::baselines::AttentionApprox;
use crate::tensor::{mat::dot, Mat, Rng};

pub struct Reformer {
    /// Number of hash buckets per round.
    pub buckets: usize,
    /// Independent hash rounds (union of supports).
    pub rounds: usize,
    pub seed: u64,
}

impl Reformer {
    pub fn new(buckets: usize, rounds: usize, seed: u64) -> Self {
        Reformer { buckets, rounds, seed }
    }

    /// Angular LSH: project on `buckets/2` random directions, bucket =
    /// argmax over `[proj; -proj]` (the Reformer construction).  The same
    /// `planes` must be used for queries and keys within a round.
    fn hash_round(&self, x: &Mat, planes: &Mat) -> Vec<usize> {
        let half = (self.buckets / 2).max(1);
        (0..x.rows)
            .map(|i| {
                let mut best = 0usize;
                let mut best_v = f32::NEG_INFINITY;
                for b in 0..half {
                    let p = dot(x.row(i), planes.row(b));
                    if p > best_v {
                        best_v = p;
                        best = b;
                    }
                    if -p > best_v {
                        best_v = -p;
                        best = b + half;
                    }
                }
                best
            })
            .collect()
    }

    pub fn support(&self, q: &Mat, k: &Mat) -> Vec<Vec<usize>> {
        let n = q.rows;
        let mut rng = Rng::new(self.seed ^ 0x4EF0);
        let mut rows: Vec<Vec<usize>> = vec![Vec::new(); n];
        for _ in 0..self.rounds {
            let half = (self.buckets / 2).max(1);
            let planes = Mat::randn(half, q.cols, 1.0, &mut rng);
            let hq = self.hash_round(q, &planes);
            let hk = self.hash_round(k, &planes);
            let mut members: Vec<Vec<usize>> = vec![Vec::new(); self.buckets.max(2)];
            for (j, &b) in hk.iter().enumerate() {
                members[b].push(j);
            }
            for (i, &b) in hq.iter().enumerate() {
                rows[i].extend(members[b].iter().copied());
            }
        }
        // every token always sees itself (Reformer's causal fallback)
        for (i, r) in rows.iter_mut().enumerate() {
            r.push(i);
        }
        normalize_support(&mut rows);
        rows
    }
}

impl AttentionApprox for Reformer {
    fn name(&self) -> String {
        format!("reformer(b={},r={})", self.buckets, self.rounds)
    }

    fn compute(&self, q: &Mat, k: &Mat, v: &Mat) -> Mat {
        sparse_attention(q, k, v, &self.support(q, k))
    }

    fn workload(&self, n: usize, d: usize) -> usize {
        // expected bucket size n/buckets; rounds unions
        let per_row = (self.rounds * n / self.buckets.max(1)).max(1);
        n * per_row * 2 * d + self.rounds * n * self.buckets * d / 2
    }

    fn memory_elems(&self, n: usize, _d: usize) -> usize {
        let per_row = (self.rounds * n / self.buckets.max(1)).max(1);
        n * per_row
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops;

    #[test]
    fn single_bucket_is_exact() {
        let mut rng = Rng::new(0);
        let q = Mat::randn(32, 8, 1.0, &mut rng);
        let k = Mat::randn(32, 8, 1.0, &mut rng);
        let v = Mat::randn(32, 8, 1.0, &mut rng);
        // buckets=2 with planes... not exact; use buckets=1-ish by checking
        // full support instead: everything hashes into <= 2 buckets, so use
        // rounds high enough to union toward full support is stochastic.
        // Deterministic check: support rows always include self.
        let s = Reformer::new(8, 2, 1).support(&q, &k);
        for (i, r) in s.iter().enumerate() {
            assert!(r.contains(&i));
        }
        let z = Reformer::new(8, 2, 1).compute(&q, &k, &v);
        assert!(z.data.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn identical_vectors_share_buckets() {
        // clone one vector across positions: LSH must group them
        let d = 8;
        let n = 16;
        let mut rng = Rng::new(1);
        let proto: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
        let mut k = Mat::zeros(n, d);
        for i in 0..n {
            for j in 0..d {
                k.set(i, j, proto[j]);
            }
        }
        let q = k.clone();
        let s = Reformer::new(4, 1, 2).support(&q, &k);
        // every row's bucket contains all n tokens (identical hashes)
        for r in &s {
            assert_eq!(r.len(), n);
        }
    }

    #[test]
    fn clustered_data_low_error() {
        // two well-separated clusters: within-cluster attention dominates,
        // which LSH recovers
        let n = 64;
        let d = 8;
        let mut rng = Rng::new(2);
        let mut q = Mat::zeros(n, d);
        for i in 0..n {
            let c = if i % 2 == 0 { 3.0 } else { -3.0 };
            for j in 0..d {
                q.set(i, j, c + 0.1 * rng.normal());
            }
        }
        let k = q.clone();
        let v = Mat::randn(n, d, 1.0, &mut rng);
        let exact = ops::exact_attention(&q, &k, &v);
        let z = Reformer::new(4, 4, 3).compute(&q, &k, &v);
        let err = ops::rel_fro_error(&z, &exact);
        assert!(err < 0.2, "err={err}");
    }
}
