//! Performer (Choromanski et al., 2021): FAVOR+ positive random features.
//!
//! `exp(q.k/sqrt(d)) ~ phi(q) . phi(k)` with
//! `phi(x) = exp(w^T x' - ||x'||^2 / 2) / sqrt(m)` over `m` Gaussian
//! features `w` (`x' = x / d^{1/4}` absorbs the score scaling), so
//! attention factorizes as `phi(Q) (phi(K)^T V)` in `O(n m d)`.

use crate::baselines::AttentionApprox;
use crate::tensor::{Mat, Rng};

pub struct Performer {
    /// Number of random features `m`.
    pub features: usize,
    pub seed: u64,
}

impl Performer {
    pub fn new(features: usize, seed: u64) -> Self {
        Performer { features, seed }
    }

    /// Positive random features.  `per_row` stabilization (subtract each
    /// row's own max) is valid for *queries* only — it cancels in the row
    /// normalization.  Keys must share a single global shift, otherwise
    /// their relative weights are distorted.
    fn phi(&self, x: &Mat, w: &Mat, per_row: bool) -> Mat {
        // x: (n, d) pre-scaled; w: (m, d)
        let n = x.rows;
        let m = w.rows;
        let logits = x.matmul_transb(w); // (n, m) = x . w
        let mut out = Mat::zeros(n, m);
        let inv_sqrt_m = 1.0 / (m as f32).sqrt();
        let global_max = logits.data.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        for i in 0..n {
            let sq: f32 = x.row(i).iter().map(|&t| t * t).sum::<f32>() * 0.5;
            let shift = if per_row {
                logits.row(i).iter().cloned().fold(f32::NEG_INFINITY, f32::max)
            } else {
                global_max
            };
            for j in 0..m {
                out.set(i, j, (logits.get(i, j) - sq - shift).exp() * inv_sqrt_m);
            }
        }
        out
    }
}

impl AttentionApprox for Performer {
    fn name(&self) -> String {
        format!("performer(m={})", self.features)
    }

    fn compute(&self, q: &Mat, k: &Mat, v: &Mat) -> Mat {
        let d = q.cols;
        let scale = 1.0 / (d as f32).powf(0.25);
        let qs = q.scale(scale);
        let ks = k.scale(scale);
        let mut rng = Rng::new(self.seed ^ 0xFA50);
        let w = Mat::randn(self.features, d, 1.0, &mut rng);
        let pq = self.phi(&qs, &w, true); // (n, m)
        let pk = self.phi(&ks, &w, false); // (n, m) — shared key shift
        // numerator: pq (pk^T V); denominator: pq (pk^T 1)
        let kv = pk.transpose().matmul(v); // (m, d)
        let num = pq.matmul(&kv); // (n, d)
        let ksum: Vec<f32> = (0..self.features)
            .map(|j| (0..pk.rows).map(|i| pk.get(i, j)).sum())
            .collect();
        let mut out = num;
        for i in 0..out.rows {
            let den: f32 = pq
                .row(i)
                .iter()
                .zip(ksum.iter())
                .map(|(a, b)| a * b)
                .sum::<f32>()
                .max(1e-20);
            let inv = 1.0 / den;
            for x in out.row_mut(i) {
                *x *= inv;
            }
        }
        out
    }

    fn workload(&self, n: usize, d: usize) -> usize {
        2 * n * self.features * d + 2 * self.features * n * d
    }

    fn memory_elems(&self, n: usize, d: usize) -> usize {
        2 * n * self.features + self.features * d
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops;

    #[test]
    fn approximates_exact_with_many_features() {
        let mut rng = Rng::new(0);
        let q = Mat::randn(48, 8, 0.4, &mut rng);
        let k = Mat::randn(48, 8, 0.4, &mut rng);
        let v = Mat::randn(48, 8, 1.0, &mut rng);
        let exact = ops::exact_attention(&q, &k, &v);
        let z = Performer::new(512, 3).compute(&q, &k, &v);
        let err = ops::rel_fro_error(&z, &exact);
        assert!(err < 0.35, "err={err}");
    }

    #[test]
    fn more_features_help_on_average() {
        let mut rng = Rng::new(1);
        let (mut e8, mut e256) = (0.0, 0.0);
        for seed in 0..6 {
            let q = Mat::randn(32, 8, 0.4, &mut rng);
            let k = Mat::randn(32, 8, 0.4, &mut rng);
            let v = Mat::randn(32, 8, 1.0, &mut rng);
            let exact = ops::exact_attention(&q, &k, &v);
            e8 += ops::rel_fro_error(&Performer::new(8, seed).compute(&q, &k, &v), &exact);
            e256 += ops::rel_fro_error(&Performer::new(256, seed).compute(&q, &k, &v), &exact);
        }
        assert!(e256 < e8, "{e256} vs {e8}");
    }

    #[test]
    fn convexity_with_ones_values() {
        let mut rng = Rng::new(2);
        let q = Mat::randn(32, 8, 1.0, &mut rng);
        let k = Mat::randn(32, 8, 1.0, &mut rng);
        let v = Mat::full(32, 8, 1.0);
        let z = Performer::new(64, 0).compute(&q, &k, &v);
        // kernel estimators normalize exactly for constant values
        for &x in z.data.iter() {
            assert!((x - 1.0).abs() < 1e-4, "{x}");
        }
    }
}
