//! Longformer (Beltagy et al., 2020): sliding-window attention of width
//! `w` plus `g` global tokens (attended by and attending to everything).
//!
//! Also hosts [`sparse_attention`], the shared row-support evaluator used
//! by Big Bird and Reformer: attention computed only on an explicit
//! per-row set of key indices, `O(sum |support|) * d`.

use crate::baselines::AttentionApprox;
use crate::tensor::{mat::dot, Mat};

/// Evaluate attention restricted to `support[i]` (distinct key indices per
/// row).  Numerically stabilized per row.
pub fn sparse_attention(q: &Mat, k: &Mat, v: &Mat, support: &[Vec<usize>]) -> Mat {
    let (n, d) = (q.rows, q.cols);
    assert_eq!(support.len(), n);
    let inv_sqrt_d = 1.0 / (d as f32).sqrt();
    let mut out = Mat::zeros(n, d);
    let mut scores: Vec<f32> = Vec::new();
    for i in 0..n {
        let cols = &support[i];
        if cols.is_empty() {
            continue;
        }
        scores.clear();
        let mut mx = f32::NEG_INFINITY;
        for &j in cols {
            let s = dot(q.row(i), k.row(j)) * inv_sqrt_d;
            mx = mx.max(s);
            scores.push(s);
        }
        let mut den = 0.0f32;
        let orow = out.row_mut(i);
        for (t, &j) in cols.iter().enumerate() {
            let a = (scores[t] - mx).exp();
            den += a;
            for (o, &vv) in orow.iter_mut().zip(v.row(j)) {
                *o += a * vv;
            }
        }
        let inv = 1.0 / den;
        for o in orow.iter_mut() {
            *o *= inv;
        }
    }
    out
}

/// Deduplicate and sort a support row in place.
pub fn normalize_support(rows: &mut [Vec<usize>]) {
    for r in rows.iter_mut() {
        r.sort_unstable();
        r.dedup();
    }
}

pub struct Longformer {
    /// One-sided window size (total window `2w + 1`).
    pub window: usize,
    /// Number of leading global tokens.
    pub globals: usize,
}

impl Longformer {
    pub fn new(window: usize, globals: usize) -> Self {
        Longformer { window, globals }
    }

    /// Build the sliding-window + global support sets.
    pub fn support(&self, n: usize) -> Vec<Vec<usize>> {
        let mut rows: Vec<Vec<usize>> = Vec::with_capacity(n);
        for i in 0..n {
            let lo = i.saturating_sub(self.window);
            let hi = (i + self.window + 1).min(n);
            let mut cols: Vec<usize> = (lo..hi).collect();
            cols.extend(0..self.globals.min(n));
            if i < self.globals {
                // global tokens attend everywhere
                cols = (0..n).collect();
            }
            rows.push(cols);
        }
        normalize_support(&mut rows);
        rows
    }
}

impl AttentionApprox for Longformer {
    fn name(&self) -> String {
        format!("longformer(w={},g={})", self.window, self.globals)
    }

    fn compute(&self, q: &Mat, k: &Mat, v: &Mat) -> Mat {
        sparse_attention(q, k, v, &self.support(q.rows))
    }

    fn workload(&self, n: usize, d: usize) -> usize {
        n * (2 * self.window + 1 + self.globals) * 2 * d
    }

    fn memory_elems(&self, n: usize, _d: usize) -> usize {
        n * (2 * self.window + 1 + self.globals)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{ops, Rng};

    #[test]
    fn full_window_is_exact() {
        let mut rng = Rng::new(0);
        let q = Mat::randn(32, 8, 1.0, &mut rng);
        let k = Mat::randn(32, 8, 1.0, &mut rng);
        let v = Mat::randn(32, 8, 1.0, &mut rng);
        let z = Longformer::new(32, 0).compute(&q, &k, &v);
        let exact = ops::exact_attention(&q, &k, &v);
        assert!(ops::rel_fro_error(&z, &exact) < 1e-4);
    }

    #[test]
    fn support_shape() {
        let s = Longformer::new(2, 1).support(8);
        assert_eq!(s[0], (0..8).collect::<Vec<_>>()); // global row
        assert_eq!(s[4], vec![0, 2, 3, 4, 5, 6]); // window +/-2 plus global 0
    }

    #[test]
    fn sparse_attention_matches_dense_on_full_support() {
        let mut rng = Rng::new(1);
        let q = Mat::randn(16, 4, 1.0, &mut rng);
        let k = Mat::randn(16, 4, 1.0, &mut rng);
        let v = Mat::randn(16, 4, 1.0, &mut rng);
        let support: Vec<Vec<usize>> = (0..16).map(|_| (0..16).collect()).collect();
        let z = sparse_attention(&q, &k, &v, &support);
        let exact = ops::exact_attention(&q, &k, &v);
        assert!(ops::rel_fro_error(&z, &exact) < 1e-5);
    }

    #[test]
    fn window_attention_is_local() {
        // token far from i must not influence row i
        let mut rng = Rng::new(2);
        let q = Mat::randn(32, 4, 1.0, &mut rng);
        let k = Mat::randn(32, 4, 1.0, &mut rng);
        let mut v1 = Mat::randn(32, 4, 1.0, &mut rng);
        let z1 = Longformer::new(2, 0).compute(&q, &k, &v1);
        // perturb a value row far outside the window of row 16
        for j in 0..4 {
            v1.set(31, j, v1.get(31, j) + 100.0);
        }
        let z2 = Longformer::new(2, 0).compute(&q, &k, &v1);
        for j in 0..4 {
            assert!((z1.get(16, j) - z2.get(16, j)).abs() < 1e-6);
        }
    }
}
