//! H-Transformer-1D (Zhu & Soricut, 2021): hierarchical attention with a
//! *prespecified* multiresolution structure — exact on-diagonal blocks,
//! progressively coarser (pooled) resolution for progressively more distant
//! off-diagonal bands.
//!
//! This is the "fixed-structure MRA" the paper contrasts with: identical
//! pyramid machinery, but the refinement pattern is data-independent, which
//! is exactly why it struggles on attention with strong distant
//! dependencies (Tab. 1/2, Fig. 8 discussion).

use crate::baselines::AttentionApprox;
use crate::mra::frame::Block;
use crate::mra::pyramid::Pyramid;
use crate::mra::select::Scored;
use crate::mra::{self};
use crate::tensor::{mat::dot, Mat};

pub struct HTransformer1d {
    /// Finest block size (diagonal blocks are exact at scale `block`;
    /// bands at distance 2^t are approximated at scale `block * 2^t`).
    pub block: usize,
}

impl HTransformer1d {
    pub fn new(block: usize) -> Self {
        HTransformer1d { block }
    }

    /// Build the fixed hierarchical block set: diagonal + first
    /// off-diagonals exact at the base scale, then dyadically coarser
    /// blocks outward (a standard H-matrix partition of the plane).
    pub fn partition(&self, n: usize) -> Vec<Block> {
        let mut blocks = Vec::new();
        let b0 = self.block.min(n);
        // recursive dyadic split of the [0,n)x[0,n) square
        fn split(blocks: &mut Vec<Block>, scale: usize, x: usize, y: usize, b0: usize) {
            let near = x == y || x + 1 == y || y + 1 == x;
            if !near || scale == b0 {
                blocks.push(Block { scale, x, y });
                return;
            }
            for dx in 0..2 {
                for dy in 0..2 {
                    split(blocks, scale / 2, 2 * x + dx, 2 * y + dy, b0);
                }
            }
        }
        split(&mut blocks, n, 0, 0, b0);
        blocks
    }
}

impl AttentionApprox for HTransformer1d {
    fn name(&self) -> String {
        format!("h-transformer-1d(b={})", self.block)
    }

    fn compute(&self, q: &Mat, k: &Mat, v: &Mat) -> Mat {
        let n = q.rows;
        let d = q.cols;
        let inv_sqrt_d = 1.0 / (d as f32).sqrt();
        let blocks = self.partition(n);
        // scales used by the partition
        let mut scales: Vec<usize> = blocks.iter().map(|b| b.scale).collect();
        scales.sort_unstable_by(|a, b| b.cmp(a));
        scales.dedup();
        let qp = Pyramid::build(q, &scales);
        let kp = Pyramid::build(k, &scales);
        let vp = Pyramid::build(v, &scales);
        // H1D uses exact entries at the finest scale — reuse the MRA matvec
        // by expanding finest blocks to scale-1 components
        let mut scored: Vec<Scored> = Vec::new();
        let mut fine_scales = scales.clone();
        for blk in &blocks {
            if blk.scale == self.block && self.block > 1 {
                // exact block -> scale-1 entries
                for child in blk.children(self.block) {
                    let lm = dot(q.row(child.x), k.row(child.y)) * inv_sqrt_d;
                    scored.push(Scored { block: child, log_mu: lm });
                }
            } else {
                // the pyramid was built from exactly these partition
                // scales, so the Result path cannot trip
                let qs = qp.at(blk.scale).expect("partition scale in pyramid");
                let ks = kp.at(blk.scale).expect("partition scale in pyramid");
                let lm = dot(qs.row(blk.x), ks.row(blk.y)) * inv_sqrt_d;
                scored.push(Scored { block: *blk, log_mu: lm });
            }
        }
        if self.block > 1 && !fine_scales.contains(&1) {
            fine_scales.push(1);
        }
        let vp_fine = if self.block > 1 { Pyramid::build(v, &fine_scales) } else { vp };
        mra::matvec::compute(&scored, &vp_fine, n, &fine_scales)
            .expect("partition scales in ladder")
            .normalized()
    }

    fn workload(&self, n: usize, d: usize) -> usize {
        // ~3 blocks per level, each (n/s)... totals O(n log n)
        let levels = (n / self.block).max(2).ilog2() as usize + 1;
        3 * n * self.block * d * levels / self.block.max(1)
            + n * self.block * d
    }

    fn memory_elems(&self, n: usize, _d: usize) -> usize {
        n * self.block * 3
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{ops, Rng};

    #[test]
    fn partition_tiles_the_square() {
        for n in [32usize, 64, 128] {
            let h = HTransformer1d::new(8);
            let blocks = h.partition(n);
            let area: usize = blocks.iter().map(|b| b.area()).sum();
            assert_eq!(area, n * n, "n={n}");
            for (i, a) in blocks.iter().enumerate() {
                for b in blocks.iter().skip(i + 1) {
                    assert!(!a.overlaps(b));
                }
            }
        }
    }

    #[test]
    fn partition_diagonal_is_finest() {
        let h = HTransformer1d::new(8);
        let blocks = h.partition(64);
        for b in &blocks {
            if b.x == b.y {
                assert_eq!(b.scale, 8, "{b:?}");
            }
        }
    }

    #[test]
    fn full_block_size_is_exact() {
        let mut rng = Rng::new(0);
        let q = Mat::randn(32, 8, 1.0, &mut rng);
        let k = Mat::randn(32, 8, 1.0, &mut rng);
        let v = Mat::randn(32, 8, 1.0, &mut rng);
        // block = n -> single exact block = exact attention
        let z = HTransformer1d::new(32).compute(&q, &k, &v);
        let exact = ops::exact_attention(&q, &k, &v);
        assert!(ops::rel_fro_error(&z, &exact) < 1e-4);
    }

    #[test]
    fn local_attention_well_approximated() {
        // diagonally-banded attention: H1D's prespecified structure fits
        let n = 64;
        let d = 8;
        let mut rng = Rng::new(1);
        let mut q = Mat::zeros(n, d);
        for i in 0..n {
            for j in 0..d {
                let angle = i as f32 / n as f32 * 3.0 + j as f32;
                q.set(i, j, angle.sin() + 0.05 * rng.normal());
            }
        }
        let k = q.clone();
        let v = Mat::randn(n, d, 1.0, &mut rng);
        let exact = ops::exact_attention(&q, &k, &v);
        let z = HTransformer1d::new(16).compute(&q, &k, &v);
        let err = ops::rel_fro_error(&z, &exact);
        assert!(err < 0.35, "err={err}");
    }

    #[test]
    fn distant_dependency_hurts_h1d_more_than_mra() {
        // a strong off-diagonal dependency: MRA refines it, H1D cannot
        let n = 128;
        let d = 8;
        let mut rng = Rng::new(2);
        let mut q = Mat::randn(n, d, 0.2, &mut rng);
        let mut k = Mat::randn(n, d, 0.2, &mut rng);
        // rows 0..16 attend strongly to keys 96..112
        for i in 0..16 {
            for j in 0..d {
                q.set(i, j, 2.0);
            }
        }
        for t in 96..112 {
            for j in 0..d {
                k.set(t, j, 2.0);
            }
        }
        let v = Mat::randn(n, d, 1.0, &mut rng);
        let exact = ops::exact_attention(&q, &k, &v);
        let e_h1d = ops::rel_fro_error(
            &HTransformer1d::new(16).compute(&q, &k, &v), &exact);
        let e_mra = ops::rel_fro_error(
            &mra::mra2_attention(&q, &k, &v, 16, 24, mra::Variant::Full), &exact);
        assert!(e_mra < e_h1d, "mra {e_mra} vs h1d {e_h1d}");
    }
}
