//! Nyströmformer (Xiong et al., 2021): Nyström factorization of the
//! softmax matrix using segment-mean landmarks and an iterative
//! pseudo-inverse.
//!
//! `A ~ softmax(Q L_k^T) (softmax(L_q L_k^T))^+ softmax(L_q K^T)`
//! with `L_q, L_k` the `l` segment means of Q and K, and the Moore–Penrose
//! inverse approximated by the paper's Newton–Schulz-style iteration.

use crate::baselines::AttentionApprox;
use crate::tensor::{ops, Mat};

pub struct Nystromformer {
    /// Number of landmarks `l`.
    pub landmarks: usize,
    /// Pseudo-inverse iterations (paper uses 6).
    pub pinv_iters: usize,
}

impl Nystromformer {
    pub fn new(landmarks: usize, pinv_iters: usize) -> Self {
        Nystromformer { landmarks, pinv_iters }
    }

    /// Segment-mean landmarks: split rows into `l` contiguous segments.
    fn landmarks_of(&self, x: &Mat) -> Mat {
        let l = self.landmarks.min(x.rows);
        let n = x.rows;
        let mut out = Mat::zeros(l, x.cols);
        for s in 0..l {
            let lo = s * n / l;
            let hi = ((s + 1) * n / l).max(lo + 1);
            let orow = out.row_mut(s);
            for i in lo..hi {
                for (o, &v) in orow.iter_mut().zip(x.row(i)) {
                    *o += v;
                }
            }
            let inv = 1.0 / (hi - lo) as f32;
            for o in orow.iter_mut() {
                *o *= inv;
            }
        }
        out
    }

    /// Iterative Moore–Penrose inverse (Razavi et al. scheme used by the
    /// Nyströmformer paper).
    fn pinv(&self, a: &Mat) -> Mat {
        let n = a.rows;
        // z0 = a^T / (||a||_1 ||a||_inf)
        let max_rowsum = (0..n)
            .map(|i| a.row(i).iter().map(|v| v.abs()).sum::<f32>())
            .fold(0.0f32, f32::max);
        let max_colsum = (0..n)
            .map(|j| (0..n).map(|i| a.get(i, j).abs()).sum::<f32>())
            .fold(0.0f32, f32::max);
        let mut z = a.transpose().scale(1.0 / (max_rowsum * max_colsum).max(1e-20));
        let eye13 = Mat::eye(n).scale(13.0);
        let eye15 = Mat::eye(n).scale(15.0);
        let eye7 = Mat::eye(n).scale(7.0);
        for _ in 0..self.pinv_iters {
            let az = a.matmul(&z);
            // z <- 0.25 z (13 I - az (15 I - az (7 I - az)))
            let inner = eye7.sub(&az);
            let mid = eye15.sub(&az.matmul(&inner));
            let outer = eye13.sub(&az.matmul(&mid));
            z = z.matmul(&outer).scale(0.25);
        }
        z
    }
}

impl AttentionApprox for Nystromformer {
    fn name(&self) -> String {
        format!("nystromformer(l={})", self.landmarks)
    }

    fn compute(&self, q: &Mat, k: &Mat, v: &Mat) -> Mat {
        let lq = self.landmarks_of(q);
        let lk = self.landmarks_of(k);
        let f = ops::softmax_rows(&ops::scores(q, &lk)); // (n, l)
        let a_mid = ops::softmax_rows(&ops::scores(&lq, &lk)); // (l, l)
        let b = ops::softmax_rows(&ops::scores(&lq, k)); // (l, n)
        let a_pinv = self.pinv(&a_mid);
        f.matmul(&a_pinv).matmul(&b.matmul(v))
    }

    fn workload(&self, n: usize, d: usize) -> usize {
        let l = self.landmarks;
        2 * n * l * d + self.pinv_iters * 3 * l * l * l + l * n * d
    }

    fn memory_elems(&self, n: usize, _d: usize) -> usize {
        2 * n * self.landmarks + 4 * self.landmarks * self.landmarks
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    #[test]
    fn pinv_of_identity_is_identity() {
        let ny = Nystromformer::new(4, 8);
        let z = ny.pinv(&Mat::eye(6));
        for i in 0..6 {
            for j in 0..6 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((z.get(i, j) - want).abs() < 1e-3);
            }
        }
    }

    #[test]
    fn pinv_inverts_well_conditioned_stochastic_matrix() {
        // softmax matrices are row-stochastic: test on one
        let mut rng = Rng::new(0);
        let raw = Mat::randn(5, 5, 1.0, &mut rng);
        let s = ops::softmax_rows(&raw);
        let z = Nystromformer::new(4, 10).pinv(&s);
        let prod = s.matmul(&z);
        for i in 0..5 {
            for j in 0..5 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((prod.get(i, j) - want).abs() < 0.05, "({i},{j})={}", prod.get(i, j));
            }
        }
    }

    #[test]
    fn landmark_segments_average() {
        let x = Mat::from_fn(8, 1, |i, _| i as f32);
        let l = Nystromformer::new(2, 1).landmarks_of(&x);
        assert!((l.get(0, 0) - 1.5).abs() < 1e-6);
        assert!((l.get(1, 0) - 5.5).abs() < 1e-6);
    }

    #[test]
    fn approximates_exact_on_smooth_attention() {
        let mut rng = Rng::new(1);
        let q = Mat::randn(64, 8, 0.3, &mut rng);
        let k = Mat::randn(64, 8, 0.3, &mut rng);
        let v = Mat::randn(64, 8, 1.0, &mut rng);
        let exact = ops::exact_attention(&q, &k, &v);
        let z = Nystromformer::new(32, 6).compute(&q, &k, &v);
        let err = ops::rel_fro_error(&z, &exact);
        assert!(err < 0.35, "err={err}");
    }

    #[test]
    fn more_landmarks_reduce_error() {
        let mut rng = Rng::new(2);
        let q = Mat::randn(64, 8, 0.3, &mut rng);
        let k = Mat::randn(64, 8, 0.3, &mut rng);
        let v = Mat::randn(64, 8, 1.0, &mut rng);
        let exact = ops::exact_attention(&q, &k, &v);
        let e4 = ops::rel_fro_error(&Nystromformer::new(4, 6).compute(&q, &k, &v), &exact);
        let e32 = ops::rel_fro_error(&Nystromformer::new(32, 6).compute(&q, &k, &v), &exact);
        assert!(e32 < e4, "{e32} vs {e4}");
    }
}
