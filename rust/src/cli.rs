//! Minimal CLI argument parser (no `clap` available offline).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments, with typed accessors and error messages listing what was
//! expected.

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

/// Parsed command-line arguments.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: HashMap<String, String>,
}

impl Args {
    /// Parse from an iterator of raw args (without the binary name).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Result<Self> {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if stripped.is_empty() {
                    bail!("bare `--` is not supported");
                }
                if let Some((k, v)) = stripped.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    out.flags.insert(stripped.to_string(), it.next().unwrap());
                } else {
                    out.flags.insert(stripped.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    /// Parse the process arguments.
    pub fn from_env() -> Result<Self> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn str_opt(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.str_opt(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} expects an integer, got {v:?}")),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} expects a number, got {v:?}")),
        }
    }

    pub fn bool(&self, key: &str) -> bool {
        matches!(self.flags.get(key).map(|s| s.as_str()), Some("true") | Some("1") | Some("yes"))
    }

    /// First positional argument (the subcommand).
    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn parses_kv_and_positional() {
        let a = parse("serve --port 8080 --mode fast extra");
        assert_eq!(a.subcommand(), Some("serve"));
        assert_eq!(a.str_or("port", ""), "8080");
        assert_eq!(a.str_or("mode", ""), "fast");
        assert_eq!(a.positional, vec!["serve", "extra"]);
    }

    #[test]
    fn parses_equals_form_and_bools() {
        let a = parse("run --n=512 --verbose --m 3");
        assert_eq!(a.usize_or("n", 0).unwrap(), 512);
        assert!(a.bool("verbose"));
        assert_eq!(a.usize_or("m", 0).unwrap(), 3);
        assert!(!a.bool("quiet"));
    }

    #[test]
    fn typed_errors() {
        let a = parse("x --n abc");
        assert!(a.usize_or("n", 0).is_err());
        assert_eq!(a.usize_or("missing", 7).unwrap(), 7);
    }

    #[test]
    fn flag_followed_by_flag_is_boolean() {
        let a = parse("x --fast --n 3");
        assert!(a.bool("fast"));
        assert_eq!(a.usize_or("n", 0).unwrap(), 3);
    }
}
