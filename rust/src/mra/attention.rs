//! End-to-end MRA attention: configuration, the general multi-scale path
//! (Alg. 1 + Alg. 2), the optimized two-scale MRA-2 / MRA-2-s fast path,
//! and the dense oracle used by tests and Fig. 8.
//!
//! The fast path is factored into a per-head [`Mra2Plan`] (pyramid, Alg. 1
//! selection, stabilization floors, **packed K^T/V panels**) plus
//! [`mra2_apply_blocks`], which computes any contiguous range of query
//! blocks independently — every query block owns its output rows and
//! denominators outright, so the engine ([`crate::engine`]) can shard one
//! head across workers and still produce bitwise-identical results to the
//! sequential path.
//!
//! The compute core runs on the fused micro-kernel layer
//! ([`crate::tensor::kernel`], DESIGN.md §8): score tiles are outer-product
//! micro-GEMMs over the plan's packed panels, and the stabilized `exp` + V
//! aggregation streams through a single pass under per-row online
//! (running-max) softmax rescaling.  All transient state lives in a
//! caller-owned [`Mra2Scratch`], so steady-state applications are
//! allocation-free.  The historical two-pass scalar path is preserved as
//! [`mra2_apply_blocks_ref`] — the parity reference for tests and
//! `benches/bench_attention.rs` (<= 1e-5 max abs).
//!
//! Both the plan and the oracles support a [`Causality`] mode: in causal
//! mode Alg. 1 selection is restricted to the lower-triangular block set
//! (diagonal coverage intact), refined tiles straddling the diagonal get
//! per-row triangular masking, and the low-res correction covers only the
//! strictly-lower blocks — see DESIGN.md §7.

use crate::mra::matvec;
use crate::mra::pyramid::Pyramid;
use crate::mra::select::{construct_j, Scored};
use crate::tensor::{kernel, ops, topk, Mat};

/// Which components of the approximation are kept (Sec. 5).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Variant {
    /// MRA-2: low-resolution everywhere + exact refined blocks.
    Full,
    /// MRA-2-s: only the refined (finest-scale) blocks — block-sparse.
    Sparse,
}

/// Attention direction: bidirectional (MLM) or causal (autoregressive).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Causality {
    /// Every query attends to every key (the paper's MLM setting).
    #[default]
    Bidirectional,
    /// Query `i` attends only to keys `j <= i`: Alg. 1 runs over the
    /// lower-triangular block set and the refined diagonal tiles are
    /// masked per row (DESIGN.md §7).
    Causal,
}

/// Configuration of the multiresolution approximation.
#[derive(Clone, Debug)]
pub struct MraConfig {
    /// Descending scale ladder `R` (powers of two, last entry usually 1).
    pub scales: Vec<usize>,
    /// Refinement budgets `m_i`, one per adjacent scale pair.
    pub budgets: Vec<usize>,
    /// Seed diagonal blocks into the refinement set (Alg. 1 prior).
    pub include_diagonal: bool,
    pub variant: Variant,
}

impl MraConfig {
    /// The paper's MRA-2: `R = {block, 1}` with budget `m` refined blocks.
    pub fn mra2(block: usize, m: usize) -> Self {
        MraConfig {
            scales: vec![block, 1],
            budgets: vec![m],
            include_diagonal: true,
            variant: Variant::Full,
        }
    }

    /// MRA-2-s (block-sparse variant).
    pub fn mra2_sparse(block: usize, m: usize) -> Self {
        MraConfig { variant: Variant::Sparse, ..Self::mra2(block, m) }
    }

    pub fn validate(&self, n: usize) {
        assert!(!self.scales.is_empty());
        assert_eq!(self.budgets.len(), self.scales.len() - 1);
        for &s in &self.scales {
            assert!(s.is_power_of_two() && n % s == 0, "scale {s} vs n {n}");
        }
        for w in self.scales.windows(2) {
            assert!(w[0] > w[1]);
        }
    }

    /// Theoretical workload (number of `mu` evaluations, Sec. 4.4):
    /// `(n/s0)^2 + sum_i m_i (s_{i-1}/s_i)^2` plus the `O(n)` pyramid.
    pub fn workload(&self, n: usize) -> usize {
        let s0 = self.scales[0];
        let mut total = (n / s0) * (n / s0) + 2 * n;
        for (i, &m) in self.budgets.iter().enumerate() {
            let ratio = self.scales[i] / self.scales[i + 1];
            total += m * ratio * ratio;
        }
        total
    }
}

/// General multi-scale MRA attention (arbitrary ladder) via
/// Alg. 1 + Alg. 2.  Returns the row-normalized `Z_hat`.
pub fn mra_attention(q: &Mat, k: &Mat, v: &Mat, cfg: &MraConfig) -> Mat {
    let n = q.rows;
    cfg.validate(n);
    let qpyr = Pyramid::build(q, &cfg.scales);
    let kpyr = Pyramid::build(k, &cfg.scales);
    let vpyr = Pyramid::build(v, &cfg.scales);
    // cfg.validate established every ladder scale, so the Result paths of
    // construct_j / compute (unknown-scale errors) cannot trip here
    let sel = construct_j(&qpyr, &kpyr, n, q.cols, &cfg.scales, &cfg.budgets, cfg.include_diagonal)
        .expect("validated ladder");
    let blocks: Vec<Scored> = match cfg.variant {
        Variant::Full => sel.blocks,
        Variant::Sparse => sel.finest_only(*cfg.scales.last().unwrap()),
    };
    matvec::compute(&blocks, &vpyr, n, &cfg.scales).expect("validated ladder").normalized()
}

/// Workload statistics of one MRA-2 invocation (feeds Fig. 7 left).
#[derive(Clone, Copy, Debug, Default)]
pub struct MraStats {
    /// `mu` evaluations (low-res grid + refined entries).
    pub mu_evals: usize,
    /// Multiply–accumulate count on the hot path.
    pub flops: usize,
    /// Peak transient f32 buffer footprint (elements).
    pub buffer_elems: usize,
}

/// Precomputed per-head state of the MRA-2 / MRA-2-s fast path: pyramid
/// pooling, Alg. 1 selection, and stabilization floors.  Read-only once
/// built — any number of [`mra2_apply_blocks`] calls (one per query-block
/// range, possibly on different threads) share one plan.
pub struct Mra2Plan {
    pub block: usize,
    pub nb: usize,
    pub d: usize,
    pub variant: Variant,
    pub causality: Causality,
    pub inv_sqrt_d: f32,
    /// Refined key-block columns per query block, ascending.  Never empty:
    /// the diagonal-coverage rule guarantees at least the diagonal block.
    pub per_row: Vec<Vec<usize>>,
    /// Dense `(nb, nb)` selection mask.
    pub selected: Vec<bool>,
    /// Total refined blocks (>= nb under the coverage rule).
    pub tiles: usize,
    /// Low-resolution scores `(nb, nb)` (Eq. 7 / Eq. 6).
    pub s_low: Mat,
    /// Block-pooled values `(nb, d)` — the low-res contribution operand.
    pub vt: Mat,
    /// Per-query-block stabilization floor: max low-res score over
    /// non-refined blocks (`-inf` for MRA-2-s and fully refined rows).
    pub mb: Vec<f32>,
    /// Packed K^T panels, one `(d, b)` transposed tile per key block
    /// (`kt_panels[y*b*d + l*b + c] = K[y*b + c, l]`), built once and
    /// reused by every score tile touching block `y` — the operand shape
    /// that makes [`kernel::score_panel`] a branch-free outer-product.
    pub kt_panels: Vec<f32>,
    /// Packed V panels: contiguous `(b, d)` per-block row copies (block `y`
    /// at `v_panels[y*b*d..]`).  Row-major V is already panel-shaped, so
    /// this is a byte-identical copy — paid deliberately (one `n*d` memcpy
    /// per plan, < 1% of the tile flops) so the plan is self-contained:
    /// [`mra2_apply_blocks`] never reads the caller's K/V buffers, which is
    /// what lets shards, scratch reuse and the decode engine treat the plan
    /// as the single read-only operand.
    pub v_panels: Vec<f32>,
}

/// Caller-owned scratch arena for [`mra2_apply_blocks`]: one score tile,
/// the per-row online-softmax state, and the low-res accumulator.  Sized
/// lazily on first use and reused verbatim afterwards, so steady-state
/// applications perform **zero heap allocations** (asserted by the
/// scratch-reuse tests).  Workers keep one scratch each
/// (`engine::pool::run_with`); a scratch must not be shared across
/// concurrent applications.
#[derive(Clone, Debug, Default)]
pub struct Mra2Scratch {
    /// One `(b, b)` score tile (the fused pass never holds more).
    tile: Vec<f32>,
    /// Per-row running maxes (`b`).
    rowmax: Vec<f32>,
    /// Per-row running denominators (`b`).
    den: Vec<f32>,
    /// Shared low-res value accumulator (`d`).
    yacc: Vec<f32>,
}

impl Mra2Scratch {
    /// Empty scratch; buffers grow on first application.
    pub fn new() -> Self {
        Self::default()
    }

    /// Scratch pre-sized for `plan` (no growth on the first application).
    pub fn for_plan(plan: &Mra2Plan) -> Self {
        let mut s = Self::new();
        s.ensure(plan.block, plan.d);
        s
    }

    fn ensure(&mut self, b: usize, d: usize) {
        if self.tile.len() < b * b {
            self.tile.resize(b * b, 0.0);
        }
        if self.rowmax.len() < b {
            self.rowmax.resize(b, 0.0);
        }
        if self.den.len() < b {
            self.den.resize(b, 0.0);
        }
        if self.yacc.len() < d {
            self.yacc.resize(d, 0.0);
        }
    }

    /// Total reserved f32 elements across all buffers — the scratch-reuse
    /// tests assert this does not grow across repeated applications.
    pub fn heap_elems(&self) -> usize {
        self.tile.capacity() + self.rowmax.capacity() + self.den.capacity() + self.yacc.capacity()
    }
}

impl Mra2Plan {
    /// Workload statistics for one full application of this plan.
    ///
    /// `buffer_elems` counts the plan-resident operands (packed panels,
    /// pooled mats, low-res scores) plus the fused-pass scratch — which is
    /// a single tile regardless of the budget `m`, the point of the online
    /// softmax rewrite (the old two-pass path buffered every tile of a
    /// query block at once).
    pub fn stats(&self, n: usize) -> MraStats {
        let (b, nb, d) = (self.block, self.nb, self.d);
        let mut s = MraStats {
            mu_evals: nb * nb + self.tiles * b * b,
            flops: nb * nb * d + 3 * n * d + self.tiles * b * b * (2 * d + 2),
            buffer_elems: (b * b + 2 * b + d) + 2 * n * d + 3 * nb * d + nb * nb,
        };
        if self.variant == Variant::Full {
            for (x, yset) in self.per_row.iter().enumerate() {
                // causal rows only see the lower-triangular blocks
                let visible = match self.causality {
                    Causality::Bidirectional => nb,
                    Causality::Causal => x + 1,
                };
                s.flops += (visible - yset.len()) * (d + 2);
            }
        }
        s
    }
}

/// Alg. 1 block selection shared by the fast path and the dense oracles:
/// every diagonal block is always refined (coverage rule), and the
/// remaining budget goes to the best off-diagonal blocks by low-res score.
///
/// In causal mode the budget is split evenly across query blocks —
/// diagonal plus up to `ceil((m - nb) / nb)` strictly-lower blocks each —
/// so the selection for query block `x` depends only on pooled statistics
/// of blocks `<= x`.  That keeps the causal path strictly block-causal
/// (rows before any block-aligned cut are bitwise invariant to the
/// future; property-tested in `proptest`), and it is exactly the per-row
/// rule the incremental decode path (`engine::decode`) applies.
fn mra2_select(s_low: &Mat, nb: usize, m: usize, causality: Causality) -> Vec<bool> {
    let mut selected = vec![false; nb * nb];
    for i in 0..nb {
        selected[i * nb + i] = true;
    }
    match causality {
        Causality::Bidirectional => {
            let extra = m.saturating_sub(nb);
            if extra > 0 {
                let mut prio = s_low.data.clone();
                for i in 0..nb {
                    prio[i * nb + i] = f32::NEG_INFINITY;
                }
                for &c in &topk::top_k_indices(&prio, extra) {
                    selected[c] = true;
                }
            }
        }
        Causality::Causal => {
            // per-block extra budget: ceil((m - nb) / nb), which for the
            // clamped m >= 1 equals (m - 1) / nb
            let extra = (m - 1) / nb;
            for x in 1..nb {
                let e = extra.min(x);
                if e == 0 {
                    continue;
                }
                let prio: Vec<f32> = (0..x).map(|y| s_low.get(x, y)).collect();
                for &y in &topk::top_k_indices(&prio, e) {
                    selected[x * nb + y] = true;
                }
            }
        }
    }
    selected
}

/// Build the per-head plan: pyramid, low-res scores, Alg. 1 selection.
///
/// Selection guarantees per-query-block coverage (§bugfix): every diagonal
/// block is always refined — with `m < nb` the old `+inf`-diagonal-prior
/// tie-break could leave query blocks with no refined block at all, making
/// `den == 0` and silently zeroing whole output rows — and the remaining
/// `m - nb` budget goes to the best off-diagonal blocks by low-res score.
/// For `m >= nb` this selects exactly the same set as the original rule.
///
/// In causal mode ([`Causality::Causal`]) the selection runs over the
/// lower-triangular block set with a per-query-block budget (see
/// `mra2_select`) and the stabilization floor only scans visible blocks.
#[allow(clippy::too_many_arguments)]
pub fn mra2_plan(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n: usize,
    d: usize,
    block: usize,
    m: usize,
    variant: Variant,
    causality: Causality,
) -> Mra2Plan {
    assert!(block > 0 && n % block == 0, "block {block} must divide n={n}");
    assert_eq!(q.len(), n * d, "q buffer/shape mismatch");
    assert_eq!(k.len(), n * d, "k buffer/shape mismatch");
    assert_eq!(v.len(), n * d, "v buffer/shape mismatch");
    let b = block;
    let nb = n / b;
    let m = m.min(nb * nb).max(1);
    let inv_sqrt_d = 1.0 / (d as f32).sqrt();

    // --- pyramid + low-res scores (Eq. 7 / Eq. 6) --------------------------
    let qt = ops::pool_rows_slice(q, n, d, b);
    let kt = ops::pool_rows_slice(k, n, d, b);
    let vt = ops::pool_rows_slice(v, n, d, b);
    let s_low = qt.matmul_transb(&kt).scale(inv_sqrt_d); // (nb, nb)

    // --- Alg. 1: diagonal coverage + off-diagonal top-k --------------------
    let selected = mra2_select(&s_low, nb, m, causality);
    let mut per_row: Vec<Vec<usize>> = vec![Vec::new(); nb];
    let mut tiles = 0usize;
    for x in 0..nb {
        for y in 0..nb {
            if selected[x * nb + y] {
                per_row[x].push(y);
                tiles += 1;
            }
        }
    }
    let mut mb = vec![f32::NEG_INFINITY; nb];
    if variant == Variant::Full {
        for x in 0..nb {
            let visible = match causality {
                Causality::Bidirectional => nb,
                Causality::Causal => x + 1,
            };
            for y in 0..visible {
                if !selected[x * nb + y] {
                    mb[x] = mb[x].max(s_low.get(x, y));
                }
            }
        }
    }

    // --- packed panels: K^T (outer-product operand) + V row copies --------
    let mut kt_panels = vec![0.0f32; n * d];
    for (y, panel) in kt_panels.chunks_exact_mut(b * d).enumerate() {
        kernel::pack_transpose(&k[y * b * d..(y + 1) * b * d], b, d, panel);
    }
    let v_panels = v.to_vec();

    Mra2Plan {
        block: b,
        nb,
        d,
        variant,
        causality,
        inv_sqrt_d,
        per_row,
        selected,
        tiles,
        s_low,
        vt,
        mb,
        kt_panels,
        v_panels,
    }
}

/// Apply a plan to the query-block range `[x0, x1)`, writing the
/// row-normalized output rows `[x0*b, x1*b)` into `out` (length
/// `(x1 - x0) * b * d`).
///
/// §Perf (DESIGN.md §8): one **fused pass** per query block — each refined
/// tile is scored as an outer-product micro-GEMM over the plan's packed
/// K^T panel ([`kernel::score_panel`]), then immediately exponentiated and
/// aggregated against the packed V panel under per-row online (running
/// max) softmax rescaling ([`kernel::softmax_accum_panel`]).  Peak
/// transient memory is one `b x b` tile regardless of the budget, tile
/// memory traffic is half the old two-pass schedule, and all transients
/// live in the caller-owned `scratch`, so steady-state calls are
/// allocation-free.  Every query block is fully self-contained (scores,
/// denominators, low-res correction and normalization), which is what
/// makes the range embarrassingly parallel.
///
/// The running max seeds at the stabilization floor `mb[x]`, so the shared
/// low-res accumulator (anchored at the same floor) rescales per row by
/// `exp(mb[x] - rowmax)` — every `exp` stays in range exactly as in the
/// two-pass path.  [`mra2_apply_blocks_ref`] preserves that historical
/// path as the parity reference (<= 1e-5 max abs; float rounding differs,
/// the math does not).
pub fn mra2_apply_blocks(
    plan: &Mra2Plan,
    q: &[f32],
    x0: usize,
    x1: usize,
    out: &mut [f32],
    scratch: &mut Mra2Scratch,
) {
    let (b, d, nb) = (plan.block, plan.d, plan.nb);
    assert!(x0 <= x1 && x1 <= nb, "query-block range {x0}..{x1} out of 0..{nb}");
    assert_eq!(out.len(), (x1 - x0) * b * d, "out shard size mismatch");
    let causal = plan.causality == Causality::Causal;
    scratch.ensure(b, d);
    for x in x0..x1 {
        let oblk = &mut out[(x - x0) * b * d..(x - x0 + 1) * b * d];
        oblk.fill(0.0);
        let rowmax = &mut scratch.rowmax[..b];
        rowmax.fill(plan.mb[x]);
        let den = &mut scratch.den[..b];
        den.fill(0.0);
        let qblk = &q[x * b * d..(x + 1) * b * d];
        let tile = &mut scratch.tile[..b * b];
        for &y in &plan.per_row[x] {
            debug_assert!(!causal || y <= x, "causal selection above the diagonal");
            let kt_panel = &plan.kt_panels[y * b * d..(y + 1) * b * d];
            kernel::score_panel(qblk, d, kt_panel, b, plan.inv_sqrt_d, tile);
            if causal && y == x {
                // refined tile straddling the diagonal: per-row triangular
                // masking (key j = y*b + c is in the future of query
                // i = x*b + r exactly when c > r)
                for r in 0..b {
                    for t in tile[r * b + r + 1..(r + 1) * b].iter_mut() {
                        *t = f32::NEG_INFINITY;
                    }
                }
            }
            let v_panel = &plan.v_panels[y * b * d..(y + 1) * b * d];
            kernel::softmax_accum_panel(tile, v_panel, b, d, rowmax, den, oblk);
        }
        // low-resolution contribution: mu * (block sum of V) per region,
        // accumulated once at the mb[x] anchor and rescaled per row
        if plan.variant == Variant::Full {
            let yacc = &mut scratch.yacc[..d];
            yacc.fill(0.0);
            let mut dacc = 0.0f32;
            let mbx = plan.mb[x];
            for y in 0..nb {
                if plan.selected[x * nb + y] {
                    continue;
                }
                // causal: blocks above the diagonal are invisible, and the
                // diagonal block itself is always refined (coverage rule),
                // so the causal low-res set is strictly below the diagonal
                if causal && y >= x {
                    continue;
                }
                let mu = (plan.s_low.get(x, y) - mbx).exp() * b as f32;
                dacc += mu;
                kernel::axpy(yacc, plan.vt.row(y), mu);
            }
            if dacc > 0.0 {
                for r in 0..b {
                    // rowmax >= mb[x] by seeding, so w <= 1
                    let w = (mbx - rowmax[r]).exp();
                    den[r] += w * dacc;
                    kernel::axpy(&mut oblk[r * d..(r + 1) * d], yacc, w);
                }
            }
        }
        // row normalization (denominators are local to this query block)
        for r in 0..b {
            let inv = if den[r] > 0.0 { 1.0 / den[r] } else { 0.0 };
            kernel::scale(&mut oblk[r * d..(r + 1) * d], inv);
        }
    }
}

/// The historical two-pass scalar path (per-element dots over strided K
/// rows, block-max stabilization, separate exp + aggregation pass),
/// preserved verbatim as the parity/throughput reference for
/// [`mra2_apply_blocks`] — gated <= 1e-5 max abs in tests and
/// `benches/bench_attention.rs`.  Reads the caller's raw `k`/`v` buffers
/// and allocates per call; never use it on a hot path.
#[allow(clippy::too_many_arguments)]
pub fn mra2_apply_blocks_ref(
    plan: &Mra2Plan,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    x0: usize,
    x1: usize,
    out: &mut [f32],
) {
    let (b, d, nb) = (plan.block, plan.d, plan.nb);
    assert!(x0 <= x1 && x1 <= nb, "query-block range {x0}..{x1} out of 0..{nb}");
    assert_eq!(out.len(), (x1 - x0) * b * d, "out shard size mismatch");
    let causal = plan.causality == Causality::Causal;
    let max_tiles = plan.per_row[x0..x1].iter().map(Vec::len).max().unwrap_or(0);
    let mut tilebuf = vec![0.0f32; max_tiles * b * b];
    let mut den = vec![0.0f32; b];
    for x in x0..x1 {
        let obase = (x - x0) * b * d;
        out[obase..obase + b * d].fill(0.0);
        den.fill(0.0);
        let yset = &plan.per_row[x];
        // pass 1: exact P tiles for this query block + running max
        let mut block_max = plan.mb[x];
        for (t, &y) in yset.iter().enumerate() {
            debug_assert!(!causal || y <= x, "causal selection above the diagonal");
            let tile = &mut tilebuf[t * b * b..(t + 1) * b * b];
            for r in 0..b {
                let qrow = &q[(x * b + r) * d..(x * b + r + 1) * d];
                for c in 0..b {
                    // refined tile straddling the diagonal: per-row
                    // triangular masking (key j = y*b + c is in the future
                    // of query i = x*b + r exactly when c > r)
                    if causal && y == x && c > r {
                        tile[r * b + c] = f32::NEG_INFINITY;
                        continue;
                    }
                    let krow = &k[(y * b + c) * d..(y * b + c + 1) * d];
                    let s = crate::tensor::mat::dot(qrow, krow) * plan.inv_sqrt_d;
                    tile[r * b + c] = s;
                    if s > block_max {
                        block_max = s;
                    }
                }
            }
        }
        // pass 2: stabilized exp + value aggregation
        for (t, &y) in yset.iter().enumerate() {
            let tile = &tilebuf[t * b * b..(t + 1) * b * b];
            for r in 0..b {
                let orow = &mut out[obase + r * d..obase + (r + 1) * d];
                let mut dsum = 0.0f32;
                for c in 0..b {
                    let a = (tile[r * b + c] - block_max).exp();
                    dsum += a;
                    let vrow = &v[(y * b + c) * d..(y * b + c + 1) * d];
                    for (o, &vv) in orow.iter_mut().zip(vrow) {
                        *o += a * vv;
                    }
                }
                den[r] += dsum;
            }
        }
        // low-resolution contribution: mu * (block sum of V) per region
        if plan.variant == Variant::Full {
            let mut yacc = vec![0.0f32; d];
            let mut dacc = 0.0f32;
            for y in 0..nb {
                if plan.selected[x * nb + y] {
                    continue;
                }
                // causal: blocks above the diagonal are invisible, and the
                // diagonal block itself is always refined (coverage rule),
                // so the causal low-res set is strictly below the diagonal
                if causal && y >= x {
                    continue;
                }
                let mu = (plan.s_low.get(x, y) - block_max).exp();
                dacc += mu * b as f32;
                let vrow = plan.vt.row(y);
                for (o, &vv) in yacc.iter_mut().zip(vrow) {
                    *o += mu * b as f32 * vv;
                }
            }
            for r in 0..b {
                den[r] += dacc;
                let orow = &mut out[obase + r * d..obase + (r + 1) * d];
                for (o, &a) in orow.iter_mut().zip(&yacc) {
                    *o += a;
                }
            }
        }
        // row normalization (denominators are local to this query block)
        for r in 0..b {
            let inv = if den[r] > 0.0 { 1.0 / den[r] } else { 0.0 };
            for o in out[obase + r * d..obase + (r + 1) * d].iter_mut() {
                *o *= inv;
            }
        }
    }
}

/// Optimized two-scale fast path (MRA-2 / MRA-2-s): gathers the selected
/// `b x b` blocks and computes them with block matmuls, mirroring the
/// Pallas kernel schedule (DESIGN.md §4).  Returns `(Z_hat, stats)`.
pub fn mra2_attention_stats(
    q: &Mat,
    k: &Mat,
    v: &Mat,
    block: usize,
    m: usize,
    variant: Variant,
) -> (Mat, MraStats) {
    let (n, d) = (q.rows, q.cols);
    let plan =
        mra2_plan(&q.data, &k.data, &v.data, n, d, block, m, variant, Causality::Bidirectional);
    let mut out = Mat::zeros(n, d);
    let mut scratch = Mra2Scratch::for_plan(&plan);
    mra2_apply_blocks(&plan, &q.data, 0, plan.nb, &mut out.data, &mut scratch);
    let stats = plan.stats(n);
    (out, stats)
}

/// Optimized MRA-2 / MRA-2-s attention (row-normalized output).
pub fn mra2_attention(q: &Mat, k: &Mat, v: &Mat, block: usize, m: usize, variant: Variant) -> Mat {
    mra2_attention_stats(q, k, v, block, m, variant).0
}

/// Causal MRA-2 / MRA-2-s fast path: lower-triangular Alg. 1 selection
/// with per-row triangular masking of the refined diagonal tiles
/// (row-normalized output; see DESIGN.md §7).
pub fn mra2_attention_causal(
    q: &Mat,
    k: &Mat,
    v: &Mat,
    block: usize,
    m: usize,
    variant: Variant,
) -> Mat {
    let (n, d) = (q.rows, q.cols);
    let plan = mra2_plan(&q.data, &k.data, &v.data, n, d, block, m, variant, Causality::Causal);
    let mut out = Mat::zeros(n, d);
    let mut scratch = Mra2Scratch::for_plan(&plan);
    mra2_apply_blocks(&plan, &q.data, 0, plan.nb, &mut out.data, &mut scratch);
    out
}

/// Dense oracle for the two-scale approximation: materializes
/// `(A_hat, Z_hat)` with the same selection rule as the fast path.
pub fn dense_mra2(
    q: &Mat,
    k: &Mat,
    v: &Mat,
    block: usize,
    m: usize,
    variant: Variant,
) -> (Mat, Mat) {
    let (n, d) = (q.rows, q.cols);
    let b = block;
    let nb = n / b;
    let m = m.min(nb * nb).max(1);
    let inv_sqrt_d = 1.0 / (d as f32).sqrt();
    let qt = ops::pool_rows(q, b);
    let kt = ops::pool_rows(k, b);
    let s_low = qt.matmul_transb(&kt).scale(inv_sqrt_d);
    let p = ops::scores(q, k);
    // same coverage rule as the fast path: all diagonal blocks + the best
    // off-diagonal blocks with the remaining budget
    let selected = mra2_select(&s_low, nb, m, Causality::Bidirectional);
    let mut a_hat = Mat::zeros(n, n);
    for x in 0..nb {
        for y in 0..nb {
            if selected[x * nb + y] {
                for i in x * b..(x + 1) * b {
                    for j in y * b..(y + 1) * b {
                        a_hat.set(i, j, p.get(i, j).exp());
                    }
                }
            } else if variant == Variant::Full {
                let mu = s_low.get(x, y).exp();
                for i in x * b..(x + 1) * b {
                    for j in y * b..(y + 1) * b {
                        a_hat.set(i, j, mu);
                    }
                }
            }
        }
    }
    let den = ops::row_sums(&a_hat);
    // A_hat has structural zeros in the sparse variant — sparse-aware matmul
    let z = ops::div_rows(&a_hat.matmul_sparse(v), &den);
    let _ = d;
    (a_hat, z)
}

/// Dense causal oracle: the same per-query-block causal selection rule as
/// the fast path, materializing `(A_hat, Z_hat)` with per-row triangular
/// masking of every block touching the diagonal — the reference the causal
/// fast path is gated against (<= 1e-5 max abs at n in {256, 1024}).
pub fn dense_mra2_causal(
    q: &Mat,
    k: &Mat,
    v: &Mat,
    block: usize,
    m: usize,
    variant: Variant,
) -> (Mat, Mat) {
    let n = q.rows;
    let b = block;
    let nb = n / b;
    let m = m.min(nb * nb).max(1);
    let inv_sqrt_d = 1.0 / (q.cols as f32).sqrt();
    let qt = ops::pool_rows(q, b);
    let kt = ops::pool_rows(k, b);
    let s_low = qt.matmul_transb(&kt).scale(inv_sqrt_d);
    let p = ops::scores(q, k);
    let selected = mra2_select(&s_low, nb, m, Causality::Causal);
    let mut a_hat = Mat::zeros(n, n);
    for x in 0..nb {
        // blocks above the diagonal contribute nothing in causal mode
        for y in 0..=x {
            if selected[x * nb + y] {
                for i in x * b..(x + 1) * b {
                    for j in y * b..(y + 1) * b {
                        if j <= i {
                            a_hat.set(i, j, p.get(i, j).exp());
                        }
                    }
                }
            } else if variant == Variant::Full {
                // strictly-lower pooled block (fully visible); the `j <= i`
                // guard is the per-row triangular mask for any straddling
                // block, which the coverage rule keeps refined anyway
                let mu = s_low.get(x, y).exp();
                for i in x * b..(x + 1) * b {
                    for j in y * b..(y + 1) * b {
                        if j <= i {
                            a_hat.set(i, j, mu);
                        }
                    }
                }
            }
        }
    }
    let den = ops::row_sums(&a_hat);
    // the whole upper triangle of A_hat is structurally zero in causal mode
    let z = ops::div_rows(&a_hat.matmul_sparse(v), &den);
    (a_hat, z)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    fn setup(n: usize, d: usize, seed: u64) -> (Mat, Mat, Mat) {
        let mut rng = Rng::new(seed);
        (
            Mat::randn(n, d, 1.0, &mut rng),
            Mat::randn(n, d, 1.0, &mut rng),
            Mat::randn(n, d, 1.0, &mut rng),
        )
    }

    #[test]
    fn fast_path_matches_dense_oracle_full() {
        let (q, k, v) = setup(128, 16, 0);
        for m in [2, 5, 16, 40] {
            let (_, z_dense) = dense_mra2(&q, &k, &v, 16, m, Variant::Full);
            let z = mra2_attention(&q, &k, &v, 16, m, Variant::Full);
            assert!(ops::rel_fro_error(&z, &z_dense) < 1e-4, "m={m}");
        }
    }

    #[test]
    fn fast_path_matches_dense_oracle_sparse() {
        let (q, k, v) = setup(128, 16, 1);
        for m in [2, 5, 16, 40] {
            let (_, z_dense) = dense_mra2(&q, &k, &v, 16, m, Variant::Sparse);
            let z = mra2_attention(&q, &k, &v, 16, m, Variant::Sparse);
            assert!(ops::rel_fro_error(&z, &z_dense) < 1e-4, "m={m}");
        }
    }

    #[test]
    fn full_budget_equals_exact_attention() {
        let (q, k, v) = setup(64, 8, 2);
        let exact = ops::exact_attention(&q, &k, &v);
        for variant in [Variant::Full, Variant::Sparse] {
            let z = mra2_attention(&q, &k, &v, 16, 16, variant);
            assert!(ops::rel_fro_error(&z, &exact) < 1e-4, "{variant:?}");
        }
    }

    #[test]
    fn general_path_agrees_with_fast_path_two_scales() {
        let (q, k, v) = setup(64, 8, 3);
        let m = 7;
        let cfg = MraConfig::mra2(16, m);
        let z_gen = mra_attention(&q, &k, &v, &cfg);
        let z_fast = mra2_attention(&q, &k, &v, 16, m, Variant::Full);
        assert!(ops::rel_fro_error(&z_gen, &z_fast) < 1e-3);
    }

    #[test]
    fn general_path_three_scales_reasonable_error() {
        let (q, k, v) = setup(64, 8, 4);
        let cfg = MraConfig {
            scales: vec![16, 4, 1],
            budgets: vec![6, 24],
            include_diagonal: true,
            variant: Variant::Full,
        };
        let z = mra_attention(&q, &k, &v, &cfg);
        let exact = ops::exact_attention(&q, &k, &v);
        let err = ops::rel_fro_error(&z, &exact);
        assert!(err < 0.8, "err={err}");
    }

    #[test]
    fn error_decreases_with_budget() {
        let (q, k, v) = setup(128, 16, 5);
        let exact = ops::exact_attention(&q, &k, &v);
        let errs: Vec<f64> = [2usize, 8, 24, 64]
            .iter()
            .map(|&m| {
                let z = mra2_attention(&q, &k, &v, 16, m, Variant::Full);
                ops::rel_fro_error(&z, &exact)
            })
            .collect();
        assert!(errs[3] <= errs[0] + 1e-9, "{errs:?}");
        assert!(errs[3] < 1e-4); // full budget
    }

    #[test]
    fn full_variant_at_least_as_good_as_sparse_on_diffuse_attention() {
        // with diffuse attention the low-res correction must help
        let (q, k, v) = setup(128, 16, 6);
        let q = q.scale(0.3);
        let k = k.scale(0.3);
        let exact = ops::exact_attention(&q, &k, &v);
        let zf = mra2_attention(&q, &k, &v, 16, 10, Variant::Full);
        let zs = mra2_attention(&q, &k, &v, 16, 10, Variant::Sparse);
        let ef = ops::rel_fro_error(&zf, &exact);
        let es = ops::rel_fro_error(&zs, &exact);
        assert!(ef <= es + 0.02, "full {ef} vs sparse {es}");
    }

    #[test]
    fn workload_formula() {
        let cfg = MraConfig::mra2(32, 24);
        // (n/32)^2 + 24*32^2 + 2n at n = 1024
        assert_eq!(cfg.workload(1024), 32 * 32 + 24 * 1024 + 2048);
        let cfg3 = MraConfig {
            scales: vec![16, 4, 1],
            budgets: vec![3, 5],
            include_diagonal: true,
            variant: Variant::Full,
        };
        assert_eq!(cfg3.workload(64), 16 + 3 * 16 + 5 * 16 + 128);
    }

    #[test]
    fn stats_flops_scale_with_m_but_buffers_do_not() {
        let (q, k, v) = setup(128, 16, 7);
        let (_, s1) = mra2_attention_stats(&q, &k, &v, 16, 8, Variant::Full);
        let (_, s2) = mra2_attention_stats(&q, &k, &v, 16, 32, Variant::Full);
        assert!(s2.flops > s1.flops);
        // fused online-softmax pass: one tile of scratch regardless of the
        // budget (the old two-pass path buffered every tile of a block)
        assert_eq!(s2.buffer_elems, s1.buffer_elems);
        assert!(s1.buffer_elems > 0);
    }

    #[test]
    fn output_rows_convex_with_ones_values() {
        let (q, k, _) = setup(64, 8, 8);
        let v = Mat::full(64, 8, 1.0);
        for variant in [Variant::Full, Variant::Sparse] {
            let z = mra2_attention(&q, &k, &v, 16, 6, variant);
            for &x in z.data.iter() {
                assert!((x - 1.0).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn sparse_small_budget_has_no_zero_rows() {
        // regression: with m = 2 and nb = 8 the old +inf diagonal-prior
        // tie-break left six query blocks with no refined block, so their
        // denominators were 0 and whole output rows silently became zero.
        // With ones-values every row must remain a convex combination (= 1).
        let (q, k, _) = setup(128, 16, 9);
        let v = Mat::full(128, 16, 1.0);
        for variant in [Variant::Full, Variant::Sparse] {
            let z = mra2_attention(&q, &k, &v, 16, 2, variant);
            for (i, &x) in z.data.iter().enumerate() {
                assert!(
                    (x - 1.0).abs() < 1e-4,
                    "{variant:?}: row {} drifted ({x})",
                    i / 16
                );
            }
        }
    }

    #[test]
    fn plan_guarantees_query_block_coverage() {
        let (q, k, v) = setup(128, 16, 10);
        for m in [1, 2, 5, 8, 20, 64] {
            for variant in [Variant::Full, Variant::Sparse] {
                let plan = mra2_plan(
                    &q.data,
                    &k.data,
                    &v.data,
                    128,
                    16,
                    16,
                    m,
                    variant,
                    Causality::Bidirectional,
                );
                for (x, ys) in plan.per_row.iter().enumerate() {
                    assert!(!ys.is_empty(), "m={m}: query block {x} uncovered");
                    assert!(ys.contains(&x), "m={m}: diagonal missing at {x}");
                }
            }
        }
    }

    /// Exact causal attention reference (row `i` attends keys `j <= i`).
    fn exact_causal(q: &Mat, k: &Mat, v: &Mat) -> Mat {
        let (n, d) = (q.rows, v.cols);
        let p = ops::scores(q, k);
        let mut z = Mat::zeros(n, d);
        for i in 0..n {
            let mx = (0..=i).map(|j| p.get(i, j)).fold(f32::NEG_INFINITY, f32::max);
            let mut den = 0.0f32;
            for j in 0..=i {
                let a = (p.get(i, j) - mx).exp();
                den += a;
                for c in 0..d {
                    z.set(i, c, z.get(i, c) + a * v.get(j, c));
                }
            }
            for c in 0..d {
                z.set(i, c, z.get(i, c) / den.max(1e-30));
            }
        }
        z
    }

    #[test]
    fn causal_fast_path_matches_causal_dense_oracle() {
        let (q, k, v) = setup(128, 16, 12);
        for m in [2, 8, 16, 40] {
            for variant in [Variant::Full, Variant::Sparse] {
                let (_, z_dense) = dense_mra2_causal(&q, &k, &v, 16, m, variant);
                let z = mra2_attention_causal(&q, &k, &v, 16, m, variant);
                assert!(ops::rel_fro_error(&z, &z_dense) < 1e-4, "m={m} {variant:?}");
            }
        }
    }

    #[test]
    fn causal_acceptance_sizes_match_oracle_to_1e5_max_abs() {
        // acceptance criterion: causal fast path within 1e-5 max abs error
        // of the causal dense oracle at n in {256, 1024}
        for &(n, block, m) in &[(256usize, 32usize, 24usize), (1024, 32, 96)] {
            let (q, k, v) = setup(n, 16, 99);
            let (_, z_dense) = dense_mra2_causal(&q, &k, &v, block, m, Variant::Full);
            let z = mra2_attention_causal(&q, &k, &v, block, m, Variant::Full);
            let max_abs = z
                .data
                .iter()
                .zip(&z_dense.data)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(max_abs <= 1e-5, "n={n}: max abs err {max_abs}");
        }
    }

    #[test]
    fn causal_full_budget_matches_exact_causal_attention() {
        let (q, k, v) = setup(64, 8, 13);
        let exact = exact_causal(&q, &k, &v);
        // m = nb^2 refines every visible block in both variants
        for variant in [Variant::Full, Variant::Sparse] {
            let z = mra2_attention_causal(&q, &k, &v, 16, 16, variant);
            assert!(ops::rel_fro_error(&z, &exact) < 1e-4, "{variant:?}");
        }
    }

    #[test]
    fn causal_rows_stay_convex_with_ones_values() {
        // every causal row is a convex combination of past values — with
        // ones-values each output entry must be exactly 1 even at tiny
        // budgets (the causal analog of the zero-row regression)
        let (q, k, _) = setup(128, 16, 14);
        let v = Mat::full(128, 16, 1.0);
        for m in [1, 2, 8, 32] {
            for variant in [Variant::Full, Variant::Sparse] {
                let z = mra2_attention_causal(&q, &k, &v, 16, m, variant);
                for (i, &x) in z.data.iter().enumerate() {
                    assert!(
                        (x - 1.0).abs() < 1e-4,
                        "m={m} {variant:?}: row {} drifted ({x})",
                        i / 16
                    );
                }
            }
        }
    }

    #[test]
    fn causal_plan_never_selects_above_the_diagonal() {
        let (q, k, v) = setup(128, 16, 15);
        for m in [1, 5, 16, 64] {
            let plan = mra2_plan(
                &q.data,
                &k.data,
                &v.data,
                128,
                16,
                16,
                m,
                Variant::Full,
                Causality::Causal,
            );
            for (x, ys) in plan.per_row.iter().enumerate() {
                assert!(ys.contains(&x), "m={m}: diagonal missing at {x}");
                assert!(
                    ys.iter().all(|&y| y <= x),
                    "m={m}: block {x} refined the future: {ys:?}"
                );
            }
            // the first query block can only ever see itself
            assert_eq!(plan.per_row[0], vec![0]);
        }
    }

    #[test]
    fn causal_apply_blocks_sharding_is_exact() {
        // the engine shards causal heads by query block too; shard
        // boundaries must not change a single bit
        let (q, k, v) = setup(128, 16, 16);
        for variant in [Variant::Full, Variant::Sparse] {
            let plan = mra2_plan(
                &q.data,
                &k.data,
                &v.data,
                128,
                16,
                16,
                12,
                variant,
                Causality::Causal,
            );
            let mut scratch = Mra2Scratch::new();
            let mut full = vec![0.0f32; 128 * 16];
            mra2_apply_blocks(&plan, &q.data, 0, plan.nb, &mut full, &mut scratch);
            let mut sharded = vec![0.0f32; 128 * 16];
            let rows_per_block = plan.block * plan.d;
            for (x0, x1) in [(0usize, 2usize), (2, 5), (5, 8)] {
                let shard = &mut sharded[x0 * rows_per_block..x1 * rows_per_block];
                // fresh scratch per shard: scratch state must never leak
                mra2_apply_blocks(&plan, &q.data, x0, x1, shard, &mut Mra2Scratch::new());
            }
            assert_eq!(full, sharded, "{variant:?}");
        }
    }

    #[test]
    fn scratch_is_reused_with_zero_growth_and_identical_results() {
        // satellite gate: a second application of the same plan must not
        // grow the scratch arena (steady-state calls are allocation-free)
        // and must produce bit-identical output
        let (q, k, v) = setup(128, 16, 20);
        for causality in [Causality::Bidirectional, Causality::Causal] {
            let plan = mra2_plan(
                &q.data,
                &k.data,
                &v.data,
                128,
                16,
                16,
                12,
                Variant::Full,
                causality,
            );
            let mut scratch = Mra2Scratch::new();
            let mut out1 = vec![0.0f32; 128 * 16];
            mra2_apply_blocks(&plan, &q.data, 0, plan.nb, &mut out1, &mut scratch);
            let footprint = scratch.heap_elems();
            assert!(footprint > 0, "first call must size the arena");
            let mut out2 = vec![0.0f32; 128 * 16];
            mra2_apply_blocks(&plan, &q.data, 0, plan.nb, &mut out2, &mut scratch);
            assert_eq!(
                scratch.heap_elems(),
                footprint,
                "{causality:?}: steady-state apply grew the scratch"
            );
            assert_eq!(out1, out2, "{causality:?}: scratch reuse changed results");
        }
        // pre-sized scratch never grows at all
        let plan = mra2_plan(
            &q.data,
            &k.data,
            &v.data,
            128,
            16,
            16,
            12,
            Variant::Full,
            Causality::Bidirectional,
        );
        let mut scratch = Mra2Scratch::for_plan(&plan);
        let before = scratch.heap_elems();
        let mut out = vec![0.0f32; 128 * 16];
        mra2_apply_blocks(&plan, &q.data, 0, plan.nb, &mut out, &mut scratch);
        assert_eq!(scratch.heap_elems(), before, "for_plan scratch grew on first use");
    }

    #[test]
    fn fused_apply_matches_scalar_reference_within_1e5() {
        // the fused online-softmax path vs the preserved two-pass scalar
        // reference: same math, different float rounding — <= 1e-5 max abs
        let (q, k, v) = setup(128, 16, 21);
        for causality in [Causality::Bidirectional, Causality::Causal] {
            for variant in [Variant::Full, Variant::Sparse] {
                for m in [2usize, 8, 24] {
                    let plan = mra2_plan(
                        &q.data, &k.data, &v.data, 128, 16, 16, m, variant, causality,
                    );
                    let mut fused = vec![0.0f32; 128 * 16];
                    mra2_apply_blocks(
                        &plan,
                        &q.data,
                        0,
                        plan.nb,
                        &mut fused,
                        &mut Mra2Scratch::new(),
                    );
                    let mut reference = vec![0.0f32; 128 * 16];
                    mra2_apply_blocks_ref(
                        &plan, &q.data, &k.data, &v.data, 0, plan.nb, &mut reference,
                    );
                    let max_abs = fused
                        .iter()
                        .zip(&reference)
                        .map(|(a, b)| (a - b).abs())
                        .fold(0.0f32, f32::max);
                    assert!(
                        max_abs <= 1e-5,
                        "{causality:?} {variant:?} m={m}: max abs {max_abs}"
                    );
                }
            }
        }
    }

    #[test]
    fn apply_blocks_sharding_is_exact() {
        // the engine shards one head by query-block ranges; shard
        // boundaries must not change a single bit of the output
        let (q, k, v) = setup(128, 16, 11);
        for variant in [Variant::Full, Variant::Sparse] {
            let plan = mra2_plan(
                &q.data,
                &k.data,
                &v.data,
                128,
                16,
                16,
                6,
                variant,
                Causality::Bidirectional,
            );
            let mut scratch = Mra2Scratch::new();
            let mut full = vec![0.0f32; 128 * 16];
            mra2_apply_blocks(&plan, &q.data, 0, plan.nb, &mut full, &mut scratch);
            let mut sharded = vec![0.0f32; 128 * 16];
            let rows_per_block = plan.block * plan.d;
            for (x0, x1) in [(0usize, 3usize), (3, 4), (4, 8)] {
                let shard = &mut sharded[x0 * rows_per_block..x1 * rows_per_block];
                // one reused scratch across shards: same bits either way
                mra2_apply_blocks(&plan, &q.data, x0, x1, shard, &mut scratch);
            }
            assert_eq!(full, sharded, "{variant:?}");
        }
    }
}
