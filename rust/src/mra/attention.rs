//! End-to-end MRA attention: configuration, the general multi-scale path
//! (Alg. 1 + Alg. 2), the optimized two-scale MRA-2 / MRA-2-s fast path,
//! and the dense oracle used by tests and Fig. 8.

use crate::mra::matvec;
use crate::mra::pyramid::Pyramid;
use crate::mra::select::{construct_j, Scored};
use crate::tensor::{ops, topk, Mat};

/// Which components of the approximation are kept (Sec. 5).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Variant {
    /// MRA-2: low-resolution everywhere + exact refined blocks.
    Full,
    /// MRA-2-s: only the refined (finest-scale) blocks — block-sparse.
    Sparse,
}

/// Configuration of the multiresolution approximation.
#[derive(Clone, Debug)]
pub struct MraConfig {
    /// Descending scale ladder `R` (powers of two, last entry usually 1).
    pub scales: Vec<usize>,
    /// Refinement budgets `m_i`, one per adjacent scale pair.
    pub budgets: Vec<usize>,
    /// Seed diagonal blocks into the refinement set (Alg. 1 prior).
    pub include_diagonal: bool,
    pub variant: Variant,
}

impl MraConfig {
    /// The paper's MRA-2: `R = {block, 1}` with budget `m` refined blocks.
    pub fn mra2(block: usize, m: usize) -> Self {
        MraConfig {
            scales: vec![block, 1],
            budgets: vec![m],
            include_diagonal: true,
            variant: Variant::Full,
        }
    }

    /// MRA-2-s (block-sparse variant).
    pub fn mra2_sparse(block: usize, m: usize) -> Self {
        MraConfig { variant: Variant::Sparse, ..Self::mra2(block, m) }
    }

    pub fn validate(&self, n: usize) {
        assert!(!self.scales.is_empty());
        assert_eq!(self.budgets.len(), self.scales.len() - 1);
        for &s in &self.scales {
            assert!(s.is_power_of_two() && n % s == 0, "scale {s} vs n {n}");
        }
        for w in self.scales.windows(2) {
            assert!(w[0] > w[1]);
        }
    }

    /// Theoretical workload (number of `mu` evaluations, Sec. 4.4):
    /// `(n/s0)^2 + sum_i m_i (s_{i-1}/s_i)^2` plus the `O(n)` pyramid.
    pub fn workload(&self, n: usize) -> usize {
        let s0 = self.scales[0];
        let mut total = (n / s0) * (n / s0) + 2 * n;
        for (i, &m) in self.budgets.iter().enumerate() {
            let ratio = self.scales[i] / self.scales[i + 1];
            total += m * ratio * ratio;
        }
        total
    }
}

/// General multi-scale MRA attention (arbitrary ladder) via
/// Alg. 1 + Alg. 2.  Returns the row-normalized `Z_hat`.
pub fn mra_attention(q: &Mat, k: &Mat, v: &Mat, cfg: &MraConfig) -> Mat {
    let n = q.rows;
    cfg.validate(n);
    let qpyr = Pyramid::build(q, &cfg.scales);
    let kpyr = Pyramid::build(k, &cfg.scales);
    let vpyr = Pyramid::build(v, &cfg.scales);
    let sel = construct_j(&qpyr, &kpyr, n, q.cols, &cfg.scales, &cfg.budgets, cfg.include_diagonal);
    let blocks: Vec<Scored> = match cfg.variant {
        Variant::Full => sel.blocks,
        Variant::Sparse => sel.finest_only(*cfg.scales.last().unwrap()),
    };
    matvec::compute(&blocks, &vpyr, n, &cfg.scales).normalized()
}

/// Workload statistics of one MRA-2 invocation (feeds Fig. 7 left).
#[derive(Clone, Copy, Debug, Default)]
pub struct MraStats {
    /// `mu` evaluations (low-res grid + refined entries).
    pub mu_evals: usize,
    /// Multiply–accumulate count on the hot path.
    pub flops: usize,
    /// Peak transient f32 buffer footprint (elements).
    pub buffer_elems: usize,
}

/// Optimized two-scale fast path (MRA-2 / MRA-2-s): gathers the selected
/// `b x b` blocks and computes them with block matmuls, mirroring the
/// Pallas kernel schedule (DESIGN.md §4).  Returns `(Z_hat, stats)`.
pub fn mra2_attention_stats(
    q: &Mat,
    k: &Mat,
    v: &Mat,
    block: usize,
    m: usize,
    variant: Variant,
) -> (Mat, MraStats) {
    let (n, d) = (q.rows, q.cols);
    assert!(n % block == 0, "block {block} must divide n={n}");
    let b = block;
    let nb = n / b;
    let m = m.min(nb * nb).max(1);
    let inv_sqrt_d = 1.0 / (d as f32).sqrt();
    let mut stats = MraStats::default();

    // --- pyramid + low-res scores (Eq. 7 / Eq. 6) --------------------------
    let qt = ops::pool_rows(q, b);
    let kt = ops::pool_rows(k, b);
    let vt = ops::pool_rows(v, b);
    let s_low = qt.matmul_transb(&kt).scale(inv_sqrt_d); // (nb, nb)
    stats.mu_evals += nb * nb;
    stats.flops += nb * nb * d + 3 * n * d;

    // --- Alg. 1: top-m selection with diagonal prior -----------------------
    let mut prio = s_low.data.clone();
    for i in 0..nb {
        prio[i * nb + i] = f32::INFINITY;
    }
    let chosen = topk::top_k_indices(&prio, m);
    let mut selected = vec![false; nb * nb];
    let mut per_row: Vec<Vec<usize>> = vec![Vec::new(); nb]; // y's per x
    for &c in &chosen {
        selected[c] = true;
        per_row[c / nb].push(c % nb);
    }

    // --- refined blocks + Alg. 2 accumulation, per query block -------------
    // §Perf: tiles are computed per query block into a single reusable
    // buffer (no per-tile Mat allocations, no row_block clones); the
    // two-pass max stabilization happens within the block's tile set, so
    // peak transient memory is O(max_tiles_per_row * b^2) instead of
    // O(m * b^2).  See EXPERIMENTS.md §Perf for the before/after.
    let max_tiles = per_row.iter().map(Vec::len).max().unwrap_or(0);
    let mut tilebuf = vec![0.0f32; max_tiles * b * b];
    stats.mu_evals += m * b * b;
    stats.buffer_elems = max_tiles * b * b + 3 * nb * d + nb * nb;
    let mut mb = vec![f32::NEG_INFINITY; nb];
    if variant == Variant::Full {
        for x in 0..nb {
            for y in 0..nb {
                if !selected[x * nb + y] {
                    mb[x] = mb[x].max(s_low.get(x, y));
                }
            }
        }
    }
    let mut out = Mat::zeros(n, d);
    let mut den = vec![0.0f32; n];
    for x in 0..nb {
        if per_row[x].is_empty() {
            continue;
        }
        // pass 1: exact P tiles for this query block + running max
        let mut block_max = mb[x];
        for (t, &y) in per_row[x].iter().enumerate() {
            let tile = &mut tilebuf[t * b * b..(t + 1) * b * b];
            for r in 0..b {
                let qrow = q.row(x * b + r);
                for c in 0..b {
                    let s = crate::tensor::mat::dot(qrow, k.row(y * b + c)) * inv_sqrt_d;
                    tile[r * b + c] = s;
                    if s > block_max {
                        block_max = s;
                    }
                }
            }
            stats.flops += b * b * d;
        }
        mb[x] = block_max;
        // pass 2: stabilized exp + value aggregation
        for (t, &y) in per_row[x].iter().enumerate() {
            let tile = &tilebuf[t * b * b..(t + 1) * b * b];
            for r in 0..b {
                let i = x * b + r;
                let orow = out.row_mut(i);
                let mut dsum = 0.0f32;
                for c in 0..b {
                    let a = (tile[r * b + c] - block_max).exp();
                    dsum += a;
                    let vrow = v.row(y * b + c);
                    for (o, &vv) in orow.iter_mut().zip(vrow) {
                        *o += a * vv;
                    }
                }
                den[i] += dsum;
            }
            stats.flops += b * b * (d + 2);
        }
    }
    if variant == Variant::Full {
        // low-resolution contribution: mu * (block sum of V) per region
        for x in 0..nb {
            let shift = mb[x];
            let mut yacc = vec![0.0f32; d];
            let mut dacc = 0.0f32;
            for y in 0..nb {
                if selected[x * nb + y] {
                    continue;
                }
                let mu = (s_low.get(x, y) - shift).exp();
                dacc += mu * b as f32;
                let vrow = vt.row(y);
                for (o, &vv) in yacc.iter_mut().zip(vrow) {
                    *o += mu * b as f32 * vv;
                }
                stats.flops += d + 2;
            }
            for r in 0..b {
                let i = x * b + r;
                den[i] += dacc;
                let orow = out.row_mut(i);
                for (o, &a) in orow.iter_mut().zip(&yacc) {
                    *o += a;
                }
            }
        }
    }
    for i in 0..n {
        let inv = if den[i] > 0.0 { 1.0 / den[i] } else { 0.0 };
        for vv in out.row_mut(i) {
            *vv *= inv;
        }
    }
    (out, stats)
}

/// Optimized MRA-2 / MRA-2-s attention (row-normalized output).
pub fn mra2_attention(q: &Mat, k: &Mat, v: &Mat, block: usize, m: usize, variant: Variant) -> Mat {
    mra2_attention_stats(q, k, v, block, m, variant).0
}

/// Dense oracle for the two-scale approximation: materializes
/// `(A_hat, Z_hat)` with the same selection rule as the fast path.
pub fn dense_mra2(
    q: &Mat,
    k: &Mat,
    v: &Mat,
    block: usize,
    m: usize,
    variant: Variant,
) -> (Mat, Mat) {
    let (n, d) = (q.rows, q.cols);
    let b = block;
    let nb = n / b;
    let m = m.min(nb * nb).max(1);
    let inv_sqrt_d = 1.0 / (d as f32).sqrt();
    let qt = ops::pool_rows(q, b);
    let kt = ops::pool_rows(k, b);
    let s_low = qt.matmul_transb(&kt).scale(inv_sqrt_d);
    let p = ops::scores(q, k);
    let mut prio = s_low.data.clone();
    for i in 0..nb {
        prio[i * nb + i] = f32::INFINITY;
    }
    let chosen = topk::top_k_indices(&prio, m);
    let mut selected = vec![false; nb * nb];
    for &c in &chosen {
        selected[c] = true;
    }
    let mut a_hat = Mat::zeros(n, n);
    for x in 0..nb {
        for y in 0..nb {
            if selected[x * nb + y] {
                for i in x * b..(x + 1) * b {
                    for j in y * b..(y + 1) * b {
                        a_hat.set(i, j, p.get(i, j).exp());
                    }
                }
            } else if variant == Variant::Full {
                let mu = s_low.get(x, y).exp();
                for i in x * b..(x + 1) * b {
                    for j in y * b..(y + 1) * b {
                        a_hat.set(i, j, mu);
                    }
                }
            }
        }
    }
    let den = ops::row_sums(&a_hat);
    let z = ops::div_rows(&a_hat.matmul(v), &den);
    let _ = d;
    (a_hat, z)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    fn setup(n: usize, d: usize, seed: u64) -> (Mat, Mat, Mat) {
        let mut rng = Rng::new(seed);
        (
            Mat::randn(n, d, 1.0, &mut rng),
            Mat::randn(n, d, 1.0, &mut rng),
            Mat::randn(n, d, 1.0, &mut rng),
        )
    }

    #[test]
    fn fast_path_matches_dense_oracle_full() {
        let (q, k, v) = setup(128, 16, 0);
        for m in [5, 16, 40] {
            let (_, z_dense) = dense_mra2(&q, &k, &v, 16, m, Variant::Full);
            let z = mra2_attention(&q, &k, &v, 16, m, Variant::Full);
            assert!(ops::rel_fro_error(&z, &z_dense) < 1e-4, "m={m}");
        }
    }

    #[test]
    fn fast_path_matches_dense_oracle_sparse() {
        let (q, k, v) = setup(128, 16, 1);
        for m in [5, 16, 40] {
            let (_, z_dense) = dense_mra2(&q, &k, &v, 16, m, Variant::Sparse);
            let z = mra2_attention(&q, &k, &v, 16, m, Variant::Sparse);
            assert!(ops::rel_fro_error(&z, &z_dense) < 1e-4, "m={m}");
        }
    }

    #[test]
    fn full_budget_equals_exact_attention() {
        let (q, k, v) = setup(64, 8, 2);
        let exact = ops::exact_attention(&q, &k, &v);
        for variant in [Variant::Full, Variant::Sparse] {
            let z = mra2_attention(&q, &k, &v, 16, 16, variant);
            assert!(ops::rel_fro_error(&z, &exact) < 1e-4, "{variant:?}");
        }
    }

    #[test]
    fn general_path_agrees_with_fast_path_two_scales() {
        let (q, k, v) = setup(64, 8, 3);
        let m = 7;
        let cfg = MraConfig::mra2(16, m);
        let z_gen = mra_attention(&q, &k, &v, &cfg);
        let z_fast = mra2_attention(&q, &k, &v, 16, m, Variant::Full);
        assert!(ops::rel_fro_error(&z_gen, &z_fast) < 1e-3);
    }

    #[test]
    fn general_path_three_scales_reasonable_error() {
        let (q, k, v) = setup(64, 8, 4);
        let cfg = MraConfig {
            scales: vec![16, 4, 1],
            budgets: vec![6, 24],
            include_diagonal: true,
            variant: Variant::Full,
        };
        let z = mra_attention(&q, &k, &v, &cfg);
        let exact = ops::exact_attention(&q, &k, &v);
        let err = ops::rel_fro_error(&z, &exact);
        assert!(err < 0.8, "err={err}");
    }

    #[test]
    fn error_decreases_with_budget() {
        let (q, k, v) = setup(128, 16, 5);
        let exact = ops::exact_attention(&q, &k, &v);
        let errs: Vec<f64> = [2usize, 8, 24, 64]
            .iter()
            .map(|&m| {
                let z = mra2_attention(&q, &k, &v, 16, m, Variant::Full);
                ops::rel_fro_error(&z, &exact)
            })
            .collect();
        assert!(errs[3] <= errs[0] + 1e-9, "{errs:?}");
        assert!(errs[3] < 1e-4); // full budget
    }

    #[test]
    fn full_variant_at_least_as_good_as_sparse_on_diffuse_attention() {
        // with diffuse attention the low-res correction must help
        let (q, k, v) = setup(128, 16, 6);
        let q = q.scale(0.3);
        let k = k.scale(0.3);
        let exact = ops::exact_attention(&q, &k, &v);
        let zf = mra2_attention(&q, &k, &v, 16, 10, Variant::Full);
        let zs = mra2_attention(&q, &k, &v, 16, 10, Variant::Sparse);
        let ef = ops::rel_fro_error(&zf, &exact);
        let es = ops::rel_fro_error(&zs, &exact);
        assert!(ef <= es + 0.02, "full {ef} vs sparse {es}");
    }

    #[test]
    fn workload_formula() {
        let cfg = MraConfig::mra2(32, 24);
        // (n/32)^2 + 24*32^2 + 2n at n = 1024
        assert_eq!(cfg.workload(1024), 32 * 32 + 24 * 1024 + 2048);
        let cfg3 = MraConfig {
            scales: vec![16, 4, 1],
            budgets: vec![3, 5],
            include_diagonal: true,
            variant: Variant::Full,
        };
        assert_eq!(cfg3.workload(64), 16 + 3 * 16 + 5 * 16 + 128);
    }

    #[test]
    fn stats_buffer_scales_with_m() {
        let (q, k, v) = setup(128, 16, 7);
        let (_, s1) = mra2_attention_stats(&q, &k, &v, 16, 8, Variant::Full);
        let (_, s2) = mra2_attention_stats(&q, &k, &v, 16, 32, Variant::Full);
        assert!(s2.buffer_elems > s1.buffer_elems);
        assert!(s2.flops > s1.flops);
    }

    #[test]
    fn output_rows_convex_with_ones_values() {
        let (q, k, _) = setup(64, 8, 8);
        let v = Mat::full(64, 8, 1.0);
        for variant in [Variant::Full, Variant::Sparse] {
            let z = mra2_attention(&q, &k, &v, 16, 6, variant);
            for &x in z.data.iter() {
                assert!((x - 1.0).abs() < 1e-4);
            }
        }
    }
}
