//! Alg. 2: compute `A_hat V` and the row sums `D_hat` without ever
//! materializing the `n x n` matrix.
//!
//! Contributions are accumulated coarse-to-fine at block granularity
//! (`Y_s[x] += mu * s * V~_s[y]`, then rows are duplicated when moving to
//! the next finer scale), exactly the telescoping structure of Alg. 2.
//! The `s` factor converts the *averaged* `V~_s` rows back to block sums.
//!
//! Numerical note: `mu = exp(log_mu)` is taken after subtracting the global
//! max `log_mu` — a pure shift that cancels in the softmax normalization
//! but keeps every `exp` in range (the CPU analog of the kernel's two-pass
//! stabilization).

use anyhow::{anyhow, Result};

use crate::mra::pyramid::Pyramid;
use crate::mra::select::Scored;
use crate::tensor::Mat;

/// Unnormalized result of Alg. 2: numerator rows and the row sums, both
/// computed under a shared exponent shift.
pub struct MatVec {
    /// `(n, d)` numerator `A_hat V` (scaled by `exp(-shift)`).
    pub y: Mat,
    /// `(n,)` row sums `D_hat` (same scaling).
    pub d: Vec<f32>,
    /// The exponent shift that was applied (for diagnostics).
    pub shift: f32,
}

impl MatVec {
    /// Row-normalized output `D_hat^{-1} A_hat V` (rows with an empty
    /// support — possible for MRA-2-s without diagonal seeding — yield 0).
    pub fn normalized(&self) -> Mat {
        let mut out = self.y.clone();
        for i in 0..out.rows {
            let den = self.d[i];
            let inv = if den > 0.0 { 1.0 / den } else { 0.0 };
            for v in out.row_mut(i) {
                *v *= inv;
            }
        }
        out
    }
}

/// Run Alg. 2 over the final set `J` (`blocks`) and the value pyramid.
///
/// `scales` must be the descending ladder used for selection; a block
/// whose scale is missing from it (or from the pyramid) is a descriptive
/// error listing the known scales — no panic (mirroring the
/// `kernel_by_name` contract; callers with a validated ladder may
/// `expect`).
pub fn compute(blocks: &[Scored], vpyr: &Pyramid, n: usize, scales: &[usize]) -> Result<MatVec> {
    let d_model = vpyr.at(scales[0])?.cols;
    let shift = blocks
        .iter()
        .map(|s| s.log_mu)
        .fold(f32::NEG_INFINITY, f32::max)
        .max(0.0);

    // group blocks by scale for the coarse-to-fine sweep
    let mut by_scale: Vec<Vec<&Scored>> = vec![Vec::new(); scales.len()];
    for b in blocks {
        let li = scales.iter().position(|&s| s == b.block.scale).ok_or_else(|| {
            anyhow!(
                "block scale {} not in ladder (known scales: {scales:?})",
                b.block.scale
            )
        })?;
        by_scale[li].push(b);
    }

    // Y / D accumulators start at the coarsest scale
    let s0 = scales[0];
    let mut y = Mat::zeros(n / s0, d_model);
    let mut dsum = vec![0.0f32; n / s0];

    for (li, &s) in scales.iter().enumerate() {
        if li > 0 {
            // duplicate rows: previous scale -> current scale
            let ratio = scales[li - 1] / s;
            let mut y2 = Mat::zeros(n / s, d_model);
            let mut d2 = vec![0.0f32; n / s];
            for r in 0..y.rows {
                for t in 0..ratio {
                    y2.row_mut(r * ratio + t).copy_from_slice(y.row(r));
                    d2[r * ratio + t] = dsum[r];
                }
            }
            y = y2;
            dsum = d2;
        }
        let vt = vpyr.at(s)?;
        for sb in &by_scale[li] {
            let mu = (sb.log_mu - shift).exp();
            if mu == 0.0 {
                continue;
            }
            let w = mu * s as f32; // block-sum of V rows = s * mean
            let yrow = y.row_mut(sb.block.x);
            for (o, &v) in yrow.iter_mut().zip(vt.row(sb.block.y)) {
                *o += w * v;
            }
            dsum[sb.block.x] += mu * s as f32;
        }
    }

    // expand to full resolution if the finest scale is > 1
    let s_fin = *scales.last().unwrap();
    if s_fin > 1 {
        let mut y2 = Mat::zeros(n, d_model);
        let mut d2 = vec![0.0f32; n];
        for r in 0..y.rows {
            for t in 0..s_fin {
                y2.row_mut(r * s_fin + t).copy_from_slice(y.row(r));
                d2[r * s_fin + t] = dsum[r];
            }
        }
        y = y2;
        dsum = d2;
    }
    Ok(MatVec { y, d: dsum, shift })
}

/// Dense oracle: materialize `A_hat` from the same block set (test / Fig. 8
/// support visualization path).
pub fn dense_a_hat(blocks: &[Scored], n: usize) -> Mat {
    let mut a = Mat::zeros(n, n);
    for sb in blocks {
        let mu = sb.log_mu.exp();
        let (r0, r1) = sb.block.rows();
        let (c0, c1) = sb.block.cols();
        for i in r0..r1 {
            for j in c0..c1 {
                a.set(i, j, mu);
            }
        }
    }
    a
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mra::select::construct_j;
    use crate::tensor::{ops, Rng};

    fn setup(n: usize, d: usize, seed: u64) -> (Mat, Mat, Mat) {
        let mut rng = Rng::new(seed);
        (
            Mat::randn(n, d, 1.0, &mut rng),
            Mat::randn(n, d, 1.0, &mut rng),
            Mat::randn(n, d, 1.0, &mut rng),
        )
    }

    #[test]
    fn matvec_matches_dense_two_scale() {
        let (n, d) = (64, 8);
        let scales = [16usize, 1];
        let (q, k, v) = setup(n, d, 0);
        let qp = Pyramid::build(&q, &scales);
        let kp = Pyramid::build(&k, &scales);
        let vp = Pyramid::build(&v, &scales);
        let sel = construct_j(&qp, &kp, n, d, &scales, &[5], true).unwrap();
        let mv = compute(&sel.blocks, &vp, n, &scales).unwrap();
        let a = dense_a_hat(&sel.blocks, n);
        let want = a.matmul(&v);
        let scale = mv.shift.exp();
        for i in 0..n {
            for j in 0..d {
                let got = mv.y.get(i, j) * scale;
                assert!(
                    (got - want.get(i, j)).abs() < 1e-2 * want.get(i, j).abs().max(1.0),
                    "({i},{j}): {got} vs {}",
                    want.get(i, j)
                );
            }
        }
        // row sums match too
        let dsum = ops::row_sums(&a);
        for i in 0..n {
            let got = mv.d[i] * scale;
            assert!((got - dsum[i]).abs() < 1e-2 * dsum[i].abs().max(1.0));
        }
    }

    #[test]
    fn matvec_matches_dense_three_scale() {
        let (n, d) = (64, 4);
        let scales = [16usize, 4, 1];
        let (q, k, v) = setup(n, d, 1);
        let qp = Pyramid::build(&q, &scales);
        let kp = Pyramid::build(&k, &scales);
        let vp = Pyramid::build(&v, &scales);
        let sel = construct_j(&qp, &kp, n, d, &scales, &[3, 6], true).unwrap();
        let mv = compute(&sel.blocks, &vp, n, &scales).unwrap();
        let a = dense_a_hat(&sel.blocks, n);
        let z_dense = {
            let den = ops::row_sums(&a);
            ops::div_rows(&a.matmul(&v), &den)
        };
        let z = mv.normalized();
        assert!(ops::rel_fro_error(&z, &z_dense) < 1e-4);
    }

    /// Regression for the error-text contract: a block whose scale is
    /// missing from the ladder is a `Result` (no panic) whose message
    /// lists the known scales.
    #[test]
    fn unknown_block_scale_error_lists_the_ladder() {
        use crate::mra::frame::Block;
        let n = 32;
        let scales = [8usize, 1];
        let v = Mat::full(n, 2, 1.0);
        let vp = Pyramid::build(&v, &scales);
        let blocks = vec![Scored { block: Block { scale: 4, x: 0, y: 0 }, log_mu: 0.0 }];
        let err = compute(&blocks, &vp, n, &scales).err().expect("must error");
        let msg = format!("{err:#}");
        assert!(msg.contains("block scale 4 not in ladder"), "{msg}");
        assert!(msg.contains("known scales"), "{msg}");
        assert!(msg.contains("[8, 1]"), "{msg}");
    }

    #[test]
    fn normalized_rows_are_convex_combinations() {
        // with V = all-ones, any row-normalized A_hat V must be exactly 1
        let (n, d) = (32, 4);
        let scales = [8usize, 1];
        let (q, k, _) = setup(n, d, 2);
        let v = Mat::full(n, d, 1.0);
        let qp = Pyramid::build(&q, &scales);
        let kp = Pyramid::build(&k, &scales);
        let vp = Pyramid::build(&v, &scales);
        let sel = construct_j(&qp, &kp, n, d, &scales, &[4], true).unwrap();
        let z = compute(&sel.blocks, &vp, n, &scales).unwrap().normalized();
        for &x in z.data.iter() {
            assert!((x - 1.0).abs() < 1e-4, "{x}");
        }
    }

    #[test]
    fn shift_invariance() {
        // the normalized output must not depend on the stabilization shift,
        // which we exercise by scaling Q (shifting all log mu)
        let (n, d) = (32, 4);
        let scales = [8usize, 1];
        let (q, k, v) = setup(n, d, 3);
        let kp = Pyramid::build(&k, &scales);
        let vp = Pyramid::build(&v, &scales);
        let qp = Pyramid::build(&q, &scales);
        let sel = construct_j(&qp, &kp, n, d, &scales, &[6], true).unwrap();
        let z1 = compute(&sel.blocks, &vp, n, &scales).unwrap().normalized();
        // manually shift all log_mu by a constant: normalization cancels it
        let shifted: Vec<Scored> = sel
            .blocks
            .iter()
            .map(|s| Scored { block: s.block, log_mu: s.log_mu + 7.5 })
            .collect();
        let z2 = compute(&shifted, &vp, n, &scales).unwrap().normalized();
        assert!(ops::rel_fro_error(&z2, &z1) < 1e-4);
    }

    #[test]
    fn empty_rows_yield_zeros() {
        use crate::mra::frame::Block;
        // single block covering only rows [0, 8): remaining rows are zero
        let n = 32;
        let v = Mat::full(n, 2, 2.0);
        let scales = [8usize, 1];
        let vp = Pyramid::build(&v, &scales);
        let blocks = vec![Scored { block: Block { scale: 8, x: 0, y: 1 }, log_mu: 0.3 }];
        let z = compute(&blocks, &vp, n, &scales).unwrap().normalized();
        for i in 0..8 {
            assert!((z.get(i, 0) - 2.0).abs() < 1e-5);
        }
        for i in 8..n {
            assert_eq!(z.get(i, 0), 0.0);
        }
    }
}
