//! Lemma 4.1 / Prop. 4.5 quantities: the Jensen-gap constant `C_r`, the
//! measured numerical range of `P` over block supports, and the relative
//! error bound.  These are validated empirically by property tests: for
//! random Q/K the *measured* approximation error must respect the bounds.

use crate::tensor::{ops, topk, Mat};

/// `C_r = 1 + exp(r) - 2 exp(r/2)` (Lemma 4.1).
pub fn c_r(r: f64) -> f64 {
    1.0 + r.exp() - 2.0 * (r / 2.0).exp()
}

/// `C_{2r} = 1 + exp(2r) - 2 exp(r)` (Prop. 4.5).
pub fn c_2r(r: f64) -> f64 {
    1.0 + (2.0 * r).exp() - 2.0 * r.exp()
}

/// Numerical range (max - min) of `P` within each `b x b` block:
/// returns an `(n/b, n/b)` matrix of ranges.  Test/diagnostic path: needs
/// the dense `P`.
pub fn block_ranges(p: &Mat, b: usize) -> Mat {
    let n = p.rows;
    assert_eq!(n % b, 0);
    let nb = n / b;
    let mut out = Mat::zeros(nb, nb);
    for x in 0..nb {
        for y in 0..nb {
            let mut lo = f32::INFINITY;
            let mut hi = f32::NEG_INFINITY;
            for i in x * b..(x + 1) * b {
                for j in y * b..(y + 1) * b {
                    let v = p.get(i, j);
                    lo = lo.min(v);
                    hi = hi.max(v);
                }
            }
            out.set(x, y, hi - lo);
        }
    }
    out
}

/// Hölder bound on the in-block range (Lemma 4.1 hypothesis):
/// `r <= 2 beta1 beta2` with `beta1` the max L2 norm of Q/K rows in the
/// block and `beta2` the max pairwise L2 spread.  Includes the `1/sqrt(d)`
/// scaling used throughout the repo.
pub fn holder_range_bound(q: &Mat, k: &Mat, b: usize, x: usize, y: usize) -> f64 {
    let d = q.cols;
    let rows = |m: &Mat, g: usize| -> Vec<Vec<f32>> {
        (g * b..(g + 1) * b).map(|i| m.row(i).to_vec()).collect()
    };
    let qs = rows(q, x);
    let ks = rows(k, y);
    let norm = |v: &[f32]| v.iter().map(|&t| (t as f64) * (t as f64)).sum::<f64>().sqrt();
    let beta1 = qs
        .iter()
        .chain(ks.iter())
        .map(|r| norm(r))
        .fold(0.0f64, f64::max);
    let spread = |set: &[Vec<f32>]| -> f64 {
        let mut worst = 0.0f64;
        for i in 0..set.len() {
            for j in i + 1..set.len() {
                let diff: f64 = set[i]
                    .iter()
                    .zip(&set[j])
                    .map(|(a, b)| ((a - b) as f64).powi(2))
                    .sum::<f64>()
                    .sqrt();
                worst = worst.max(diff);
            }
        }
        worst
    };
    let beta2 = spread(&qs).max(spread(&ks));
    2.0 * beta1 * beta2 / (d as f64).sqrt()
}

/// Prop. 4.5 relative-error bound for `R = {b, 1}` with budget `m`:
/// `sqrt((n^2 - m b^2) C_{2r} delta^2 / sum exp(2 P))`.
///
/// `delta` is the `m`-th largest `mu_{b,x,y}`; `r` is the max in-block
/// range of `P` (measured).  Diagnostic path: materializes `P`.
pub fn prop45_bound(q: &Mat, k: &Mat, b: usize, m: usize) -> f64 {
    let n = q.rows;
    let p = ops::scores(q, k);
    let mu = {
        let qt = ops::pool_rows(q, b);
        let kt = ops::pool_rows(k, b);
        qt.matmul_transb(&kt).scale(1.0 / (q.cols as f32).sqrt())
    };
    let mu_exp: Vec<f32> = mu.data.iter().map(|&v| v.exp()).collect();
    let delta = topk::kth_largest(&mu_exp, m.min(mu_exp.len())) as f64;
    let r = block_ranges(&p, b)
        .data
        .iter()
        .cloned()
        .fold(f32::NEG_INFINITY, f32::max) as f64;
    let sum_exp2p: f64 = p.data.iter().map(|&v| (2.0 * v as f64).exp()).sum();
    let numer = ((n * n) as f64 - (m * b * b) as f64).max(0.0) * c_2r(r) * delta * delta;
    (numer / sum_exp2p).sqrt()
}

/// Measured unnormalized relative error `||A_hat - A||_F / ||A||_F` for the
/// two-scale approximation **without** diagonal seeding (the Prop. 4.5
/// setting).
pub fn measured_rel_error_no_diag(q: &Mat, k: &Mat, b: usize, m: usize) -> f64 {
    let n = q.rows;
    let nb = n / b;
    let p = ops::scores(q, k);
    let a = ops::exp(&p);
    let mu = {
        let qt = ops::pool_rows(q, b);
        let kt = ops::pool_rows(k, b);
        qt.matmul_transb(&kt).scale(1.0 / (q.cols as f32).sqrt())
    };
    let chosen = topk::top_k_indices(&mu.data, m.min(nb * nb));
    let mut selected = vec![false; nb * nb];
    for &c in &chosen {
        selected[c] = true;
    }
    let mut a_hat = Mat::zeros(n, n);
    for x in 0..nb {
        for y in 0..nb {
            if selected[x * nb + y] {
                for i in x * b..(x + 1) * b {
                    for j in y * b..(y + 1) * b {
                        a_hat.set(i, j, a.get(i, j));
                    }
                }
            } else {
                let muv = mu.get(x, y).exp();
                for i in x * b..(x + 1) * b {
                    for j in y * b..(y + 1) * b {
                        a_hat.set(i, j, muv);
                    }
                }
            }
        }
    }
    ops::rel_fro_error(&a_hat, &a)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    #[test]
    fn c_r_properties() {
        assert!(c_r(0.0).abs() < 1e-12); // zero range -> exact
        assert!(c_r(1.0) > 0.0);
        assert!(c_r(2.0) > c_r(1.0)); // monotone in r
        assert!(c_2r(1.0) > c_r(1.0));
    }

    #[test]
    fn block_ranges_zero_for_constant_p() {
        let p = Mat::full(16, 16, 3.0);
        let r = block_ranges(&p, 4);
        assert!(r.data.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn lemma41_gap_bounded_by_cr_mu() {
        // 0 <= mu* - mu <= C_r mu over random Q/K at several seeds
        for seed in 0..5 {
            let mut rng = Rng::new(seed);
            let (n, d, b) = (32usize, 8usize, 8usize);
            let q = Mat::randn(n, d, 0.7, &mut rng);
            let k = Mat::randn(n, d, 0.7, &mut rng);
            let p = ops::scores(&q, &k);
            let a = ops::exp(&p);
            let nb = n / b;
            let ranges = block_ranges(&p, b);
            let qt = ops::pool_rows(&q, b);
            let kt = ops::pool_rows(&k, b);
            let s_low = qt.matmul_transb(&kt).scale(1.0 / (d as f32).sqrt());
            for x in 0..nb {
                for y in 0..nb {
                    let mu = (s_low.get(x, y) as f64).exp();
                    let mut mu_star = 0.0f64;
                    for i in x * b..(x + 1) * b {
                        for j in y * b..(y + 1) * b {
                            mu_star += a.get(i, j) as f64;
                        }
                    }
                    mu_star /= (b * b) as f64;
                    let gap = mu_star - mu;
                    assert!(gap >= -1e-6 * mu, "jensen violated: {gap}");
                    let cr = c_r(ranges.get(x, y) as f64);
                    assert!(gap <= cr * mu * (1.0 + 1e-4) + 1e-9, "gap {gap} > C_r mu {}", cr * mu);
                }
            }
        }
    }

    #[test]
    fn holder_bound_dominates_measured_range() {
        let mut rng = Rng::new(3);
        let (n, d, b) = (32usize, 8usize, 8usize);
        let q = Mat::randn(n, d, 1.0, &mut rng);
        let k = Mat::randn(n, d, 1.0, &mut rng);
        let p = ops::scores(&q, &k);
        let ranges = block_ranges(&p, b);
        for x in 0..n / b {
            for y in 0..n / b {
                let bound = holder_range_bound(&q, &k, b, x, y);
                assert!(
                    (ranges.get(x, y) as f64) <= bound * (1.0 + 1e-4),
                    "range {} > holder {}",
                    ranges.get(x, y),
                    bound
                );
            }
        }
    }

    #[test]
    fn prop45_bound_dominates_measured_error() {
        for seed in 0..5 {
            let mut rng = Rng::new(100 + seed);
            let (n, d, b) = (64usize, 8usize, 16usize);
            let q = Mat::randn(n, d, 0.5, &mut rng);
            let k = Mat::randn(n, d, 0.5, &mut rng);
            for m in [2usize, 6, 12] {
                let bound = prop45_bound(&q, &k, b, m);
                let measured = measured_rel_error_no_diag(&q, &k, b, m);
                assert!(
                    measured <= bound * (1.0 + 1e-6),
                    "seed {seed} m {m}: measured {measured} > bound {bound}"
                );
            }
        }
    }

    #[test]
    fn bound_tightens_with_budget() {
        let mut rng = Rng::new(42);
        let q = Mat::randn(64, 8, 0.5, &mut rng);
        let k = Mat::randn(64, 8, 0.5, &mut rng);
        let b1 = prop45_bound(&q, &k, 16, 2);
        let b2 = prop45_bound(&q, &k, 16, 14);
        assert!(b2 <= b1 * (1.0 + 1e-6), "{b2} vs {b1}");
    }
}
