//! Alg. 1: greedy construction of the selected component set `J`.
//!
//! The ladder `R = {s_0 > s_1 > ... > s_k}` starts from a full coarse grid
//! at `s_0`; at every level the `m_i` surviving blocks with the largest
//! `mu` (Eq. 6, computed as exp-of-mean from the pooled pyramid — we carry
//! `log mu` to avoid overflow) are refined into their children at the next
//! scale.  Blocks never popped become final members of `J`, so the final
//! supports are pairwise disjoint and tile the full `n x n` matrix
//! (Remark 4.4 — asserted in tests).

use anyhow::Result;

use crate::mra::frame::Block;
use crate::mra::pyramid::Pyramid;
use crate::tensor::{mat::dot, topk, Mat};

/// A final member of `J` with its (log) score `log mu = <B, P>/s^2`.
#[derive(Clone, Copy, Debug)]
pub struct Scored {
    pub block: Block,
    pub log_mu: f32,
}

/// The constructed set `J`.
pub struct Selection {
    pub blocks: Vec<Scored>,
    /// Number of `mu` evaluations performed (the Sec. 4.4 workload figure).
    pub mu_evals: usize,
}

/// Score a block from the pooled pyramids: `q~_s[x] . k~_s[y] / sqrt(d)`.
#[inline]
fn score(qp: &Mat, kp: &Mat, x: usize, y: usize, inv_sqrt_d: f32) -> f32 {
    dot(qp.row(x), kp.row(y)) * inv_sqrt_d
}

/// Run Alg. 1.
///
/// * `scales`  — descending ladder `R` (powers of two dividing `n`).
/// * `budgets` — `m_i` for each refinement step (`len = scales.len() - 1`).
/// * `include_diagonal` — seed the diagonal blocks at `s_0` into the pop
///   set ("initial J prespecified via priors"), guaranteeing every query
///   row block has at least one finest-scale block (used by MRA-2-s).
///
/// Errors when a ladder scale is missing from either pyramid (the
/// descriptive `Pyramid::at` error listing the known scales).
pub fn construct_j(
    qpyr: &Pyramid,
    kpyr: &Pyramid,
    n: usize,
    d: usize,
    scales: &[usize],
    budgets: &[usize],
    include_diagonal: bool,
) -> Result<Selection> {
    assert!(!scales.is_empty());
    assert_eq!(budgets.len(), scales.len() - 1, "one budget per refinement");
    for w in scales.windows(2) {
        assert!(w[0] > w[1], "scales must be strictly descending");
    }
    let inv_sqrt_d = 1.0 / (d as f32).sqrt();

    let s0 = scales[0];
    let nb0 = n / s0;
    let qp0 = qpyr.at(s0)?;
    let kp0 = kpyr.at(s0)?;
    let mut mu_evals = nb0 * nb0;

    // frontier: surviving blocks at the current scale with (log_mu, prio)
    let mut frontier: Vec<(Block, f32, f32)> = Vec::with_capacity(nb0 * nb0);
    for x in 0..nb0 {
        for y in 0..nb0 {
            let lm = score(qp0, kp0, x, y, inv_sqrt_d);
            let prio = if include_diagonal && x == y && scales.len() > 1 {
                f32::INFINITY
            } else {
                lm
            };
            frontier.push((Block { scale: s0, x, y }, lm, prio));
        }
    }

    let mut final_blocks: Vec<Scored> = Vec::new();
    for level in 1..scales.len() {
        let (s_prev, s_new) = (scales[level - 1], scales[level]);
        let ratio = s_prev / s_new;
        assert!(ratio >= 2, "adjacent scales must differ");
        let m = budgets[level - 1].min(frontier.len());
        let prios: Vec<f32> = frontier.iter().map(|b| b.2).collect();
        let popped_idx = topk::top_k_indices(&prios, m);
        let mut popped_mark = vec![false; frontier.len()];
        for &i in &popped_idx {
            popped_mark[i] = true;
        }
        let qp = qpyr.at(s_new)?;
        let kp = kpyr.at(s_new)?;
        let mut next: Vec<(Block, f32, f32)> =
            Vec::with_capacity(m * ratio * ratio);
        for (i, (block, lm, _)) in frontier.iter().enumerate() {
            if popped_mark[i] {
                for child in block.children(ratio) {
                    let clm = score(qp, kp, child.x, child.y, inv_sqrt_d);
                    next.push((child, clm, clm));
                    mu_evals += 1;
                }
            } else {
                final_blocks.push(Scored { block: *block, log_mu: *lm });
            }
        }
        frontier = next;
    }
    for (block, lm, _) in frontier {
        final_blocks.push(Scored { block, log_mu: lm });
    }
    Ok(Selection { blocks: final_blocks, mu_evals })
}

impl Selection {
    /// Only the blocks at the finest scale of the ladder (MRA-2-s keeps
    /// exactly these — the `A_hat_1` of Sec. 5).
    pub fn finest_only(&self, finest: usize) -> Vec<Scored> {
        self.blocks.iter().copied().filter(|s| s.block.scale == finest).collect()
    }

    /// Total covered area (must equal `n^2` by construction).
    pub fn covered_area(&self) -> usize {
        self.blocks.iter().map(|s| s.block.area()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    fn setup(n: usize, d: usize, scales: &[usize], seed: u64) -> (Pyramid, Pyramid) {
        let mut rng = Rng::new(seed);
        let q = Mat::randn(n, d, 1.0, &mut rng);
        let k = Mat::randn(n, d, 1.0, &mut rng);
        (Pyramid::build(&q, scales), Pyramid::build(&k, scales))
    }

    #[test]
    fn selection_tiles_the_matrix() {
        let (n, d) = (64, 8);
        let scales = [16usize, 4, 1];
        let (qp, kp) = setup(n, d, &scales, 0);
        let sel = construct_j(&qp, &kp, n, d, &scales, &[3, 5], true).unwrap();
        assert_eq!(sel.covered_area(), n * n);
        // pairwise disjoint
        for (i, a) in sel.blocks.iter().enumerate() {
            for b in sel.blocks.iter().skip(i + 1) {
                assert!(!a.block.overlaps(&b.block), "{:?} {:?}", a.block, b.block);
            }
        }
    }

    #[test]
    fn block_count_formula() {
        // |J| = (n/s0)^2 + sum_i m_i (ratio^2 - 1)
        let (n, d) = (64, 4);
        let scales = [16usize, 4, 1];
        let budgets = [3usize, 5];
        let (qp, kp) = setup(n, d, &scales, 1);
        let sel = construct_j(&qp, &kp, n, d, &scales, &budgets, false).unwrap();
        let expect = 16 + 3 * (16 - 1) + 5 * (16 - 1);
        assert_eq!(sel.blocks.len(), expect);
    }

    #[test]
    fn mu_evals_matches_sec44_formula() {
        let (n, d) = (64, 4);
        let scales = [16usize, 4, 1];
        let budgets = [3usize, 5];
        let (qp, kp) = setup(n, d, &scales, 2);
        let sel = construct_j(&qp, &kp, n, d, &scales, &budgets, false).unwrap();
        // (n/s0)^2 + m_1 (s0/s1)^2 + m_2 (s1/s2)^2
        assert_eq!(sel.mu_evals, 16 + 3 * 16 + 5 * 16);
    }

    #[test]
    fn diagonal_seeding_refines_all_diagonal_blocks() {
        let (n, d) = (64, 8);
        let scales = [16usize, 1];
        let (qp, kp) = setup(n, d, &scales, 3);
        let sel = construct_j(&qp, &kp, n, d, &scales, &[4], true).unwrap();
        // with budget = nb = 4 and diagonal priority, every popped block is
        // on the diagonal -> all finest blocks lie in diagonal regions
        for s in sel.finest_only(1) {
            assert_eq!(s.block.x / 16, s.block.y / 16);
        }
    }

    #[test]
    fn greedy_pops_largest_scores() {
        let (n, d) = (32, 4);
        let scales = [8usize, 1];
        let (qp, kp) = setup(n, d, &scales, 4);
        let sel = construct_j(&qp, &kp, n, d, &scales, &[2], false).unwrap();
        // every refined (finest) region must have a parent score >= any
        // surviving coarse block's score
        let coarse_max = sel
            .blocks
            .iter()
            .filter(|s| s.block.scale == 8)
            .map(|s| s.log_mu)
            .fold(f32::NEG_INFINITY, f32::max);
        // reconstruct parent scores of refined children via pooled mats
        let qp8 = qp.at(8).unwrap();
        let kp8 = kp.at(8).unwrap();
        let inv = 1.0 / (d as f32).sqrt();
        let mut parents: std::collections::HashSet<(usize, usize)> =
            std::collections::HashSet::new();
        for s in sel.finest_only(1) {
            parents.insert((s.block.x / 8, s.block.y / 8));
        }
        for (x, y) in parents {
            let ps = dot(qp8.row(x), kp8.row(y)) * inv;
            assert!(ps >= coarse_max - 1e-5, "popped {ps} < kept {coarse_max}");
        }
    }

    #[test]
    fn budget_zero_keeps_everything_coarse() {
        let (n, d) = (32, 4);
        let scales = [8usize, 1];
        let (qp, kp) = setup(n, d, &scales, 5);
        let sel = construct_j(&qp, &kp, n, d, &scales, &[0], false).unwrap();
        assert!(sel.blocks.iter().all(|s| s.block.scale == 8));
        assert_eq!(sel.blocks.len(), 16);
    }

    #[test]
    fn oversized_budget_is_clamped() {
        let (n, d) = (32, 4);
        let scales = [8usize, 1];
        let (qp, kp) = setup(n, d, &scales, 6);
        let sel = construct_j(&qp, &kp, n, d, &scales, &[1000], false).unwrap();
        // everything refined to scale 1
        assert!(sel.blocks.iter().all(|s| s.block.scale == 1));
        assert_eq!(sel.blocks.len(), n * n);
    }
}
