//! The overcomplete frame `B^s_{x,y}` of Eq. (1) and its bookkeeping.
//!
//! A component is an axis-aligned `s x s` all-ones block supported on rows
//! `[x*s, (x+1)*s)` and columns `[y*s, (y+1)*s)` (0-based; the paper is
//! 1-based).  Fig. 2 counts 85 components at `n = 8` — asserted in the
//! tests.

/// One frame component `B^s_{x,y}`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Block {
    pub scale: usize,
    pub x: usize,
    pub y: usize,
}

impl Block {
    /// Row range `[start, end)` of the support.
    #[inline]
    pub fn rows(&self) -> (usize, usize) {
        (self.x * self.scale, (self.x + 1) * self.scale)
    }

    /// Column range `[start, end)` of the support.
    #[inline]
    pub fn cols(&self) -> (usize, usize) {
        (self.y * self.scale, (self.y + 1) * self.scale)
    }

    /// Does the support contain entry `(i, j)`?
    pub fn contains(&self, i: usize, j: usize) -> bool {
        let (r0, r1) = self.rows();
        let (c0, c1) = self.cols();
        i >= r0 && i < r1 && j >= c0 && j < c1
    }

    /// Is `other`'s support a subset of this block's support?
    /// (True iff `other` is a descendant in the refinement tree.)
    pub fn covers(&self, other: &Block) -> bool {
        let (r0, r1) = self.rows();
        let (c0, c1) = self.cols();
        let (or0, or1) = other.rows();
        let (oc0, oc1) = other.cols();
        or0 >= r0 && or1 <= r1 && oc0 >= c0 && oc1 <= c1
    }

    /// Do two supports intersect?
    pub fn overlaps(&self, other: &Block) -> bool {
        let (r0, r1) = self.rows();
        let (c0, c1) = self.cols();
        let (or0, or1) = other.rows();
        let (oc0, oc1) = other.cols();
        r0 < or1 && or0 < r1 && c0 < oc1 && oc0 < c1
    }

    /// The `(ratio)^2` children at `scale / ratio`.
    pub fn children(&self, ratio: usize) -> Vec<Block> {
        assert!(ratio >= 1 && self.scale % ratio == 0);
        let s = self.scale / ratio;
        let mut out = Vec::with_capacity(ratio * ratio);
        for dx in 0..ratio {
            for dy in 0..ratio {
                out.push(Block { scale: s, x: self.x * ratio + dx, y: self.y * ratio + dy });
            }
        }
        out
    }

    /// Support area `s^2`.
    pub fn area(&self) -> usize {
        self.scale * self.scale
    }
}

/// Number of components in the frame of Eq. (1) for sequence length `n`
/// (power of two): `sum_{s in {1,2,..,n}} (n/s)^2`.
pub fn frame_size(n: usize) -> usize {
    assert!(n.is_power_of_two());
    let mut total = 0usize;
    let mut s = 1usize;
    while s <= n {
        total += (n / s) * (n / s);
        s *= 2;
    }
    total
}

/// Number of elements in the 2D Haar basis for comparison (Fig. 2 right:
/// three detail orientations per level plus the constant).
pub fn haar_basis_size(n: usize) -> usize {
    assert!(n.is_power_of_two());
    // 3 * sum_{level} (n/2^l)^2 over detail levels + 1 constant
    let mut total = 1usize;
    let mut s = 2usize;
    while s <= n {
        total += 3 * (n / s) * (n / s);
        s *= 2;
    }
    total
}

/// All components at a given scale (row-major order).
pub fn blocks_at_scale(n: usize, scale: usize) -> Vec<Block> {
    assert_eq!(n % scale, 0);
    let nb = n / scale;
    let mut out = Vec::with_capacity(nb * nb);
    for x in 0..nb {
        for y in 0..nb {
            out.push(Block { scale, x, y });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2_frame_count_n8_is_85() {
        // 64 + 16 + 4 + 1 (Fig. 2 left: "85 matrices for n = 8")
        assert_eq!(frame_size(8), 85);
    }

    #[test]
    fn fig2_haar_count_n8_is_64() {
        // "three groups of 21 self-similar matrices plus a constant" = 64
        assert_eq!(haar_basis_size(8), 64);
    }

    #[test]
    fn frame_has_one_extra_scale_vs_haar() {
        // the frame spans scales {1..n} (k+1 levels), Haar detail spans k
        for n in [4usize, 8, 16, 32] {
            assert!(frame_size(n) > haar_basis_size(n));
        }
    }

    #[test]
    fn contains_and_ranges() {
        let b = Block { scale: 4, x: 1, y: 2 };
        assert_eq!(b.rows(), (4, 8));
        assert_eq!(b.cols(), (8, 12));
        assert!(b.contains(5, 9));
        assert!(!b.contains(3, 9));
        assert!(!b.contains(5, 12));
    }

    #[test]
    fn children_partition_parent() {
        let b = Block { scale: 8, x: 1, y: 1 };
        let kids = b.children(4);
        assert_eq!(kids.len(), 16);
        // children tile the parent support exactly: disjoint + covered
        let mut covered = 0usize;
        for (i, a) in kids.iter().enumerate() {
            assert!(b.covers(a));
            covered += a.area();
            for c in kids.iter().skip(i + 1) {
                assert!(!a.overlaps(c), "{a:?} vs {c:?}");
            }
        }
        assert_eq!(covered, b.area());
    }

    #[test]
    fn same_scale_blocks_disjoint() {
        let blocks = blocks_at_scale(16, 4);
        assert_eq!(blocks.len(), 16);
        for (i, a) in blocks.iter().enumerate() {
            for b in blocks.iter().skip(i + 1) {
                assert!(!a.overlaps(b));
            }
        }
    }

    #[test]
    fn covers_requires_subset() {
        let big = Block { scale: 8, x: 0, y: 0 };
        let inside = Block { scale: 2, x: 1, y: 3 };
        let outside = Block { scale: 2, x: 4, y: 0 };
        assert!(big.covers(&inside));
        assert!(!big.covers(&outside));
        assert!(!inside.covers(&big));
    }
}
