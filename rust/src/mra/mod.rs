//! The paper's contribution: multi-resolution approximate self-attention.
//!
//! * [`pyramid`]   — Eq. (7): multi-scale average pooling of Q/K/V.
//! * [`frame`]     — the overcomplete frame `B^s_{x,y}` of Eq. (1) and its
//!   bookkeeping (Fig. 2 component counting, support logic).
//! * [`select`]    — Alg. 1: greedy construction of the selected set `J`
//!   for an arbitrary descending scale ladder `R`.
//! * [`matvec`]    — Alg. 2: `A_hat V` + row sums without materializing
//!   the `n x n` matrix.
//! * [`attention`] — end-to-end MRA attention (MRA-2 / MRA-2-s fast paths,
//!   dense oracle, workload accounting).
//! * [`theory`]    — Lemma 4.1 / Prop. 4.5 quantities (`C_r`, bounds).

pub mod attention;
pub mod frame;
pub mod matvec;
pub mod pyramid;
pub mod select;
pub mod theory;

pub use attention::{
    dense_mra2, dense_mra2_causal, mra2_apply_blocks, mra2_apply_blocks_ref, mra2_attention,
    mra2_attention_causal, mra2_attention_stats, mra2_plan, mra_attention, Causality, Mra2Plan,
    Mra2Scratch, MraConfig, MraStats, Variant,
};
pub use frame::Block;
pub use select::Selection;
