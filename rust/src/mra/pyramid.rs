//! Eq. (7): the multi-scale average-pooling pyramid over Q / K / V rows.
//!
//! `Q~_s` halves the row count level by level; building every scale in
//! `{1, 2, 4, ..., n}` costs `O(n d)` total (the telescoping sum of
//! Sec. 4.4).

use anyhow::{anyhow, Result};

use crate::tensor::Mat;

/// Pooled copies of a matrix at a descending ladder of scales.
pub struct Pyramid {
    /// `(scale, pooled matrix with n/scale rows)`, in the order given.
    levels: Vec<(usize, Mat)>,
}

impl Pyramid {
    /// Build pooled matrices for every scale in `scales` (descending or
    /// not — each level is derived by halving from the nearest computed
    /// finer scale, so the total cost stays `O(n d)`).
    pub fn build(x: &Mat, scales: &[usize]) -> Self {
        let n = x.rows;
        let mut wanted: Vec<usize> = scales.to_vec();
        wanted.sort_unstable();
        wanted.dedup();
        for &s in &wanted {
            assert!(s >= 1 && n % s == 0, "scale {s} must divide n={n}");
            assert!(s.is_power_of_two(), "scales must be powers of two");
        }
        // halve from scale 1 upwards, keeping only requested levels
        let mut levels: Vec<(usize, Mat)> = Vec::new();
        let mut cur = x.clone();
        let mut cur_s = 1usize;
        let max_s = *wanted.last().unwrap_or(&1);
        while cur_s <= max_s {
            if wanted.contains(&cur_s) {
                levels.push((cur_s, cur.clone()));
            }
            if cur_s == max_s {
                break;
            }
            cur = halve(&cur);
            cur_s *= 2;
        }
        // return in the caller's order (descending ladder for Alg. 1)
        let mut ordered = Vec::with_capacity(scales.len());
        for &s in scales {
            let m = levels.iter().find(|(ls, _)| *ls == s).unwrap().1.clone();
            ordered.push((s, m));
        }
        Pyramid { levels: ordered }
    }

    /// Pooled matrix at `scale`; a scale that was not requested at build
    /// time is a descriptive error listing the known scales (mirroring
    /// the `kernel_by_name` contract — callers whose ladder is validated
    /// up front may `expect` it).
    pub fn at(&self, scale: usize) -> Result<&Mat> {
        self.levels.iter().find(|(s, _)| *s == scale).map(|(_, m)| m).ok_or_else(|| {
            anyhow!("scale {scale} not in pyramid (known scales: {:?})", self.scales())
        })
    }

    pub fn scales(&self) -> Vec<usize> {
        self.levels.iter().map(|(s, _)| *s).collect()
    }
}

/// Average adjacent row pairs: `(n, d) -> (n/2, d)` (one pyramid level).
pub fn halve(x: &Mat) -> Mat {
    assert_eq!(x.rows % 2, 0);
    let mut out = Mat::zeros(x.rows / 2, x.cols);
    for i in 0..out.rows {
        let a = x.row(2 * i);
        let b = x.row(2 * i + 1);
        let o = out.row_mut(i);
        for j in 0..a.len() {
            o[j] = 0.5 * (a[j] + b[j]);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{ops, Rng};

    #[test]
    fn halve_is_pairwise_mean() {
        let x = Mat::from_fn(4, 1, |i, _| i as f32);
        let h = halve(&x);
        assert_eq!(h.data, vec![0.5, 2.5]);
    }

    #[test]
    fn pyramid_matches_direct_pooling() {
        let mut rng = Rng::new(0);
        let x = Mat::randn(64, 8, 1.0, &mut rng);
        let p = Pyramid::build(&x, &[16, 4, 1]);
        for &s in &[16usize, 4, 1] {
            let want = ops::pool_rows(&x, s);
            let got = p.at(s).unwrap();
            for (a, b) in got.data.iter().zip(want.data.iter()) {
                assert!((a - b).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn pyramid_scale_one_is_input() {
        let mut rng = Rng::new(1);
        let x = Mat::randn(8, 3, 1.0, &mut rng);
        let p = Pyramid::build(&x, &[1]);
        assert_eq!(p.at(1).unwrap(), &x);
    }

    #[test]
    fn pyramid_preserves_total_mean() {
        let mut rng = Rng::new(2);
        let x = Mat::randn(32, 4, 1.0, &mut rng);
        let p = Pyramid::build(&x, &[32]);
        let top = p.at(32).unwrap();
        assert_eq!(top.rows, 1);
        for j in 0..4 {
            let mean: f32 = (0..32).map(|i| x.get(i, j)).sum::<f32>() / 32.0;
            assert!((top.get(0, j) - mean).abs() < 1e-5);
        }
    }

    /// Regression for the error-text contract: an unknown scale is a
    /// `Result` (no panic) whose message lists the scales that exist.
    #[test]
    fn unknown_scale_error_lists_known_scales() {
        let x = Mat::zeros(16, 2);
        let p = Pyramid::build(&x, &[8, 2]);
        let err = p.at(4).err().expect("unknown scale must error");
        let msg = format!("{err:#}");
        assert!(msg.contains("scale 4 not in pyramid"), "{msg}");
        assert!(msg.contains("known scales"), "{msg}");
        assert!(msg.contains("[8, 2]"), "{msg}");
    }

    #[test]
    #[should_panic]
    fn pyramid_rejects_non_dividing_scale() {
        let x = Mat::zeros(12, 2);
        let _ = Pyramid::build(&x, &[8]);
    }
}
