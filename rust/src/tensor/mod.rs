//! Dense `f32` linear-algebra substrate.
//!
//! No external linear-algebra crates are available in the offline build, so
//! everything the coordinator, the MRA core, and the baselines need is
//! implemented here from scratch: a row-major matrix type with a cache-tiled
//! matmul, the vectorization-friendly micro-kernel layer ([`kernel`] —
//! lane-unrolled dot/AXPY, packed-panel score tiles, fused online-softmax
//! accumulation; DESIGN.md §8), elementwise/reduction ops, a deterministic
//! PRNG, randomized truncated SVD, and partial top-k selection.

pub mod kernel;
pub mod mat;
pub mod ops;
pub mod rng;
pub mod svd;
pub mod topk;

pub use mat::Mat;
pub use rng::Rng;
