//! Partial top-k selection (quickselect) — Alg. 1's "pop m elements with the
//! largest mu" without a full sort.

/// Indices of the `k` largest values (descending by value, ties by index).
///
/// `O(n)` average via quickselect on a scratch index vector, then only the
/// selected prefix is sorted (`O(k log k)`).
pub fn top_k_indices(values: &[f32], k: usize) -> Vec<usize> {
    let mut idx = Vec::new();
    top_k_into(values, k, &mut idx);
    idx
}

/// [`top_k_indices`] into a caller-owned index buffer: `idx` is cleared and
/// refilled, so once its capacity covers `values.len()` repeated calls are
/// allocation-free — the form the per-token decode hot path
/// (`engine::decode`) uses.  Result order is identical to
/// [`top_k_indices`].
pub fn top_k_into(values: &[f32], k: usize, idx: &mut Vec<usize>) {
    let n = values.len();
    let k = k.min(n);
    idx.clear();
    if k == 0 {
        return;
    }
    idx.extend(0..n);
    if k < n {
        // descending comparator: largest k to the front
        idx.select_nth_unstable_by(k - 1, |&a, &b| {
            values[b]
                .partial_cmp(&values[a])
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        idx.truncate(k);
    }
    idx.sort_unstable_by(|&a, &b| {
        values[b]
            .partial_cmp(&values[a])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
}

/// The `k`-th largest value (1-based: `k = 1` is the max) — the
/// `delta` of Prop. 4.5.
pub fn kth_largest(values: &[f32], k: usize) -> f32 {
    assert!(k >= 1 && k <= values.len());
    let mut v = values.to_vec();
    let pos = k - 1;
    v.select_nth_unstable_by(pos, |a, b| {
        b.partial_cmp(a).unwrap_or(std::cmp::Ordering::Equal)
    });
    v[pos]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    #[test]
    fn top_k_known() {
        let v = [3.0f32, 1.0, 4.0, 1.5, 9.0, 2.6];
        assert_eq!(top_k_indices(&v, 3), vec![4, 2, 0]);
    }

    #[test]
    fn top_k_full_is_argsort_desc() {
        let v = [0.5f32, -1.0, 2.0, 2.0, 0.0];
        // ties broken by index
        assert_eq!(top_k_indices(&v, 5), vec![2, 3, 0, 4, 1]);
    }

    #[test]
    fn top_k_zero_and_overflow() {
        let v = [1.0f32, 2.0];
        assert!(top_k_indices(&v, 0).is_empty());
        assert_eq!(top_k_indices(&v, 10), vec![1, 0]);
    }

    #[test]
    fn top_k_matches_sort_random() {
        let mut rng = Rng::new(8);
        for _ in 0..20 {
            let v: Vec<f32> = (0..200).map(|_| rng.normal()).collect();
            let got = top_k_indices(&v, 17);
            let mut all: Vec<usize> = (0..v.len()).collect();
            all.sort_by(|&a, &b| {
                v[b].partial_cmp(&v[a]).unwrap().then(a.cmp(&b))
            });
            assert_eq!(got, all[..17].to_vec());
        }
    }

    #[test]
    fn top_k_into_reuses_capacity_and_matches() {
        let mut rng = Rng::new(11);
        let v: Vec<f32> = (0..64).map(|_| rng.normal()).collect();
        let mut buf = Vec::new();
        top_k_into(&v, 7, &mut buf);
        assert_eq!(buf, top_k_indices(&v, 7));
        let cap = buf.capacity();
        for k in [0usize, 3, 7, 64] {
            top_k_into(&v, k, &mut buf);
            assert_eq!(buf, top_k_indices(&v, k), "k={k}");
            assert_eq!(buf.capacity(), cap, "k={k}: buffer regrew");
        }
    }

    #[test]
    fn kth_largest_known() {
        let v = [5.0f32, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(kth_largest(&v, 1), 5.0);
        assert_eq!(kth_largest(&v, 3), 3.0);
        assert_eq!(kth_largest(&v, 5), 1.0);
    }
}
