//! Deterministic PRNG (SplitMix64) + sampling helpers.
//!
//! The `rand` crate is not available offline, and determinism across the
//! bench harness matters more than cryptographic quality; SplitMix64 passes
//! BigCrush-level statistical tests and seeds reproducibly.

/// SplitMix64 generator with Box–Muller normal sampling.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
    cached_normal: Option<f32>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E3779B97F4A7C15), cached_normal: None }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn uniform(&mut self) -> f32 {
        // top 24 bits -> f32 mantissa precision
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in `[0, n)`.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller (caches the second draw).
    pub fn normal(&mut self) -> f32 {
        if let Some(z) = self.cached_normal.take() {
            return z;
        }
        let mut u1 = self.uniform();
        if u1 < 1e-12 {
            u1 = 1e-12;
        }
        let u2 = self.uniform();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f32::consts::PI * u2;
        self.cached_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (k <= n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        // partial Fisher–Yates over an index vector
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Split off an independent child stream (for per-thread RNGs).
    pub fn split(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_in_range_and_roughly_uniform() {
        let mut rng = Rng::new(7);
        let n = 20_000;
        let mut sum = 0.0f64;
        for _ in 0..n {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u as f64;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(11);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let z = rng.normal() as f64;
            s1 += z;
            s2 += z * z;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_bounds() {
        let mut rng = Rng::new(3);
        for _ in 0..1000 {
            assert!(rng.below(17) < 17);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(5);
        let mut xs: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Rng::new(9);
        let idx = rng.sample_indices(100, 30);
        assert_eq!(idx.len(), 30);
        let mut s = idx.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 30);
        assert!(s.iter().all(|&i| i < 100));
    }

    #[test]
    fn split_streams_diverge() {
        let mut rng = Rng::new(1);
        let mut a = rng.split();
        let mut b = rng.split();
        let same = (0..32).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}
