//! Row-major `f32` matrix with a cache-tiled matmul hot path.
//!
//! The dense inner loops run through the [`crate::tensor::kernel`] layer
//! (branch-free AXPY / lane-unrolled dot) so LLVM auto-vectorizes them;
//! structurally sparse left operands get the dedicated
//! [`Mat::matmul_sparse`] entry point instead of a data-dependent skip in
//! the dense path (DESIGN.md §8).

use crate::tensor::kernel;
use crate::tensor::rng::Rng;

/// Dense row-major matrix of `f32`.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    /// All-zeros matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Matrix filled with a constant.
    pub fn full(rows: usize, cols: usize, v: f32) -> Self {
        Mat { rows, cols, data: vec![v; rows * cols] }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    /// Build from a closure over `(row, col)`.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Mat { rows, cols, data }
    }

    /// Wrap an existing buffer (must have `rows * cols` elements).
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "buffer/shape mismatch");
        Mat { rows, cols, data }
    }

    /// i.i.d. standard-normal entries scaled by `scale`.
    pub fn randn(rows: usize, cols: usize, scale: f32, rng: &mut Rng) -> Self {
        let mut m = Mat::zeros(rows, cols);
        for v in m.data.iter_mut() {
            *v = rng.normal() * scale;
        }
        m
    }

    #[inline(always)]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline(always)]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j] = v;
    }

    #[inline(always)]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline(always)]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        t
    }

    /// `self @ other` — ikj loop order (B rows stream through cache), dense:
    /// every rank-1 update is a branch-free kernel AXPY.  The old
    /// `if a == 0.0 { continue }` skip lives in [`Mat::matmul_sparse`] now —
    /// a data-dependent branch in the innermost loop defeats
    /// auto-vectorization for dense operands.
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let (m, n) = (self.rows, other.cols);
        let mut out = Mat::zeros(m, n);
        for i in 0..m {
            let o_row = &mut out.data[i * n..(i + 1) * n];
            for (k, &a) in self.row(i).iter().enumerate() {
                kernel::axpy(o_row, &other.data[k * n..(k + 1) * n], a);
            }
        }
        out
    }

    /// `self @ other` skipping exact-zero left-operand entries — the
    /// sparse-aware entry point for structurally sparse `A` (masked score
    /// matrices, the block oracles' `A_hat`).  For finite operands the
    /// result is bitwise identical to [`Mat::matmul`]; the zero-skip only
    /// pays off when whole runs of `A[i, k]` are zero.
    pub fn matmul_sparse(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let (m, n) = (self.rows, other.cols);
        let mut out = Mat::zeros(m, n);
        for i in 0..m {
            let o_row = &mut out.data[i * n..(i + 1) * n];
            for (k, &a) in self.row(i).iter().enumerate() {
                if a == 0.0 {
                    continue;
                }
                let b_row = &other.data[k * n..(k + 1) * n];
                for (o, &b) in o_row.iter_mut().zip(b_row.iter()) {
                    *o += a * b;
                }
            }
        }
        out
    }

    /// `self @ other^T` — both operands traversed row-major (fast path for
    /// attention scores `Q K^T`).
    pub fn matmul_transb(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.cols, "matmul_transb shape mismatch");
        let (m, n) = (self.rows, other.rows);
        let mut out = Mat::zeros(m, n);
        for i in 0..m {
            let a_row = self.row(i);
            let o_row = &mut out.data[i * n..(i + 1) * n];
            for j in 0..n {
                o_row[j] = dot(a_row, other.row(j));
            }
        }
        out
    }

    /// In-place `self += other * alpha`.
    pub fn axpy(&mut self, other: &Mat, alpha: f32) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(other.data.iter()) {
            *a += b * alpha;
        }
    }

    /// Elementwise difference `self - other`.
    pub fn sub(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self
            .data
            .iter()
            .zip(other.data.iter())
            .map(|(a, b)| a - b)
            .collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    /// Elementwise sum `self + other`.
    pub fn add(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self
            .data
            .iter()
            .zip(other.data.iter())
            .map(|(a, b)| a + b)
            .collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    /// Scaled copy.
    pub fn scale(&self, alpha: f32) -> Mat {
        let data = self.data.iter().map(|v| v * alpha).collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    /// Map every element.
    pub fn map(&self, f: impl Fn(f32) -> f32) -> Mat {
        let data = self.data.iter().map(|&v| f(v)).collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt()
    }

    /// Copy of rows `[r0, r1)`.
    pub fn row_block(&self, r0: usize, r1: usize) -> Mat {
        assert!(r0 <= r1 && r1 <= self.rows);
        Mat {
            rows: r1 - r0,
            cols: self.cols,
            data: self.data[r0 * self.cols..r1 * self.cols].to_vec(),
        }
    }
}

/// Dot product of two equal-length slices — re-exported from the
/// micro-kernel layer ([`crate::tensor::kernel::dot`]), which adds
/// `d`-specialized fast paths for d ∈ {32, 64} while computing the exact
/// historical float sequence.
pub use crate::tensor::kernel::dot;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small_known() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_vec(2, 2, vec![5.0, 6.0, 7.0, 8.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn matmul_identity() {
        let mut rng = Rng::new(1);
        let a = Mat::randn(7, 7, 1.0, &mut rng);
        let c = a.matmul(&Mat::eye(7));
        for (x, y) in a.data.iter().zip(c.data.iter()) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn matmul_transb_matches_matmul() {
        let mut rng = Rng::new(2);
        let a = Mat::randn(5, 9, 1.0, &mut rng);
        let b = Mat::randn(6, 9, 1.0, &mut rng);
        let c1 = a.matmul_transb(&b);
        let c2 = a.matmul(&b.transpose());
        for (x, y) in c1.data.iter().zip(c2.data.iter()) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(3);
        let a = Mat::randn(4, 11, 1.0, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn matmul_sparse_matches_dense_matmul() {
        // regression for the satellite: the dense path dropped the
        // zero-skip branch; the sparse-aware entry point must stay
        // result-identical on structurally sparse left operands
        let mut rng = Rng::new(9);
        let mut a = Mat::randn(6, 9, 1.0, &mut rng);
        for i in 0..6 {
            for j in 0..9 {
                if (i + j) % 3 != 0 {
                    a.set(i, j, 0.0);
                }
            }
        }
        let b = Mat::randn(9, 4, 1.0, &mut rng);
        assert_eq!(a.matmul(&b), a.matmul_sparse(&b));
    }

    #[test]
    fn dot_unrolled_matches_naive() {
        let mut rng = Rng::new(4);
        for len in [0, 1, 7, 8, 9, 31, 64, 100] {
            let a: Vec<f32> = (0..len).map(|_| rng.normal()).collect();
            let b: Vec<f32> = (0..len).map(|_| rng.normal()).collect();
            let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((dot(&a, &b) - naive).abs() < 1e-3, "len={len}");
        }
    }

    #[test]
    fn row_block_extracts_rows() {
        let a = Mat::from_fn(6, 3, |i, j| (i * 3 + j) as f32);
        let b = a.row_block(2, 4);
        assert_eq!(b.rows, 2);
        assert_eq!(b.row(0), a.row(2));
        assert_eq!(b.row(1), a.row(3));
    }

    #[test]
    fn fro_norm_known() {
        let a = Mat::from_vec(1, 2, vec![3.0, 4.0]);
        assert!((a.fro_norm() - 5.0).abs() < 1e-9);
    }

    #[test]
    #[should_panic]
    fn matmul_shape_mismatch_panics() {
        let a = Mat::zeros(2, 3);
        let b = Mat::zeros(2, 3);
        let _ = a.matmul(&b);
    }
}
