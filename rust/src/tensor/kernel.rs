//! Fixed-width f32 micro-kernels for the attention hot paths.
//!
//! Everything here is written so rustc/LLVM auto-vectorizes it — fixed
//! 8-lane chunk loops with scalar tails, `d`-specialized dispatch for the
//! common head widths (d ∈ {32, 64}) that exposes the trip count to the
//! optimizer, and branch-free inner loops (no data-dependent skips, which
//! defeat vectorization — see `Mat::matmul_sparse` for the one deliberate
//! exception).  No `unsafe`, no intrinsics: `benches/bench_attention.rs`
//! verifies the vectorized throughput empirically and gates parity against
//! the scalar reference path.
//!
//! The tile kernels operate on **packed panels** (DESIGN.md §8):
//!
//! * a K^T panel is one key block transposed to `(d, width)` so the score
//!   tile `Q_blk @ K_blk^T` becomes `width`-wide contiguous rank-1 updates
//!   (an outer-product micro-GEMM, no horizontal reductions);
//! * a V panel is the block's rows `(width, d)` contiguous, so value
//!   aggregation is a `d`-wide AXPY per key.
//!
//! [`softmax_accum_panel`] fuses the stabilized `exp` with the V
//! aggregation under per-row *online* (running-max) softmax rescaling —
//! FlashAttention's recurrence — so one pass over each score tile replaces
//! the old two-pass (materialize-then-exp) schedule.

/// Vector width the lane loops are unrolled to (f32 lanes per chunk).
pub const LANES: usize = 8;

/// Core 8-lane dot product: 8 partial accumulators combined pairwise, then
/// a scalar tail — the exact float sequence of the historical `mat::dot`.
#[inline(always)]
fn dot_lanes(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len();
    let chunks = n / LANES;
    let mut acc = [0.0f32; LANES];
    for c in 0..chunks {
        let i = c * LANES;
        let (x, y) = (&a[i..i + LANES], &b[i..i + LANES]);
        for l in 0..LANES {
            acc[l] += x[l] * y[l];
        }
    }
    let mut s = (acc[0] + acc[1]) + (acc[2] + acc[3]) + ((acc[4] + acc[5]) + (acc[6] + acc[7]));
    for i in chunks * LANES..n {
        s += a[i] * b[i];
    }
    s
}

/// Dot product of two equal-length slices, with `d`-specialized fast paths
/// for the common head widths: dispatching on a constant-length subslice
/// lets LLVM fully unroll and vectorize the lane loop.  Every path computes
/// the same float sequence, so the dispatch is bitwise-invisible.
#[inline(always)]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    match a.len() {
        32 => dot_lanes(&a[..32], &b[..32]),
        64 => dot_lanes(&a[..64], &b[..64]),
        _ => dot_lanes(a, b),
    }
}

#[inline(always)]
fn axpy_lanes(out: &mut [f32], x: &[f32], alpha: f32) {
    for (o, &v) in out.iter_mut().zip(x) {
        *o += alpha * v;
    }
}

/// `out += alpha * x` (branch-free; the zip loop auto-vectorizes), with the
/// same width-specialized dispatch as [`dot`].
#[inline(always)]
pub fn axpy(out: &mut [f32], x: &[f32], alpha: f32) {
    debug_assert_eq!(out.len(), x.len());
    match out.len() {
        32 => axpy_lanes(&mut out[..32], &x[..32], alpha),
        64 => axpy_lanes(&mut out[..64], &x[..64], alpha),
        _ => axpy_lanes(out, x, alpha),
    }
}

/// `out *= alpha` in place.
#[inline(always)]
pub fn scale(out: &mut [f32], alpha: f32) {
    for o in out.iter_mut() {
        *o *= alpha;
    }
}

/// Round-to-nearest-even `f32 -> bf16` conversion: keep the top 16 bits
/// of the IEEE-754 pattern after rounding the dropped mantissa half up
/// on ties-to-even.  bf16 shares f32's exponent range, so no value ever
/// over/underflows — only 16 mantissa bits are lost.
#[inline(always)]
pub fn f32_to_bf16(x: f32) -> u16 {
    let bits = x.to_bits();
    let round = 0x7FFF + ((bits >> 16) & 1);
    (bits.wrapping_add(round) >> 16) as u16
}

/// Exact `bf16 -> f32` widening (the stored pattern *is* the high half
/// of an f32 — decode is a shift, bitwise lossless).
#[inline(always)]
pub fn bf16_to_f32(h: u16) -> f32 {
    f32::from_bits((h as u32) << 16)
}

/// Quantize a slice to bf16 elementwise (the page-demotion encode path).
pub fn quant_bf16(src: &[f32], out: &mut [u16]) {
    debug_assert_eq!(src.len(), out.len(), "quant_bf16 shape");
    for (o, &x) in out.iter_mut().zip(src) {
        *o = f32_to_bf16(x);
    }
}

/// Dequantize a bf16 slice back to f32 (the compressed-page attend read;
/// registered in the xtask hot-path-alloc manifest — the zip loop
/// auto-vectorizes and never allocates).
pub fn dequant_bf16(src: &[u16], out: &mut [f32]) {
    debug_assert_eq!(src.len(), out.len(), "dequant_bf16 shape");
    for (o, &h) in out.iter_mut().zip(src) {
        *o = bf16_to_f32(h);
    }
}

/// Symmetric int8 scale of a slice: `maxabs / 127` (0.0 for an all-zero
/// slice — the matching [`quant_i8`]/[`dequant_i8`] then store/read
/// exact zeros).  NaN elements are ignored by the max, matching the
/// comparison semantics of the kernels above.
pub fn int8_scale(src: &[f32]) -> f32 {
    let mut maxabs = 0.0f32;
    for &x in src {
        let a = x.abs();
        if a > maxabs {
            maxabs = a;
        }
    }
    maxabs / 127.0
}

/// Quantize a slice to symmetric int8 under `scale` (round-to-nearest,
/// clamped to `[-127, 127]`).  `scale == 0.0` writes all zeros.
pub fn quant_i8(src: &[f32], scale: f32, out: &mut [i8]) {
    debug_assert_eq!(src.len(), out.len(), "quant_i8 shape");
    if scale == 0.0 {
        for o in out.iter_mut() {
            *o = 0;
        }
        return;
    }
    let inv = 1.0 / scale;
    for (o, &x) in out.iter_mut().zip(src) {
        *o = (x * inv).round().clamp(-127.0, 127.0) as i8;
    }
}

/// Dequantize a symmetric int8 slice under `scale` (the compressed-page
/// attend read; registered in the xtask hot-path-alloc manifest).
pub fn dequant_i8(src: &[i8], scale: f32, out: &mut [f32]) {
    debug_assert_eq!(src.len(), out.len(), "dequant_i8 shape");
    for (o, &q) in out.iter_mut().zip(src) {
        *o = q as f32 * scale;
    }
}

/// Pack `rows` consecutive `d`-wide rows of `src` into a transposed
/// `(d, rows)` panel: `panel[l * rows + r] = src[r * d + l]`.  A pure
/// permutation (bitwise-exact), built once per key block and reused by
/// every score tile touching that block.
pub fn pack_transpose(src: &[f32], rows: usize, d: usize, panel: &mut [f32]) {
    debug_assert_eq!(src.len(), rows * d, "pack_transpose src shape");
    debug_assert_eq!(panel.len(), rows * d, "pack_transpose panel shape");
    for (r, row) in src.chunks_exact(d).enumerate() {
        for (l, &v) in row.iter().enumerate() {
            panel[l * rows + r] = v;
        }
    }
}

/// Score tile against a packed K^T panel:
/// `tile[r * width + c] = scale * sum_l q[r * d + l] * kt_panel[l * width + c]`
/// for every `d`-wide query row in `q`.
///
/// Outer-product formulation: the inner loop is a contiguous `width`-wide
/// AXPY (rank-1 update), so there is no horizontal reduction anywhere —
/// the shape LLVM vectorizes best at the block widths we use (16/32).
pub fn score_panel(
    q: &[f32],
    d: usize,
    kt_panel: &[f32],
    width: usize,
    scale_by: f32,
    tile: &mut [f32],
) {
    let rows = q.len() / d;
    debug_assert_eq!(q.len(), rows * d, "score_panel q shape");
    debug_assert_eq!(kt_panel.len(), width * d, "score_panel panel shape");
    debug_assert_eq!(tile.len(), rows * width, "score_panel tile shape");
    for (qrow, trow) in q.chunks_exact(d).zip(tile.chunks_exact_mut(width)) {
        trow.fill(0.0);
        for (l, &ql) in qrow.iter().enumerate() {
            axpy(trow, &kt_panel[l * width..(l + 1) * width], ql);
        }
        scale(trow, scale_by);
    }
}

/// Fused stabilized-exp + value aggregation of one `(rows, width)` score
/// tile against a packed `(width, d)` V panel, under per-row **online
/// softmax**: `m` holds each row's running max, `den` its running
/// denominator, and `out` its unnormalized `(rows, d)` accumulator.  When a
/// tile raises a row's max, the row's previous `den`/`out` contributions
/// are rescaled by `exp(m_old - m_new)` — the FlashAttention recurrence —
/// so tiles stream through in a single pass.
///
/// Seeding: initialize `m` to the row's stabilization floor (or `-inf`
/// with no floor), `den`/`out` to zero.  `exp(-inf) == 0`, so the first
/// finite tile rescales the empty accumulators by zero harmlessly.  Score
/// entries of `-inf` (causal masking) contribute exactly zero.  A tile row
/// that is entirely `-inf` while `m` is still `-inf` is skipped outright
/// (guards the `-inf - -inf = NaN` corner; cannot happen for MRA-2's
/// diagonal-coverage tiles, where every row has at least one live key).
pub fn softmax_accum_panel(
    tile: &[f32],
    v_panel: &[f32],
    width: usize,
    d: usize,
    m: &mut [f32],
    den: &mut [f32],
    out: &mut [f32],
) {
    let rows = m.len();
    debug_assert_eq!(tile.len(), rows * width, "softmax_accum tile shape");
    debug_assert_eq!(v_panel.len(), width * d, "softmax_accum panel shape");
    debug_assert_eq!(den.len(), rows, "softmax_accum den len");
    debug_assert_eq!(out.len(), rows * d, "softmax_accum out shape");
    for r in 0..rows {
        let trow = &tile[r * width..(r + 1) * width];
        let mut tmax = f32::NEG_INFINITY;
        for &t in trow {
            if t > tmax {
                tmax = t;
            }
        }
        if tmax == f32::NEG_INFINITY {
            continue; // fully masked row: no contribution
        }
        let orow = &mut out[r * d..(r + 1) * d];
        if tmax > m[r] {
            let alpha = (m[r] - tmax).exp();
            m[r] = tmax;
            den[r] *= alpha;
            scale(orow, alpha);
        }
        let mr = m[r];
        let mut dsum = 0.0f32;
        for (&t, vrow) in trow.iter().zip(v_panel.chunks_exact(d)) {
            let a = (t - mr).exp();
            dsum += a;
            axpy(orow, vrow, a);
        }
        den[r] += dsum;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    fn randv(n: usize, rng: &mut Rng) -> Vec<f32> {
        (0..n).map(|_| rng.normal()).collect()
    }

    #[test]
    fn dot_matches_naive_at_every_width() {
        let mut rng = Rng::new(1);
        for len in [0usize, 1, 7, 8, 9, 31, 32, 33, 63, 64, 65, 100] {
            let a = randv(len, &mut rng);
            let b = randv(len, &mut rng);
            let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((dot(&a, &b) - naive).abs() < 1e-3, "len={len}");
        }
    }

    #[test]
    fn dot_specialized_paths_are_bitwise_generic() {
        // the 32/64 dispatch must not change a single bit
        let mut rng = Rng::new(2);
        for len in [32usize, 64] {
            let a = randv(len, &mut rng);
            let b = randv(len, &mut rng);
            assert_eq!(dot(&a, &b), dot_lanes(&a, &b), "len={len}");
        }
    }

    #[test]
    fn axpy_and_scale_basics() {
        let mut rng = Rng::new(3);
        for len in [1usize, 5, 32, 64, 77] {
            let x = randv(len, &mut rng);
            let mut out = randv(len, &mut rng);
            let want: Vec<f32> = out.iter().zip(&x).map(|(o, v)| o + 0.5 * v).collect();
            axpy(&mut out, &x, 0.5);
            for (g, w) in out.iter().zip(&want) {
                assert!((g - w).abs() < 1e-6);
            }
            scale(&mut out, 2.0);
            for (g, w) in out.iter().zip(&want) {
                assert!((g - 2.0 * w).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn pack_transpose_is_the_transpose() {
        let mut rng = Rng::new(4);
        let (rows, d) = (5usize, 7usize);
        let src = randv(rows * d, &mut rng);
        let mut panel = vec![0.0f32; rows * d];
        pack_transpose(&src, rows, d, &mut panel);
        for r in 0..rows {
            for l in 0..d {
                assert_eq!(panel[l * rows + r], src[r * d + l]);
            }
        }
    }

    #[test]
    fn score_panel_matches_per_element_dots() {
        let mut rng = Rng::new(5);
        for (rows, width, d) in [(4usize, 8usize, 16usize), (3, 5, 7), (1, 32, 64)] {
            let q = randv(rows * d, &mut rng);
            let kblk = randv(width * d, &mut rng);
            let mut panel = vec![0.0f32; width * d];
            pack_transpose(&kblk, width, d, &mut panel);
            let mut tile = vec![0.0f32; rows * width];
            let s = 0.25f32;
            score_panel(&q, d, &panel, width, s, &mut tile);
            for r in 0..rows {
                for c in 0..width {
                    let want = dot(&q[r * d..(r + 1) * d], &kblk[c * d..(c + 1) * d]) * s;
                    let got = tile[r * width + c];
                    assert!((got - want).abs() < 1e-4, "({r},{c}): {got} vs {want}");
                }
            }
        }
    }

    #[test]
    fn softmax_accum_matches_two_pass_reference() {
        // stream three tiles through the online recurrence; compare against
        // a global-max two-pass softmax over the concatenated scores
        let mut rng = Rng::new(6);
        let (rows, width, d, tiles) = (4usize, 8usize, 16usize, 3usize);
        let all_scores: Vec<Vec<f32>> = (0..tiles).map(|_| randv(rows * width, &mut rng)).collect();
        let all_v: Vec<Vec<f32>> = (0..tiles).map(|_| randv(width * d, &mut rng)).collect();

        let mut m = vec![f32::NEG_INFINITY; rows];
        let mut den = vec![0.0f32; rows];
        let mut out = vec![0.0f32; rows * d];
        for (t, v) in all_scores.iter().zip(&all_v) {
            softmax_accum_panel(t, v, width, d, &mut m, &mut den, &mut out);
        }

        for r in 0..rows {
            let mut gmax = f32::NEG_INFINITY;
            for t in &all_scores {
                for c in 0..width {
                    gmax = gmax.max(t[r * width + c]);
                }
            }
            let mut rden = 0.0f32;
            let mut rout = vec![0.0f32; d];
            for (t, v) in all_scores.iter().zip(&all_v) {
                for c in 0..width {
                    let a = (t[r * width + c] - gmax).exp();
                    rden += a;
                    for (o, &vv) in rout.iter_mut().zip(&v[c * d..(c + 1) * d]) {
                        *o += a * vv;
                    }
                }
            }
            assert!((den[r] - rden).abs() < 1e-4 * rden.abs().max(1.0), "row {r} den");
            for (c, (&g, &w)) in out[r * d..(r + 1) * d].iter().zip(&rout).enumerate() {
                assert!((g - w).abs() < 1e-4 * w.abs().max(1.0), "({r},{c}): {g} vs {w}");
            }
        }
    }

    #[test]
    fn softmax_accum_masked_entries_contribute_nothing() {
        let mut rng = Rng::new(7);
        let (width, d) = (4usize, 8usize);
        let v = randv(width * d, &mut rng);
        // row with a -inf (masked) entry == row over only the live keys
        let tile = vec![1.0f32, f32::NEG_INFINITY, -0.5, 0.25];
        let live = vec![1.0f32, -0.5, 0.25];
        let mut live_v = v[..d].to_vec();
        live_v.extend_from_slice(&v[2 * d..4 * d]);

        let (mut m1, mut den1, mut out1) = (vec![f32::NEG_INFINITY], vec![0.0f32], vec![0.0f32; d]);
        softmax_accum_panel(&tile, &v, width, d, &mut m1, &mut den1, &mut out1);
        let (mut m2, mut den2, mut out2) = (vec![f32::NEG_INFINITY], vec![0.0f32], vec![0.0f32; d]);
        softmax_accum_panel(&live, &live_v, 3, d, &mut m2, &mut den2, &mut out2);
        assert_eq!(m1, m2);
        assert!((den1[0] - den2[0]).abs() < 1e-6);
        for (a, b) in out1.iter().zip(&out2) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn softmax_accum_fully_masked_row_is_skipped() {
        let d = 4usize;
        let v = vec![1.0f32; d];
        let tile = vec![f32::NEG_INFINITY];
        let (mut m, mut den, mut out) = (vec![f32::NEG_INFINITY], vec![0.0f32], vec![0.0f32; d]);
        softmax_accum_panel(&tile, &v, 1, d, &mut m, &mut den, &mut out);
        assert_eq!(m[0], f32::NEG_INFINITY);
        assert_eq!(den[0], 0.0);
        assert!(out.iter().all(|&x| x == 0.0), "no NaN leakage: {out:?}");
    }

    #[test]
    fn bf16_roundtrip_is_exact_for_representable_values_and_close_otherwise() {
        // values with <= 7 mantissa bits survive bitwise
        for x in [0.0f32, -0.0, 1.0, -1.5, 0.25, 96.0, -1024.0] {
            assert_eq!(bf16_to_f32(f32_to_bf16(x)).to_bits(), x.to_bits(), "{x}");
        }
        // everything else stays within half a bf16 ulp (relative 2^-8)
        let mut rng = Rng::new(9);
        let src = randv(512, &mut rng);
        let mut q = vec![0u16; src.len()];
        let mut back = vec![0.0f32; src.len()];
        quant_bf16(&src, &mut q);
        dequant_bf16(&q, &mut back);
        for (&x, &y) in src.iter().zip(&back) {
            assert!((x - y).abs() <= x.abs() * (1.0 / 256.0) + 1e-30, "{x} -> {y}");
        }
    }

    #[test]
    fn int8_roundtrip_error_is_bounded_by_half_a_step() {
        let mut rng = Rng::new(10);
        let src = randv(512, &mut rng);
        let scale = int8_scale(&src);
        assert!(scale > 0.0);
        let mut q = vec![0i8; src.len()];
        let mut back = vec![0.0f32; src.len()];
        quant_i8(&src, scale, &mut q);
        dequant_i8(&q, scale, &mut back);
        for (&x, &y) in src.iter().zip(&back) {
            assert!((x - y).abs() <= 0.5 * scale + 1e-6, "{x} -> {y} (scale {scale})");
        }
        // the extreme element maps to +-127 exactly
        let maxabs = src.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        assert!((maxabs - 127.0 * scale).abs() < 1e-6);
    }

    #[test]
    fn int8_zero_slice_has_zero_scale_and_exact_roundtrip() {
        let src = vec![0.0f32; 16];
        assert_eq!(int8_scale(&src), 0.0);
        let mut q = vec![7i8; 16];
        quant_i8(&src, 0.0, &mut q);
        assert!(q.iter().all(|&b| b == 0));
        let mut back = vec![9.0f32; 16];
        dequant_i8(&q, 0.0, &mut back);
        assert!(back.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn online_rescale_handles_ascending_and_descending_maxes() {
        // tiles arriving with increasing then decreasing maxes hit both the
        // rescale branch and the no-rescale branch
        let d = 2usize;
        let v = vec![1.0f32, 2.0];
        let (mut m, mut den, mut out) = (vec![0.0f32], vec![0.0f32], vec![0.0f32; d]);
        for &s in &[1.0f32, 5.0, 3.0] {
            softmax_accum_panel(&[s], &v, 1, d, &mut m, &mut den, &mut out);
        }
        let want_den: f32 = [1.0f32, 5.0, 3.0].iter().map(|s| (s - 5.0f32).exp()).sum();
        assert!((den[0] - want_den).abs() < 1e-6);
        assert!((out[0] - want_den * 1.0).abs() < 1e-5);
        assert!((out[1] - want_den * 2.0).abs() < 1e-5);
        assert_eq!(m[0], 5.0);
    }
}
