//! Elementwise / reduction operations shared by the MRA core and baselines.

use crate::tensor::kernel;
use crate::tensor::Mat;

/// Row-wise softmax (numerically stabilized).
pub fn softmax_rows(m: &Mat) -> Mat {
    let mut out = m.clone();
    for i in 0..m.rows {
        let row = out.row_mut(i);
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - mx).exp();
            sum += *v;
        }
        let inv = 1.0 / sum.max(1e-30);
        for v in row.iter_mut() {
            *v *= inv;
        }
    }
    out
}

/// Elementwise `exp`.
pub fn exp(m: &Mat) -> Mat {
    m.map(f32::exp)
}

/// Per-row sums as a vector.
pub fn row_sums(m: &Mat) -> Vec<f32> {
    (0..m.rows).map(|i| m.row(i).iter().sum()).collect()
}

/// Divide each row by the matching entry of `d` (row normalization).
pub fn div_rows(m: &Mat, d: &[f32]) -> Mat {
    assert_eq!(m.rows, d.len());
    let mut out = m.clone();
    for i in 0..m.rows {
        let inv = 1.0 / d[i].max(1e-30);
        kernel::scale(out.row_mut(i), inv);
    }
    out
}

/// Relative Frobenius error `||a - b||_F / ||b||_F` (the paper's metric).
pub fn rel_fro_error(a: &Mat, b: &Mat) -> f64 {
    assert_eq!((a.rows, a.cols), (b.rows, b.cols));
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for (x, y) in a.data.iter().zip(b.data.iter()) {
        let d = (*x as f64) - (*y as f64);
        num += d * d;
        den += (*y as f64) * (*y as f64);
    }
    (num / den.max(1e-300)).sqrt()
}

/// Mean softmax row entropy (x-axis of Fig. 5 / Fig. 7 right).
pub fn attention_entropy(p: &Mat) -> f64 {
    let a = softmax_rows(p);
    let mut total = 0.0f64;
    for i in 0..a.rows {
        for &v in a.row(i) {
            if v > 1e-30 {
                total -= (v as f64) * (v as f64).ln();
            }
        }
    }
    total / a.rows as f64
}

/// Average-pool groups of `b` consecutive rows: `(n, d) -> (n/b, d)`.
pub fn pool_rows(x: &Mat, b: usize) -> Mat {
    pool_rows_slice(&x.data, x.rows, x.cols, b)
}

/// [`pool_rows`] over a flat row-major `(rows, cols)` buffer (the form the
/// batched engine's per-head views use).
pub fn pool_rows_slice(x: &[f32], rows: usize, cols: usize, b: usize) -> Mat {
    assert_eq!(x.len(), rows * cols, "buffer/shape mismatch");
    assert_eq!(rows % b, 0, "block must divide rows");
    let nb = rows / b;
    let inv = 1.0 / b as f32;
    let mut out = Mat::zeros(nb, cols);
    for g in 0..nb {
        let orow = out.row_mut(g);
        for r in 0..b {
            // alpha = 1 AXPY: bitwise identical to the historical `+= v`
            // loop (1.0 * v == v), so the decode pyramid invariants hold
            kernel::axpy(orow, &x[(g * b + r) * cols..(g * b + r + 1) * cols], 1.0);
        }
        kernel::scale(orow, inv);
    }
    out
}

/// Index of the largest element (first on ties; 0 for an empty slice) —
/// the shared prediction argmax of the serving paths.
pub fn argmax(row: &[f32]) -> usize {
    let mut best = 0usize;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &v) in row.iter().enumerate() {
        if v > best_v {
            best_v = v;
            best = i;
        }
    }
    best
}

/// Scaled score matrix `P = Q K^T / sqrt(d)`.
pub fn scores(q: &Mat, k: &Mat) -> Mat {
    let scale = 1.0 / (q.cols as f32).sqrt();
    q.matmul_transb(k).scale(scale)
}

/// Exact attention `softmax(QK^T/sqrt(d)) V` — the gold standard everything
/// else is measured against.
pub fn exact_attention(q: &Mat, k: &Mat, v: &Mat) -> Mat {
    softmax_rows(&scores(q, k)).matmul(v)
}

/// LayerNorm over the last axis (gain 1, bias 0) — substrate for baselines.
pub fn layer_norm_rows(x: &Mat, eps: f32) -> Mat {
    let mut out = x.clone();
    for i in 0..x.rows {
        let row = out.row_mut(i);
        let n = row.len() as f32;
        let mu: f32 = row.iter().sum::<f32>() / n;
        let var: f32 = row.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / n;
        let inv = 1.0 / (var + eps).sqrt();
        for v in row.iter_mut() {
            *v = (*v - mu) * inv;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut rng = Rng::new(0);
        let m = Mat::randn(6, 10, 3.0, &mut rng);
        let s = softmax_rows(&m);
        for i in 0..6 {
            let sum: f32 = s.row(i).iter().sum();
            assert!((sum - 1.0).abs() < 1e-5);
            assert!(s.row(i).iter().all(|&v| v >= 0.0));
        }
    }

    #[test]
    fn softmax_handles_large_values() {
        let m = Mat::from_vec(1, 3, vec![1000.0, 1000.0, -1000.0]);
        let s = softmax_rows(&m);
        assert!((s.get(0, 0) - 0.5).abs() < 1e-5);
        assert!(s.get(0, 2) < 1e-6);
    }

    #[test]
    fn rel_fro_error_zero_for_identical() {
        let mut rng = Rng::new(1);
        let m = Mat::randn(4, 4, 1.0, &mut rng);
        assert!(rel_fro_error(&m, &m) < 1e-12);
    }

    #[test]
    fn rel_fro_error_scale_invariance() {
        let mut rng = Rng::new(2);
        let b = Mat::randn(8, 8, 1.0, &mut rng);
        let a = b.scale(1.1);
        let e1 = rel_fro_error(&a, &b);
        let a2 = b.scale(2.0).scale(1.1);
        let b2 = b.scale(2.0);
        let e2 = rel_fro_error(&a2, &b2);
        assert!((e1 - e2).abs() < 1e-6);
    }

    #[test]
    fn pool_rows_means() {
        let x = Mat::from_fn(4, 2, |i, _| i as f32);
        let p = pool_rows(&x, 2);
        assert_eq!(p.rows, 2);
        assert!((p.get(0, 0) - 0.5).abs() < 1e-6);
        assert!((p.get(1, 0) - 2.5).abs() < 1e-6);
        // the flat-slice form is the same computation
        assert_eq!(pool_rows_slice(&x.data, 4, 2, 2), p);
    }

    #[test]
    fn argmax_first_on_ties_and_empty_safe() {
        assert_eq!(argmax(&[1.0, 5.0, 5.0, -2.0]), 1);
        assert_eq!(argmax(&[-1.0]), 0);
        assert_eq!(argmax(&[]), 0);
    }

    #[test]
    fn entropy_bounds() {
        // uniform scores -> entropy = ln(n); peaked scores -> ~0
        let n = 16;
        let uniform = Mat::zeros(n, n);
        let e_u = attention_entropy(&uniform);
        assert!((e_u - (n as f64).ln()).abs() < 1e-4);
        let peaked = Mat::from_fn(n, n, |i, j| if i == j { 50.0 } else { 0.0 });
        assert!(attention_entropy(&peaked) < 1e-3);
    }

    #[test]
    fn exact_attention_rows_are_convex_combos() {
        let mut rng = Rng::new(3);
        let q = Mat::randn(8, 4, 1.0, &mut rng);
        let k = Mat::randn(8, 4, 1.0, &mut rng);
        let v = Mat::full(8, 4, 1.0);
        let z = exact_attention(&q, &k, &v);
        for &x in z.data.iter() {
            assert!((x - 1.0).abs() < 1e-5); // convex combo of ones = 1
        }
    }

    #[test]
    fn layer_norm_zero_mean_unit_var() {
        let mut rng = Rng::new(4);
        let x = Mat::randn(3, 32, 5.0, &mut rng);
        let y = layer_norm_rows(&x, 1e-5);
        for i in 0..3 {
            let mu: f32 = y.row(i).iter().sum::<f32>() / 32.0;
            let var: f32 = y.row(i).iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / 32.0;
            assert!(mu.abs() < 1e-4);
            assert!((var - 1.0).abs() < 1e-2);
        }
    }
}
