//! Randomized truncated SVD — the "optimal low-rank" comparator of
//! Fig. 1 / Fig. 7 and the Linformer/Scatterbrain low-rank substrates.
//!
//! Algorithm: randomized range finder with power iteration
//! (Halko–Martinsson–Tropp), small-side eigendecomposition via cyclic
//! Jacobi.  Accuracy is validated against exactly-low-rank matrices in the
//! tests below.

use crate::tensor::{Mat, Rng};

/// Result of a truncated SVD `A ~ U diag(s) V^T`.
pub struct Svd {
    pub u: Mat,      // (m, k)
    pub s: Vec<f32>, // (k,) descending
    pub v: Mat,      // (n, k)
}

impl Svd {
    /// Reconstruct the rank-`r` approximation (`r <= k`).
    pub fn reconstruct(&self, r: usize) -> Mat {
        let r = r.min(self.s.len());
        let m = self.u.rows;
        let n = self.v.rows;
        let mut out = Mat::zeros(m, n);
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f32;
                for t in 0..r {
                    acc += self.u.get(i, t) * self.s[t] * self.v.get(j, t);
                }
                out.set(i, j, acc);
            }
        }
        out
    }
}

/// Modified Gram–Schmidt QR: orthonormalize the columns of `a` in place,
/// returning the Q factor (columns with ~zero norm are re-randomized).
pub fn orthonormalize(a: &Mat, rng: &mut Rng) -> Mat {
    let (m, k) = (a.rows, a.cols);
    let mut q = a.clone();
    for j in 0..k {
        // retry loop: a (near-)zero column is re-randomized and
        // re-orthogonalized against all previous columns.  The projection
        // sweep runs twice ("twice is enough") — power-iterated sketches
        // have nearly parallel columns and single-pass MGS loses
        // orthogonality in f32.
        loop {
            for _pass in 0..2 {
                for prev in 0..j {
                    let mut dot = 0.0f32;
                    for i in 0..m {
                        dot += q.get(i, j) * q.get(i, prev);
                    }
                    for i in 0..m {
                        let v = q.get(i, j) - dot * q.get(i, prev);
                        q.set(i, j, v);
                    }
                }
            }
            let norm: f32 =
                (0..m).map(|i| q.get(i, j) * q.get(i, j)).sum::<f32>().sqrt();
            if norm >= 1e-6 {
                let inv = 1.0 / norm;
                for i in 0..m {
                    q.set(i, j, q.get(i, j) * inv);
                }
                break;
            }
            for i in 0..m {
                q.set(i, j, rng.normal());
            }
        }
    }
    q
}

/// Cyclic Jacobi eigendecomposition of a symmetric `k x k` matrix.
/// Returns `(eigenvalues desc, eigenvectors as columns)`.
pub fn jacobi_eigh(s: &Mat, sweeps: usize) -> (Vec<f32>, Mat) {
    assert_eq!(s.rows, s.cols);
    let n = s.rows;
    let mut a = s.clone();
    let mut v = Mat::eye(n);
    for _ in 0..sweeps {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                off += (a.get(p, q) as f64).powi(2);
            }
        }
        if off.sqrt() < 1e-10 {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = a.get(p, q);
                if apq.abs() < 1e-12 {
                    continue;
                }
                let app = a.get(p, p);
                let aqq = a.get(q, q);
                let theta = 0.5 * (aqq - app) as f64 / apq as f64;
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let sn = t * c;
                let (c, sn) = (c as f32, sn as f32);
                for i in 0..n {
                    let aip = a.get(i, p);
                    let aiq = a.get(i, q);
                    a.set(i, p, c * aip - sn * aiq);
                    a.set(i, q, sn * aip + c * aiq);
                }
                for j in 0..n {
                    let apj = a.get(p, j);
                    let aqj = a.get(q, j);
                    a.set(p, j, c * apj - sn * aqj);
                    a.set(q, j, sn * apj + c * aqj);
                }
                let _ = (app, aqq);
                for i in 0..n {
                    let vip = v.get(i, p);
                    let viq = v.get(i, q);
                    v.set(i, p, c * vip - sn * viq);
                    v.set(i, q, sn * vip + c * viq);
                }
            }
        }
    }
    let mut pairs: Vec<(f32, usize)> = (0..n).map(|i| (a.get(i, i), i)).collect();
    pairs.sort_by(|x, y| y.0.partial_cmp(&x.0).unwrap());
    let evals: Vec<f32> = pairs.iter().map(|p| p.0).collect();
    let mut evecs = Mat::zeros(n, n);
    for (newc, &(_, oldc)) in pairs.iter().enumerate() {
        for i in 0..n {
            evecs.set(i, newc, v.get(i, oldc));
        }
    }
    (evals, evecs)
}

/// Randomized truncated SVD with `iters` power iterations and oversampling.
pub fn randomized_svd(a: &Mat, k: usize, iters: usize, rng: &mut Rng) -> Svd {
    let (m, n) = (a.rows, a.cols);
    let k = k.min(m.min(n));
    let p = (k + 8).min(n); // oversampled sketch width
    let omega = Mat::randn(n, p, 1.0, rng);
    let mut y = a.matmul(&omega); // (m, p)
    let at = a.transpose();
    for _ in 0..iters {
        y = orthonormalize(&y, rng);
        let z = at.matmul(&y); // (n, p)
        y = a.matmul(&orthonormalize(&z, rng));
    }
    let q = orthonormalize(&y, rng); // (m, p)
    let b = q.transpose().matmul(a); // (p, n)
    let bbt = b.matmul_transb(&b); // (p, p) symmetric
    let (evals, evecs) = jacobi_eigh(&bbt, 30);
    // singular values / vectors from the small eigenproblem
    let mut s = Vec::with_capacity(k);
    let mut ub = Mat::zeros(q.rows, k);
    let mut vt = Mat::zeros(n, k);
    let u_small = evecs; // (p, p)
    let ub_full = q.matmul(&u_small); // (m, p) — left singular vectors
    for t in 0..k {
        let sigma = evals[t].max(0.0).sqrt();
        s.push(sigma);
        for i in 0..m {
            ub.set(i, t, ub_full.get(i, t));
        }
        if sigma > 1e-12 {
            // v_t = B^T u_small_t / sigma
            for j in 0..n {
                let mut acc = 0.0f32;
                for r in 0..b.rows {
                    acc += b.get(r, j) * u_small.get(r, t);
                }
                vt.set(j, t, acc / sigma);
            }
        }
    }
    Svd { u: ub, s, v: vt }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops::rel_fro_error;

    fn low_rank_matrix(m: usize, n: usize, r: usize, rng: &mut Rng) -> Mat {
        let a = Mat::randn(m, r, 1.0, rng);
        let b = Mat::randn(r, n, 1.0, rng);
        a.matmul(&b)
    }

    #[test]
    fn orthonormalize_gives_orthonormal_columns() {
        let mut rng = Rng::new(0);
        let a = Mat::randn(20, 6, 1.0, &mut rng);
        let q = orthonormalize(&a, &mut rng);
        let g = q.transpose().matmul(&q);
        for i in 0..6 {
            for j in 0..6 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((g.get(i, j) - want).abs() < 1e-4, "({i},{j})");
            }
        }
    }

    #[test]
    fn jacobi_diagonalizes_known_matrix() {
        // eigenvalues of [[2,1],[1,2]] are 3 and 1
        let s = Mat::from_vec(2, 2, vec![2.0, 1.0, 1.0, 2.0]);
        let (evals, evecs) = jacobi_eigh(&s, 20);
        assert!((evals[0] - 3.0).abs() < 1e-4);
        assert!((evals[1] - 1.0).abs() < 1e-4);
        // S v = lambda v
        for t in 0..2 {
            for i in 0..2 {
                let sv: f32 = (0..2).map(|j| s.get(i, j) * evecs.get(j, t)).sum();
                assert!((sv - evals[t] * evecs.get(i, t)).abs() < 1e-3);
            }
        }
    }

    #[test]
    fn svd_recovers_exactly_low_rank() {
        let mut rng = Rng::new(1);
        let a = low_rank_matrix(40, 30, 5, &mut rng);
        let svd = randomized_svd(&a, 5, 3, &mut rng);
        let rec = svd.reconstruct(5);
        let err = rel_fro_error(&rec, &a);
        let gu = svd.u.transpose().matmul(&svd.u);
        let gv = svd.v.transpose().matmul(&svd.v);
        println!("s={:?} err={err}", svd.s);
        println!("UtU diag={:?}", (0..5).map(|i| gu.get(i, i)).collect::<Vec<_>>());
        println!("VtV diag={:?}", (0..5).map(|i| gv.get(i, i)).collect::<Vec<_>>());
        assert!(err < 1e-3, "err={err}");
    }

    #[test]
    fn singular_values_descending() {
        let mut rng = Rng::new(2);
        let a = Mat::randn(30, 30, 1.0, &mut rng);
        let svd = randomized_svd(&a, 10, 3, &mut rng);
        for w in svd.s.windows(2) {
            assert!(w[0] >= w[1] - 1e-4);
        }
    }

    #[test]
    fn truncation_error_decreases_with_rank() {
        let mut rng = Rng::new(3);
        let a = Mat::randn(32, 32, 1.0, &mut rng);
        let svd = randomized_svd(&a, 24, 4, &mut rng);
        let e8 = rel_fro_error(&svd.reconstruct(8), &a);
        let e24 = rel_fro_error(&svd.reconstruct(24), &a);
        assert!(e24 <= e8 + 1e-5, "e8={e8} e24={e24}");
    }
}
