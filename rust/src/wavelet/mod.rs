//! 1D / 2D Haar wavelet transform — the classical MRA machinery of Sec. 2.2
//! and the comparator of Fig. 1 (coefficient histogram, top-coefficient
//! reconstruction).
//!
//! The orthonormal Haar filters are `L = (1/sqrt2, 1/sqrt2)` and
//! `H = (1/sqrt2, -1/sqrt2)`; the analysis operator is a linear isometry
//! (Parseval — asserted in tests).

use crate::tensor::Mat;

const INV_SQRT2: f32 = std::f32::consts::FRAC_1_SQRT_2;

/// One analysis level in place: `x[..n]` -> `[approx | detail]`, each `n/2`.
fn haar1d_step(x: &mut [f32], n: usize, scratch: &mut [f32]) {
    let half = n / 2;
    for i in 0..half {
        scratch[i] = (x[2 * i] + x[2 * i + 1]) * INV_SQRT2;
        scratch[half + i] = (x[2 * i] - x[2 * i + 1]) * INV_SQRT2;
    }
    x[..n].copy_from_slice(&scratch[..n]);
}

/// One synthesis level in place (inverse of [`haar1d_step`]).
fn haar1d_inv_step(x: &mut [f32], n: usize, scratch: &mut [f32]) {
    let half = n / 2;
    for i in 0..half {
        scratch[2 * i] = (x[i] + x[half + i]) * INV_SQRT2;
        scratch[2 * i + 1] = (x[i] - x[half + i]) * INV_SQRT2;
    }
    x[..n].copy_from_slice(&scratch[..n]);
}

/// Full 1D Haar analysis (length must be a power of two).
pub fn haar1d(x: &[f32]) -> Vec<f32> {
    let n = x.len();
    assert!(n.is_power_of_two(), "length must be a power of two");
    let mut out = x.to_vec();
    let mut scratch = vec![0.0f32; n];
    let mut len = n;
    while len >= 2 {
        haar1d_step(&mut out, len, &mut scratch);
        len /= 2;
    }
    out
}

/// Full 1D Haar synthesis (inverse of [`haar1d`]).
pub fn haar1d_inverse(c: &[f32]) -> Vec<f32> {
    let n = c.len();
    assert!(n.is_power_of_two());
    let mut out = c.to_vec();
    let mut scratch = vec![0.0f32; n];
    let mut len = 2;
    while len <= n {
        haar1d_inv_step(&mut out, len, &mut scratch);
        len *= 2;
    }
    out
}

/// 2D Haar analysis: standard (non-separable-level) square decomposition —
/// alternate one level on all rows then all columns, down to 1x1.
pub fn haar2d(a: &Mat) -> Mat {
    let n = a.rows;
    assert_eq!(a.rows, a.cols, "square matrices only");
    assert!(n.is_power_of_two());
    let mut out = a.clone();
    let mut scratch = vec![0.0f32; n];
    let mut len = n;
    while len >= 2 {
        for i in 0..len {
            let row = &mut out.data[i * n..i * n + len];
            haar1d_step(row, len, &mut scratch);
        }
        let mut col = vec![0.0f32; len];
        for j in 0..len {
            for i in 0..len {
                col[i] = out.data[i * n + j];
            }
            haar1d_step(&mut col, len, &mut scratch);
            for i in 0..len {
                out.data[i * n + j] = col[i];
            }
        }
        len /= 2;
    }
    out
}

/// Inverse of [`haar2d`].
pub fn haar2d_inverse(c: &Mat) -> Mat {
    let n = c.rows;
    assert_eq!(c.rows, c.cols);
    assert!(n.is_power_of_two());
    let mut out = c.clone();
    let mut scratch = vec![0.0f32; n];
    let mut len = 2;
    while len <= n {
        let mut col = vec![0.0f32; len];
        for j in 0..len {
            for i in 0..len {
                col[i] = out.data[i * n + j];
            }
            haar1d_inv_step(&mut col, len, &mut scratch);
            for i in 0..len {
                out.data[i * n + j] = col[i];
            }
        }
        for i in 0..len {
            let row = &mut out.data[i * n..i * n + len];
            haar1d_inv_step(row, len, &mut scratch);
        }
        len *= 2;
    }
    out
}

/// Keep only the `k` largest-magnitude coefficients (the Fig. 1
/// "top p% of coefficients" reconstruction), zeroing the rest.
pub fn threshold_top_k(c: &Mat, k: usize) -> Mat {
    let mags: Vec<f32> = c.data.iter().map(|v| v.abs()).collect();
    let keep = crate::tensor::topk::top_k_indices(&mags, k);
    let mut out = Mat::zeros(c.rows, c.cols);
    for idx in keep {
        out.data[idx] = c.data[idx];
    }
    out
}

/// Histogram of |coefficient| in log10 bins — the Fig. 1 left panel.
/// Returns `(bin_edges, counts)` over `[10^lo, 10^hi]` with `bins` bins.
pub fn coeff_histogram(c: &Mat, lo: f64, hi: f64, bins: usize) -> (Vec<f64>, Vec<usize>) {
    let mut counts = vec![0usize; bins];
    let width = (hi - lo) / bins as f64;
    for &v in c.data.iter() {
        let lg = (v.abs().max(1e-30) as f64).log10();
        let b = ((lg - lo) / width).floor();
        let b = b.clamp(0.0, bins as f64 - 1.0) as usize;
        counts[b] += 1;
    }
    let edges = (0..=bins).map(|i| lo + i as f64 * width).collect();
    (edges, counts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{ops::rel_fro_error, Rng};

    #[test]
    fn haar1d_roundtrip() {
        let mut rng = Rng::new(0);
        let x: Vec<f32> = (0..64).map(|_| rng.normal()).collect();
        let c = haar1d(&x);
        let y = haar1d_inverse(&c);
        for (a, b) in x.iter().zip(y.iter()) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn haar1d_constant_signal_single_coeff() {
        let x = vec![3.0f32; 8];
        let c = haar1d(&x);
        // all energy in the approximation coefficient
        assert!((c[0] - 3.0 * (8.0f32).sqrt()).abs() < 1e-4);
        for &v in &c[1..] {
            assert!(v.abs() < 1e-5);
        }
    }

    #[test]
    fn haar1d_parseval() {
        let mut rng = Rng::new(1);
        let x: Vec<f32> = (0..128).map(|_| rng.normal()).collect();
        let c = haar1d(&x);
        let ex: f64 = x.iter().map(|&v| (v as f64).powi(2)).sum();
        let ec: f64 = c.iter().map(|&v| (v as f64).powi(2)).sum();
        assert!((ex - ec).abs() / ex < 1e-5);
    }

    #[test]
    fn haar2d_roundtrip() {
        let mut rng = Rng::new(2);
        let a = Mat::randn(32, 32, 1.0, &mut rng);
        let c = haar2d(&a);
        let b = haar2d_inverse(&c);
        assert!(rel_fro_error(&b, &a) < 1e-5);
    }

    #[test]
    fn haar2d_parseval() {
        let mut rng = Rng::new(3);
        let a = Mat::randn(16, 16, 2.0, &mut rng);
        let c = haar2d(&a);
        assert!((a.fro_norm() - c.fro_norm()).abs() / a.fro_norm() < 1e-5);
    }

    #[test]
    fn threshold_reconstruction_error_decreases_with_k() {
        let mut rng = Rng::new(4);
        let a = Mat::randn(32, 32, 1.0, &mut rng);
        let c = haar2d(&a);
        let mut prev = f64::INFINITY;
        for k in [64, 256, 1024] {
            let rec = haar2d_inverse(&threshold_top_k(&c, k));
            let e = rel_fro_error(&rec, &a);
            assert!(e <= prev + 1e-6);
            prev = e;
        }
        // full coefficient set -> exact
        let rec = haar2d_inverse(&threshold_top_k(&c, 32 * 32));
        assert!(rel_fro_error(&rec, &a) < 1e-5);
    }

    #[test]
    fn histogram_counts_all_entries() {
        let mut rng = Rng::new(5);
        let a = Mat::randn(16, 16, 1.0, &mut rng);
        let (_edges, counts) = coeff_histogram(&a, -6.0, 2.0, 24);
        assert_eq!(counts.iter().sum::<usize>(), 256);
    }
}
