//! Training driver: runs the AOT `train_<tag>_b{B}` step artifact in a loop
//! over synthetic-corpus batches, holding parameters + Adam moments as flat
//! host vectors (the artifact's interchange layout).
//!
//! The whole loop is Rust-side: Python produced the HLO once at build time.

use anyhow::{Context, Result};

use crate::config::TrainConfig;
use crate::coordinator::native::{NativeMlm, NativeMlmConfig};
use crate::data::corpus::{Corpus, CorpusConfig, MlmBatch};
use crate::runtime::{HostTensor, Manifest, RuntimeHandle};

/// Loss/accuracy trace of a training run.
#[derive(Debug, Default, Clone)]
pub struct TrainLog {
    /// Step index of each recorded sample.
    pub steps: Vec<usize>,
    /// Training loss at each recorded step.
    pub losses: Vec<f32>,
    /// Masked-prediction accuracy at each recorded step.
    pub accs: Vec<f32>,
}

impl TrainLog {
    /// Last recorded loss (`NaN` when the log is empty).
    pub fn final_loss(&self) -> f32 {
        *self.losses.last().unwrap_or(&f32::NAN)
    }

    /// Mean of the first / last `k` recorded losses (trend check).
    pub fn head_tail_means(&self, k: usize) -> (f32, f32) {
        let k = k.min(self.losses.len());
        let head: f32 = self.losses[..k].iter().sum::<f32>() / k as f32;
        let tail: f32 =
            self.losses[self.losses.len() - k..].iter().sum::<f32>() / k as f32;
        (head, tail)
    }
}

/// MLM trainer over one model tag.
pub struct Trainer {
    rt: RuntimeHandle,
    #[allow(dead_code)]
    manifest: std::sync::Arc<Manifest>,
    /// Training hyperparameters and model tag.
    pub cfg: TrainConfig,
    /// Flattened parameter vector in the `train_step` artifact's layout.
    pub params: Vec<f32>,
    m: Vec<f32>,
    v: Vec<f32>,
    step: usize,
    corpus: Corpus,
    train_artifact: String,
    eval_artifact: String,
    seq_len: usize,
}

impl Trainer {
    /// Set up training over `cfg.model`'s artifacts: initial parameters
    /// from the manifest, fresh Adam moments, a seeded corpus.
    pub fn new(
        rt: RuntimeHandle,
        #[allow(dead_code)]
    manifest: std::sync::Arc<Manifest>,
        cfg: TrainConfig,
    ) -> Result<Self> {
        let params = manifest
            .load_f32(&format!("{}.params.f32", cfg.model))
            .context("loading initial params")?;
        let model_cfg = manifest.load_cfg(&cfg.model)?;
        let seq_len: usize = model_cfg
            .get("seq_len")
            .context("cfg missing seq_len")?
            .parse()?;
        let vocab: usize = model_cfg.get("vocab").context("cfg missing vocab")?.parse()?;
        let train_artifact = format!("train_{}_b{}", cfg.model, cfg.batch);
        let eval_artifact = format!("eval_{}_b{}", cfg.model, cfg.batch);
        manifest.get(&train_artifact)?; // fail fast with a clear error
        let corpus = Corpus::new(
            CorpusConfig { vocab, seq_len, ..Default::default() },
            cfg.seed,
        );
        let n = params.len();
        Ok(Trainer {
            rt,
            manifest,
            cfg,
            params,
            m: vec![0.0; n],
            v: vec![0.0; n],
            step: 0,
            corpus,
            train_artifact,
            eval_artifact,
            seq_len,
        })
    }

    fn batch_tensors(&self, b: &MlmBatch) -> Vec<HostTensor> {
        vec![
            HostTensor::I32(b.input_ids.clone(), vec![b.batch, self.seq_len]),
            HostTensor::I32(b.labels.clone(), vec![b.batch, self.seq_len]),
            HostTensor::F32(b.weights.clone(), vec![b.batch, self.seq_len]),
        ]
    }

    /// One optimizer step; returns `(loss, acc)`.
    pub fn train_step(&mut self) -> Result<(f32, f32)> {
        let batch = self.corpus.mlm_batch(self.cfg.batch);
        let mut inputs = vec![
            HostTensor::F32(std::mem::take(&mut self.params), vec![self.m.len()]),
            HostTensor::F32(std::mem::take(&mut self.m), vec![self.v.len()]),
            HostTensor::F32(std::mem::take(&mut self.v), vec![0]),
        ];
        // fix the placeholder dims (taken vectors know their own length)
        if let HostTensor::F32(p, d) = &mut inputs[0] {
            *d = vec![p.len()];
        }
        if let HostTensor::F32(p, d) = &mut inputs[1] {
            *d = vec![p.len()];
        }
        if let HostTensor::F32(p, d) = &mut inputs[2] {
            *d = vec![p.len()];
        }
        inputs.push(HostTensor::scalar_f32(self.step as f32));
        inputs.extend(self.batch_tensors(&batch));
        let mut out = self.rt.execute(&self.train_artifact, inputs)?;
        // outputs: params', m', v', loss, acc
        let acc = scalar(&out.pop().unwrap())?;
        let loss = scalar(&out.pop().unwrap())?;
        let v = out.pop().unwrap();
        let m = out.pop().unwrap();
        let p = out.pop().unwrap();
        self.params = into_f32(p)?;
        self.m = into_f32(m)?;
        self.v = into_f32(v)?;
        self.step += 1;
        Ok((loss, acc))
    }

    /// Held-out evaluation batch (fresh seed stream).
    pub fn eval(&mut self) -> Result<(f32, f32)> {
        let mut held_out = Corpus::new(
            CorpusConfig { vocab: 512, seq_len: self.seq_len, ..Default::default() },
            self.cfg.seed ^ 0xEEE,
        );
        let batch = held_out.mlm_batch(self.cfg.batch);
        let mut inputs =
            vec![HostTensor::F32(self.params.clone(), vec![self.params.len()])];
        inputs.extend(self.batch_tensors(&batch));
        let out = self.rt.execute(&self.eval_artifact, inputs)?;
        Ok((scalar(&out[0])?, scalar(&out[1])?))
    }

    /// Native-fallback evaluation for when `artifacts/` has not been built:
    /// score one held-out MLM batch through the batched engine
    /// ([`NativeMlm`], untrained deterministic weights) with
    /// `engine_threads` attention workers.  Returns `(loss, masked-acc)` —
    /// a smoke-level analog of [`Trainer::eval`] that keeps the evaluation
    /// path exercisable offline.
    pub fn eval_native(cfg: &TrainConfig, engine_threads: usize) -> Result<(f32, f32)> {
        let model_cfg = NativeMlmConfig::from_tag(&cfg.model);
        let (vocab, seq_len) = (model_cfg.vocab, model_cfg.seq_len);
        let model = NativeMlm::new(model_cfg, engine_threads);
        let mut held_out = Corpus::new(
            CorpusConfig { vocab, seq_len, ..Default::default() },
            cfg.seed ^ 0xEEE,
        );
        let batch = held_out.mlm_batch(cfg.batch.clamp(1, 8));
        model.masked_eval(&batch)
    }

    /// Run the configured number of steps, logging every `log_every`.
    pub fn run(&mut self) -> Result<TrainLog> {
        let mut log = TrainLog::default();
        for s in 0..self.cfg.steps {
            let (loss, acc) = self.train_step()?;
            if s % self.cfg.log_every == 0 || s + 1 == self.cfg.steps {
                log.steps.push(s);
                log.losses.push(loss);
                log.accs.push(acc);
                println!("step {s:>5}  loss {loss:.4}  masked-acc {acc:.3}");
            }
            if self.cfg.eval_every > 0 && s > 0 && s % self.cfg.eval_every == 0 {
                let (el, ea) = self.eval()?;
                println!("step {s:>5}  [eval] loss {el:.4}  masked-acc {ea:.3}");
            }
        }
        Ok(log)
    }
}

fn scalar(t: &HostTensor) -> Result<f32> {
    Ok(t.as_f32()?[0])
}

fn into_f32(t: HostTensor) -> Result<Vec<f32>> {
    match t {
        HostTensor::F32(v, _) => Ok(v),
        _ => anyhow::bail!("expected f32 output"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eval_native_runs_without_artifacts() {
        let cfg = TrainConfig {
            steps: 1,
            batch: 2,
            eval_every: 0,
            seed: 5,
            model: "mlm_mra2_n64_d32_l1_h2_v64".to_string(),
            artifacts_dir: "no-such-dir".to_string(),
            log_every: 1,
        };
        let (loss, acc) = Trainer::eval_native(&cfg, 2).unwrap();
        assert!(loss.is_finite() && loss > 0.0, "loss={loss}");
        assert!((0.0..=1.0).contains(&acc), "acc={acc}");
        // deterministic across engine thread counts (bitwise engine)
        let again = Trainer::eval_native(&cfg, 4).unwrap();
        assert_eq!((loss, acc), again);
    }

    #[test]
    fn train_log_trend_helpers() {
        let log = TrainLog {
            steps: vec![0, 1, 2, 3],
            losses: vec![4.0, 3.0, 2.0, 1.0],
            accs: vec![0.1, 0.2, 0.3, 0.4],
        };
        assert_eq!(log.final_loss(), 1.0);
        let (head, tail) = log.head_tail_means(2);
        assert!((head - 3.5).abs() < 1e-6);
        assert!((tail - 1.5).abs() < 1e-6);
    }
}
