//! Serving metrics: log-bucketed latency histogram + counters, plus the
//! session-serving gauges (page-pool occupancy, radix prefix-cache hit
//! rate, preemptions, running-batch size) the continuous-batching
//! scheduler publishes every step.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Number of doubling buckets in a [`Histogram`] (1us to ~17min).
pub const HIST_BUCKETS: usize = 31;

/// Interpolated percentile over power-of-two µs bucket counts: find the
/// bucket holding the `ceil(total * p)`-th sample, then place the result
/// linearly inside `[2^i, 2^(i+1))` by the sample's rank among the
/// bucket's occupants (each sample owns the midpoint of its 1/b span).
/// A single 1µs sample therefore reports 1µs, not the 2µs upper edge —
/// the bias [`percentile_upper_edge`] keeps for comparison.
fn percentile_interp(buckets: &[u64], total: u64, p: f64) -> u64 {
    if total == 0 {
        return 0;
    }
    let target = ((total as f64) * p).ceil() as u64;
    let mut seen = 0u64;
    for (i, &b) in buckets.iter().enumerate() {
        if seen + b >= target {
            let lo = 1u64 << i;
            let hi = 1u64 << (i + 1);
            let rank = target.saturating_sub(seen) as f64;
            let frac =
                if b == 0 { 0.0 } else { ((rank - 0.5) / b as f64).clamp(0.0, 1.0) };
            return (lo as f64 + frac * (hi - lo) as f64).floor() as u64;
        }
        seen += b;
    }
    1u64 << buckets.len()
}

/// The historical percentile estimate: the *upper edge* of the containing
/// bucket.  Biased high by up to 2x (a bucket-0 sample of 1µs reports
/// 2µs); kept verbatim so the interpolated fix stays comparable.
fn percentile_upper_edge(buckets: &[u64], total: u64, p: f64) -> u64 {
    if total == 0 {
        return 0;
    }
    let target = ((total as f64) * p).ceil() as u64;
    let mut seen = 0u64;
    for (i, &b) in buckets.iter().enumerate() {
        seen += b;
        if seen >= target {
            return 1u64 << (i + 1);
        }
    }
    1u64 << buckets.len()
}

/// Log-spaced latency histogram from 1us to ~17min (31 doubling buckets).
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_us: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    /// An empty histogram (power-of-two microsecond buckets).
    pub fn new() -> Self {
        Histogram {
            buckets: (0..HIST_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
        }
    }

    /// Record one duration (clamped into the top bucket).
    pub fn record(&self, d: Duration) {
        let us = d.as_micros().max(1) as u64;
        let idx = (63 - us.leading_zeros() as usize).min(self.buckets.len() - 1);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
    }

    /// Total samples recorded.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Sum of recorded durations in microseconds.
    pub fn sum_us(&self) -> u64 {
        self.sum_us.load(Ordering::Relaxed)
    }

    /// Mean recorded latency in microseconds (`0.0` when empty).
    pub fn mean_us(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            return 0.0;
        }
        self.sum_us.load(Ordering::Relaxed) as f64 / c as f64
    }

    fn load_buckets(&self) -> [u64; HIST_BUCKETS] {
        let mut out = [0u64; HIST_BUCKETS];
        for (o, b) in out.iter_mut().zip(&self.buckets) {
            *o = b.load(Ordering::Relaxed);
        }
        out
    }

    /// Approximate percentile in µs, interpolated linearly within the
    /// containing power-of-two bucket (see [`Histogram::snapshot`] for
    /// windowed percentiles).
    pub fn percentile_us(&self, p: f64) -> u64 {
        percentile_interp(&self.load_buckets(), self.count(), p)
    }

    /// The pre-interpolation percentile (upper edge of the containing
    /// bucket) — biased high by up to 2x, kept for comparison against
    /// [`Histogram::percentile_us`].
    pub fn percentile_us_upper_edge(&self, p: f64) -> u64 {
        percentile_upper_edge(&self.load_buckets(), self.count(), p)
    }

    /// Upper edge (exclusive, µs) of bucket `i` — the `le` label the
    /// Prometheus exposition uses.
    pub fn bucket_upper_edge_us(i: usize) -> u64 {
        1u64 << (i + 1)
    }

    /// A point-in-time copy of the histogram.  Pair two snapshots with
    /// [`HistogramSnapshot::delta_since`] to window percentiles over the
    /// last N steps instead of the process lifetime.  Loads are relaxed
    /// and per-field, so a snapshot taken concurrently with `record` may
    /// be off by the in-flight sample — deltas remain non-negative.
    pub fn snapshot(&self) -> HistogramSnapshot {
        HistogramSnapshot {
            buckets: self.load_buckets(),
            count: self.count(),
            sum_us: self.sum_us.load(Ordering::Relaxed),
        }
    }
}

/// Immutable copy of a [`Histogram`], with the same percentile/mean
/// queries plus windowed deltas — the snapshot/delta form of a
/// `reset_window()` (no observer can clear another's window).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct HistogramSnapshot {
    buckets: [u64; HIST_BUCKETS],
    count: u64,
    sum_us: u64,
}

impl HistogramSnapshot {
    /// Samples in this snapshot (or window).
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Sum of recorded µs in this snapshot (or window).
    pub fn sum_us(&self) -> u64 {
        self.sum_us
    }

    /// Mean µs (`0.0` when empty).
    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        self.sum_us as f64 / self.count as f64
    }

    /// Interpolated percentile in µs (see [`Histogram::percentile_us`]).
    pub fn percentile_us(&self, p: f64) -> u64 {
        percentile_interp(&self.buckets, self.count, p)
    }

    /// Upper-edge percentile in µs (the historical biased estimate).
    pub fn percentile_us_upper_edge(&self, p: f64) -> u64 {
        percentile_upper_edge(&self.buckets, self.count, p)
    }

    /// Per-bucket sample counts (index `i` spans `[2^i, 2^(i+1))` µs).
    pub fn bucket_counts(&self) -> &[u64; HIST_BUCKETS] {
        &self.buckets
    }

    /// The window recorded since `prev`: per-bucket, count and sum
    /// differences (saturating, so a mismatched pair cannot underflow).
    /// `prev` plus the returned delta sums back to `self` field-by-field
    /// (unit-tested).
    pub fn delta_since(&self, prev: &HistogramSnapshot) -> HistogramSnapshot {
        let mut buckets = [0u64; HIST_BUCKETS];
        for (o, (a, b)) in buckets.iter_mut().zip(self.buckets.iter().zip(&prev.buckets)) {
            *o = a.saturating_sub(*b);
        }
        HistogramSnapshot {
            buckets,
            count: self.count.saturating_sub(prev.count),
            sum_us: self.sum_us.saturating_sub(prev.sum_us),
        }
    }
}

/// The phases one scheduler step's elapsed time is attributed to
/// (DESIGN.md §14 states the attribution rules).  Indexes the per-phase
/// histograms ([`Metrics::phase`]) and the `StepEnd` trace event's
/// `phases` array, in declaration order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StepPhase {
    /// Draining newly arrived requests from the ingress queue (the idle
    /// blocking wait for a *first* request is excluded — it is not work).
    Ingress,
    /// Waiter shedding, deadline expiry, admission and finish delivery.
    Admission,
    /// Chunk planning + page reservation, including cache eviction and
    /// preemption triggered by the reservation.
    Reserve,
    /// Prefill work: q/k/v projection + bulk append and chunk-row
    /// attention (the phased path's final-chunk logits included).
    PrefillAttend,
    /// Decode work: token selection, embed, per-stream attention,
    /// residual + layer norm.
    DecodeAttend,
    /// Tied-head vocab projection (logits) of the fused/batched step.
    Logits,
    /// Token stream delivery and gauge publication.
    StreamEgress,
}

impl StepPhase {
    /// Every phase, in histogram/trace-array order.
    pub const ALL: [StepPhase; 7] = [
        StepPhase::Ingress,
        StepPhase::Admission,
        StepPhase::Reserve,
        StepPhase::PrefillAttend,
        StepPhase::DecodeAttend,
        StepPhase::Logits,
        StepPhase::StreamEgress,
    ];

    /// Stable snake_case name (Prometheus label / summarizer column).
    pub fn name(self) -> &'static str {
        match self {
            StepPhase::Ingress => "ingress",
            StepPhase::Admission => "admission",
            StepPhase::Reserve => "reserve",
            StepPhase::PrefillAttend => "prefill_attend",
            StepPhase::DecodeAttend => "decode_attend",
            StepPhase::Logits => "logits",
            StepPhase::StreamEgress => "stream_egress",
        }
    }

    /// Position in [`StepPhase::ALL`] (and the `StepEnd` phases array).
    pub fn index(self) -> usize {
        self as usize
    }
}

/// Coordinator-wide metrics.
///
/// The session-serving fields split into **counters** (monotone:
/// `sessions`, `preemptions`, `prefix_*`, `generated_tokens`,
/// `decode_steps`) and **gauges** (last published value: `pool_pages`,
/// `free_pages`, `cache_pages`, `running_sessions`, `waiting_sessions`),
/// refreshed by the scheduler once per decode step.
#[derive(Default)]
pub struct Metrics {
    /// End-to-end request latency (submit to response).
    pub request_latency: Histogram,
    /// Per-batch (or per-step) execution latency of the worker body.
    pub batch_exec: Histogram,
    /// Wall latency of scheduler steps that advanced at least one decode
    /// (fused drain or legacy sub-phases) — the tail this histogram
    /// records during long prefills is exactly what the budget
    /// controller holds under `sessions.decode_p95_target_us`.
    pub decode_step_latency: Histogram,
    /// Requests accepted into the serving queue.
    pub requests: AtomicU64,
    /// Batches executed by the workers (fixed-round path).
    pub batches: AtomicU64,
    /// Requests refused at ingress (full queue) or expired past their
    /// admission deadline.
    pub rejected: AtomicU64,
    /// Padding slots added to fill routed batch buckets.
    pub padded_slots: AtomicU64,
    // --- session-serving counters ---
    /// Sessions admitted by the scheduler (their prompt prefill may still
    /// be in progress — see `prefilling_sessions`).
    pub sessions: AtomicU64,
    /// Sessions preempted under memory pressure (recomputed on readmit).
    pub preemptions: AtomicU64,
    /// Radix prefix-cache lookups at admission.
    pub prefix_lookups: AtomicU64,
    /// Lookups that reused at least one cached block.
    pub prefix_hits: AtomicU64,
    /// Prompt tokens served from shared cache pages instead of recomputed.
    pub prefix_hit_tokens: AtomicU64,
    /// Tokens emitted by the continuous decode loop.
    pub generated_tokens: AtomicU64,
    /// Continuous-batching decode steps executed.
    pub decode_steps: AtomicU64,
    /// Prefill chunks run through the engine-parallel chunked path.
    pub prefill_chunks: AtomicU64,
    /// Prompt tokens prefilled through chunks (radix-cached tokens are
    /// *not* counted — they were never recomputed).
    pub prefill_tokens: AtomicU64,
    /// Tokens delivered on per-request stream channels (each generated
    /// token is streamed at most once, preemption or not).
    pub streamed_tokens: AtomicU64,
    /// Non-blocking stream sends refused by a full channel (consumer
    /// backpressure; the tokens retry next step, the scheduler never
    /// blocks).
    pub stream_stalls: AtomicU64,
    /// Waiting requests expired past their admission deadline (answered
    /// with a descriptive error, never silently dropped).
    pub deadline_expired: AtomicU64,
    /// Prefill-budget grants beyond each session's first chunk of a step
    /// — leftover budget (block-snap remainders, short finishing prompts)
    /// re-offered within the same step instead of stranded.
    pub budget_reoffers: AtomicU64,
    /// Admissions whose radix prefix hit matched blocks published by a
    /// session *still mid-prefill* — per-chunk publication turning a
    /// would-be duplicate prefill into page sharing before the first
    /// prefill even finishes.
    pub midprefill_prefix_hits: AtomicU64,
    /// KV pages demoted to the configured compressed format under memory
    /// pressure (each page counted once per demotion) — the reclaim step
    /// tried after cache eviction and before preemption.
    pub demotions: AtomicU64,
    // --- session-serving gauges ---
    /// Page-pool capacity (constant once serving starts).
    pub pool_pages: AtomicU64,
    /// Free pages in the pool at the last step.
    pub free_pages: AtomicU64,
    /// Page handles held by the radix prefix cache at the last step.
    pub cache_pages: AtomicU64,
    /// Sessions in the running decode batch at the last step.
    pub running_sessions: AtomicU64,
    /// Sessions waiting for admission at the last step.
    pub waiting_sessions: AtomicU64,
    /// Admitted sessions still mid-prefill at the last step — the
    /// per-step stall gauge: with monolithic prefill this was always 0
    /// because admission blocked the whole step instead.
    pub prefilling_sessions: AtomicU64,
    /// Prompt tokens still to prefill across the running set at the last
    /// step (the prefill backlog the decode steps are interleaving with).
    pub prefill_backlog_tokens: AtomicU64,
    /// Live prefill token budget chosen by the AIMD controller at the
    /// last step (equals `prefill_chunk_tokens` when autotune is off).
    pub autotuned_chunk_tokens: AtomicU64,
    /// Live pages held in a compressed (bf16/int8) format at the last
    /// step — 0 whenever `sessions.page_format = "f32"`.
    pub compressed_pages: AtomicU64,
    /// Resident KV bytes across every live page at the last step; with
    /// compressed pages this runs below `pool_pages * page_bytes` — the
    /// headroom demotion bought.
    pub pool_bytes_in_use: AtomicU64,
    /// High-water mark of sessions simultaneously in the decode phase
    /// (prefill complete) — the resident-sessions capacity figure the
    /// compressed-KV bench compares across page formats.
    pub peak_decoding_sessions: AtomicU64,
    // --- per-phase step timing (one histogram per StepPhase) ---
    /// Per-step µs draining the ingress queue ([`StepPhase::Ingress`]).
    pub phase_ingress: Histogram,
    /// Per-step µs in shed/expire/admit/finish ([`StepPhase::Admission`]).
    pub phase_admission: Histogram,
    /// Per-step µs planning + reserving pages ([`StepPhase::Reserve`]).
    pub phase_reserve: Histogram,
    /// Per-step µs in prefill work ([`StepPhase::PrefillAttend`]).
    pub phase_prefill_attend: Histogram,
    /// Per-step µs in decode work ([`StepPhase::DecodeAttend`]).
    pub phase_decode_attend: Histogram,
    /// Per-step µs projecting logits ([`StepPhase::Logits`]).
    pub phase_logits: Histogram,
    /// Per-step µs streaming tokens + publishing gauges
    /// ([`StepPhase::StreamEgress`]).
    pub phase_stream_egress: Histogram,
}

impl Metrics {
    /// Fresh metrics with every counter and gauge at zero.
    pub fn new() -> Self {
        Self::default()
    }

    /// Count one accepted request.
    pub fn inc_requests(&self) {
        self.requests.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one executed batch and the padding slots it carried.
    pub fn inc_batches(&self, padded: u64) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.padded_slots.fetch_add(padded, Ordering::Relaxed);
    }

    /// Count one refused (or deadline-expired) request.
    pub fn inc_rejected(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one admission-time prefix-cache lookup.
    pub fn record_prefix_lookup(&self, hit_tokens: usize) {
        self.prefix_lookups.fetch_add(1, Ordering::Relaxed);
        if hit_tokens > 0 {
            self.prefix_hits.fetch_add(1, Ordering::Relaxed);
            self.prefix_hit_tokens.fetch_add(hit_tokens as u64, Ordering::Relaxed);
        }
    }

    /// Fraction of admission lookups that reused cached pages (0 when no
    /// lookups happened yet).
    pub fn prefix_hit_rate(&self) -> f64 {
        let lookups = self.prefix_lookups.load(Ordering::Relaxed);
        if lookups == 0 {
            return 0.0;
        }
        self.prefix_hits.load(Ordering::Relaxed) as f64 / lookups as f64
    }

    /// Record one engine-parallel prefill chunk of `tokens` tokens.
    pub fn record_prefill_chunk(&self, tokens: usize) {
        self.prefill_chunks.fetch_add(1, Ordering::Relaxed);
        self.prefill_tokens.fetch_add(tokens as u64, Ordering::Relaxed);
    }

    /// The per-phase step-timing histogram for `phase`.
    pub fn phase(&self, phase: StepPhase) -> &Histogram {
        match phase {
            StepPhase::Ingress => &self.phase_ingress,
            StepPhase::Admission => &self.phase_admission,
            StepPhase::Reserve => &self.phase_reserve,
            StepPhase::PrefillAttend => &self.phase_prefill_attend,
            StepPhase::DecodeAttend => &self.phase_decode_attend,
            StepPhase::Logits => &self.phase_logits,
            StepPhase::StreamEgress => &self.phase_stream_egress,
        }
    }

    /// Publish the per-step scheduler gauges.
    pub fn set_session_gauges(
        &self,
        free_pages: u64,
        cache_pages: u64,
        running: u64,
        waiting: u64,
        prefilling: u64,
        prefill_backlog: u64,
    ) {
        self.free_pages.store(free_pages, Ordering::Relaxed);
        self.cache_pages.store(cache_pages, Ordering::Relaxed);
        self.running_sessions.store(running, Ordering::Relaxed);
        self.waiting_sessions.store(waiting, Ordering::Relaxed);
        self.prefilling_sessions.store(prefilling, Ordering::Relaxed);
        self.prefill_backlog_tokens.store(prefill_backlog, Ordering::Relaxed);
    }

    /// One-line summary for logs / bench output; appends the
    /// session-serving block once the scheduler has admitted sessions.
    pub fn summary(&self) -> String {
        let mut s = format!(
            "requests={} batches={} rejected={} pad_slots={} latency_mean={:.2}ms p50={:.2}ms p95={:.2}ms batch_exec_mean={:.2}ms",
            self.requests.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.rejected.load(Ordering::Relaxed),
            self.padded_slots.load(Ordering::Relaxed),
            self.request_latency.mean_us() / 1e3,
            self.request_latency.percentile_us(0.5) as f64 / 1e3,
            self.request_latency.percentile_us(0.95) as f64 / 1e3,
            self.batch_exec.mean_us() / 1e3,
        );
        if self.sessions.load(Ordering::Relaxed) > 0 {
            s.push_str(&format!(
                " sessions={} preemptions={} prefix_hit_rate={:.2} prefix_hit_tokens={} gen_tokens={} steps={} prefill_chunks={} prefill_tokens={} streamed={} stream_stalls={} expired={} pages={}/{} cache_pages={} running={} waiting={} prefilling={} prefill_backlog={} chunk_budget={} reoffers={} midprefill_hits={} demotions={} compressed_pages={} kv_bytes={} peak_decoding={} decode_step_p95={:.2}ms",
                self.sessions.load(Ordering::Relaxed),
                self.preemptions.load(Ordering::Relaxed),
                self.prefix_hit_rate(),
                self.prefix_hit_tokens.load(Ordering::Relaxed),
                self.generated_tokens.load(Ordering::Relaxed),
                self.decode_steps.load(Ordering::Relaxed),
                self.prefill_chunks.load(Ordering::Relaxed),
                self.prefill_tokens.load(Ordering::Relaxed),
                self.streamed_tokens.load(Ordering::Relaxed),
                self.stream_stalls.load(Ordering::Relaxed),
                self.deadline_expired.load(Ordering::Relaxed),
                self.free_pages.load(Ordering::Relaxed),
                self.pool_pages.load(Ordering::Relaxed),
                self.cache_pages.load(Ordering::Relaxed),
                self.running_sessions.load(Ordering::Relaxed),
                self.waiting_sessions.load(Ordering::Relaxed),
                self.prefilling_sessions.load(Ordering::Relaxed),
                self.prefill_backlog_tokens.load(Ordering::Relaxed),
                self.autotuned_chunk_tokens.load(Ordering::Relaxed),
                self.budget_reoffers.load(Ordering::Relaxed),
                self.midprefill_prefix_hits.load(Ordering::Relaxed),
                self.demotions.load(Ordering::Relaxed),
                self.compressed_pages.load(Ordering::Relaxed),
                self.pool_bytes_in_use.load(Ordering::Relaxed),
                self.peak_decoding_sessions.load(Ordering::Relaxed),
                self.decode_step_latency.percentile_us(0.95) as f64 / 1e3,
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_counts_and_mean() {
        let h = Histogram::new();
        h.record(Duration::from_micros(100));
        h.record(Duration::from_micros(300));
        assert_eq!(h.count(), 2);
        assert!((h.mean_us() - 200.0).abs() < 1.0);
    }

    #[test]
    fn percentiles_monotone() {
        let h = Histogram::new();
        for i in 1..100u64 {
            h.record(Duration::from_micros(i * 10));
        }
        let p50 = h.percentile_us(0.5);
        let p95 = h.percentile_us(0.95);
        assert!(p50 <= p95);
        assert!(p95 <= 2048, "p95={p95}"); // 990us rounds up to <=1024 bucket
    }

    #[test]
    fn empty_histogram_safe() {
        let h = Histogram::new();
        assert_eq!(h.percentile_us(0.99), 0);
        assert_eq!(h.mean_us(), 0.0);
    }

    #[test]
    fn metrics_summary_contains_counts() {
        let m = Metrics::new();
        m.inc_requests();
        m.inc_batches(3);
        m.inc_rejected();
        let s = m.summary();
        assert!(s.contains("requests=1"));
        assert!(s.contains("pad_slots=3"));
        assert!(s.contains("rejected=1"));
        // no session block until the scheduler admits something
        assert!(!s.contains("sessions="), "{s}");
    }

    #[test]
    fn prefix_hit_rate_counts_only_hits() {
        let m = Metrics::new();
        assert_eq!(m.prefix_hit_rate(), 0.0);
        m.record_prefix_lookup(0);
        m.record_prefix_lookup(32);
        m.record_prefix_lookup(64);
        m.record_prefix_lookup(0);
        assert_eq!(m.prefix_lookups.load(Ordering::Relaxed), 4);
        assert_eq!(m.prefix_hits.load(Ordering::Relaxed), 2);
        assert_eq!(m.prefix_hit_tokens.load(Ordering::Relaxed), 96);
        assert!((m.prefix_hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn session_gauges_overwrite_not_accumulate() {
        let m = Metrics::new();
        m.set_session_gauges(100, 10, 3, 7, 2, 640);
        m.set_session_gauges(90, 12, 4, 6, 1, 320);
        assert_eq!(m.free_pages.load(Ordering::Relaxed), 90);
        assert_eq!(m.cache_pages.load(Ordering::Relaxed), 12);
        assert_eq!(m.running_sessions.load(Ordering::Relaxed), 4);
        assert_eq!(m.waiting_sessions.load(Ordering::Relaxed), 6);
        assert_eq!(m.prefilling_sessions.load(Ordering::Relaxed), 1);
        assert_eq!(m.prefill_backlog_tokens.load(Ordering::Relaxed), 320);
    }

    #[test]
    fn prefill_chunk_counters_accumulate() {
        let m = Metrics::new();
        m.record_prefill_chunk(128);
        m.record_prefill_chunk(32);
        assert_eq!(m.prefill_chunks.load(Ordering::Relaxed), 2);
        assert_eq!(m.prefill_tokens.load(Ordering::Relaxed), 160);
    }

    #[test]
    fn summary_surfaces_the_session_block_once_serving() {
        let m = Metrics::new();
        m.sessions.fetch_add(2, Ordering::Relaxed);
        m.preemptions.fetch_add(1, Ordering::Relaxed);
        m.pool_pages.store(256, Ordering::Relaxed);
        m.record_prefix_lookup(16);
        m.record_prefill_chunk(48);
        m.set_session_gauges(200, 16, 2, 0, 1, 96);
        let s = m.summary();
        assert!(s.contains("sessions=2"), "{s}");
        assert!(s.contains("preemptions=1"), "{s}");
        assert!(s.contains("prefix_hit_rate=1.00"), "{s}");
        assert!(s.contains("pages=200/256"), "{s}");
        assert!(s.contains("prefill_chunks=1"), "{s}");
        assert!(s.contains("prefill_tokens=48"), "{s}");
        assert!(s.contains("prefill_backlog=96"), "{s}");
    }

    #[test]
    fn summary_surfaces_fused_step_counters() {
        let m = Metrics::new();
        m.sessions.fetch_add(1, Ordering::Relaxed);
        m.budget_reoffers.fetch_add(3, Ordering::Relaxed);
        m.midprefill_prefix_hits.fetch_add(2, Ordering::Relaxed);
        m.autotuned_chunk_tokens.store(128, Ordering::Relaxed);
        m.decode_step_latency.record(Duration::from_micros(900));
        let s = m.summary();
        assert!(s.contains("reoffers=3"), "{s}");
        assert!(s.contains("midprefill_hits=2"), "{s}");
        assert!(s.contains("chunk_budget=128"), "{s}");
        // 900us lands in the 512..1024 bucket; a lone sample interpolates
        // to the bucket midpoint, 768us
        assert!(s.contains("decode_step_p95=0.77ms"), "{s}");
    }

    #[test]
    fn summary_surfaces_compressed_kv_counters() {
        let m = Metrics::new();
        m.sessions.fetch_add(1, Ordering::Relaxed);
        m.demotions.fetch_add(6, Ordering::Relaxed);
        m.compressed_pages.store(4, Ordering::Relaxed);
        m.pool_bytes_in_use.store(81_920, Ordering::Relaxed);
        m.peak_decoding_sessions.fetch_max(3, Ordering::Relaxed);
        m.peak_decoding_sessions.fetch_max(2, Ordering::Relaxed);
        let s = m.summary();
        assert!(s.contains("demotions=6"), "{s}");
        assert!(s.contains("compressed_pages=4"), "{s}");
        assert!(s.contains("kv_bytes=81920"), "{s}");
        assert!(s.contains("peak_decoding=3"), "peak is a high-water mark: {s}");
    }

    #[test]
    fn summary_surfaces_streaming_and_qos_counters() {
        let m = Metrics::new();
        m.sessions.fetch_add(1, Ordering::Relaxed);
        m.streamed_tokens.fetch_add(9, Ordering::Relaxed);
        m.stream_stalls.fetch_add(2, Ordering::Relaxed);
        m.deadline_expired.fetch_add(1, Ordering::Relaxed);
        let s = m.summary();
        assert!(s.contains("streamed=9"), "{s}");
        assert!(s.contains("stream_stalls=2"), "{s}");
        assert!(s.contains("expired=1"), "{s}");
    }

    /// Regression for the upper-bucket-edge bias fix, at both edges of
    /// the bucket range: the interpolated estimate stays inside the
    /// containing bucket while the legacy estimate reports its upper
    /// edge (up to 2x high).
    #[test]
    fn interpolated_percentile_fixes_the_upper_edge_bias() {
        // low edge: one 1us sample (bucket 0 = [1, 2))
        let h = Histogram::new();
        h.record(Duration::from_micros(1));
        assert_eq!(h.percentile_us(1.0), 1, "1us must report 1us, not the 2us edge");
        assert_eq!(h.percentile_us_upper_edge(1.0), 2, "legacy bias kept for comparison");
        // interior: one 900us sample (bucket [512, 1024)) interpolates to
        // the bucket midpoint instead of the upper edge
        let h = Histogram::new();
        h.record(Duration::from_micros(900));
        assert_eq!(h.percentile_us(0.95), 768);
        assert_eq!(h.percentile_us_upper_edge(0.95), 1024);
        // high edge: a ~17min sample clamps into the top bucket and both
        // estimates stay finite and ordered
        let h = Histogram::new();
        h.record(Duration::from_secs(1_000));
        let interp = h.percentile_us(1.0);
        let edge = h.percentile_us_upper_edge(1.0);
        assert!(interp <= edge, "{interp} vs {edge}");
        assert!(interp >= 1u64 << 29, "top-bucket sample must stay in the top bucket");
        // many samples in one bucket: ranks spread across the span, so
        // different percentiles separate inside the bucket
        let h = Histogram::new();
        for _ in 0..100 {
            h.record(Duration::from_micros(600));
        }
        let p10 = h.percentile_us(0.10);
        let p90 = h.percentile_us(0.90);
        assert!((512..1024).contains(&p10), "{p10}");
        assert!((512..1024).contains(&p90), "{p90}");
        assert!(p10 < p90, "ranks must spread inside the bucket: {p10} vs {p90}");
        assert_eq!(h.percentile_us_upper_edge(0.10), h.percentile_us_upper_edge(0.90));
    }

    /// The snapshot/delta window API: `prev + delta == now` for every
    /// field, and windowed percentiles reflect only the window.
    #[test]
    fn snapshot_deltas_sum_to_cumulative_totals() {
        let h = Histogram::new();
        for _ in 0..10 {
            h.record(Duration::from_micros(100));
        }
        let snap1 = h.snapshot();
        assert_eq!(snap1.count(), 10);
        for _ in 0..30 {
            h.record(Duration::from_micros(5_000));
        }
        let snap2 = h.snapshot();
        let delta = snap2.delta_since(&snap1);
        // deltas sum back to the cumulative totals, field by field
        assert_eq!(snap1.count() + delta.count(), snap2.count());
        assert_eq!(snap1.sum_us() + delta.sum_us(), snap2.sum_us());
        for (i, (a, d)) in
            snap1.bucket_counts().iter().zip(delta.bucket_counts()).enumerate()
        {
            assert_eq!(a + d, snap2.bucket_counts()[i], "bucket {i}");
        }
        // the window holds only the 5ms samples; the cumulative histogram
        // still sees the old 100us population at low percentiles
        assert_eq!(delta.count(), 30);
        assert!(delta.percentile_us(0.01) >= 4096, "{}", delta.percentile_us(0.01));
        assert!(snap2.percentile_us(0.01) < 256, "{}", snap2.percentile_us(0.01));
        assert!((delta.mean_us() - 5_000.0).abs() < 600.0, "{}", delta.mean_us());
        // a reversed pair saturates instead of underflowing
        let rev = snap1.delta_since(&snap2);
        assert_eq!(rev.count(), 0);
        assert_eq!(rev.sum_us(), 0);
    }

    /// Per-phase histograms are distinct and addressable through the
    /// `StepPhase` index used by traces and the summarizer.
    #[test]
    fn phase_histograms_are_distinct_and_named() {
        let m = Metrics::new();
        let mut names = std::collections::HashSet::new();
        for (i, p) in StepPhase::ALL.iter().enumerate() {
            assert_eq!(p.index(), i, "ALL order must match the discriminants");
            assert!(names.insert(p.name()), "duplicate phase name {}", p.name());
        }
        m.phase(StepPhase::DecodeAttend).record(Duration::from_micros(50));
        m.phase(StepPhase::DecodeAttend).record(Duration::from_micros(70));
        m.phase(StepPhase::Logits).record(Duration::from_micros(30));
        assert_eq!(m.phase(StepPhase::DecodeAttend).count(), 2);
        assert_eq!(m.phase(StepPhase::Logits).count(), 1);
        for p in [StepPhase::Ingress, StepPhase::Admission, StepPhase::Reserve] {
            assert_eq!(m.phase(p).count(), 0, "{}", p.name());
        }
        assert_eq!(m.phase(StepPhase::DecodeAttend).sum_us(), 120);
    }

    #[test]
    fn percentile_edges_cover_extremes() {
        // percentile behavior at p -> 0 and p -> 1 plus micro samples
        let h = Histogram::new();
        h.record(Duration::from_micros(1));
        h.record(Duration::from_secs(10));
        let lo = h.percentile_us(0.0);
        let hi = h.percentile_us(1.0);
        assert!(lo <= hi);
        assert!(hi >= 10_000_000 / 2, "p100 must land in the seconds bucket: {hi}");
        // zero-duration records clamp to the 1us bucket
        h.record(Duration::ZERO);
        assert_eq!(h.count(), 3);
    }
}
