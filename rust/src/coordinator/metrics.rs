//! Serving metrics: log-bucketed latency histogram + counters.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Log-spaced latency histogram from 1us to ~17min (31 doubling buckets).
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_us: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram {
            buckets: (0..31).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
        }
    }

    pub fn record(&self, d: Duration) {
        let us = d.as_micros().max(1) as u64;
        let idx = (63 - us.leading_zeros() as usize).min(self.buckets.len() - 1);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_us(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            return 0.0;
        }
        self.sum_us.load(Ordering::Relaxed) as f64 / c as f64
    }

    /// Approximate percentile (upper edge of the containing bucket, us).
    pub fn percentile_us(&self, p: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = ((total as f64) * p).ceil() as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return 1u64 << (i + 1);
            }
        }
        1u64 << self.buckets.len()
    }
}

/// Coordinator-wide metrics.
#[derive(Default)]
pub struct Metrics {
    pub request_latency: Histogram,
    pub batch_exec: Histogram,
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    pub rejected: AtomicU64,
    pub padded_slots: AtomicU64,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn inc_requests(&self) {
        self.requests.fetch_add(1, Ordering::Relaxed);
    }

    pub fn inc_batches(&self, padded: u64) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.padded_slots.fetch_add(padded, Ordering::Relaxed);
    }

    pub fn inc_rejected(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    /// One-line summary for logs / bench output.
    pub fn summary(&self) -> String {
        format!(
            "requests={} batches={} rejected={} pad_slots={} latency_mean={:.2}ms p50={:.2}ms p95={:.2}ms batch_exec_mean={:.2}ms",
            self.requests.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.rejected.load(Ordering::Relaxed),
            self.padded_slots.load(Ordering::Relaxed),
            self.request_latency.mean_us() / 1e3,
            self.request_latency.percentile_us(0.5) as f64 / 1e3,
            self.request_latency.percentile_us(0.95) as f64 / 1e3,
            self.batch_exec.mean_us() / 1e3,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_counts_and_mean() {
        let h = Histogram::new();
        h.record(Duration::from_micros(100));
        h.record(Duration::from_micros(300));
        assert_eq!(h.count(), 2);
        assert!((h.mean_us() - 200.0).abs() < 1.0);
    }

    #[test]
    fn percentiles_monotone() {
        let h = Histogram::new();
        for i in 1..100u64 {
            h.record(Duration::from_micros(i * 10));
        }
        let p50 = h.percentile_us(0.5);
        let p95 = h.percentile_us(0.95);
        assert!(p50 <= p95);
        assert!(p95 <= 2048, "p95={p95}"); // 990us rounds up to <=1024 bucket
    }

    #[test]
    fn empty_histogram_safe() {
        let h = Histogram::new();
        assert_eq!(h.percentile_us(0.99), 0);
        assert_eq!(h.mean_us(), 0.0);
    }

    #[test]
    fn metrics_summary_contains_counts() {
        let m = Metrics::new();
        m.inc_requests();
        m.inc_batches(3);
        m.inc_rejected();
        let s = m.summary();
        assert!(s.contains("requests=1"));
        assert!(s.contains("pad_slots=3"));
        assert!(s.contains("rejected=1"));
    }
}
