//! Dynamic batcher: groups requests into batches bounded by `max_batch`,
//! flushing partial batches after `flush_after` (the latency/throughput
//! knob of every serving system; tuned in EXPERIMENTS.md §Perf).
//!
//! Pure data structure — the server thread drives it with `push` /
//! `poll_due`, so every invariant is unit-testable without threads.
//!
//! Scope note: the batcher forms **fixed rounds** — right for the MLM
//! predict path (one forward per batch) and kept as the LM serving
//! baseline, but generation requests are better served by the
//! continuous-batching session scheduler
//! ([`crate::coordinator::scheduler`]), which retires this round barrier;
//! `benches/bench_serve.rs` measures the two against each other.

// the batcher sits on the request path: a panic here drops every queued
// request's responder.  `cargo xtask lint` enforces the same rule.
#![deny(clippy::unwrap_used, clippy::expect_used)]

use std::collections::VecDeque;
use std::sync::mpsc::SyncSender;
use std::time::{Duration, Instant};

use crate::config::SamplingParams;

/// Default request priority — the midpoint of the `u8` range, so callers
/// can both boost and deprioritize relative to unmarked traffic.
pub const PRIORITY_NORMAL: u8 = 100;

/// One inference request (token ids, any length <= the model's seq_len).
#[derive(Clone, Debug)]
pub struct Request {
    /// Caller-assigned request id, echoed in the `Response`.
    pub id: u64,
    /// Prompt token ids.
    pub tokens: Vec<i32>,
    /// Autoregressive decode request: how many tokens to generate from
    /// `tokens` as a prompt.  `0` = MLM predict-all-positions request;
    /// LM runners clamp it to at least 1 (`Server::generate`).
    pub gen_tokens: usize,
    /// Arrival timestamp (admission-deadline and latency reference point).
    pub arrived: Instant,
    /// QoS priority: higher admits sooner ([`PRIORITY_NORMAL`] default).
    /// The session scheduler ages waiting requests so low priority means
    /// *later*, never *never* (DESIGN.md §12).
    pub priority: u8,
    /// Admission deadline, as a time-to-live from `arrived`: a request
    /// still **waiting (never admitted)** past this duration is answered
    /// with a descriptive error instead of being served late.  Once
    /// admitted, a request is never expired — accepted means served, even
    /// across preemption.  `None` = wait indefinitely.
    pub deadline: Option<Duration>,
    /// Token-selection policy for this request (greedy default).
    pub sampling: SamplingParams,
    /// Per-token streaming channel: when set, the scheduler delivers each
    /// generated token with a non-blocking send as soon as it is chosen
    /// (the final `Response` still carries the full sequence, so a slow
    /// consumer can always recover the tail).  `None` = finish-only.
    pub stream: Option<SyncSender<i32>>,
}

impl Request {
    /// A request with default QoS (normal priority, no deadline), greedy
    /// sampling and finish-only delivery — override fields as needed.
    pub fn new(id: u64, tokens: Vec<i32>, gen_tokens: usize) -> Self {
        Request {
            id,
            tokens,
            gen_tokens,
            arrived: Instant::now(),
            priority: PRIORITY_NORMAL,
            deadline: None,
            sampling: SamplingParams::default(),
            stream: None,
        }
    }
}

/// A formed batch, FIFO order preserved.
#[derive(Debug)]
pub struct Batch {
    /// The batched requests, in arrival (FIFO) order.
    pub requests: Vec<Request>,
}

impl Batch {
    /// Number of requests in the batch.
    pub fn len(&self) -> usize {
        self.requests.len()
    }

    /// Whether the batch holds no requests.
    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }
}

/// Deadline-flushed dynamic batcher.
pub struct Batcher {
    queue: VecDeque<Request>,
    max_batch: usize,
    flush_after: Duration,
}

impl Batcher {
    /// A batcher that releases full batches of `max_batch` requests and
    /// flushes partial ones once the oldest has waited `flush_after`.
    pub fn new(max_batch: usize, flush_after: Duration) -> Self {
        assert!(max_batch > 0);
        Batcher { queue: VecDeque::new(), max_batch, flush_after }
    }

    /// Enqueue a request; returns a full batch when `max_batch` is reached.
    pub fn push(&mut self, req: Request) -> Option<Batch> {
        self.queue.push_back(req);
        if self.queue.len() >= self.max_batch {
            return self.take(self.max_batch);
        }
        None
    }

    /// Flush a partial batch whose oldest request has exceeded the
    /// deadline (called periodically by the server loop).
    pub fn poll_due(&mut self, now: Instant) -> Option<Batch> {
        let oldest = self.queue.front()?;
        if now.duration_since(oldest.arrived) >= self.flush_after {
            return self.take(self.max_batch);
        }
        None
    }

    /// Time remaining until the *oldest* pending request's flush deadline
    /// (zero if already overdue); `None` when the queue is empty.  The
    /// server loop bounds its `recv_timeout` with this so a steady trickle
    /// of arrivals cannot keep resetting the wait and starve the oldest
    /// request (§bugfix).
    pub fn next_deadline(&self, now: Instant) -> Option<Duration> {
        let oldest = self.queue.front()?;
        Some(self.flush_after.saturating_sub(now.duration_since(oldest.arrived)))
    }

    /// Drain everything (shutdown path).
    pub fn drain(&mut self) -> Option<Batch> {
        if self.queue.is_empty() {
            None
        } else {
            self.take(self.queue.len())
        }
    }

    fn take(&mut self, k: usize) -> Option<Batch> {
        let k = k.min(self.queue.len());
        if k == 0 {
            return None;
        }
        let requests: Vec<Request> = self.queue.drain(..k).collect();
        Some(Batch { requests })
    }

    /// Queued requests not yet released in a batch.
    pub fn pending(&self) -> usize {
        self.queue.len()
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    fn req(id: u64) -> Request {
        Request::new(id, vec![2, 5, 6], 0)
    }

    #[test]
    fn full_batch_released_immediately() {
        let mut b = Batcher::new(3, Duration::from_secs(10));
        assert!(b.push(req(0)).is_none());
        assert!(b.push(req(1)).is_none());
        let batch = b.push(req(2)).expect("full batch");
        assert_eq!(batch.len(), 3);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn fifo_order_preserved() {
        let mut b = Batcher::new(4, Duration::from_secs(10));
        for i in 0..3 {
            b.push(req(i));
        }
        let batch = b.push(req(3)).unwrap();
        let ids: Vec<u64> = batch.requests.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3]);
    }

    #[test]
    fn deadline_flush_releases_partial() {
        let mut b = Batcher::new(8, Duration::from_micros(1));
        b.push(req(0));
        b.push(req(1));
        std::thread::sleep(Duration::from_millis(1));
        let batch = b.poll_due(Instant::now()).expect("due batch");
        assert_eq!(batch.len(), 2);
    }

    #[test]
    fn poll_not_due_returns_none() {
        let mut b = Batcher::new(8, Duration::from_secs(60));
        b.push(req(0));
        assert!(b.poll_due(Instant::now()).is_none());
        assert_eq!(b.pending(), 1);
    }

    #[test]
    fn next_deadline_tracks_oldest_request() {
        let mut b = Batcher::new(8, Duration::from_millis(50));
        assert!(b.next_deadline(Instant::now()).is_none());
        let t0 = Instant::now();
        b.push(Request { arrived: t0, ..Request::new(0, vec![2], 0) });
        std::thread::sleep(Duration::from_millis(2));
        b.push(Request::new(1, vec![2], 0));
        // deadline follows the oldest request, not the newest
        let d = b.next_deadline(Instant::now()).unwrap();
        assert!(d <= Duration::from_millis(49), "{d:?}");
        // overdue -> zero, never panics
        let d = b.next_deadline(t0 + Duration::from_millis(500)).unwrap();
        assert_eq!(d, Duration::ZERO);
    }

    #[test]
    fn no_request_lost_or_duplicated_property() {
        use crate::proptest::for_all_seeds;
        for_all_seeds(25, |_, rng| {
            let max_batch = 1 + rng.below(7);
            let mut b = Batcher::new(max_batch, Duration::from_secs(100));
            let n = 1 + rng.below(40);
            let mut seen: Vec<u64> = Vec::new();
            for i in 0..n as u64 {
                if let Some(batch) = b.push(req(i)) {
                    if batch.len() > max_batch {
                        return Err(format!("batch too big: {}", batch.len()));
                    }
                    seen.extend(batch.requests.iter().map(|r| r.id));
                }
            }
            if let Some(batch) = b.drain() {
                seen.extend(batch.requests.iter().map(|r| r.id));
            }
            let want: Vec<u64> = (0..n as u64).collect();
            if seen != want {
                return Err(format!("lost/dup/reordered: {seen:?}"));
            }
            Ok(())
        });
    }
}
