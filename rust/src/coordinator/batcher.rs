//! Dynamic batcher: groups requests into batches bounded by `max_batch`,
//! flushing partial batches after `flush_after` (the latency/throughput
//! knob of every serving system; tuned in EXPERIMENTS.md §Perf).
//!
//! Pure data structure — the server thread drives it with `push` /
//! `poll_due`, so every invariant is unit-testable without threads.
//!
//! Scope note: the batcher forms **fixed rounds** — right for the MLM
//! predict path (one forward per batch) and kept as the LM serving
//! baseline, but generation requests are better served by the
//! continuous-batching session scheduler
//! ([`crate::coordinator::scheduler`]), which retires this round barrier;
//! `benches/bench_serve.rs` measures the two against each other.

// the batcher sits on the request path: a panic here drops every queued
// request's responder.  `cargo xtask lint` enforces the same rule.
#![deny(clippy::unwrap_used, clippy::expect_used)]

use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// One inference request (token ids, any length <= the model's seq_len).
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub tokens: Vec<i32>,
    /// Autoregressive decode request: how many tokens to generate from
    /// `tokens` as a prompt.  `0` = MLM predict-all-positions request;
    /// LM runners clamp it to at least 1 (`Server::generate`).
    pub gen_tokens: usize,
    pub arrived: Instant,
}

/// A formed batch, FIFO order preserved.
#[derive(Debug)]
pub struct Batch {
    pub requests: Vec<Request>,
}

impl Batch {
    pub fn len(&self) -> usize {
        self.requests.len()
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }
}

/// Deadline-flushed dynamic batcher.
pub struct Batcher {
    queue: VecDeque<Request>,
    max_batch: usize,
    flush_after: Duration,
}

impl Batcher {
    pub fn new(max_batch: usize, flush_after: Duration) -> Self {
        assert!(max_batch > 0);
        Batcher { queue: VecDeque::new(), max_batch, flush_after }
    }

    /// Enqueue a request; returns a full batch when `max_batch` is reached.
    pub fn push(&mut self, req: Request) -> Option<Batch> {
        self.queue.push_back(req);
        if self.queue.len() >= self.max_batch {
            return self.take(self.max_batch);
        }
        None
    }

    /// Flush a partial batch whose oldest request has exceeded the
    /// deadline (called periodically by the server loop).
    pub fn poll_due(&mut self, now: Instant) -> Option<Batch> {
        let oldest = self.queue.front()?;
        if now.duration_since(oldest.arrived) >= self.flush_after {
            return self.take(self.max_batch);
        }
        None
    }

    /// Time remaining until the *oldest* pending request's flush deadline
    /// (zero if already overdue); `None` when the queue is empty.  The
    /// server loop bounds its `recv_timeout` with this so a steady trickle
    /// of arrivals cannot keep resetting the wait and starve the oldest
    /// request (§bugfix).
    pub fn next_deadline(&self, now: Instant) -> Option<Duration> {
        let oldest = self.queue.front()?;
        Some(self.flush_after.saturating_sub(now.duration_since(oldest.arrived)))
    }

    /// Drain everything (shutdown path).
    pub fn drain(&mut self) -> Option<Batch> {
        if self.queue.is_empty() {
            None
        } else {
            self.take(self.queue.len())
        }
    }

    fn take(&mut self, k: usize) -> Option<Batch> {
        let k = k.min(self.queue.len());
        if k == 0 {
            return None;
        }
        let requests: Vec<Request> = self.queue.drain(..k).collect();
        Some(Batch { requests })
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    fn req(id: u64) -> Request {
        Request { id, tokens: vec![2, 5, 6], gen_tokens: 0, arrived: Instant::now() }
    }

    #[test]
    fn full_batch_released_immediately() {
        let mut b = Batcher::new(3, Duration::from_secs(10));
        assert!(b.push(req(0)).is_none());
        assert!(b.push(req(1)).is_none());
        let batch = b.push(req(2)).expect("full batch");
        assert_eq!(batch.len(), 3);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn fifo_order_preserved() {
        let mut b = Batcher::new(4, Duration::from_secs(10));
        for i in 0..3 {
            b.push(req(i));
        }
        let batch = b.push(req(3)).unwrap();
        let ids: Vec<u64> = batch.requests.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3]);
    }

    #[test]
    fn deadline_flush_releases_partial() {
        let mut b = Batcher::new(8, Duration::from_micros(1));
        b.push(req(0));
        b.push(req(1));
        std::thread::sleep(Duration::from_millis(1));
        let batch = b.poll_due(Instant::now()).expect("due batch");
        assert_eq!(batch.len(), 2);
    }

    #[test]
    fn poll_not_due_returns_none() {
        let mut b = Batcher::new(8, Duration::from_secs(60));
        b.push(req(0));
        assert!(b.poll_due(Instant::now()).is_none());
        assert_eq!(b.pending(), 1);
    }

    #[test]
    fn next_deadline_tracks_oldest_request() {
        let mut b = Batcher::new(8, Duration::from_millis(50));
        assert!(b.next_deadline(Instant::now()).is_none());
        let t0 = Instant::now();
        b.push(Request { id: 0, tokens: vec![2], gen_tokens: 0, arrived: t0 });
        std::thread::sleep(Duration::from_millis(2));
        b.push(Request { id: 1, tokens: vec![2], gen_tokens: 0, arrived: Instant::now() });
        // deadline follows the oldest request, not the newest
        let d = b.next_deadline(Instant::now()).unwrap();
        assert!(d <= Duration::from_millis(49), "{d:?}");
        // overdue -> zero, never panics
        let d = b.next_deadline(t0 + Duration::from_millis(500)).unwrap();
        assert_eq!(d, Duration::ZERO);
    }

    #[test]
    fn no_request_lost_or_duplicated_property() {
        use crate::proptest::for_all_seeds;
        for_all_seeds(25, |_, rng| {
            let max_batch = 1 + rng.below(7);
            let mut b = Batcher::new(max_batch, Duration::from_secs(100));
            let n = 1 + rng.below(40);
            let mut seen: Vec<u64> = Vec::new();
            for i in 0..n as u64 {
                if let Some(batch) = b.push(req(i)) {
                    if batch.len() > max_batch {
                        return Err(format!("batch too big: {}", batch.len()));
                    }
                    seen.extend(batch.requests.iter().map(|r| r.id));
                }
            }
            if let Some(batch) = b.drain() {
                seen.extend(batch.requests.iter().map(|r| r.id));
            }
            let want: Vec<u64> = (0..n as u64).collect();
            if seen != want {
                return Err(format!("lost/dup/reordered: {seen:?}"));
            }
            Ok(())
        });
    }
}
