//! Metrics exposition: the Prometheus text format renderer over
//! [`Metrics`] and the typed [`MetricsSnapshot`] the server hands to
//! programmatic scrapers (DESIGN.md §14).
//!
//! Everything here is **pull-side and read-only**: rendering walks the
//! relaxed atomic counters and histogram bucket arrays the serving hot
//! path already maintains, so a scrape costs the scraper — never the
//! scheduler.  Histograms render in classic Prometheus cumulative-bucket
//! form (`_bucket{le="..."}` + `+Inf` + `_sum`/`_count`), with the `le`
//! edges taken from the power-of-two bucket layout
//! ([`Histogram::bucket_upper_edge_us`]).  Per-phase step timing renders
//! as one histogram family labeled by [`StepPhase::name`]; per-worker
//! busy/steal counters come from [`crate::engine::pool::worker_stats`]
//! and skip never-used worker slots to keep the page small.

use std::fmt::Write as _;

use std::sync::atomic::{AtomicU64, Ordering};

use crate::coordinator::metrics::{
    Histogram, HistogramSnapshot, Metrics, StepPhase, HIST_BUCKETS,
};
use crate::engine::pool::worker_stats;

fn counter(out: &mut String, name: &str, help: &str, v: &AtomicU64) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} counter");
    let _ = writeln!(out, "{name} {}", v.load(Ordering::Relaxed));
}

fn gauge(out: &mut String, name: &str, help: &str, v: &AtomicU64) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} gauge");
    let _ = writeln!(out, "{name} {}", v.load(Ordering::Relaxed));
}

/// One histogram series in cumulative-bucket form.  `labels` is either
/// empty or a `key="value",` fragment spliced before `le`.
fn histogram_series(out: &mut String, name: &str, labels: &str, h: &Histogram) {
    let snap = h.snapshot();
    let mut cum = 0u64;
    for (i, &b) in snap.bucket_counts().iter().enumerate() {
        cum += b;
        if b == 0 && i + 1 < HIST_BUCKETS {
            continue; // empty interior buckets add bytes, not information
        }
        let le = Histogram::bucket_upper_edge_us(i);
        let _ = writeln!(out, "{name}_bucket{{{labels}le=\"{le}\"}} {cum}");
    }
    let _ = writeln!(out, "{name}_bucket{{{labels}le=\"+Inf\"}} {}", snap.count());
    if labels.is_empty() {
        let _ = writeln!(out, "{name}_sum {}", snap.sum_us());
        let _ = writeln!(out, "{name}_count {}", snap.count());
    } else {
        let trimmed = labels.trim_end_matches(',');
        let _ = writeln!(out, "{name}_sum{{{trimmed}}} {}", snap.sum_us());
        let _ = writeln!(out, "{name}_count{{{trimmed}}} {}", snap.count());
    }
}

fn histogram(out: &mut String, name: &str, help: &str, h: &Histogram) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} histogram");
    histogram_series(out, name, "", h);
}

impl Metrics {
    /// Render every counter, gauge and histogram in the Prometheus text
    /// exposition format (version 0.0.4 — the `text/plain` scrape body).
    pub fn render_prometheus(&self) -> String {
        let mut out = String::with_capacity(4096);
        // --- request/batch counters ---
        counter(&mut out, "mra_requests_total", "Requests accepted at ingress.", &self.requests);
        counter(&mut out, "mra_batches_total", "Fixed-round batches executed.", &self.batches);
        counter(
            &mut out,
            "mra_rejected_total",
            "Requests refused at ingress or expired past deadline.",
            &self.rejected,
        );
        counter(
            &mut out,
            "mra_padded_slots_total",
            "Padding slots added to fill routed batch buckets.",
            &self.padded_slots,
        );
        // --- session-serving counters ---
        counter(
            &mut out,
            "mra_sessions_total",
            "Sessions admitted by the scheduler.",
            &self.sessions,
        );
        counter(
            &mut out,
            "mra_preemptions_total",
            "Sessions preempted under memory pressure.",
            &self.preemptions,
        );
        counter(
            &mut out,
            "mra_prefix_lookups_total",
            "Radix prefix-cache lookups at admission.",
            &self.prefix_lookups,
        );
        counter(
            &mut out,
            "mra_prefix_hits_total",
            "Lookups that reused at least one cached block.",
            &self.prefix_hits,
        );
        counter(
            &mut out,
            "mra_prefix_hit_tokens_total",
            "Prompt tokens served from shared cache pages.",
            &self.prefix_hit_tokens,
        );
        counter(
            &mut out,
            "mra_generated_tokens_total",
            "Tokens emitted by the continuous decode loop.",
            &self.generated_tokens,
        );
        counter(
            &mut out,
            "mra_decode_steps_total",
            "Continuous-batching decode steps executed.",
            &self.decode_steps,
        );
        counter(
            &mut out,
            "mra_prefill_chunks_total",
            "Prefill chunks run through the chunked path.",
            &self.prefill_chunks,
        );
        counter(
            &mut out,
            "mra_prefill_tokens_total",
            "Prompt tokens prefilled through chunks.",
            &self.prefill_tokens,
        );
        counter(
            &mut out,
            "mra_streamed_tokens_total",
            "Tokens delivered on per-request stream channels.",
            &self.streamed_tokens,
        );
        counter(
            &mut out,
            "mra_stream_stalls_total",
            "Non-blocking stream sends refused by a full channel.",
            &self.stream_stalls,
        );
        counter(
            &mut out,
            "mra_deadline_expired_total",
            "Waiting requests expired past their admission deadline.",
            &self.deadline_expired,
        );
        counter(
            &mut out,
            "mra_budget_reoffers_total",
            "Prefill-budget grants beyond a session's first chunk of a step.",
            &self.budget_reoffers,
        );
        counter(
            &mut out,
            "mra_midprefill_prefix_hits_total",
            "Admissions whose prefix hit matched blocks still mid-prefill.",
            &self.midprefill_prefix_hits,
        );
        counter(
            &mut out,
            "mra_demotions_total",
            "KV pages demoted to the compressed format under memory pressure.",
            &self.demotions,
        );
        // --- session-serving gauges ---
        gauge(&mut out, "mra_pool_pages", "Page-pool capacity.", &self.pool_pages);
        gauge(&mut out, "mra_free_pages", "Free pages at the last step.", &self.free_pages);
        gauge(
            &mut out,
            "mra_cache_pages",
            "Pages held by the radix prefix cache at the last step.",
            &self.cache_pages,
        );
        gauge(
            &mut out,
            "mra_running_sessions",
            "Sessions in the running batch at the last step.",
            &self.running_sessions,
        );
        gauge(
            &mut out,
            "mra_waiting_sessions",
            "Sessions waiting for admission at the last step.",
            &self.waiting_sessions,
        );
        gauge(
            &mut out,
            "mra_prefilling_sessions",
            "Admitted sessions still mid-prefill at the last step.",
            &self.prefilling_sessions,
        );
        gauge(
            &mut out,
            "mra_prefill_backlog_tokens",
            "Prompt tokens still to prefill across the running set.",
            &self.prefill_backlog_tokens,
        );
        gauge(
            &mut out,
            "mra_autotuned_chunk_tokens",
            "Live prefill token budget chosen by the AIMD controller.",
            &self.autotuned_chunk_tokens,
        );
        gauge(
            &mut out,
            "mra_compressed_pages",
            "Live KV pages currently held in a compressed format.",
            &self.compressed_pages,
        );
        gauge(
            &mut out,
            "mra_pool_bytes_in_use",
            "Bytes of KV pool backing live pages, all formats.",
            &self.pool_bytes_in_use,
        );
        gauge(
            &mut out,
            "mra_peak_decoding_sessions",
            "High-water mark of sessions decoding concurrently.",
            &self.peak_decoding_sessions,
        );
        // --- latency histograms ---
        histogram(
            &mut out,
            "mra_request_latency_us",
            "End-to-end request latency (submit to response), microseconds.",
            &self.request_latency,
        );
        histogram(
            &mut out,
            "mra_batch_exec_us",
            "Per-batch worker execution latency, microseconds.",
            &self.batch_exec,
        );
        histogram(
            &mut out,
            "mra_decode_step_latency_us",
            "Wall latency of scheduler steps that decoded, microseconds.",
            &self.decode_step_latency,
        );
        // --- per-phase step timing: one family, labeled by phase ---
        let name = "mra_step_phase_us";
        let _ = writeln!(
            out,
            "# HELP {name} Per-step time attributed to each scheduler phase, microseconds."
        );
        let _ = writeln!(out, "# TYPE {name} histogram");
        for phase in StepPhase::ALL {
            let labels = format!("phase=\"{}\",", phase.name());
            histogram_series(&mut out, name, &labels, self.phase(phase));
        }
        // --- per-worker pool counters (engine-wide, process-global) ---
        let stats = worker_stats();
        let _ = writeln!(out, "# HELP mra_pool_worker_tasks_total Tasks run per pool worker slot.");
        let _ = writeln!(out, "# TYPE mra_pool_worker_tasks_total counter");
        for (w, (busy, _)) in stats.iter().enumerate() {
            if *busy > 0 {
                let _ = writeln!(out, "mra_pool_worker_tasks_total{{worker=\"{w}\"}} {busy}");
            }
        }
        let _ = writeln!(
            out,
            "# HELP mra_pool_worker_steals_total Tasks claimed off another worker's share."
        );
        let _ = writeln!(out, "# TYPE mra_pool_worker_steals_total counter");
        for (w, (busy, steals)) in stats.iter().enumerate() {
            if *busy > 0 {
                let _ = writeln!(out, "mra_pool_worker_steals_total{{worker=\"{w}\"}} {steals}");
            }
        }
        out
    }

    /// A typed point-in-time copy of the serving metrics — the
    /// programmatic twin of [`Metrics::render_prometheus`], used by
    /// benches and tests that want numbers, not text.
    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            generated_tokens: self.generated_tokens.load(Ordering::Relaxed),
            prefill_tokens: self.prefill_tokens.load(Ordering::Relaxed),
            prefill_chunks: self.prefill_chunks.load(Ordering::Relaxed),
            sessions: self.sessions.load(Ordering::Relaxed),
            preemptions: self.preemptions.load(Ordering::Relaxed),
            decode_steps: self.decode_steps.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            budget_reoffers: self.budget_reoffers.load(Ordering::Relaxed),
            midprefill_prefix_hits: self.midprefill_prefix_hits.load(Ordering::Relaxed),
            prefix_hit_tokens: self.prefix_hit_tokens.load(Ordering::Relaxed),
            decode_step_latency: self.decode_step_latency.snapshot(),
            phases: [
                self.phase(StepPhase::Ingress).snapshot(),
                self.phase(StepPhase::Admission).snapshot(),
                self.phase(StepPhase::Reserve).snapshot(),
                self.phase(StepPhase::PrefillAttend).snapshot(),
                self.phase(StepPhase::DecodeAttend).snapshot(),
                self.phase(StepPhase::Logits).snapshot(),
                self.phase(StepPhase::StreamEgress).snapshot(),
            ],
        }
    }
}

/// Point-in-time copy of the scheduler-relevant [`Metrics`]: the ten
/// behavior-defining counters (the exact set the fused/phased and
/// trace-on/off equivalence proptests compare) plus the decode-step and
/// per-phase latency snapshots.  `Copy`, so holding one never borrows
/// the live metrics.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MetricsSnapshot {
    /// Tokens emitted by the continuous decode loop.
    pub generated_tokens: u64,
    /// Prompt tokens prefilled through chunks.
    pub prefill_tokens: u64,
    /// Prefill chunks executed.
    pub prefill_chunks: u64,
    /// Sessions admitted.
    pub sessions: u64,
    /// Sessions preempted.
    pub preemptions: u64,
    /// Decode steps executed.
    pub decode_steps: u64,
    /// Requests refused or deadline-expired.
    pub rejected: u64,
    /// Same-step prefill budget re-offers.
    pub budget_reoffers: u64,
    /// Mid-prefill prefix-cache attachments.
    pub midprefill_prefix_hits: u64,
    /// Prompt tokens served from shared cache pages.
    pub prefix_hit_tokens: u64,
    /// Decode-step wall latency at the snapshot.
    pub decode_step_latency: HistogramSnapshot,
    /// Per-phase step timing at the snapshot, in [`StepPhase::ALL`] order.
    pub phases: [HistogramSnapshot; 7],
}

impl MetricsSnapshot {
    /// The ten behavior-defining counters in their canonical order —
    /// two runs of the same workload must produce equal signatures
    /// regardless of tracing, fusion or timing.
    pub fn counter_signature(&self) -> [u64; 10] {
        [
            self.generated_tokens,
            self.prefill_tokens,
            self.prefill_chunks,
            self.sessions,
            self.preemptions,
            self.decode_steps,
            self.rejected,
            self.budget_reoffers,
            self.midprefill_prefix_hits,
            self.prefix_hit_tokens,
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn exposition_contains_counters_gauges_and_histograms() {
        let m = Metrics::new();
        m.inc_requests();
        m.sessions.fetch_add(3, Ordering::Relaxed);
        m.pool_pages.store(256, Ordering::Relaxed);
        m.demotions.fetch_add(6, Ordering::Relaxed);
        m.compressed_pages.store(4, Ordering::Relaxed);
        m.pool_bytes_in_use.store(81920, Ordering::Relaxed);
        m.peak_decoding_sessions.fetch_max(3, Ordering::Relaxed);
        m.request_latency.record(Duration::from_micros(900));
        let text = m.render_prometheus();
        assert!(text.contains("# TYPE mra_requests_total counter"), "{text}");
        assert!(text.contains("mra_requests_total 1"), "{text}");
        assert!(text.contains("mra_sessions_total 3"), "{text}");
        assert!(text.contains("# TYPE mra_pool_pages gauge"), "{text}");
        assert!(text.contains("mra_pool_pages 256"), "{text}");
        assert!(text.contains("# TYPE mra_demotions_total counter"), "{text}");
        assert!(text.contains("mra_demotions_total 6"), "{text}");
        assert!(text.contains("mra_compressed_pages 4"), "{text}");
        assert!(text.contains("mra_pool_bytes_in_use 81920"), "{text}");
        assert!(text.contains("mra_peak_decoding_sessions 3"), "{text}");
        // 900us -> bucket [512, 1024): cumulative le="1024" and +Inf both 1
        assert!(text.contains("mra_request_latency_us_bucket{le=\"1024\"} 1"), "{text}");
        assert!(text.contains("mra_request_latency_us_bucket{le=\"+Inf\"} 1"), "{text}");
        assert!(text.contains("mra_request_latency_us_sum 900"), "{text}");
        assert!(text.contains("mra_request_latency_us_count 1"), "{text}");
    }

    #[test]
    fn histogram_buckets_render_cumulative() {
        let m = Metrics::new();
        m.decode_step_latency.record(Duration::from_micros(3)); // bucket [2,4)
        m.decode_step_latency.record(Duration::from_micros(3));
        m.decode_step_latency.record(Duration::from_micros(100)); // bucket [64,128)
        let text = m.render_prometheus();
        assert!(text.contains("mra_decode_step_latency_us_bucket{le=\"4\"} 2"), "{text}");
        // cumulative: the [64,128) bucket line includes the two earlier samples
        assert!(text.contains("mra_decode_step_latency_us_bucket{le=\"128\"} 3"), "{text}");
        assert!(text.contains("mra_decode_step_latency_us_bucket{le=\"+Inf\"} 3"), "{text}");
    }

    #[test]
    fn phase_family_renders_one_series_per_phase() {
        let m = Metrics::new();
        m.phase(StepPhase::DecodeAttend).record(Duration::from_micros(40));
        let text = m.render_prometheus();
        for phase in StepPhase::ALL {
            let series =
                format!("mra_step_phase_us_bucket{{phase=\"{}\",le=\"+Inf\"}}", phase.name());
            assert!(text.contains(&series), "missing {series} in\n{text}");
        }
        assert!(
            text.contains("mra_step_phase_us_count{phase=\"decode_attend\"} 1"),
            "{text}"
        );
        assert!(text.contains("mra_step_phase_us_sum{phase=\"decode_attend\"} 40"), "{text}");
        // exactly one HELP/TYPE header for the whole family
        assert_eq!(text.matches("# TYPE mra_step_phase_us histogram").count(), 1);
    }

    #[test]
    fn worker_series_appear_after_pool_work() {
        // drain a pool so at least worker slot 0 has a nonzero counter
        crate::engine::pool::run(1, (0..4usize).collect(), |_| {});
        let m = Metrics::new();
        let text = m.render_prometheus();
        assert!(text.contains("# TYPE mra_pool_worker_tasks_total counter"), "{text}");
        assert!(text.contains("mra_pool_worker_tasks_total{worker=\"0\"}"), "{text}");
    }

    #[test]
    fn snapshot_signature_matches_the_live_counters() {
        let m = Metrics::new();
        m.generated_tokens.fetch_add(7, Ordering::Relaxed);
        m.prefill_tokens.fetch_add(64, Ordering::Relaxed);
        m.prefill_chunks.fetch_add(4, Ordering::Relaxed);
        m.sessions.fetch_add(2, Ordering::Relaxed);
        m.preemptions.fetch_add(1, Ordering::Relaxed);
        m.decode_steps.fetch_add(7, Ordering::Relaxed);
        m.rejected.fetch_add(1, Ordering::Relaxed);
        m.budget_reoffers.fetch_add(3, Ordering::Relaxed);
        m.midprefill_prefix_hits.fetch_add(1, Ordering::Relaxed);
        m.prefix_hit_tokens.fetch_add(16, Ordering::Relaxed);
        m.decode_step_latency.record(Duration::from_micros(500));
        m.phase(StepPhase::Logits).record(Duration::from_micros(20));
        let snap = m.snapshot();
        assert_eq!(snap.counter_signature(), [7, 64, 4, 2, 1, 7, 1, 3, 1, 16]);
        assert_eq!(snap.decode_step_latency.count(), 1);
        assert_eq!(snap.phases[StepPhase::Logits.index()].count(), 1);
        assert_eq!(snap.phases[StepPhase::Ingress.index()].count(), 0);
        // snapshots are value types: a later mutation leaves them alone
        m.generated_tokens.fetch_add(1, Ordering::Relaxed);
        assert_eq!(snap.generated_tokens, 7);
    }
}
