//! Bucket router: maps a formed batch onto the AOT artifact grid.
//!
//! Artifacts are compiled per `(model tag, batch size)` bucket
//! (`fwd_<tag>_b{B}`); the router picks the smallest bucket that fits,
//! pads the token matrix to `(B, seq_len)`, and slices the outputs back to
//! the real requests.
//!
//! Scope note: bucket routing (and its padding waste, tracked by
//! `Metrics::padded_slots`) exists because AOT executables have static
//! shapes.  The native session-serving path
//! ([`crate::coordinator::scheduler`]) has no buckets at all — sessions
//! of any length join/leave the running batch per step, and its paged KV
//! arena plays the role padding plays here (DESIGN.md §9).

use anyhow::{bail, Context, Result};

use crate::runtime::Manifest;

/// Routing decision for one batch.
#[derive(Clone, Debug, PartialEq)]
pub struct Route {
    /// Artifact to execute (`fwd_<tag>_b<bucket>`).
    pub artifact: String,
    /// Chosen batch bucket (slot count).
    pub bucket: usize,
    /// Padding slots added to fill the bucket.
    pub padded_slots: usize,
}

/// Router over the `fwd_<tag>_b*` artifacts of one model.
pub struct Router {
    /// Model tag the router serves.
    pub tag: String,
    /// Model sequence length (from the artifact config).
    pub seq_len: usize,
    /// Available batch buckets, ascending.
    buckets: Vec<usize>,
}

impl Router {
    /// Discover buckets for `tag` from the manifest.
    pub fn new(manifest: &Manifest, tag: &str) -> Result<Self> {
        let prefix = format!("fwd_{tag}_b");
        let mut buckets: Vec<usize> = manifest
            .names_matching(&prefix)
            .iter()
            .filter_map(|n| n.strip_prefix(&prefix).and_then(|b| b.parse().ok()))
            .collect();
        buckets.sort_unstable();
        if buckets.is_empty() {
            bail!("no fwd artifacts for tag {tag}");
        }
        let cfg = manifest.load_cfg(tag)?;
        let seq_len = cfg
            .get("seq_len")
            .context("cfg missing seq_len")?
            .parse()
            .context("bad seq_len")?;
        Ok(Router { tag: tag.to_string(), seq_len, buckets })
    }

    /// Construct directly (tests).
    pub fn with_buckets(tag: &str, seq_len: usize, buckets: Vec<usize>) -> Self {
        Router { tag: tag.to_string(), seq_len, buckets }
    }

    /// Largest available batch bucket.
    pub fn max_bucket(&self) -> usize {
        *self.buckets.last().unwrap()
    }

    /// Pick the smallest bucket >= `batch_len`.
    pub fn route(&self, batch_len: usize) -> Result<Route> {
        let bucket = *self
            .buckets
            .iter()
            .find(|&&b| b >= batch_len)
            .with_context(|| {
                format!("batch {batch_len} exceeds largest bucket {}", self.max_bucket())
            })?;
        Ok(Route {
            artifact: format!("fwd_{}_b{}", self.tag, bucket),
            bucket,
            padded_slots: bucket - batch_len,
        })
    }

    /// Pad token rows (each <= seq_len) into a `(bucket, seq_len)` i32 grid.
    pub fn pad_tokens(&self, rows: &[Vec<i32>], bucket: usize) -> Result<Vec<i32>> {
        if rows.len() > bucket {
            bail!("{} rows exceed bucket {bucket}", rows.len());
        }
        let n = self.seq_len;
        let mut out = vec![0i32; bucket * n];
        for (i, row) in rows.iter().enumerate() {
            if row.len() > n {
                bail!("request length {} exceeds seq_len {n}", row.len());
            }
            out[i * n..i * n + row.len()].copy_from_slice(row);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn router() -> Router {
        Router::with_buckets("mlm_test", 8, vec![1, 4, 8])
    }

    #[test]
    fn picks_smallest_fitting_bucket() {
        let r = router();
        assert_eq!(r.route(1).unwrap().bucket, 1);
        assert_eq!(r.route(2).unwrap().bucket, 4);
        assert_eq!(r.route(4).unwrap().bucket, 4);
        assert_eq!(r.route(5).unwrap().bucket, 8);
        assert_eq!(r.route(5).unwrap().padded_slots, 3);
        assert!(r.route(9).is_err());
    }

    #[test]
    fn artifact_name_format() {
        let r = router();
        assert_eq!(r.route(3).unwrap().artifact, "fwd_mlm_test_b4");
    }

    #[test]
    fn pads_token_grid() {
        let r = router();
        let rows = vec![vec![2, 9, 9], vec![2, 7]];
        let grid = r.pad_tokens(&rows, 4).unwrap();
        assert_eq!(grid.len(), 4 * 8);
        assert_eq!(&grid[0..4], &[2, 9, 9, 0]);
        assert_eq!(&grid[8..12], &[2, 7, 0, 0]);
        assert!(grid[16..].iter().all(|&t| t == 0));
    }

    #[test]
    fn rejects_oversized_requests() {
        let r = router();
        assert!(r.pad_tokens(&[vec![0; 9]], 1).is_err());
        assert!(r.pad_tokens(&[vec![], vec![]], 1).is_err());
    }

    #[test]
    fn manifest_discovery() {
        use std::path::PathBuf;
        let text = "fwd_mlm_x_b1\ta\tfloat32:4,int32:1x8\t1\tmlm_x\nfwd_mlm_x_b8\ta\tfloat32:4,int32:8x8\t1\tmlm_x\n";
        let m = Manifest::parse(text, PathBuf::from("/tmp")).unwrap();
        let prefix = "fwd_mlm_x_b";
        let mut buckets: Vec<usize> = m
            .names_matching(prefix)
            .iter()
            .filter_map(|n| n.strip_prefix(prefix).and_then(|b| b.parse().ok()))
            .collect();
        buckets.sort_unstable();
        assert_eq!(buckets, vec![1, 8]);
    }
}
