//! Continuous-batching session scheduler — the serving loop behind
//! `Server::start_native_lm_sessions`.
//!
//! Replaces the fixed-round batcher for LM generation: instead of forming
//! a batch, decoding every request to completion and only then starting
//! the next batch (the slowest request gates the round), the scheduler
//! keeps a **running set** of live [`LmSession`]s and advances *all* of
//! them one token per step ([`NativeLm::step_sessions`]).  Requests join
//! the running set the step after admission and leave the step they
//! finish — no request ever waits for an unrelated slow request.
//!
//! Prompt prefill is **chunked and interleaved** (Sarathi-style): an
//! admitted session enters a `Prefilling` phase and each scheduler step
//! spends a configurable token budget
//! ([`SessionConfig::prefill_chunk_tokens`]) on block-aligned prefill
//! chunks — run through
//! the engine-parallel [`NativeLm::prefill_chunk`] path — *alongside* the
//! one-token decode of the running set.  A 16k-token prompt therefore no
//! longer freezes every running decode for its whole prefill; it
//! progresses one budget's worth per step while decodes keep emitting.
//! Chunked prefill is bitwise identical to the historical per-token
//! prefill (property-tested), so interleaving never changes outputs.
//!
//! State machine per request (DESIGN.md §9, §10, §12):
//!
//! ```text
//!          admit (pages >= est + watermark;    prefill complete
//!          priority + aging order)
//!  WAITING ---------------------------> PREFILLING ----------> RUNNING --+-- finished
//!     ^  |                                     |                          |
//!     |  | deadline TTL elapses while never    |                          |
//!     |  | admitted: descriptive error         |                          |
//!     |  v                                     |                          |
//!     |     preempt (pool pressure; lowest     |                          |
//!     +---- priority then youngest; generated -+--------------------------+
//!     |     tokens and stream cursor kept)
//!     `-- shutdown: never-admitted waiters get a descriptive error
//! ```
//!
//! **Streaming**: a request may carry a bounded per-token channel
//! (`Request::stream`).  After every step the scheduler pushes each
//! session's not-yet-delivered generated tokens with a *non-blocking*
//! `try_send` — a slow consumer stalls only its own stream (the cursor
//! holds and retries next step; the final `Response` always carries the
//! full sequence, so the tail is never lost), and the scheduler never
//! blocks on a client.  The delivery cursor survives preemption, so a
//! replayed session resumes its stream silently: no token is ever
//! streamed twice, none is skipped.
//!
//! **Sampling**: each request's `SamplingParams` are installed into its
//! session at (re)admission.  Stochastic selection draws from a
//! counter-based RNG (`crate::engine::DrawState`) whose cursor is
//! restored to `generated.len()` on readmission — one draw per emitted
//! token, so replay reproduces the identical stream (`Scheduler::verify`
//! asserts this draw-count coherence every step).
//!
//! Memory control is page-based: the KV state of every session lives in
//! one bounded [`PagePool`].  Admission requires the pool to hold a
//! session's *lifetime* estimate (`prompt + gen_tokens` pages) plus a
//! free watermark; each step plans the prefill chunks it is about to run
//! and reserves the pages the running set will touch (decode appends +
//! planned chunks), reclaiming in order (1) LRU radix-cache entries, then
//! (2) preempting the most recently admitted session.  A preempted
//! session's prompt *and already-generated tokens* are replayed through
//! the same chunked prefill on readmission — decode is deterministic, so
//! recompute-on-readmit is lossless (asserted in tests), and the radix
//! prefix cache usually turns the replay into a page-sharing hit.
//!
//! Fairness and QoS: admission picks the waiting request with the
//! highest *effective* priority — `Request::priority` plus one point per
//! [`SessionConfig::aging_steps`] scheduler steps waited, so low priority
//! means later, never never — with preempted sessions resuming first
//! (accepted means served) and FIFO order breaking exact ties.  The
//! selected head is admitted or waited for, never bypassed (no
//! starvation-by-overtaking of large requests); head-of-line requests
//! that can never fit the pool are rejected, not allowed to wedge the
//! queue, and waiting requests whose admission deadline (`Request::
//! deadline`) elapses are answered with a descriptive error.  The
//! prefill budget is spent oldest-admitted first; every decodable session
//! gets exactly one token per step; preemption takes the lowest-priority,
//! then youngest, session so high-priority and older sessions keep their
//! progress.  On shutdown, requests still waiting for admission are
//! answered with a descriptive error instead of having their responders
//! dropped (a hung client); sessions that were already admitted
//! (including preempted ones) still run to completion.
//!
//! State lives in the crate-internal `Scheduler` struct, one phase per
//! method, and every step ends in `Scheduler::check_invariants` (compiled under
//! `debug_assertions` or the `paranoid` feature — see DESIGN.md §11):
//! the page pool's conservation accounting, the radix tree's structure,
//! and the scheduler's own queue/page arithmetic are machine-checked
//! after each step of every serving test, not asserted in prose.

// request/responder paths must never panic mid-step: a panicking
// scheduler thread drops every queued responder (the PR 5 hung-client
// bug class).  `cargo xtask lint` enforces the same rule textually.
#![deny(clippy::unwrap_used, clippy::expect_used)]

use std::collections::{HashSet, VecDeque};
use std::sync::atomic::Ordering;
use std::sync::mpsc::{Receiver, SyncSender, TryRecvError, TrySendError};
use std::sync::Arc;
use std::time::Duration;

pub use crate::config::SessionConfig;
use crate::coordinator::autotune::{AutotuneBudget, MonotonicClock, StepClock};
use crate::coordinator::batcher::Request;
use crate::coordinator::metrics::{Metrics, StepPhase};
use crate::coordinator::native::{FusedPrefill, LmSession, NativeLm, StepPhases};
use crate::coordinator::server::{Ingress, Responder, Response};
use crate::coordinator::trace::{FlightRecorder, PreemptReason, TraceEvent};
use crate::engine::{PageFormat, PagePool, PoolExhausted, RadixCache};

/// A request waiting for admission (fresh, or preempted with its partial
/// generation kept for replay).
struct Pending {
    req: Request,
    resp: Responder,
    /// Tokens generated before a preemption; replayed through prefill on
    /// readmission so the visible output is identical.
    generated: Vec<i32>,
    /// True once this request has been admitted at least once (a
    /// preempted session awaiting readmission).  Admitted requests are
    /// never shed at shutdown and never deadline-expired — accepted
    /// means served.
    admitted: bool,
    /// Stream-delivery cursor: `generated[..streamed]` has been sent on
    /// the request's token channel.  Survives preemption so replay never
    /// re-streams a token.
    streamed: usize,
    /// Scheduler step at which this entry (re)joined the waiting queue —
    /// the reference point for priority aging.
    enqueued_step: u64,
}

/// A request in the running set (prefilling or decoding).
struct Running {
    req: Request,
    resp: Responder,
    session: LmSession,
    generated: Vec<i32>,
    /// `Some(prompt)` while the session is still prefilling `prompt`
    /// (request tokens + any pre-preemption generation to replay); the
    /// session's `len()` is the prefill cursor.  `None` once decoding.
    prefill: Option<Vec<i32>>,
    /// Admission stamp; preemption evicts the lowest priority, then the
    /// largest stamp (youngest).
    admitted_at: u64,
    /// Stream-delivery cursor (see [`Pending::streamed`]).
    streamed: usize,
}

impl Running {
    fn target_tokens(&self) -> usize {
        self.req.gen_tokens.max(1)
    }

    /// Decode-phase and not one token from target (those leave through
    /// the finisher path, straight from logits).
    fn decodable(&self) -> bool {
        self.prefill.is_none() && self.generated.len() + 1 < self.target_tokens()
    }
}

/// One block-aligned prefill chunk the step is about to run:
/// `(running index, tokens to take, prefill completes after, grew from
/// re-offered budget)` — the last flag flows into the
/// [`TraceEvent::PrefillChunk`] record.
type ChunkPlan = Vec<(usize, usize, bool, bool)>;

/// The continuous-batching scheduler state: the page pool, the radix
/// prefix cache and the session queues, advanced one step at a time by
/// [`Scheduler::step`] (the phases of the old monolithic loop, one
/// method each).  [`scheduler_loop`] is the thread body driving it.
pub(crate) struct Scheduler {
    lm: Arc<NativeLm>,
    scfg: SessionConfig,
    metrics: Arc<Metrics>,
    pool: PagePool,
    cache: Option<RadixCache>,
    waiting: VecDeque<Pending>,
    running: Vec<Running>,
    open: bool,
    admit_stamp: u64,
    seq_len: usize,
    block: usize,
    /// Self-tuning prefill token budget (AIMD against
    /// `sessions.decode_p95_target_us`; `sessions.prefill_chunk_tokens`
    /// is its initial value and hard cap, one block its floor — so
    /// prefill always progresses).
    autotune: AutotuneBudget,
    /// Execute each step as one fused task drain
    /// ([`NativeLm::fused_step`]) instead of the legacy
    /// prefill-then-decode sub-phases (`sessions.fused_step`; results
    /// are bitwise identical either way — property-tested).
    fused: bool,
    /// Monotone step counter — the clock priority aging reads.  Step-based
    /// (not wall-clock) so QoS ordering is deterministic under test.
    steps: u64,
    /// The flight recorder, when `[trace] enabled` — `None` is the
    /// zero-cost disabled form (every record site is one `Option` branch;
    /// tracing on vs off is behavior-invariant, property-tested).
    trace: Option<Arc<FlightRecorder>>,
    /// Pressure-demotion target format (`[sessions] page_format` when
    /// `demote_before_preempt` is on and the format is compressed).
    /// `None` means demotion is off and pressure goes straight to
    /// preemption, the pre-compression behavior.
    demote_fmt: Option<PageFormat>,
}

/// The scheduler thread body: drains `ingress` until shutdown *and* all
/// admitted work is finished.
pub(crate) fn scheduler_loop(
    ingress: Receiver<Ingress>,
    lm: Arc<NativeLm>,
    scfg: SessionConfig,
    metrics: Arc<Metrics>,
    trace: Option<Arc<FlightRecorder>>,
) {
    let mut sched =
        Scheduler::with_trace(lm, scfg, metrics, Box::new(MonotonicClock::default()), trace);
    while sched.step(&ingress) {}
}

impl Scheduler {
    pub(crate) fn new(lm: Arc<NativeLm>, scfg: SessionConfig, metrics: Arc<Metrics>) -> Self {
        Self::with_clock(lm, scfg, metrics, Box::new(MonotonicClock::default()))
    }

    /// [`Scheduler::new`] with an injected step clock — the hook tests
    /// and benches use to drive the budget controller deterministically
    /// ([`crate::coordinator::autotune::ManualClock`]).
    pub(crate) fn with_clock(
        lm: Arc<NativeLm>,
        scfg: SessionConfig,
        metrics: Arc<Metrics>,
        clock: Box<dyn StepClock>,
    ) -> Self {
        Self::with_trace(lm, scfg, metrics, clock, None)
    }

    /// [`Scheduler::with_clock`] plus an optional flight recorder — the
    /// full-injection constructor [`scheduler_loop`] uses.  The same
    /// injected clock stamps both the autotune controller and every
    /// trace record, so all observability surfaces agree on "now".
    pub(crate) fn with_trace(
        lm: Arc<NativeLm>,
        scfg: SessionConfig,
        metrics: Arc<Metrics>,
        clock: Box<dyn StepClock>,
        trace: Option<Arc<FlightRecorder>>,
    ) -> Self {
        let pool = lm.new_page_pool(scfg.total_pages);
        metrics.pool_pages.store(scfg.total_pages as u64, Ordering::Relaxed);
        let cache = if scfg.prefix_cache { Some(lm.new_radix_cache()) } else { None };
        let seq_len = lm.config().seq_len;
        let block = lm.config().block;
        let autotune = AutotuneBudget::new(
            scfg.prefill_chunk_tokens.max(block),
            block,
            scfg.decode_p95_target_us,
            scfg.autotune_prefill,
            clock,
        );
        let fused = scfg.fused_step;
        let demote_fmt = scfg.demote_target();
        Scheduler {
            lm,
            scfg,
            metrics,
            pool,
            cache,
            waiting: VecDeque::new(),
            running: Vec::new(),
            open: true,
            admit_stamp: 0,
            seq_len,
            block,
            autotune,
            fused,
            steps: 0,
            trace,
            demote_fmt,
        }
    }

    /// Append one event to the flight recorder, if tracing is on — the
    /// single indirection every record site shares.  A free function over
    /// the field (not `&self`) so retain/loop bodies can capture
    /// `&self.trace` disjointly from their other field borrows.
    fn trace_ev(trace: &Option<Arc<FlightRecorder>>, step: u64, at_us: u64, ev: TraceEvent) {
        if let Some(t) = trace.as_ref() {
            t.record(step, at_us, ev);
        }
    }

    /// One full scheduler step; returns `false` when the loop should
    /// exit (shutdown observed and all admitted work drained).  Ends in
    /// [`Scheduler::check_invariants`] on every path that mutated state.
    pub(crate) fn step(&mut self, ingress: &Receiver<Ingress>) -> bool {
        // ---- ingress: block only when fully idle ----------------------
        if self.running.is_empty() && self.waiting.is_empty() {
            if !self.open {
                return false;
            }
            match ingress.recv() {
                Ok(Ingress::Req(req, resp)) => self.enqueue(req, resp),
                Ok(Ingress::Shutdown) | Err(_) => {
                    self.open = false;
                    return true;
                }
            }
        }
        // phase attribution starts here: the idle recv above is excluded
        // (time spent with no work is not a step phase)
        let t0 = self.autotune.now_us();
        loop {
            match ingress.try_recv() {
                Ok(Ingress::Req(req, resp)) => self.enqueue(req, resp),
                Ok(Ingress::Shutdown) => self.open = false,
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    self.open = false;
                    break;
                }
            }
        }
        let t1 = self.autotune.now_us();

        self.steps = self.steps.wrapping_add(1);
        self.shed_unadmitted_waiters();
        self.expire_deadlines();
        self.admit();
        self.finish_ready();
        let t2 = self.autotune.now_us();

        if self.running.is_empty() {
            self.stream_progress();
            self.publish_gauges();
            self.check_invariants();
            return true;
        }

        let plan = self.plan_and_reserve();
        let t3 = self.autotune.now_us();
        let budget_before = self.autotune.current();
        self.autotune.begin_step();
        let mut native = StepPhases::default();
        let decoded = if self.fused {
            self.fused_execute(&plan, &mut native)
        } else {
            self.run_prefill_chunks(&plan, &mut native);
            self.decode_step(&mut native)
        };
        let dt = self.autotune.end_step(!plan.is_empty());
        if decoded {
            // observe only steps that actually decoded: the p95 the
            // controller regulates is decode latency under prefill load
            self.metrics.decode_step_latency.record(Duration::from_micros(dt));
        }
        let budget_after = self.autotune.current();
        if budget_after != budget_before {
            let at = self.autotune.now_us();
            Self::trace_ev(
                &self.trace,
                self.steps,
                at,
                TraceEvent::AutotuneResize {
                    old: budget_before as u32,
                    new: budget_after as u32,
                },
            );
        }
        let t4 = self.autotune.now_us();
        self.stream_progress();
        self.publish_gauges();
        let t5 = self.autotune.now_us();
        // fold the step's phase spans into the per-phase histograms and
        // close the step with its StepEnd trace marker.  The native
        // attend/logits spans subdivide t3..t4; glue around them (task
        // assembly, metric pushes, preemption bookkeeping) is deliberately
        // unattributed, so the phase sum tracks the step total to within
        // one histogram bucket (gated in benches/bench_serve.rs).
        let spans: [u64; 7] = [
            t1.saturating_sub(t0),
            t2.saturating_sub(t1),
            t3.saturating_sub(t2),
            native.prefill_attend_us,
            native.decode_attend_us,
            native.logits_us,
            t5.saturating_sub(t4),
        ];
        for (phase, &us) in StepPhase::ALL.iter().zip(&spans) {
            self.metrics.phase(*phase).record(Duration::from_micros(us));
        }
        Self::trace_ev(
            &self.trace,
            self.steps,
            t5,
            TraceEvent::StepEnd {
                phases: [
                    spans[0] as u32,
                    spans[1] as u32,
                    spans[2] as u32,
                    spans[3] as u32,
                    spans[4] as u32,
                    spans[5] as u32,
                    spans[6] as u32,
                ],
                total_us: t5.saturating_sub(t0) as u32,
            },
        );
        self.check_invariants();
        true
    }

    fn enqueue(&mut self, req: Request, resp: Responder) {
        self.waiting.push_back(Pending {
            req,
            resp,
            generated: Vec::new(),
            admitted: false,
            streamed: 0,
            enqueued_step: self.steps,
        });
    }

    /// Shutdown shed (§bugfix): never-admitted waiters get a descriptive
    /// error instead of a dropped responder (hung client).  Preempted
    /// sessions stay — they were admitted once and finish through
    /// readmission (accepted means served).
    fn shed_unadmitted_waiters(&mut self) {
        if self.open || self.waiting.is_empty() {
            return;
        }
        let metrics = &self.metrics;
        self.waiting.retain(|p| {
            if !p.admitted {
                metrics.inc_rejected();
                let _ = p.resp.send(Err(format!(
                    "scheduler shutting down: request {} was still waiting for \
                     admission and was not served — resubmit after restart",
                    p.req.id
                )));
                false
            } else {
                true
            }
        });
    }

    /// Deadline expiry: a waiting request whose admission TTL
    /// (`Request::deadline`, measured from `Request::arrived`) elapses
    /// before it is ever admitted is answered with a descriptive error —
    /// a deadline-carrying client prefers a prompt refusal to a late
    /// answer.  Preempted (once-admitted) requests are exempt: accepted
    /// means served.
    fn expire_deadlines(&mut self) {
        let at = self.autotune.now_us();
        let step = self.steps;
        let metrics = &self.metrics;
        let trace = &self.trace;
        self.waiting.retain(|p| {
            if p.admitted {
                return true;
            }
            let Some(ttl) = p.req.deadline else { return true };
            let waited = p.req.arrived.elapsed();
            if waited < ttl {
                return true;
            }
            metrics.deadline_expired.fetch_add(1, Ordering::Relaxed);
            metrics.inc_rejected();
            Self::trace_ev(trace, step, at, TraceEvent::Expire { id: p.req.id });
            let _ = p.resp.send(Err(format!(
                "request {} missed its {ttl:?} admission deadline after waiting \
                 {waited:?} — raise the deadline, lower the load, or raise \
                 sessions.total_pages",
                p.req.id
            )));
            false
        });
    }

    /// The waiting entry admission should try next: preempted sessions
    /// first (accepted means served), then highest *effective* priority —
    /// `Request::priority` plus one point per `SessionConfig::aging_steps`
    /// steps spent waiting, so low priority means later, never never —
    /// with queue order (earlier enqueue step, then earlier position)
    /// breaking exact ties.  Every key component is deterministic, so the
    /// admission sequence is reproducible under test.
    fn pick_waiting(&self) -> Option<usize> {
        use std::cmp::Reverse;
        let aging = self.scfg.aging_steps as u64;
        (0..self.waiting.len()).max_by_key(|&i| {
            let p = &self.waiting[i];
            let waited = self.steps.saturating_sub(p.enqueued_step);
            let boost = if aging > 0 { waited / aging } else { 0 };
            (p.admitted, (p.req.priority as u64).saturating_add(boost), Reverse(p.enqueued_step), Reverse(i))
        })
    }

    /// Push `generated[*streamed..]` down a request's token channel with
    /// non-blocking sends.  Full buffer: count a stall and retry next step
    /// (the cursor holds, nothing is dropped).  Disconnected receiver:
    /// forget the channel — the requester kept the `Response` path, which
    /// always carries the full sequence.
    #[allow(clippy::too_many_arguments)]
    fn stream_tokens(
        metrics: &Metrics,
        trace: &Option<Arc<FlightRecorder>>,
        step: u64,
        at_us: u64,
        id: u64,
        stream: &mut Option<SyncSender<i32>>,
        generated: &[i32],
        streamed: &mut usize,
    ) {
        let Some(tx) = stream.as_ref() else {
            return;
        };
        while *streamed < generated.len() {
            match tx.try_send(generated[*streamed]) {
                Ok(()) => {
                    *streamed += 1;
                    metrics.streamed_tokens.fetch_add(1, Ordering::Relaxed);
                }
                Err(TrySendError::Full(_)) => {
                    metrics.stream_stalls.fetch_add(1, Ordering::Relaxed);
                    Self::trace_ev(trace, step, at_us, TraceEvent::StreamStall { id });
                    return;
                }
                Err(TrySendError::Disconnected(_)) => {
                    *stream = None;
                    return;
                }
            }
        }
    }

    /// Streaming phase: flush every session's undelivered tokens —
    /// running sessions and preempted waiters alike (a preempted session's
    /// already-generated tokens keep streaming while it waits for
    /// readmission; the cursor guarantees its replay never re-sends one).
    fn stream_progress(&mut self) {
        let at = self.autotune.now_us();
        let step = self.steps;
        let (metrics, trace) = (&self.metrics, &self.trace);
        for r in &mut self.running {
            Self::stream_tokens(
                metrics,
                trace,
                step,
                at,
                r.req.id,
                &mut r.req.stream,
                &r.generated,
                &mut r.streamed,
            );
        }
        for p in &mut self.waiting {
            if p.admitted {
                Self::stream_tokens(
                    metrics,
                    trace,
                    step,
                    at,
                    p.req.id,
                    &mut p.req.stream,
                    &p.generated,
                    &mut p.streamed,
                );
            }
        }
    }

    /// Admission: highest effective priority first ([`Scheduler::
    /// pick_waiting`]) against the free-page watermark.
    fn admit(&mut self) {
        while self.running.len() < self.scfg.max_running.max(1) {
            // inspect the pick; `est` is the page estimate the timing
            // check uses, `reject` a terminal refusal for this request
            let Some(bi) = self.pick_waiting() else { break };
            let (reject, est) = {
                let Some(front) = self.waiting.get(bi) else { break };
                let gen = front.req.gen_tokens.max(1);
                if front.req.tokens.is_empty() {
                    (Some("empty prompt".to_string()), 0)
                } else if front.req.tokens.len() + gen > self.seq_len {
                    (
                        Some(format!(
                            "prompt {} + {} new tokens exceeds seq_len {}",
                            front.req.tokens.len(),
                            gen,
                            self.seq_len
                        )),
                        0,
                    )
                } else {
                    // lifetime footprint: every page the session will ever
                    // hold.  The *feasibility* check must use this cold
                    // estimate — a request admitted thanks to cache sharing
                    // could otherwise be hard-rejected on readmission after
                    // its cached prefix was evicted, breaking the
                    // accepted-means-served contract.
                    let est_cold =
                        self.lm.session_page_estimate(front.req.tokens.len() + gen);
                    // the *timing* check may discount the prompt prefix the
                    // radix cache will share instead of allocate (read-only
                    // probe, no LRU touch — readmits probe only their
                    // original prompt, a safe under-count)
                    let mut est = est_cold;
                    if let Some(c) = self.cache.as_ref() {
                        let probe_len =
                            front.req.tokens.len().saturating_sub(1) / self.block * self.block;
                        let cached = c.probe(&front.req.tokens[..probe_len]);
                        est = est.saturating_sub(self.lm.streams() * (cached / self.block));
                    }
                    if est_cold + self.scfg.free_watermark > self.scfg.total_pages {
                        (
                            Some(format!(
                                "request needs ~{est_cold} pages + watermark {} but the pool \
                                 holds only {} — raise sessions.total_pages",
                                self.scfg.free_watermark, self.scfg.total_pages
                            )),
                            0,
                        )
                    } else {
                        (None, est)
                    }
                }
            };
            if let Some(msg) = reject {
                let Some(p) = self.waiting.remove(bi) else { break };
                self.metrics.inc_rejected();
                let _ = p.resp.send(Err(msg));
                continue;
            }
            if self.pool.free_pages() < est + self.scfg.free_watermark {
                // reclaim cold radix-cache entries before refusing
                let need = est + self.scfg.free_watermark - self.pool.free_pages();
                if let Some(c) = self.cache.as_mut() {
                    c.evict_lru(need);
                }
                if self.pool.free_pages() < est + self.scfg.free_watermark {
                    // cache eviction wasn't enough — shrink cold decode-phase
                    // pages to the compressed format before giving up
                    self.demote_pressure(est + self.scfg.free_watermark);
                }
                if self.pool.free_pages() < est + self.scfg.free_watermark {
                    // the picked request waits; it is never bypassed by a
                    // smaller one (no starvation-by-overtaking)
                    break;
                }
            }
            let Some(mut p) = self.waiting.remove(bi) else { break };
            // replay = prompt + any generation from before a preemption
            let mut prompt = p.req.tokens.clone();
            prompt.extend_from_slice(&p.generated);
            // opening a session computes nothing and consumes no pages —
            // it only attaches the radix-cached prefix; the prompt then
            // prefills in budgeted chunks across the following steps
            match self.lm.begin_session(&prompt, &self.pool, self.cache.as_mut()) {
                Ok(mut session) => {
                    self.metrics.sessions.fetch_add(1, Ordering::Relaxed);
                    let at = self.autotune.now_us();
                    if p.admitted {
                        Self::trace_ev(
                            &self.trace,
                            self.steps,
                            at,
                            TraceEvent::Readmit {
                                id: p.req.id,
                                replay_tokens: p.generated.len() as u32,
                            },
                        );
                    } else {
                        Self::trace_ev(
                            &self.trace,
                            self.steps,
                            at,
                            TraceEvent::Admit {
                                id: p.req.id,
                                prompt_tokens: p.req.tokens.len() as u32,
                            },
                        );
                    }
                    // readmissions of preempted sessions mostly re-find
                    // their *own* blocks — real recompute savings, but not
                    // cross-session sharing, so they stay out of the
                    // prefix-hit metrics
                    if p.generated.is_empty() {
                        let cached = session.cached_tokens();
                        if cached > 0 {
                            Self::trace_ev(
                                &self.trace,
                                self.steps,
                                at,
                                TraceEvent::RadixHit {
                                    id: p.req.id,
                                    cached_tokens: cached as u32,
                                },
                            );
                        }
                        self.metrics.record_prefix_lookup(cached);
                        // blocks published mid-prefill (per-chunk) by a
                        // *still-prefilling* session with the same prompt:
                        // the dedup the chunk-granular publication buys
                        if cached > 0
                            && self.running.iter().any(|r| {
                                r.prefill.as_ref().is_some_and(|pf| {
                                    pf.len() >= cached && pf[..cached] == prompt[..cached]
                                })
                            })
                        {
                            self.metrics.midprefill_prefix_hits.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                    // install the request's sampling policy; a readmitted
                    // stochastic session fast-forwards its draw counter to
                    // one draw per already-emitted token, so its replay
                    // re-selects the identical sequence (greedy keeps the
                    // counter at zero — `verify` asserts both)
                    let params = p.req.sampling;
                    if params.is_greedy() {
                        session.set_sampling(params);
                    } else {
                        session.restore_sampling(params, p.generated.len() as u64);
                    }
                    self.admit_stamp += 1;
                    self.running.push(Running {
                        req: p.req,
                        resp: p.resp,
                        session,
                        generated: std::mem::take(&mut p.generated),
                        prefill: Some(prompt),
                        admitted_at: self.admit_stamp,
                        streamed: p.streamed,
                    });
                }
                Err(e) => {
                    self.metrics.inc_rejected();
                    let _ = p.resp.send(Err(format!("{e:#}")));
                }
            }
        }
    }

    /// Finishers: decoded sessions one token from target take it
    /// straight from their current logits — no advance, no pages, no
    /// risk of a pointless final-step preemption (mirrors generate()'s
    /// `gi + 1 < max_new` skip, so outputs stay bitwise aligned).
    fn finish_ready(&mut self) {
        let mut i = 0;
        while i < self.running.len() {
            if self.running[i].prefill.is_none()
                && self.running[i].generated.len() + 1 >= self.running[i].target_tokens()
            {
                let mut r = self.running.remove(i);
                r.generated.push(r.session.choose_token());
                self.metrics.generated_tokens.fetch_add(1, Ordering::Relaxed);
                let at = self.autotune.now_us();
                Self::trace_ev(
                    &self.trace,
                    self.steps,
                    at,
                    TraceEvent::Finish { id: r.req.id, generated: r.generated.len() as u32 },
                );
                // best-effort final flush; the sender drops with `r`, so a
                // streaming consumer sees end-of-stream and recovers any
                // unflushed tail from the Response's full sequence
                Self::stream_tokens(
                    &self.metrics,
                    &self.trace,
                    self.steps,
                    at,
                    r.req.id,
                    &mut r.req.stream,
                    &r.generated,
                    &mut r.streamed,
                );
                let latency = r.req.arrived.elapsed();
                self.metrics.request_latency.record(latency);
                let _ = r.resp.send(Ok(Response {
                    id: r.req.id,
                    predictions: r.generated,
                    latency,
                }));
            } else {
                i += 1;
            }
        }
    }

    /// The running session preemption takes when pages run short: lowest
    /// request priority first, youngest admission stamp breaking ties —
    /// high-priority and long-resident sessions keep their progress.
    fn preempt_victim(&self) -> Option<usize> {
        self.running
            .iter()
            .enumerate()
            .min_by_key(|(_, r)| (r.req.priority, std::cmp::Reverse(r.admitted_at)))
            .map(|(i, _)| i)
    }

    /// Pressure-relief pass between cache eviction and preemption: demote
    /// cold (non-tail, exclusively-held) pages of decode-phase sessions to
    /// the configured compressed format until `pool.free_pages() >= target`
    /// or nothing cold remains.  Victim order mirrors
    /// [`Scheduler::preempt_victim`] — lowest priority first, youngest
    /// admission breaking ties — so the sessions that would be preempted
    /// anyway lose fidelity first and high-priority residents keep full
    /// precision longest.  Returns the number of pages demoted (0 when
    /// `[sessions]` disables demotion, no session is in decode phase, or
    /// every cold page is already compressed/shared).
    fn demote_pressure(&mut self, target: usize) -> usize {
        let Some(fmt) = self.demote_fmt else { return 0 };
        let mut order: Vec<usize> =
            (0..self.running.len()).filter(|&i| self.running[i].prefill.is_none()).collect();
        order.sort_unstable_by_key(|&i| {
            let r = &self.running[i];
            (r.req.priority, std::cmp::Reverse(r.admitted_at))
        });
        let mut total = 0usize;
        for i in order {
            if self.pool.free_pages() >= target {
                break;
            }
            let r = &mut self.running[i];
            let n = r.session.demote_cold(fmt, usize::MAX);
            if n > 0 {
                total += n;
                let at = self.autotune.now_us();
                Self::trace_ev(
                    &self.trace,
                    self.steps,
                    at,
                    TraceEvent::PageDemote { id: r.req.id, pages: n as u32 },
                );
            }
        }
        if total > 0 {
            self.metrics.demotions.fetch_add(total as u64, Ordering::Relaxed);
        }
        total
    }

    /// Spend the step's autotuned token budget over the prefilling
    /// sessions, oldest admission first, and keep re-offering the
    /// leftover until it is gone or nobody can take more.
    ///
    /// One pass is not enough (§bugfix): [`NativeLm::prefill_take`]
    /// snaps non-final chunks *down* to a block boundary, so a 44-token
    /// budget against a long prompt hands out 32 and strands 12 — every
    /// step, forever.  Re-offering lets the same session (or the next
    /// one in admission order) extend its planned chunk into the
    /// remainder, so the whole budget is spent whenever work exists.
    /// Extended entries stay one chunk per session (`plan` entry takes
    /// are merged), and every re-offer is counted into
    /// `Metrics::budget_reoffers` by [`Scheduler::commit_plan`].
    ///
    /// Pure arithmetic over scheduler state — recomputable after every
    /// eviction/preemption of the reserve loop.
    fn plan_chunks(&self) -> (ChunkPlan, u64) {
        let mut budget = self.autotune.current();
        let mut plan: ChunkPlan = Vec::new();
        let mut reoffers: u64 = 0;
        let mut order: Vec<usize> =
            (0..self.running.len()).filter(|&i| self.running[i].prefill.is_some()).collect();
        order.sort_unstable_by_key(|&i| self.running[i].admitted_at);
        let mut first_pass = true;
        loop {
            let mut progressed = false;
            for &i in &order {
                if budget == 0 {
                    break;
                }
                let r = &self.running[i];
                let Some(pf) = r.prefill.as_ref() else { continue };
                let entry = plan.iter().position(|e| e.0 == i);
                let done = r.session.len() + entry.map(|e| plan[e].1).unwrap_or(0);
                if done >= pf.len() {
                    continue;
                }
                let take = self.lm.prefill_take(done, pf.len(), budget);
                if take == 0 {
                    continue;
                }
                budget -= take;
                progressed = true;
                let done_after = done + take == pf.len();
                match entry {
                    Some(e) => {
                        plan[e].1 += take;
                        plan[e].2 = done_after;
                        plan[e].3 = true;
                        reoffers += 1;
                    }
                    None => {
                        if !first_pass {
                            reoffers += 1;
                        }
                        plan.push((i, take, done_after, !first_pass));
                    }
                }
            }
            if !progressed || budget == 0 {
                break;
            }
            first_pass = false;
        }
        (plan, reoffers)
    }

    /// Record a finally-reserved plan's re-offer count (the reserve loop
    /// may replan several times; only the plan actually run counts).
    fn commit_plan(&self, plan: ChunkPlan, reoffers: u64) -> ChunkPlan {
        if reoffers > 0 {
            self.metrics.budget_reoffers.fetch_add(reoffers, Ordering::Relaxed);
        }
        plan
    }

    /// Plan + reserve this step (evict, then preempt lowest-priority,
    /// youngest — [`Scheduler::preempt_victim`]).  The
    /// prefill plan ([`Scheduler::plan_chunks`]) is pure arithmetic, so
    /// it can be recomputed after
    /// every preemption until the step's page demand fits: one
    /// chunk per prefilling session (oldest first) from
    /// the shared token budget, alongside one decode append per
    /// decodable session.
    fn plan_and_reserve(&mut self) -> ChunkPlan {
        loop {
            let (plan, reoffers) = self.plan_chunks();
            let mut needed: usize = self
                .running
                .iter()
                .filter(|r| r.decodable())
                .map(|r| r.session.pages_needed_next_step())
                .sum();
            for &(i, take, done_after, _) in &plan {
                let r = &self.running[i];
                needed += r.session.pages_needed_for_chunk(take);
                // a session finishing its prefill this step decodes this
                // step too — its first decode append may start a block
                if done_after && r.generated.len() + 1 < r.target_tokens() {
                    let Some(pf) = r.prefill.as_ref() else { continue };
                    if pf.len() % self.block == 0 {
                        needed += self.lm.streams();
                    }
                }
            }
            if self.pool.free_pages() >= needed {
                return self.commit_plan(plan, reoffers);
            }
            let short = needed - self.pool.free_pages();
            if let Some(c) = self.cache.as_mut() {
                if c.evict_lru(short) > 0 {
                    continue;
                }
            }
            // compress cold decode-phase pages before sacrificing a whole
            // session — preemption becomes the last resort.  Terminates:
            // each pass either frees pages (progress towards `needed`) or
            // demotes nothing and falls through to preemption.
            if self.demote_pressure(needed) > 0 {
                continue;
            }
            if self.running.len() <= 1 {
                // a single session always fits its admission estimate; if
                // this still trips, the chunk/step below surfaces
                // PoolExhausted and the session is preempted whole
                return self.commit_plan(plan, reoffers);
            }
            let Some(vi) = self.preempt_victim() else {
                return self.commit_plan(plan, reoffers);
            };
            let victim = self.running.swap_remove(vi);
            self.metrics.preemptions.fetch_add(1, Ordering::Relaxed);
            let at = self.autotune.now_us();
            Self::trace_ev(
                &self.trace,
                self.steps,
                at,
                TraceEvent::Preempt { id: victim.req.id, reason: PreemptReason::Pages },
            );
            self.waiting.push_front(Pending {
                req: victim.req,
                resp: victim.resp,
                generated: victim.generated,
                admitted: true,
                streamed: victim.streamed,
                enqueued_step: self.steps,
            });
            // victim.session drops here; its exclusive pages return
        }
    }

    /// Advertise running index `i`'s complete, immutable prompt blocks
    /// to the radix cache — called after *every* successful prefill
    /// chunk, not only the final one, so a concurrent session with the
    /// same prompt shares the prefix pages physically while the first
    /// is still mid-prefill (the insert is prefix-idempotent and
    /// block-aligned, so repeated per-chunk publication just extends the
    /// cached run).
    fn publish_completed_blocks(&mut self, i: usize) {
        let Some(c) = self.cache.as_mut() else { return };
        let r = &self.running[i];
        let Some(prompt) = r.prefill.as_ref() else { return };
        let nb = r.session.len() / self.block;
        if nb > 0 {
            self.lm.publish_prompt_pages(c, &prompt[..nb * self.block], &r.session);
        }
    }

    /// Prefill: run the planned chunks through the engine, folding each
    /// chunk's wall time into [`StepPhases::prefill_attend_us`].
    fn run_prefill_chunks(&mut self, plan: &ChunkPlan, phases: &mut StepPhases) {
        let mut torn: Vec<usize> = Vec::new();
        for &(i, take, done_after, reoffered) in plan {
            let tc0 = self.autotune.now_us();
            let ok = {
                let Running { session, prefill, .. } = &mut self.running[i];
                let Some(prompt) = prefill.as_ref() else { continue };
                let from = session.len();
                self.lm.prefill_chunk(session, &prompt[from..from + take], done_after).is_ok()
            };
            let tc1 = self.autotune.now_us();
            phases.prefill_attend_us += tc1.saturating_sub(tc0);
            if ok {
                self.metrics.record_prefill_chunk(take);
                Self::trace_ev(
                    &self.trace,
                    self.steps,
                    tc1,
                    TraceEvent::PrefillChunk {
                        id: self.running[i].req.id,
                        tokens: take as u32,
                        reoffered,
                    },
                );
                self.publish_completed_blocks(i);
            } else {
                torn.push(i);
            }
        }
        for &(i, _, done_after, _) in plan {
            if done_after && !torn.contains(&i) {
                self.running[i].prefill = None;
            }
        }
        // plan order is admission order, not index order: sort so the
        // reverse removal below never invalidates a pending index
        torn.sort_unstable();
        for &i in torn.iter().rev() {
            // mid-chunk pool exhaustion: the session's streams are torn —
            // drop it and replay prompt + generated on readmission
            // (chunked prefill is deterministic, so the replay is
            // lossless), unless nothing in the system can ever free a
            // page, in which case fail loudly instead of looping forever
            let r = self.running.remove(i);
            let reclaimable = !self.running.is_empty()
                || self.cache.as_ref().map(|c| c.pages_held() > 0).unwrap_or(false);
            if reclaimable {
                self.metrics.preemptions.fetch_add(1, Ordering::Relaxed);
                let at = self.autotune.now_us();
                Self::trace_ev(
                    &self.trace,
                    self.steps,
                    at,
                    TraceEvent::Preempt { id: r.req.id, reason: PreemptReason::TornPrefill },
                );
                self.waiting.push_front(Pending {
                    req: r.req,
                    resp: r.resp,
                    generated: r.generated,
                    admitted: true,
                    streamed: r.streamed,
                    enqueued_step: self.steps,
                });
            } else {
                self.metrics.inc_rejected();
                let _ = r
                    .resp
                    .send(Err("page pool exhausted with nothing reclaimable".to_string()));
            }
        }
    }

    /// One continuous decode step: every decodable session, one token —
    /// sessions whose prefill just completed join immediately.  Returns
    /// whether anything decoded (the autotune controller only observes
    /// steps that did).
    fn decode_step(&mut self, phases: &mut StepPhases) -> bool {
        let decodable: Vec<usize> =
            (0..self.running.len()).filter(|&i| self.running[i].decodable()).collect();
        if decodable.is_empty() {
            return false;
        }
        let results = {
            let mut refs: Vec<&mut LmSession> = self
                .running
                .iter_mut()
                .filter(|r| r.decodable())
                .map(|r| &mut r.session)
                .collect();
            self.lm.step_sessions_timed(&mut refs, self.autotune.clock_mut(), phases)
        };
        self.metrics.decode_steps.fetch_add(1, Ordering::Relaxed);

        // join/leave: record tokens, preempt the pool-starved (every
        // stepped session had >= 2 tokens to go, so none finishes here —
        // sessions reaching their last token leave through the pre-step
        // finisher path next iteration, straight from logits)
        let at = self.autotune.now_us();
        let mut starved: Vec<usize> = Vec::new();
        for (k, res) in results.iter().enumerate() {
            let i = decodable[k];
            match res {
                Ok(tok) => {
                    self.running[i].generated.push(*tok);
                    self.metrics.generated_tokens.fetch_add(1, Ordering::Relaxed);
                    Self::trace_ev(
                        &self.trace,
                        self.steps,
                        at,
                        TraceEvent::Decode { id: self.running[i].req.id, token: *tok },
                    );
                }
                Err(PoolExhausted) => starved.push(i),
            }
        }
        for &i in starved.iter().rev() {
            // mid-step pool exhaustion: caches are torn — drop them and
            // replay prompt + generated on readmission (deterministic)
            let r = self.running.remove(i);
            self.metrics.preemptions.fetch_add(1, Ordering::Relaxed);
            Self::trace_ev(
                &self.trace,
                self.steps,
                at,
                TraceEvent::Preempt { id: r.req.id, reason: PreemptReason::StarvedDecode },
            );
            self.waiting.push_front(Pending {
                req: r.req,
                resp: r.resp,
                generated: r.generated,
                admitted: true,
                streamed: r.streamed,
                enqueued_step: self.steps,
            });
        }
        true
    }

    /// The fused execution path: the step's planned prefill chunks and
    /// its decode batch run as one heterogeneous task list
    /// ([`NativeLm::fused_step`]) — no prefill→decode barrier.  All
    /// bookkeeping (chunk metrics, per-chunk prefix publication, token
    /// commits, torn/starved preemption, requeue order) mirrors
    /// [`Scheduler::run_prefill_chunks`] + [`Scheduler::decode_step`]
    /// exactly, and sessions finishing their prefill this step decode
    /// through a follow-up [`NativeLm::step_sessions`] micro-batch
    /// (batching cannot change their streams), so the fused and phased
    /// paths are bitwise interchangeable (property-tested).  Returns
    /// whether anything decoded, like [`Scheduler::decode_step`].
    fn fused_execute(&mut self, plan: &ChunkPlan, phases: &mut StepPhases) -> bool {
        let entry = |i: usize| plan.iter().find(|e| e.0 == i).copied();
        let mut torn: Vec<usize> = Vec::new();
        let mut starved: Vec<usize> = Vec::new();
        let mut job_idx: Vec<usize> = Vec::new();
        let mut dec_idx: Vec<usize> = Vec::new();
        let (pre_out, dec_out) = {
            let mut jobs: Vec<FusedPrefill<'_>> = Vec::new();
            let mut dec_refs: Vec<&mut LmSession> = Vec::new();
            for (i, r) in self.running.iter_mut().enumerate() {
                if let Some((_, take, done_after, _)) = entry(i) {
                    let Running { session, prefill, .. } = r;
                    let Some(pf) = prefill.as_ref() else { continue };
                    let from = session.len();
                    jobs.push(FusedPrefill {
                        session,
                        tokens: &pf[from..from + take],
                        with_logits: done_after,
                    });
                    job_idx.push(i);
                } else if r.decodable() {
                    dec_refs.push(&mut r.session);
                    dec_idx.push(i);
                }
            }
            self.lm.fused_step_timed(&mut jobs, &mut dec_refs, self.autotune.clock_mut(), phases)
        };
        let at = self.autotune.now_us();
        for (k, res) in pre_out.iter().enumerate() {
            let i = job_idx[k];
            match res {
                Ok(()) => {
                    let (take, reoffered) =
                        entry(i).map(|e| (e.1, e.3)).unwrap_or((0, false));
                    self.metrics.record_prefill_chunk(take);
                    Self::trace_ev(
                        &self.trace,
                        self.steps,
                        at,
                        TraceEvent::PrefillChunk {
                            id: self.running[i].req.id,
                            tokens: take as u32,
                            reoffered,
                        },
                    );
                    self.publish_completed_blocks(i);
                }
                Err(PoolExhausted) => torn.push(i),
            }
        }
        for &(i, _, done_after, _) in plan {
            if done_after && !torn.contains(&i) {
                self.running[i].prefill = None;
            }
        }
        for (k, res) in dec_out.iter().enumerate() {
            let i = dec_idx[k];
            match res {
                Ok(tok) => {
                    self.running[i].generated.push(*tok);
                    self.metrics.generated_tokens.fetch_add(1, Ordering::Relaxed);
                    Self::trace_ev(
                        &self.trace,
                        self.steps,
                        at,
                        TraceEvent::Decode { id: self.running[i].req.id, token: *tok },
                    );
                }
                Err(PoolExhausted) => starved.push(i),
            }
        }
        // sessions that finished prefill this step join the decode *this
        // step* (as in the phased path) via a follow-up micro-batch —
        // their logits only exist after the fused drain
        let mut joiners: Vec<usize> = plan
            .iter()
            .filter(|&&(i, _, done_after, _)| {
                done_after && !torn.contains(&i) && self.running[i].decodable()
            })
            .map(|e| e.0)
            .collect();
        joiners.sort_unstable();
        if !joiners.is_empty() {
            let results = {
                let mut refs: Vec<&mut LmSession> = self
                    .running
                    .iter_mut()
                    .enumerate()
                    .filter(|(i, _)| joiners.binary_search(i).is_ok())
                    .map(|(_, r)| &mut r.session)
                    .collect();
                self.lm.step_sessions_timed(&mut refs, self.autotune.clock_mut(), phases)
            };
            let at = self.autotune.now_us();
            for (k, res) in results.iter().enumerate() {
                let i = joiners[k];
                match res {
                    Ok(tok) => {
                        self.running[i].generated.push(*tok);
                        self.metrics.generated_tokens.fetch_add(1, Ordering::Relaxed);
                        Self::trace_ev(
                            &self.trace,
                            self.steps,
                            at,
                            TraceEvent::Decode { id: self.running[i].req.id, token: *tok },
                        );
                    }
                    Err(PoolExhausted) => starved.push(i),
                }
            }
        }
        let decoded = !dec_idx.is_empty() || !joiners.is_empty();
        if decoded {
            self.metrics.decode_steps.fetch_add(1, Ordering::Relaxed);
        }
        // torn/starved preemption, replicating the phased path's waiting-
        // queue order exactly: both sets were collected against the same
        // pre-removal indices, so remove the union descending (stashing by
        // category), then requeue torn first, then starved — each set
        // pushed front in descending index order so the queue reads
        // ascending, with the starved in front of the torn (the phased
        // decode sub-phase runs after the prefill sub-phase).
        starved.sort_unstable();
        torn.sort_unstable();
        let mut combined: Vec<(usize, bool)> = torn.iter().map(|&i| (i, true)).collect();
        combined.extend(starved.iter().map(|&i| (i, false)));
        combined.sort_unstable();
        let mut removed_torn: Vec<Running> = Vec::new();
        let mut removed_starved: Vec<Running> = Vec::new();
        for &(i, is_torn) in combined.iter().rev() {
            let r = self.running.remove(i);
            if is_torn {
                removed_torn.push(r);
            } else {
                removed_starved.push(r);
            }
        }
        removed_torn.reverse(); // ascending original-index order
        removed_starved.reverse();
        let starved_pending = removed_starved.len();
        let at = self.autotune.now_us();
        for (k, r) in removed_torn.into_iter().enumerate().rev() {
            // reclaimability as the phased path saw it at this torn
            // session's removal: every other session (running, earlier
            // torn, or not-yet-preempted starved) still held pages then
            let reclaimable = !self.running.is_empty()
                || k > 0
                || starved_pending > 0
                || self.cache.as_ref().map(|c| c.pages_held() > 0).unwrap_or(false);
            if reclaimable {
                self.metrics.preemptions.fetch_add(1, Ordering::Relaxed);
                Self::trace_ev(
                    &self.trace,
                    self.steps,
                    at,
                    TraceEvent::Preempt { id: r.req.id, reason: PreemptReason::TornPrefill },
                );
                self.waiting.push_front(Pending {
                    req: r.req,
                    resp: r.resp,
                    generated: r.generated,
                    admitted: true,
                    streamed: r.streamed,
                    enqueued_step: self.steps,
                });
            } else {
                self.metrics.inc_rejected();
                let _ = r
                    .resp
                    .send(Err("page pool exhausted with nothing reclaimable".to_string()));
            }
        }
        for r in removed_starved.into_iter().rev() {
            self.metrics.preemptions.fetch_add(1, Ordering::Relaxed);
            Self::trace_ev(
                &self.trace,
                self.steps,
                at,
                TraceEvent::Preempt { id: r.req.id, reason: PreemptReason::StarvedDecode },
            );
            self.waiting.push_front(Pending {
                req: r.req,
                resp: r.resp,
                generated: r.generated,
                admitted: true,
                streamed: r.streamed,
                enqueued_step: self.steps,
            });
        }
        decoded
    }

    fn publish_gauges(&self) {
        let live_budget = self.autotune.current() as u64;
        self.metrics.autotuned_chunk_tokens.store(live_budget, Ordering::Relaxed);
        self.metrics
            .compressed_pages
            .store(self.pool.compressed_pages_in_use() as u64, Ordering::Relaxed);
        self.metrics.pool_bytes_in_use.store(self.pool.bytes_in_use() as u64, Ordering::Relaxed);
        let decoding = self.running.iter().filter(|r| r.prefill.is_none()).count() as u64;
        self.metrics.peak_decoding_sessions.fetch_max(decoding, Ordering::Relaxed);
        let prefilling = self.running.iter().filter(|r| r.prefill.is_some()).count() as u64;
        let backlog: u64 = self
            .running
            .iter()
            .filter_map(|r| r.prefill.as_ref().map(|p| (p.len() - r.session.len()) as u64))
            .sum();
        self.metrics.set_session_gauges(
            self.pool.free_pages() as u64,
            self.cache.as_ref().map(|c| c.pages_held()).unwrap_or(0) as u64,
            self.running.len() as u64,
            self.waiting.len() as u64,
            prefilling,
            backlog,
        );
    }

    /// Structural self-check of the whole serving state, for the
    /// verification layer (DESIGN.md §11).  Composes the page pool's and
    /// radix cache's own checkers, then verifies the scheduler-level
    /// invariants.  Returns `Err` describing the first violation:
    ///
    /// * **sub-checkers** — [`PagePool::verify`] (buffer conservation,
    ///   capacity arithmetic) and [`RadixCache::verify`] (edge alignment,
    ///   LRU/tree consistency, handle accounting);
    /// * **no poisoned survivors** — a session poisoned by mid-step or
    ///   mid-chunk [`PoolExhausted`] must never outlive the step that
    ///   poisoned it (it is preempted whole and replayed);
    /// * **page and byte conservation** — the scheduler is the pool's
    ///   only client, so the distinct physical pages reachable from the
    ///   running sessions and the radix cache equal `pages_in_use`
    ///   exactly (no leak, no double-count) and their format-weighted
    ///   bytes equal `bytes_in_use`; `in_use + free == total_pages`
    ///   holds exactly in the all-f32 state and relaxes to `>=` while
    ///   compressed pages are live (DESIGN.md §15);
    /// * **queue sanity** — responders are structurally present on every
    ///   queued/running request (non-optional fields — checked here by
    ///   construction); admission stamps are unique and within the
    ///   counter; running sessions are within `seq_len`, unfinished, and
    ///   phase-consistent (prefill cursor inside the replay prompt;
    ///   decode phase has logits to emit); never-admitted waiters carry
    ///   no generated tokens;
    /// * **draw-count coherence** — a stochastic session has consumed
    ///   exactly one RNG draw per generated token (the replay-safety
    ///   contract: a readmitted session's fast-forwarded counter lands on
    ///   the same value), and a greedy session has consumed none;
    /// * **stream cursors** — never past the generated sequence, on
    ///   running sessions and preempted waiters alike (a cursor beyond
    ///   `generated` would mean a token was streamed that was never
    ///   generated — or would double-stream after replay).
    pub(crate) fn verify(&self) -> Result<(), String> {
        self.pool.verify().map_err(|e| format!("page pool: {e}"))?;
        if let Some(c) = self.cache.as_ref() {
            c.verify().map_err(|e| format!("radix cache: {e}"))?;
        }
        for r in &self.running {
            if r.session.is_poisoned() {
                return Err(format!(
                    "request {}: poisoned session retained in the running set",
                    r.req.id
                ));
            }
        }
        let mut seen: HashSet<usize> = HashSet::new();
        let mut reachable_bytes: usize = 0;
        for r in &self.running {
            for st in r.session.states() {
                for p in st.pages() {
                    if seen.insert(Arc::as_ptr(p) as usize) {
                        reachable_bytes += p.bytes();
                    }
                }
            }
        }
        if let Some(c) = self.cache.as_ref() {
            c.for_each_page(&mut |p| {
                if seen.insert(Arc::as_ptr(p) as usize) {
                    reachable_bytes += p.bytes();
                }
            });
        }
        // byte conservation first: with mixed formats the page count can
        // match while the per-format byte ledger drifts (e.g. a page
        // demoted without its byte delta applied) — the finer check must
        // fire before the coarser one masks it
        if reachable_bytes != self.pool.bytes_in_use() {
            return Err(format!(
                "byte conservation violated: {} byte(s) reachable from sessions \
                 + cache, but the pool reports {} in use",
                reachable_bytes,
                self.pool.bytes_in_use()
            ));
        }
        if seen.len() != self.pool.pages_in_use() {
            return Err(format!(
                "page conservation violated: {} distinct page(s) reachable from \
                 sessions + cache, but the pool reports {} in use",
                seen.len(),
                self.pool.pages_in_use()
            ));
        }
        // `free_pages` is denominated in f32-page units off the byte
        // ledger, so with compressed pages live the pool can hold *more*
        // than `total_pages` worth of slots; equality is only exact in
        // the all-f32 state
        if self.pool.compressed_pages_in_use() == 0 {
            if self.pool.pages_in_use() + self.pool.free_pages() != self.scfg.total_pages {
                return Err(format!(
                    "page arithmetic violated: in_use {} + free {} != total_pages {}",
                    self.pool.pages_in_use(),
                    self.pool.free_pages(),
                    self.scfg.total_pages
                ));
            }
        } else if self.pool.pages_in_use() + self.pool.free_pages() < self.scfg.total_pages {
            return Err(format!(
                "page arithmetic violated: in_use {} + free {} < total_pages {} \
                 with {} compressed page(s) live",
                self.pool.pages_in_use(),
                self.pool.free_pages(),
                self.scfg.total_pages,
                self.pool.compressed_pages_in_use()
            ));
        }
        if self.metrics.pool_pages.load(Ordering::Relaxed) != self.scfg.total_pages as u64 {
            return Err("pool_pages gauge does not match the configured pool size".into());
        }
        let mut stamps: HashSet<u64> = HashSet::new();
        for r in &self.running {
            if r.admitted_at == 0 || r.admitted_at > self.admit_stamp {
                return Err(format!(
                    "request {}: admission stamp {} outside 1..={}",
                    r.req.id, r.admitted_at, self.admit_stamp
                ));
            }
            if !stamps.insert(r.admitted_at) {
                return Err(format!(
                    "request {}: duplicate admission stamp {}",
                    r.req.id, r.admitted_at
                ));
            }
            if r.session.len() > self.seq_len {
                return Err(format!(
                    "request {}: session length {} exceeds seq_len {}",
                    r.req.id,
                    r.session.len(),
                    self.seq_len
                ));
            }
            if r.generated.len() >= r.target_tokens() {
                return Err(format!(
                    "request {}: finished session ({} of {} tokens) still running",
                    r.req.id,
                    r.generated.len(),
                    r.target_tokens()
                ));
            }
            let want_draws =
                if r.req.sampling.is_greedy() { 0 } else { r.generated.len() as u64 };
            if r.session.draws() != want_draws {
                return Err(format!(
                    "request {}: draw-count incoherence — session consumed {} RNG \
                     draw(s) but {} generated token(s) require exactly {} (replay \
                     would diverge)",
                    r.req.id,
                    r.session.draws(),
                    r.generated.len(),
                    want_draws
                ));
            }
            if r.streamed > r.generated.len() {
                return Err(format!(
                    "request {}: stream cursor {} past the {} generated token(s)",
                    r.req.id,
                    r.streamed,
                    r.generated.len()
                ));
            }
            match r.prefill.as_ref() {
                Some(p) => {
                    if r.session.len() > p.len() {
                        return Err(format!(
                            "request {}: prefill cursor {} past the {}-token replay prompt",
                            r.req.id,
                            r.session.len(),
                            p.len()
                        ));
                    }
                    if p.len() != r.req.tokens.len() + r.generated.len() {
                        return Err(format!(
                            "request {}: replay prompt of {} tokens != request {} + generated {}",
                            r.req.id,
                            p.len(),
                            r.req.tokens.len(),
                            r.generated.len()
                        ));
                    }
                }
                None => {
                    if r.session.logits().is_empty() {
                        return Err(format!(
                            "request {}: decode-phase session with no logits",
                            r.req.id
                        ));
                    }
                    if r.session.len() < r.req.tokens.len() {
                        return Err(format!(
                            "request {}: decode-phase session shorter than its prompt",
                            r.req.id
                        ));
                    }
                }
            }
        }
        for p in &self.waiting {
            if !p.admitted && !p.generated.is_empty() {
                return Err(format!(
                    "request {}: never-admitted waiter carries {} generated token(s)",
                    p.req.id,
                    p.generated.len()
                ));
            }
            if p.streamed > p.generated.len() {
                return Err(format!(
                    "request {}: waiting stream cursor {} past the {} generated token(s)",
                    p.req.id,
                    p.streamed,
                    p.generated.len()
                ));
            }
        }
        Ok(())
    }

    /// Assert [`Scheduler::verify`] under `debug_assertions` or the
    /// `paranoid` feature; compiled to a no-op in plain release builds,
    /// so the serving hot loop pays nothing.  Every serving test runs
    /// debug, so every scheduler step of every test is checked.
    #[track_caller]
    pub(crate) fn check_invariants(&self) {
        if cfg!(any(debug_assertions, feature = "paranoid")) {
            if let Err(msg) = self.verify() {
                panic!("Scheduler invariant violated: {msg}");
            }
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::config::SamplingParams;
    use crate::coordinator::batcher::PRIORITY_NORMAL;
    use crate::coordinator::native::NativeMlmConfig;
    use std::sync::mpsc::{channel, sync_channel, SyncSender};
    use std::time::Duration;

    fn small_cfg() -> NativeMlmConfig {
        NativeMlmConfig {
            vocab: 64,
            seq_len: 64,
            d_model: 32,
            heads: 2,
            layers: 1,
            block: 16,
            budget: 0,
            attention: "mra2".to_string(),
            seed: 7,
        }
    }

    /// `small_cfg` with room for a 200-token prompt (the re-offer
    /// regression needs a prompt much longer than one step's budget).
    fn wide_cfg() -> NativeMlmConfig {
        NativeMlmConfig { seq_len: 256, ..small_cfg() }
    }

    fn spawn_scheduler(
        scfg: SessionConfig,
    ) -> (SyncSender<Ingress>, Arc<NativeLm>, Arc<Metrics>, std::thread::JoinHandle<()>) {
        let lm = Arc::new(NativeLm::new(small_cfg(), 2));
        let metrics = Arc::new(Metrics::new());
        let (tx, rx) = sync_channel::<Ingress>(64);
        let (lm2, m2) = (lm.clone(), metrics.clone());
        let handle = std::thread::spawn(move || scheduler_loop(rx, lm2, scfg, m2, None));
        (tx, lm, metrics, handle)
    }

    fn send_req(
        tx: &SyncSender<Ingress>,
        id: u64,
        prompt: Vec<i32>,
        gen: usize,
    ) -> std::sync::mpsc::Receiver<Result<Response, String>> {
        send_req_cfg(tx, Request::new(id, prompt, gen))
    }

    /// `send_req` for a caller-built request (priority / deadline /
    /// sampling / stream fields set).
    fn send_req_cfg(
        tx: &SyncSender<Ingress>,
        req: Request,
    ) -> std::sync::mpsc::Receiver<Result<Response, String>> {
        let (rtx, rrx) = channel();
        tx.send(Ingress::Req(req, rtx)).unwrap();
        rrx
    }

    fn prompt(seed: usize, len: usize) -> Vec<i32> {
        (0..len).map(|i| (2 + (seed * 13 + i * 7) % 60) as i32).collect()
    }

    /// A `Pending` waiting-queue entry for direct `pick_waiting` /
    /// `expire_deadlines` unit tests.
    fn pending_entry(id: u64, priority: u8, enqueued_step: u64) -> Pending {
        let (rtx, rrx) = channel();
        std::mem::forget(rrx); // keep the responder sendable
        Pending {
            req: Request { priority, ..Request::new(id, vec![2, 3], 2) },
            resp: rtx,
            generated: Vec::new(),
            admitted: false,
            streamed: 0,
            enqueued_step,
        }
    }

    /// A `Running` entry for direct injection into a scheduler under
    /// test (invariant negative tests corrupt state deliberately).
    fn running_entry(id: u64, tokens: Vec<i32>, session: LmSession, admitted_at: u64) -> Running {
        let (rtx, rrx) = channel();
        std::mem::forget(rrx); // keep the responder sendable
        let prefill = Some(tokens.clone());
        Running {
            req: Request::new(id, tokens, 4),
            resp: rtx,
            session,
            generated: Vec::new(),
            prefill,
            admitted_at,
            streamed: 0,
        }
    }

    #[test]
    fn continuous_sessions_match_direct_generation_bitwise() {
        let scfg = SessionConfig { total_pages: 512, free_watermark: 8, ..Default::default() };
        let (tx, lm, metrics, handle) = spawn_scheduler(scfg);
        let cases: Vec<(Vec<i32>, usize)> = (0..6)
            .map(|i| (prompt(i, 4 + i * 9 % 40), 3 + i % 5))
            .collect();
        let receivers: Vec<_> = cases
            .iter()
            .enumerate()
            .map(|(i, (p, g))| send_req(&tx, i as u64, p.clone(), *g))
            .collect();
        for ((p, g), rx) in cases.iter().zip(receivers) {
            let resp = rx.recv().unwrap().expect("scheduler response");
            let want = lm.generate(p, *g).unwrap();
            assert_eq!(resp.predictions, want, "continuous decode diverged from generate()");
        }
        tx.send(Ingress::Shutdown).unwrap();
        drop(tx);
        handle.join().unwrap();
        assert_eq!(metrics.sessions.load(Ordering::Relaxed) as usize, 6);
        assert!(metrics.decode_steps.load(Ordering::Relaxed) > 0);
        assert!(metrics.prefill_chunks.load(Ordering::Relaxed) >= 6, "{}", metrics.summary());
    }

    #[test]
    fn shared_prompts_hit_the_prefix_cache() {
        let scfg = SessionConfig { total_pages: 512, free_watermark: 8, ..Default::default() };
        let (tx, lm, metrics, handle) = spawn_scheduler(scfg);
        let shared = prompt(0, 33); // 2 cacheable blocks at block=16
        let r1 = send_req(&tx, 0, shared.clone(), 4);
        let first = r1.recv().unwrap().expect("first response");
        // second identical prompt after the first finished: guaranteed hit
        let r2 = send_req(&tx, 1, shared.clone(), 4);
        let second = r2.recv().unwrap().expect("second response");
        assert_eq!(first.predictions, second.predictions, "cache hit changed the output");
        assert_eq!(first.predictions, lm.generate(&shared, 4).unwrap());
        tx.send(Ingress::Shutdown).unwrap();
        handle.join().unwrap();
        assert!(
            metrics.prefix_hit_tokens.load(Ordering::Relaxed) >= 32,
            "second session must reuse the cached prompt blocks: {}",
            metrics.summary()
        );
    }

    #[test]
    fn tight_pool_preempts_and_recompute_on_readmit_is_lossless() {
        // streams = 2, block = 16.  prompt 16 + gen 6 => lifetime estimate
        // 2 * ceil(22/16) = 4 pages.  With a 10-page pool and no watermark,
        // admission over-commits: 5 sessions admitted (opening a session
        // is free), but their first-step prefill chunks demand 2 pages
        // each — the plan/reserve loop must preempt the youngest sessions,
        // and their replay on readmission must reproduce the exact same
        // tokens.  Requests are enqueued *before* the scheduler thread
        // starts so the admission sequence is deterministic.
        let scfg = SessionConfig {
            total_pages: 10,
            free_watermark: 0,
            max_running: 8,
            prefix_cache: false,
            prefill_chunk_tokens: 256,
            ..Default::default()
        };
        let lm = Arc::new(NativeLm::new(small_cfg(), 2));
        let metrics = Arc::new(Metrics::new());
        let (tx, rx) = sync_channel::<Ingress>(64);
        let cases: Vec<(Vec<i32>, usize)> = (0..5).map(|i| (prompt(i, 16), 6)).collect();
        let receivers: Vec<_> = cases
            .iter()
            .enumerate()
            .map(|(i, (p, g))| send_req(&tx, i as u64, p.clone(), *g))
            .collect();
        let (lm2, m2) = (lm.clone(), metrics.clone());
        let handle = std::thread::spawn(move || scheduler_loop(rx, lm2, scfg, m2, None));
        for ((p, g), rxr) in cases.iter().zip(receivers) {
            let resp = rxr.recv().unwrap().expect("response under memory pressure");
            assert_eq!(
                resp.predictions,
                lm.generate(p, *g).unwrap(),
                "preemption/readmit changed the output"
            );
        }
        tx.send(Ingress::Shutdown).unwrap();
        handle.join().unwrap();
        assert!(
            metrics.preemptions.load(Ordering::Relaxed) >= 1,
            "the 10-page pool must force at least one preemption: {}",
            metrics.summary()
        );
        // readmissions re-prefill, so admitted sessions > request count
        assert!(metrics.sessions.load(Ordering::Relaxed) > 5, "{}", metrics.summary());
        assert_eq!(metrics.pool_pages.load(Ordering::Relaxed), 10);
    }

    #[test]
    fn long_prompt_prefills_in_chunks_alongside_decodes() {
        // prefill budget of one block: the 48-token prompt must take
        // several steps of chunked prefill while the short session's
        // decode keeps stepping — with the monolithic prefill this was a
        // single inline stall and prefill_chunks stayed 0/1.  Requests
        // (and nothing else) are enqueued before the scheduler starts, so
        // the chunk accounting is exact.
        let scfg = SessionConfig {
            total_pages: 512,
            free_watermark: 0,
            max_running: 8,
            prefix_cache: false,
            prefill_chunk_tokens: 16,
            ..Default::default()
        };
        let lm = Arc::new(NativeLm::new(small_cfg(), 2));
        let metrics = Arc::new(Metrics::new());
        let (tx, rx) = sync_channel::<Ingress>(64);
        let short = prompt(0, 4);
        let long = prompt(1, 48);
        let ra = send_req(&tx, 0, short.clone(), 12);
        let rb = send_req(&tx, 1, long.clone(), 3);
        let (lm2, m2) = (lm.clone(), metrics.clone());
        let handle = std::thread::spawn(move || scheduler_loop(rx, lm2, scfg, m2, None));
        let a = ra.recv().unwrap().expect("short response");
        let b = rb.recv().unwrap().expect("long response");
        assert_eq!(a.predictions, lm.generate(&short, 12).unwrap(), "interleaving changed output");
        assert_eq!(b.predictions, lm.generate(&long, 3).unwrap(), "chunked prefill changed output");
        tx.send(Ingress::Shutdown).unwrap();
        handle.join().unwrap();
        // short prefills in 1 chunk; the long prompt needs >= 3 chunks of
        // <= 16 tokens, spread across steps that also decoded the short
        // session (no inline full-prompt prefill)
        let chunks = metrics.prefill_chunks.load(Ordering::Relaxed);
        let tokens = metrics.prefill_tokens.load(Ordering::Relaxed);
        assert!(chunks >= 4, "long prompt must prefill chunked: {}", metrics.summary());
        assert_eq!(tokens, 4 + 48, "every prompt token prefilled exactly once");
        assert!(
            metrics.decode_steps.load(Ordering::Relaxed) >= 11,
            "decodes must run alongside the chunked prefill: {}",
            metrics.summary()
        );
    }

    #[test]
    fn shutdown_with_waiting_queue_errors_every_pending_requester() {
        // §bugfix regression: shutting down with requests still in the
        // waiting queue used to drop their responders — the clients hung
        // forever on recv().  Requests and the shutdown are enqueued
        // before the scheduler thread starts, so both requests are
        // guaranteed to still be waiting when the shutdown is observed.
        let scfg = SessionConfig { total_pages: 64, free_watermark: 4, ..Default::default() };
        let lm = Arc::new(NativeLm::new(small_cfg(), 1));
        let metrics = Arc::new(Metrics::new());
        let (tx, rx) = sync_channel::<Ingress>(64);
        let r1 = send_req(&tx, 7, prompt(0, 8), 4);
        let r2 = send_req(&tx, 8, prompt(1, 8), 4);
        tx.send(Ingress::Shutdown).unwrap();
        let (lm2, m2) = (lm.clone(), metrics.clone());
        let handle = std::thread::spawn(move || scheduler_loop(rx, lm2, scfg, m2, None));
        let e1 = r1.recv().expect("responder must not be dropped").unwrap_err();
        let e2 = r2.recv().expect("responder must not be dropped").unwrap_err();
        assert!(e1.contains("shutting down") && e1.contains('7'), "{e1}");
        assert!(e2.contains("shutting down") && e2.contains('8'), "{e2}");
        handle.join().unwrap();
        assert_eq!(metrics.rejected.load(Ordering::Relaxed), 2);
        assert_eq!(metrics.sessions.load(Ordering::Relaxed), 0, "nothing was admitted");
    }

    #[test]
    fn oversized_and_empty_requests_fail_cleanly_without_wedging() {
        let scfg = SessionConfig { total_pages: 64, free_watermark: 4, ..Default::default() };
        let (tx, lm, _metrics, handle) = spawn_scheduler(scfg);
        let too_long = send_req(&tx, 0, prompt(0, 60), 8); // 60 + 8 > 64
        let empty = send_req(&tx, 1, Vec::new(), 4);
        let ok = send_req(&tx, 2, prompt(2, 6), 3);
        assert!(too_long.recv().unwrap().unwrap_err().contains("seq_len"));
        assert!(empty.recv().unwrap().unwrap_err().contains("empty"));
        let resp = ok.recv().unwrap().expect("well-formed request still served");
        assert_eq!(resp.predictions, lm.generate(&prompt(2, 6), 3).unwrap());
        tx.send(Ingress::Shutdown).unwrap();
        handle.join().unwrap();
    }

    #[test]
    fn request_larger_than_the_pool_is_rejected_not_queued_forever() {
        let scfg = SessionConfig {
            total_pages: 4,
            free_watermark: 2,
            max_running: 4,
            prefix_cache: true,
            prefill_chunk_tokens: 256,
            ..Default::default()
        };
        let (tx, _lm, _metrics, handle) = spawn_scheduler(scfg);
        // est = 2 streams * ceil(48/16) = 6 pages > 4 - watermark
        let rx = send_req(&tx, 0, prompt(0, 40), 8);
        let err = rx.recv().unwrap().unwrap_err();
        assert!(err.contains("total_pages"), "{err}");
        tx.send(Ingress::Shutdown).unwrap();
        handle.join().unwrap();
    }

    /// Drive a full request lifecycle step by step and re-verify all
    /// three invariant checkers after every single step (on top of the
    /// `check_invariants` call `step` itself makes) — admission,
    /// chunked prefill, decode, finish and shutdown all leave the pool,
    /// the cache and the queues consistent.
    #[test]
    fn invariants_hold_after_every_step_of_a_served_request() {
        let scfg = SessionConfig {
            total_pages: 64,
            free_watermark: 0,
            max_running: 4,
            prefix_cache: true,
            prefill_chunk_tokens: 16,
            ..Default::default()
        };
        let lm = Arc::new(NativeLm::new(small_cfg(), 1));
        let metrics = Arc::new(Metrics::new());
        let mut sched = Scheduler::new(lm.clone(), scfg, metrics);
        sched.verify().expect("fresh scheduler");
        let (tx, rx) = sync_channel::<Ingress>(8);
        let p = prompt(0, 36);
        let rresp = send_req(&tx, 0, p.clone(), 5);
        let mut steps = 0;
        let resp = loop {
            assert!(sched.step(&rx), "loop must stay live while work remains");
            sched.verify().unwrap_or_else(|e| panic!("after step {steps}: {e}"));
            steps += 1;
            assert!(steps < 100, "request did not finish");
            if let Ok(resp) = rresp.try_recv() {
                break resp.expect("served response");
            }
        };
        assert_eq!(resp.predictions, lm.generate(&p, 5).unwrap());
        assert!(steps >= 3, "36-token prompt at chunk 16 must take multiple steps");
        tx.send(Ingress::Shutdown).unwrap();
        assert!(sched.step(&rx), "shutdown observation is one more step");
        assert!(!sched.step(&rx), "drained scheduler must exit");
        sched.verify().expect("post-shutdown state");
    }

    /// The scheduler-level checker must catch seeded corruption: a page
    /// leaked outside the session/cache reachability set, and duplicate
    /// admission stamps.  (The sub-checkers' own negative cases live in
    /// the page/radix test suites.)
    #[test]
    fn verify_reports_seeded_scheduler_corruption() {
        let scfg = SessionConfig {
            total_pages: 64,
            free_watermark: 0,
            max_running: 4,
            prefix_cache: false,
            prefill_chunk_tokens: 64,
            ..Default::default()
        };
        let lm = Arc::new(NativeLm::new(small_cfg(), 1));
        let mut sched = Scheduler::new(lm.clone(), scfg, Arc::new(Metrics::new()));
        assert!(sched.verify().is_ok());
        // (a) a page allocated behind the scheduler's back is a leak:
        // reachable from neither a session nor the cache
        let hog = sched.pool.try_alloc().unwrap();
        let msg = sched.verify().unwrap_err();
        assert!(msg.contains("conservation"), "{msg}");
        drop(hog);
        assert!(sched.verify().is_ok());
        // (b) a page registered in the pool's ledger but reachable from
        // nowhere drifts the byte ledger — the finer byte-conservation
        // check must name the violation (the pool's own checker stays
        // green because its internal accounting is self-consistent)
        sched.pool.register_phantom_page_for_test();
        assert!(sched.pool.verify().is_ok(), "pool ledger must stay self-consistent");
        let msg = sched.verify().unwrap_err();
        assert!(msg.contains("byte conservation"), "{msg}");
        sched.pool.unregister_phantom_page_for_test();
        assert!(sched.verify().is_ok());
        // (c) duplicate admission stamps break preemption's youngest-first
        // ordering
        let s1 = lm.begin_session(&prompt(0, 8), &sched.pool, None).unwrap();
        let s2 = lm.begin_session(&prompt(1, 8), &sched.pool, None).unwrap();
        sched.admit_stamp = 1;
        sched.running.push(running_entry(0, prompt(0, 8), s1, 1));
        assert!(sched.verify().is_ok());
        sched.running.push(running_entry(1, prompt(1, 8), s2, 1));
        let msg = sched.verify().unwrap_err();
        assert!(msg.contains("stamp"), "{msg}");
    }

    /// Pressure-driven demotion, end to end.  Three sessions with
    /// 2-block prompts decode across a third block boundary: at len 48
    /// every session needs a fresh page per stream at once, against an
    /// 18-page pool already fully committed.  Under `[sessions]
    /// page_format = "bf16"` the scheduler compresses cold pages and
    /// serves all three without preempting; the identical workload under
    /// pure f32 must preempt.  Every step re-runs `Scheduler::verify`,
    /// so the byte-conservation and relaxed page-arithmetic invariants
    /// are exercised with compressed pages live.  (No bitwise output
    /// check: compressed KV is an approximation — the accuracy contract
    /// is the decode-level error-budget proptest.)
    #[test]
    fn demotion_relieves_pressure_before_preemption() {
        let run = |page_format: &str| {
            let scfg = SessionConfig {
                total_pages: 18,
                free_watermark: 0,
                max_running: 8,
                prefix_cache: false,
                prefill_chunk_tokens: 256,
                page_format: page_format.to_string(),
                ..Default::default()
            };
            let lm = Arc::new(NativeLm::new(small_cfg(), 2));
            let metrics = Arc::new(Metrics::new());
            let trace = Arc::new(FlightRecorder::new(256));
            let mut sched = Scheduler::with_trace(
                lm,
                scfg,
                metrics.clone(),
                Box::new(MonotonicClock::default()),
                Some(trace.clone()),
            );
            let (tx, rx) = sync_channel::<Ingress>(8);
            // prompt 32 + gen 18 ends at len 50: the 17th append crosses
            // the len-48 block boundary in lockstep across all sessions
            let receivers: Vec<_> =
                (0..3).map(|i| send_req(&tx, i as u64, prompt(i, 32), 18)).collect();
            tx.send(Ingress::Shutdown).unwrap();
            let mut steps = 0;
            while sched.step(&rx) {
                sched.verify().unwrap_or_else(|e| panic!("after step {steps}: {e}"));
                steps += 1;
                assert!(steps < 400, "workload did not drain");
            }
            for rrx in receivers {
                let resp = rrx.recv().unwrap().unwrap_or_else(|e| panic!("served response: {e}"));
                assert_eq!(resp.predictions.len(), 18, "accepted means served, in full");
            }
            (metrics, trace)
        };
        let (m_bf16, t_bf16) = run("bf16");
        assert!(
            m_bf16.demotions.load(Ordering::Relaxed) >= 6,
            "pressure must demote cold pages: {}",
            m_bf16.summary()
        );
        assert_eq!(
            m_bf16.preemptions.load(Ordering::Relaxed),
            0,
            "demotion must keep preemption a last resort: {}",
            m_bf16.summary()
        );
        assert_eq!(m_bf16.peak_decoding_sessions.load(Ordering::Relaxed), 3, "all resident");
        assert!(
            t_bf16
                .records()
                .iter()
                .any(|r| matches!(r.event, TraceEvent::PageDemote { pages, .. } if pages > 0)),
            "each demotion pass must leave a PageDemote trace record"
        );
        let (m_f32, t_f32) = run("f32");
        assert_eq!(m_f32.demotions.load(Ordering::Relaxed), 0, "f32 target disables demotion");
        assert!(
            m_f32.preemptions.load(Ordering::Relaxed) >= 1,
            "the same workload must preempt without demotion: {}",
            m_f32.summary()
        );
        assert!(
            !t_f32.records().iter().any(|r| matches!(r.event, TraceEvent::PageDemote { .. })),
            "no demotion records under pure f32"
        );
    }

    /// Poisoned-session recovery, end to end: a session poisoned by
    /// mid-step pool exhaustion (1) reports `is_poisoned`, (2) is
    /// rejected by `Scheduler::verify` if it ever survives a step, and
    /// (3) after being discarded, a replay of the same prompt on a
    /// healthy pool reproduces `generate()`'s tokens bitwise — the
    /// discard-and-replay contract the preemption paths rely on.
    #[test]
    fn poisoned_session_is_rejected_by_invariants_and_replays_bitwise() {
        let scfg = SessionConfig {
            total_pages: 2,
            free_watermark: 0,
            max_running: 4,
            prefix_cache: false,
            prefill_chunk_tokens: 256,
            ..Default::default()
        };
        let lm = Arc::new(NativeLm::new(small_cfg(), 1));
        let mut sched = Scheduler::new(lm.clone(), scfg, Arc::new(Metrics::new()));
        // prompt of exactly one block: prefill fits the 2-page pool
        // (one page per stream), the first decode append needs a fresh
        // block per stream and must exhaust mid-step
        let p = prompt(0, 16);
        let mut session = lm.new_session(&p, &sched.pool, None).unwrap();
        sched.pool.check_invariants();
        let err = lm.session_step(&mut session).unwrap_err();
        assert!(format!("{err:#}").contains("pool exhausted"), "{err:#}");
        assert!(session.is_poisoned(), "mid-step exhaustion must poison the session");
        // (2) a poisoned session surviving in the running set is an
        // invariant violation, not a tolerated state
        sched.admit_stamp = 1;
        sched.running.push(running_entry(0, p.clone(), session, 1));
        let msg = sched.verify().unwrap_err();
        assert!(msg.contains("poisoned"), "{msg}");
        // (3) discard (pages return to the pool) and replay losslessly
        sched.running.clear();
        sched.verify().expect("discarding the poisoned session restores consistency");
        assert_eq!(sched.pool.pages_in_use(), 0, "poisoned session's pages must return");
        let healthy = lm.new_page_pool(64);
        let mut replay = lm.new_session(&p, &healthy, None).unwrap();
        let mut got = Vec::new();
        for _ in 0..4 {
            got.push(lm.session_step(&mut replay).unwrap());
        }
        assert_eq!(got, lm.generate(&p, 5).unwrap()[..4], "replay diverged after poisoning");
        // mid-chunk poisoning carries the same contract
        let tiny = lm.new_page_pool(1);
        let mut torn = lm.begin_session(&p, &tiny, None).unwrap();
        assert_eq!(lm.prefill_chunk(&mut torn, &p, true).unwrap_err(), PoolExhausted);
        assert!(torn.is_poisoned(), "mid-chunk exhaustion must poison the session");
    }

    // ---- streaming, sampling and QoS --------------------------------

    #[test]
    fn streaming_delivers_exactly_the_response_tokens_in_order() {
        let scfg = SessionConfig { total_pages: 512, free_watermark: 8, ..Default::default() };
        let (tx, lm, metrics, handle) = spawn_scheduler(scfg);
        let p = prompt(0, 8);
        let (stx, srx) = sync_channel::<i32>(64);
        let rx = send_req_cfg(&tx, Request { stream: Some(stx), ..Request::new(0, p.clone(), 6) });
        // the sender drops when the request finishes, ending the iterator
        let streamed: Vec<i32> = srx.iter().collect();
        let resp = rx.recv().unwrap().expect("streamed response");
        assert_eq!(streamed, resp.predictions, "stream must carry the full sequence, in order");
        assert_eq!(resp.predictions, lm.generate(&p, 6).unwrap(), "streaming changed the output");
        tx.send(Ingress::Shutdown).unwrap();
        handle.join().unwrap();
        assert_eq!(metrics.streamed_tokens.load(Ordering::Relaxed), 6, "{}", metrics.summary());
    }

    #[test]
    fn priority_orders_service_under_a_serial_bottleneck() {
        // max_running = 1 serializes service; all three requests are
        // queued before the first step, so completion order is exactly
        // admission order.  FIFO would serve 0, 1, 2 — priority must
        // serve 2 (high), 1 (normal), 0 (low).
        let scfg = SessionConfig {
            total_pages: 512,
            free_watermark: 0,
            max_running: 1,
            prefix_cache: false,
            prefill_chunk_tokens: 256,
            ..Default::default()
        };
        let lm = Arc::new(NativeLm::new(small_cfg(), 1));
        let mut sched = Scheduler::new(lm, scfg, Arc::new(Metrics::new()));
        let (tx, rx) = sync_channel::<Ingress>(8);
        let low = send_req_cfg(&tx, Request { priority: 10, ..Request::new(0, prompt(0, 8), 3) });
        let norm = send_req_cfg(&tx, Request::new(1, prompt(1, 8), 3));
        let high = send_req_cfg(&tx, Request { priority: 200, ..Request::new(2, prompt(2, 8), 3) });
        let mut order: Vec<u64> = Vec::new();
        for _ in 0..100 {
            if order.len() == 3 {
                break;
            }
            assert!(sched.step(&rx), "work remains");
            for (id, rxr) in [(0u64, &low), (1, &norm), (2, &high)] {
                if let Ok(resp) = rxr.try_recv() {
                    resp.expect("served");
                    order.push(id);
                }
            }
        }
        assert_eq!(order, vec![2, 1, 0], "service order must follow priority, not FIFO");
        tx.send(Ingress::Shutdown).unwrap();
        while sched.step(&rx) {}
    }

    #[test]
    fn aging_lifts_a_starved_low_priority_request_over_fresh_arrivals() {
        let scfg = SessionConfig { aging_steps: 4, ..Default::default() };
        let lm = Arc::new(NativeLm::new(small_cfg(), 1));
        let mut sched = Scheduler::new(lm, scfg, Arc::new(Metrics::new()));
        // not yet aged past a fresh normal-priority arrival
        sched.steps = 36; // low has waited 36 steps: boost 36/4 = 9 -> 99
        sched.waiting.push_back(pending_entry(0, 90, 0));
        sched.waiting.push_back(pending_entry(1, PRIORITY_NORMAL, 36));
        assert_eq!(sched.pick_waiting(), Some(1), "priority still outranks a young wait");
        // 8 steps later the boost reaches +11 -> 101 > any fresh normal
        sched.waiting.clear();
        sched.steps = 44;
        sched.waiting.push_back(pending_entry(0, 90, 0));
        sched.waiting.push_back(pending_entry(1, PRIORITY_NORMAL, 44));
        assert_eq!(sched.pick_waiting(), Some(0), "aging must lift the starved request");
        // a preempted (admitted) session resumes before any fresh arrival,
        // regardless of priority — accepted means served
        let mut preempted = pending_entry(2, 0, 44);
        preempted.admitted = true;
        sched.waiting.push_back(preempted);
        assert_eq!(sched.pick_waiting(), Some(2), "preempted sessions resume first");
        // exact ties fall back to queue order (earlier enqueue step wins)
        sched.waiting.clear();
        sched.waiting.push_back(pending_entry(3, PRIORITY_NORMAL, 40));
        sched.waiting.push_back(pending_entry(4, PRIORITY_NORMAL, 38));
        assert_eq!(sched.pick_waiting(), Some(1), "FIFO breaks exact priority ties");
    }

    #[test]
    fn deadline_expires_only_never_admitted_waiters() {
        // max_running = 1: request 0 is admitted first (FIFO tie-break),
        // request 1 with a zero TTL can never be admitted before its
        // deadline check and must be answered with a descriptive error.
        let scfg = SessionConfig {
            total_pages: 512,
            free_watermark: 0,
            max_running: 1,
            prefix_cache: false,
            prefill_chunk_tokens: 256,
            ..Default::default()
        };
        let lm = Arc::new(NativeLm::new(small_cfg(), 1));
        let metrics = Arc::new(Metrics::new());
        let mut sched = Scheduler::new(lm.clone(), scfg, metrics.clone());
        let (tx, rx) = sync_channel::<Ingress>(8);
        let ra = send_req(&tx, 0, prompt(0, 8), 4);
        let rb = send_req_cfg(
            &tx,
            Request { deadline: Some(Duration::ZERO), ..Request::new(1, prompt(1, 8), 4) },
        );
        let mut served = None;
        for _ in 0..100 {
            assert!(sched.step(&rx), "work remains");
            if let Ok(resp) = ra.try_recv() {
                served = Some(resp.expect("undeadlined request served"));
                break;
            }
        }
        let served = served.expect("request 0 must finish");
        assert_eq!(served.predictions, lm.generate(&prompt(0, 8), 4).unwrap());
        let err = rb.recv().unwrap().unwrap_err();
        assert!(err.contains("deadline") && err.contains('1'), "{err}");
        assert_eq!(metrics.deadline_expired.load(Ordering::Relaxed), 1);
        // once admitted, a deadline never expires a request — accepted
        // means served, even while preempted with an elapsed TTL
        let mut preempted = pending_entry(9, PRIORITY_NORMAL, 0);
        preempted.req.deadline = Some(Duration::ZERO);
        preempted.admitted = true;
        preempted.generated.push(5);
        sched.waiting.push_back(preempted);
        sched.expire_deadlines();
        assert_eq!(sched.waiting.len(), 1, "admitted requests are never expired");
        assert_eq!(metrics.deadline_expired.load(Ordering::Relaxed), 1, "counter unchanged");
        sched.waiting.clear();
        tx.send(Ingress::Shutdown).unwrap();
        while sched.step(&rx) {}
    }

    #[test]
    fn preemption_takes_the_lowest_priority_then_the_youngest() {
        let scfg = SessionConfig {
            total_pages: 64,
            free_watermark: 0,
            max_running: 4,
            prefix_cache: false,
            prefill_chunk_tokens: 64,
            ..Default::default()
        };
        let lm = Arc::new(NativeLm::new(small_cfg(), 1));
        let mut sched = Scheduler::new(lm.clone(), scfg, Arc::new(Metrics::new()));
        let s0 = lm.begin_session(&prompt(0, 8), &sched.pool, None).unwrap();
        let s1 = lm.begin_session(&prompt(1, 8), &sched.pool, None).unwrap();
        let s2 = lm.begin_session(&prompt(2, 8), &sched.pool, None).unwrap();
        sched.admit_stamp = 3;
        let mut high = running_entry(0, prompt(0, 8), s0, 1);
        high.req.priority = 200;
        sched.running.push(high);
        sched.running.push(running_entry(1, prompt(1, 8), s1, 2));
        sched.running.push(running_entry(2, prompt(2, 8), s2, 3));
        sched.verify().expect("constructed running set is consistent");
        assert_eq!(
            sched.preempt_victim(),
            Some(2),
            "equal priority: the youngest admission is the victim"
        );
        sched.running[1].req.priority = 50;
        assert_eq!(
            sched.preempt_victim(),
            Some(1),
            "a lower priority session is preempted before younger, higher-priority ones"
        );
    }

    #[test]
    fn verify_reports_draw_incoherence_and_stream_cursor_overrun() {
        let scfg = SessionConfig {
            total_pages: 64,
            free_watermark: 0,
            max_running: 4,
            prefix_cache: false,
            prefill_chunk_tokens: 64,
            ..Default::default()
        };
        let lm = Arc::new(NativeLm::new(small_cfg(), 1));
        let mut sched = Scheduler::new(lm.clone(), scfg, Arc::new(Metrics::new()));
        let p = prompt(0, 16);
        // full prefill so the entry passes the decode-phase logits check
        let session = lm.new_session(&p, &sched.pool, None).unwrap();
        sched.admit_stamp = 1;
        let mut entry = running_entry(0, p, session, 1);
        entry.prefill = None;
        entry.generated.push(5);
        sched.running.push(entry);
        // greedy with zero draws and one generated token: coherent
        sched.verify().expect("greedy session with zero draws is coherent");
        // stochastic sampling demands one draw per generated token
        let params = SamplingParams { temperature: 0.7, seed: 3, ..Default::default() };
        sched.running[0].req.sampling = params;
        let msg = sched.verify().unwrap_err();
        assert!(msg.contains("draw"), "{msg}");
        // fast-forwarding the counter to generated.len() restores coherence
        sched.running[0].session.restore_sampling(params, 1);
        sched.verify().expect("restored draw counter is coherent");
        // a stream cursor past the generated sequence is corruption
        sched.running[0].streamed = 3;
        let msg = sched.verify().unwrap_err();
        assert!(msg.contains("stream cursor"), "{msg}");
    }

    /// The tentpole property: sampled, streamed generation under a pool
    /// tight enough to force preemption (a) matches the un-preempted
    /// `generate_sampled` reference bitwise — the fast-forwarded draw
    /// counter replays the identical stochastic choices — and (b) every
    /// token observed on a stream is an in-order prefix token of the
    /// final sequence: none duplicated across preempt/replay, none
    /// skipped, even with tiny stream buffers forcing retries.
    #[test]
    fn sampled_streaming_replays_bitwise_under_random_preemption() {
        use crate::proptest::for_all_seeds;
        for_all_seeds(6, |_, rng| {
            let scfg = SessionConfig {
                total_pages: 10,
                free_watermark: 0,
                max_running: 8,
                prefix_cache: false,
                prefill_chunk_tokens: 256,
                ..Default::default()
            };
            let lm = Arc::new(NativeLm::new(small_cfg(), 2));
            let metrics = Arc::new(Metrics::new());
            let (tx, rx) = sync_channel::<Ingress>(64);
            let mut cases = Vec::new();
            let mut consumers = Vec::new();
            let mut receivers = Vec::new();
            for i in 0..5u64 {
                let p = prompt(i as usize, 16);
                let sampling = if rng.below(3) == 0 {
                    SamplingParams::default() // greedy mixes with sampled
                } else {
                    SamplingParams {
                        temperature: 0.5 + rng.uniform(),
                        top_k: [0usize, 4, 16][rng.below(3)],
                        top_p: 0.7 + 0.3 * rng.uniform(),
                        seed: rng.next_u64(),
                    }
                };
                let (stx, srx) = sync_channel::<i32>(1 + rng.below(3));
                consumers.push(std::thread::spawn(move || srx.iter().collect::<Vec<i32>>()));
                receivers.push(send_req_cfg(
                    &tx,
                    Request { sampling, stream: Some(stx), ..Request::new(i, p.clone(), 6) },
                ));
                cases.push((p, sampling));
            }
            let (lm2, m2) = (lm.clone(), metrics.clone());
            let handle = std::thread::spawn(move || scheduler_loop(rx, lm2, scfg, m2, None));
            for (((p, sampling), rxr), consumer) in
                cases.iter().zip(receivers).zip(consumers)
            {
                let resp = rxr.recv().unwrap().expect("served under memory pressure");
                let want = lm.generate_sampled(p, 6, *sampling).unwrap();
                if resp.predictions != want {
                    return Err(format!(
                        "preempt/replay diverged: {:?} != {:?} under {sampling:?}",
                        resp.predictions, want
                    ));
                }
                let streamed = consumer.join().unwrap();
                if streamed.len() > resp.predictions.len()
                    || streamed != resp.predictions[..streamed.len()]
                {
                    return Err(format!(
                        "streamed {streamed:?} is not a prefix of {:?} (dup/drop/reorder)",
                        resp.predictions
                    ));
                }
            }
            tx.send(Ingress::Shutdown).unwrap();
            handle.join().unwrap();
            if metrics.preemptions.load(Ordering::Relaxed) < 1 {
                return Err("the 10-page pool must force at least one preemption".into());
            }
            Ok(())
        });
    }

    // ---- fused step, budget re-offer, mid-prefill publication -------

    /// §bugfix regression: `prefill_take` snaps non-final chunks down to
    /// a block boundary, and the old single-pass planner stranded the
    /// remainder — a 44-token budget against a long prompt handed out 32
    /// tokens per step, forever.  The re-offer loop must spend the
    /// leftover 12 in the same step, finishing the 200-token prompt in 5
    /// prefill steps instead of 7 (observable as a lower total step
    /// count) and counting each re-offer.
    #[test]
    fn leftover_budget_is_reoffered_within_the_same_step() {
        let scfg = SessionConfig {
            total_pages: 512,
            free_watermark: 0,
            max_running: 8,
            prefix_cache: false,
            prefill_chunk_tokens: 44, // 2 blocks + a 12-token remainder
            autotune_prefill: false,
            ..Default::default()
        };
        let lm = Arc::new(NativeLm::new(wide_cfg(), 2));
        let metrics = Arc::new(Metrics::new());
        let mut sched = Scheduler::new(lm.clone(), scfg, metrics.clone());
        let (tx, rx) = sync_channel::<Ingress>(8);
        let long = prompt(0, 200);
        let short = prompt(1, 8);
        let ra = send_req(&tx, 0, long.clone(), 4);
        let rb = send_req(&tx, 1, short.clone(), 4);
        let mut steps = 0;
        let a = loop {
            assert!(sched.step(&rx), "work remains");
            steps += 1;
            assert!(steps < 40, "long request did not finish");
            if let Ok(resp) = ra.try_recv() {
                break resp.expect("long response");
            }
        };
        let b = rb.recv().unwrap().expect("short response");
        assert_eq!(a.predictions, lm.generate(&long, 4).unwrap(), "re-offer changed the output");
        assert_eq!(b.predictions, lm.generate(&short, 4).unwrap());
        // re-offered: 36/44/44/44/32-token prefill steps + 3 decode-only
        // steps + the finisher = 8 steps; the stranded-remainder bug
        // needs 7 prefill steps (32/step) and finishes at step 10
        assert!(steps <= 9, "budget remainder was stranded: took {steps} steps");
        assert!(
            metrics.budget_reoffers.load(Ordering::Relaxed) >= 1,
            "re-offers must be counted: {}",
            metrics.summary()
        );
        assert_eq!(metrics.prefill_tokens.load(Ordering::Relaxed), 200 + 8);
        assert_eq!(
            metrics.autotuned_chunk_tokens.load(Ordering::Relaxed),
            44,
            "disabled controller must pin the gauge at the configured knob"
        );
        tx.send(Ingress::Shutdown).unwrap();
        while sched.step(&rx) {}
    }

    /// Mid-prefill prefix publication: a second identical prompt
    /// admitted while the first is *still prefilling* attaches the
    /// blocks published chunk by chunk — counted by
    /// `midprefill_prefix_hits` — and skips recomputing them, without
    /// changing either output.
    #[test]
    fn identical_prompt_admitted_mid_prefill_shares_published_blocks() {
        let scfg = SessionConfig {
            total_pages: 512,
            free_watermark: 0,
            max_running: 8,
            prefix_cache: true,
            prefill_chunk_tokens: 16,
            autotune_prefill: false,
            ..Default::default()
        };
        let lm = Arc::new(NativeLm::new(small_cfg(), 2));
        let metrics = Arc::new(Metrics::new());
        let mut sched = Scheduler::new(lm.clone(), scfg, metrics.clone());
        let (tx, rx) = sync_channel::<Ingress>(8);
        let shared = prompt(0, 48);
        let r1 = send_req(&tx, 0, shared.clone(), 3);
        // two chunked steps in: 32 tokens prefilled, 2 blocks published
        assert!(sched.step(&rx));
        assert!(sched.step(&rx));
        assert!(metrics.prefill_tokens.load(Ordering::Relaxed) >= 32, "{}", metrics.summary());
        // the twin arrives while the first session is mid-prefill
        let r2 = send_req(&tx, 1, shared.clone(), 3);
        assert!(sched.step(&rx));
        assert_eq!(
            metrics.midprefill_prefix_hits.load(Ordering::Relaxed),
            1,
            "{}",
            metrics.summary()
        );
        let (mut a, mut b) = (None, None);
        let mut steps = 0;
        while a.is_none() || b.is_none() {
            assert!(sched.step(&rx), "work remains");
            steps += 1;
            assert!(steps < 50, "requests did not finish");
            if a.is_none() {
                if let Ok(x) = r1.try_recv() {
                    a = Some(x.expect("first response"));
                }
            }
            if b.is_none() {
                if let Ok(x) = r2.try_recv() {
                    b = Some(x.expect("second response"));
                }
            }
        }
        let want = lm.generate(&shared, 3).unwrap();
        assert_eq!(a.unwrap().predictions, want);
        assert_eq!(b.unwrap().predictions, want, "mid-prefill sharing changed the output");
        // the twin attached >= 2 published blocks instead of recomputing
        assert!(
            metrics.prefix_hit_tokens.load(Ordering::Relaxed) >= 32,
            "{}",
            metrics.summary()
        );
        assert!(
            metrics.prefill_tokens.load(Ordering::Relaxed) < 96,
            "the shared blocks must not be prefilled twice: {}",
            metrics.summary()
        );
        tx.send(Ingress::Shutdown).unwrap();
        while sched.step(&rx) {}
    }

    /// The tentpole equivalence: the fused single-drain step and the
    /// legacy phased (prefill-then-decode) step must be bitwise
    /// indistinguishable — same responses, same token/chunk/session
    /// accounting, same preemption and replay behavior — across random
    /// mixed workloads (prompt lengths, shared prompts, priorities,
    /// greedy and stochastic sampling) under a pool tight enough to
    /// force preemptions.
    #[test]
    fn fused_step_matches_the_phased_path_bitwise() {
        use crate::proptest::for_all_seeds;
        for_all_seeds(6, |_, rng| {
            let prefix_cache = rng.below(2) == 0;
            let chunk = [16, 24, 44, 256][rng.below(4)];
            let n = 4 + rng.below(3);
            let mut cases: Vec<(Vec<i32>, usize, SamplingParams, u8)> = Vec::new();
            for i in 0..n {
                let p = if i > 0 && rng.below(3) == 0 {
                    cases[i - 1].0.clone() // shared prompts hit the cache
                } else {
                    prompt(i, 1 + rng.below(40))
                };
                let sampling = if rng.below(2) == 0 {
                    SamplingParams::default()
                } else {
                    SamplingParams {
                        temperature: 0.5 + rng.uniform(),
                        top_k: [0usize, 4][rng.below(2)],
                        top_p: 0.7 + 0.3 * rng.uniform(),
                        seed: rng.next_u64(),
                    }
                };
                let priority = [PRIORITY_NORMAL, 10, 200][rng.below(3)];
                cases.push((p, 1 + rng.below(6), sampling, priority));
            }
            let run = |fused: bool| {
                let scfg = SessionConfig {
                    total_pages: 12,
                    free_watermark: 0,
                    max_running: 8,
                    prefix_cache,
                    prefill_chunk_tokens: chunk,
                    fused_step: fused,
                    autotune_prefill: false,
                    ..Default::default()
                };
                let lm = Arc::new(NativeLm::new(small_cfg(), 2));
                let metrics = Arc::new(Metrics::new());
                let mut sched = Scheduler::new(lm, scfg, metrics.clone());
                let (tx, rx) = sync_channel::<Ingress>(64);
                let receivers: Vec<_> = cases
                    .iter()
                    .enumerate()
                    .map(|(i, (p, g, s, prio))| {
                        send_req_cfg(
                            &tx,
                            Request {
                                sampling: *s,
                                priority: *prio,
                                ..Request::new(i as u64, p.clone(), *g)
                            },
                        )
                    })
                    .collect();
                let mut outs: Vec<Option<Result<Response, String>>> =
                    (0..cases.len()).map(|_| None).collect();
                let mut steps = 0;
                while outs.iter().any(|o| o.is_none()) {
                    assert!(sched.step(&rx), "work remains");
                    steps += 1;
                    assert!(steps < 3000, "workload did not drain");
                    for (o, r) in outs.iter_mut().zip(&receivers) {
                        if o.is_none() {
                            if let Ok(resp) = r.try_recv() {
                                *o = Some(resp);
                            }
                        }
                    }
                }
                tx.send(Ingress::Shutdown).unwrap();
                while sched.step(&rx) {}
                let sig: Vec<Result<(u64, Vec<i32>), String>> = outs
                    .into_iter()
                    .map(|o| match o {
                        Some(Ok(resp)) => Ok((resp.id, resp.predictions)),
                        Some(Err(e)) => Err(e),
                        None => Err("missing".into()),
                    })
                    .collect();
                let counters = [
                    metrics.generated_tokens.load(Ordering::Relaxed),
                    metrics.prefill_tokens.load(Ordering::Relaxed),
                    metrics.prefill_chunks.load(Ordering::Relaxed),
                    metrics.sessions.load(Ordering::Relaxed),
                    metrics.preemptions.load(Ordering::Relaxed),
                    metrics.decode_steps.load(Ordering::Relaxed),
                    metrics.rejected.load(Ordering::Relaxed),
                    metrics.budget_reoffers.load(Ordering::Relaxed),
                    metrics.midprefill_prefix_hits.load(Ordering::Relaxed),
                    metrics.prefix_hit_tokens.load(Ordering::Relaxed),
                ];
                (sig, counters)
            };
            let (fused_sig, fused_counters) = run(true);
            let (phased_sig, phased_counters) = run(false);
            if fused_sig != phased_sig {
                return Err(format!(
                    "fused and phased outputs diverged:\n{fused_sig:?}\n{phased_sig:?}"
                ));
            }
            if fused_counters != phased_counters {
                return Err(format!(
                    "fused and phased accounting diverged: {fused_counters:?} != \
                     {phased_counters:?}"
                ));
            }
            Ok(())
        });
    }

    /// Observability must be free of observer effects: the same random
    /// workload driven with the flight recorder attached and detached
    /// must produce identical responses and identical counter accounting
    /// — the recorder only *watches* the step, it never participates.
    /// Covers both the fused and the phased execution paths under a pool
    /// tight enough to force preemptions (so the Preempt/Readmit record
    /// sites run too).
    #[test]
    fn tracing_on_and_off_are_behaviorally_identical() {
        use crate::proptest::for_all_seeds;
        for_all_seeds(6, |_, rng| {
            let fused = rng.below(2) == 0;
            let chunk = [16, 44, 256][rng.below(3)];
            let n = 3 + rng.below(3);
            let mut cases: Vec<(Vec<i32>, usize, u8)> = Vec::new();
            for i in 0..n {
                let p = if i > 0 && rng.below(3) == 0 {
                    cases[i - 1].0.clone() // shared prompts hit the cache
                } else {
                    prompt(i, 1 + rng.below(40))
                };
                let priority = [PRIORITY_NORMAL, 10, 200][rng.below(3)];
                cases.push((p, 1 + rng.below(6), priority));
            }
            let run = |trace: Option<Arc<FlightRecorder>>| {
                let scfg = SessionConfig {
                    total_pages: 12,
                    free_watermark: 0,
                    max_running: 8,
                    prefix_cache: true,
                    prefill_chunk_tokens: chunk,
                    fused_step: fused,
                    autotune_prefill: false,
                    ..Default::default()
                };
                let lm = Arc::new(NativeLm::new(small_cfg(), 2));
                let metrics = Arc::new(Metrics::new());
                let mut sched = Scheduler::with_trace(
                    lm,
                    scfg,
                    metrics.clone(),
                    Box::new(MonotonicClock::default()),
                    trace,
                );
                let (tx, rx) = sync_channel::<Ingress>(64);
                let receivers: Vec<_> = cases
                    .iter()
                    .enumerate()
                    .map(|(i, (p, g, prio))| {
                        send_req_cfg(
                            &tx,
                            Request {
                                priority: *prio,
                                ..Request::new(i as u64, p.clone(), *g)
                            },
                        )
                    })
                    .collect();
                let mut outs: Vec<Option<Result<Response, String>>> =
                    (0..cases.len()).map(|_| None).collect();
                let mut steps = 0;
                while outs.iter().any(|o| o.is_none()) {
                    assert!(sched.step(&rx), "work remains");
                    steps += 1;
                    assert!(steps < 3000, "workload did not drain");
                    for (o, r) in outs.iter_mut().zip(&receivers) {
                        if o.is_none() {
                            if let Ok(resp) = r.try_recv() {
                                *o = Some(resp);
                            }
                        }
                    }
                }
                tx.send(Ingress::Shutdown).unwrap();
                while sched.step(&rx) {}
                let sig: Vec<Result<(u64, Vec<i32>), String>> = outs
                    .into_iter()
                    .map(|o| match o {
                        Some(Ok(resp)) => Ok((resp.id, resp.predictions)),
                        Some(Err(e)) => Err(e),
                        None => Err("missing".into()),
                    })
                    .collect();
                let counters = [
                    metrics.generated_tokens.load(Ordering::Relaxed),
                    metrics.prefill_tokens.load(Ordering::Relaxed),
                    metrics.prefill_chunks.load(Ordering::Relaxed),
                    metrics.sessions.load(Ordering::Relaxed),
                    metrics.preemptions.load(Ordering::Relaxed),
                    metrics.decode_steps.load(Ordering::Relaxed),
                    metrics.rejected.load(Ordering::Relaxed),
                    metrics.budget_reoffers.load(Ordering::Relaxed),
                    metrics.midprefill_prefix_hits.load(Ordering::Relaxed),
                    metrics.prefix_hit_tokens.load(Ordering::Relaxed),
                ];
                (sig, counters)
            };
            let recorder = Arc::new(FlightRecorder::new(1024));
            let (traced_sig, traced_counters) = run(Some(recorder.clone()));
            let (plain_sig, plain_counters) = run(None);
            if traced_sig != plain_sig {
                return Err(format!(
                    "tracing changed the outputs:\n{traced_sig:?}\n{plain_sig:?}"
                ));
            }
            if traced_counters != plain_counters {
                return Err(format!(
                    "tracing changed the accounting: {traced_counters:?} != \
                     {plain_counters:?}"
                ));
            }
            if recorder.is_empty() {
                return Err("the traced run recorded no events".into());
            }
            Ok(())
        });
    }
}
