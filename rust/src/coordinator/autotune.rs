//! Self-tuning prefill chunk budget (AIMD against a decode-step latency
//! target) — the controller behind the fused scheduler step (DESIGN.md
//! §13).
//!
//! The static `sessions.prefill_chunk_tokens` knob is wrong for every
//! workload but the one it was tuned on: too large and prefill chunks
//! inflate the tail latency of the decode tokens they share a step with,
//! too small and prompt throughput collapses.  [`AutotuneBudget`] turns
//! the knob into an **initial value and hard cap**: each fused step that
//! ran prefill work reports its wall duration, and once a window of
//! observations is full the controller compares the window tail against
//! `sessions.decode_p95_target_us` — over target halves the budget
//! (multiplicative decrease), under target adds one block (additive
//! increase), classic AIMD.  The budget never leaves
//! `[block, prefill_chunk_tokens]`, so prefill always progresses and
//! never exceeds the operator's configured ceiling.
//!
//! **Determinism**: budget changes alter only *scheduling* (how many
//! prompt tokens each step feeds), never *results* — chunked prefill is
//! bitwise identical to per-token prefill for any chunk split
//! (property-tested), so an autotuned server emits exactly the tokens a
//! static-budget server emits.
//!
//! **Clock injection**: all timing flows through the [`StepClock`] trait.
//! Production uses [`MonotonicClock`] (a `std::time::Instant` origin);
//! tests and benches use [`ManualClock`], which only advances when told
//! to — controller behavior is reproducible down to the microsecond, and
//! the bitwise-gated modules covered by `cargo xtask lint`'s
//! `no-wallclock` rule stay free of wall-clock reads.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// Monotone microsecond time source for the scheduler's step timing —
/// injected so the controller (and every test driving it) is
/// deterministic.  `&mut self` keeps implementations trivially
/// thread-free; the scheduler owns exactly one.
pub trait StepClock: Send {
    /// Microseconds since an arbitrary fixed origin; never decreases.
    fn now_us(&mut self) -> u64;
}

/// The production [`StepClock`]: microseconds since construction, read
/// from a monotonic [`Instant`].
pub struct MonotonicClock {
    origin: Instant,
}

impl Default for MonotonicClock {
    fn default() -> Self {
        MonotonicClock { origin: Instant::now() }
    }
}

impl StepClock for MonotonicClock {
    fn now_us(&mut self) -> u64 {
        self.origin.elapsed().as_micros() as u64
    }
}

/// A [`StepClock`] that advances only when told to — the deterministic
/// test/bench clock.  Clone-cheap handles ([`ManualClock::handle`]) let a
/// test advance time while the scheduler owns the clock.
#[derive(Default)]
pub struct ManualClock {
    now: Arc<AtomicU64>,
}

impl ManualClock {
    /// A clock at time zero.
    pub fn new() -> Self {
        Self::default()
    }

    /// A handle sharing this clock's time: `fetch_add` on it advances
    /// every reader.
    pub fn handle(&self) -> Arc<AtomicU64> {
        Arc::clone(&self.now)
    }
}

impl StepClock for ManualClock {
    fn now_us(&mut self) -> u64 {
        self.now.load(Ordering::Relaxed)
    }
}

/// A [`StepClock`] pinned at zero — injected by the *untimed* wrappers of
/// the timed native step bodies (`NativeLm::fused_step` and friends), so
/// the shared body always has a clock without the untimed callers paying
/// for (or even owning) one.  All spans measured against it are zero.
#[derive(Clone, Copy, Debug, Default)]
pub struct FrozenClock;

impl StepClock for FrozenClock {
    fn now_us(&mut self) -> u64 {
        0
    }
}

/// Observations per adjustment window.  The window tail (its maximum) is
/// the controller's latency signal — for windows this small the max *is*
/// the p95 estimate (exact p95 would need >= 20 samples per window and
/// would react a window too late under bursty load).
const WINDOW: usize = 8;

/// AIMD controller for the per-step prefill token budget (module docs
/// for the control law; `Scheduler` wiring in DESIGN.md §13).
pub struct AutotuneBudget {
    /// `false` pins the budget at `cap` forever (the legacy static knob).
    enabled: bool,
    budget: usize,
    /// Lower bound and additive-increase step: one block, so prefill
    /// always progresses and the budget stays block-meaningful.
    floor: usize,
    /// Upper bound: the configured `prefill_chunk_tokens`.
    cap: usize,
    target_us: u64,
    window: Vec<u64>,
    clock: Box<dyn StepClock>,
    /// Step-start stamp; `None` when no step is in flight.
    t0: Option<u64>,
    halvings: u64,
    raises: u64,
}

impl AutotuneBudget {
    /// Controller starting (and capped) at `cap` tokens, floored at
    /// `floor` (one block), targeting `target_us` step latency.  Disabled
    /// controllers never move off `cap`.
    pub fn new(
        cap: usize,
        floor: usize,
        target_us: u64,
        enabled: bool,
        clock: Box<dyn StepClock>,
    ) -> Self {
        let floor = floor.max(1);
        let cap = cap.max(floor);
        AutotuneBudget {
            enabled,
            budget: cap,
            floor,
            cap,
            target_us,
            window: Vec::with_capacity(WINDOW),
            clock,
            t0: None,
            halvings: 0,
            raises: 0,
        }
    }

    /// The current per-step prefill token budget.
    pub fn current(&self) -> usize {
        self.budget
    }

    /// Read the injected clock — the scheduler's only time source, shared
    /// by the flight recorder's event stamps and the per-phase step
    /// timing so every observability surface agrees on "now".
    pub fn now_us(&mut self) -> u64 {
        self.clock.now_us()
    }

    /// Borrow the injected clock (to thread through the timed native step
    /// bodies without a second clock instance).
    pub fn clock_mut(&mut self) -> &mut dyn StepClock {
        &mut *self.clock
    }

    /// Stamp the start of a scheduler step.
    pub fn begin_step(&mut self) {
        self.t0 = Some(self.clock.now_us());
    }

    /// Close the step opened by [`AutotuneBudget::begin_step`] and return
    /// its wall duration (µs).  The duration feeds the controller only
    /// when the step actually ran prefill work (`prefilled`) — pure
    /// decode steps say nothing about the chunk budget.
    pub fn end_step(&mut self, prefilled: bool) -> u64 {
        let Some(t0) = self.t0.take() else { return 0 };
        let dt = self.clock.now_us().saturating_sub(t0);
        if prefilled {
            self.observe(dt);
        }
        dt
    }

    /// Feed one step-duration observation directly (the begin/end pair is
    /// a convenience over this).  Every `WINDOW` observations the budget
    /// adjusts: window max over target halves it (snapped down to a
    /// `floor` multiple), otherwise it gains one `floor` step, clamped to
    /// `[floor, cap]`.
    pub fn observe(&mut self, us: u64) {
        if !self.enabled {
            return;
        }
        self.window.push(us);
        if self.window.len() < WINDOW {
            return;
        }
        let tail = self.window.iter().copied().max().unwrap_or(0);
        self.window.clear();
        if tail > self.target_us {
            self.budget = (self.budget / 2 / self.floor * self.floor).max(self.floor);
            self.halvings += 1;
        } else if self.budget < self.cap {
            self.budget = (self.budget + self.floor).min(self.cap);
            self.raises += 1;
        }
    }

    /// Multiplicative decreases taken so far (introspection for tests
    /// and bench convergence checks).
    pub fn halvings(&self) -> u64 {
        self.halvings
    }

    /// Additive increases taken so far.
    pub fn raises(&self) -> u64 {
        self.raises
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn controller(target_us: u64, enabled: bool) -> AutotuneBudget {
        AutotuneBudget::new(256, 32, target_us, enabled, Box::new(ManualClock::new()))
    }

    #[test]
    fn overload_halves_until_the_floor_and_never_below() {
        let mut a = controller(1_000, true);
        assert_eq!(a.current(), 256);
        for round in 0..6 {
            for _ in 0..WINDOW {
                a.observe(5_000);
            }
            assert!(a.current() >= 32, "round {round} went below the floor");
        }
        // 256 -> 128 -> 64 -> 32, then pinned at the floor
        assert_eq!(a.current(), 32);
        assert_eq!(a.halvings(), 6);
    }

    #[test]
    fn headroom_raises_one_block_per_window_up_to_the_cap() {
        let mut a = controller(1_000_000, true);
        for _ in 0..WINDOW {
            a.observe(5_000); // over no threshold: 5ms << 1s target
        }
        assert_eq!(a.current(), 256, "already at the cap: no raise possible");
        // knock it down once, then watch it climb back block by block
        for _ in 0..WINDOW {
            a.observe(2_000_000);
        }
        assert_eq!(a.current(), 128);
        for step in 1..=4 {
            for _ in 0..WINDOW {
                a.observe(5_000);
            }
            assert_eq!(a.current(), 128 + 32 * step);
        }
        assert_eq!(a.current(), 256);
        for _ in 0..WINDOW {
            a.observe(5_000);
        }
        assert_eq!(a.current(), 256, "cap is a hard ceiling");
    }

    #[test]
    fn one_bursty_window_tail_triggers_the_decrease() {
        let mut a = controller(1_000, true);
        for i in 0..WINDOW {
            // seven quiet steps, one burst: the window tail (max) decides
            a.observe(if i == 3 { 50_000 } else { 100 });
        }
        assert_eq!(a.current(), 128);
    }

    #[test]
    fn disabled_controller_is_the_static_knob() {
        let mut a = controller(1, false);
        for _ in 0..10 * WINDOW {
            a.observe(1_000_000);
        }
        assert_eq!(a.current(), 256);
        assert_eq!(a.halvings(), 0);
    }

    #[test]
    fn halving_snaps_to_a_block_multiple() {
        // cap 96, floor 64: 96/2 = 48 snaps down past the floor -> 64
        let mut a = AutotuneBudget::new(96, 64, 1_000, true, Box::new(ManualClock::new()));
        for _ in 0..WINDOW {
            a.observe(5_000);
        }
        assert_eq!(a.current(), 64);
    }

    #[test]
    fn begin_end_measures_the_manual_clock_and_feeds_only_prefill_steps() {
        let clock = ManualClock::new();
        let hand = clock.handle();
        let mut a = AutotuneBudget::new(256, 32, 1_000, true, Box::new(clock));
        // a non-prefill step is timed but not observed
        a.begin_step();
        hand.fetch_add(9_000, Ordering::Relaxed);
        assert_eq!(a.end_step(false), 9_000);
        for _ in 0..WINDOW {
            a.begin_step();
            hand.fetch_add(9_000, Ordering::Relaxed);
            assert_eq!(a.end_step(true), 9_000);
        }
        assert_eq!(a.current(), 128, "eight over-target prefill steps must halve");
        // end without begin is a no-op zero, not a bogus huge sample
        assert_eq!(a.end_step(true), 0);
    }

    #[test]
    fn now_us_reads_the_injected_clock_and_frozen_stays_zero() {
        let clock = ManualClock::new();
        let hand = clock.handle();
        let mut a = AutotuneBudget::new(256, 32, 1_000, true, Box::new(clock));
        assert_eq!(a.now_us(), 0);
        hand.fetch_add(123, Ordering::Relaxed);
        assert_eq!(a.now_us(), 123);
        assert_eq!(a.clock_mut().now_us(), 123);
        let mut frozen = FrozenClock;
        assert_eq!(frozen.now_us(), 0);
        assert_eq!(frozen.now_us(), 0);
    }
}
