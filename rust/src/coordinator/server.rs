//! Serving loop: bounded ingress queue -> dynamic batcher -> bucket router
//! -> PJRT worker pool.  Threads + channels (no async runtime available
//! offline); the architecture mirrors a vLLM-style router with one
//! compiled executable per `(model, batch-bucket)`.
//!
//! ```text
//!  submit() --sync_channel(queue_depth)--> batcher thread --+--> worker 0
//!     ^                                   (deadline flush)  +--> worker 1
//!     `-- backpressure: TrySendError => Busy                ...
//! ```

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, Sender, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::config::ServeConfig;
use crate::coordinator::batcher::{Batch, Batcher, Request};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::router::Router;
use crate::runtime::{HostTensor, Manifest, RuntimeHandle};

/// Per-request response: argmax token predictions for the request's
/// positions (MLM head output).
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub predictions: Vec<i32>,
    pub latency: Duration,
}

enum Ingress {
    Req(Request, Sender<Result<Response, String>>),
    Shutdown,
}

/// Handle to a running server.
pub struct Server {
    ingress: SyncSender<Ingress>,
    pub metrics: Arc<Metrics>,
    next_id: AtomicU64,
    threads: Vec<JoinHandle<()>>,
}

impl Server {
    /// Spin up the batcher + worker threads over the runtime executor.
    pub fn start(
        runtime: RuntimeHandle,
        manifest: Arc<Manifest>,
        cfg: ServeConfig,
    ) -> Result<Self> {
        let metrics = Arc::new(Metrics::new());
        let router = Arc::new(Router::new(&manifest, &cfg.model)?);
        // model parameters are loaded once and shared by every worker
        let params = Arc::new(
            manifest
                .load_f32(&format!("{}.params.f32", cfg.model))
                .context("loading model params")?,
        );
        // warm the executable cache so first requests don't pay compile time
        for b in [1usize, cfg.max_batch] {
            if let Ok(route) = router.route(b) {
                runtime.warm(&route.artifact)?;
            }
        }
        let (ingress_tx, ingress_rx) = sync_channel::<Ingress>(cfg.queue_depth);
        let (batch_tx, batch_rx) =
            sync_channel::<(Batch, Vec<Sender<Result<Response, String>>>)>(cfg.workers * 2);
        let batch_rx = Arc::new(std::sync::Mutex::new(batch_rx));

        let mut threads = Vec::new();
        // batcher thread
        {
            let cfg = cfg.clone();
            threads.push(std::thread::spawn(move || {
                batcher_loop(ingress_rx, batch_tx, &cfg);
            }));
        }
        // workers
        for _ in 0..cfg.workers.max(1) {
            let rx = batch_rx.clone();
            let rt = runtime.clone();
            let router = router.clone();
            let params = params.clone();
            let metrics = metrics.clone();
            threads.push(std::thread::spawn(move || {
                worker_loop(rx, rt, router, params, metrics);
            }));
        }
        Ok(Server { ingress: ingress_tx, metrics, next_id: AtomicU64::new(0), threads })
    }

    /// Submit a request; blocks until the response arrives.
    /// Returns `Err` on backpressure (queue full) or execution failure.
    pub fn infer(&self, tokens: Vec<i32>) -> Result<Response> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = std::sync::mpsc::channel();
        let req = Request { id, tokens, arrived: Instant::now() };
        self.metrics.inc_requests();
        match self.ingress.try_send(Ingress::Req(req, tx)) {
            Ok(()) => {}
            Err(TrySendError::Full(_)) => {
                self.metrics.inc_rejected();
                bail!("server busy (queue full)");
            }
            Err(TrySendError::Disconnected(_)) => bail!("server stopped"),
        }
        rx.recv()
            .context("server dropped request")?
            .map_err(|e| anyhow::anyhow!(e))
    }

    /// Graceful shutdown: flush pending batches, join threads.
    pub fn shutdown(mut self) {
        let _ = self.ingress.send(Ingress::Shutdown);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

fn batcher_loop(
    ingress: Receiver<Ingress>,
    batch_tx: SyncSender<(Batch, Vec<Sender<Result<Response, String>>>)>,
    cfg: &ServeConfig,
) {
    let mut batcher = Batcher::new(cfg.max_batch, Duration::from_micros(cfg.flush_us));
    let mut responders: Vec<Sender<Result<Response, String>>> = Vec::new();
    loop {
        // wait up to the flush deadline for the next request
        match ingress.recv_timeout(Duration::from_micros(cfg.flush_us.max(100))) {
            Ok(Ingress::Req(req, resp)) => {
                responders.push(resp);
                if let Some(batch) = batcher.push(req) {
                    let rs = responders.drain(..).collect();
                    if batch_tx.send((batch, rs)).is_err() {
                        return;
                    }
                }
            }
            Ok(Ingress::Shutdown) => {
                if let Some(batch) = batcher.drain() {
                    let rs = responders.drain(..).collect();
                    let _ = batch_tx.send((batch, rs));
                }
                return;
            }
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                if let Some(batch) = batcher.poll_due(Instant::now()) {
                    let rs = responders.drain(..).collect();
                    if batch_tx.send((batch, rs)).is_err() {
                        return;
                    }
                }
            }
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                if let Some(batch) = batcher.drain() {
                    let rs = responders.drain(..).collect();
                    let _ = batch_tx.send((batch, rs));
                }
                return;
            }
        }
    }
}

#[allow(clippy::type_complexity)]
fn worker_loop(
    rx: Arc<std::sync::Mutex<Receiver<(Batch, Vec<Sender<Result<Response, String>>>)>>>,
    rt: RuntimeHandle,
    router: Arc<Router>,
    params: Arc<Vec<f32>>,
    metrics: Arc<Metrics>,
) {
    loop {
        let item = {
            let guard = rx.lock().unwrap();
            guard.recv()
        };
        let (batch, responders) = match item {
            Ok(x) => x,
            Err(_) => return,
        };
        let result = run_batch(&rt, &router, &params, &batch, &metrics);
        match result {
            Ok(mut responses) => {
                for (resp, tx) in responses.drain(..).zip(responders) {
                    let _ = tx.send(Ok(resp));
                }
            }
            Err(e) => {
                let msg = format!("{e:#}");
                for tx in responders {
                    let _ = tx.send(Err(msg.clone()));
                }
            }
        }
    }
}

/// Execute one batch through the routed artifact; slice outputs per request.
fn run_batch(
    rt: &RuntimeHandle,
    router: &Router,
    params: &[f32],
    batch: &Batch,
    metrics: &Metrics,
) -> Result<Vec<Response>> {
    let route = router.route(batch.len())?;
    let rows: Vec<Vec<i32>> = batch.requests.iter().map(|r| r.tokens.clone()).collect();
    let ids = router.pad_tokens(&rows, route.bucket)?;
    let n = router.seq_len;
    let inputs = vec![
        HostTensor::F32(params.to_vec(), vec![params.len()]),
        HostTensor::I32(ids, vec![route.bucket, n]),
    ];
    let t0 = Instant::now();
    let outputs = rt.execute(&route.artifact, inputs)?;
    metrics.batch_exec.record(t0.elapsed());
    metrics.inc_batches(route.padded_slots as u64);
    // logits: (bucket, n, vocab) -> per-request argmax over the vocab
    let logits = outputs[0].as_f32()?;
    let dims = outputs[0].dims();
    let vocab = dims[2];
    let mut out = Vec::with_capacity(batch.len());
    for (bi, req) in batch.requests.iter().enumerate() {
        let len = req.tokens.len();
        let mut preds = Vec::with_capacity(len);
        for pos in 0..len {
            let base = (bi * n + pos) * vocab;
            let row = &logits[base..base + vocab];
            let mut best = 0usize;
            let mut best_v = f32::NEG_INFINITY;
            for (t, &v) in row.iter().enumerate() {
                if v > best_v {
                    best_v = v;
                    best = t;
                }
            }
            preds.push(best as i32);
        }
        let latency = req.arrived.elapsed();
        metrics.request_latency.record(latency);
        out.push(Response { id: req.id, predictions: preds, latency });
    }
    Ok(out)
}

// Integration tests that exercise Server against real artifacts live in
// rust/tests/serve_integration.rs (skipped when artifacts/ is absent).
