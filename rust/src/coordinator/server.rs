//! Serving loop: bounded ingress queue -> dynamic batcher -> worker pool.
//! Threads + channels (no async runtime available offline); the
//! architecture mirrors a vLLM-style router with one compiled executable
//! per `(model, batch-bucket)`.
//!
//! ```text
//!  submit() --sync_channel(queue_depth)--> batcher thread --+--> worker 0
//!     ^                                   (deadline flush)  +--> worker 1
//!     `-- backpressure: TrySendError => Busy                ...
//! ```
//!
//! Workers execute batches through a `BatchRunner`: the AOT artifact
//! path (PJRT runtime + bucket router, [`Server::start`]), the native MLM
//! fallback ([`Server::start_native`]) that routes the batch through the
//! parallel batched engine when `artifacts/` is absent, or the native
//! causal-LM path ([`Server::start_native_lm`]) that greedily decodes
//! generation requests ([`Server::generate`]) through incremental KV
//! caches.
//!
//! LM generation has a second, preferred backend:
//! [`Server::start_native_lm_sessions`] swaps the batcher + workers for
//! the continuous-batching session scheduler
//! ([`crate::coordinator::scheduler`]) — paged KV cache, radix prefix
//! sharing, per-step join/leave — behind the same submit API.
//!
//! Generation supports **per-token streaming**: [`Server::generate_stream`]
//! returns a [`TokenStream`] whose tokens arrive as they are decoded (a
//! bounded channel; the scheduler never blocks on a slow consumer), and
//! [`GenOptions`] carries the per-request QoS (priority, admission
//! deadline) and [`SamplingParams`] knobs.  Both serving backends honor
//! the same options; outputs under greedy sampling are bitwise identical
//! to the finish-only [`Server::generate`] path.

// a panic in the batcher or a worker drops every responder it holds and
// hangs the waiting clients — request paths handle errors, they don't
// unwrap them.  `cargo xtask lint` enforces the same rule textually.
#![deny(clippy::unwrap_used, clippy::expect_used)]

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, Sender, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::config::{SamplingParams, ServeConfig};
use crate::coordinator::batcher::{Batch, Batcher, Request, PRIORITY_NORMAL};
use crate::coordinator::expose::MetricsSnapshot;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::native::{NativeLm, NativeMlm, NativeMlmConfig};
use crate::coordinator::router::Router;
use crate::coordinator::trace::FlightRecorder;
use crate::runtime::{HostTensor, Manifest, RuntimeHandle};

/// Per-request response: argmax token predictions for the request's
/// positions (MLM head output), or the generated token stream for
/// autoregressive requests ([`Server::generate`]).
#[derive(Clone, Debug)]
pub struct Response {
    /// Server-assigned request id.
    pub id: u64,
    /// Predicted token ids — the full generated sequence for generation
    /// requests (even when tokens were also streamed), per-position
    /// predictions for MLM requests.
    pub predictions: Vec<i32>,
    /// Submission-to-completion latency.
    pub latency: Duration,
}

pub(crate) type Responder = Sender<Result<Response, String>>;

pub(crate) enum Ingress {
    Req(Request, Responder),
    Shutdown,
}

/// Per-request generation options: decode length, QoS and sampling.
///
/// Built fluently: `GenOptions::new(16).priority(200).sampling(params)`.
#[derive(Clone, Debug)]
pub struct GenOptions {
    /// Tokens to generate (clamped to at least 1).
    pub max_new: usize,
    /// QoS priority — higher admits sooner ([`PRIORITY_NORMAL`] default);
    /// the session scheduler ages waiters so low never means never.
    pub priority: u8,
    /// Admission deadline (time-to-live while waiting, `None` = wait
    /// indefinitely).  Only the session scheduler enforces it.
    pub deadline: Option<Duration>,
    /// Token-selection override; `None` uses the server's default policy
    /// (`sessions.sampling` on the session server, greedy elsewhere).
    pub sampling: Option<SamplingParams>,
}

impl GenOptions {
    /// Options for `max_new` tokens with default QoS and sampling.
    pub fn new(max_new: usize) -> Self {
        GenOptions { max_new, priority: PRIORITY_NORMAL, deadline: None, sampling: None }
    }

    /// Set the QoS priority.
    pub fn priority(mut self, priority: u8) -> Self {
        self.priority = priority;
        self
    }

    /// Set the admission deadline.
    pub fn deadline(mut self, ttl: Duration) -> Self {
        self.deadline = Some(ttl);
        self
    }

    /// Set the token-selection policy.
    pub fn sampling(mut self, params: SamplingParams) -> Self {
        self.sampling = Some(params);
        self
    }
}

/// Handle to an in-flight streaming generation request
/// ([`Server::generate_stream`]).
///
/// Iterate it (or call [`TokenStream::next_token`]) to receive tokens as
/// they are decoded; call [`TokenStream::wait`] for the final
/// [`Response`].  Every generated token is yielded **exactly once**, in
/// order: tokens the server could not stream before the request finished
/// (slow consumer, tiny buffer) are recovered from the response's full
/// sequence, and a preempted-and-replayed session resumes its stream
/// without duplicating a token.
pub struct TokenStream {
    tokens: Receiver<i32>,
    done: Receiver<Result<Response, String>>,
    /// Tokens already yielded to the consumer (stream + recovered tail).
    yielded: usize,
    /// The resolved terminal result, once observed.
    finished: Option<Result<Response, String>>,
}

impl TokenStream {
    /// Blocking receive of the next token; `None` once the request has
    /// finished and every generated token has been yielded.  A request
    /// that failed (rejected, expired, shut down) ends the stream early —
    /// [`TokenStream::wait`] returns the error.
    pub fn next_token(&mut self) -> Option<i32> {
        if self.finished.is_none() {
            if let Ok(t) = self.tokens.recv() {
                self.yielded += 1;
                return Some(t);
            }
        }
        // channel closed: the request left the server.  Drain any tokens
        // still buffered, then serve the unstreamed tail from the final
        // response so the stream always yields the complete sequence.
        if let Ok(t) = self.tokens.try_recv() {
            self.yielded += 1;
            return Some(t);
        }
        match self.resolve() {
            Ok(r) if self.yielded < r.predictions.len() => {
                let t = r.predictions[self.yielded];
                self.yielded += 1;
                Some(t)
            }
            _ => None,
        }
    }

    /// Block until the request completes and return the final
    /// [`Response`] (its `predictions` always hold the full sequence,
    /// independent of how many tokens were streamed).
    pub fn wait(mut self) -> Result<Response> {
        self.resolve();
        match self.finished.take() {
            Some(Ok(r)) => Ok(r),
            Some(Err(e)) => Err(anyhow::anyhow!(e)),
            None => bail!("server dropped the request"),
        }
    }

    fn resolve(&mut self) -> &Result<Response, String> {
        if self.finished.is_none() {
            let r = self
                .done
                .recv()
                .unwrap_or_else(|_| Err("server dropped the request".to_string()));
            self.finished = Some(r);
        }
        // just populated above; the closure is unreachable
        self.finished.get_or_insert_with(|| Err("unreachable".to_string()))
    }
}

impl Iterator for TokenStream {
    type Item = i32;

    fn next(&mut self) -> Option<i32> {
        self.next_token()
    }
}

/// Executes one formed batch; implemented by the artifact path and the
/// native engine fallback.  Each worker owns its runner.
trait BatchRunner: Send {
    fn run(&self, batch: &Batch, metrics: &Metrics) -> Result<Vec<Response>>;
}

/// Handle to a running server.
pub struct Server {
    ingress: SyncSender<Ingress>,
    /// Live serving metrics (counters, gauges, latency histograms).
    pub metrics: Arc<Metrics>,
    next_id: AtomicU64,
    threads: Vec<JoinHandle<()>>,
    /// Capacity of each per-request token stream channel.
    stream_buffer: usize,
    /// Policy for requests without a [`GenOptions::sampling`] override.
    default_sampling: SamplingParams,
    /// The flight recorder shared with the scheduler thread — present
    /// only on session servers started with `[trace] enabled = true`.
    trace: Option<Arc<FlightRecorder>>,
}

impl Server {
    /// Spin up the batcher + worker threads over the AOT artifact runtime.
    pub fn start(
        runtime: RuntimeHandle,
        manifest: Arc<Manifest>,
        cfg: ServeConfig,
    ) -> Result<Self> {
        let router = Arc::new(Router::new(&manifest, &cfg.model)?);
        // model parameters are loaded once and shared by every worker
        let params = Arc::new(
            manifest
                .load_f32(&format!("{}.params.f32", cfg.model))
                .context("loading model params")?,
        );
        // warm the executable cache so first requests don't pay compile time
        for b in [1usize, cfg.max_batch] {
            if let Ok(route) = router.route(b) {
                runtime.warm(&route.artifact)?;
            }
        }
        Self::start_with(cfg, move || -> Box<dyn BatchRunner> {
            Box::new(ArtifactRunner {
                rt: runtime.clone(),
                router: router.clone(),
                params: params.clone(),
            })
        })
    }

    /// Spin up the batcher + worker threads over the native batched engine
    /// (no artifacts required): each worker routes its batches through a
    /// shared deterministic [`NativeMlm`] whose attention runs on the
    /// parallel engine with `engine_threads` workers.
    pub fn start_native(
        cfg: ServeConfig,
        model_cfg: NativeMlmConfig,
        engine_threads: usize,
    ) -> Result<Self> {
        let model = Arc::new(NativeMlm::new(model_cfg, engine_threads));
        Self::start_with(cfg, move || -> Box<dyn BatchRunner> {
            Box::new(NativeRunner { model: model.clone() })
        })
    }

    /// Spin up the batcher + worker threads over the native causal LM:
    /// generation requests stream through the same dynamic batcher as MLM
    /// inference, and each worker decodes its batch on a shared
    /// [`NativeLm`] (prompt prefill + greedy decode through per-(layer,
    /// head) [`crate::engine::DecodeState`] KV caches).
    ///
    /// This is the **fixed-round** LM path: a formed batch decodes to
    /// completion before its worker takes another, so the slowest request
    /// gates its whole round.  The session server
    /// ([`Server::start_native_lm_sessions`]) replaces it with continuous
    /// batching; this path is kept as the serving baseline
    /// (`benches/bench_serve.rs` measures the gap).
    pub fn start_native_lm(
        cfg: ServeConfig,
        model_cfg: NativeMlmConfig,
        engine_threads: usize,
    ) -> Result<Self> {
        let model = Arc::new(NativeLm::new(model_cfg, engine_threads));
        Self::start_with(cfg, move || -> Box<dyn BatchRunner> {
            Box::new(LmRunner { model: model.clone() })
        })
    }

    /// Spin up the **session-serving** LM server: one scheduler thread
    /// running continuous batching over page-backed KV sessions
    /// ([`crate::coordinator::scheduler`]) — admission against free-page
    /// watermarks, chunked engine-parallel prompt prefill interleaved
    /// with decode steps (`sessions.prefill_chunk_tokens`), per-step
    /// join/leave (no fixed rounds), radix prefix-cache sharing for
    /// common prompts, and preemption with recompute-on-readmit under
    /// memory pressure.  Requests submit
    /// through the same [`Server::generate`] / [`Server::infer`] API, and
    /// outputs are bitwise identical to the fixed-round path.
    pub fn start_native_lm_sessions(
        cfg: ServeConfig,
        model_cfg: NativeMlmConfig,
        engine_threads: usize,
        session_cfg: crate::config::SessionConfig,
    ) -> Result<Self> {
        let model = Arc::new(NativeLm::new(model_cfg, engine_threads));
        let metrics = Arc::new(Metrics::new());
        let (ingress_tx, ingress_rx) = sync_channel::<Ingress>(cfg.queue_depth);
        let stream_buffer = session_cfg.stream_buffer;
        let default_sampling = session_cfg.sampling;
        let trace = session_cfg
            .trace
            .enabled
            .then(|| Arc::new(FlightRecorder::new(session_cfg.trace.capacity)));
        let sched_metrics = metrics.clone();
        let sched_trace = trace.clone();
        let threads = vec![std::thread::spawn(move || {
            crate::coordinator::scheduler::scheduler_loop(
                ingress_rx,
                model,
                session_cfg,
                sched_metrics,
                sched_trace,
            );
        })];
        Ok(Server {
            ingress: ingress_tx,
            metrics,
            next_id: AtomicU64::new(0),
            threads,
            stream_buffer,
            default_sampling,
            trace,
        })
    }

    /// Shared startup: batcher thread + `cfg.workers` workers, one runner
    /// per worker from `make_runner`.
    fn start_with(
        cfg: ServeConfig,
        make_runner: impl Fn() -> Box<dyn BatchRunner>,
    ) -> Result<Self> {
        let metrics = Arc::new(Metrics::new());
        let (ingress_tx, ingress_rx) = sync_channel::<Ingress>(cfg.queue_depth);
        let (batch_tx, batch_rx) =
            sync_channel::<(Batch, Vec<Responder>)>(cfg.workers.max(1) * 2);
        let batch_rx = Arc::new(std::sync::Mutex::new(batch_rx));

        let mut threads = Vec::new();
        // batcher thread
        {
            let cfg = cfg.clone();
            threads.push(std::thread::spawn(move || {
                batcher_loop(ingress_rx, batch_tx, &cfg);
            }));
        }
        // workers
        for _ in 0..cfg.workers.max(1) {
            let rx = batch_rx.clone();
            let runner = make_runner();
            let metrics = metrics.clone();
            threads.push(std::thread::spawn(move || {
                worker_loop(rx, runner, metrics);
            }));
        }
        Ok(Server {
            ingress: ingress_tx,
            metrics,
            next_id: AtomicU64::new(0),
            threads,
            stream_buffer: 32,
            default_sampling: SamplingParams::default(),
            trace: None,
        })
    }

    /// A typed point-in-time copy of the serving metrics (counters +
    /// decode/phase latency snapshots) — see
    /// [`MetricsSnapshot::counter_signature`].
    pub fn metrics_snapshot(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    /// The Prometheus text exposition of the live metrics (the body a
    /// `/metrics` scrape endpoint would serve).
    pub fn render_metrics(&self) -> String {
        self.metrics.render_prometheus()
    }

    /// Dump the flight recorder as JSON lines (chronological), or `None`
    /// when tracing is disabled or this server has no scheduler.  Safe to
    /// call while serving: the dump locks the ring only long enough to
    /// copy it.
    pub fn dump_trace(&self) -> Option<String> {
        self.trace.as_ref().map(|t| t.dump_jsonl())
    }

    /// The flight recorder itself, when tracing is enabled — for callers
    /// that want typed [`crate::coordinator::trace::TraceRecord`]s rather
    /// than the JSONL dump.
    pub fn trace_recorder(&self) -> Option<&Arc<FlightRecorder>> {
        self.trace.as_ref()
    }

    /// Submit a request; blocks until the response arrives.
    /// Returns `Err` on backpressure (queue full) or execution failure.
    pub fn infer(&self, tokens: Vec<i32>) -> Result<Response> {
        self.submit(tokens, 0)
    }

    /// Submit an autoregressive generation request: `tokens` is the
    /// prompt, the response's `predictions` are the `max_new` greedily
    /// decoded token ids.  The request rides the same dynamic batcher as
    /// [`Server::infer`]; only servers started with
    /// [`Server::start_native_lm`] decode it causally (MLM runners treat
    /// it as a predict request).
    pub fn generate(&self, tokens: Vec<i32>, max_new: usize) -> Result<Response> {
        self.submit(tokens, max_new.max(1))
    }

    /// [`Server::generate`] with explicit [`GenOptions`] (priority,
    /// admission deadline, sampling), blocking until the full response.
    pub fn generate_opts(&self, tokens: Vec<i32>, opts: GenOptions) -> Result<Response> {
        let rx = self.post(self.make_req(tokens, &opts, None))?;
        rx.recv()
            .context("server dropped request")?
            .map_err(|e| anyhow::anyhow!(e))
    }

    /// Submit a generation request for **per-token streaming**: returns a
    /// [`TokenStream`] immediately; tokens arrive on it as they are
    /// decoded (bounded buffer `sessions.stream_buffer`; the scheduler
    /// never blocks on a slow consumer, and any unstreamed tail is
    /// recovered from the final [`Response`]).  Under greedy sampling the
    /// streamed sequence is bitwise identical to [`Server::generate`]'s.
    ///
    /// # Examples
    ///
    /// ```
    /// use mra::config::{ServeConfig, SessionConfig};
    /// use mra::coordinator::native::NativeMlmConfig;
    /// use mra::coordinator::server::{GenOptions, Server};
    ///
    /// let cfg = ServeConfig {
    ///     model: "mlm_mra2_n64_d32_l1_h2_v64".to_string(),
    ///     ..ServeConfig::default_config()
    /// };
    /// let model_cfg = NativeMlmConfig::from_tag(&cfg.model);
    /// let server = Server::start_native_lm_sessions(
    ///     cfg, model_cfg, 2, SessionConfig::default())?;
    ///
    /// let mut stream = server.generate_stream(vec![2, 9, 11], GenOptions::new(4))?;
    /// let tokens: Vec<i32> = stream.by_ref().collect(); // arrive per token
    /// let response = stream.wait()?;                    // full sequence
    /// assert_eq!(tokens, response.predictions);
    /// server.shutdown();
    /// # Ok::<(), anyhow::Error>(())
    /// ```
    pub fn generate_stream(&self, tokens: Vec<i32>, opts: GenOptions) -> Result<TokenStream> {
        let (stx, srx) = sync_channel::<i32>(self.stream_buffer.max(1));
        let done = self.post(self.make_req(tokens, &opts, Some(stx)))?;
        Ok(TokenStream { tokens: srx, done, yielded: 0, finished: None })
    }

    fn make_req(
        &self,
        tokens: Vec<i32>,
        opts: &GenOptions,
        stream: Option<SyncSender<i32>>,
    ) -> Request {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        Request {
            priority: opts.priority,
            deadline: opts.deadline,
            sampling: opts.sampling.unwrap_or(self.default_sampling),
            stream,
            ..Request::new(id, tokens, opts.max_new.max(1))
        }
    }

    /// Enqueue a request; the returned receiver resolves to its terminal
    /// result.  `Err` on backpressure or a stopped server.
    fn post(&self, req: Request) -> Result<Receiver<Result<Response, String>>> {
        let (tx, rx) = std::sync::mpsc::channel();
        self.metrics.inc_requests();
        match self.ingress.try_send(Ingress::Req(req, tx)) {
            Ok(()) => Ok(rx),
            Err(TrySendError::Full(_)) => {
                self.metrics.inc_rejected();
                bail!("server busy (queue full)");
            }
            Err(TrySendError::Disconnected(_)) => bail!("server stopped"),
        }
    }

    fn submit(&self, tokens: Vec<i32>, gen_tokens: usize) -> Result<Response> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let rx = self.post(Request::new(id, tokens, gen_tokens))?;
        rx.recv()
            .context("server dropped request")?
            .map_err(|e| anyhow::anyhow!(e))
    }

    /// Graceful shutdown: flush pending batches, join threads.
    pub fn shutdown(mut self) {
        let _ = self.ingress.send(Ingress::Shutdown);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

fn batcher_loop(
    ingress: Receiver<Ingress>,
    batch_tx: SyncSender<(Batch, Vec<Responder>)>,
    cfg: &ServeConfig,
) {
    let mut batcher = Batcher::new(cfg.max_batch, Duration::from_micros(cfg.flush_us));
    let mut responders: Vec<Responder> = Vec::new();
    let idle_wait = Duration::from_micros(cfg.flush_us.max(100));
    loop {
        // §bugfix: bound the wait by the *oldest* pending request's
        // remaining deadline.  The old `recv_timeout(flush_us)` reset on
        // every arrival, so a steady trickle of sub-`max_batch` requests
        // (inter-arrival < flush_us) postponed the flush indefinitely and
        // the oldest request waited unboundedly.
        let wait = match batcher.next_deadline(Instant::now()) {
            Some(d) => d.min(idle_wait),
            None => idle_wait,
        };
        match ingress.recv_timeout(wait) {
            Ok(Ingress::Req(req, resp)) => {
                responders.push(resp);
                // check the deadline after every push, not only on idle gaps
                let due = match batcher.push(req) {
                    Some(batch) => Some(batch),
                    None => batcher.poll_due(Instant::now()),
                };
                if let Some(batch) = due {
                    let rs = responders.drain(..).collect();
                    if batch_tx.send((batch, rs)).is_err() {
                        return;
                    }
                }
            }
            Ok(Ingress::Shutdown) => {
                if let Some(batch) = batcher.drain() {
                    let rs = responders.drain(..).collect();
                    let _ = batch_tx.send((batch, rs));
                }
                return;
            }
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                if let Some(batch) = batcher.poll_due(Instant::now()) {
                    let rs = responders.drain(..).collect();
                    if batch_tx.send((batch, rs)).is_err() {
                        return;
                    }
                }
            }
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                if let Some(batch) = batcher.drain() {
                    let rs = responders.drain(..).collect();
                    let _ = batch_tx.send((batch, rs));
                }
                return;
            }
        }
    }
}

#[allow(clippy::type_complexity)]
fn worker_loop(
    rx: Arc<std::sync::Mutex<Receiver<(Batch, Vec<Responder>)>>>,
    runner: Box<dyn BatchRunner>,
    metrics: Arc<Metrics>,
) {
    loop {
        let item = {
            // a poisoned receiver mutex means a sibling worker panicked
            // while holding it; the channel itself is still sound, so
            // recover the guard — exiting here would strand every batch
            // (and its responders) still in flight
            let guard = match rx.lock() {
                Ok(g) => g,
                Err(poisoned) => poisoned.into_inner(),
            };
            guard.recv()
        };
        let (batch, responders) = match item {
            Ok(x) => x,
            Err(_) => return,
        };
        let result = runner.run(&batch, &metrics);
        match result {
            Ok(mut responses) => {
                for (resp, tx) in responses.drain(..).zip(responders) {
                    let _ = tx.send(Ok(resp));
                }
            }
            Err(e) => {
                let msg = format!("{e:#}");
                for tx in responders {
                    let _ = tx.send(Err(msg.clone()));
                }
            }
        }
    }
}

/// AOT artifact path: route the batch to a bucket executable, execute
/// through PJRT, slice the logits back per request.
struct ArtifactRunner {
    rt: RuntimeHandle,
    router: Arc<Router>,
    params: Arc<Vec<f32>>,
}

impl BatchRunner for ArtifactRunner {
    fn run(&self, batch: &Batch, metrics: &Metrics) -> Result<Vec<Response>> {
        let route = self.router.route(batch.len())?;
        let rows: Vec<Vec<i32>> = batch.requests.iter().map(|r| r.tokens.clone()).collect();
        let ids = self.router.pad_tokens(&rows, route.bucket)?;
        let n = self.router.seq_len;
        let inputs = vec![
            HostTensor::F32(self.params.to_vec(), vec![self.params.len()]),
            HostTensor::I32(ids, vec![route.bucket, n]),
        ];
        let t0 = Instant::now();
        let outputs = self.rt.execute(&route.artifact, inputs)?;
        metrics.batch_exec.record(t0.elapsed());
        metrics.inc_batches(route.padded_slots as u64);
        // logits: (bucket, n, vocab) -> per-request argmax over the vocab
        let logits = outputs[0].as_f32()?;
        let dims = outputs[0].dims();
        let vocab = dims[2];
        let mut out = Vec::with_capacity(batch.len());
        for (bi, req) in batch.requests.iter().enumerate() {
            let len = req.tokens.len();
            let mut preds = Vec::with_capacity(len);
            for pos in 0..len {
                let base = (bi * n + pos) * vocab;
                preds.push(crate::tensor::ops::argmax(&logits[base..base + vocab]) as i32);
            }
            let latency = req.arrived.elapsed();
            metrics.request_latency.record(latency);
            out.push(Response { id: req.id, predictions: preds, latency });
        }
        Ok(out)
    }
}

/// Native fallback: run the whole batch through the deterministic
/// [`NativeMlm`] forward (batched multi-head attention on the engine).
struct NativeRunner {
    model: Arc<NativeMlm>,
}

impl BatchRunner for NativeRunner {
    fn run(&self, batch: &Batch, metrics: &Metrics) -> Result<Vec<Response>> {
        let rows: Vec<Vec<i32>> = batch.requests.iter().map(|r| r.tokens.clone()).collect();
        let t0 = Instant::now();
        let preds = self.model.predict(&rows)?;
        metrics.batch_exec.record(t0.elapsed());
        metrics.inc_batches(0);
        let mut out = Vec::with_capacity(batch.len());
        for (req, predictions) in batch.requests.iter().zip(preds) {
            let latency = req.arrived.elapsed();
            metrics.request_latency.record(latency);
            out.push(Response { id: req.id, predictions, latency });
        }
        Ok(out)
    }
}

/// Causal-LM fallback: greedily decode every request of the batch through
/// the shared [`NativeLm`] (prompt prefill + incremental KV-cache decode;
/// the per-head attention of each step runs on the engine's worker pool).
/// A malformed request fails its whole batch, mirroring [`NativeRunner`].
struct LmRunner {
    model: Arc<NativeLm>,
}

impl BatchRunner for LmRunner {
    fn run(&self, batch: &Batch, metrics: &Metrics) -> Result<Vec<Response>> {
        let t0 = Instant::now();
        let mut out = Vec::with_capacity(batch.len());
        for req in &batch.requests {
            let n = req.gen_tokens.max(1);
            let predictions = match req.stream.as_ref() {
                Some(stx) => {
                    // non-blocking delivery with prefix semantics: on the
                    // first full/closed buffer, stop streaming this request
                    // entirely (the fixed-round path has no retry step), so
                    // the stream stays an exact prefix — never a token
                    // skipped mid-stream — and the tail comes from the
                    // Response's full sequence
                    let mut open = true;
                    self.model.generate_sampled_with(&req.tokens, n, req.sampling, |_, t| {
                        if open {
                            match stx.try_send(t) {
                                Ok(()) => {
                                    metrics.streamed_tokens.fetch_add(1, Ordering::Relaxed);
                                }
                                Err(_) => {
                                    metrics.stream_stalls.fetch_add(1, Ordering::Relaxed);
                                    open = false;
                                }
                            }
                        }
                    })?
                }
                None => self.model.generate_sampled(&req.tokens, n, req.sampling)?,
            };
            let latency = req.arrived.elapsed();
            metrics.request_latency.record(latency);
            out.push(Response { id: req.id, predictions, latency });
        }
        metrics.batch_exec.record(t0.elapsed());
        metrics.inc_batches(0);
        Ok(out)
    }
}

// Integration tests that exercise Server against real artifacts live in
// rust/tests/ (skipped when artifacts/ is absent); the native path and the
// batcher loop are covered below without artifacts.

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    fn serve_cfg(max_batch: usize, flush_us: u64) -> ServeConfig {
        ServeConfig {
            max_batch,
            flush_us,
            workers: 1,
            queue_depth: 64,
            model: "mlm_mra2_n64_d32_l1_h2_v64".to_string(),
            artifacts_dir: "artifacts".to_string(),
        }
    }

    /// Regression for the deadline-starvation bug: requests arriving
    /// steadily but slower than `max_batch` fills must still flush once
    /// the *oldest* request exceeds `flush_us`, not only on an idle gap.
    #[test]
    fn batcher_loop_flushes_oldest_under_steady_trickle() {
        let cfg = serve_cfg(64, 20_000); // flush after 20ms, never fills 64
        let (in_tx, in_rx) = sync_channel::<Ingress>(64);
        let (b_tx, b_rx) = sync_channel::<(Batch, Vec<Responder>)>(16);
        let loop_cfg = cfg.clone();
        let handle = std::thread::spawn(move || batcher_loop(in_rx, b_tx, &loop_cfg));

        // steady trickle: 50 requests, one every 2ms (inter-arrival far
        // below flush_us) — the old loop only flushed after the last send
        let mut keep_alive = Vec::new();
        for id in 0..50u64 {
            let (tx, rx) = std::sync::mpsc::channel();
            keep_alive.push(rx);
            let req = Request { id, tokens: vec![2, 3], gen_tokens: 0, arrived: Instant::now() };
            in_tx.send(Ingress::Req(req, tx)).unwrap();
            std::thread::sleep(Duration::from_millis(2));
        }
        drop(in_tx); // disconnect -> final drain

        let mut batches = Vec::new();
        while let Ok((batch, rs)) = b_rx.recv_timeout(Duration::from_secs(5)) {
            assert_eq!(batch.len(), rs.len(), "responders must track requests");
            batches.push(batch);
        }
        handle.join().unwrap();

        // every request accounted for, FIFO order preserved
        let ids: Vec<u64> = batches.iter().flat_map(|b| b.requests.iter().map(|r| r.id)).collect();
        assert_eq!(ids, (0..50).collect::<Vec<u64>>());
        // the fix: the first flush happens at the ~20ms deadline (a dozen
        // requests in), not after the full 100ms trickle
        assert!(batches.len() >= 2, "single batch => oldest request starved");
        assert!(
            batches[0].len() < 40,
            "first flush held {} requests — deadline ignored under trickle",
            batches[0].len()
        );
        assert_eq!(batches[0].requests[0].id, 0);
    }

    /// End-to-end native serving: batcher -> worker -> batched engine.
    #[test]
    fn native_server_round_trip_under_concurrency() {
        let cfg = serve_cfg(4, 500);
        let model_cfg = NativeMlmConfig::from_tag(&cfg.model);
        let server =
            Arc::new(Server::start_native(cfg, model_cfg, 2).expect("native server"));
        std::thread::scope(|s| {
            for c in 0..3u64 {
                let server = server.clone();
                s.spawn(move || {
                    for r in 0..4u64 {
                        let len = 8 + ((c * 7 + r) % 40) as usize;
                        let toks: Vec<i32> = (0..len).map(|t| 4 + (t as i32 % 60)).collect();
                        let resp = server.infer(toks.clone()).expect("infer");
                        assert_eq!(resp.predictions.len(), toks.len());
                        assert!(resp.predictions.iter().all(|&p| p >= 0 && p < 64));
                    }
                });
            }
        });
        assert_eq!(server.metrics.requests.load(Ordering::Relaxed), 12);
        assert!(server.metrics.batches.load(Ordering::Relaxed) >= 1);
        if let Ok(s) = Arc::try_unwrap(server) {
            s.shutdown();
        }
    }

    /// Generation requests ride the same batcher: prompt in, greedy token
    /// stream out, identical to the direct (serverless) decode path.
    #[test]
    fn native_lm_server_generates_through_the_batcher() {
        let cfg = serve_cfg(4, 500);
        let model_cfg = NativeMlmConfig::from_tag(&cfg.model);
        let server = Server::start_native_lm(cfg, model_cfg.clone(), 2).expect("lm server");
        let resp = server.generate(vec![2, 9, 11], 4).expect("generate");
        assert_eq!(resp.predictions.len(), 4);
        assert!(resp.predictions.iter().all(|&t| t >= 0 && (t as usize) < 64));
        // bitwise identical to the direct model path (deterministic decode)
        let direct = NativeLm::new(model_cfg, 2).generate(&[2, 9, 11], 4).unwrap();
        assert_eq!(resp.predictions, direct);
        // infer() on an LM server decodes a single next token
        let one = server.infer(vec![2, 9]).expect("infer");
        assert_eq!(one.predictions.len(), 1);
        // prompts that cannot fit the requested continuation error cleanly
        let err = server.generate(vec![2; 64], 8).unwrap_err();
        assert!(format!("{err:#}").contains("seq_len"), "{err:#}");
        server.shutdown();
    }

    /// The session server answers the same API as the fixed-round LM
    /// server, bitwise identically, and reports prefix-cache reuse for a
    /// repeated prompt in its stats.
    #[test]
    fn session_server_matches_fixed_round_and_reports_cache_hits() {
        use crate::config::SessionConfig;
        let cfg = serve_cfg(4, 500);
        let model_cfg = NativeMlmConfig::from_tag(&cfg.model);
        let scfg = SessionConfig { total_pages: 512, free_watermark: 8, ..Default::default() };
        let server =
            Server::start_native_lm_sessions(cfg.clone(), model_cfg.clone(), 2, scfg)
                .expect("session server");
        // longer than one block (32 for this tag) so the repeat can hit
        let prompt: Vec<i32> = (0..40).map(|i| 2 + (i as i32 * 7) % 60).collect();
        let resp = server.generate(prompt.clone(), 4).expect("generate");
        // bitwise identical to the direct model path and the batcher path
        let direct = NativeLm::new(model_cfg.clone(), 2).generate(&prompt, 4).unwrap();
        assert_eq!(resp.predictions, direct);
        let fixed = Server::start_native_lm(cfg, model_cfg, 2).expect("lm server");
        let fixed_resp = fixed.generate(prompt.clone(), 4).expect("fixed generate");
        assert_eq!(fixed_resp.predictions, direct);
        fixed.shutdown();
        // repeated prompt: served from shared prefix pages
        let resp2 = server.generate(prompt.clone(), 4).expect("second generate");
        assert_eq!(resp2.predictions, direct);
        assert!(
            server.metrics.prefix_hit_tokens.load(Ordering::Relaxed) >= 16,
            "{}",
            server.metrics.summary()
        );
        assert!(server.metrics.summary().contains("sessions="), "stats must surface sessions");
        // infer() decodes one token, errors stay clean
        let one = server.infer(vec![2, 9]).expect("infer");
        assert_eq!(one.predictions.len(), 1);
        let err = server.generate(vec![2; 64], 8).unwrap_err();
        assert!(format!("{err:#}").contains("seq_len"), "{err:#}");
        server.shutdown();
    }

    /// Stream-vs-one-shot equality under greedy decoding, on both LM
    /// backends: the streamed token sequence and the final response are
    /// bitwise identical to the finish-only `generate` path.
    #[test]
    fn generate_stream_matches_generate_on_both_backends() {
        use crate::config::SessionConfig;
        let cfg = serve_cfg(4, 500);
        let model_cfg = NativeMlmConfig::from_tag(&cfg.model);
        let scfg = SessionConfig { total_pages: 512, free_watermark: 8, ..Default::default() };
        let sessions = Server::start_native_lm_sessions(cfg.clone(), model_cfg.clone(), 2, scfg)
            .expect("session server");
        let fixed = Server::start_native_lm(cfg, model_cfg, 2).expect("lm server");
        let prompt = vec![2, 9, 11, 30];
        let want = fixed.generate(prompt.clone(), 6).expect("finish-only").predictions;
        for server in [&sessions, &fixed] {
            let mut stream =
                server.generate_stream(prompt.clone(), GenOptions::new(6)).expect("stream");
            let tokens: Vec<i32> = stream.by_ref().collect();
            let resp = stream.wait().expect("streamed response");
            assert_eq!(tokens, want, "stream-vs-one-shot mismatch");
            assert_eq!(resp.predictions, want, "response must carry the full sequence");
        }
        assert_eq!(sessions.metrics.streamed_tokens.load(Ordering::Relaxed), 6);
        assert_eq!(fixed.metrics.streamed_tokens.load(Ordering::Relaxed), 6);
        sessions.shutdown();
        fixed.shutdown();
    }

    /// Sampled serving is deterministic per seed and matches the direct
    /// (serverless) sampled decode bitwise.
    #[test]
    fn sampled_requests_reproduce_per_seed_and_match_the_direct_path() {
        use crate::config::SessionConfig;
        let cfg = serve_cfg(4, 500);
        let model_cfg = NativeMlmConfig::from_tag(&cfg.model);
        let scfg = SessionConfig { total_pages: 512, free_watermark: 8, ..Default::default() };
        let server = Server::start_native_lm_sessions(cfg, model_cfg.clone(), 2, scfg)
            .expect("session server");
        let prompt = vec![2, 9, 11, 30];
        let params = SamplingParams { temperature: 0.8, top_k: 8, top_p: 0.95, seed: 42 };
        let a = server
            .generate_opts(prompt.clone(), GenOptions::new(6).sampling(params))
            .expect("sampled");
        let b = server
            .generate_opts(prompt.clone(), GenOptions::new(6).sampling(params))
            .expect("sampled repeat");
        assert_eq!(a.predictions, b.predictions, "same seed must reproduce bitwise");
        let direct = NativeLm::new(model_cfg, 2).generate_sampled(&prompt, 6, params).unwrap();
        assert_eq!(a.predictions, direct, "served sampling diverged from the direct path");
        server.shutdown();
    }

    /// Over-long requests error cleanly instead of poisoning the batch
    /// pipeline for other requests.
    #[test]
    fn native_server_rejects_oversized_requests() {
        let cfg = serve_cfg(2, 300);
        let model_cfg = NativeMlmConfig::from_tag(&cfg.model);
        let server = Server::start_native(cfg, model_cfg, 1).expect("native server");
        let err = server.infer(vec![2; 65]).unwrap_err();
        assert!(format!("{err:#}").contains("seq_len"), "{err:#}");
        // server still serves well-formed requests afterwards
        let ok = server.infer(vec![2, 9, 11]).expect("infer after error");
        assert_eq!(ok.predictions.len(), 3);
        server.shutdown();
    }
}
