//! Serving loop: bounded ingress queue -> dynamic batcher -> worker pool.
//! Threads + channels (no async runtime available offline); the
//! architecture mirrors a vLLM-style router with one compiled executable
//! per `(model, batch-bucket)`.
//!
//! ```text
//!  submit() --sync_channel(queue_depth)--> batcher thread --+--> worker 0
//!     ^                                   (deadline flush)  +--> worker 1
//!     `-- backpressure: TrySendError => Busy                ...
//! ```
//!
//! Workers execute batches through a [`BatchRunner`]: the AOT artifact
//! path (PJRT runtime + bucket router, [`Server::start`]), the native MLM
//! fallback ([`Server::start_native`]) that routes the batch through the
//! parallel batched engine when `artifacts/` is absent, or the native
//! causal-LM path ([`Server::start_native_lm`]) that greedily decodes
//! generation requests ([`Server::generate`]) through incremental KV
//! caches.
//!
//! LM generation has a second, preferred backend:
//! [`Server::start_native_lm_sessions`] swaps the batcher + workers for
//! the continuous-batching session scheduler
//! ([`crate::coordinator::scheduler`]) — paged KV cache, radix prefix
//! sharing, per-step join/leave — behind the same submit API.

// a panic in the batcher or a worker drops every responder it holds and
// hangs the waiting clients — request paths handle errors, they don't
// unwrap them.  `cargo xtask lint` enforces the same rule textually.
#![deny(clippy::unwrap_used, clippy::expect_used)]

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, Sender, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::config::ServeConfig;
use crate::coordinator::batcher::{Batch, Batcher, Request};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::native::{NativeLm, NativeMlm, NativeMlmConfig};
use crate::coordinator::router::Router;
use crate::runtime::{HostTensor, Manifest, RuntimeHandle};

/// Per-request response: argmax token predictions for the request's
/// positions (MLM head output), or the generated token stream for
/// autoregressive requests ([`Server::generate`]).
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub predictions: Vec<i32>,
    pub latency: Duration,
}

pub(crate) type Responder = Sender<Result<Response, String>>;

pub(crate) enum Ingress {
    Req(Request, Responder),
    Shutdown,
}

/// Executes one formed batch; implemented by the artifact path and the
/// native engine fallback.  Each worker owns its runner.
trait BatchRunner: Send {
    fn run(&self, batch: &Batch, metrics: &Metrics) -> Result<Vec<Response>>;
}

/// Handle to a running server.
pub struct Server {
    ingress: SyncSender<Ingress>,
    pub metrics: Arc<Metrics>,
    next_id: AtomicU64,
    threads: Vec<JoinHandle<()>>,
}

impl Server {
    /// Spin up the batcher + worker threads over the AOT artifact runtime.
    pub fn start(
        runtime: RuntimeHandle,
        manifest: Arc<Manifest>,
        cfg: ServeConfig,
    ) -> Result<Self> {
        let router = Arc::new(Router::new(&manifest, &cfg.model)?);
        // model parameters are loaded once and shared by every worker
        let params = Arc::new(
            manifest
                .load_f32(&format!("{}.params.f32", cfg.model))
                .context("loading model params")?,
        );
        // warm the executable cache so first requests don't pay compile time
        for b in [1usize, cfg.max_batch] {
            if let Ok(route) = router.route(b) {
                runtime.warm(&route.artifact)?;
            }
        }
        Self::start_with(cfg, move || -> Box<dyn BatchRunner> {
            Box::new(ArtifactRunner {
                rt: runtime.clone(),
                router: router.clone(),
                params: params.clone(),
            })
        })
    }

    /// Spin up the batcher + worker threads over the native batched engine
    /// (no artifacts required): each worker routes its batches through a
    /// shared deterministic [`NativeMlm`] whose attention runs on the
    /// parallel engine with `engine_threads` workers.
    pub fn start_native(
        cfg: ServeConfig,
        model_cfg: NativeMlmConfig,
        engine_threads: usize,
    ) -> Result<Self> {
        let model = Arc::new(NativeMlm::new(model_cfg, engine_threads));
        Self::start_with(cfg, move || -> Box<dyn BatchRunner> {
            Box::new(NativeRunner { model: model.clone() })
        })
    }

    /// Spin up the batcher + worker threads over the native causal LM:
    /// generation requests stream through the same dynamic batcher as MLM
    /// inference, and each worker decodes its batch on a shared
    /// [`NativeLm`] (prompt prefill + greedy decode through per-(layer,
    /// head) [`crate::engine::DecodeState`] KV caches).
    ///
    /// This is the **fixed-round** LM path: a formed batch decodes to
    /// completion before its worker takes another, so the slowest request
    /// gates its whole round.  The session server
    /// ([`Server::start_native_lm_sessions`]) replaces it with continuous
    /// batching; this path is kept as the serving baseline
    /// (`benches/bench_serve.rs` measures the gap).
    pub fn start_native_lm(
        cfg: ServeConfig,
        model_cfg: NativeMlmConfig,
        engine_threads: usize,
    ) -> Result<Self> {
        let model = Arc::new(NativeLm::new(model_cfg, engine_threads));
        Self::start_with(cfg, move || -> Box<dyn BatchRunner> {
            Box::new(LmRunner { model: model.clone() })
        })
    }

    /// Spin up the **session-serving** LM server: one scheduler thread
    /// running continuous batching over page-backed KV sessions
    /// ([`crate::coordinator::scheduler`]) — admission against free-page
    /// watermarks, chunked engine-parallel prompt prefill interleaved
    /// with decode steps (`sessions.prefill_chunk_tokens`), per-step
    /// join/leave (no fixed rounds), radix prefix-cache sharing for
    /// common prompts, and preemption with recompute-on-readmit under
    /// memory pressure.  Requests submit
    /// through the same [`Server::generate`] / [`Server::infer`] API, and
    /// outputs are bitwise identical to the fixed-round path.
    pub fn start_native_lm_sessions(
        cfg: ServeConfig,
        model_cfg: NativeMlmConfig,
        engine_threads: usize,
        session_cfg: crate::config::SessionConfig,
    ) -> Result<Self> {
        let model = Arc::new(NativeLm::new(model_cfg, engine_threads));
        let metrics = Arc::new(Metrics::new());
        let (ingress_tx, ingress_rx) = sync_channel::<Ingress>(cfg.queue_depth);
        let sched_metrics = metrics.clone();
        let threads = vec![std::thread::spawn(move || {
            crate::coordinator::scheduler::scheduler_loop(
                ingress_rx,
                model,
                session_cfg,
                sched_metrics,
            );
        })];
        Ok(Server { ingress: ingress_tx, metrics, next_id: AtomicU64::new(0), threads })
    }

    /// Shared startup: batcher thread + `cfg.workers` workers, one runner
    /// per worker from `make_runner`.
    fn start_with(
        cfg: ServeConfig,
        make_runner: impl Fn() -> Box<dyn BatchRunner>,
    ) -> Result<Self> {
        let metrics = Arc::new(Metrics::new());
        let (ingress_tx, ingress_rx) = sync_channel::<Ingress>(cfg.queue_depth);
        let (batch_tx, batch_rx) =
            sync_channel::<(Batch, Vec<Responder>)>(cfg.workers.max(1) * 2);
        let batch_rx = Arc::new(std::sync::Mutex::new(batch_rx));

        let mut threads = Vec::new();
        // batcher thread
        {
            let cfg = cfg.clone();
            threads.push(std::thread::spawn(move || {
                batcher_loop(ingress_rx, batch_tx, &cfg);
            }));
        }
        // workers
        for _ in 0..cfg.workers.max(1) {
            let rx = batch_rx.clone();
            let runner = make_runner();
            let metrics = metrics.clone();
            threads.push(std::thread::spawn(move || {
                worker_loop(rx, runner, metrics);
            }));
        }
        Ok(Server { ingress: ingress_tx, metrics, next_id: AtomicU64::new(0), threads })
    }

    /// Submit a request; blocks until the response arrives.
    /// Returns `Err` on backpressure (queue full) or execution failure.
    pub fn infer(&self, tokens: Vec<i32>) -> Result<Response> {
        self.submit(tokens, 0)
    }

    /// Submit an autoregressive generation request: `tokens` is the
    /// prompt, the response's `predictions` are the `max_new` greedily
    /// decoded token ids.  The request rides the same dynamic batcher as
    /// [`Server::infer`]; only servers started with
    /// [`Server::start_native_lm`] decode it causally (MLM runners treat
    /// it as a predict request).
    pub fn generate(&self, tokens: Vec<i32>, max_new: usize) -> Result<Response> {
        self.submit(tokens, max_new.max(1))
    }

    fn submit(&self, tokens: Vec<i32>, gen_tokens: usize) -> Result<Response> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = std::sync::mpsc::channel();
        let req = Request { id, tokens, gen_tokens, arrived: Instant::now() };
        self.metrics.inc_requests();
        match self.ingress.try_send(Ingress::Req(req, tx)) {
            Ok(()) => {}
            Err(TrySendError::Full(_)) => {
                self.metrics.inc_rejected();
                bail!("server busy (queue full)");
            }
            Err(TrySendError::Disconnected(_)) => bail!("server stopped"),
        }
        rx.recv()
            .context("server dropped request")?
            .map_err(|e| anyhow::anyhow!(e))
    }

    /// Graceful shutdown: flush pending batches, join threads.
    pub fn shutdown(mut self) {
        let _ = self.ingress.send(Ingress::Shutdown);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

fn batcher_loop(
    ingress: Receiver<Ingress>,
    batch_tx: SyncSender<(Batch, Vec<Responder>)>,
    cfg: &ServeConfig,
) {
    let mut batcher = Batcher::new(cfg.max_batch, Duration::from_micros(cfg.flush_us));
    let mut responders: Vec<Responder> = Vec::new();
    let idle_wait = Duration::from_micros(cfg.flush_us.max(100));
    loop {
        // §bugfix: bound the wait by the *oldest* pending request's
        // remaining deadline.  The old `recv_timeout(flush_us)` reset on
        // every arrival, so a steady trickle of sub-`max_batch` requests
        // (inter-arrival < flush_us) postponed the flush indefinitely and
        // the oldest request waited unboundedly.
        let wait = match batcher.next_deadline(Instant::now()) {
            Some(d) => d.min(idle_wait),
            None => idle_wait,
        };
        match ingress.recv_timeout(wait) {
            Ok(Ingress::Req(req, resp)) => {
                responders.push(resp);
                // check the deadline after every push, not only on idle gaps
                let due = match batcher.push(req) {
                    Some(batch) => Some(batch),
                    None => batcher.poll_due(Instant::now()),
                };
                if let Some(batch) = due {
                    let rs = responders.drain(..).collect();
                    if batch_tx.send((batch, rs)).is_err() {
                        return;
                    }
                }
            }
            Ok(Ingress::Shutdown) => {
                if let Some(batch) = batcher.drain() {
                    let rs = responders.drain(..).collect();
                    let _ = batch_tx.send((batch, rs));
                }
                return;
            }
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                if let Some(batch) = batcher.poll_due(Instant::now()) {
                    let rs = responders.drain(..).collect();
                    if batch_tx.send((batch, rs)).is_err() {
                        return;
                    }
                }
            }
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                if let Some(batch) = batcher.drain() {
                    let rs = responders.drain(..).collect();
                    let _ = batch_tx.send((batch, rs));
                }
                return;
            }
        }
    }
}

#[allow(clippy::type_complexity)]
fn worker_loop(
    rx: Arc<std::sync::Mutex<Receiver<(Batch, Vec<Responder>)>>>,
    runner: Box<dyn BatchRunner>,
    metrics: Arc<Metrics>,
) {
    loop {
        let item = {
            // a poisoned receiver mutex means a sibling worker panicked
            // while holding it; the channel itself is still sound, so
            // recover the guard — exiting here would strand every batch
            // (and its responders) still in flight
            let guard = match rx.lock() {
                Ok(g) => g,
                Err(poisoned) => poisoned.into_inner(),
            };
            guard.recv()
        };
        let (batch, responders) = match item {
            Ok(x) => x,
            Err(_) => return,
        };
        let result = runner.run(&batch, &metrics);
        match result {
            Ok(mut responses) => {
                for (resp, tx) in responses.drain(..).zip(responders) {
                    let _ = tx.send(Ok(resp));
                }
            }
            Err(e) => {
                let msg = format!("{e:#}");
                for tx in responders {
                    let _ = tx.send(Err(msg.clone()));
                }
            }
        }
    }
}

/// AOT artifact path: route the batch to a bucket executable, execute
/// through PJRT, slice the logits back per request.
struct ArtifactRunner {
    rt: RuntimeHandle,
    router: Arc<Router>,
    params: Arc<Vec<f32>>,
}

impl BatchRunner for ArtifactRunner {
    fn run(&self, batch: &Batch, metrics: &Metrics) -> Result<Vec<Response>> {
        let route = self.router.route(batch.len())?;
        let rows: Vec<Vec<i32>> = batch.requests.iter().map(|r| r.tokens.clone()).collect();
        let ids = self.router.pad_tokens(&rows, route.bucket)?;
        let n = self.router.seq_len;
        let inputs = vec![
            HostTensor::F32(self.params.to_vec(), vec![self.params.len()]),
            HostTensor::I32(ids, vec![route.bucket, n]),
        ];
        let t0 = Instant::now();
        let outputs = self.rt.execute(&route.artifact, inputs)?;
        metrics.batch_exec.record(t0.elapsed());
        metrics.inc_batches(route.padded_slots as u64);
        // logits: (bucket, n, vocab) -> per-request argmax over the vocab
        let logits = outputs[0].as_f32()?;
        let dims = outputs[0].dims();
        let vocab = dims[2];
        let mut out = Vec::with_capacity(batch.len());
        for (bi, req) in batch.requests.iter().enumerate() {
            let len = req.tokens.len();
            let mut preds = Vec::with_capacity(len);
            for pos in 0..len {
                let base = (bi * n + pos) * vocab;
                preds.push(crate::tensor::ops::argmax(&logits[base..base + vocab]) as i32);
            }
            let latency = req.arrived.elapsed();
            metrics.request_latency.record(latency);
            out.push(Response { id: req.id, predictions: preds, latency });
        }
        Ok(out)
    }
}

/// Native fallback: run the whole batch through the deterministic
/// [`NativeMlm`] forward (batched multi-head attention on the engine).
struct NativeRunner {
    model: Arc<NativeMlm>,
}

impl BatchRunner for NativeRunner {
    fn run(&self, batch: &Batch, metrics: &Metrics) -> Result<Vec<Response>> {
        let rows: Vec<Vec<i32>> = batch.requests.iter().map(|r| r.tokens.clone()).collect();
        let t0 = Instant::now();
        let preds = self.model.predict(&rows)?;
        metrics.batch_exec.record(t0.elapsed());
        metrics.inc_batches(0);
        let mut out = Vec::with_capacity(batch.len());
        for (req, predictions) in batch.requests.iter().zip(preds) {
            let latency = req.arrived.elapsed();
            metrics.request_latency.record(latency);
            out.push(Response { id: req.id, predictions, latency });
        }
        Ok(out)
    }
}

/// Causal-LM fallback: greedily decode every request of the batch through
/// the shared [`NativeLm`] (prompt prefill + incremental KV-cache decode;
/// the per-head attention of each step runs on the engine's worker pool).
/// A malformed request fails its whole batch, mirroring [`NativeRunner`].
struct LmRunner {
    model: Arc<NativeLm>,
}

impl BatchRunner for LmRunner {
    fn run(&self, batch: &Batch, metrics: &Metrics) -> Result<Vec<Response>> {
        let t0 = Instant::now();
        let mut out = Vec::with_capacity(batch.len());
        for req in &batch.requests {
            let predictions = self.model.generate(&req.tokens, req.gen_tokens.max(1))?;
            let latency = req.arrived.elapsed();
            metrics.request_latency.record(latency);
            out.push(Response { id: req.id, predictions, latency });
        }
        metrics.batch_exec.record(t0.elapsed());
        metrics.inc_batches(0);
        Ok(out)
    }
}

// Integration tests that exercise Server against real artifacts live in
// rust/tests/ (skipped when artifacts/ is absent); the native path and the
// batcher loop are covered below without artifacts.

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    fn serve_cfg(max_batch: usize, flush_us: u64) -> ServeConfig {
        ServeConfig {
            max_batch,
            flush_us,
            workers: 1,
            queue_depth: 64,
            model: "mlm_mra2_n64_d32_l1_h2_v64".to_string(),
            artifacts_dir: "artifacts".to_string(),
        }
    }

    /// Regression for the deadline-starvation bug: requests arriving
    /// steadily but slower than `max_batch` fills must still flush once
    /// the *oldest* request exceeds `flush_us`, not only on an idle gap.
    #[test]
    fn batcher_loop_flushes_oldest_under_steady_trickle() {
        let cfg = serve_cfg(64, 20_000); // flush after 20ms, never fills 64
        let (in_tx, in_rx) = sync_channel::<Ingress>(64);
        let (b_tx, b_rx) = sync_channel::<(Batch, Vec<Responder>)>(16);
        let loop_cfg = cfg.clone();
        let handle = std::thread::spawn(move || batcher_loop(in_rx, b_tx, &loop_cfg));

        // steady trickle: 50 requests, one every 2ms (inter-arrival far
        // below flush_us) — the old loop only flushed after the last send
        let mut keep_alive = Vec::new();
        for id in 0..50u64 {
            let (tx, rx) = std::sync::mpsc::channel();
            keep_alive.push(rx);
            let req = Request { id, tokens: vec![2, 3], gen_tokens: 0, arrived: Instant::now() };
            in_tx.send(Ingress::Req(req, tx)).unwrap();
            std::thread::sleep(Duration::from_millis(2));
        }
        drop(in_tx); // disconnect -> final drain

        let mut batches = Vec::new();
        while let Ok((batch, rs)) = b_rx.recv_timeout(Duration::from_secs(5)) {
            assert_eq!(batch.len(), rs.len(), "responders must track requests");
            batches.push(batch);
        }
        handle.join().unwrap();

        // every request accounted for, FIFO order preserved
        let ids: Vec<u64> = batches.iter().flat_map(|b| b.requests.iter().map(|r| r.id)).collect();
        assert_eq!(ids, (0..50).collect::<Vec<u64>>());
        // the fix: the first flush happens at the ~20ms deadline (a dozen
        // requests in), not after the full 100ms trickle
        assert!(batches.len() >= 2, "single batch => oldest request starved");
        assert!(
            batches[0].len() < 40,
            "first flush held {} requests — deadline ignored under trickle",
            batches[0].len()
        );
        assert_eq!(batches[0].requests[0].id, 0);
    }

    /// End-to-end native serving: batcher -> worker -> batched engine.
    #[test]
    fn native_server_round_trip_under_concurrency() {
        let cfg = serve_cfg(4, 500);
        let model_cfg = NativeMlmConfig::from_tag(&cfg.model);
        let server =
            Arc::new(Server::start_native(cfg, model_cfg, 2).expect("native server"));
        std::thread::scope(|s| {
            for c in 0..3u64 {
                let server = server.clone();
                s.spawn(move || {
                    for r in 0..4u64 {
                        let len = 8 + ((c * 7 + r) % 40) as usize;
                        let toks: Vec<i32> = (0..len).map(|t| 4 + (t as i32 % 60)).collect();
                        let resp = server.infer(toks.clone()).expect("infer");
                        assert_eq!(resp.predictions.len(), toks.len());
                        assert!(resp.predictions.iter().all(|&p| p >= 0 && p < 64));
                    }
                });
            }
        });
        assert_eq!(server.metrics.requests.load(Ordering::Relaxed), 12);
        assert!(server.metrics.batches.load(Ordering::Relaxed) >= 1);
        if let Ok(s) = Arc::try_unwrap(server) {
            s.shutdown();
        }
    }

    /// Generation requests ride the same batcher: prompt in, greedy token
    /// stream out, identical to the direct (serverless) decode path.
    #[test]
    fn native_lm_server_generates_through_the_batcher() {
        let cfg = serve_cfg(4, 500);
        let model_cfg = NativeMlmConfig::from_tag(&cfg.model);
        let server = Server::start_native_lm(cfg, model_cfg.clone(), 2).expect("lm server");
        let resp = server.generate(vec![2, 9, 11], 4).expect("generate");
        assert_eq!(resp.predictions.len(), 4);
        assert!(resp.predictions.iter().all(|&t| t >= 0 && (t as usize) < 64));
        // bitwise identical to the direct model path (deterministic decode)
        let direct = NativeLm::new(model_cfg, 2).generate(&[2, 9, 11], 4).unwrap();
        assert_eq!(resp.predictions, direct);
        // infer() on an LM server decodes a single next token
        let one = server.infer(vec![2, 9]).expect("infer");
        assert_eq!(one.predictions.len(), 1);
        // prompts that cannot fit the requested continuation error cleanly
        let err = server.generate(vec![2; 64], 8).unwrap_err();
        assert!(format!("{err:#}").contains("seq_len"), "{err:#}");
        server.shutdown();
    }

    /// The session server answers the same API as the fixed-round LM
    /// server, bitwise identically, and reports prefix-cache reuse for a
    /// repeated prompt in its stats.
    #[test]
    fn session_server_matches_fixed_round_and_reports_cache_hits() {
        use crate::config::SessionConfig;
        let cfg = serve_cfg(4, 500);
        let model_cfg = NativeMlmConfig::from_tag(&cfg.model);
        let scfg = SessionConfig { total_pages: 512, free_watermark: 8, ..Default::default() };
        let server =
            Server::start_native_lm_sessions(cfg.clone(), model_cfg.clone(), 2, scfg)
                .expect("session server");
        // longer than one block (32 for this tag) so the repeat can hit
        let prompt: Vec<i32> = (0..40).map(|i| 2 + (i as i32 * 7) % 60).collect();
        let resp = server.generate(prompt.clone(), 4).expect("generate");
        // bitwise identical to the direct model path and the batcher path
        let direct = NativeLm::new(model_cfg.clone(), 2).generate(&prompt, 4).unwrap();
        assert_eq!(resp.predictions, direct);
        let fixed = Server::start_native_lm(cfg, model_cfg, 2).expect("lm server");
        let fixed_resp = fixed.generate(prompt.clone(), 4).expect("fixed generate");
        assert_eq!(fixed_resp.predictions, direct);
        fixed.shutdown();
        // repeated prompt: served from shared prefix pages
        let resp2 = server.generate(prompt.clone(), 4).expect("second generate");
        assert_eq!(resp2.predictions, direct);
        assert!(
            server.metrics.prefix_hit_tokens.load(Ordering::Relaxed) >= 16,
            "{}",
            server.metrics.summary()
        );
        assert!(server.metrics.summary().contains("sessions="), "stats must surface sessions");
        // infer() decodes one token, errors stay clean
        let one = server.infer(vec![2, 9]).expect("infer");
        assert_eq!(one.predictions.len(), 1);
        let err = server.generate(vec![2; 64], 8).unwrap_err();
        assert!(format!("{err:#}").contains("seq_len"), "{err:#}");
        server.shutdown();
    }

    /// Over-long requests error cleanly instead of poisoning the batch
    /// pipeline for other requests.
    #[test]
    fn native_server_rejects_oversized_requests() {
        let cfg = serve_cfg(2, 300);
        let model_cfg = NativeMlmConfig::from_tag(&cfg.model);
        let server = Server::start_native(cfg, model_cfg, 1).expect("native server");
        let err = server.infer(vec![2; 65]).unwrap_err();
        assert!(format!("{err:#}").contains("seq_len"), "{err:#}");
        // server still serves well-formed requests afterwards
        let ok = server.infer(vec![2, 9, 11]).expect("infer after error");
        assert_eq!(ok.predictions.len(), 3);
        server.shutdown();
    }
}
