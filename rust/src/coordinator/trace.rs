//! Flight recorder: a lock-light, fixed-capacity ring buffer of typed
//! scheduler/session events (DESIGN.md §14).
//!
//! Every consequential scheduler decision — admission, prefill chunking,
//! decode commits, preemption, readmission, radix hits, budget resizes,
//! stream stalls, deadline expiry, completion — is recorded as one
//! [`TraceEvent`] stamped with the scheduler step index and the injected
//! [`StepClock`](crate::coordinator::autotune::StepClock) time (never a
//! wall clock read in core code, so the `no-wallclock` lint surface stays
//! clean and `ManualClock` tests can drive fully deterministic traces).
//!
//! Recording is **allocation-free** per event: the ring is preallocated
//! at construction, events are `Copy` (no strings, no boxing), and a
//! record is one uncontended mutex lock + a slot overwrite.  When the ring
//! is full the oldest record is overwritten ([`FlightRecorder::dropped`]
//! counts the overwrites) — a flight recorder keeps the *recent* past, the
//! regime where "why was this token late?" questions get asked.
//!
//! Dump the ring as JSON-lines ([`FlightRecorder::dump_jsonl`]) and
//! reconstruct any request's full timeline offline
//! (`scripts/trace_summarize.py`): admit → prefill chunks → first token →
//! preemptions → finish.

use std::sync::Mutex;

/// Why the scheduler preempted a running session (DESIGN.md §11).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PreemptReason {
    /// The next step's page reservation could not be satisfied even after
    /// cache eviction — the lowest-priority victim released its pages.
    Pages,
    /// A prefill chunk tore mid-layer on pool exhaustion; the session was
    /// poisoned and requeued for recompute.
    TornPrefill,
    /// A decode step could not get a page for this session; the session
    /// was poisoned and requeued for recompute.
    StarvedDecode,
}

impl PreemptReason {
    /// Stable lowercase name used in the JSON-lines dump.
    pub fn as_str(self) -> &'static str {
        match self {
            PreemptReason::Pages => "pages",
            PreemptReason::TornPrefill => "torn-prefill",
            PreemptReason::StarvedDecode => "starved-decode",
        }
    }
}

/// One typed scheduler/session event.  All variants are `Copy` — no heap
/// allocation ever rides a record call.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum TraceEvent {
    /// A waiting request was admitted and began its prefill.
    Admit {
        /// Server-assigned request id.
        id: u64,
        /// Prompt length at admission.
        prompt_tokens: u32,
    },
    /// A previously preempted request re-entered the running set
    /// (recompute-on-readmit replays its generated suffix).
    Readmit {
        /// Server-assigned request id.
        id: u64,
        /// Generated tokens replayed into the rebuilt session.
        replay_tokens: u32,
    },
    /// One planned prefill chunk completed.
    PrefillChunk {
        /// Server-assigned request id.
        id: u64,
        /// Prompt tokens fed by this chunk.
        tokens: u32,
        /// True when the chunk grew from budget re-offered by sessions
        /// that could not use their fair share this step.
        reoffered: bool,
    },
    /// One decode step committed a token for this session.
    Decode {
        /// Server-assigned request id.
        id: u64,
        /// The committed token id.
        token: i32,
    },
    /// A running session was preempted (pages released, request requeued).
    Preempt {
        /// Server-assigned request id (the victim).
        id: u64,
        /// What forced the preemption.
        reason: PreemptReason,
    },
    /// Cold KV pages of a decode-phase session were demoted to the
    /// configured compressed format under memory pressure — the reclaim
    /// the scheduler tries after cache eviction and before preemption.
    PageDemote {
        /// Server-assigned request id (the session whose pages shrank).
        id: u64,
        /// Pages demoted in this pass.
        pages: u32,
    },
    /// Admission found a radix-cached prompt prefix and shared its pages.
    RadixHit {
        /// Server-assigned request id.
        id: u64,
        /// Prompt tokens served from shared pages instead of recomputed.
        cached_tokens: u32,
    },
    /// The AIMD prefill-budget controller resized the live chunk budget.
    AutotuneResize {
        /// Budget (tokens/step) before the resize.
        old: u32,
        /// Budget (tokens/step) after the resize.
        new: u32,
    },
    /// A token could not be streamed this step (bounded per-request
    /// buffer full); it is retried next step, the scheduler never blocks.
    StreamStall {
        /// Server-assigned request id.
        id: u64,
    },
    /// A waiting request missed its admission deadline and was rejected.
    Expire {
        /// Server-assigned request id.
        id: u64,
    },
    /// A request finished and its response was sent.
    Finish {
        /// Server-assigned request id.
        id: u64,
        /// Total generated tokens in the response.
        generated: u32,
    },
    /// End-of-step marker carrying the per-phase time attribution of one
    /// full scheduler step (µs, [`crate::coordinator::metrics::StepPhase`]
    /// order: ingress, admission, reserve, prefill-attend, decode-attend,
    /// logits, stream-egress).
    StepEnd {
        /// Per-phase elapsed µs in `StepPhase::ALL` order.
        phases: [u32; 7],
        /// Total step elapsed µs (phases plus scheduler glue, so the
        /// phase sum is within one histogram bucket of this — gated in
        /// `benches/bench_serve.rs`).
        total_us: u32,
    },
}

/// One recorded ring slot: the event plus its step index and clock stamp.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TraceRecord {
    /// Scheduler step counter when the event was recorded.
    pub step: u64,
    /// Injected-clock microseconds when the event was recorded.
    pub at_us: u64,
    /// The event itself.
    pub event: TraceEvent,
}

impl TraceRecord {
    /// Render this record as one JSON line (no trailing newline).
    pub fn to_jsonl(&self) -> String {
        let head = format!("{{\"step\":{},\"us\":{}", self.step, self.at_us);
        let body = match self.event {
            TraceEvent::Admit { id, prompt_tokens } => {
                format!(",\"ev\":\"Admit\",\"id\":{id},\"prompt_tokens\":{prompt_tokens}")
            }
            TraceEvent::Readmit { id, replay_tokens } => {
                format!(",\"ev\":\"Readmit\",\"id\":{id},\"replay_tokens\":{replay_tokens}")
            }
            TraceEvent::PrefillChunk { id, tokens, reoffered } => format!(
                ",\"ev\":\"PrefillChunk\",\"id\":{id},\"tokens\":{tokens},\"reoffered\":{reoffered}"
            ),
            TraceEvent::Decode { id, token } => {
                format!(",\"ev\":\"Decode\",\"id\":{id},\"token\":{token}")
            }
            TraceEvent::Preempt { id, reason } => {
                format!(",\"ev\":\"Preempt\",\"id\":{id},\"reason\":\"{}\"", reason.as_str())
            }
            TraceEvent::PageDemote { id, pages } => {
                format!(",\"ev\":\"PageDemote\",\"id\":{id},\"pages\":{pages}")
            }
            TraceEvent::RadixHit { id, cached_tokens } => {
                format!(",\"ev\":\"RadixHit\",\"id\":{id},\"cached_tokens\":{cached_tokens}")
            }
            TraceEvent::AutotuneResize { old, new } => {
                format!(",\"ev\":\"AutotuneResize\",\"old\":{old},\"new\":{new}")
            }
            TraceEvent::StreamStall { id } => format!(",\"ev\":\"StreamStall\",\"id\":{id}"),
            TraceEvent::Expire { id } => format!(",\"ev\":\"Expire\",\"id\":{id}"),
            TraceEvent::Finish { id, generated } => {
                format!(",\"ev\":\"Finish\",\"id\":{id},\"generated\":{generated}")
            }
            TraceEvent::StepEnd { phases, total_us } => {
                let mut p = String::new();
                for (i, v) in phases.iter().enumerate() {
                    if i > 0 {
                        p.push(',');
                    }
                    p.push_str(&v.to_string());
                }
                format!(",\"ev\":\"StepEnd\",\"phases\":[{p}],\"total_us\":{total_us}")
            }
        };
        format!("{head}{body}}}")
    }
}

/// Event sink abstraction: the scheduler records through this, so a
/// disabled trace costs one branch (`enabled() == false` — in practice
/// the scheduler holds `Option<Arc<FlightRecorder>>` and a `None` is the
/// zero-cost disabled form).
pub trait TraceSink: Send + Sync {
    /// Record one event stamped with the step index and clock time.
    fn record(&self, step: u64, at_us: u64, event: TraceEvent);
    /// Whether records are kept at all (lets callers skip event assembly).
    fn enabled(&self) -> bool {
        true
    }
}

/// A sink that drops everything — the explicit disabled form for tests
/// and generic callers.
#[derive(Clone, Copy, Debug, Default)]
pub struct NullSink;

impl TraceSink for NullSink {
    fn record(&self, _step: u64, _at_us: u64, _event: TraceEvent) {}

    fn enabled(&self) -> bool {
        false
    }
}

/// Ring state behind the recorder's mutex: preallocated slots, a write
/// head, the live length and the overwrite count.
struct Ring {
    slots: Vec<TraceRecord>,
    /// Next write index.
    head: usize,
    /// Live records (`<= slots.len()`).
    len: usize,
    /// Records overwritten after the ring filled.
    dropped: u64,
}

/// The flight recorder: a fixed-capacity overwrite-oldest ring of
/// [`TraceRecord`]s (see the module docs for semantics).
///
/// Sharing: the scheduler thread records, any thread may snapshot/dump —
/// a single uncontended `Mutex` is cheaper here than per-slot atomics
/// (one writer, rare readers), and `record` stays allocation-free
/// (enforced by `cargo xtask lint` hot-path-alloc).
pub struct FlightRecorder {
    inner: Mutex<Ring>,
}

impl FlightRecorder {
    /// A recorder with `capacity` preallocated slots (clamped to >= 1).
    pub fn new(capacity: usize) -> Self {
        let cap = capacity.max(1);
        let mut slots = Vec::with_capacity(cap);
        slots.resize(cap, TraceRecord { step: 0, at_us: 0, event: TraceEvent::Expire { id: 0 } });
        FlightRecorder { inner: Mutex::new(Ring { slots, head: 0, len: 0, dropped: 0 }) }
    }

    /// Ring capacity in records.
    pub fn capacity(&self) -> usize {
        self.lock().slots.len()
    }

    /// Live records currently held.
    pub fn len(&self) -> usize {
        self.lock().len
    }

    /// True when nothing has been recorded yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Records overwritten because the ring was full.
    pub fn dropped(&self) -> u64 {
        self.lock().dropped
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Ring> {
        // the ring holds plain data; a poisoned lock cannot leave it in a
        // state worse than a torn-off trace, so recover the guard
        self.inner.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
    }

    /// Record one event.  Allocation-free: one uncontended lock, one slot
    /// overwrite (the oldest record when the ring is full).
    pub fn record(&self, step: u64, at_us: u64, event: TraceEvent) {
        let mut ring = self.lock();
        let cap = ring.slots.len();
        let head = ring.head;
        ring.slots[head] = TraceRecord { step, at_us, event };
        ring.head = (head + 1) % cap;
        if ring.len < cap {
            ring.len += 1;
        } else {
            ring.dropped += 1;
        }
    }

    /// Snapshot the live records in chronological (oldest-first) order.
    pub fn records(&self) -> Vec<TraceRecord> {
        let ring = self.lock();
        let cap = ring.slots.len();
        let start = (ring.head + cap - ring.len) % cap;
        (0..ring.len).map(|k| ring.slots[(start + k) % cap]).collect()
    }

    /// Dump the live records as JSON-lines (chronological, one event per
    /// line, trailing newline) — the offline-analysis format
    /// `scripts/trace_summarize.py` consumes.
    pub fn dump_jsonl(&self) -> String {
        let mut out = String::new();
        for r in self.records() {
            out.push_str(&r.to_jsonl());
            out.push('\n');
        }
        out
    }
}

impl TraceSink for FlightRecorder {
    fn record(&self, step: u64, at_us: u64, event: TraceEvent) {
        FlightRecorder::record(self, step, at_us, event);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_overwrites_oldest_and_counts_drops() {
        let rec = FlightRecorder::new(4);
        assert_eq!(rec.capacity(), 4);
        assert!(rec.is_empty());
        for i in 0..6u64 {
            rec.record(i, i * 10, TraceEvent::Decode { id: i, token: i as i32 });
        }
        assert_eq!(rec.len(), 4);
        assert_eq!(rec.dropped(), 2);
        let recs = rec.records();
        let steps: Vec<u64> = recs.iter().map(|r| r.step).collect();
        assert_eq!(steps, vec![2, 3, 4, 5], "oldest two overwritten, order chronological");
        assert_eq!(recs[0].at_us, 20);
    }

    #[test]
    fn capacity_is_clamped_to_one() {
        let rec = FlightRecorder::new(0);
        assert_eq!(rec.capacity(), 1);
        rec.record(1, 1, TraceEvent::Expire { id: 7 });
        rec.record(2, 2, TraceEvent::Expire { id: 8 });
        assert_eq!(rec.len(), 1);
        assert_eq!(rec.dropped(), 1);
        assert_eq!(rec.records()[0].step, 2);
    }

    #[test]
    fn jsonl_covers_every_event_shape() {
        let rec = FlightRecorder::new(16);
        let events = [
            TraceEvent::Admit { id: 1, prompt_tokens: 40 },
            TraceEvent::Readmit { id: 1, replay_tokens: 3 },
            TraceEvent::PrefillChunk { id: 1, tokens: 32, reoffered: true },
            TraceEvent::Decode { id: 1, token: 9 },
            TraceEvent::Preempt { id: 1, reason: PreemptReason::Pages },
            TraceEvent::PageDemote { id: 1, pages: 4 },
            TraceEvent::RadixHit { id: 2, cached_tokens: 32 },
            TraceEvent::AutotuneResize { old: 256, new: 128 },
            TraceEvent::StreamStall { id: 3 },
            TraceEvent::Expire { id: 4 },
            TraceEvent::Finish { id: 1, generated: 12 },
            TraceEvent::StepEnd { phases: [1, 2, 3, 4, 5, 6, 7], total_us: 30 },
        ];
        for (i, ev) in events.iter().enumerate() {
            rec.record(i as u64, i as u64, *ev);
        }
        let dump = rec.dump_jsonl();
        assert_eq!(dump.lines().count(), events.len());
        for needle in [
            "\"ev\":\"Admit\",\"id\":1,\"prompt_tokens\":40",
            "\"ev\":\"Readmit\",\"id\":1,\"replay_tokens\":3",
            "\"ev\":\"PrefillChunk\",\"id\":1,\"tokens\":32,\"reoffered\":true",
            "\"ev\":\"Decode\",\"id\":1,\"token\":9",
            "\"ev\":\"Preempt\",\"id\":1,\"reason\":\"pages\"",
            "\"ev\":\"PageDemote\",\"id\":1,\"pages\":4",
            "\"ev\":\"RadixHit\",\"id\":2,\"cached_tokens\":32",
            "\"ev\":\"AutotuneResize\",\"old\":256,\"new\":128",
            "\"ev\":\"StreamStall\",\"id\":3",
            "\"ev\":\"Expire\",\"id\":4",
            "\"ev\":\"Finish\",\"id\":1,\"generated\":12",
            "\"ev\":\"StepEnd\",\"phases\":[1,2,3,4,5,6,7],\"total_us\":30",
        ] {
            assert!(dump.contains(needle), "missing {needle} in {dump}");
        }
        // every line is minimally well-formed JSON (balanced braces, no
        // trailing comma) — the real parser check lives in
        // scripts/trace_summarize.py's CI run
        for line in dump.lines() {
            assert!(line.starts_with('{') && line.ends_with('}'), "{line}");
            assert!(!line.contains(",}"), "{line}");
        }
    }

    #[test]
    fn null_sink_reports_disabled() {
        let sink = NullSink;
        assert!(!sink.enabled());
        sink.record(1, 2, TraceEvent::Expire { id: 0 });
        let rec = FlightRecorder::new(4);
        assert!(TraceSink::enabled(&rec));
        TraceSink::record(&rec, 1, 2, TraceEvent::Expire { id: 0 });
        assert_eq!(rec.len(), 1);
    }
}
