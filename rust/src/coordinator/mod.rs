//! Layer-3 coordinator: the serving/training control plane that owns the
//! request path (Python never appears here — only AOT artifacts executed
//! through [`crate::runtime`]).
//!
//! * [`metrics`] — latency histograms + throughput counters.
//! * [`batcher`] — dynamic batching with deadline flush.
//! * [`router`]  — sequence-length / batch-size bucket routing + padding.
//! * [`server`]  — thread/worker serving loop with backpressure.
//! * [`trainer`] — training driver over the AOT `train_step` artifacts.

pub mod batcher;
pub mod metrics;
pub mod router;
pub mod server;
pub mod trainer;

pub use batcher::{Batch, Batcher, Request};
pub use metrics::Metrics;
pub use router::Router;
pub use server::Server;
pub use trainer::Trainer;
