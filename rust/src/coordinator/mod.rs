//! Layer-3 coordinator: the serving/training control plane that owns the
//! request path (Python never appears here — only AOT artifacts executed
//! through [`crate::runtime`], or the native batched engine when artifacts
//! are absent).
//!
//! * [`metrics`] — latency histograms + throughput counters + the
//!   session-serving gauges (free pages, cache occupancy, prefix hits),
//!   including the per-phase step-timing histograms.
//! * [`expose`] — Prometheus text exposition over [`Metrics`] and the
//!   typed [`MetricsSnapshot`] for programmatic scrapers.
//! * [`trace`] — the flight recorder: a fixed-capacity ring of typed
//!   scheduler events ([`TraceEvent`]) stamped with step index and the
//!   injected clock, dumpable as JSON lines.
//! * [`autotune`] — the AIMD prefill-budget controller behind the fused
//!   scheduler step, with its injectable [`StepClock`].
//! * [`batcher`] — dynamic batching with deadline flush (fixed rounds).
//! * [`scheduler`] — continuous batching for LM sessions: admission
//!   against page watermarks, per-step join/leave, preemption with
//!   recompute-on-readmit, radix prefix-cache management.
//! * [`router`]  — sequence-length / batch-size bucket routing + padding.
//! * [`server`]  — thread/worker serving loop with backpressure, over the
//!   artifact runtime or the native engine fallback (MLM inference and
//!   causal-LM generation share the batcher).
//! * [`native`]  — deterministic native models on the batched engine:
//!   [`NativeMlm`] (bidirectional) and [`NativeLm`] (causal scoring +
//!   incremental decode).
//! * [`trainer`] — training driver over the AOT `train_step` artifacts,
//!   plus a native batched-engine evaluation fallback.
//!
//! This module is the crate's serving API surface, so every public item
//! must carry documentation (`missing_docs` is enforced below and CI
//! builds the docs with `-D warnings`).

#![warn(missing_docs)]

pub mod autotune;
pub mod batcher;
pub mod expose;
pub mod metrics;
pub mod native;
pub mod router;
pub mod scheduler;
pub mod server;
pub mod trace;
pub mod trainer;

pub use autotune::{AutotuneBudget, FrozenClock, ManualClock, MonotonicClock, StepClock};
pub use batcher::{Batch, Batcher, Request, PRIORITY_NORMAL};
pub use expose::MetricsSnapshot;
pub use metrics::{Histogram, HistogramSnapshot, Metrics, StepPhase};
pub use native::{LmSession, NativeLm, NativeMlm, NativeMlmConfig, StepPhases};
pub use trace::{FlightRecorder, NullSink, PreemptReason, TraceEvent, TraceRecord, TraceSink};
pub use router::Router;
pub use scheduler::SessionConfig;
pub use server::{GenOptions, Response, Server, TokenStream};
pub use trainer::Trainer;
