//! Native-fallback models: deterministic (untrained) mini-transformers
//! whose attention runs through the batched engine
//! ([`crate::engine::Engine`]).
//!
//! When `artifacts/` has not been built (or the crate is compiled without
//! the `pjrt` feature), the serving coordinator cannot execute AOT HLO —
//! these models keep the whole request path (batcher -> workers -> batched
//! multi-head attention -> predictions) exercisable end to end on pure
//! CPU.  Weights are derived from a seed, so predictions are reproducible
//! across runs and across engine thread counts (the MRA-2 parallel path is
//! bitwise deterministic).
//!
//! Two heads share one weight core ([`NativeCore`]):
//!
//! * [`NativeMlm`] — bidirectional attention, per-position MLM argmax.
//! * [`NativeLm`]  — causal attention: a batch scoring path through the
//!   engine's causal kernels, plus an incremental greedy decode path over
//!   per-(layer, head) [`DecodeState`] KV caches (DESIGN.md §7).

use anyhow::{bail, Result};

use crate::data::corpus::MlmBatch;
use crate::engine::{kernel_by_name, pool, BatchedTensor, DecodeState, Engine};
use crate::mra::Variant;
use crate::tensor::{kernel, mat::dot, ops, Mat, Rng};

/// Shape/knob description of the native models, parseable from the model
/// tags used by the artifact grid (`mlm_mra2_n128_d128_l2_h2_v512`;
/// `lm_...` tags parse identically — the prefix only picks the serving
/// path).
#[derive(Clone, Debug)]
pub struct NativeMlmConfig {
    pub vocab: usize,
    pub seq_len: usize,
    pub d_model: usize,
    pub heads: usize,
    pub layers: usize,
    /// MRA-2 block size (clamped to divide `seq_len`).
    pub block: usize,
    /// MRA refinement budget; 0 = auto (`2 * seq_len / block`).
    pub budget: usize,
    /// Attention kernel short name: `mra2`, `mra2s` or `exact` (the LM
    /// path maps these onto their `-causal` siblings).
    pub attention: String,
    pub seed: u64,
}

impl Default for NativeMlmConfig {
    fn default() -> Self {
        NativeMlmConfig {
            vocab: 512,
            seq_len: 128,
            d_model: 128,
            heads: 2,
            layers: 2,
            block: 32,
            budget: 0,
            attention: "mra2".to_string(),
            seed: 0x5EED,
        }
    }
}

impl NativeMlmConfig {
    /// Parse an artifact model tag (`mlm_mra2_n128_d128_l2_h2_v512`);
    /// unrecognized segments keep their defaults.
    pub fn from_tag(tag: &str) -> Self {
        let mut cfg = Self::default();
        for seg in tag.split('_') {
            match seg {
                "exact" | "mra2" | "mra2s" => cfg.attention = seg.to_string(),
                _ => {
                    if let Some(v) = seg.strip_prefix('n').and_then(|s| s.parse::<usize>().ok()) {
                        cfg.seq_len = v;
                    } else if let Some(v) =
                        seg.strip_prefix('d').and_then(|s| s.parse::<usize>().ok())
                    {
                        cfg.d_model = v;
                    } else if let Some(v) =
                        seg.strip_prefix('l').and_then(|s| s.parse::<usize>().ok())
                    {
                        cfg.layers = v;
                    } else if let Some(v) =
                        seg.strip_prefix('h').and_then(|s| s.parse::<usize>().ok())
                    {
                        cfg.heads = v;
                    } else if let Some(v) =
                        seg.strip_prefix('v').and_then(|s| s.parse::<usize>().ok())
                    {
                        cfg.vocab = v;
                    }
                }
            }
        }
        cfg
    }

    /// Validate, clamp `block` to divide `seq_len` and resolve the auto
    /// budget — shared by both model constructors.
    fn normalized(mut self) -> Self {
        assert!(self.vocab > 0 && self.seq_len > 0 && self.heads > 0 && self.layers > 0);
        assert_eq!(self.d_model % self.heads, 0, "d_model must split across heads");
        self.block = self.block.min(self.seq_len).max(1);
        while self.seq_len % self.block != 0 {
            self.block /= 2;
        }
        if self.budget == 0 {
            self.budget = 2 * (self.seq_len / self.block);
        }
        self
    }
}

/// Map a kernel short name onto its causal sibling.  Baseline shims
/// (longformer, nystromformer) have no causal form, and an arbitrary name
/// cannot be trusted to be causal — so anything without a known causal
/// sibling maps to the MRA-2 causal default: the LM path must never
/// silently run a bidirectional kernel (tested).
fn causal_kernel_name(name: &str) -> String {
    match name {
        "exact" => "exact-causal".to_string(),
        "mra2" => "mra2-causal".to_string(),
        "mra2s" => "mra2s-causal".to_string(),
        other if other.ends_with("-causal") => other.to_string(),
        _ => "mra2-causal".to_string(),
    }
}

struct LayerWeights {
    wq: Vec<Mat>,
    wk: Vec<Mat>,
    wv: Vec<Mat>,
}

/// Seed-derived weights + batched forward shared by [`NativeMlm`] and
/// [`NativeLm`] — the two differ only in the attention kernel the engine
/// runs (bidirectional vs causal) and in their prediction heads.
struct NativeCore {
    cfg: NativeMlmConfig,
    /// Token embeddings `(vocab, d_model)`; also the tied output head.
    embed: Mat,
    layers: Vec<LayerWeights>,
    engine: Engine,
}

impl NativeCore {
    fn new(cfg: NativeMlmConfig, threads: usize, causal: bool) -> Self {
        let cfg = cfg.normalized();
        let d_head = cfg.d_model / cfg.heads;
        let mut rng = Rng::new(cfg.seed);
        let embed = Mat::randn(cfg.vocab, cfg.d_model, 0.5, &mut rng);
        let proj_scale = 1.0 / (cfg.d_model as f32).sqrt();
        let layers = (0..cfg.layers)
            .map(|_| LayerWeights {
                wq: (0..cfg.heads)
                    .map(|_| Mat::randn(cfg.d_model, d_head, proj_scale, &mut rng))
                    .collect(),
                wk: (0..cfg.heads)
                    .map(|_| Mat::randn(cfg.d_model, d_head, proj_scale, &mut rng))
                    .collect(),
                wv: (0..cfg.heads)
                    .map(|_| Mat::randn(cfg.d_model, d_head, proj_scale, &mut rng))
                    .collect(),
            })
            .collect();
        let name = if causal {
            causal_kernel_name(&cfg.attention)
        } else {
            cfg.attention.clone()
        };
        let fallback = if causal { "mra2-causal" } else { "mra2" };
        // constructors stay infallible for the serving path, but a config
        // typo must surface somewhere — log the descriptive error before
        // falling back instead of swallowing it
        let kernel = match kernel_by_name(&name, cfg.block, cfg.budget) {
            Ok(k) => k,
            Err(e) => {
                eprintln!("warning: {e:#}; falling back to {fallback}");
                kernel_by_name(fallback, cfg.block, cfg.budget)
                    .expect("fallback kernel always resolves")
            }
        };
        let engine = Engine::new(kernel, threads);
        NativeCore { cfg, embed, layers, engine }
    }

    /// Per-sequence logits `(row_len, vocab)` for a batch of token rows
    /// (each `<= seq_len`; shorter rows are PAD-extended internally).
    fn logits(&self, rows: &[Vec<i32>]) -> Result<Vec<Mat>> {
        let n = self.cfg.seq_len;
        let dm = self.cfg.d_model;
        let heads = self.cfg.heads;
        let d_head = dm / heads;
        for (i, row) in rows.iter().enumerate() {
            if row.len() > n {
                bail!("request {i} length {} exceeds seq_len {n}", row.len());
            }
        }
        if rows.is_empty() {
            return Ok(Vec::new());
        }
        let bsz = rows.len();
        // token embedding (PAD = id 0 beyond each row's length)
        let mut hidden: Vec<Mat> = rows
            .iter()
            .map(|row| {
                Mat::from_fn(n, dm, |i, j| {
                    let tok = if i < row.len() { row[i] } else { 0 };
                    let t = (tok.max(0) as usize).min(self.cfg.vocab - 1);
                    self.embed.get(t, j)
                })
            })
            .collect();
        for lw in &self.layers {
            // project every sequence into the batched (b, h, n, d_head)
            // layout — per-(sequence, head) matmuls drain through the same
            // worker pool as the attention itself
            let mut qb = BatchedTensor::zeros(bsz, heads, n, d_head);
            let mut kb = BatchedTensor::zeros(bsz, heads, n, d_head);
            let mut vb = BatchedTensor::zeros(bsz, heads, n, d_head);
            self.project_into(&hidden, &lw.wq, &mut qb);
            self.project_into(&hidden, &lw.wk, &mut kb);
            self.project_into(&hidden, &lw.wv, &mut vb);
            let attn = self.engine.forward(&qb, &kb, &vb);
            // concat heads + residual + layer norm
            for (bi, hmat) in hidden.iter_mut().enumerate() {
                let mut cat = Mat::zeros(n, dm);
                for h in 0..heads {
                    let hv = attn.view(bi, h);
                    for i in 0..n {
                        cat.row_mut(i)[h * d_head..(h + 1) * d_head].copy_from_slice(hv.row(i));
                    }
                }
                *hmat = ops::layer_norm_rows(&cat.add(hmat), 1e-5);
            }
        }
        // tied output head: logits = hidden @ embed^T, truncated per row —
        // the largest matmul of the forward (n * d_model * vocab), one task
        // per sequence
        let mut logits: Vec<Option<Mat>> = Vec::with_capacity(bsz);
        logits.resize_with(bsz, || None);
        let slots = logits.iter_mut().enumerate().collect::<Vec<_>>();
        pool::run(self.engine.threads(), slots, |(bi, slot): (usize, &mut Option<Mat>)| {
            *slot = Some(hidden[bi].matmul_transb(&self.embed).row_block(0, rows[bi].len()));
        });
        Ok(logits.into_iter().map(|m| m.expect("logit slot filled")).collect())
    }

    /// Project every `(sequence, head)` pair (`hidden[bi] @ w[h]`) into the
    /// batched tensor, parallel over the engine's worker pool.
    fn project_into(&self, hidden: &[Mat], w: &[Mat], out: &mut BatchedTensor) {
        let heads = out.heads;
        let head_len = out.head_len();
        let tasks = out.data.chunks_mut(head_len).enumerate().collect::<Vec<_>>();
        pool::run(self.engine.threads(), tasks, |(p, chunk): (usize, &mut [f32])| {
            let (bi, h) = (p / heads, p % heads);
            chunk.copy_from_slice(&hidden[bi].matmul(&w[h]).data);
        });
    }
}

/// Deterministic native MLM forward pass over the batched engine.
pub struct NativeMlm {
    core: NativeCore,
}

impl NativeMlm {
    /// Build the model with `threads` engine workers.
    pub fn new(cfg: NativeMlmConfig, threads: usize) -> Self {
        NativeMlm { core: NativeCore::new(cfg, threads, false) }
    }

    pub fn config(&self) -> &NativeMlmConfig {
        &self.core.cfg
    }

    pub fn kernel_name(&self) -> String {
        self.core.engine.kernel_name()
    }

    /// Per-sequence MLM logits `(row_len, vocab)` for a batch of token
    /// rows (each `<= seq_len`; shorter rows are PAD-extended internally).
    pub fn logits(&self, rows: &[Vec<i32>]) -> Result<Vec<Mat>> {
        self.core.logits(rows)
    }

    /// Per-position argmax token predictions for each row.
    pub fn predict(&self, rows: &[Vec<i32>]) -> Result<Vec<Vec<i32>>> {
        Ok(self
            .logits(rows)?
            .iter()
            .map(|lg| (0..lg.rows).map(|i| ops::argmax(lg.row(i)) as i32).collect())
            .collect())
    }

    /// Masked-LM cross-entropy loss and accuracy of the (untrained) model
    /// on one corpus batch — the native analog of the AOT `eval_*`
    /// artifacts, used by `Trainer::eval_native`.
    pub fn masked_eval(&self, batch: &MlmBatch) -> Result<(f32, f32)> {
        let n = batch.seq_len;
        if n != self.core.cfg.seq_len {
            bail!("batch seq_len {n} != model seq_len {}", self.core.cfg.seq_len);
        }
        let rows: Vec<Vec<i32>> = batch.input_ids.chunks(n).map(|c| c.to_vec()).collect();
        let logits = self.logits(&rows)?;
        let mut loss = 0.0f64;
        let mut correct = 0usize;
        let mut count = 0usize;
        for (bi, lg) in logits.iter().enumerate() {
            let probs = ops::softmax_rows(lg);
            for pos in 0..lg.rows {
                let idx = bi * n + pos;
                if batch.weights[idx] <= 0.0 {
                    continue;
                }
                let label = batch.labels[idx].max(0) as usize;
                if label >= self.core.cfg.vocab {
                    continue;
                }
                count += 1;
                loss -= (probs.get(pos, label).max(1e-30) as f64).ln();
                if ops::argmax(probs.row(pos)) == label {
                    correct += 1;
                }
            }
        }
        let count = count.max(1);
        Ok(((loss / count as f64) as f32, correct as f32 / count as f32))
    }
}

/// Deterministic native causal LM — the autoregressive sibling of
/// [`NativeMlm`], sharing its seed-derived weights.
///
/// Two execution paths:
///
/// * [`NativeLm::logits`] — batch scoring through the engine's *causal*
///   kernels (block-level causal plan; training-time parallel form).
/// * [`NativeLm::generate`] — incremental greedy decode through
///   per-(layer, head) [`DecodeState`] KV caches: each new token reuses
///   the pooled pyramid of the prefix instead of re-running full
///   attention, and generation is bitwise reproducible — continuing from
///   a generated prefix equals generating in one call (tested).
pub struct NativeLm {
    core: NativeCore,
    /// Refined complete past blocks per decode step (per-row Alg. 1
    /// budget), derived from the plan budget: `budget / (seq_len /
    /// block)`, at least 1.
    decode_budget: usize,
}

impl NativeLm {
    /// Build the model with `threads` engine workers; `cfg.attention` is
    /// mapped onto its `-causal` sibling.
    pub fn new(cfg: NativeMlmConfig, threads: usize) -> Self {
        let core = NativeCore::new(cfg, threads, true);
        let nb = core.cfg.seq_len / core.cfg.block;
        let decode_budget = (core.cfg.budget / nb.max(1)).max(1);
        NativeLm { core, decode_budget }
    }

    pub fn config(&self) -> &NativeMlmConfig {
        &self.core.cfg
    }

    pub fn kernel_name(&self) -> String {
        self.core.engine.kernel_name()
    }

    /// Refined past blocks per decode step.
    pub fn decode_budget(&self) -> usize {
        self.decode_budget
    }

    /// Per-sequence next-token logits `(row_len, vocab)` under causal
    /// attention (batch scoring path through the engine).
    pub fn logits(&self, rows: &[Vec<i32>]) -> Result<Vec<Mat>> {
        self.core.logits(rows)
    }

    fn variant(&self) -> Variant {
        if self.core.cfg.attention.contains("mra2s") {
            Variant::Sparse
        } else {
            Variant::Full
        }
    }

    /// Greedy generation: prefill the prompt through the decode caches,
    /// then emit `max_new` argmax tokens.  Returns only the generated ids.
    pub fn generate(&self, prompt: &[i32], max_new: usize) -> Result<Vec<i32>> {
        self.generate_with(prompt, max_new, |_, _| {})
    }

    /// [`Self::generate`] with a per-token callback `(position, token)` —
    /// the streaming hook used by `examples/generate.rs` and the serving
    /// path.
    pub fn generate_with(
        &self,
        prompt: &[i32],
        max_new: usize,
        mut on_token: impl FnMut(usize, i32),
    ) -> Result<Vec<i32>> {
        let cfg = &self.core.cfg;
        if prompt.is_empty() {
            bail!("empty prompt");
        }
        if prompt.len() + max_new > cfg.seq_len {
            bail!(
                "prompt {} + {} new tokens exceeds seq_len {}",
                prompt.len(),
                max_new,
                cfg.seq_len
            );
        }
        let d_head = cfg.d_model / cfg.heads;
        let variant = self.variant();
        let mut states: Vec<Vec<DecodeState>> = (0..cfg.layers)
            .map(|_| {
                (0..cfg.heads)
                    .map(|_| DecodeState::new(cfg.block, self.decode_budget, variant, d_head))
                    .collect()
            })
            .collect();
        // prefill: advance the caches over every prompt token, paying the
        // tied-head vocab projection only at the last position
        let mut logits = Vec::new();
        for (pi, &t) in prompt.iter().enumerate() {
            let hidden = self.advance(&mut states, t);
            if pi + 1 == prompt.len() {
                logits = self.project_logits(&hidden);
            }
        }
        let mut out = Vec::with_capacity(max_new);
        for gi in 0..max_new {
            let next = ops::argmax(&logits) as i32;
            out.push(next);
            on_token(prompt.len() + gi, next);
            if gi + 1 < max_new {
                let hidden = self.advance(&mut states, next);
                logits = self.project_logits(&hidden);
            }
        }
        Ok(out)
    }

    /// Tied output head for one position: `hidden @ embed^T`.
    fn project_logits(&self, hidden: &[f32]) -> Vec<f32> {
        (0..self.core.cfg.vocab).map(|tk| dot(hidden, self.core.embed.row(tk))).collect()
    }

    /// One incremental cache advance: embed `tok`, then per layer project
    /// q/k/v for every head, append k/v to that head's KV cache and attend
    /// the newest row.  Heads drain through the engine's worker pool; each
    /// head owns its cache and output slot, so the step is deterministic
    /// at any thread count.  Returns the position's final hidden row (the
    /// vocab projection is separate — prefill skips it; see
    /// [`Self::project_logits`]).
    fn advance(&self, states: &mut [Vec<DecodeState>], tok: i32) -> Vec<f32> {
        let cfg = &self.core.cfg;
        let dm = cfg.d_model;
        let d_head = dm / cfg.heads;
        let t = (tok.max(0) as usize).min(cfg.vocab - 1);
        let mut hidden: Vec<f32> = self.core.embed.row(t).to_vec();
        for (lw, layer_states) in self.core.layers.iter().zip(states.iter_mut()) {
            let mut cat = vec![0.0f32; dm];
            let tasks: Vec<(usize, &mut DecodeState, &mut [f32])> = layer_states
                .iter_mut()
                .zip(cat.chunks_mut(d_head))
                .enumerate()
                .map(|(h, (st, slot))| (h, st, slot))
                .collect();
            let hidden_ref = &hidden;
            pool::run(self.core.engine.threads(), tasks, |(h, st, slot)| {
                let q = row_project(hidden_ref, &lw.wq[h]);
                let k = row_project(hidden_ref, &lw.wk[h]);
                let v = row_project(hidden_ref, &lw.wv[h]);
                st.append(&k, &v);
                // allocation-free steady path: attend straight into the slot
                st.attend_last_into(&q, slot);
            });
            // residual + layer norm on the single row
            for (c, &hv) in cat.iter_mut().zip(hidden.iter()) {
                *c += hv;
            }
            hidden = layer_norm_row(&cat, 1e-5);
        }
        hidden
    }
}

/// `row @ w` for a single row — the decode-path analog of `Mat::matmul`
/// (same k-major accumulation order, same branch-free kernel AXPY: dense
/// embeddings never benefit from a zero-skip, which defeats vectorization).
fn row_project(row: &[f32], w: &Mat) -> Vec<f32> {
    debug_assert_eq!(row.len(), w.rows);
    let mut out = vec![0.0f32; w.cols];
    for (i, &a) in row.iter().enumerate() {
        kernel::axpy(&mut out, w.row(i), a);
    }
    out
}

/// Single-row LayerNorm (gain 1, bias 0) — the decode twin of
/// [`ops::layer_norm_rows`].
fn layer_norm_row(x: &[f32], eps: f32) -> Vec<f32> {
    let n = x.len() as f32;
    let mu: f32 = x.iter().sum::<f32>() / n;
    let var: f32 = x.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / n;
    let inv = 1.0 / (var + eps).sqrt();
    x.iter().map(|v| (v - mu) * inv).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Corpus, CorpusConfig};

    fn small_cfg() -> NativeMlmConfig {
        NativeMlmConfig {
            vocab: 64,
            seq_len: 64,
            d_model: 32,
            heads: 2,
            layers: 1,
            block: 16,
            budget: 0,
            attention: "mra2".to_string(),
            seed: 7,
        }
    }

    #[test]
    fn tag_parsing_covers_the_artifact_grid() {
        let cfg = NativeMlmConfig::from_tag("mlm_mra2s_n256_d64_l3_h4_v1024");
        assert_eq!(cfg.attention, "mra2s");
        assert_eq!(cfg.seq_len, 256);
        assert_eq!(cfg.d_model, 64);
        assert_eq!(cfg.layers, 3);
        assert_eq!(cfg.heads, 4);
        assert_eq!(cfg.vocab, 1024);
        // unknown segments keep defaults
        let d = NativeMlmConfig::from_tag("garbage_tag");
        assert_eq!(d.seq_len, NativeMlmConfig::default().seq_len);
    }

    #[test]
    fn predictions_have_request_shape_and_vocab_range() {
        let model = NativeMlm::new(small_cfg(), 2);
        let rows = vec![vec![2, 5, 9, 11], vec![2; 64], vec![3]];
        let preds = model.predict(&rows).unwrap();
        assert_eq!(preds.len(), 3);
        for (row, p) in rows.iter().zip(&preds) {
            assert_eq!(p.len(), row.len());
            assert!(p.iter().all(|&t| t >= 0 && (t as usize) < 64));
        }
        // over-long requests are rejected, not truncated
        assert!(model.predict(&[vec![0; 65]]).is_err());
    }

    #[test]
    fn deterministic_across_thread_counts() {
        let rows = vec![vec![2, 8, 4, 4, 19, 33], vec![2, 60, 1, 7]];
        let p1 = NativeMlm::new(small_cfg(), 1).predict(&rows).unwrap();
        let p4 = NativeMlm::new(small_cfg(), 4).predict(&rows).unwrap();
        assert_eq!(p1, p4);
    }

    #[test]
    fn masked_eval_is_finite_and_bounded() {
        let model = NativeMlm::new(small_cfg(), 2);
        let mut corpus = Corpus::new(
            CorpusConfig { vocab: 64, seq_len: 64, ..Default::default() },
            3,
        );
        let batch = corpus.mlm_batch(4);
        let (loss, acc) = model.masked_eval(&batch).unwrap();
        assert!(loss.is_finite() && loss > 0.0, "loss={loss}");
        assert!((0.0..=1.0).contains(&acc), "acc={acc}");
    }

    #[test]
    fn block_clamps_to_divide_seq_len() {
        let cfg = NativeMlmConfig { seq_len: 48, block: 32, ..small_cfg() };
        let model = NativeMlm::new(cfg, 1);
        // 32 does not divide 48; halved to 16 which does
        assert_eq!(model.config().block, 16);
        assert!(model.kernel_name().contains("mra-2"));
    }

    #[test]
    fn lm_uses_causal_kernel_and_scores_batches() {
        let model = NativeLm::new(small_cfg(), 2);
        assert!(model.kernel_name().contains("causal"), "{}", model.kernel_name());
        assert!(model.decode_budget() >= 1);
        let lg = model.logits(&[vec![2, 5, 9, 11]]).unwrap();
        assert_eq!(lg.len(), 1);
        assert_eq!((lg[0].rows, lg[0].cols), (4, 64));
    }

    #[test]
    fn lm_never_runs_a_bidirectional_kernel() {
        // regression: baseline shims have no causal sibling — the LM must
        // fall back to causal MRA-2 instead of silently attending to the
        // future through a bidirectional kernel
        for attention in ["longformer", "nystromformer", "garbage"] {
            let cfg = NativeMlmConfig { attention: attention.to_string(), ..small_cfg() };
            let model = NativeLm::new(cfg, 1);
            assert!(
                model.kernel_name().contains("causal"),
                "{attention} resolved to {}",
                model.kernel_name()
            );
        }
    }

    #[test]
    fn lm_generates_within_vocab_and_length() {
        let model = NativeLm::new(small_cfg(), 2);
        let toks = model.generate(&[2, 7, 9], 5).unwrap();
        assert_eq!(toks.len(), 5);
        assert!(toks.iter().all(|&t| t >= 0 && (t as usize) < 64));
        // context-budget and prompt validation
        assert!(model.generate(&[], 3).is_err());
        assert!(model.generate(&[2; 60], 5).is_err()); // 60 + 5 > seq_len 64
    }

    #[test]
    fn lm_generation_deterministic_across_thread_counts() {
        let prompt = vec![2, 8, 4, 19, 33, 5];
        let t1 = NativeLm::new(small_cfg(), 1).generate(&prompt, 8).unwrap();
        let t4 = NativeLm::new(small_cfg(), 4).generate(&prompt, 8).unwrap();
        assert_eq!(t1, t4);
    }

    #[test]
    fn lm_continuation_matches_full_generation() {
        // the acceptance-criterion shape at the model level: incremental
        // decode == recomputing the full causal prefix.  Generating 6
        // tokens in one call must equal generating 3, re-prefilling
        // prompt + those 3 from a fresh cache, and generating 3 more.
        let model = NativeLm::new(small_cfg(), 2);
        let prompt = vec![2, 8, 4, 19];
        let full = model.generate(&prompt, 6).unwrap();
        let first = model.generate(&prompt, 3).unwrap();
        assert_eq!(&first[..], &full[..3]);
        let mut ext = prompt.clone();
        ext.extend_from_slice(&first);
        let rest = model.generate(&ext, 3).unwrap();
        assert_eq!(&rest[..], &full[3..]);
    }

    #[test]
    fn lm_streaming_callback_sees_every_token() {
        let model = NativeLm::new(small_cfg(), 2);
        let mut streamed = Vec::new();
        let toks = model
            .generate_with(&[2, 7], 4, |pos, tok| streamed.push((pos, tok)))
            .unwrap();
        assert_eq!(streamed.len(), 4);
        assert_eq!(streamed.iter().map(|&(_, t)| t).collect::<Vec<_>>(), toks);
        assert_eq!(streamed[0].0, 2); // first generated position
        assert_eq!(streamed[3].0, 5);
    }
}
